"""AOT path sanity: artifacts lower to valid HLO text and the text
round-trips through the XLA parser with correct numerics."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)


def test_hlo_text_is_parseable_and_numerically_correct(tmp_path):
    # Lower the tiny vadv artifact, reload it with the local CPU client,
    # execute, compare against the oracle.
    from jax._src.lib import xla_client as xc

    shapes = (jax.ShapeDtypeStruct((3, 2, 4), "float64"),) * 4
    lowered = jax.jit(model.vadv_model).lower(*shapes)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text

    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.uniform(-0.2, 0.2, (3, 2, 4)))
    b = jnp.asarray(rng.uniform(2.0, 3.0, (3, 2, 4)))
    c = jnp.asarray(rng.uniform(-0.2, 0.2, (3, 2, 4)))
    d = jnp.asarray(rng.uniform(-0.5, 0.5, (3, 2, 4)))
    xr, utr = ref.vadv_ref(a, b, c, d)
    x, ut = model.vadv_model(a, b, c, d)
    np.testing.assert_allclose(x, xr, rtol=1e-12)
    np.testing.assert_allclose(ut, utr, rtol=1e-12)


def test_aot_writes_manifest(tmp_path):
    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--outdir", str(out),
         "--only", "laplace_tiny"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert "laplace_tiny" in manifest
    text = (out / "laplace_tiny.hlo.txt").read_text()
    assert text.startswith("HloModule")


@pytest.mark.parametrize("name", ["vadv_tiny", "laplace_tiny", "matmul_tiny"])
def test_artifact_specs_cover_presets(name):
    names = [n for n, _, _ in aot.artifact_specs()]
    assert name in names
