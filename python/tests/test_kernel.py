"""pytest: Pallas kernels vs pure-jnp oracles — the core L1 correctness
signal. Hypothesis sweeps shapes and dtypes (per-session guidance)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.laplace import laplace
from compile.kernels.matmul import matmul
from compile.kernels.vadv import vadv

jax.config.update("jax_enable_x64", True)


def _rand(shape, seed, lo=-0.5, hi=0.5, dtype="float64"):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(lo, hi, shape), dtype=dtype)


def _vadv_inputs(i, j, k, seed=0, dtype="float64"):
    a = _rand((i, j, k), seed, -0.2, 0.2, dtype)
    b = _rand((i, j, k), seed + 1, 2.0, 3.0, dtype)
    c = _rand((i, j, k), seed + 2, -0.2, 0.2, dtype)
    d = _rand((i, j, k), seed + 3, -0.5, 0.5, dtype)
    return a, b, c, d


class TestVadv:
    def test_matches_ref_tiny(self):
        a, b, c, d = _vadv_inputs(6, 5, 8)
        x, utens = vadv(a, b, c, d)
        xr, utr = ref.vadv_ref(a, b, c, d)
        np.testing.assert_allclose(x, xr, rtol=1e-12)
        np.testing.assert_allclose(utens, utr, rtol=1e-12)

    @settings(max_examples=10, deadline=None)
    @given(
        k=st.integers(2, 12),
        j=st.integers(1, 6),
        i=st.integers(1, 8),
        seed=st.integers(0, 1000),
    )
    def test_shape_sweep(self, k, j, i, seed):
        a, b, c, d = _vadv_inputs(i, j, k, seed)
        x, utens = vadv(a, b, c, d)
        xr, utr = ref.vadv_ref(a, b, c, d)
        np.testing.assert_allclose(x, xr, rtol=1e-11)
        np.testing.assert_allclose(utens, utr, rtol=1e-11)

    @pytest.mark.parametrize("dtype", ["float32", "float64"])
    def test_dtypes(self, dtype):
        a, b, c, d = _vadv_inputs(4, 3, 6, dtype=dtype)
        x, _ = vadv(a, b, c, d)
        xr, _ = ref.vadv_ref(a, b, c, d)
        tol = 1e-5 if dtype == "float32" else 1e-12
        np.testing.assert_allclose(x, xr, rtol=tol)
        assert x.dtype == jnp.dtype(dtype)

    def test_solves_tridiagonal_system(self):
        # x must satisfy the tridiagonal system per column.
        k, j, i = 10, 2, 3
        a, b, c, d = _vadv_inputs(i, j, k, seed=7)
        x, _ = vadv(a, b, c, d)
        x = np.asarray(x)
        a_, b_, c_, d_ = map(np.asarray, (a, b, c, d))
        for jj in range(j):
            for ii in range(i):
                xa, aa = x[ii, jj, :], a_[ii, jj, :]
                bb, cc, dd = b_[ii, jj, :], c_[ii, jj, :], d_[ii, jj, :]
                resid = bb[0] * xa[0] + cc[0] * xa[1] - dd[0]
                assert abs(resid) < 1e-9
                for kk in range(1, k - 1):
                    resid = (
                        aa[kk] * xa[kk - 1]
                        + bb[kk] * xa[kk]
                        + cc[kk] * xa[kk + 1]
                        - dd[kk]
                    )
                    assert abs(resid) < 1e-9


class TestLaplace:
    def test_matches_ref(self):
        g = _rand((14, 16), 3)
        np.testing.assert_allclose(laplace(g), ref.laplace_ref(g), rtol=1e-13)

    @settings(max_examples=10, deadline=None)
    @given(j=st.integers(3, 20), i=st.integers(3, 20), seed=st.integers(0, 100))
    def test_shape_sweep(self, j, i, seed):
        g = _rand((j, i), seed)
        np.testing.assert_allclose(laplace(g), ref.laplace_ref(g), rtol=1e-12)

    def test_boundary_untouched(self):
        g = _rand((10, 10), 5)
        out = np.asarray(laplace(g))
        assert (out[0, :] == 0).all() and (out[-1, :] == 0).all()
        assert (out[:, 0] == 0).all() and (out[:, -1] == 0).all()


class TestMatmul:
    @pytest.mark.parametrize("n", [32, 64, 96])
    def test_matches_ref(self, n):
        a = _rand((n, n), 11)
        b = _rand((n, n), 12)
        np.testing.assert_allclose(matmul(a, b), ref.matmul_ref(a, b), rtol=1e-11)

    def test_identity(self):
        n = 32
        a = _rand((n, n), 13)
        eye = jnp.eye(n, dtype="float64")
        np.testing.assert_allclose(matmul(a, eye), a, rtol=1e-13)

    @pytest.mark.parametrize("dtype", ["float32", "float64"])
    def test_dtypes(self, dtype):
        n = 32
        a = _rand((n, n), 14, dtype=dtype)
        b = _rand((n, n), 15, dtype=dtype)
        tol = 1e-4 if dtype == "float32" else 1e-11
        np.testing.assert_allclose(matmul(a, b), a @ b, rtol=tol)
