"""L2: the jax compute graphs the coordinator AOT-loads, calling the L1
Pallas kernels so everything lowers into one HLO module per artifact.

Build-time only — python never runs on the rust request path.
"""

import jax

from .kernels.laplace import laplace
from .kernels.matmul import matmul
from .kernels.vadv import vadv

jax.config.update("jax_enable_x64", True)


def vadv_model(a, b, c, d):
    """Vertical advection: returns (x, utens) as a tuple."""
    x, utens = vadv(a, b, c, d)
    return (x, utens)


def laplace_model(grid):
    return (laplace(grid),)


def matmul_model(a, b):
    return (matmul(a, b),)
