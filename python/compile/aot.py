"""AOT lowering: jax → HLO *text* artifacts the rust runtime loads.

HLO text (NOT `.serialize()`): jax ≥ 0.5 emits HloModuleProto with 64-bit
instruction ids which the xla crate's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Usage: python -m compile.aot --outdir ../artifacts
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

jax.config.update("jax_enable_x64", True)

DTYPE = "float64"


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def artifact_specs():
    """(name, fn, input shapes) for every artifact. Shapes match the rust
    presets (kernels/*.rs) so the artifacts serve as oracles."""
    vadv_shapes = {
        "tiny": (6, 5, 8),   # (I, J, K) — rust Preset::Tiny, K contiguous
        "small": (32, 32, 45),
    }
    specs = []
    for tag, (i, j, k) in vadv_shapes.items():
        s = jax.ShapeDtypeStruct((i, j, k), DTYPE)
        specs.append((f"vadv_{tag}", model.vadv_model, (s, s, s, s)))
    for tag, (jj, ii) in {"tiny": (12, 14), "small": (254, 254)}.items():
        g = jax.ShapeDtypeStruct((jj + 2, ii + 2), DTYPE)
        specs.append((f"laplace_{tag}", model.laplace_model, (g,)))
    for tag, n in {"tiny": (64), "small": (128)}.items():
        m = jax.ShapeDtypeStruct((n, n), DTYPE)
        specs.append((f"matmul_{tag}", model.matmul_model, (m, m)))
    return specs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated artifact names")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None

    manifest = {}
    for name, fn, shapes in artifact_specs():
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*shapes)
        text = to_hlo_text(lowered)
        path = os.path.join(args.outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "inputs": [list(s.shape) for s in shapes],
            "dtype": DTYPE,
            "path": f"{name}.hlo.txt",
        }
        print(f"wrote {path} ({len(text)} chars)")
    mpath = os.path.join(args.outdir, "manifest.json")
    existing = {}
    if os.path.exists(mpath) and only:
        with open(mpath) as f:
            existing = json.load(f)
    existing.update(manifest)
    with open(mpath, "w") as f:
        json.dump(existing, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
