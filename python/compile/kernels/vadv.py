"""L1 Pallas kernel: vertical-advection Thomas solve.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the (J, I) plane is
the parallel dimension — the Pallas grid walks J so each program instance
holds one (K, 1, I) column slab in VMEM and runs the K recurrence as a
`fori_loop` inside the kernel. That is the TPU analogue of the paper's
"DOALL over I×J, pipeline K". `interpret=True` everywhere: the CPU PJRT
plugin cannot execute Mosaic custom-calls (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)


def _vadv_kernel(a_ref, b_ref, c_ref, d_ref, x_ref, utens_ref):
    # Block shape (1, J, K): move K leading for the recurrence, move back
    # on store.
    import jax.numpy as jnp  # noqa: F811 (kernel-local alias)
    a = jnp.moveaxis(a_ref[...], -1, 0)
    b = jnp.moveaxis(b_ref[...], -1, 0)
    c = jnp.moveaxis(c_ref[...], -1, 0)
    d = jnp.moveaxis(d_ref[...], -1, 0)
    K = a.shape[0]

    cp0 = c[0] / b[0]
    dp0 = d[0] / b[0]
    cp = jnp.zeros_like(a).at[0].set(cp0)
    dp = jnp.zeros_like(a).at[0].set(dp0)
    utens = jnp.zeros_like(a)

    def fwd(k, state):
        cp, dp, utens = state
        den = b[k] - a[k] * cp[k - 1]
        cp_k = c[k] / den
        dp_k = (d[k] - a[k] * dp[k - 1]) / den
        col = 0.25 * a[k] + 0.5 * b[k]
        utens = utens.at[k].set(0.1 * dp_k + col)
        return cp.at[k].set(cp_k), dp.at[k].set(dp_k), utens

    cp, dp, utens = jax.lax.fori_loop(1, K, fwd, (cp, dp, utens))

    x = jnp.zeros_like(a).at[K - 1].set(dp[K - 1])

    def bwd(t, x):
        k = K - 2 - t
        return x.at[k].set(dp[k] - cp[k] * x[k + 1])

    x = jax.lax.fori_loop(0, K - 1, bwd, x)
    x_ref[...] = jnp.moveaxis(x, 0, -1)
    utens_ref[...] = jnp.moveaxis(utens, 0, -1)


@functools.partial(jax.jit, static_argnames=())
def vadv(a, b, c, d):
    """x, utens = vadv(a, b, c, d) over [I, J, K] arrays (K contiguous)."""
    I, J, K = a.shape
    out_shape = (
        jax.ShapeDtypeStruct((I, J, K), a.dtype),
        jax.ShapeDtypeStruct((I, J, K), a.dtype),
    )
    # One (1, J, K) slab per program instance: the whole K column set of
    # one i row lives in VMEM while the recurrence runs.
    spec = pl.BlockSpec((1, J, K), lambda i: (i, 0, 0))
    return pl.pallas_call(
        _vadv_kernel,
        out_shape=out_shape,
        grid=(I,),
        in_specs=[spec] * 4,
        out_specs=(spec, spec),
        interpret=True,
    )(a, b, c, d)
