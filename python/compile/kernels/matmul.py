"""L1 Pallas kernel: tiled matrix multiplication (Table 1's workload).

BlockSpec tiles the (i, j) output space in MXU-friendly 32-aligned blocks
and accumulates over the k grid dimension — the Pallas analogue of the
twice-tiled DaCe recipe the paper optimizes. interpret=True (CPU PJRT).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)

TILE = 32


def _mm_kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += a_ref[...] @ b_ref[...]


@functools.partial(jax.jit, static_argnames=())
def matmul(a, b):
    n = a.shape[0]
    t = min(TILE, n)
    grid = (n // t, n // t, n // t)
    return pl.pallas_call(
        _mm_kernel,
        out_shape=jax.ShapeDtypeStruct((n, n), a.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, t), lambda i, j, k: (i, k)),
            pl.BlockSpec((t, t), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((t, t), lambda i, j, k: (i, j)),
        interpret=True,
    )(a, b)
