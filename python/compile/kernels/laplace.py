"""L1 Pallas kernel: 5-point Laplace stencil (the Fig. 1 computation).

The grid walks row blocks; each instance loads a (BJ+2, I+2) halo slab
into VMEM and produces BJ interior rows. interpret=True (CPU PJRT).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)


def _laplace_kernel(g_ref, out_ref):
    g = g_ref[...]
    lap = (
        4.0 * g[1:-1, 1:-1]
        - g[1:-1, 2:]
        - g[1:-1, :-2]
        - g[2:, 1:-1]
        - g[:-2, 1:-1]
    )
    out = jnp.zeros_like(g)
    out_ref[...] = out.at[1:-1, 1:-1].set(lap)


@functools.partial(jax.jit, static_argnames=())
def laplace(grid):
    """Apply the 5-point operator to a [J+2, I+2] grid (interior only)."""
    return pl.pallas_call(
        _laplace_kernel,
        out_shape=jax.ShapeDtypeStruct(grid.shape, grid.dtype),
        interpret=True,
    )(grid)
