"""Pure-jnp correctness oracles for the Pallas kernels (L1's ground truth).

Every Pallas kernel in this package is checked against these references by
pytest (+ hypothesis shape sweeps) at build time; the lowered HLO artifacts
then serve as numerical oracles for the rust VM (runtime/pjrt.rs).
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)


def vadv_ref(a, b, c, d):
    """Thomas-algorithm vertical advection over an [I, J, K] domain
    (K contiguous, NPBench's layout).

    Forward sweep (cp/dp recurrences across K), a column-scratch output
    stage (utens), and backward substitution (x) — mirroring
    rust/src/kernels/vadv.rs statement for statement.
    """
    # Work K-leading internally; move back at the end.
    a, b, c, d = (jnp.moveaxis(v, -1, 0) for v in (a, b, c, d))
    K = a.shape[0]

    cp0 = c[0] / b[0]
    dp0 = d[0] / b[0]

    def fwd(carry, inputs):
        cp_prev, dp_prev = carry
        ak, bk, ck, dk = inputs
        den = bk - ak * cp_prev
        cp_k = ck / den
        dp_k = (dk - ak * dp_prev) / den
        col = 0.25 * ak + 0.5 * bk
        utens_k = 0.1 * dp_k + col
        return (cp_k, dp_k), (cp_k, dp_k, utens_k)

    (_, _), (cps, dps, utens_rest) = jax.lax.scan(
        fwd, (cp0, dp0), (a[1:], b[1:], c[1:], d[1:])
    )
    cp = jnp.concatenate([cp0[None], cps], axis=0)
    dp = jnp.concatenate([dp0[None], dps], axis=0)
    utens = jnp.concatenate([jnp.zeros_like(a[0])[None], utens_rest], axis=0)

    def bwd(x_next, inputs):
        cp_k, dp_k = inputs
        x_k = dp_k - cp_k * x_next
        return x_k, x_k

    x_last = dp[K - 1]
    _, xs = jax.lax.scan(bwd, x_last, (cp[: K - 1], dp[: K - 1]), reverse=True)
    x = jnp.concatenate([xs, x_last[None]], axis=0)
    return jnp.moveaxis(x, 0, -1), jnp.moveaxis(utens, 0, -1)


def laplace_ref(grid):
    """5-point Laplace operator: 4·center − N − S − E − W on the interior
    of a [J+2, I+2] grid (zero elsewhere), matching Fig. 1's math."""
    lap = (
        4.0 * grid[1:-1, 1:-1]
        - grid[1:-1, 2:]
        - grid[1:-1, :-2]
        - grid[2:, 1:-1]
        - grid[:-2, 1:-1]
    )
    out = jnp.zeros_like(grid)
    return out.at[1:-1, 1:-1].set(lap)


def matmul_ref(a, b):
    """Plain matrix product (the Table 1 workload's semantics)."""
    return a @ b
