//! Bench: Fig. 1 — Laplace with parametric strides, naive vs ptr-inc VM
//! wall-clock + the toolchain-model table. `cargo bench --bench bench_fig1_laplace`

use silo::bench::{black_box, time_budgeted};
use silo::exec::Vm;
use silo::kernels::{self, gen_inputs, laplace, Preset};
use silo::schedules::schedule_all_ptr_inc;
use std::time::Duration;

fn main() {
    println!("{}", silo::coordinator::experiments::run("fig1").unwrap());
    let params = laplace::preset(Preset::Small);
    for ptr_inc in [false, true] {
        let mut p = laplace::build();
        if ptr_inc {
            schedule_all_ptr_inc(&mut p);
        }
        let inputs = gen_inputs(&p, &params, kernels::default_init).unwrap();
        let refs: Vec<_> = inputs.iter().map(|(c, v)| (*c, v.as_slice())).collect();
        let vm = Vm::compile(&p).unwrap();
        let st = time_budgeted(Duration::from_secs(2), || {
            black_box(vm.run(&params, &refs, 1).unwrap());
        });
        println!(
            "laplace_{}: {:.3} ms/iter ({} iters)",
            if ptr_inc { "ptrinc" } else { "naive" },
            st.mean_ms(),
            st.iters
        );
    }
}
