//! Bench: Table 1 — software prefetching on the tiled matmul (trace-driven
//! cache simulation). `cargo bench --bench bench_table1_prefetch`

fn main() {
    println!("{}", silo::coordinator::experiments::run("table1").unwrap());
}
