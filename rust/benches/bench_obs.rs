//! Bench: what observability costs — the plain execution path (tracing
//! compiled away), the instrumented profiled replay, and hardware
//! counters around a run. `cargo bench --bench bench_obs`
//!
//! Emits `BENCH_obs.json` at the repository root. The headline
//! invariant is the off-path: the plain VM run is measured twice and
//! the two samples must agree within noise — observability that is
//! switched off has no business showing up in the run loop. The
//! tracer-on and `--hw` columns quantify the *opt-in* overheads so a
//! regression there is visible in the trajectory, not asserted away.
//!
//! Per-measurement time budget defaults to 200 ms; set
//! `BENCH_OBS_BUDGET_MS` to change it.

use std::time::Duration;

use silo::bench::{black_box, time_budgeted};
use silo::coordinator::{compile_program, MemSchedules, PipelineSpec};
use silo::exec::{ExecLimits, Vm};
use silo::kernels::{resolve, Preset};
use silo::native::Tier;
use silo::obs::{HwGroup, ProfileTracer};
use silo::verify::CheckSet;

const KERNELS: [&str; 3] = ["jacobi_1d", "softmax", "matmul_tiled"];

fn budget() -> Duration {
    let ms = std::env::var("BENCH_OBS_BUDGET_MS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(200);
    Duration::from_millis(ms.max(10))
}

fn main() {
    let hw = silo::obs::perf::available();
    if !hw {
        eprintln!("hardware counters unavailable on this host; hw columns will be null");
    }
    let mut rows = Vec::new();
    let mut worst_noise = 1.0f64;
    println!(
        "{:<16} {:>9} {:>9} {:>11} {:>9} {:>9}",
        "kernel", "off ms", "off2 ms", "profiled ms", "hw ms", "hw over"
    );
    for name in KERNELS {
        let kernel = resolve(name).unwrap();
        let compiled = compile_program(
            kernel.program(),
            &PipelineSpec::parse("cfg1"),
            MemSchedules::default(),
        )
        .unwrap_or_else(|e| panic!("{name}: {e:#}"));
        let params = kernel.params(Preset::Small).unwrap();
        let inputs = kernel.inputs(&compiled.program, &params).unwrap();
        let refs: Vec<_> = inputs.iter().map(|(c, v)| (*c, v.as_slice())).collect();
        let limits = ExecLimits::none();

        // Off path, twice: the second sample is the noise floor the
        // first is judged against.
        let run_off = || {
            time_budgeted(budget(), || {
                black_box(
                    compiled
                        .execute_limited_tier(Tier::Vm, &params, &refs, 1, &limits)
                        .unwrap(),
                );
            })
            .mean_ms()
        };
        let off_ms = run_off();
        let off2_ms = run_off();
        let noise = (off_ms / off2_ms).max(off2_ms / off_ms);
        worst_noise = worst_noise.max(noise);

        // Tracer on: the profiled artifact replayed under ProfileTracer
        // (what `silo profile` pays for per-loop attribution).
        let pvm = Vm::compile_profiled(&compiled.program, &CheckSet::none()).unwrap();
        let profiled_ms = time_budgeted(budget(), || {
            let mut tracer = ProfileTracer::new();
            black_box(
                pvm.run_limited_traced(&params, &refs, 1, &limits, &mut tracer)
                    .unwrap(),
            );
        })
        .mean_ms();

        // `--hw`: the plain run bracketed by a counter window (open +
        // reset/enable + run + disable/read + close per measurement).
        let hw_ms = hw.then(|| {
            time_budgeted(budget(), || {
                let g = HwGroup::open().unwrap();
                g.start().unwrap();
                black_box(
                    compiled
                        .execute_limited_tier(Tier::Vm, &params, &refs, 1, &limits)
                        .unwrap(),
                );
                black_box(g.stop().unwrap());
            })
            .mean_ms()
        });

        let hw_over = hw_ms.map(|h| h / off_ms.min(off2_ms));
        println!(
            "{:<16} {:>9.3} {:>9.3} {:>11.3} {:>9} {:>9}",
            name,
            off_ms,
            off2_ms,
            profiled_ms,
            hw_ms.map_or("-".into(), |v| format!("{v:.3}")),
            hw_over.map_or("-".into(), |v| format!("{v:.2}x")),
        );
        rows.push(format!(
            "    {{\"name\": \"{name}\", \"off_ms\": {off_ms:.4}, \"off2_ms\": {off2_ms:.4}, \
             \"profiled_ms\": {profiled_ms:.4}, \"hw_ms\": {}, \"hw_overhead\": {}, \
             \"profiled_overhead\": {:.3}}}",
            hw_ms.map_or("null".into(), |v| format!("{v:.4}")),
            hw_over.map_or("null".into(), |v| format!("{v:.3}")),
            profiled_ms / off_ms.min(off2_ms),
        ));
    }

    println!("\nworst off-path repeat ratio: {worst_noise:.3}x");
    // Lenient on purpose: CI machines are noisy neighbors. A genuine
    // always-on instrumentation cost shows up as a systematic gap far
    // beyond this bound.
    assert!(
        worst_noise < 1.5,
        "tracer-off runs disagree by {worst_noise:.3}x — the off path is not free"
    );

    let json = format!(
        "{{\n  \"bench\": \"obs\",\n  \"hw_available\": {hw},\n  \"preset\": \"small\",\n  \
         \"worst_off_repeat_ratio\": {worst_noise:.4},\n  \"kernels\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_obs.json");
    match std::fs::write(path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
