//! Bench: the autotuner vs the hand-written configurations — what does
//! `--pipeline auto` pick per kernel, how does its modeled score compare
//! to cfg1/cfg2/cfg3, and how expensive is the search itself.
//!
//! Uses the shared comparison protocol
//! (`tuner::compare_with_named_configs`, the same code path the autotune
//! experiment and acceptance tests run) and emits `BENCH_autotune.json`
//! next to the manifest (hand-rolled JSON; no serde in the vendored set)
//! so future PRs have a machine-readable trajectory of the tuner's
//! decisions.
//!
//!     cargo bench --bench bench_autotune

use std::time::Instant;

use silo::kernels::all_kernels;
use silo::tuner::{compare_with_named_configs, TuneOptions};

fn main() {
    let opts = TuneOptions::default();

    let mut rows = Vec::new();
    let mut never_worse = true;
    let mut total_ms = 0.0f64;
    println!(
        "{:<16} {:>8} {:>8} {:>8} {:>8}  {:<24} {:>9}",
        "kernel", "cfg1", "cfg2", "cfg3", "auto", "auto schedule", "ms"
    );
    for entry in all_kernels() {
        let t0 = Instant::now();
        let cmp = compare_with_named_configs(entry.build, &opts)
            .unwrap_or_else(|e| panic!("autotune {}: {e:#}", entry.name));
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        total_ms += ms;
        never_worse &= cmp.auto_never_worse();
        println!(
            "{:<16} {:>8.2} {:>8.2} {:>8.2} {:>8.2}  {:<24} {:>9.1}",
            entry.name,
            cmp.cfg_scores[0],
            cmp.cfg_scores[1],
            cmp.cfg_scores[2],
            cmp.outcome.cost.score,
            cmp.outcome.best.candidate.spec(),
            ms
        );
        rows.push(format!(
            "    {{\"name\": \"{}\", \"auto_spec\": \"{}\", \"auto_score\": {:.4}, \
             \"cfg1\": {:.4}, \"cfg2\": {:.4}, \"cfg3\": {:.4}, \"best_cfg\": {:.4}, \
             \"improvement_vs_best_cfg\": {:.4}, \"compare_ms\": {:.3}, \
             \"candidates\": {}, \"analysis_hits\": {}, \"refined_nests\": {}}}",
            entry.name,
            cmp.outcome.best.candidate.spec(),
            cmp.outcome.cost.score,
            cmp.cfg_scores[0],
            cmp.cfg_scores[1],
            cmp.cfg_scores[2],
            cmp.best_cfg,
            cmp.best_cfg / cmp.outcome.cost.score,
            ms,
            cmp.outcome.candidates.len(),
            cmp.outcome.analysis_hits,
            cmp.outcome.refined_nests
        ));
    }
    println!(
        "\nauto ≤ best named config on every kernel: {}; total compare time {:.0} ms",
        if never_worse { "yes" } else { "NO" },
        total_ms
    );

    let json = format!(
        "{{\n  \"bench\": \"autotune\",\n  \"compiler\": \"{}\",\n  \"node\": \"{}\",\n  \
         \"kernels_tuned\": {},\n  \"auto_never_worse\": {},\n  \
         \"total_compare_ms\": {:.3},\n  \"kernels\": [\n{}\n  ]\n}}\n",
        opts.compiler.name,
        opts.node.name,
        rows.len(),
        never_worse,
        total_ms,
        rows.join(",\n")
    );
    match std::fs::write("BENCH_autotune.json", &json) {
        Ok(()) => println!("wrote BENCH_autotune.json"),
        Err(e) => eprintln!("could not write BENCH_autotune.json: {e}"),
    }
}
