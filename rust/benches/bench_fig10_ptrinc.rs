//! Bench: Fig. 10 — pointer incrementation across the NPBench corpus:
//! modeled speedups + measured VM wall-clock for the headline kernels.
//! `cargo bench --bench bench_fig10_ptrinc`

use silo::bench::{black_box, time_budgeted};
use silo::exec::Vm;
use silo::kernels::{gen_inputs, npbench_corpus, Preset};
use silo::schedules::schedule_all_ptr_inc;
use std::time::Duration;

fn main() {
    println!("{}", silo::coordinator::experiments::run("fig10").unwrap());
    for name in ["jacobi_1d", "softmax"] {
        let entry = npbench_corpus().into_iter().find(|k| k.name == name).unwrap();
        let params = (entry.preset)(Preset::Small);
        let mut means = Vec::new();
        for ptr_inc in [false, true] {
            let mut p = (entry.build)();
            if ptr_inc {
                schedule_all_ptr_inc(&mut p);
            }
            let inputs = gen_inputs(&p, &params, entry.init).unwrap();
            let refs: Vec<_> = inputs.iter().map(|(c, v)| (*c, v.as_slice())).collect();
            let vm = Vm::compile(&p).unwrap();
            let st = time_budgeted(Duration::from_secs(2), || {
                black_box(vm.run(&params, &refs, 1).unwrap());
            });
            println!(
                "{name}_{}: {:.3} ms/iter",
                if ptr_inc { "ptrinc" } else { "naive" },
                st.mean_ms()
            );
            means.push(st.mean_ms());
        }
        println!("{name}: measured VM speedup {:.2}×", means[0] / means[1]);
    }
}
