//! Bench: Fig. 9 — vertical advection. VM wall-clock per config + the
//! strong-scaling simulation. `cargo bench --bench bench_fig9_vadv`

use silo::bench::{black_box, time_budgeted};
use silo::coordinator::{optimize_and_run, MemSchedules, OptConfig};
use silo::kernels::Preset;
use std::time::Duration;

fn main() {
    for (name, cfg) in [
        ("baseline", OptConfig::None),
        ("cfg1", OptConfig::Cfg1),
        ("cfg2", OptConfig::Cfg2),
    ] {
        let st = time_budgeted(Duration::from_secs(2), || {
            black_box(
                optimize_and_run("vadv", cfg, MemSchedules::default(), Preset::Small, 2)
                    .unwrap(),
            );
        });
        println!("vadv_{name}: {:.2} ms/iter (opt+run, {} iters)", st.mean_ms(), st.iters);
    }
    println!("{}", silo::coordinator::experiments::run("fig9").unwrap());
}
