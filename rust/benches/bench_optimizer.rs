//! Bench: optimizer throughput with the analysis cache on vs. off — how
//! expensive is SILO itself, and how much does memoizing per-loop
//! analyses buy (DESIGN.md §Pass manager).
//!
//! Runs the full cfg2 pipeline over every registered kernel with (a) a
//! fresh enabled `AnalysisCache` per kernel and (b) a disabled cache that
//! recomputes every query, then repeats the seed's analyze+schedule+lower
//! sweep for continuity. Emits `BENCH_optimizer.json` next to the
//! manifest so future PRs have a machine-readable perf trajectory.
//!
//!     cargo bench --bench bench_optimizer

use silo::analysis::AnalysisCache;
use silo::bench::{black_box, time_budgeted};
use silo::kernels::{all_kernels, npbench_corpus};
use silo::lowering::lower;
use silo::schedules::schedule_all_ptr_inc;
use silo::transforms::Pipeline;
use std::time::Duration;

fn main() {
    let n_kernels = all_kernels().len();

    // (a) cfg2 pipeline, cache enabled.
    let mut hits = 0u64;
    let mut misses = 0u64;
    let cached = time_budgeted(Duration::from_secs(2), || {
        let pipeline = Pipeline::cfg2();
        let (mut h, mut m) = (0u64, 0u64);
        for entry in all_kernels() {
            let mut p = (entry.build)();
            let mut cache = AnalysisCache::new();
            black_box(pipeline.run_with(&mut p, &mut cache).unwrap());
            h += cache.hits();
            m += cache.misses();
        }
        hits = h;
        misses = m;
    });

    // (b) cfg2 pipeline, cache disabled (every query recomputes).
    let uncached = time_budgeted(Duration::from_secs(2), || {
        let pipeline = Pipeline::cfg2();
        for entry in all_kernels() {
            let mut p = (entry.build)();
            let mut cache = AnalysisCache::disabled();
            black_box(pipeline.run_with(&mut p, &mut cache).unwrap());
        }
    });

    // (c) the seed's analyze+schedule+lower sweep (continuity series).
    let legacy = time_budgeted(Duration::from_secs(2), || {
        for entry in npbench_corpus() {
            let mut p = (entry.build)();
            black_box(silo::analysis::classify_program(&p).is_scop());
            for l in p.loops() {
                black_box(silo::analysis::loop_deps(l, &p.containers));
            }
            schedule_all_ptr_inc(&mut p);
            black_box(lower(&p).unwrap());
        }
    });

    let speedup = uncached.mean_ms() / cached.mean_ms().max(1e-9);
    println!(
        "cfg2 pipeline over {n_kernels} kernels: {:.1} ms/sweep cached, {:.1} ms/sweep uncached ({speedup:.2}x, {hits} hits / {misses} misses)",
        cached.mean_ms(),
        uncached.mean_ms(),
    );
    println!(
        "analyze+schedule+lower 20-kernel corpus: {:.1} ms/sweep",
        legacy.mean_ms()
    );

    // Machine-readable trajectory (hand-rolled JSON; no serde in the
    // vendored set).
    let json = format!(
        "{{\n  \"bench\": \"optimizer\",\n  \"kernels\": {n_kernels},\n  \"pipeline\": \"cfg2\",\n  \"cache_on_ms_per_sweep\": {:.3},\n  \"cache_off_ms_per_sweep\": {:.3},\n  \"cache_speedup\": {:.3},\n  \"cache_hits\": {hits},\n  \"cache_misses\": {misses},\n  \"legacy_analyze_schedule_lower_ms\": {:.3}\n}}\n",
        cached.mean_ms(),
        uncached.mean_ms(),
        speedup,
        legacy.mean_ms(),
    );
    match std::fs::write("BENCH_optimizer.json", &json) {
        Ok(()) => println!("wrote BENCH_optimizer.json"),
        Err(e) => eprintln!("could not write BENCH_optimizer.json: {e}"),
    }
}
