//! Bench: analysis + transform throughput over the whole NPBench corpus
//! (ablation: how expensive is SILO itself). `cargo bench --bench bench_optimizer`

use silo::bench::{black_box, time_budgeted};
use silo::kernels::npbench_corpus;
use silo::lowering::lower;
use silo::schedules::schedule_all_ptr_inc;
use std::time::Duration;

fn main() {
    let st = time_budgeted(Duration::from_secs(3), || {
        for entry in npbench_corpus() {
            let mut p = (entry.build)();
            black_box(silo::analysis::classify_program(&p).is_scop());
            for l in p.loops() {
                black_box(silo::analysis::loop_deps(l, &p.containers));
            }
            schedule_all_ptr_inc(&mut p);
            black_box(lower(&p).unwrap());
        }
    });
    println!(
        "analyze+schedule+lower 20-kernel corpus: {:.1} ms/sweep",
        st.mean_ms()
    );
}
