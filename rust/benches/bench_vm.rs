//! Bench: VM hot path — statement-instance throughput on jacobi_1d and the
//! optimizer pipeline latency. `cargo bench --bench bench_vm`

use silo::bench::{black_box, time_budgeted};
use silo::exec::Vm;
use silo::kernels::{gen_inputs, npbench_corpus, Preset};
use std::time::Duration;

fn main() {
    let entry = npbench_corpus().into_iter().find(|k| k.name == "jacobi_1d").unwrap();
    let p = (entry.build)();
    let params = (entry.preset)(Preset::Medium);
    let inputs = gen_inputs(&p, &params, entry.init).unwrap();
    let refs: Vec<_> = inputs.iter().map(|(c, v)| (*c, v.as_slice())).collect();
    let vm = Vm::compile(&p).unwrap();
    let st = time_budgeted(Duration::from_secs(3), || {
        black_box(vm.run(&params, &refs, 1).unwrap());
    });
    // medium preset: 100 steps × 2 sweeps × ~16k points
    let instances = 100.0 * 2.0 * 15998.0;
    println!(
        "vm jacobi_1d: {:.3} ms/run → {:.1} M stmt-instances/s",
        st.mean_ms(),
        instances / st.mean.as_secs_f64() / 1e6
    );

    // Optimizer pipeline latency on vadv.
    let st = time_budgeted(Duration::from_secs(2), || {
        let mut p = silo::kernels::vadv::build();
        black_box(silo::transforms::silo_cfg2(&mut p).unwrap());
    });
    println!("optimizer silo_cfg2(vadv): {:.2} ms/iter", st.mean_ms());
}
