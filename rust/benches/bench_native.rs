//! Bench: the native x86-64 tier vs the bytecode VM — per named config,
//! on every registered kernel — and the measured-cycles calibration of
//! the tuner's cost model. `cargo bench --bench bench_native`
//!
//! Emits `BENCH_native.json` at the repository root so the perf
//! trajectory is pinned across PRs. The headline number is the geomean
//! native speedup on the ptr-inc/prefetch kernels (the Fig. 10 and
//! Table 1 workloads: jacobi_1d, softmax, matmul_tiled) measured with
//! both memory schedules applied — the schedules whose wins the JIT
//! exists to make real.
//!
//! Per-measurement time budget defaults to 300 ms; set
//! `BENCH_NATIVE_BUDGET_MS` to change it.

use std::time::Duration;

use silo::bench::{black_box, time_budgeted};
use silo::coordinator::{compile_program, CompiledKernel, MemSchedules, PipelineSpec};
use silo::exec::ExecLimits;
use silo::kernels::{resolve, all_kernels, Preset};
use silo::native::Tier;
use silo::tuner::{schedule_cost, schedule_cost_with, CostCalibration};

/// Fig. 10 + Table 1 workloads: the geomean acceptance set.
const HEADLINE: [&str; 3] = ["jacobi_1d", "softmax", "matmul_tiled"];

fn budget() -> Duration {
    let ms = std::env::var("BENCH_NATIVE_BUDGET_MS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(300);
    Duration::from_millis(ms.max(10))
}

/// Mean wall-clock of one tier on one compiled artifact, milliseconds.
fn measure(
    compiled: &CompiledKernel,
    tier: Tier,
    params: &[(silo::symbolic::Sym, i64)],
    refs: &[(silo::symbolic::ContainerId, &[f64])],
) -> f64 {
    let st = time_budgeted(budget(), || {
        black_box(
            compiled
                .execute_limited_tier(tier, params, refs, 1, &ExecLimits::none())
                .unwrap(),
        );
    });
    st.mean_ms()
}

fn main() {
    let native = silo::native::available();
    if !native {
        eprintln!("native tier unavailable on this host; emitting VM-only baseline");
    }
    let specs = ["none", "cfg1", "cfg2", "cfg3"];
    let mut rows = Vec::new();
    println!(
        "{:<16} {:<6} {:>10} {:>10} {:>8}",
        "kernel", "config", "vm ms", "native ms", "speedup"
    );
    for entry in all_kernels() {
        let kernel = resolve(entry.name).unwrap();
        for spec in specs {
            let compiled = compile_program(
                kernel.program(),
                &PipelineSpec::parse(spec),
                MemSchedules::default(),
            )
            .unwrap_or_else(|e| panic!("{}/{spec}: {e:#}", entry.name));
            let params = kernel.params(Preset::Small).unwrap();
            let inputs = kernel.inputs(&compiled.program, &params).unwrap();
            let refs: Vec<_> = inputs.iter().map(|(c, v)| (*c, v.as_slice())).collect();
            let vm_ms = measure(&compiled, Tier::Vm, &params, &refs);
            let nat_ms = (native && compiled.native.is_some())
                .then(|| measure(&compiled, Tier::Native, &params, &refs));
            match nat_ms {
                Some(n) => println!(
                    "{:<16} {:<6} {:>10.3} {:>10.3} {:>7.2}x",
                    entry.name,
                    spec,
                    vm_ms,
                    n,
                    vm_ms / n
                ),
                None => println!(
                    "{:<16} {:<6} {:>10.3} {:>10} {:>8}",
                    entry.name, spec, vm_ms, "-", "-"
                ),
            }
            rows.push(format!(
                "    {{\"name\": \"{}\", \"config\": \"{spec}\", \"vm_ms\": {:.4}, \
                 \"native_ms\": {}, \"speedup\": {}}}",
                entry.name,
                vm_ms,
                nat_ms.map_or("null".into(), |n| format!("{n:.4}")),
                nat_ms.map_or("null".into(), |n| format!("{:.3}", vm_ms / n)),
            ));
        }
    }

    // Headline: the ptr-inc + prefetch schedules on the Fig. 10 /
    // Table 1 kernels, native vs VM.
    let mem = MemSchedules { ptr_inc: true, prefetch: true };
    let mut headline_rows = Vec::new();
    let mut log_sum = 0.0f64;
    let mut measured = 0usize;
    let mut calibration = CostCalibration::identity();
    for name in HEADLINE {
        let kernel = resolve(name).unwrap();
        let compiled =
            compile_program(kernel.program(), &PipelineSpec::parse("cfg1"), mem).unwrap();
        let params = kernel.params(Preset::Small).unwrap();
        let inputs = kernel.inputs(&compiled.program, &params).unwrap();
        let refs: Vec<_> = inputs.iter().map(|(c, v)| (*c, v.as_slice())).collect();
        let vm_ms = measure(&compiled, Tier::Vm, &params, &refs);
        let Some(()) = (native && compiled.native.is_some()).then_some(()) else {
            headline_rows.push(format!(
                "    {{\"name\": \"{name}\", \"vm_ms\": {vm_ms:.4}, \"native_ms\": null}}"
            ));
            continue;
        };
        let nat_ms = measure(&compiled, Tier::Native, &params, &refs);
        let speedup = vm_ms / nat_ms;
        log_sum += speedup.ln();
        measured += 1;
        println!("headline {name}: {speedup:.2}x (vm {vm_ms:.3} ms, native {nat_ms:.3} ms)");
        headline_rows.push(format!(
            "    {{\"name\": \"{name}\", \"vm_ms\": {vm_ms:.4}, \"native_ms\": {nat_ms:.4}, \
             \"speedup\": {speedup:.3}}}"
        ));
        // Calibrate the cost model against the first measured kernel:
        // modeled cycles/iter vs the native measurement (the VM's
        // interpretation overhead is exactly what calibration factors
        // out). The scale feeds schedule_cost_with without re-ranking.
        if measured == 1 {
            let opts = silo::tuner::TuneOptions::default();
            let modeled = schedule_cost(&compiled.program, &opts.compiler, &opts.node)
                .map(|c| c.cycles_per_iter)
                .unwrap_or(0.0);
            calibration = CostCalibration::from_measurement(modeled, nat_ms * 1e6);
            let recal =
                schedule_cost_with(&compiled.program, &opts.compiler, &opts.node, calibration)
                    .unwrap();
            println!(
                "calibration on {name}: scale {:.4} → {:.2} calibrated cycles/iter",
                calibration.scale, recal.cycles_per_iter
            );
        }
    }
    let geomean = (measured > 0).then(|| (log_sum / measured as f64).exp());
    if let Some(g) = geomean {
        println!("\nptr-inc/prefetch geomean native speedup: {g:.2}x");
    }

    let json = format!(
        "{{\n  \"bench\": \"native\",\n  \"native_available\": {},\n  \
         \"preset\": \"small\",\n  \"headline_geomean_speedup\": {},\n  \
         \"calibration_scale\": {:.6},\n  \"headline\": [\n{}\n  ],\n  \
         \"kernels\": [\n{}\n  ]\n}}\n",
        native,
        geomean.map_or("null".into(), |g| format!("{g:.3}")),
        calibration.scale,
        headline_rows.join(",\n"),
        rows.join(",\n")
    );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_native.json");
    match std::fs::write(path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
