//! The symbolic interval engine behind the static bounds prover.
//!
//! Bounds are *expressions over program parameters* (never loop
//! variables): every loop variable is eliminated through its iteration
//! range, so the final obligations — `lo ≥ 0` and `extent − 1 − hi ≥ 0`
//! — are sign queries the assumption machinery
//! (`crate::symbolic::assume`) can discharge under the parameter
//! floors.
//!
//! Three mechanisms carry all of the precision:
//!
//! * **Polynomial intervals.** An offset is converted with [`to_poly`]
//!   and bounded by *variable-wise elimination*: written as `A·v + B`
//!   for an environment variable `v` (so coefficient structure like
//!   `i·(N−2)` stays intact instead of splitting into decorrelated
//!   monomials), with sign-aware endpoint products and the bilinear
//!   corner rule as fallback; polynomials without a top-level ranged
//!   variable fall back to monomial-wise atom products.
//! * **Min/Max case analysis.** `min`/`max` subterms (tiled loop bounds)
//!   are eliminated by substituting each argument for the whole subterm
//!   — sound pointwise because `min(a,b)` *equals* one of its arguments
//!   at every valuation. When the subterm's polarity in the expression
//!   is a constant coefficient, one arm alone is a valid bound (e.g. an
//!   upper bound of `min(kt+T, N) − kt` is `T`), which is what keeps
//!   tile-relative offsets tight.
//! * **Opaque rules.** Non-polynomial heads get sound VM-semantics
//!   intervals: `mod(a,b) ∈ [0, b−1]` for a provably positive divisor
//!   (the VM computes `rem_euclid`, and 0 on a zero divisor),
//!   `floordiv` by a *constant* positive divisor is monotone so the
//!   numerator endpoints map through exactly (symbolic positive
//!   divisors fall back to `[min(a,0), max(a,0)]`), `log2 ∈ [0, 62]`
//!   (i64 inputs; non-positive clamps to 0), `abs ∈ [0, max(hi, −lo)]`.
//!   [`prove_nonneg`] then discharges residual constant-divisor
//!   `floordiv` terms through their rational envelope
//!   (`(num−c+1)/c ≤ floordiv(num,c) ≤ num/c`).

use crate::symbolic::{
    int, is_nonneg, max as emax, min as emin, simplify, to_poly, Atom, Expr, FuncKind, Sym, Truth,
};

/// Recursion budget for interval derivation (min/max splits nest).
const MAX_DEPTH: u32 = 24;

/// Recursion budget for [`prove_nonneg`] case splits.
const PROVE_DEPTH: u32 = 10;

/// Inclusive symbolic range of one eliminated variable. Both endpoints
/// are closed: they mention parameters (and resolved min/max over them)
/// only.
#[derive(Debug, Clone)]
pub struct Range {
    pub lo: Expr,
    pub hi: Expr,
}

/// Variable environment of one loop-nest position: ranges for bounded
/// variables, an explicit "unknown" set for variables whose iteration
/// set could not be bounded (non-sign-provable strides). Symbols in
/// neither set are treated as exact parameters (`[s, s]`).
#[derive(Debug, Clone, Default)]
pub struct BoundEnv {
    ranges: Vec<(Sym, Range)>,
    unknown: Vec<Sym>,
}

impl BoundEnv {
    pub fn push_range(&mut self, s: Sym, r: Range) {
        self.ranges.push((s, r));
    }

    pub fn push_unknown(&mut self, s: Sym) {
        self.unknown.push(s);
    }

    /// Undo the most recent `push_range`/`push_unknown` for `s`.
    pub fn pop(&mut self, s: Sym) {
        if self.ranges.last().map(|(x, _)| *x == s).unwrap_or(false) {
            self.ranges.pop();
        } else if self.unknown.last() == Some(&s) {
            self.unknown.pop();
        }
    }

    fn get(&self, s: Sym) -> Option<&Range> {
        self.ranges.iter().rev().find(|(x, _)| *x == s).map(|(_, r)| r)
    }

    fn is_unknown(&self, s: Sym) -> bool {
        self.unknown.contains(&s)
    }

    /// Is `s` a bounded environment variable?
    pub fn has(&self, s: Sym) -> bool {
        self.get(s).is_some()
    }

    /// Does `e` mention any environment variable (bounded or unknown)?
    pub fn mentions_env(&self, e: &Expr) -> bool {
        e.symbols()
            .iter()
            .any(|s| self.has(*s) || self.is_unknown(*s))
    }

    /// A copy with `s`'s range tightened (new endpoints already proven
    /// sound by the caller — guard refinement).
    pub fn refined(&self, s: Sym, lo: Option<Expr>, hi: Option<Expr>) -> BoundEnv {
        let mut out = self.clone();
        for (x, r) in out.ranges.iter_mut().rev() {
            if *x == s {
                if let Some(l) = lo {
                    r.lo = smax(r.lo.clone(), l);
                }
                if let Some(h) = hi {
                    r.hi = smin(r.hi.clone(), h);
                }
                break;
            }
        }
        out
    }
}

/// A (possibly half-open) symbolic interval: `None` = no bound derived.
#[derive(Debug, Clone, Default)]
pub struct Iv {
    pub lo: Option<Expr>,
    pub hi: Option<Expr>,
}

/// Provable-order-resolving `min`: returns the provably smaller operand,
/// or the symbolic `Min` when the order is not decidable.
pub fn smin(a: Expr, b: Expr) -> Expr {
    match resolve_ordered(true, &a, &b) {
        Some(r) => r,
        None => emin(a, b),
    }
}

/// Provable-order-resolving `max`.
pub fn smax(a: Expr, b: Expr) -> Expr {
    match resolve_ordered(false, &a, &b) {
        Some(r) => r,
        None => emax(a, b),
    }
}

/// If `a ≥ b` or `b ≥ a` is provable, return the min/max accordingly.
fn resolve_ordered(is_min: bool, a: &Expr, b: &Expr) -> Option<Expr> {
    let a_ge_b = is_nonneg(&(a.clone() - b.clone())) == Truth::Yes;
    if is_min {
        if is_nonneg(&(b.clone() - a.clone())) == Truth::Yes {
            return Some(a.clone());
        }
        if a_ge_b {
            return Some(b.clone());
        }
    } else {
        if a_ge_b {
            return Some(a.clone());
        }
        if is_nonneg(&(b.clone() - a.clone())) == Truth::Yes {
            return Some(b.clone());
        }
    }
    None
}

/// Derive a symbolic interval containing every value `e` takes over the
/// environment's variable ranges. Sound: may be wider than the true
/// range, endpoints may be `None` when no bound is derivable.
pub fn interval(e: &Expr, env: &BoundEnv) -> Iv {
    interval_at(e, env, MAX_DEPTH)
}

fn interval_at(e: &Expr, env: &BoundEnv, depth: u32) -> Iv {
    if depth == 0 {
        return Iv::default();
    }
    let e = simplify(e);
    if let Some(v) = e.as_int() {
        return Iv {
            lo: Some(int(v)),
            hi: Some(int(v)),
        };
    }
    if let Some(m) = find_minmax(&e) {
        return split_minmax(&e, &m, env, depth);
    }
    poly_interval(&e, env, depth)
}

/// First `Min`/`Max` subterm of `e` (pre-order), if any.
fn find_minmax(e: &Expr) -> Option<Expr> {
    let mut found: Option<Expr> = None;
    e.visit(&mut |x| {
        if found.is_none() && matches!(x, Expr::Min(..) | Expr::Max(..)) {
            found = Some(x.clone());
        }
    });
    found
}

/// Replace every occurrence of subterm `target` in `e` with `with`.
fn replace_subterm(e: &Expr, target: &Expr, with: &Expr) -> Expr {
    let mapped = e.map(&|x| {
        if x == target {
            with.clone()
        } else {
            x.clone()
        }
    });
    simplify(&mapped)
}

/// Constant top-level coefficient of subterm `m` inside `e`, when `m`
/// appears linearly and outside any opaque atom; `None` = unknown
/// polarity.
fn minmax_polarity(e: &Expr, m: &Expr) -> Option<i64> {
    // `#` is unlexable in identifiers, so no untrusted program can intern
    // a symbol that collides with the hole (which would corrupt the
    // polarity computation); reusing one name keeps the table bounded.
    let hole = Sym::new("silo#bounds#hole");
    let et = replace_subterm(e, m, &Expr::Sym(hole));
    let p = to_poly(&et)?;
    let ah = Atom::Sym(hole);
    // The hole must not hide inside another opaque atom.
    for (mono, _) in &p.0 {
        for (a, _) in &mono.0 {
            if *a != ah && a.depends_on(hole) {
                return None;
            }
        }
    }
    let by = p.collect(&ah);
    if by.keys().max().copied().unwrap_or(0) > 1 {
        return None;
    }
    match by.get(&1) {
        Some(c) => c.as_constant(),
        None => Some(0),
    }
}

/// Interval of an expression containing a `Min`/`Max` subterm `m`, by
/// pointwise case analysis (`m` equals one of its arguments at every
/// valuation). With a constant polarity, one arm alone bounds the
/// appropriate side tightly.
fn split_minmax(e: &Expr, m: &Expr, env: &BoundEnv, depth: u32) -> Iv {
    let (is_min, a, b) = match m {
        Expr::Min(a, b) => (true, (**a).clone(), (**b).clone()),
        Expr::Max(a, b) => (false, (**a).clone(), (**b).clone()),
        _ => return Iv::default(),
    };
    if let Some(r) = resolve_ordered(is_min, &a, &b) {
        return interval_at(&replace_subterm(e, m, &r), env, depth - 1);
    }
    let ia = interval_at(&replace_subterm(e, m, &a), env, depth - 1);
    let ib = interval_at(&replace_subterm(e, m, &b), env, depth - 1);
    let (either_hi, either_lo) = match minmax_polarity(e, m) {
        Some(c) => (
            (is_min && c >= 0) || (!is_min && c <= 0),
            (is_min && c <= 0) || (!is_min && c >= 0),
        ),
        None => (false, false),
    };
    let hi = if either_hi {
        pick(smin, ia.hi, ib.hi)
    } else {
        both(smax, ia.hi, ib.hi)
    };
    let lo = if either_lo {
        pick(smax, ia.lo, ib.lo)
    } else {
        both(smin, ia.lo, ib.lo)
    };
    Iv { lo, hi }
}

/// Either arm alone is sound: keep whichever exists, combine when both do.
fn pick(f: fn(Expr, Expr) -> Expr, a: Option<Expr>, b: Option<Expr>) -> Option<Expr> {
    match (a, b) {
        (Some(x), Some(y)) => Some(f(x, y)),
        (Some(x), None) | (None, Some(x)) => Some(x),
        (None, None) => None,
    }
}

/// Both arms are needed (pointwise case analysis).
fn both(f: fn(Expr, Expr) -> Expr, a: Option<Expr>, b: Option<Expr>) -> Option<Expr> {
    match (a, b) {
        (Some(x), Some(y)) => Some(f(x, y)),
        _ => None,
    }
}

fn poly_interval(e: &Expr, env: &BoundEnv, depth: u32) -> Iv {
    let Some(p) = to_poly(e) else {
        return Iv::default();
    };
    // Variable-wise elimination: writing the polynomial as `A·v + B`
    // (A, B free of v at the top level) and bounding `A`'s and `B`'s
    // intervals recursively keeps coefficient cancellation exact —
    // monomial-wise bounding would split `i·(N−2)` into `i·N − 2i` and
    // lose the correlation between the two terms.
    if let Some(s) = pick_env_var(&p, env) {
        let a = Atom::Sym(s);
        if p.degree_in(&a) == 1 {
            let by = p.collect(&a);
            let coef = by.get(&1).map(|q| q.to_expr()).unwrap_or_else(|| int(0));
            let rest = by.get(&0).map(|q| q.to_expr()).unwrap_or_else(|| int(0));
            let iva = interval_at(&coef, env, depth - 1);
            let ivr = interval_at(&rest, env, depth - 1);
            let Some(r) = env.get(s).cloned() else {
                return Iv::default();
            };
            let prod = mul_range(&iva, &r);
            return Iv {
                lo: add_opt(prod.lo, ivr.lo),
                hi: add_opt(prod.hi, ivr.hi),
            };
        }
    }
    monomial_interval(&p, env, depth)
}

/// First top-level symbol atom that carries an environment range.
fn pick_env_var(p: &crate::symbolic::Poly, env: &BoundEnv) -> Option<Sym> {
    for (mono, _) in &p.0 {
        for (a, _) in &mono.0 {
            if let Atom::Sym(s) = a {
                if env.has(*s) {
                    return Some(*s);
                }
            }
        }
    }
    None
}

/// Interval of `A·v` for `A ∈ iva` and `v` in range `r`, by sign-aware
/// endpoint products (corner rule as the sign-oblivious fallback —
/// a bilinear form over a box is extremal at the corners).
fn mul_range(a: &Iv, v: &Range) -> Iv {
    if prove_nonneg(&v.lo) {
        let lo = a.lo.as_ref().map(|a1| {
            if prove_nonneg(a1) {
                a1.clone() * v.lo.clone()
            } else if prove_nonneg(&(-a1.clone())) {
                a1.clone() * v.hi.clone()
            } else {
                smin(a1.clone() * v.lo.clone(), a1.clone() * v.hi.clone())
            }
        });
        let hi = a.hi.as_ref().map(|a2| {
            if prove_nonneg(a2) {
                a2.clone() * v.hi.clone()
            } else if prove_nonneg(&(-a2.clone())) {
                a2.clone() * v.lo.clone()
            } else {
                smax(a2.clone() * v.lo.clone(), a2.clone() * v.hi.clone())
            }
        });
        return Iv { lo, hi };
    }
    if prove_nonneg(&(-v.hi.clone())) {
        // v ≤ 0: A·v = −(A·(−v)) with −v ∈ [−hi, −lo] ⊆ [0, ∞).
        let flipped = mul_range(
            a,
            &Range {
                lo: -v.hi.clone(),
                hi: -v.lo.clone(),
            },
        );
        return Iv {
            lo: flipped.hi.map(|h| -h),
            hi: flipped.lo.map(|l| -l),
        };
    }
    match (&a.lo, &a.hi) {
        (Some(a1), Some(a2)) => {
            let prod = |x: &Expr, y: &Expr| x.clone() * y.clone();
            Iv {
                lo: Some(smin(
                    smin(prod(a1, &v.lo), prod(a1, &v.hi)),
                    smin(prod(a2, &v.lo), prod(a2, &v.hi)),
                )),
                hi: Some(smax(
                    smax(prod(a1, &v.lo), prod(a1, &v.hi)),
                    smax(prod(a2, &v.lo), prod(a2, &v.hi)),
                )),
            }
        }
        _ => Iv::default(),
    }
}

/// Monomial-wise fallback (no top-level degree-1 environment variable):
/// each monomial is the product of its atoms' intervals, which must
/// have provably nonnegative lower bounds.
fn monomial_interval(p: &crate::symbolic::Poly, env: &BoundEnv, depth: u32) -> Iv {
    let mut lo: Option<Expr> = Some(int(0));
    let mut hi: Option<Expr> = Some(int(0));
    for (mono, c) in &p.0 {
        if *c == 0 {
            continue;
        }
        if mono.0.is_empty() {
            lo = add_opt(lo, Some(int(*c)));
            hi = add_opt(hi, Some(int(*c)));
            continue;
        }
        let (mut mlo, mut mhi): (Option<Expr>, Option<Expr>) = (Some(int(1)), Some(int(1)));
        for (atom, pw) in &mono.0 {
            let iv = atom_interval(atom, env, depth);
            // Monomial products require provably nonnegative factors.
            let nonneg = iv
                .lo
                .as_ref()
                .map(|l| prove_nonneg(l))
                .unwrap_or(false);
            if !nonneg {
                mlo = None;
                mhi = None;
                break;
            }
            let alo = iv.lo.unwrap();
            for _ in 0..*pw {
                mlo = mlo.map(|x| x * alo.clone());
                mhi = match (mhi, iv.hi.clone()) {
                    (Some(x), Some(h)) => Some(x * h),
                    _ => None,
                };
            }
        }
        if *c > 0 {
            lo = add_scaled(lo, *c, mlo);
            hi = add_scaled(hi, *c, mhi);
        } else {
            lo = add_scaled(lo, *c, mhi);
            hi = add_scaled(hi, *c, mlo);
        }
    }
    Iv { lo, hi }
}

fn add_opt(acc: Option<Expr>, t: Option<Expr>) -> Option<Expr> {
    match (acc, t) {
        (Some(a), Some(b)) => Some(a + b),
        _ => None,
    }
}

fn add_scaled(acc: Option<Expr>, c: i64, t: Option<Expr>) -> Option<Expr> {
    match (acc, t) {
        (Some(a), Some(b)) => Some(a + int(c) * b),
        _ => None,
    }
}

fn atom_interval(a: &Atom, env: &BoundEnv, depth: u32) -> Iv {
    match a {
        Atom::Sym(s) => {
            if let Some(r) = env.get(*s) {
                Iv {
                    lo: Some(r.lo.clone()),
                    hi: Some(r.hi.clone()),
                }
            } else if env.is_unknown(*s) {
                Iv::default()
            } else {
                // A free parameter is exactly itself.
                let e = Expr::Sym(*s);
                Iv {
                    lo: Some(e.clone()),
                    hi: Some(e),
                }
            }
        }
        Atom::Opaque(inner) => opaque_interval(inner, env, depth),
    }
}

/// VM-semantics intervals for non-polynomial heads.
fn opaque_interval(e: &Expr, env: &BoundEnv, depth: u32) -> Iv {
    if depth == 0 {
        return Iv::default();
    }
    match e {
        Expr::Mod(_, b) => {
            // rem_euclid lies in [0, |b|−1]; a zero divisor yields 0.
            let ib = interval_at(b, env, depth - 1);
            let hi = match (&ib.lo, &ib.hi) {
                (Some(l), Some(h)) if prove_nonneg(&(l.clone() - int(1))) => {
                    Some(h.clone() - int(1))
                }
                _ => b.as_int().filter(|c| *c != 0).map(|c| int(c.abs() - 1)),
            };
            Iv {
                lo: Some(int(0)),
                hi,
            }
        }
        Expr::FloorDiv(a, b) => {
            let ib = interval_at(b, env, depth - 1);
            let pos = ib
                .lo
                .as_ref()
                .map(|l| prove_nonneg(&(l.clone() - int(1))))
                .unwrap_or(false);
            if !pos {
                return Iv::default();
            }
            let ia = interval_at(a, env, depth - 1);
            // A constant positive divisor makes floor division monotone
            // in the numerator, so the numerator's endpoints map through
            // exactly: `i/2` over `i ∈ [0, N−1]` is `[0, (N−1)/2]`, not
            // the sign-clamped envelope below. The elimination step in
            // [`prove_nonneg`] discharges the resulting symbolic
            // `floordiv` endpoints.
            if let Some(c) = b.as_int().filter(|c| *c >= 1) {
                return Iv {
                    lo: ia.lo.map(|l| crate::symbolic::floordiv(l, int(c))),
                    hi: ia.hi.map(|h| crate::symbolic::floordiv(h, int(c))),
                };
            }
            Iv {
                lo: ia.lo.map(|l| smin(l, int(0))),
                hi: ia.hi.map(|h| smax(h, int(0))),
            }
        }
        // i64 inputs: floor(log2) ≤ 62; non-positive inputs clamp to 0.
        Expr::Func(FuncKind::Log2, _) => Iv {
            lo: Some(int(0)),
            hi: Some(int(62)),
        },
        Expr::Func(FuncKind::Abs, args) => {
            let ia = interval_at(&args[0], env, depth - 1);
            let hi = match (ia.lo, ia.hi) {
                (Some(l), Some(h)) => Some(smax(h, -l)),
                _ => None,
            };
            Iv {
                lo: Some(int(0)),
                hi,
            }
        }
        // Nested min/max reached through an opaque shell: recurse.
        Expr::Min(..) | Expr::Max(..) => interval_at(e, env, depth - 1),
        _ => Iv::default(),
    }
}

/// Prove `e ≥ 0` under the global symbol assumptions, case-splitting on
/// `min`/`max` subterms: both arms must hold in general; a single arm
/// suffices when the subterm's constant polarity makes that arm a lower
/// bound of `e` (e.g. `X − min(a,b) ≥ X − a`).
pub fn prove_nonneg(e: &Expr) -> bool {
    prove_nonneg_at(e, PROVE_DEPTH)
}

fn prove_nonneg_at(e: &Expr, depth: u32) -> bool {
    let e = simplify(e);
    if is_nonneg(&e) == Truth::Yes {
        return true;
    }
    if depth == 0 {
        return false;
    }
    let Some(m) = find_minmax(&e) else {
        return fd_eliminate(&e, depth);
    };
    let (is_min, a, b) = match &m {
        Expr::Min(a, b) => (true, (**a).clone(), (**b).clone()),
        Expr::Max(a, b) => (false, (**a).clone(), (**b).clone()),
        _ => return false,
    };
    if let Some(r) = resolve_ordered(is_min, &a, &b) {
        return prove_nonneg_at(&replace_subterm(&e, &m, &r), depth - 1);
    }
    let ea = replace_subterm(&e, &m, &a);
    let eb = replace_subterm(&e, &m, &b);
    let either = match minmax_polarity(&e, &m) {
        Some(c) => (is_min && c <= 0) || (!is_min && c >= 0),
        None => false,
    };
    if either {
        prove_nonneg_at(&ea, depth - 1) || prove_nonneg_at(&eb, depth - 1)
    } else {
        prove_nonneg_at(&ea, depth - 1) && prove_nonneg_at(&eb, depth - 1)
    }
}

/// First `floordiv(num, c)` subterm of `e` with a constant divisor
/// `c ≥ 1` (pre-order), if any.
fn find_const_floordiv(e: &Expr) -> Option<(Expr, Expr, i64)> {
    let mut found: Option<(Expr, Expr, i64)> = None;
    e.visit(&mut |x| {
        if found.is_none() {
            if let Expr::FloorDiv(num, den) = x {
                if let Some(c) = den.as_int().filter(|c| *c >= 1) {
                    found = Some((x.clone(), (**num).clone(), c));
                }
            }
        }
    });
    found
}

/// Eliminate one constant-divisor `floordiv` via its rational envelope.
///
/// Writing `e = A·q + B` with `q = floordiv(num, c)`, `c ≥ 1`, and a
/// constant coefficient `A`, Euclidean division gives the two-sided
/// envelope `(num − c + 1)/c ≤ q ≤ num/c`. Scaling the obligation by the
/// positive `c` (which preserves sign) turns `e ≥ 0` into a `floordiv`-
/// free sufficient condition:
///
/// * `A ≥ 0`: prove `A·(num − c + 1) + c·B ≥ 0` — or, when the envelope
///   is too loose, `num ≥ 0 ∧ B ≥ 0` (then `q ≥ 0` and `e ≥ B`).
/// * `A < 0`: prove `A·num + c·B ≥ 0`.
///
/// This is a local judging step, not a rewrite in `simplify` — the
/// canonical form (and with it printed kernels and cache keys) keeps
/// `floordiv` intact.
fn fd_eliminate(e: &Expr, depth: u32) -> bool {
    if depth == 0 {
        return false;
    }
    let Some((m, num, c)) = find_const_floordiv(e) else {
        return false;
    };
    // Reuse the unlexable hole symbol (see `minmax_polarity`) to expose
    // the subterm's linear coefficient.
    let hole = Sym::new("silo#bounds#hole");
    let et = replace_subterm(e, &m, &Expr::Sym(hole));
    let Some(p) = to_poly(&et) else {
        return false;
    };
    let ah = Atom::Sym(hole);
    // The hole must not hide inside another opaque atom.
    for (mono, _) in &p.0 {
        for (a, _) in &mono.0 {
            if *a != ah && a.depends_on(hole) {
                return false;
            }
        }
    }
    let by = p.collect(&ah);
    if by.keys().max().copied().unwrap_or(0) > 1 {
        return false;
    }
    let Some(a_coef) = by.get(&1).map(|q| q.as_constant()) else {
        return false;
    };
    let Some(a_coef) = a_coef else {
        return false;
    };
    let b_rest = by
        .get(&0)
        .cloned()
        .unwrap_or_else(crate::symbolic::Poly::zero)
        .to_expr();
    if a_coef >= 0 {
        let env_lo = int(a_coef) * (num.clone() - int(c - 1)) + int(c) * b_rest.clone();
        prove_nonneg_at(&env_lo, depth - 1)
            || (prove_nonneg_at(&num, depth - 1) && prove_nonneg_at(&b_rest, depth - 1))
    } else {
        prove_nonneg_at(&(int(a_coef) * num + int(c) * b_rest), depth - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::symbolic::{imod, psym, sym};

    fn env_with(s: Sym, lo: Expr, hi: Expr) -> BoundEnv {
        let mut env = BoundEnv::default();
        env.push_range(s, Range { lo, hi });
        env
    }

    #[test]
    fn affine_offset_interval() {
        let n = psym("bnd_N");
        let i = Sym::new("bnd_i");
        let env = env_with(i, int(1), n.clone() - int(2));
        // 2i + 1 over i ∈ [1, N−2] → [3, 2N−3].
        let iv = interval(&(int(2) * Expr::Sym(i) + int(1)), &env);
        assert_eq!(iv.lo, Some(int(3)));
        assert_eq!(iv.hi, Some(int(2) * n.clone() - int(3)));
        // Negative coefficient swaps endpoints: N − i ∈ [2, N−1].
        let iv = interval(&(n.clone() - Expr::Sym(i)), &env);
        assert!(prove_nonneg(&(iv.lo.unwrap() - int(2))));
        assert_eq!(iv.hi, Some(n - int(1)));
    }

    #[test]
    fn min_polarity_keeps_tile_bounds_tight() {
        // upper(min(kt + 32, N) − kt) must be 32, not N.
        let n = psym("bnd_tN");
        let kt = Sym::nonneg("bnd_kt");
        let env = env_with(kt, int(0), n.clone() - int(1));
        let e = emin(Expr::Sym(kt) + int(32), n.clone()) - Expr::Sym(kt);
        let iv = interval(&e, &env);
        let hi = iv.hi.expect("upper bound");
        assert!(prove_nonneg(&(int(32) - hi)), "tile span bound too loose");
    }

    #[test]
    fn mod_rule_bounds_gather() {
        let r = psym("bnd_R");
        let k = Sym::nonneg("bnd_k");
        let env = env_with(k, int(0), r.clone() - int(1));
        let off = imod(int(7) * Expr::Sym(k) + int(3), r.clone());
        let iv = interval(&off, &env);
        assert_eq!(iv.lo, Some(int(0)));
        // hi = R − 1 → extent R − 1 − hi = 0 ≥ 0.
        let slack = r - int(1) - iv.hi.unwrap();
        assert!(prove_nonneg(&slack));
    }

    #[test]
    fn log2_rule_is_word_bounded() {
        let x = sym("bnd_lx");
        let off = crate::symbolic::func(FuncKind::Log2, vec![x]);
        let iv = interval(&off, &BoundEnv::default());
        assert_eq!(iv.lo, Some(int(0)));
        assert_eq!(iv.hi, Some(int(62)));
    }

    #[test]
    fn prove_nonneg_case_splits_minmax() {
        let n = psym("bnd_pn");
        // 1056 − 33·min(32, N) ≥ 0 via the min→32 arm.
        let e = int(1056) - int(33) * emin(int(32), n.clone());
        assert!(prove_nonneg(&e));
        // min in positive polarity needs both arms: min(32, N) ≥ 0 holds.
        assert!(prove_nonneg(&emin(int(32), n.clone())));
        // max needs only one arm for a lower bound: max(N − 100, 5) ≥ 0.
        assert!(prove_nonneg(&emax(n - int(100), int(5))));
    }

    #[test]
    fn floordiv_const_divisor_interval_is_exact() {
        let n = psym("bnd_fdN");
        let i = Sym::nonneg("bnd_fdi");
        let env = env_with(i, int(0), n.clone() - int(1));
        // i/2 over i ∈ [0, N−1] → [0, (N−1)/2]; against extent N the
        // slack N − 1 − (N−1)/2 must prove (the old sign-clamped rule
        // gave hi = max(N−1, 0) and the proof failed).
        let off = crate::symbolic::floordiv(Expr::Sym(i), int(2));
        let iv = interval(&off, &env);
        assert_eq!(iv.lo, Some(int(0)));
        let hi = iv.hi.expect("upper bound");
        assert!(prove_nonneg(&(n - int(1) - hi)), "slack unproven: {hi}");
    }

    #[test]
    fn floordiv_envelope_elimination() {
        let n = psym("bnd_feN");
        let q = crate::symbolic::floordiv(n.clone() - int(1), int(2));
        // Lower side via num ≥ 0: floor((N−1)/2) ≥ 0.
        assert!(prove_nonneg(&q));
        // Negative-coefficient side: N − 1 − floor((N−1)/2) ≥ 0.
        assert!(prove_nonneg(&(n.clone() - int(1) - q.clone())));
        // Unsound direction must stay unproven: floor((N−1)/2) ≥ N − 1
        // already fails at N = 2.
        assert!(!prove_nonneg(&(q - n + int(1))));
    }

    #[test]
    fn unknown_vars_yield_no_bound() {
        let mut env = BoundEnv::default();
        let v = Sym::new("bnd_uv");
        env.push_unknown(v);
        let iv = interval(&(Expr::Sym(v) + int(1)), &env);
        assert!(iv.lo.is_none() && iv.hi.is_none());
    }
}
