//! Static bounds verifier — the safety side of the paper's symbolic
//! loop model.
//!
//! The same machinery that characterizes subscripts as symbolic
//! functions of loop strides for *optimization* also suffices to
//! *prove memory safety*: for every container subscript we derive a
//! symbolic `[min, max]` over the enclosing nest ([`bounds`]) and
//! compare it against the container extent under the parameter
//! assumption floors. Each access gets a verdict:
//!
//! * [`AccessVerdict::ProvenInBounds`] — every execution under the
//!   declared parameter assumptions stays inside the container; no
//!   runtime check is needed.
//! * [`AccessVerdict::NeedsCheck`] — the prover could not discharge one
//!   of the two obligations; the checked VM tier guards this access
//!   with an [`Op::BoundsCheck`](crate::lowering::bytecode::Op) at run
//!   time.
//! * [`AccessVerdict::RuntimeCheckable`] — unprovable for a *structural*
//!   reason the runtime tiers are built for: the subscript contains
//!   `mod`/`floordiv` arithmetic or a value-dependent `Load`. Such an
//!   access still carries an `Op::BoundsCheck` (it lowers exactly like
//!   `NeedsCheck`), but the verdict additionally marks the program as a
//!   candidate for the inspector ([`crate::inspect`]) and the
//!   speculative executor (`exec::speculate`), which decide
//!   parallelizability from concrete runtime values.
//! * [`AccessVerdict::ProvenOutOfBounds`] — the access can *never* be
//!   in bounds (its derived lower bound is ≥ the extent, or its upper
//!   bound is < 0); an untrusted service refuses such programs outright.
//!
//! The verdict lattice orders `ProvenInBounds < NeedsCheck =
//! RuntimeCheckable < ProvenOutOfBounds` (the two middle verdicts lower
//! identically; they differ only in what they tell the runtime tiers);
//! a program's tier is the join over its accesses.
//! The report also carries a **symbolic worst-case fuel bound** — an
//! upper bound on loop back-edges the program can execute — which is
//! what a fuel-budgeted runtime compares its meter against.
//!
//! Statement guards participate: `if (g) D[f] = …` only executes its
//! body accesses when `g > 0`, so for integer-valued guards linear in a
//! single loop variable the variable's range is tightened before
//! judging the guarded accesses (the `blur_guard` boundary pattern).
//! Relational guards over *several* loop variables (`i + j < N`) go
//! through a λ=1 slack fallback instead ([`judge_guarded`]): the
//! obligation is judged with the guard slack `g − 1` subtracted once,
//! which cancels correlated subscripts symbolically.
//!
//! Soundness direction: everything here over-approximates. A
//! `ProvenInBounds` verdict is a theorem under the parameter floors the
//! program was compiled with (which is why the service validates run
//! parameters against the floors snapshotted at compile time);
//! `NeedsCheck` is always a safe answer.

pub mod bounds;

use std::collections::HashSet;

use crate::ir::{AccessKind, Loop, Node, Program, Stmt, StmtId};
use crate::symbolic::{floordiv, int, subs_many, to_poly, Atom, ContainerId, Expr, FuncKind, Sym};

use bounds::{interval, prove_nonneg, smax, BoundEnv, Range};

/// Safety tier of a compiled artifact.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SafetyTier {
    /// Compiled without verification — executes with CLI-level trust.
    Trusted,
    /// Every access statically proven in bounds; runs unchecked at full
    /// speed.
    Proven,
    /// One or more accesses carry runtime bounds checks in the bytecode.
    Checked,
}

impl SafetyTier {
    pub fn as_str(self) -> &'static str {
        match self {
            SafetyTier::Trusted => "trusted",
            SafetyTier::Proven => "proven",
            SafetyTier::Checked => "checked",
        }
    }
}

/// Per-access verdict (see the module docs for the lattice).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AccessVerdict {
    ProvenInBounds,
    NeedsCheck { reason: String },
    /// Unprovable because the subscript is structurally irregular
    /// (`mod`/`floordiv`/value-dependent `Load`) — guarded at run time
    /// like [`AccessVerdict::NeedsCheck`], and additionally a candidate
    /// for inspector-executor runtime analysis.
    RuntimeCheckable { reason: String },
    ProvenOutOfBounds { reason: String },
}

/// One verified access.
#[derive(Debug, Clone)]
pub struct AccessReport {
    pub stmt: StmtId,
    pub container: ContainerId,
    pub container_name: String,
    pub kind: AccessKind,
    pub offset: Expr,
    pub verdict: AccessVerdict,
}

/// The whole-program verification result.
#[derive(Debug, Clone)]
pub struct VerifyReport {
    pub program: String,
    pub accesses: Vec<AccessReport>,
    /// Symbolic upper bound on loop back-edges (the fuel meter's unit);
    /// `None` when some loop's trip count could not be bounded.
    pub fuel_bound: Option<Expr>,
}

impl VerifyReport {
    pub fn all_proven(&self) -> bool {
        self.accesses
            .iter()
            .all(|a| a.verdict == AccessVerdict::ProvenInBounds)
    }

    pub fn proven_count(&self) -> usize {
        self.accesses
            .iter()
            .filter(|a| a.verdict == AccessVerdict::ProvenInBounds)
            .count()
    }

    pub fn unproven(&self) -> Vec<&AccessReport> {
        self.accesses
            .iter()
            .filter(|a| a.verdict != AccessVerdict::ProvenInBounds)
            .collect()
    }

    pub fn proven_oob(&self) -> Vec<&AccessReport> {
        self.accesses
            .iter()
            .filter(|a| matches!(a.verdict, AccessVerdict::ProvenOutOfBounds { .. }))
            .collect()
    }

    /// The tier this program earns when lowered with
    /// [`CheckSet::from_report`]. A `ProvenOutOfBounds` access still
    /// maps to `Checked` here — refusing it is a policy decision made
    /// by the caller (the untrusted service refuses; the CLI reports).
    pub fn tier(&self) -> SafetyTier {
        if self.all_proven() {
            SafetyTier::Proven
        } else {
            SafetyTier::Checked
        }
    }

    /// Human-readable per-access report (the `silo verify` output).
    pub fn summary(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "program {}: {} accesses, {} proven in bounds, {} need runtime checks, \
             {} provably out of bounds",
            self.program,
            self.accesses.len(),
            self.proven_count(),
            self.accesses.len() - self.proven_count() - self.proven_oob().len(),
            self.proven_oob().len(),
        );
        for a in &self.accesses {
            let kind = match a.kind {
                AccessKind::Read => "read ",
                AccessKind::Write => "write",
            };
            let verdict = match &a.verdict {
                AccessVerdict::ProvenInBounds => "proven in bounds".to_string(),
                AccessVerdict::NeedsCheck { reason } => format!("NEEDS CHECK — {reason}"),
                AccessVerdict::RuntimeCheckable { reason } => {
                    format!("RUNTIME CHECKABLE — {reason}")
                }
                AccessVerdict::ProvenOutOfBounds { reason } => {
                    format!("OUT OF BOUNDS — {reason}")
                }
            };
            let _ = writeln!(
                out,
                "  [s{}] {kind} {}[{}]: {verdict}",
                a.stmt.0, a.container_name, a.offset
            );
        }
        match &self.fuel_bound {
            Some(f) => {
                let _ = writeln!(out, "worst-case fuel (loop back-edges): {f}");
            }
            None => {
                let _ = writeln!(out, "worst-case fuel: unbounded (non-sign-provable stride)");
            }
        }
        out
    }
}

/// Which accesses the lowering must guard with runtime bounds checks.
/// Keyed by `(statement, container, offset)` — exactly the identity the
/// bytecode compiler sees, so proven accesses keep every fast path
/// (cursors, offset folding) and only unproven ones pay.
#[derive(Debug, Clone, Default)]
pub struct CheckSet {
    all: bool,
    keys: HashSet<(StmtId, ContainerId, Expr)>,
}

impl CheckSet {
    /// Check nothing (today's trusted tier).
    pub fn none() -> CheckSet {
        CheckSet::default()
    }

    /// Check every access (paranoid tier; used by differential tests).
    pub fn all() -> CheckSet {
        CheckSet {
            all: true,
            keys: HashSet::new(),
        }
    }

    /// Check exactly the accesses the report could not prove.
    pub fn from_report(r: &VerifyReport) -> CheckSet {
        let mut keys = HashSet::new();
        for a in &r.accesses {
            if a.verdict != AccessVerdict::ProvenInBounds {
                keys.insert((a.stmt, a.container, a.offset.clone()));
            }
        }
        CheckSet { all: false, keys }
    }

    pub fn needs(&self, stmt: StmtId, c: ContainerId, off: &Expr) -> bool {
        self.all || self.keys.contains(&(stmt, c, off.clone()))
    }

    /// True when lowering with this set emits no checks at all.
    pub fn is_empty(&self) -> bool {
        !self.all && self.keys.is_empty()
    }
}

/// Verify every access of `p` and bound its worst-case fuel.
pub fn verify_program(p: &Program) -> VerifyReport {
    let mut v = Verifier {
        p,
        accesses: Vec::new(),
    };
    let mut ctx = Ctx::default();
    for n in &p.body {
        v.walk_node(n, &mut ctx);
    }
    let mut fuel_env = BoundEnv::default();
    let fuel_bound =
        fuel_bound_nodes(&p.body, &mut fuel_env).map(|e| crate::symbolic::simplify(&e));
    VerifyReport {
        program: p.name.clone(),
        accesses: v.accesses,
        fuel_bound,
    }
}

// ---------------------------------------------------------------------------
// The nest walker
// ---------------------------------------------------------------------------

/// Provable stride direction of a loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Dirn {
    Asc,
    Desc,
    Unknown,
}

fn stride_dir(stride: &Expr, env: &BoundEnv) -> Dirn {
    if let Some(c) = stride.as_int() {
        return match c.cmp(&0) {
            std::cmp::Ordering::Greater => Dirn::Asc,
            std::cmp::Ordering::Less => Dirn::Desc,
            std::cmp::Ordering::Equal => Dirn::Unknown,
        };
    }
    let iv = interval(stride, env);
    if iv
        .lo
        .as_ref()
        .map(|l| prove_nonneg(&(l.clone() - int(1))))
        .unwrap_or(false)
    {
        Dirn::Asc
    } else if iv
        .hi
        .as_ref()
        .map(|h| prove_nonneg(&(int(-1) - h.clone())))
        .unwrap_or(false)
    {
        Dirn::Desc
    } else {
        Dirn::Unknown
    }
}

/// A loop whose own variable feeds its stride or bounds (Fig. 2's
/// `i += i`) has no closed characterization — over-approximate.
fn self_dependent(l: &Loop) -> bool {
    l.stride.depends_on(l.var) || l.start.depends_on(l.var) || l.end.depends_on(l.var)
}

/// Absolute range of `l.var` (closed over parameters), via the loop's
/// own bounds: ascending loops run in `[start, end − 1]`, descending in
/// `[end + 1, start]`.
fn abs_range(l: &Loop, env: &BoundEnv) -> (Dirn, Option<Range>) {
    if self_dependent(l) {
        return (Dirn::Unknown, None);
    }
    let d = stride_dir(&l.stride, env);
    let r = match d {
        Dirn::Asc => {
            let lo = interval(&l.start, env).lo;
            let hi = interval(&l.end, env).hi.map(|h| h - int(1));
            match (lo, hi) {
                (Some(lo), Some(hi)) => Some(Range { lo, hi }),
                _ => None,
            }
        }
        Dirn::Desc => {
            let lo = interval(&l.end, env).lo.map(|l| l + int(1));
            let hi = interval(&l.start, env).hi;
            match (lo, hi) {
                (Some(lo), Some(hi)) => Some(Range { lo, hi }),
                _ => None,
            }
        }
        Dirn::Unknown => None,
    };
    (d, r)
}

#[derive(Default)]
struct Ctx {
    /// Absolute mode: loop variables bounded by their own loop's range.
    abs: BoundEnv,
    /// Relative mode: variables rewritten to `start ± ṽ` with `ṽ`
    /// spanning the (normalized) trip range — keeps start-relative
    /// offsets like `bk − kt` exact for tile-local buffers.
    rel: BoundEnv,
    subs: Vec<(Sym, Expr)>,
}

struct Verifier<'a> {
    p: &'a Program,
    accesses: Vec<AccessReport>,
}

impl Verifier<'_> {
    fn walk_node(&mut self, n: &Node, ctx: &mut Ctx) {
        match n {
            Node::Stmt(s) => self.walk_stmt(s, ctx),
            Node::Loop(l) => self.walk_loop(l, ctx),
        }
    }

    fn walk_loop(&mut self, l: &Loop, ctx: &mut Ctx) {
        // Absolute entry.
        let (_, abs_r) = abs_range(l, &ctx.abs);
        match abs_r {
            Some(r) => ctx.abs.push_range(l.var, r),
            None => ctx.abs.push_unknown(l.var),
        }

        // Relative entry: substitute var → start ± ṽ.
        let mut rel_sym = l.var;
        let mut pushed_sub = false;
        if !self_dependent(l) {
            let start_s = subs_many(&l.start, &ctx.subs);
            let end_s = subs_many(&l.end, &ctx.subs);
            let stride_s = subs_many(&l.stride, &ctx.subs);
            let dir = stride_dir(&stride_s, &ctx.rel);
            if dir != Dirn::Unknown {
                // `#` cannot appear in a lexed identifier, so an untrusted
                // submission can never intern a symbol colliding with the
                // elimination variable (a same-named collision would hand
                // the attacker's symbol our trip range — an unsound proof).
                // Interning by name (not `Sym::fresh`) keeps the table
                // growth bounded by the set of loop-variable names.
                let tilde = Sym::nonneg(&format!("{}#vr", l.var.name()));
                let span = match dir {
                    Dirn::Asc => end_s.clone() - start_s.clone(),
                    _ => start_s.clone() - end_s.clone(),
                };
                rel_sym = tilde;
                match interval(&span, &ctx.rel).hi {
                    Some(h) => ctx.rel.push_range(
                        tilde,
                        Range {
                            lo: int(0),
                            hi: h - int(1),
                        },
                    ),
                    None => ctx.rel.push_unknown(tilde),
                }
                let repl = match dir {
                    Dirn::Asc => start_s + Expr::Sym(tilde),
                    _ => start_s - Expr::Sym(tilde),
                };
                ctx.subs.push((l.var, repl));
                pushed_sub = true;
            } else {
                ctx.rel.push_unknown(l.var);
            }
        } else {
            ctx.rel.push_unknown(l.var);
        }

        for n in &l.body {
            self.walk_node(n, ctx);
        }

        ctx.abs.pop(l.var);
        ctx.rel.pop(rel_sym);
        if pushed_sub {
            ctx.subs.pop();
        }
    }

    fn walk_stmt(&mut self, s: &Stmt, ctx: &Ctx) {
        let mut seen: HashSet<(ContainerId, Expr, bool)> = HashSet::new();
        // Guard-expression reads execute unconditionally: judge them
        // under the unrefined environment.
        if let Some(g) = &s.guard {
            for (c, off) in g.loads() {
                if seen.insert((c, off.clone(), false)) {
                    self.record(
                        s,
                        c,
                        &off,
                        AccessKind::Read,
                        &ctx.abs,
                        &ctx.rel,
                        &ctx.subs,
                        None,
                    );
                }
            }
        }
        // The guarded body only runs when guard > 0 — tighten ranges.
        let (abs_ref, rel_ref) = match &s.guard {
            Some(g) if integer_guard(g) => (
                guard_refinement(g, &ctx.abs),
                guard_refinement(&subs_many(g, &ctx.subs), &ctx.rel),
            ),
            _ => (None, None),
        };
        let abs_env = abs_ref.as_ref().unwrap_or(&ctx.abs);
        let rel_env = rel_ref.as_ref().unwrap_or(&ctx.rel);
        // Guard handed to `record` for the λ=1 slack fallback — only
        // integer guards give `g > 0 ⟺ g − 1 ≥ 0`.
        let guard = s.guard.as_ref().filter(|g| integer_guard(g));
        for (c, off) in s.rhs.loads() {
            if seen.insert((c, off.clone(), false)) {
                self.record(s, c, &off, AccessKind::Read, abs_env, rel_env, &ctx.subs, guard);
            }
        }
        self.record(
            s,
            s.write.container,
            &s.write.offset,
            AccessKind::Write,
            abs_env,
            rel_env,
            &ctx.subs,
            guard,
        );
    }

    fn record(
        &mut self,
        s: &Stmt,
        c: ContainerId,
        off: &Expr,
        kind: AccessKind,
        abs: &BoundEnv,
        rel: &BoundEnv,
        subs: &[(Sym, Expr)],
        guard: Option<&Expr>,
    ) {
        let size = self.p.container(c).size.clone();
        let verdict = match judge(off, abs, &size) {
            Judge::Proven => AccessVerdict::ProvenInBounds,
            Judge::Oob(reason) => AccessVerdict::ProvenOutOfBounds { reason },
            Judge::Unknown(reason) => {
                // Second attempt in start-relative form.
                let off_rel = subs_many(off, subs);
                match judge(&off_rel, rel, &size) {
                    Judge::Proven => AccessVerdict::ProvenInBounds,
                    Judge::Oob(reason) => AccessVerdict::ProvenOutOfBounds { reason },
                    Judge::Unknown(_)
                        if guard.is_some_and(|g| {
                            judge_guarded(off, g, abs, &size)
                                || judge_guarded(&off_rel, &subs_many(g, subs), rel, &size)
                        }) =>
                    {
                        AccessVerdict::ProvenInBounds
                    }
                    Judge::Unknown(_) if structurally_irregular(off) => {
                        AccessVerdict::RuntimeCheckable { reason }
                    }
                    Judge::Unknown(_) => AccessVerdict::NeedsCheck { reason },
                }
            }
        };
        self.accesses.push(AccessReport {
            stmt: s.id,
            container: c,
            container_name: self.p.container(c).name.clone(),
            kind,
            offset: off.clone(),
            verdict,
        });
    }
}

/// Does the subscript contain arithmetic the interval prover cannot see
/// through for *structural* reasons — `mod`, `floordiv`, or a
/// value-dependent `Load`? These are the shapes the runtime tiers
/// (inspector, speculative executor) exist for, so a double-`Unknown`
/// verdict on such an offset reports `RuntimeCheckable` rather than the
/// generic `NeedsCheck`.
fn structurally_irregular(e: &Expr) -> bool {
    match e {
        Expr::Mod(..) | Expr::FloorDiv(..) | Expr::Load(..) => true,
        Expr::Int(_) | Expr::Real(_) | Expr::Sym(_) => false,
        Expr::Add(xs) | Expr::Mul(xs) | Expr::Func(_, xs) => {
            xs.iter().any(structurally_irregular)
        }
        Expr::Pow(a, _) => structurally_irregular(a),
        Expr::Min(a, b) | Expr::Max(a, b) => {
            structurally_irregular(a) || structurally_irregular(b)
        }
    }
}

enum Judge {
    Proven,
    Oob(String),
    Unknown(String),
}

/// Judge one offset against one extent under one environment.
fn judge(off: &Expr, env: &BoundEnv, size: &Expr) -> Judge {
    let iv = interval(off, env);
    let lo_ok = iv.lo.as_ref().map(|l| prove_nonneg(l)).unwrap_or(false);
    let hi_ok = iv
        .hi
        .as_ref()
        .map(|h| prove_nonneg(&(size.clone() - int(1) - h.clone())))
        .unwrap_or(false);
    if lo_ok && hi_ok {
        return Judge::Proven;
    }
    if let Some(h) = &iv.hi {
        if prove_nonneg(&(int(-1) - h.clone())) {
            return Judge::Oob(format!("upper bound {h} is below 0"));
        }
    }
    if let Some(l) = &iv.lo {
        if prove_nonneg(&(l.clone() - size.clone())) {
            return Judge::Oob(format!("lower bound {l} reaches or exceeds extent {size}"));
        }
    }
    let side = if !lo_ok {
        match &iv.lo {
            Some(l) => format!("cannot prove offset ≥ 0 (derived lower bound {l})"),
            None => "no lower bound derivable".to_string(),
        }
    } else {
        match &iv.hi {
            Some(h) => format!("cannot prove offset ≤ {size} − 1 (derived upper bound {h})"),
            None => "no upper bound derivable".to_string(),
        }
    };
    Judge::Unknown(side)
}

/// λ=1 guard-slack judging — the fallback for relational guards the
/// per-variable refinement cannot use.
///
/// The guarded body runs only where the integer guard satisfies
/// `g ≥ 1`, i.e. where the slack `g − 1` is nonnegative. Subtracting
/// that slack once from a failing obligation is sound (a Farkas
/// combination with multiplier 1): `off − (g − 1) ≥ 0` over the whole
/// iteration box implies `off ≥ 0` wherever the body actually executes,
/// and symmetrically for the extent side. Unlike
/// [`guard_refinement`], the subtraction keeps correlated variables
/// together — for `if (i + j < N) … x[i + j]` the guard
/// `N − i − j` cancels the subscript symbolically, which no
/// per-variable interval can express. Each side independently accepts
/// the plain or the slack-adjusted obligation: the slack helps exactly
/// one side and can loosen the other.
fn judge_guarded(off: &Expr, g: &Expr, env: &BoundEnv, size: &Expr) -> bool {
    let in_lo = |e: &Expr| {
        interval(e, env)
            .lo
            .as_ref()
            .map(prove_nonneg)
            .unwrap_or(false)
    };
    let slack = g.clone() - int(1);
    let lo = off.clone();
    let hi = size.clone() - int(1) - off.clone();
    (in_lo(&lo) || in_lo(&(lo.clone() - slack.clone())))
        && (in_lo(&hi) || in_lo(&(hi.clone() - slack)))
}

/// Is `g` a purely integer-valued expression (so `g > 0 ⟺ g ≥ 1`)?
fn integer_guard(g: &Expr) -> bool {
    let mut ok = true;
    g.visit(&mut |e| match e {
        Expr::Real(_) | Expr::Load(..) => ok = false,
        Expr::Func(k, _) if !matches!(k, FuncKind::Log2 | FuncKind::Abs) => ok = false,
        _ => {}
    });
    ok
}

/// Tighten an environment using `g ≥ 1`, when `g` is linear with unit
/// coefficient in a single environment variable and the rest is closed
/// over parameters: `v + r ≥ 1 ⇒ v ≥ 1 − r`; `r − v ≥ 1 ⇒ v ≤ r − 1`.
fn guard_refinement(g: &Expr, env: &BoundEnv) -> Option<BoundEnv> {
    if g.contains_load() {
        return None;
    }
    let p = to_poly(g)?;
    for s in g.symbols() {
        if !env.has(s) {
            continue;
        }
        let a = Atom::Sym(s);
        let hidden = p
            .0
            .keys()
            .any(|m| m.0.iter().any(|(x, _)| *x != a && x.depends_on(s)));
        if hidden {
            continue;
        }
        let by = p.collect(&a);
        if by.keys().max().copied().unwrap_or(0) != 1 {
            continue;
        }
        let Some(c) = by.get(&1).and_then(|q| q.as_constant()) else {
            continue;
        };
        if c != 1 && c != -1 {
            continue;
        }
        let rest = by
            .get(&0)
            .cloned()
            .unwrap_or_else(crate::symbolic::Poly::zero)
            .to_expr();
        if env.mentions_env(&rest) {
            continue;
        }
        return Some(if c == 1 {
            env.refined(s, Some(int(1) - rest), None)
        } else {
            env.refined(s, None, Some(rest - int(1)))
        });
    }
    None
}

// ---------------------------------------------------------------------------
// Worst-case fuel
// ---------------------------------------------------------------------------

/// Closed upper bound on loop back-edges executed by `nodes` (each loop
/// contributes its iteration bound times `1 + ` its body's bound).
fn fuel_bound_nodes(nodes: &[Node], env: &mut BoundEnv) -> Option<Expr> {
    let mut total = int(0);
    for n in nodes {
        if let Node::Loop(l) = n {
            let (dirn, r) = abs_range(l, env);
            let iters = loop_iter_bound(l, env, dirn)?;
            match r {
                Some(r) => env.push_range(l.var, r),
                None => env.push_unknown(l.var),
            }
            let inner = fuel_bound_nodes(&l.body, env);
            env.pop(l.var);
            total = total + iters * (int(1) + inner?);
        }
    }
    Some(total)
}

fn loop_iter_bound(l: &Loop, env: &BoundEnv, d: Dirn) -> Option<Expr> {
    let span = match d {
        Dirn::Asc => {
            let u_end = interval(&l.end, env).hi?;
            let l_start = interval(&l.start, env).lo?;
            u_end - l_start
        }
        Dirn::Desc => {
            let u_start = interval(&l.start, env).hi?;
            let l_end = interval(&l.end, env).lo?;
            u_start - l_end
        }
        Dirn::Unknown => return None,
    };
    let step = l.stride.as_int().map(i64::abs).unwrap_or(1);
    let count = if step > 1 {
        floordiv(span + int(step - 1), int(step))
    } else {
        span
    };
    Some(smax(int(0), count))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::ProgramBuilder;
    use crate::symbolic::{load, Expr};

    #[test]
    fn interior_stencil_is_proven() {
        let mut b = ProgramBuilder::new("ver_stencil");
        let n = b.dim_param("ver_N");
        let a = b.array("A", Expr::Sym(n));
        let t = b.transient("T", Expr::Sym(n));
        let i = b.sym("ver_i");
        b.for_(i, int(1), Expr::Sym(n) - int(1), int(1), |b| {
            b.assign(
                t,
                Expr::Sym(i),
                load(a, Expr::Sym(i) - int(1)) + load(a, Expr::Sym(i) + int(1)),
            );
        });
        let p = b.finish();
        let r = verify_program(&p);
        assert!(r.all_proven(), "{}", r.summary());
        // Worst-case fuel: the single loop runs ≤ N − 2 back-edges.
        let fuel = r.fuel_bound.expect("bounded");
        let slack = Expr::Sym(n) - fuel;
        assert!(bounds::prove_nonneg(&slack), "fuel bound too loose: {fuel}");
    }

    #[test]
    fn overrunning_gather_needs_check() {
        let mut b = ProgramBuilder::new("ver_gather");
        let n = b.param_positive("verg_N");
        let src = b.array("src", Expr::Sym(n));
        let dst = b.array("dst", Expr::Sym(n));
        let i = b.sym("verg_i");
        b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
            b.assign(dst, Expr::Sym(i), load(src, int(2) * Expr::Sym(i)));
        });
        let p = b.finish();
        let r = verify_program(&p);
        assert!(!r.all_proven());
        let checks = CheckSet::from_report(&r);
        assert!(!checks.is_empty());
        // The in-bounds write is NOT in the check set.
        let w = p.stmts()[0].write.clone();
        assert!(!checks.needs(p.stmts()[0].id, w.container, &w.offset));
    }

    #[test]
    fn definitely_oob_access_is_flagged() {
        let mut b = ProgramBuilder::new("ver_oob");
        let n = b.param_positive("vero_N");
        let a = b.array("A", Expr::Sym(n));
        let i = b.sym("vero_i");
        b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
            b.assign(a, Expr::Sym(i) + Expr::Sym(n), Expr::real(0.0));
        });
        let p = b.finish();
        let r = verify_program(&p);
        assert_eq!(r.proven_oob().len(), 1, "{}", r.summary());
    }

    #[test]
    fn guards_refine_boundary_accesses() {
        // if (i) y[i] = x[i-1]; if (1-i) y[i] = x[i]  — the blur_guard
        // pattern: both statements proven only through the guard.
        let mut b = ProgramBuilder::new("ver_guard");
        let n = b.param_positive("vgd_N");
        let x = b.array("x", Expr::Sym(n));
        let y = b.array("y", Expr::Sym(n));
        let i = b.sym("vgd_i");
        b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
            b.assign_if(
                Expr::Sym(i),
                y,
                Expr::Sym(i),
                load(x, Expr::Sym(i) - int(1)),
            );
            b.assign_if(
                int(1) - Expr::Sym(i),
                y,
                Expr::Sym(i),
                load(x, Expr::Sym(i)),
            );
        });
        let p = b.finish();
        let r = verify_program(&p);
        assert!(r.all_proven(), "{}", r.summary());
    }

    #[test]
    fn two_variable_guard_proves_antidiagonal() {
        // for i, j in [0, N): if (i + j < N) y[i + j] = x[i + j] — the
        // guard `N − i − j` correlates i and j, which per-variable
        // refinement cannot represent; the λ=1 slack fallback cancels
        // the subscript against the guard symbolically.
        let mut b = ProgramBuilder::new("ver_diag");
        let n = b.param_positive("vdg_N");
        let x = b.array("x", Expr::Sym(n));
        let y = b.array("y", Expr::Sym(n));
        let i = b.sym("vdg_i");
        let j = b.sym("vdg_j");
        b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
            b.for_(j, int(0), Expr::Sym(n), int(1), |b| {
                b.assign_if(
                    Expr::Sym(n) - Expr::Sym(i) - Expr::Sym(j),
                    y,
                    Expr::Sym(i) + Expr::Sym(j),
                    load(x, Expr::Sym(i) + Expr::Sym(j)),
                );
            });
        });
        let p = b.finish();
        let r = verify_program(&p);
        assert!(r.all_proven(), "{}", r.summary());
    }

    #[test]
    fn floordiv_subscript_proves_with_const_divisor() {
        // dst[i/2] over i ∈ [0, N) with |dst| = N: the exact
        // constant-divisor interval plus envelope elimination prove
        // both sides, so no runtime check is emitted.
        let mut b = ProgramBuilder::new("ver_fd");
        let n = b.param_positive("vfd_N");
        let src = b.array("src", Expr::Sym(n));
        let dst = b.array("dst", Expr::Sym(n));
        let i = b.sym("vfd_i");
        b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
            b.assign(
                dst,
                floordiv(Expr::Sym(i), int(2)),
                load(src, Expr::Sym(i)),
            );
        });
        let p = b.finish();
        let r = verify_program(&p);
        assert!(r.all_proven(), "{}", r.summary());
    }

    #[test]
    fn variable_stride_loop_is_fuel_unbounded_but_log2_access_proves() {
        use crate::symbolic::{func, FuncKind};
        let mut b = ProgramBuilder::new("ver_fig2");
        let n = b.param_positive("vf2_N");
        let a = b.array("A", int(64));
        let i = b.sym("vf2_i");
        b.for_(i, int(1), Expr::Sym(n), Expr::Sym(i), |b| {
            b.assign(a, func(FuncKind::Log2, vec![Expr::Sym(i)]), Expr::real(1.0));
        });
        let p = b.finish();
        let r = verify_program(&p);
        assert!(r.all_proven(), "{}", r.summary());
        assert!(r.fuel_bound.is_none());
    }
}
