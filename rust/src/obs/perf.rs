//! Hardware performance counters over raw `perf_event_open`, std-only.
//!
//! The cost model predicts cycles; the observability stack so far could
//! only *infer* them from wall clock × nominal GHz. This module makes
//! the hardware's own counts observable — cycles, instructions, cache
//! references/misses, branch misses — sampled around kernel execution
//! with the same no-crates raw-syscall discipline as `native/mem.rs`'s
//! mmap (a `#[repr(C)]` `perf_event_attr`, `syscall` via inline asm,
//! errno decoding by hand).
//!
//! Counters are opened per measurement as five independent fds with
//! `inherit = 1`, so worker threads the VM spawns *during* the run are
//! counted too (the kernel forbids combining `inherit` with group
//! reads, hence five fds instead of one group). Each is user-space
//! only (`exclude_kernel`/`exclude_hv`) so the default
//! `perf_event_paranoid = 2` policy still admits them.
//!
//! **Graceful degradation is the contract.** Containers, seccomp
//! filters, and locked-down hosts commonly deny `perf_event_open`, and
//! VMs without a PMU report `ENOENT` for hardware events. Every entry
//! point returns `Result<_, String>` with a human-actionable reason,
//! [`available`] probes once per process, and callers are expected to
//! surface `hw: unavailable (<reason>)` — never silent zeros (a 0.0
//! miss rate must mean "measured zero misses", not "could not
//! measure"). [`HwCounts::ipc`]/[`HwCounts::miss_rate`] return `None`
//! on a zero denominator for the same reason.

use std::collections::HashMap;

use crate::ir::LoopId;

use super::profile::ProfileTracer;
use crate::exec::trace::Tracer;

/// One sample of the five hardware counters (totals since the group's
/// last reset).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HwCounts {
    pub cycles: u64,
    pub instructions: u64,
    pub cache_references: u64,
    pub cache_misses: u64,
    pub branch_misses: u64,
}

impl HwCounts {
    /// Instructions per cycle — `None` when no cycles were counted, so
    /// an unmeasured sample can never read as an IPC of 0.0.
    pub fn ipc(&self) -> Option<f64> {
        (self.cycles > 0).then(|| self.instructions as f64 / self.cycles as f64)
    }

    /// Cache misses ÷ cache references — `None` when no references were
    /// counted (see [`HwCounts::ipc`] for why not 0.0).
    pub fn miss_rate(&self) -> Option<f64> {
        (self.cache_references > 0)
            .then(|| self.cache_misses as f64 / self.cache_references as f64)
    }

    /// Counter-wise `self − earlier`, saturating (counters are
    /// monotonic within one enable window, but saturate anyway so a
    /// reordered read cannot produce garbage deltas).
    pub fn minus(&self, earlier: &HwCounts) -> HwCounts {
        HwCounts {
            cycles: self.cycles.saturating_sub(earlier.cycles),
            instructions: self.instructions.saturating_sub(earlier.instructions),
            cache_references: self.cache_references.saturating_sub(earlier.cache_references),
            cache_misses: self.cache_misses.saturating_sub(earlier.cache_misses),
            branch_misses: self.branch_misses.saturating_sub(earlier.branch_misses),
        }
    }

    /// Counter-wise accumulate.
    pub fn add(&mut self, d: &HwCounts) {
        self.cycles += d.cycles;
        self.instructions += d.instructions;
        self.cache_references += d.cache_references;
        self.cache_misses += d.cache_misses;
        self.branch_misses += d.branch_misses;
    }

    /// One compact human-readable line (`silo profile --hw`).
    pub fn render(&self) -> String {
        let ipc = self
            .ipc()
            .map(|v| format!("{v:.2}"))
            .unwrap_or_else(|| "n/a".into());
        let miss = self
            .miss_rate()
            .map(|v| format!("{:.2}%", v * 100.0))
            .unwrap_or_else(|| "n/a".into());
        format!(
            "cycles {}  instructions {}  ipc {}  cache {}/{} ({})  branch-misses {}",
            self.cycles,
            self.instructions,
            ipc,
            self.cache_misses,
            self.cache_references,
            miss,
            self.branch_misses,
        )
    }
}

#[cfg(all(target_arch = "x86_64", target_os = "linux"))]
mod imp {
    use super::HwCounts;
    use std::arch::asm;

    const SYS_READ: i64 = 0;
    const SYS_CLOSE: i64 = 3;
    const SYS_IOCTL: i64 = 16;
    const SYS_PERF_EVENT_OPEN: i64 = 298;

    const PERF_TYPE_HARDWARE: u32 = 0;
    /// `perf_event_attr.size` of the original ABI revision. The kernel
    /// accepts any published size and treats the missing tail as
    /// zeroed, and the five fields this module sets all live in the
    /// first 64 bytes — pinning VER0 keeps the struct layout below
    /// honest on every kernel that has `perf_event_open` at all.
    const PERF_ATTR_SIZE_VER0: u32 = 64;

    /// Flag bits in the attr bitfield word: `disabled` (start stopped,
    /// enabled explicitly around the measured region), `inherit`
    /// (count threads spawned during the run), `exclude_kernel` +
    /// `exclude_hv` (user-space only, admissible under
    /// `perf_event_paranoid = 2`).
    const ATTR_DISABLED: u64 = 1 << 0;
    const ATTR_INHERIT: u64 = 1 << 1;
    const ATTR_EXCLUDE_KERNEL: u64 = 1 << 5;
    const ATTR_EXCLUDE_HV: u64 = 1 << 6;

    const PERF_EVENT_IOC_ENABLE: i64 = 0x2400;
    const PERF_EVENT_IOC_DISABLE: i64 = 0x2401;
    const PERF_EVENT_IOC_RESET: i64 = 0x2403;

    /// The five sampled events: `PERF_COUNT_HW_*` config values, in
    /// [`HwCounts`] field order.
    const EVENTS: [(&str, u64); 5] = [
        ("cycles", 0),            // PERF_COUNT_HW_CPU_CYCLES
        ("instructions", 1),      // PERF_COUNT_HW_INSTRUCTIONS
        ("cache-references", 2),  // PERF_COUNT_HW_CACHE_REFERENCES
        ("cache-misses", 3),      // PERF_COUNT_HW_CACHE_MISSES
        ("branch-misses", 5),     // PERF_COUNT_HW_BRANCH_MISSES
    ];

    /// First 64 bytes of the kernel's `perf_event_attr` (VER0 layout):
    /// everything this module needs, valid at `size = 64` everywhere.
    #[repr(C)]
    struct PerfEventAttr {
        type_: u32,
        size: u32,
        config: u64,
        sample_period: u64,
        sample_type: u64,
        read_format: u64,
        flags: u64,
        wakeup_events: u32,
        bp_type: u32,
        config1: u64,
    }

    /// `syscall` returns a negative errno in rax on failure; the kernel
    /// reserves the top 4095 values of the address space for that
    /// encoding (same decoding as `native/mem.rs`).
    fn syscall_failed(ret: i64) -> Option<i64> {
        if (ret as u64) >= (-4095i64) as u64 {
            Some(-ret)
        } else {
            None
        }
    }

    #[inline]
    unsafe fn sys3(n: i64, a: i64, b: i64, c: i64) -> i64 {
        let ret: i64;
        asm!(
            "syscall",
            inlateout("rax") n => ret,
            in("rdi") a,
            in("rsi") b,
            in("rdx") c,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
        ret
    }

    #[inline]
    unsafe fn sys5(n: i64, a: i64, b: i64, c: i64, d: i64, e: i64) -> i64 {
        let ret: i64;
        asm!(
            "syscall",
            inlateout("rax") n => ret,
            in("rdi") a,
            in("rsi") b,
            in("rdx") c,
            in("r10") d,
            in("r8") e,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
        ret
    }

    /// Human hint for the errnos `perf_event_open` actually returns.
    fn errno_hint(errno: i64) -> &'static str {
        match errno {
            1 | 13 => "denied — raise /proc/sys/kernel/perf_event_paranoid or grant \
                       CAP_PERFMON",
            2 => "hardware events unsupported on this host (no PMU — common in VMs)",
            22 => "attr rejected (EINVAL)",
            38 => "perf_event_open not implemented (seccomp or ancient kernel)",
            _ => "see perf_event_open(2)",
        }
    }

    /// One measurement window over the five hardware counters.
    ///
    /// Lifecycle: [`HwGroup::open`] (counters exist, stopped) →
    /// [`HwGroup::start`] (reset + enable) → run the measured code →
    /// [`HwGroup::stop`] (disable + read), with [`HwGroup::snapshot`]
    /// available for mid-window reads (the per-loop tracer). Fds close
    /// on drop.
    pub struct HwGroup {
        fds: [i64; 5],
    }

    impl HwGroup {
        /// Open all five counters for this thread (+ future children,
        /// via `inherit`). Any single failure closes what was opened
        /// and reports which event was refused and why.
        pub fn open() -> Result<HwGroup, String> {
            let mut fds = [-1i64; 5];
            for (i, (name, config)) in EVENTS.iter().enumerate() {
                let attr = PerfEventAttr {
                    type_: PERF_TYPE_HARDWARE,
                    size: PERF_ATTR_SIZE_VER0,
                    config: *config,
                    sample_period: 0,
                    sample_type: 0,
                    read_format: 0,
                    flags: ATTR_DISABLED
                        | ATTR_INHERIT
                        | ATTR_EXCLUDE_KERNEL
                        | ATTR_EXCLUDE_HV,
                    wakeup_events: 0,
                    bp_type: 0,
                    config1: 0,
                };
                // pid = 0 (this thread), cpu = -1 (any), no group fd,
                // no flags. Group reads are incompatible with inherit,
                // which is why every event is its own fd.
                let ret = unsafe {
                    sys5(
                        SYS_PERF_EVENT_OPEN,
                        &attr as *const PerfEventAttr as i64,
                        0,
                        -1,
                        -1,
                        0,
                    )
                };
                if let Some(errno) = syscall_failed(ret) {
                    for fd in fds.iter().take(i) {
                        unsafe { sys3(SYS_CLOSE, *fd, 0, 0) };
                    }
                    return Err(format!(
                        "perf_event_open({name}) failed (errno {errno}: {})",
                        errno_hint(errno)
                    ));
                }
                fds[i] = ret;
            }
            Ok(HwGroup { fds })
        }

        fn ioctl_all(&self, op: i64) -> Result<(), String> {
            for fd in self.fds {
                let ret = unsafe { sys3(SYS_IOCTL, fd, op, 0) };
                if let Some(errno) = syscall_failed(ret) {
                    return Err(format!("perf ioctl {op:#x} failed (errno {errno})"));
                }
            }
            Ok(())
        }

        /// Reset all counters to zero and start counting.
        pub fn start(&self) -> Result<(), String> {
            self.ioctl_all(PERF_EVENT_IOC_RESET)?;
            self.ioctl_all(PERF_EVENT_IOC_ENABLE)
        }

        /// Read the current totals without stopping the counters
        /// (inherited children are summed into each read).
        pub fn snapshot(&self) -> Result<HwCounts, String> {
            let mut vals = [0u64; 5];
            for (i, fd) in self.fds.iter().enumerate() {
                let mut buf = [0u8; 8];
                let ret =
                    unsafe { sys3(SYS_READ, *fd, buf.as_mut_ptr() as i64, buf.len() as i64) };
                if let Some(errno) = syscall_failed(ret) {
                    return Err(format!("perf read failed (errno {errno})"));
                }
                if ret != 8 {
                    return Err(format!("perf read returned {ret} bytes, expected 8"));
                }
                vals[i] = u64::from_ne_bytes(buf);
            }
            Ok(HwCounts {
                cycles: vals[0],
                instructions: vals[1],
                cache_references: vals[2],
                cache_misses: vals[3],
                branch_misses: vals[4],
            })
        }

        /// Stop counting and return the window's totals.
        pub fn stop(&self) -> Result<HwCounts, String> {
            self.ioctl_all(PERF_EVENT_IOC_DISABLE)?;
            self.snapshot()
        }
    }

    impl Drop for HwGroup {
        fn drop(&mut self) {
            for fd in self.fds {
                unsafe { sys3(SYS_CLOSE, fd, 0, 0) };
            }
        }
    }

    /// One open → start → stop round trip, run once per process.
    pub(super) fn probe() -> Result<(), String> {
        let g = HwGroup::open()?;
        g.start()?;
        g.stop().map(|_| ())
    }
}

/// Stub for hosts without the raw-syscall implementation (non-x86-64 or
/// non-Linux): [`HwGroup::open`] always fails with the reason, so every
/// caller takes its graceful-degradation path.
#[cfg(not(all(target_arch = "x86_64", target_os = "linux")))]
mod imp {
    use super::HwCounts;

    pub struct HwGroup {
        _private: (),
    }

    impl HwGroup {
        pub fn open() -> Result<HwGroup, String> {
            Err("hardware counters are only supported on x86-64 Linux".into())
        }

        pub fn start(&self) -> Result<(), String> {
            unreachable!("stub HwGroup cannot be constructed")
        }

        pub fn snapshot(&self) -> Result<HwCounts, String> {
            unreachable!("stub HwGroup cannot be constructed")
        }

        pub fn stop(&self) -> Result<HwCounts, String> {
            unreachable!("stub HwGroup cannot be constructed")
        }
    }

    pub(super) fn probe() -> Result<(), String> {
        HwGroup::open().map(|_| ())
    }
}

pub use imp::HwGroup;

/// Whether this host can count (probed once per process, like
/// [`crate::native::available`]). `false` means every `HwGroup::open`
/// would fail; [`status`] carries the reason.
pub fn available() -> bool {
    status().is_ok()
}

/// The probe's verdict: `Ok(())` or the denial reason callers must
/// surface as `hw: unavailable (<reason>)`.
pub fn status() -> Result<(), String> {
    static PROBE: std::sync::OnceLock<Result<(), String>> = std::sync::OnceLock::new();
    PROBE.get_or_init(imp::probe).clone()
}

/// Per-loop hardware-counter attribution from one instrumented replay:
/// what [`HwProfileTracer`] hands back next to the trip/access tallies.
#[derive(Debug, Default)]
pub struct HwLoopProfile {
    /// Loops in first-enter order (matches the [`ProfileTracer`] order).
    pub order: Vec<LoopId>,
    /// Exclusive counter deltas attributed to each loop (time spent in
    /// an inner loop is attributed to the inner loop, not its parents).
    pub per_loop: HashMap<LoopId, HwCounts>,
    /// Deltas attributed to no loop (prologue/epilogue).
    pub outside: HwCounts,
    /// First mid-run read failure, if any — partial attributions are
    /// reported, flagged, never passed off as complete.
    pub failed: Option<String>,
}

/// A [`ProfileTracer`] that additionally samples the hardware counters
/// at every loop boundary and attributes the deltas to the innermost
/// live loop — `silo profile --hw`'s per-loop IPC and miss-rate rows.
///
/// Sampling happens on `loop_enter`/`loop_exit` only (five `read`
/// syscalls per boundary); `loop_iter` stays unsampled so the replay's
/// cost stays proportional to the loop *structure*, not the trip count.
pub struct HwProfileTracer {
    inner: ProfileTracer,
    group: HwGroup,
    hw: HwLoopProfile,
    stack: Vec<LoopId>,
    last: HwCounts,
}

impl HwProfileTracer {
    /// Open-and-started tracer: counters run from here until
    /// [`HwProfileTracer::finish`].
    pub fn start(group: HwGroup) -> Result<HwProfileTracer, String> {
        group.start()?;
        let last = group.snapshot()?;
        Ok(HwProfileTracer {
            inner: ProfileTracer::new(),
            group,
            hw: HwLoopProfile::default(),
            stack: Vec::new(),
            last,
        })
    }

    /// Attribute the delta since the previous boundary to the loop that
    /// was innermost *during* that window (top of stack before the
    /// event that triggered this call).
    fn boundary(&mut self) {
        match self.group.snapshot() {
            Ok(now) => {
                let delta = now.minus(&self.last);
                match self.stack.last() {
                    Some(id) => self.hw.per_loop.entry(*id).or_default().add(&delta),
                    None => self.hw.outside.add(&delta),
                }
                self.last = now;
            }
            Err(e) => {
                if self.hw.failed.is_none() {
                    self.hw.failed = Some(e);
                }
            }
        }
    }

    /// Flush the trailing window and split into the access/trip tracer
    /// and the per-loop counter attribution.
    pub fn finish(mut self) -> (ProfileTracer, HwLoopProfile) {
        self.boundary();
        let _ = self.group.stop();
        (self.inner, self.hw)
    }
}

impl Tracer for HwProfileTracer {
    fn access(&mut self, cont: u16, idx: i64, write: bool, prefetch: bool) {
        self.inner.access(cont, idx, write, prefetch);
    }

    fn loop_enter(&mut self, id: LoopId) {
        self.boundary();
        if !self.hw.per_loop.contains_key(&id) {
            self.hw.order.push(id);
            self.hw.per_loop.insert(id, HwCounts::default());
        }
        self.stack.push(id);
        self.inner.loop_enter(id);
    }

    fn loop_iter(&mut self, id: LoopId) {
        self.inner.loop_iter(id);
    }

    fn loop_exit(&mut self, id: LoopId) {
        self.boundary();
        while let Some(top) = self.stack.pop() {
            if top == id {
                break;
            }
        }
        self.inner.loop_exit(id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A zero denominator must read as "unmeasured", never as 0.0 —
    /// the gauge-can't-silently-read-zero contract.
    #[test]
    fn derived_rates_refuse_zero_denominators() {
        let zero = HwCounts::default();
        assert_eq!(zero.ipc(), None);
        assert_eq!(zero.miss_rate(), None);
        let c = HwCounts {
            cycles: 100,
            instructions: 250,
            cache_references: 50,
            cache_misses: 5,
            branch_misses: 1,
        };
        assert_eq!(c.ipc(), Some(2.5));
        assert_eq!(c.miss_rate(), Some(0.1));
        assert!(c.render().contains("ipc 2.50"));
        assert!(zero.render().contains("n/a"));
    }

    #[test]
    fn delta_arithmetic_saturates() {
        let a = HwCounts {
            cycles: 10,
            instructions: 20,
            cache_references: 5,
            cache_misses: 1,
            branch_misses: 0,
        };
        let b = HwCounts {
            cycles: 25,
            instructions: 60,
            cache_references: 9,
            cache_misses: 1,
            branch_misses: 2,
        };
        let d = b.minus(&a);
        assert_eq!(d.cycles, 15);
        assert_eq!(d.instructions, 40);
        assert_eq!(a.minus(&b).cycles, 0, "reordered reads saturate, never wrap");
        let mut acc = HwCounts::default();
        acc.add(&d);
        acc.add(&d);
        assert_eq!(acc.instructions, 80);
    }

    /// Whatever the sandbox says, it must say it twice, and the probe's
    /// verdict must agree with a fresh open attempt.
    #[test]
    fn probe_is_stable_and_honest() {
        assert_eq!(available(), available());
        assert_eq!(available(), status().is_ok());
        match status() {
            Ok(()) => assert!(HwGroup::open().is_ok()),
            Err(reason) => {
                assert!(!reason.is_empty(), "denials must carry a reason");
                assert!(HwGroup::open().is_err());
            }
        }
    }

    /// On counting hosts: a real measurement window sees instructions
    /// retire. Hosts that deny the syscall exercise the degradation
    /// path instead — the test must pass both ways.
    #[test]
    fn measurement_window_counts_or_degrades() {
        let group = match HwGroup::open() {
            Ok(g) => g,
            Err(reason) => {
                assert!(!reason.is_empty());
                return;
            }
        };
        group.start().unwrap();
        let mut acc = 0u64;
        for i in 0..100_000u64 {
            acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
        }
        std::hint::black_box(acc);
        let counts = group.stop().unwrap();
        assert!(
            counts.instructions > 10_000,
            "a 100k-iteration loop retired only {} instructions",
            counts.instructions
        );
        assert!(counts.ipc().is_some());
    }
}
