//! Chrome trace-event JSON export.
//!
//! Serializes collected [`SpanEvent`]s into the Trace Event Format's
//! JSON-object form (`{"traceEvents": [...]}` with complete `"ph": "X"`
//! events), loadable directly by `chrome://tracing` and Perfetto. Events
//! are sorted by `(start, tid, name)` so the export is deterministic for
//! a given event set — the golden-file test depends on that.

use crate::service::json::Json;

use super::span::SpanEvent;

/// Render events as a Chrome trace JSON document.
pub fn chrome_trace_json(events: &[SpanEvent]) -> String {
    let mut sorted: Vec<&SpanEvent> = events.iter().collect();
    sorted.sort_by(|a, b| {
        a.start_us
            .cmp(&b.start_us)
            .then(a.tid.cmp(&b.tid))
            .then(a.name.cmp(&b.name))
    });
    let rows: Vec<Json> = sorted.iter().map(|e| event_json(e)).collect();
    let doc = Json::Obj(vec![
        ("traceEvents".into(), Json::Arr(rows)),
        ("displayTimeUnit".into(), Json::Str("ms".into())),
    ]);
    doc.to_string()
}

fn event_json(e: &SpanEvent) -> Json {
    let mut args: Vec<(String, Json)> = e
        .args
        .iter()
        .map(|(k, v)| ((*k).to_string(), Json::Str(v.clone())))
        .collect();
    if e.trace != 0 {
        args.push(("trace".into(), Json::Num(e.trace as f64)));
    }
    let mut obj = vec![
        ("name".into(), Json::Str(e.name.clone())),
        ("cat".into(), Json::Str(e.cat.to_string())),
        ("ph".into(), Json::Str("X".into())),
        ("ts".into(), Json::Num(e.start_us as f64)),
        ("dur".into(), Json::Num(e.dur_us as f64)),
        ("pid".into(), Json::Num(1.0)),
        ("tid".into(), Json::Num(e.tid as f64)),
    ];
    if !args.is_empty() {
        obj.push(("args".into(), Json::Obj(args)));
    }
    Json::Obj(obj)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn export_is_parseable_and_sorted() {
        let evs = vec![
            SpanEvent {
                name: "later".into(),
                cat: "compile",
                trace: 2,
                tid: 1,
                start_us: 50,
                dur_us: 5,
                args: vec![],
            },
            SpanEvent {
                name: "earlier".into(),
                cat: "tune",
                trace: 0,
                tid: 1,
                start_us: 10,
                dur_us: 30,
                args: vec![("score", "0.5".into())],
            },
        ];
        let s = chrome_trace_json(&evs);
        let doc = Json::parse(&s).expect("valid JSON");
        let rows = doc.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("name").and_then(|v| v.as_str()), Some("earlier"));
        assert_eq!(rows[0].get("ph").and_then(|v| v.as_str()), Some("X"));
        assert_eq!(rows[1].get("ts").and_then(|v| v.as_i64()), Some(50));
        assert_eq!(
            rows[0].get("args").and_then(|a| a.get("score")).and_then(|v| v.as_str()),
            Some("0.5")
        );
    }
}
