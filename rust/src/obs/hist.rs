//! Log₂-bucketed latency histograms.
//!
//! Fixed bucket layout shared by the plain and atomic variants: bucket
//! `i` counts samples `v` (in microseconds) with
//! `lower_edge(i) < v ≤ upper_edge(i)` where `upper_edge(i) = 2^i` µs,
//! except bucket 0 which also absorbs `v = 0` and the last bucket whose
//! upper edge is +∞. 28 buckets span 1 µs … 67 s — the full range of a
//! compile or metered run — in a fixed 224-byte footprint, which is what
//! lets the daemon keep one histogram per endpoint with no allocation on
//! the request path.

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of buckets (last one is the +∞ overflow bucket).
pub const BUCKETS: usize = 28;

/// Index of the bucket that counts `us`.
#[inline]
pub fn bucket_of(us: u64) -> usize {
    if us <= 1 {
        return 0;
    }
    // Smallest i with us <= 2^i, i.e. ceil(log2(us)).
    let i = (64 - (us - 1).leading_zeros()) as usize;
    i.min(BUCKETS - 1)
}

/// Inclusive upper edge of bucket `i` in µs (+∞ for the last bucket).
pub fn upper_edge(i: usize) -> f64 {
    if i >= BUCKETS - 1 {
        f64::INFINITY
    } else {
        (1u64 << i) as f64
    }
}

/// Exclusive lower edge of bucket `i` in µs.
pub fn lower_edge(i: usize) -> f64 {
    if i == 0 {
        0.0
    } else {
        (1u64 << (i - 1)) as f64
    }
}

/// A plain (single-writer) histogram of microsecond samples.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Histogram {
    pub counts: [u64; BUCKETS],
    pub count: u64,
    /// Sum of all recorded samples, µs.
    pub sum_us: u64,
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    pub fn record(&mut self, us: u64) {
        self.counts[bucket_of(us)] += 1;
        self.count += 1;
        self.sum_us = self.sum_us.saturating_add(us);
    }

    /// Fold another histogram into this one (same fixed layout).
    pub fn merge(&mut self, other: &Histogram) {
        for i in 0..BUCKETS {
            self.counts[i] += other.counts[i];
        }
        self.count += other.count;
        self.sum_us = self.sum_us.saturating_add(other.sum_us);
    }

    /// Arithmetic mean in µs (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    /// Estimate the `p`-quantile (`0 ≤ p ≤ 1`) by linear interpolation
    /// inside the bucket containing the target rank. The overflow bucket
    /// has no finite upper edge, so samples landing there estimate as its
    /// lower edge — an admitted underestimate, stated rather than hidden.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = p.clamp(0.0, 1.0) * self.count as f64;
        let mut cum = 0u64;
        for i in 0..BUCKETS {
            let c = self.counts[i];
            if c == 0 {
                continue;
            }
            let next = cum + c;
            if (next as f64) >= target {
                let lo = lower_edge(i);
                let hi = upper_edge(i);
                if !hi.is_finite() {
                    return lo;
                }
                let within = ((target - cum as f64) / c as f64).clamp(0.0, 1.0);
                return lo + (hi - lo) * within;
            }
            cum = next;
        }
        lower_edge(BUCKETS - 1)
    }
}

/// Shared-writer histogram: relaxed atomics, fixed footprint, snapshot
/// by copy. The counters are monotone and read individually, so a
/// snapshot taken under concurrent writes is a valid (if slightly torn)
/// histogram — exactly the Prometheus scrape model.
#[derive(Debug, Default)]
pub struct AtomicHistogram {
    counts: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl AtomicHistogram {
    pub fn new() -> AtomicHistogram {
        AtomicHistogram::default()
    }

    pub fn record(&self, us: u64) {
        self.counts[bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> Histogram {
        let mut h = Histogram::new();
        for i in 0..BUCKETS {
            h.counts[i] = self.counts[i].load(Ordering::Relaxed);
        }
        h.count = self.count.load(Ordering::Relaxed);
        h.sum_us = self.sum_us.load(Ordering::Relaxed);
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(2), 1);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 2);
        assert_eq!(bucket_of(5), 3);
        assert_eq!(bucket_of(1 << 20), 20);
        assert_eq!(bucket_of((1 << 20) + 1), 21);
        assert_eq!(bucket_of(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn record_merge_and_mean() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record(1);
        a.record(100);
        b.record(1000);
        a.merge(&b);
        assert_eq!(a.count, 3);
        assert_eq!(a.sum_us, 1101);
        assert_eq!(a.counts.iter().sum::<u64>(), 3);
        assert!((a.mean() - 367.0).abs() < 1.0);
    }

    #[test]
    fn percentile_estimation() {
        let mut h = Histogram::new();
        for _ in 0..100 {
            h.record(3); // bucket 2: (2, 4]
        }
        // All mass in one bucket: quantiles interpolate across (2, 4].
        assert!(h.percentile(0.0) >= 2.0);
        assert!(h.percentile(1.0) <= 4.0);
        assert!(h.percentile(0.5) > 2.0 && h.percentile(0.5) < 4.0);
        // Empty histogram.
        assert_eq!(Histogram::new().percentile(0.5), 0.0);
    }

    #[test]
    fn atomic_snapshot_matches_plain() {
        let ah = AtomicHistogram::new();
        let mut h = Histogram::new();
        for v in [0, 1, 7, 4096, 1 << 30] {
            ah.record(v);
            h.record(v);
        }
        assert_eq!(ah.snapshot(), h);
    }
}
