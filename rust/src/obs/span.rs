//! Span/event core: monotonic-clock spans with per-thread buffers.
//!
//! A [`Span`] is an RAII guard: construct it where the work starts, drop
//! it where the work ends, and (iff collection is enabled) a
//! [`SpanEvent`] with microsecond start/duration lands in the current
//! thread's buffer. Buffers flush into a global sink in batches — and
//! unconditionally when their thread exits, so spans recorded on scoped
//! worker threads (the tuner's candidate evaluators) are never lost.
//!
//! Cost model: when collection is disabled (the default), `span()` is a
//! single relaxed atomic load and **zero allocations** — callers may
//! leave instrumentation in place permanently. When enabled, recording a
//! span is a clock read, a `String`, and an (amortized) uncontended
//! buffer push.
//!
//! Trace scoping: [`next_trace_id`] mints process-unique ids; the daemon
//! assigns one per HTTP request and the profiler one per profile run, so
//! exported events group by the request that caused them.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// One completed span, timestamped in microseconds since the process
/// epoch (the first clock read after the observability layer woke up).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpanEvent {
    /// What ran (e.g. a pass name, an endpoint, a kernel).
    pub name: String,
    /// Coarse taxonomy bucket: `"compile"`, `"tune"`, `"exec"`, `"http"`.
    pub cat: &'static str,
    /// Request/run-scoped trace id (0 = unscoped).
    pub trace: u64,
    /// Small dense per-thread tag (not the OS tid).
    pub tid: u64,
    pub start_us: u64,
    pub dur_us: u64,
    /// Free-form key/value annotations (score, cache hits, …).
    pub args: Vec<(&'static str, String)>,
}

/// Serializes in-crate tests that toggle the process-global enabled
/// flag or drain the sink (the harness runs tests on parallel threads).
#[cfg(test)]
pub(crate) static TEST_GUARD: Mutex<()> = Mutex::new(());

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_TRACE: AtomicU64 = AtomicU64::new(1);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
static EPOCH: OnceLock<Instant> = OnceLock::new();
static SINK: Mutex<Vec<SpanEvent>> = Mutex::new(Vec::new());

/// Hard cap on buffered events; beyond it new spans are dropped (never
/// an OOM vector, mirroring `CollectingTracer`'s cap).
const SINK_CAP: usize = 1 << 20;
/// Thread-local batch size before flushing into the global sink.
const FLUSH_AT: usize = 256;

/// Turn span collection on or off process-wide.
pub fn set_enabled(on: bool) {
    // Pin the epoch before the first span so timestamps are meaningful.
    if on {
        let _ = EPOCH.get_or_init(Instant::now);
    }
    ENABLED.store(on, Ordering::Relaxed);
}

/// Is span collection currently enabled?
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Microseconds since the process epoch.
pub fn now_us() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

/// Mint a process-unique trace id (requests, profile runs).
pub fn next_trace_id() -> u64 {
    NEXT_TRACE.fetch_add(1, Ordering::Relaxed)
}

thread_local! {
    static CUR_TRACE: RefCell<u64> = const { RefCell::new(0) };
    static BUF: RefCell<ThreadBuf> = RefCell::new(ThreadBuf {
        events: Vec::new(),
        tag: NEXT_TID.fetch_add(1, Ordering::Relaxed),
    });
}

struct ThreadBuf {
    events: Vec<SpanEvent>,
    tag: u64,
}

impl Drop for ThreadBuf {
    fn drop(&mut self) {
        flush_into_sink(&mut self.events);
    }
}

fn flush_into_sink(events: &mut Vec<SpanEvent>) {
    if events.is_empty() {
        return;
    }
    if let Ok(mut sink) = SINK.lock() {
        let room = SINK_CAP.saturating_sub(sink.len());
        let take = events.len().min(room);
        sink.extend(events.drain(..take));
    }
    events.clear();
}

/// Set the current thread's trace id; returns the previous one so callers
/// can restore it (request handlers bracket their work with this).
pub fn set_current_trace(id: u64) -> u64 {
    CUR_TRACE.with(|t| std::mem::replace(&mut *t.borrow_mut(), id))
}

/// The current thread's trace id (0 = unscoped).
pub fn current_trace() -> u64 {
    CUR_TRACE.with(|t| *t.borrow())
}

/// Drain every buffered event: the current thread's batch plus the
/// global sink. Other *live* threads' partial batches are not visible
/// until they flush or exit — the CLI profiler drains after its scoped
/// workers have joined, so it always sees a complete trace.
pub fn take_events() -> Vec<SpanEvent> {
    BUF.with(|b| flush_into_sink(&mut b.borrow_mut().events));
    match SINK.lock() {
        Ok(mut sink) => std::mem::take(&mut *sink),
        Err(_) => Vec::new(),
    }
}

/// RAII span guard — see the module docs. Obtain via [`span`].
pub struct Span {
    live: Option<LiveSpan>,
}

struct LiveSpan {
    name: String,
    cat: &'static str,
    trace: u64,
    start_us: u64,
    args: Vec<(&'static str, String)>,
}

/// Open a span. When collection is disabled this is one atomic load and
/// the `name` closure is never called (no allocation).
pub fn span(cat: &'static str, name: impl FnOnce() -> String) -> Span {
    if !enabled() {
        return Span { live: None };
    }
    Span {
        live: Some(LiveSpan {
            name: name(),
            cat,
            trace: current_trace(),
            start_us: now_us(),
            args: Vec::new(),
        }),
    }
}

impl Span {
    /// Attach a key/value annotation (no-op when the span is dead).
    pub fn arg(&mut self, key: &'static str, val: impl FnOnce() -> String) {
        if let Some(l) = self.live.as_mut() {
            l.args.push((key, val()));
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(l) = self.live.take() {
            let dur_us = now_us().saturating_sub(l.start_us);
            let ev = SpanEvent {
                name: l.name,
                cat: l.cat,
                trace: l.trace,
                tid: BUF.with(|b| b.borrow().tag),
                start_us: l.start_us,
                dur_us,
                args: l.args,
            };
            BUF.with(|b| {
                let mut b = b.borrow_mut();
                b.events.push(ev);
                if b.events.len() >= FLUSH_AT {
                    flush_into_sink(&mut b.events);
                }
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // One test, not several: the enabled flag is process-global and the
    // test harness runs threads concurrently.
    #[test]
    fn span_lifecycle() {
        let _g = TEST_GUARD.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(false);
        drop(span("compile", || "never".into()));
        assert!(!take_events().iter().any(|e| e.name == "never"));

        set_enabled(true);
        let t = next_trace_id();
        let prev = set_current_trace(t);
        {
            let mut s = span("tune", || "candidate".into());
            s.arg("score", || "1.5".into());
        }
        set_current_trace(prev);
        set_enabled(false);
        let evs = take_events();
        let ev = evs
            .iter()
            .find(|e| e.name == "candidate" && e.trace == t)
            .expect("span recorded");
        assert_eq!(ev.cat, "tune");
        assert_eq!(ev.args, vec![("score", "1.5".to_string())]);
    }
}
