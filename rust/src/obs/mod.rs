//! Full-stack observability: spans, profiles, histograms, exports.
//!
//! SILO's schedule decisions are only as good as the machine model behind
//! them, and the model is only as good as what we can *measure*. This
//! subsystem (std-only, like everything else in the crate) provides the
//! measurement substrate threaded through every layer:
//!
//! | Module      | Role                                                    |
//! |-------------|---------------------------------------------------------|
//! | [`span`]    | Monotonic-clock spans, thread-buffered, trace-scoped    |
//! | [`chrome`]  | Chrome trace-event JSON export (`chrome://tracing`)     |
//! | [`hist`]    | Log₂-bucketed latency histograms (plain + atomic)       |
//! | [`profile`] | Per-loop execution profiles via the VM `Tracer` hooks   |
//! | [`perf`]    | Hardware counters via raw `perf_event_open` syscalls    |
//!
//! Design contract: **off means off**. Span collection is gated on one
//! relaxed atomic load and allocates nothing when disabled; the VM loop
//! hooks are default-empty trait methods monomorphized away for
//! [`crate::exec::NullTracer`]; profiled execution uses a *separate*
//! lowering ([`crate::lowering::lower_profiled`]) so ordinary artifacts —
//! and therefore all differential VM/native/speculative tests — are
//! byte-for-byte unaffected by this subsystem's existence.

pub mod chrome;
pub mod hist;
pub mod perf;
pub mod profile;
pub mod span;

pub use chrome::chrome_trace_json;
pub use hist::{AtomicHistogram, Histogram, BUCKETS};
pub use perf::{HwCounts, HwGroup, HwLoopProfile, HwProfileTracer};
pub use profile::{ExecProfile, LoopProfile, ProfileTracer};
pub use span::{enabled, next_trace_id, set_enabled, span, take_events, Span, SpanEvent};
