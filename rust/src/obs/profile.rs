//! Per-loop execution profiles via the VM's [`Tracer`] loop hooks.
//!
//! [`ProfileTracer`] rides along a VM run of a *profiled* artifact
//! ([`crate::lowering::lower_profiled`] keeps every loop a tree node so
//! the hooks see loop identity) and tallies, per loop: iterations and the
//! reads/writes/prefetches its body performed. Accesses are attributed
//! to the innermost live loop — the hook call order is a well-nested
//! enter/iter/…/exit bracket on the sequential path, which is the only
//! path `silo profile` uses (it runs the profiled artifact at 1 thread
//! for determinism; wall-clock numbers come from the real artifact).

use std::collections::HashMap;

use crate::exec::trace::Tracer;
use crate::ir::{LoopId, Program};

/// Raw per-loop tallies.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LoopTally {
    pub iters: u64,
    pub reads: u64,
    pub writes: u64,
    pub prefetches: u64,
}

/// Tracer that builds an [`ExecProfile`] from one sequential VM run.
#[derive(Default)]
pub struct ProfileTracer {
    /// First-enter order, for stable reporting.
    order: Vec<LoopId>,
    tallies: HashMap<LoopId, LoopTally>,
    stack: Vec<LoopId>,
    /// Accesses performed outside any tree loop (prologue/epilogue code).
    pub outside: LoopTally,
}

impl ProfileTracer {
    pub fn new() -> ProfileTracer {
        ProfileTracer::default()
    }

    /// Resolve tallies into a report, naming loops via `program` (the
    /// *same* program the profiled artifact was lowered from, so every
    /// hook id resolves).
    pub fn finish(self, program: &Program) -> ExecProfile {
        let parents = program.loop_parents();
        let loops = self
            .order
            .iter()
            .map(|id| {
                let t = self.tallies.get(id).copied().unwrap_or_default();
                LoopProfile {
                    id: *id,
                    var: program
                        .find_loop(*id)
                        .map(|l| l.var.name())
                        .unwrap_or_else(|| format!("loop#{}", id.0)),
                    depth: parents.get(id).map(|p| p.len()).unwrap_or(0),
                    iters: t.iters,
                    reads: t.reads,
                    writes: t.writes,
                    prefetches: t.prefetches,
                }
            })
            .collect();
        ExecProfile {
            loops,
            outside: self.outside,
        }
    }
}

impl Tracer for ProfileTracer {
    fn access(&mut self, _cont: u16, _idx: i64, write: bool, prefetch: bool) {
        let t = match self.stack.last() {
            Some(id) => self.tallies.entry(*id).or_default(),
            None => &mut self.outside,
        };
        if prefetch {
            t.prefetches += 1;
        } else if write {
            t.writes += 1;
        } else {
            t.reads += 1;
        }
    }

    fn loop_enter(&mut self, id: LoopId) {
        if !self.tallies.contains_key(&id) {
            self.order.push(id);
            self.tallies.insert(id, LoopTally::default());
        }
        self.stack.push(id);
    }

    fn loop_iter(&mut self, id: LoopId) {
        self.tallies.entry(id).or_default().iters += 1;
    }

    fn loop_exit(&mut self, id: LoopId) {
        // Pop to (and including) the matching frame; tolerate an
        // unbalanced stack rather than corrupting attribution.
        while let Some(top) = self.stack.pop() {
            if top == id {
                break;
            }
        }
    }
}

/// One loop's row in the execution profile.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LoopProfile {
    pub id: LoopId,
    /// The loop variable's name (`i`, `k`, …).
    pub var: String,
    /// Nesting depth (0 = outermost).
    pub depth: usize,
    pub iters: u64,
    pub reads: u64,
    pub writes: u64,
    pub prefetches: u64,
}

/// The full per-loop execution report of one profiled run.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct ExecProfile {
    /// Loops in first-execution order.
    pub loops: Vec<LoopProfile>,
    /// Accesses attributed to no loop (prologue/epilogue).
    pub outside: LoopTally,
}

impl ExecProfile {
    /// Total iterations across all loops — equals the sequential run's
    /// `fuel_used` (one fuel unit per back-edge; see `Tracer::loop_iter`).
    pub fn total_iters(&self) -> u64 {
        self.loops.iter().map(|l| l.iters).sum()
    }

    /// Human-readable table, one row per loop.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("  loop        iters        reads       writes   prefetches\n");
        for l in &self.loops {
            let name = format!("{}{}", "  ".repeat(l.depth), l.var);
            out.push_str(&format!(
                "  {:<8} {:>10} {:>12} {:>12} {:>12}\n",
                name, l.iters, l.reads, l.writes, l.prefetches
            ));
        }
        if self.outside.reads + self.outside.writes + self.outside.prefetches > 0 {
            out.push_str(&format!(
                "  {:<8} {:>10} {:>12} {:>12} {:>12}\n",
                "(outer)", "-", self.outside.reads, self.outside.writes, self.outside.prefetches
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attribution_follows_the_loop_stack() {
        let mut tr = ProfileTracer::new();
        let outer = LoopId(0);
        let inner = LoopId(1);
        tr.access(0, 0, false, false); // before any loop → outside
        tr.loop_enter(outer);
        tr.loop_iter(outer);
        tr.access(0, 1, true, false); // outer body write
        tr.loop_enter(inner);
        tr.loop_iter(inner);
        tr.access(0, 2, false, false); // inner body read
        tr.loop_iter(inner);
        tr.access(0, 3, false, true); // inner prefetch
        tr.loop_exit(inner);
        tr.loop_exit(outer);

        assert_eq!(tr.outside.reads, 1);
        let o = tr.tallies[&outer];
        let i = tr.tallies[&inner];
        assert_eq!((o.iters, o.writes), (1, 1));
        assert_eq!((i.iters, i.reads, i.prefetches), (2, 1, 1));
    }
}
