//! Minimal in-crate property testing (proptest is not in the vendored
//! crate set): a deterministic xorshift generator plus helpers for
//! randomized invariant checks with reproducible seeds.

/// Deterministic xorshift64* PRNG.
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Rng(seed.max(1))
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + (self.next_u64() % ((hi - lo + 1) as u64)) as i64
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (self.next_u64() as f64 / u64::MAX as f64) * (hi - lo)
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[(self.next_u64() % xs.len() as u64) as usize]
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }
}

/// Run `f` for `cases` seeded cases; panic messages name the failing seed
/// so failures reproduce exactly.
pub fn check(name: &str, cases: u64, mut f: impl FnMut(&mut Rng)) {
    for case in 0..cases {
        let seed = 0x9E3779B97F4A7C15u64.wrapping_mul(case + 1);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            panic!("property {name} failed at case {case} (seed {seed:#x}): {e:?}");
        }
    }
}
