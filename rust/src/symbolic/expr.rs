//! Core symbolic expression ADT.
//!
//! SILO characterizes loops by four symbolic quantities and data accesses by
//! symbolic offset expressions (paper §2.1). This module provides the
//! expression tree those quantities are made of. Expressions are plain
//! value types (`Eq + Ord + Hash`) so canonical forms can be compared and
//! used as map keys; floating-point constants are stored as bit patterns to
//! keep those derives sound.
//!
//! Index expressions are integer-valued; compute expressions (statement
//! right-hand sides) may additionally contain [`Expr::Load`] leaves reading
//! from data containers and real-valued constants/functions.

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

/// Interned symbol identifier. Symbols are global to the process and carry
/// a name plus assumptions (see [`crate::symbolic::assume`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Sym(pub u32);

/// Identifier of a data container (declared in [`crate::ir::Program`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ContainerId(pub u32);

/// Uninterpreted / numeric function heads usable in expressions.
///
/// For *index* analysis these are uninterpreted atoms: two applications are
/// equal iff their canonicalized arguments are equal, which preserves the
/// injectivity reasoning of the paper (e.g. `a[log2(i)]` in Fig. 2). For
/// *compute* evaluation each head has a numeric semantics in `eval`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum FuncKind {
    Log2,
    Exp,
    Sqrt,
    Abs,
    /// select(cond, a, b): cond > 0 ? a : b  (used for guards / max-style updates)
    Select,
    /// 1/x — compute-only (division is not index arithmetic); uninterpreted
    /// for the dependence analysis like every other function head.
    Recip,
}

impl FuncKind {
    pub fn name(self) -> &'static str {
        match self {
            FuncKind::Log2 => "log2",
            FuncKind::Exp => "exp",
            FuncKind::Sqrt => "sqrt",
            FuncKind::Abs => "abs",
            FuncKind::Select => "select",
            FuncKind::Recip => "recip",
        }
    }
}

/// Symbolic expression.
///
/// Canonical-form invariants (established by [`crate::symbolic::simplify`]):
/// * `Add`/`Mul` operand lists are flattened, sorted, and have ≥ 2 elements;
///   integer constants are folded and, if present, appear first.
/// * `Add` carries no duplicate non-constant terms (they are collected with
///   integer coefficients); `Mul` collects repeated factors into `Pow`.
/// * `Pow` exponents are ≥ 2 (x^0, x^1 never survive simplification).
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Expr {
    /// Integer constant.
    Int(i64),
    /// Real constant, stored as `f64::to_bits` so `Eq`/`Hash` are derivable.
    Real(u64),
    /// Reference to an interned symbol.
    Sym(Sym),
    /// n-ary sum.
    Add(Vec<Expr>),
    /// n-ary product.
    Mul(Vec<Expr>),
    /// Integer power (exponent ≥ 2 in canonical form).
    Pow(Box<Expr>, u32),
    /// Floor division `a / b` (integer semantics).
    FloorDiv(Box<Expr>, Box<Expr>),
    /// Remainder `a mod b`.
    Mod(Box<Expr>, Box<Expr>),
    Min(Box<Expr>, Box<Expr>),
    Max(Box<Expr>, Box<Expr>),
    /// Function application (uninterpreted for index analysis).
    Func(FuncKind, Vec<Expr>),
    /// Read of `container[offset]` — only valid in compute expressions.
    Load(ContainerId, Box<Expr>),
}

impl Expr {
    pub fn real(v: f64) -> Expr {
        Expr::Real(v.to_bits())
    }

    pub fn real_value(&self) -> Option<f64> {
        match self {
            Expr::Real(bits) => Some(f64::from_bits(*bits)),
            Expr::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Expr::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn is_zero(&self) -> bool {
        matches!(self, Expr::Int(0)) || matches!(self, Expr::Real(b) if f64::from_bits(*b) == 0.0)
    }

    pub fn is_one(&self) -> bool {
        matches!(self, Expr::Int(1))
    }

    /// All symbols occurring in the expression.
    pub fn symbols(&self) -> Vec<Sym> {
        let mut out = Vec::new();
        self.visit(&mut |e| {
            if let Expr::Sym(s) = e {
                if !out.contains(s) {
                    out.push(*s);
                }
            }
        });
        out
    }

    /// Does the expression mention symbol `s`?
    pub fn depends_on(&self, s: Sym) -> bool {
        let mut found = false;
        self.visit(&mut |e| {
            if let Expr::Sym(x) = e {
                if *x == s {
                    found = true;
                }
            }
        });
        found
    }

    /// All containers loaded from (compute expressions).
    pub fn loads(&self) -> Vec<(ContainerId, Expr)> {
        let mut out = Vec::new();
        self.visit(&mut |e| {
            if let Expr::Load(c, off) = e {
                out.push((*c, (**off).clone()));
            }
        });
        out
    }

    pub fn contains_load(&self) -> bool {
        let mut found = false;
        self.visit(&mut |e| {
            if matches!(e, Expr::Load(..)) {
                found = true;
            }
        });
        found
    }

    /// Pre-order visit of every node.
    pub fn visit(&self, f: &mut impl FnMut(&Expr)) {
        f(self);
        match self {
            Expr::Add(xs) | Expr::Mul(xs) | Expr::Func(_, xs) => {
                for x in xs {
                    x.visit(f);
                }
            }
            Expr::Pow(b, _) => b.visit(f),
            Expr::FloorDiv(a, b) | Expr::Mod(a, b) | Expr::Min(a, b) | Expr::Max(a, b) => {
                a.visit(f);
                b.visit(f);
            }
            Expr::Load(_, off) => off.visit(f),
            Expr::Int(_) | Expr::Real(_) | Expr::Sym(_) => {}
        }
    }

    /// Structural map over children (bottom-up rebuild).
    pub fn map(&self, f: &impl Fn(&Expr) -> Expr) -> Expr {
        let rebuilt = match self {
            Expr::Add(xs) => Expr::Add(xs.iter().map(|x| x.map(f)).collect()),
            Expr::Mul(xs) => Expr::Mul(xs.iter().map(|x| x.map(f)).collect()),
            Expr::Func(k, xs) => Expr::Func(*k, xs.iter().map(|x| x.map(f)).collect()),
            Expr::Pow(b, e) => Expr::Pow(Box::new(b.map(f)), *e),
            Expr::FloorDiv(a, b) => Expr::FloorDiv(Box::new(a.map(f)), Box::new(b.map(f))),
            Expr::Mod(a, b) => Expr::Mod(Box::new(a.map(f)), Box::new(b.map(f))),
            Expr::Min(a, b) => Expr::Min(Box::new(a.map(f)), Box::new(b.map(f))),
            Expr::Max(a, b) => Expr::Max(Box::new(a.map(f)), Box::new(b.map(f))),
            Expr::Load(c, off) => Expr::Load(*c, Box::new(off.map(f))),
            Expr::Int(_) | Expr::Real(_) | Expr::Sym(_) => self.clone(),
        };
        f(&rebuilt)
    }

    /// Number of nodes (used by cost heuristics and tests).
    pub fn size(&self) -> usize {
        let mut n = 0;
        self.visit(&mut |_| n += 1);
        n
    }
}

// ---------------------------------------------------------------------------
// Symbol interner
// ---------------------------------------------------------------------------

/// Assumption flags carried by a symbol (paper: "program parameters that do
/// not change over the course of the loop" are typically positive sizes).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Assumptions {
    /// Known strictly positive (array extents, strides in canonical kernels).
    pub positive: bool,
    /// Known non-negative (loop counters starting at 0).
    pub nonneg: bool,
    /// Provable lower bound (array extents are ≥ 2 — the assumption a
    /// multidimensional-array IR gives for free and that disambiguates
    /// linearized cross-dimension accesses).
    pub min: i64,
}

#[derive(Default)]
struct SymTable {
    names: Vec<String>,
    assume: Vec<Assumptions>,
    by_name: HashMap<String, Sym>,
    /// Slots handed back by [`release_syms`], reused by the next intern.
    free: Vec<u32>,
}

impl SymTable {
    /// Allocate a slot for a new name, reusing a released slot if any.
    fn alloc(&mut self, name: &str) -> Sym {
        let s = match self.free.pop() {
            Some(i) => {
                self.names[i as usize] = name.to_string();
                self.assume[i as usize] = Assumptions::default();
                Sym(i)
            }
            None => {
                let s = Sym(self.names.len() as u32);
                self.names.push(name.to_string());
                self.assume.push(Assumptions::default());
                s
            }
        };
        self.by_name.insert(name.to_string(), s);
        s
    }
}

fn table() -> &'static Mutex<SymTable> {
    static TABLE: OnceLock<Mutex<SymTable>> = OnceLock::new();
    TABLE.get_or_init(|| Mutex::new(SymTable::default()))
}

/// Number of *live* interned symbols (allocated minus released). The
/// table is process-global; without scoped release it only ever grows,
/// which the service daemon makes observable on `/metrics` and bounds
/// by releasing each cache entry's symbols on eviction.
pub fn intern_table_size() -> usize {
    let t = table().lock().unwrap();
    t.names.len() - t.free.len()
}

// Recording scopes are per thread: the daemon compiles on several worker
// threads at once, and one compile's scope must not capture another's
// interns.
thread_local! {
    static RECORDERS: std::cell::RefCell<Vec<Vec<(Sym, bool)>>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

fn record(s: Sym, newly_interned: bool) {
    RECORDERS.with(|r| {
        for scope in r.borrow_mut().iter_mut() {
            scope.push((s, newly_interned));
        }
    });
}

/// RAII recording scope: every symbol this *thread* interns (or looks
/// up) between [`SymScope::begin`] and [`SymScope::finish`] is captured,
/// each tagged with whether the intern created it. The service daemon
/// wraps each compile in one, refcounts the captured symbols per cache
/// entry, and hands symbols whose last entry was evicted to
/// [`release_syms`] — bounding the intern table by the cache capacity
/// instead of the submission history.
pub struct SymScope(());

impl SymScope {
    pub fn begin() -> SymScope {
        RECORDERS.with(|r| r.borrow_mut().push(Vec::new()));
        SymScope(())
    }

    /// End the scope and return the captured symbols, deduplicated (the
    /// `bool` is true iff this scope's thread created the symbol), in
    /// first-touch order.
    pub fn finish(self) -> Vec<(Sym, bool)> {
        let raw = RECORDERS.with(|r| r.borrow_mut().pop().unwrap_or_default());
        std::mem::forget(self);
        let mut seen: HashMap<Sym, usize> = HashMap::new();
        let mut out: Vec<(Sym, bool)> = Vec::new();
        for (s, new) in raw {
            match seen.get(&s) {
                Some(&i) => out[i].1 |= new,
                None => {
                    seen.insert(s, out.len());
                    out.push((s, new));
                }
            }
        }
        out
    }
}

impl Drop for SymScope {
    fn drop(&mut self) {
        // Abandoned scope (error path): discard the recording.
        RECORDERS.with(|r| {
            r.borrow_mut().pop();
        });
    }
}

/// Return symbols' slots to the interner's free list. **Caller-proved
/// precondition**: no live [`Sym`] copy of any released symbol remains —
/// a stale copy would read (or alias) whatever name reuses the slot.
/// The service daemon is the intended caller: it releases a symbol only
/// when the last cache entry recorded as touching it is evicted and no
/// compile is in flight. Symbols whose `by_name` entry no longer points
/// at them (already released, or renamed by a re-intern) are skipped.
pub fn release_syms(syms: &[Sym]) {
    let mut t = table().lock().unwrap();
    for s in syms {
        let i = s.0 as usize;
        if i >= t.names.len() || t.by_name.get(&t.names[i]) != Some(s) {
            continue;
        }
        let name = std::mem::take(&mut t.names[i]);
        t.by_name.remove(&name);
        t.assume[i] = Assumptions::default();
        t.free.push(s.0);
    }
}

impl Sym {
    /// Intern a symbol by name. Repeated calls with the same name return the
    /// same symbol (assumptions are preserved from the first registration).
    pub fn new(name: &str) -> Sym {
        let mut t = table().lock().unwrap();
        if let Some(s) = t.by_name.get(name) {
            let s = *s;
            drop(t);
            record(s, false);
            return s;
        }
        let s = t.alloc(name);
        drop(t);
        record(s, true);
        s
    }

    /// Intern a symbol assumed strictly positive (e.g. array sizes/strides).
    pub fn positive(name: &str) -> Sym {
        let s = Sym::new(name);
        let mut t = table().lock().unwrap();
        t.assume[s.0 as usize].positive = true;
        t.assume[s.0 as usize].nonneg = true;
        t.assume[s.0 as usize].min = t.assume[s.0 as usize].min.max(1);
        s
    }

    /// Intern a symbol assumed ≥ `min` (array dimension extents: ≥ 2).
    pub fn positive_min(name: &str, min: i64) -> Sym {
        let s = Sym::positive(name);
        let mut t = table().lock().unwrap();
        t.assume[s.0 as usize].min = t.assume[s.0 as usize].min.max(min);
        s
    }

    /// Intern a symbol assumed non-negative.
    pub fn nonneg(name: &str) -> Sym {
        let s = Sym::new(name);
        let mut t = table().lock().unwrap();
        t.assume[s.0 as usize].nonneg = true;
        s
    }

    /// A fresh symbol guaranteed not to collide with any existing name.
    pub fn fresh(prefix: &str) -> Sym {
        let mut t = table().lock().unwrap();
        let mut i = t.names.len();
        loop {
            let name = format!("{prefix}#{i}");
            if !t.by_name.contains_key(&name) {
                let s = t.alloc(&name);
                drop(t);
                record(s, true);
                return s;
            }
            i += 1;
        }
    }

    pub fn name(self) -> String {
        table().lock().unwrap().names[self.0 as usize].clone()
    }

    pub fn assumptions(self) -> Assumptions {
        table().lock().unwrap().assume[self.0 as usize]
    }

    pub fn expr(self) -> Expr {
        Expr::Sym(self)
    }
}

// ---------------------------------------------------------------------------
// Operator sugar
// ---------------------------------------------------------------------------

use crate::symbolic::simplify::simplify;

impl std::ops::Add for Expr {
    type Output = Expr;
    fn add(self, rhs: Expr) -> Expr {
        simplify(&Expr::Add(vec![self, rhs]))
    }
}

impl std::ops::Sub for Expr {
    type Output = Expr;
    fn sub(self, rhs: Expr) -> Expr {
        simplify(&Expr::Add(vec![
            self,
            Expr::Mul(vec![Expr::Int(-1), rhs]),
        ]))
    }
}

impl std::ops::Mul for Expr {
    type Output = Expr;
    fn mul(self, rhs: Expr) -> Expr {
        simplify(&Expr::Mul(vec![self, rhs]))
    }
}

impl std::ops::Neg for Expr {
    type Output = Expr;
    fn neg(self) -> Expr {
        simplify(&Expr::Mul(vec![Expr::Int(-1), self]))
    }
}

impl From<i64> for Expr {
    fn from(v: i64) -> Expr {
        Expr::Int(v)
    }
}

impl From<Sym> for Expr {
    fn from(s: Sym) -> Expr {
        Expr::Sym(s)
    }
}

/// Convenience constructors used by kernel builders and tests.
pub fn sym(name: &str) -> Expr {
    Expr::Sym(Sym::new(name))
}

pub fn psym(name: &str) -> Expr {
    Expr::Sym(Sym::positive(name))
}

pub fn int(v: i64) -> Expr {
    Expr::Int(v)
}

pub fn load(c: ContainerId, off: Expr) -> Expr {
    Expr::Load(c, Box::new(off))
}

pub fn min(a: Expr, b: Expr) -> Expr {
    simplify(&Expr::Min(Box::new(a), Box::new(b)))
}

pub fn max(a: Expr, b: Expr) -> Expr {
    simplify(&Expr::Max(Box::new(a), Box::new(b)))
}

pub fn floordiv(a: Expr, b: Expr) -> Expr {
    simplify(&Expr::FloorDiv(Box::new(a), Box::new(b)))
}

pub fn imod(a: Expr, b: Expr) -> Expr {
    simplify(&Expr::Mod(Box::new(a), Box::new(b)))
}

pub fn func(k: FuncKind, args: Vec<Expr>) -> Expr {
    simplify(&Expr::Func(k, args))
}

/// Compute-expression division: `a * recip(b)`.
pub fn fdiv(a: Expr, b: Expr) -> Expr {
    simplify(&Expr::Mul(vec![
        a,
        Expr::Func(FuncKind::Recip, vec![b]),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interning_is_stable() {
        let a = Sym::new("interning_a");
        let b = Sym::new("interning_a");
        assert_eq!(a, b);
        assert_eq!(a.name(), "interning_a");
    }

    #[test]
    fn positive_assumption_sticks() {
        let n = Sym::positive("interning_n");
        assert!(n.assumptions().positive);
        // Re-interning by plain name keeps the assumption.
        let n2 = Sym::new("interning_n");
        assert!(n2.assumptions().positive);
    }

    #[test]
    fn fresh_never_collides() {
        let a = Sym::fresh("tmp");
        let b = Sym::fresh("tmp");
        assert_ne!(a, b);
    }

    #[test]
    fn symbols_and_depends_on() {
        let i = Sym::new("expr_i");
        let j = Sym::new("expr_j");
        let e = Expr::Add(vec![Expr::Sym(i), Expr::Mul(vec![Expr::Int(3), Expr::Sym(j)])]);
        let syms = e.symbols();
        assert!(syms.contains(&i) && syms.contains(&j));
        assert!(e.depends_on(i));
        assert!(!e.depends_on(Sym::new("expr_k")));
    }

    #[test]
    fn real_bits_roundtrip() {
        let e = Expr::real(2.5);
        assert_eq!(e.real_value(), Some(2.5));
    }

    /// A recording scope captures this thread's interns (tagged new vs
    /// looked-up), release returns their slots, and the next intern
    /// reuses a freed slot — the table stays bounded under churn.
    ///
    /// The table is process-global and the test binary is multithreaded,
    /// so the count/reuse assertions can be perturbed by a concurrent
    /// test interning in the same instant; those run under a short
    /// retry, while the recording-semantics assertions (deterministic:
    /// scopes are thread-local) run once.
    #[test]
    fn scoped_release_reuses_slots() {
        let scope = SymScope::begin();
        let a = Sym::new("scoped_rel_a0");
        let again = Sym::new("scoped_rel_a0");
        let b = Sym::fresh("scoped_rel0");
        let rec = scope.finish();
        assert_eq!(again, a);
        // Deduplicated, and `a` keeps its new=true tag despite the
        // second (hit) touch.
        assert_eq!(rec.iter().filter(|(s, _)| *s == a).count(), 1);
        assert!(rec.iter().any(|(s, new)| *s == a && *new));
        assert!(rec.iter().any(|(s, new)| *s == b && *new));
        release_syms(&[a, b]);

        let attempt = |tag: usize| -> bool {
            let scope = SymScope::begin();
            let x = Sym::new(&format!("scoped_rel_x{tag}"));
            let y = Sym::new(&format!("scoped_rel_y{tag}"));
            scope.finish();
            let live = intern_table_size();
            release_syms(&[x, y]);
            if intern_table_size() != live - 2 {
                return false;
            }
            // Releasing an already-released symbol is a no-op.
            release_syms(&[y]);
            if intern_table_size() != live - 2 {
                return false;
            }
            // A fresh intern reuses one of the freed slots.
            let z = Sym::new(&format!("scoped_rel_z{tag}"));
            let reused = z == x || z == y;
            reused && z.name() == format!("scoped_rel_z{tag}") && intern_table_size() == live - 1
            // `z` stays live; its slot simply holds a new name.
        };
        assert!(
            (0..64).any(attempt),
            "release/reuse never observed cleanly despite 64 attempts"
        );
    }

    /// An abandoned scope (dropped, not finished) discards its recording
    /// without corrupting an enclosing scope.
    #[test]
    fn abandoned_scope_is_discarded() {
        let outer = SymScope::begin();
        {
            let inner = SymScope::begin();
            let _ = Sym::new("scoped_drop_x");
            drop(inner);
        }
        let rec = outer.finish();
        // The outer scope still saw the intern (it records on every
        // scope in the stack); the inner recording just vanished.
        assert!(rec.iter().any(|(s, _)| s.name() == "scoped_drop_x"));
    }
}
