//! Canonicalizing simplifier.
//!
//! Establishes the canonical-form invariants documented on [`Expr`]:
//! flattened, sorted n-ary sums/products with folded constants and collected
//! like terms. Canonical forms make symbolic equality a structural
//! comparison, which the dependence tests (paper §3.2/§3.3) rely on.

use std::collections::BTreeMap;

use super::expr::{Expr, FuncKind};

/// Fully simplify an expression to canonical form (bottom-up, fixpoint per
/// node — the rewrite rules here are confluent for the fragment we use).
pub fn simplify(e: &Expr) -> Expr {
    match e {
        Expr::Int(_) | Expr::Real(_) | Expr::Sym(_) => e.clone(),
        Expr::Add(xs) => simplify_add(xs),
        Expr::Mul(xs) => simplify_mul(xs),
        Expr::Pow(b, exp) => simplify_pow(&simplify(b), *exp),
        Expr::FloorDiv(a, b) => {
            let (a, b) = (simplify(a), simplify(b));
            match (&a, &b) {
                (Expr::Int(x), Expr::Int(y)) if *y != 0 => Expr::Int(x.div_euclid(*y)),
                (_, Expr::Int(1)) => a,
                _ if a.is_zero() => Expr::Int(0),
                _ => Expr::FloorDiv(Box::new(a), Box::new(b)),
            }
        }
        Expr::Mod(a, b) => {
            let (a, b) = (simplify(a), simplify(b));
            match (&a, &b) {
                (Expr::Int(x), Expr::Int(y)) if *y != 0 => Expr::Int(x.rem_euclid(*y)),
                (_, Expr::Int(1)) => Expr::Int(0),
                _ if a.is_zero() => Expr::Int(0),
                _ => Expr::Mod(Box::new(a), Box::new(b)),
            }
        }
        Expr::Min(a, b) => {
            let (a, b) = (simplify(a), simplify(b));
            match (&a, &b) {
                (Expr::Int(x), Expr::Int(y)) => Expr::Int(*x.min(y)),
                _ if a == b => a,
                _ => {
                    // Canonical operand order for commutativity.
                    let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
                    Expr::Min(Box::new(lo), Box::new(hi))
                }
            }
        }
        Expr::Max(a, b) => {
            let (a, b) = (simplify(a), simplify(b));
            match (&a, &b) {
                (Expr::Int(x), Expr::Int(y)) => Expr::Int(*x.max(y)),
                _ if a == b => a,
                _ => {
                    let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
                    Expr::Max(Box::new(lo), Box::new(hi))
                }
            }
        }
        Expr::Func(k, args) => {
            let args: Vec<Expr> = args.iter().map(simplify).collect();
            // Fold a few numerically-safe cases; otherwise keep uninterpreted.
            match (k, args.as_slice()) {
                (FuncKind::Log2, [Expr::Int(v)]) if *v > 0 && (*v as u64).is_power_of_two() => {
                    Expr::Int((*v as u64).trailing_zeros() as i64)
                }
                (FuncKind::Abs, [Expr::Int(v)]) => Expr::Int(v.abs()),
                _ => Expr::Func(*k, args),
            }
        }
        Expr::Load(c, off) => Expr::Load(*c, Box::new(simplify(off))),
    }
}

/// Key identifying a non-constant additive term: the term with its integer
/// coefficient stripped. `3*i*SJ` → key `i*SJ`, coeff 3.
fn split_coeff(term: &Expr) -> (i64, Expr) {
    match term {
        Expr::Int(v) => (*v, Expr::Int(1)),
        Expr::Mul(fs) => {
            let mut coeff = 1i64;
            let mut rest: Vec<Expr> = Vec::with_capacity(fs.len());
            for f in fs {
                if let Expr::Int(v) = f {
                    coeff = coeff.wrapping_mul(*v);
                } else {
                    rest.push(f.clone());
                }
            }
            let key = match rest.len() {
                0 => Expr::Int(1),
                1 => rest.pop().unwrap(),
                _ => Expr::Mul(rest),
            };
            (coeff, key)
        }
        other => (1, other.clone()),
    }
}

fn simplify_add(xs: &[Expr]) -> Expr {
    // Flatten + simplify children.
    let mut flat: Vec<Expr> = Vec::with_capacity(xs.len());
    for x in xs {
        match simplify(x) {
            Expr::Add(inner) => flat.extend(inner),
            other => flat.push(other),
        }
    }
    // Fold real constants separately from ints (mixed arithmetic promotes).
    let mut int_c: i64 = 0;
    let mut real_c: f64 = 0.0;
    let mut has_real = false;
    let mut terms: BTreeMap<Expr, i64> = BTreeMap::new();
    let mut real_terms: Vec<Expr> = Vec::new(); // terms with real coefficients kept verbatim
    for t in flat {
        match t {
            Expr::Int(v) => int_c = int_c.wrapping_add(v),
            Expr::Real(b) => {
                real_c += f64::from_bits(b);
                has_real = true;
            }
            other => {
                let (c, key) = split_coeff(&other);
                if key == Expr::Int(1) {
                    int_c = int_c.wrapping_add(c);
                } else if key_has_real(&key) {
                    real_terms.push(other);
                } else {
                    *terms.entry(key).or_insert(0) += c;
                }
            }
        }
    }
    let mut out: Vec<Expr> = Vec::new();
    if has_real {
        let total = real_c + int_c as f64;
        if total != 0.0 {
            out.push(Expr::real(total));
        }
    } else if int_c != 0 {
        out.push(Expr::Int(int_c));
    }
    for (key, c) in terms {
        if c == 0 {
            continue;
        }
        out.push(if c == 1 {
            key
        } else {
            simplify_mul(&[Expr::Int(c), key])
        });
    }
    out.extend(real_terms);
    match out.len() {
        0 => Expr::Int(0),
        1 => out.pop().unwrap(),
        _ => {
            out.sort();
            Expr::Add(out)
        }
    }
}

fn key_has_real(e: &Expr) -> bool {
    let mut found = false;
    e.visit(&mut |x| {
        if matches!(x, Expr::Real(_)) {
            found = true;
        }
    });
    found
}

fn simplify_mul(xs: &[Expr]) -> Expr {
    let mut flat: Vec<Expr> = Vec::with_capacity(xs.len());
    for x in xs {
        match simplify(x) {
            Expr::Mul(inner) => flat.extend(inner),
            other => flat.push(other),
        }
    }
    let mut int_c: i64 = 1;
    let mut real_c: f64 = 1.0;
    let mut has_real = false;
    // base -> accumulated power
    let mut powers: BTreeMap<Expr, u32> = BTreeMap::new();
    for f in flat {
        match f {
            Expr::Int(0) => return Expr::Int(0),
            Expr::Int(v) => int_c = int_c.wrapping_mul(v),
            Expr::Real(b) => {
                real_c *= f64::from_bits(b);
                has_real = true;
            }
            Expr::Pow(b, e) => *powers.entry((*b).clone()).or_insert(0) += e,
            other => *powers.entry(other).or_insert(0) += 1,
        }
    }
    if has_real && real_c == 0.0 {
        return Expr::real(0.0);
    }
    // Fully distribute products over sums so that `(i+1)*S` and `i*S + S`
    // share one canonical form — polynomial normal form requires expansion.
    let expandable = powers.keys().any(|b| matches!(b, Expr::Add(_)));
    if expandable {
        if let Some(expanded) = expand_product(int_c, real_c, has_real, &powers) {
            return expanded;
        }
    }
    let mut out: Vec<Expr> = Vec::new();
    if has_real {
        let total = real_c * int_c as f64;
        if total != 1.0 {
            out.push(Expr::real(total));
        }
    } else if int_c != 1 {
        out.push(Expr::Int(int_c));
    }
    for (base, p) in powers {
        match p {
            0 => {}
            1 => out.push(base),
            _ => out.push(Expr::Pow(Box::new(base), p)),
        }
    }
    match out.len() {
        0 => Expr::Int(1),
        1 => out.pop().unwrap(),
        _ => {
            out.sort();
            Expr::Mul(out)
        }
    }
}

/// Distribute a product whose factors include sums. `powers` maps canonical
/// bases to exponents. Returns `None` if expansion would blow up (> 4096
/// terms or a sum raised to a power > 4) — the caller then keeps the
/// unexpanded form.
fn expand_product(
    int_c: i64,
    real_c: f64,
    has_real: bool,
    powers: &BTreeMap<Expr, u32>,
) -> Option<Expr> {
    // Each factor contributes a list of addends (non-sums contribute one).
    let mut factor_sums: Vec<Vec<Expr>> = Vec::new();
    for (base, p) in powers {
        match base {
            Expr::Add(ts) => {
                if *p > 4 {
                    return None;
                }
                for _ in 0..*p {
                    factor_sums.push(ts.clone());
                }
            }
            other => {
                let f = if *p == 1 {
                    other.clone()
                } else {
                    Expr::Pow(Box::new(other.clone()), *p)
                };
                factor_sums.push(vec![f]);
            }
        }
    }
    let head = if has_real {
        Expr::real(real_c * int_c as f64)
    } else {
        Expr::Int(int_c)
    };
    let mut acc: Vec<Expr> = vec![head];
    for addends in &factor_sums {
        let mut next: Vec<Expr> = Vec::with_capacity(acc.len() * addends.len());
        for a in &acc {
            for t in addends {
                // Terms of canonical sums are themselves Add-free, so this
                // recursion cannot re-enter expansion unboundedly.
                next.push(simplify_mul(&[a.clone(), t.clone()]));
            }
        }
        if next.len() > 4096 {
            return None;
        }
        acc = next;
    }
    Some(simplify_add(&acc))
}

fn simplify_pow(base: &Expr, exp: u32) -> Expr {
    match exp {
        0 => Expr::Int(1),
        1 => base.clone(),
        _ => match base {
            Expr::Int(v) => {
                if let Some(r) = v.checked_pow(exp) {
                    Expr::Int(r)
                } else {
                    Expr::Pow(Box::new(base.clone()), exp)
                }
            }
            Expr::Real(b) => Expr::real(f64::from_bits(*b).powi(exp as i32)),
            Expr::Pow(inner, e2) => Expr::Pow(inner.clone(), e2 * exp),
            // Expand small powers of sums for canonical polynomial form.
            Expr::Add(_) if exp <= 4 => simplify_mul(&vec![base.clone(); exp as usize]),
            _ => Expr::Pow(Box::new(base.clone()), exp),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::symbolic::expr::{int, psym, sym};

    #[test]
    fn constant_folding() {
        assert_eq!(int(2) + int(3), int(5));
        assert_eq!(int(2) * int(3), int(6));
        assert_eq!(int(7) - int(7), int(0));
    }

    #[test]
    fn like_terms_collect() {
        let i = sym("simp_i");
        let e = i.clone() + i.clone() + i.clone();
        assert_eq!(e, int(3) * i);
    }

    #[test]
    fn cancellation() {
        let i = sym("simp_i2");
        let e = (i.clone() + int(5)) - (i.clone() + int(5));
        assert_eq!(e, int(0));
    }

    #[test]
    fn distribution_canonicalizes() {
        let (a, b) = (sym("simp_a"), sym("simp_b"));
        let lhs = int(2) * (a.clone() + b.clone());
        let rhs = int(2) * a + int(2) * b;
        assert_eq!(lhs, rhs);
    }

    #[test]
    fn mul_zero_annihilates() {
        let x = sym("simp_x");
        assert_eq!(x * int(0), int(0));
    }

    #[test]
    fn pow_collection() {
        let x = sym("simp_px");
        let e = x.clone() * x.clone() * x.clone();
        assert_eq!(e, Expr::Pow(Box::new(x), 3));
    }

    #[test]
    fn commutative_canonical_order() {
        let (a, b) = (sym("simp_ca"), sym("simp_cb"));
        assert_eq!(a.clone() + b.clone(), b.clone() + a.clone());
        assert_eq!(a.clone() * b.clone(), b * a);
    }

    #[test]
    fn floordiv_mod_folding() {
        use crate::symbolic::expr::{floordiv, imod};
        assert_eq!(floordiv(int(7), int(2)), int(3));
        assert_eq!(imod(int(7), int(2)), int(1));
        assert_eq!(floordiv(int(-7), int(2)), int(-4)); // euclidean
        let x = sym("simp_fd");
        assert_eq!(floordiv(x.clone(), int(1)), x.clone());
        assert_eq!(imod(x, int(1)), int(0));
    }

    #[test]
    fn min_max_folding() {
        use crate::symbolic::expr::{max, min};
        assert_eq!(min(int(3), int(5)), int(3));
        assert_eq!(max(int(3), int(5)), int(5));
        let x = sym("simp_mm");
        assert_eq!(min(x.clone(), x.clone()), x.clone());
        // commutative canonicalization
        let n = psym("simp_mmn");
        assert_eq!(min(x.clone(), n.clone()), min(n, x));
    }

    #[test]
    fn log2_power_of_two_folds() {
        use crate::symbolic::expr::func;
        assert_eq!(func(FuncKind::Log2, vec![int(8)]), int(3));
        // non-power-of-two stays symbolic
        let e = func(FuncKind::Log2, vec![int(6)]);
        assert!(matches!(e, Expr::Func(FuncKind::Log2, _)));
    }

    #[test]
    fn real_arithmetic() {
        let e = Expr::real(1.5) + Expr::real(2.5);
        assert_eq!(e.real_value(), Some(4.0));
        let m = Expr::real(2.0) * int(3);
        assert_eq!(m.real_value(), Some(6.0));
    }

    #[test]
    fn laplace_offset_equivalence() {
        // (i+1)*isI + j*isJ - (i*isI + j*isJ) == isI  — the Fig. 1 pattern.
        let (i, j) = (sym("simp_li"), sym("simp_lj"));
        let (is_i, is_j) = (psym("simp_isI"), psym("simp_isJ"));
        let f1 = (i.clone() + int(1)) * is_i.clone() + j.clone() * is_j.clone();
        let f0 = i * is_i.clone() + j * is_j;
        assert_eq!(f1 - f0, is_i);
    }
}
