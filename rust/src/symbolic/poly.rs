//! Canonical multivariate polynomials over integer coefficients.
//!
//! The δ-dependence test at the heart of SILO (paper §3.2.2/§3.3.1) solves
//! `f(L) − g(L ± δ·stride) = 0` for δ. For the expression fragment HPC index
//! arithmetic lives in — sums/products of loop variables, array strides and
//! constants — this is polynomial algebra. Non-polynomial subexpressions
//! (`log2(i)`, `floordiv`, `mod`, `min/max`, loads) become *uninterpreted
//! atoms*: equal canonical arguments ⇒ equal atoms. That preserves the
//! injectivity reasoning of the paper and degrades to its conservative
//! over-approximation everywhere else.

use std::collections::BTreeMap;

use super::expr::{Expr, Sym};
use super::simplify::simplify;

/// An indivisible multiplicand: either a symbol or an opaque subexpression.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Atom {
    Sym(Sym),
    /// Canonicalized non-polynomial subexpression (FloorDiv, Mod, Min, Max,
    /// Func, Load) treated as an opaque variable.
    Opaque(Expr),
}

impl Atom {
    pub fn to_expr(&self) -> Expr {
        match self {
            Atom::Sym(s) => Expr::Sym(*s),
            Atom::Opaque(e) => e.clone(),
        }
    }

    /// Does this atom (transitively) mention symbol `s`?
    pub fn depends_on(&self, s: Sym) -> bool {
        match self {
            Atom::Sym(x) => *x == s,
            Atom::Opaque(e) => e.depends_on(s),
        }
    }
}

/// A monomial: sorted `(atom, power)` pairs, powers ≥ 1. Empty = constant 1.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Monomial(pub Vec<(Atom, u32)>);

impl Monomial {
    pub fn one() -> Monomial {
        Monomial(Vec::new())
    }

    pub fn var(a: Atom) -> Monomial {
        Monomial(vec![(a, 1)])
    }

    pub fn degree(&self) -> u32 {
        self.0.iter().map(|(_, p)| p).sum()
    }

    pub fn degree_in(&self, a: &Atom) -> u32 {
        self.0
            .iter()
            .find(|(x, _)| x == a)
            .map(|(_, p)| *p)
            .unwrap_or(0)
    }

    pub fn mul(&self, other: &Monomial) -> Monomial {
        let mut map: BTreeMap<Atom, u32> = BTreeMap::new();
        for (a, p) in self.0.iter().chain(other.0.iter()) {
            *map.entry(a.clone()).or_insert(0) += p;
        }
        Monomial(map.into_iter().collect())
    }

    /// self / other, if other's atoms all divide self.
    pub fn div(&self, other: &Monomial) -> Option<Monomial> {
        let mut map: BTreeMap<Atom, u32> = self.0.iter().cloned().collect();
        for (a, p) in &other.0 {
            let have = map.get_mut(a)?;
            if *have < *p {
                return None;
            }
            *have -= p;
            if *have == 0 {
                map.remove(a);
            }
        }
        Some(Monomial(map.into_iter().collect()))
    }

    /// Strip all powers of atom `a`, returning (remaining monomial, power).
    pub fn without(&self, a: &Atom) -> (Monomial, u32) {
        let mut p = 0;
        let rest: Vec<(Atom, u32)> = self
            .0
            .iter()
            .filter(|(x, q)| {
                if x == a {
                    p = *q;
                    false
                } else {
                    true
                }
            })
            .cloned()
            .collect();
        (Monomial(rest), p)
    }

    pub fn to_expr(&self) -> Expr {
        let factors: Vec<Expr> = self
            .0
            .iter()
            .map(|(a, p)| {
                if *p == 1 {
                    a.to_expr()
                } else {
                    Expr::Pow(Box::new(a.to_expr()), *p)
                }
            })
            .collect();
        match factors.len() {
            0 => Expr::Int(1),
            1 => factors.into_iter().next().unwrap(),
            _ => simplify(&Expr::Mul(factors)),
        }
    }
}

/// Multivariate polynomial: monomial → nonzero integer coefficient.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Poly(pub BTreeMap<Monomial, i64>);

impl Poly {
    pub fn zero() -> Poly {
        Poly(BTreeMap::new())
    }

    pub fn constant(c: i64) -> Poly {
        let mut p = Poly::zero();
        if c != 0 {
            p.0.insert(Monomial::one(), c);
        }
        p
    }

    pub fn var(a: Atom) -> Poly {
        let mut p = Poly::zero();
        p.0.insert(Monomial::var(a), 1);
        p
    }

    pub fn is_zero(&self) -> bool {
        self.0.is_empty()
    }

    pub fn as_constant(&self) -> Option<i64> {
        if self.0.is_empty() {
            return Some(0);
        }
        if self.0.len() == 1 {
            if let Some(c) = self.0.get(&Monomial::one()) {
                return Some(*c);
            }
        }
        None
    }

    pub fn add(&self, other: &Poly) -> Poly {
        let mut out = self.0.clone();
        for (m, c) in &other.0 {
            let e = out.entry(m.clone()).or_insert(0);
            *e += c;
            if *e == 0 {
                out.remove(m);
            }
        }
        Poly(out)
    }

    pub fn neg(&self) -> Poly {
        Poly(self.0.iter().map(|(m, c)| (m.clone(), -c)).collect())
    }

    pub fn sub(&self, other: &Poly) -> Poly {
        self.add(&other.neg())
    }

    pub fn mul(&self, other: &Poly) -> Poly {
        let mut out: BTreeMap<Monomial, i64> = BTreeMap::new();
        for (m1, c1) in &self.0 {
            for (m2, c2) in &other.0 {
                let m = m1.mul(m2);
                let e = out.entry(m.clone()).or_insert(0);
                *e += c1 * c2;
                if *e == 0 {
                    out.remove(&m);
                }
            }
        }
        Poly(out)
    }

    pub fn scale(&self, k: i64) -> Poly {
        if k == 0 {
            return Poly::zero();
        }
        Poly(self.0.iter().map(|(m, c)| (m.clone(), c * k)).collect())
    }

    pub fn pow(&self, e: u32) -> Poly {
        let mut acc = Poly::constant(1);
        for _ in 0..e {
            acc = acc.mul(self);
        }
        acc
    }

    /// Exact multivariate division: returns `q` with `self = q * d`, if one
    /// exists with integer coefficients. Long division by `d`'s leading
    /// monomial under graded-lex order (a genuine monomial order, so each
    /// step strictly decreases the remainder's leading monomial and the
    /// loop terminates).
    pub fn div_exact(&self, d: &Poly) -> Option<Poly> {
        if d.is_zero() {
            return None;
        }
        let lead = |p: &Poly| -> Option<(Monomial, i64)> {
            p.0.iter()
                .max_by(|(a, _), (b, _)| grlex_cmp(a, b))
                .map(|(m, c)| (m.clone(), *c))
        };
        let (dm, dc) = lead(d)?;
        let mut rem = self.clone();
        let mut q = Poly::zero();
        // Safety cap far above any realistic quotient size.
        for _ in 0..10_000 {
            if rem.is_zero() {
                return Some(q);
            }
            let (rm, rc) = lead(&rem)?;
            let mq = rm.div(&dm)?;
            if rc % dc != 0 {
                return None;
            }
            let qc = rc / dc;
            let mut t = Poly::zero();
            t.0.insert(mq, qc);
            q = q.add(&t);
            rem = rem.sub(&t.mul(d));
        }
        None
    }

    /// Collect by powers of atom `a`: power → coefficient polynomial
    /// (free of `a` at the top level; `a` may still hide inside opaque atoms).
    pub fn collect(&self, a: &Atom) -> BTreeMap<u32, Poly> {
        let mut out: BTreeMap<u32, Poly> = BTreeMap::new();
        for (m, c) in &self.0 {
            let (rest, p) = m.without(a);
            let entry = out.entry(p).or_insert_with(Poly::zero);
            let mut t = Poly::zero();
            t.0.insert(rest, *c);
            *entry = entry.add(&t);
        }
        out.retain(|_, p| !p.is_zero());
        out
    }

    /// Highest power of atom `a` at the top level.
    pub fn degree_in(&self, a: &Atom) -> u32 {
        self.0.keys().map(|m| m.degree_in(a)).max().unwrap_or(0)
    }

    /// Does any monomial (incl. inside opaque atoms) depend on symbol `s`?
    pub fn depends_on(&self, s: Sym) -> bool {
        self.0
            .keys()
            .any(|m| m.0.iter().any(|(a, _)| a.depends_on(s)))
    }

    pub fn to_expr(&self) -> Expr {
        let terms: Vec<Expr> = self
            .0
            .iter()
            .map(|(m, c)| {
                if m.0.is_empty() {
                    Expr::Int(*c)
                } else if *c == 1 {
                    m.to_expr()
                } else {
                    simplify(&Expr::Mul(vec![Expr::Int(*c), m.to_expr()]))
                }
            })
            .collect();
        match terms.len() {
            0 => Expr::Int(0),
            1 => terms.into_iter().next().unwrap(),
            _ => simplify(&Expr::Add(terms)),
        }
    }

    /// All atoms appearing at the top level.
    pub fn atoms(&self) -> Vec<Atom> {
        let mut out: Vec<Atom> = Vec::new();
        for m in self.0.keys() {
            for (a, _) in &m.0 {
                if !out.contains(a) {
                    out.push(a.clone());
                }
            }
        }
        out
    }
}

/// Graded-lexicographic monomial comparison: first by total degree, then
/// lexicographically over the (sorted) atom exponent vectors. Compatible
/// with monomial multiplication, as polynomial long division requires.
pub fn grlex_cmp(a: &Monomial, b: &Monomial) -> std::cmp::Ordering {
    use std::cmp::Ordering;
    match a.degree().cmp(&b.degree()) {
        Ordering::Equal => {}
        other => return other,
    }
    // Merge-walk both sorted atom lists; the first atom where exponents
    // differ decides (an atom missing on one side has exponent 0; smaller
    // atoms rank as "earlier variables").
    let mut ia = a.0.iter().peekable();
    let mut ib = b.0.iter().peekable();
    loop {
        match (ia.peek(), ib.peek()) {
            (None, None) => return Ordering::Equal,
            (Some((aa, ap)), Some((ba, bp))) => match aa.cmp(ba) {
                Ordering::Equal => {
                    match ap.cmp(bp) {
                        Ordering::Equal => {
                            ia.next();
                            ib.next();
                        }
                        other => return other,
                    }
                }
                // `a` has the earlier variable with a positive exponent.
                Ordering::Less => return Ordering::Greater,
                Ordering::Greater => return Ordering::Less,
            },
            (Some(_), None) => return Ordering::Greater,
            (None, Some(_)) => return Ordering::Less,
        }
    }
}

/// Convert a canonicalized expression to a polynomial. Returns `None` only
/// for `Real` constants (polynomials are integer-coefficient; index
/// expressions never contain reals).
pub fn to_poly(e: &Expr) -> Option<Poly> {
    let e = simplify(e);
    to_poly_inner(&e)
}

fn to_poly_inner(e: &Expr) -> Option<Poly> {
    match e {
        Expr::Int(v) => Some(Poly::constant(*v)),
        Expr::Real(_) => None,
        Expr::Sym(s) => Some(Poly::var(Atom::Sym(*s))),
        Expr::Add(xs) => {
            let mut acc = Poly::zero();
            for x in xs {
                acc = acc.add(&to_poly_inner(x)?);
            }
            Some(acc)
        }
        Expr::Mul(xs) => {
            let mut acc = Poly::constant(1);
            for x in xs {
                acc = acc.mul(&to_poly_inner(x)?);
            }
            Some(acc)
        }
        Expr::Pow(b, p) => Some(to_poly_inner(b)?.pow(*p)),
        // Opaque atoms — keyed by their canonical form.
        Expr::FloorDiv(..) | Expr::Mod(..) | Expr::Min(..) | Expr::Max(..) | Expr::Func(..)
        | Expr::Load(..) => Some(Poly::var(Atom::Opaque(e.clone()))),
    }
}

/// Symbolic equality via polynomial normal form (falls back to canonical
/// expression comparison when reals are involved).
pub fn sym_eq(a: &Expr, b: &Expr) -> bool {
    match (to_poly(a), to_poly(b)) {
        (Some(pa), Some(pb)) => pa.sub(&pb).is_zero(),
        _ => simplify(a) == simplify(b),
    }
}

/// `a - b` as a polynomial, when both convert.
pub fn poly_diff(a: &Expr, b: &Expr) -> Option<Poly> {
    Some(to_poly(a)?.sub(&to_poly(b)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::symbolic::expr::{int, psym, sym};

    #[test]
    fn roundtrip() {
        let (i, s) = (sym("poly_i"), psym("poly_s"));
        let e = i.clone() * s.clone() + int(3) * i.clone() + int(7);
        let p = to_poly(&e).unwrap();
        assert!(sym_eq(&p.to_expr(), &e));
    }

    #[test]
    fn exact_division_by_symbol() {
        let (i, s) = (sym("pd_i"), psym("pd_s"));
        // (2*i*s + 4*s) / s = 2*i + 4
        let num = to_poly(&(int(2) * i.clone() * s.clone() + int(4) * s.clone())).unwrap();
        let den = to_poly(&s).unwrap();
        let q = num.div_exact(&den).unwrap();
        assert!(sym_eq(&q.to_expr(), &(int(2) * i + int(4))));
    }

    #[test]
    fn division_fails_when_inexact() {
        let (i, s) = (sym("pdf_i"), psym("pdf_s"));
        let num = to_poly(&(i.clone() * s.clone() + int(1))).unwrap();
        let den = to_poly(&s).unwrap();
        assert!(num.div_exact(&den).is_none());
        // coefficient divisibility
        let num2 = to_poly(&(int(3) * i)).unwrap();
        let den2 = to_poly(&int(2)).unwrap();
        assert!(num2.div_exact(&den2).is_none());
    }

    #[test]
    fn division_multiterm_divisor() {
        let (a, b) = (sym("pdm_a"), sym("pdm_b"));
        // (a^2 - b^2) / (a + b) = a - b
        let num = to_poly(&(a.clone() * a.clone() - b.clone() * b.clone())).unwrap();
        let den = to_poly(&(a.clone() + b.clone())).unwrap();
        let q = num.div_exact(&den).unwrap();
        assert!(sym_eq(&q.to_expr(), &(a - b)));
    }

    #[test]
    fn collect_powers() {
        let (d, s) = (sym("pc_d"), psym("pc_s"));
        // 3*d^2 + s*d + 5
        let e = int(3) * d.clone() * d.clone() + s.clone() * d.clone() + int(5);
        let p = to_poly(&e).unwrap();
        let by = p.collect(&Atom::Sym(match d {
            Expr::Sym(x) => x,
            _ => unreachable!(),
        }));
        assert_eq!(by.len(), 3);
        assert_eq!(by[&0].as_constant(), Some(5));
        assert!(sym_eq(&by[&1].to_expr(), &s));
        assert_eq!(by[&2].as_constant(), Some(3));
    }

    #[test]
    fn opaque_atoms_equal_iff_args_equal() {
        use crate::symbolic::expr::{func, FuncKind};
        let i = sym("po_i");
        let a = func(FuncKind::Log2, vec![i.clone()]);
        let b = func(FuncKind::Log2, vec![i.clone() + int(0)]);
        assert!(sym_eq(&a, &b));
        let c = func(FuncKind::Log2, vec![i + int(1)]);
        assert!(!sym_eq(&a, &c));
    }

    #[test]
    fn sym_eq_detects_laplace_stride_identity() {
        let (i, j) = (sym("pl_i"), sym("pl_j"));
        let (si, sj) = (psym("pl_si"), psym("pl_sj"));
        let f = i.clone() * si.clone() + j.clone() * sj.clone();
        let g = j * sj + i * si;
        assert!(sym_eq(&f, &g));
    }
}
