//! The symbolic-algebra engine underpinning SILO's inductive loop analysis.
//!
//! This is the in-crate replacement for the paper's use of SymPy (§5): a
//! small computer-algebra system covering exactly the fragment the
//! analyses need — canonicalized expressions, multivariate polynomials with
//! exact division, substitution/shifting, sign queries under assumptions,
//! and the δ-equation solver of §3.2/§3.3.

pub mod assume;
pub mod eval;
pub mod expr;
pub mod fmt;
pub mod poly;
pub mod simplify;
pub mod solve;
pub mod subs;

pub use assume::{is_nonneg, is_positive, is_zero, Truth};
pub use expr::{
    fdiv, floordiv, func, imod, int, intern_table_size, load, max, min, psym, release_syms,
    sym, Assumptions, ContainerId, Expr, FuncKind, Sym, SymScope,
};
pub use poly::{poly_diff, sym_eq, to_poly, Atom, Monomial, Poly};
pub use simplify::simplify;
pub use solve::{solve_delta, solve_linear, DeltaSolution, ShiftDir};
pub use subs::{shift, subs, subs_many};
