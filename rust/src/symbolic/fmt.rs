//! Human-readable rendering of expressions (for `pretty`, reports, CLI).

use std::fmt::Write;

use super::expr::Expr;

/// Render an expression with conventional infix syntax.
pub fn render(e: &Expr) -> String {
    let mut s = String::new();
    write_expr(&mut s, e, 0);
    s
}

// Precedence levels: 0 add, 1 mul, 2 unary/pow/atom.
fn write_expr(out: &mut String, e: &Expr, parent_prec: u8) {
    match e {
        Expr::Int(v) => {
            let _ = write!(out, "{v}");
        }
        Expr::Real(b) => {
            let v = f64::from_bits(*b);
            // Integral reals keep a `.0` suffix so the SILO-Text parser
            // reads them back as reals, not integers.
            if v.is_finite() && v == v.trunc() {
                let _ = write!(out, "{v:.1}");
            } else {
                let _ = write!(out, "{v}");
            }
        }
        Expr::Sym(s) => {
            let _ = write!(out, "{}", s.name());
        }
        Expr::Add(xs) => {
            let need = parent_prec > 0;
            if need {
                out.push('(');
            }
            for (k, x) in xs.iter().enumerate() {
                if k > 0 {
                    // Render `+ -c*y` as `- c*y`.
                    if let Some(stripped) = negative_part(x) {
                        out.push_str(" - ");
                        write_expr(out, &stripped, 1);
                        continue;
                    }
                    out.push_str(" + ");
                }
                write_expr(out, x, 1);
            }
            if need {
                out.push(')');
            }
        }
        Expr::Mul(xs) => {
            let need = parent_prec > 1;
            if need {
                out.push('(');
            }
            for (k, x) in xs.iter().enumerate() {
                if k > 0 {
                    out.push('*');
                }
                write_expr(out, x, 2);
            }
            if need {
                out.push(')');
            }
        }
        Expr::Pow(b, p) => {
            write_expr(out, b, 2);
            let _ = write!(out, "^{p}");
        }
        // Function-call syntax: unambiguous to reparse (SILO-Text), unlike
        // infix `floor(a / b)` / `(a mod b)` forms.
        Expr::FloorDiv(a, b) => binary_fn(out, "floordiv", a, b),
        Expr::Mod(a, b) => binary_fn(out, "mod", a, b),
        Expr::Min(a, b) => binary_fn(out, "min", a, b),
        Expr::Max(a, b) => binary_fn(out, "max", a, b),
        Expr::Func(k, args) => {
            let _ = write!(out, "{}(", k.name());
            for (i, a) in args.iter().enumerate() {
                if i > 0 {
                    out.push_str(", ");
                }
                write_expr(out, a, 0);
            }
            out.push(')');
        }
        Expr::Load(c, off) => {
            let _ = write!(out, "%{}[", c.0);
            write_expr(out, off, 0);
            out.push(']');
        }
    }
}

fn binary_fn(out: &mut String, name: &str, a: &Expr, b: &Expr) {
    let _ = write!(out, "{name}(");
    write_expr(out, a, 0);
    out.push_str(", ");
    write_expr(out, b, 0);
    out.push(')');
}

/// If `e` is `-1 * rest` or a negative constant, return its positive part.
fn negative_part(e: &Expr) -> Option<Expr> {
    match e {
        Expr::Int(v) if *v < 0 => Some(Expr::Int(-v)),
        Expr::Real(b) if f64::from_bits(*b) < 0.0 => Some(Expr::real(-f64::from_bits(*b))),
        Expr::Mul(fs) => {
            if let Some(Expr::Int(c)) = fs.first() {
                if *c < 0 {
                    let mut rest = fs[1..].to_vec();
                    if *c != -1 {
                        rest.insert(0, Expr::Int(-c));
                    }
                    return Some(if rest.len() == 1 {
                        rest.pop().unwrap()
                    } else {
                        Expr::Mul(rest)
                    });
                }
            }
            None
        }
        _ => None,
    }
}

impl std::fmt::Display for Expr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&render(self))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::symbolic::expr::{int, psym, sym};

    #[test]
    fn renders_sum_and_product() {
        let (i, s) = (sym("fmt_i"), psym("fmt_S"));
        let e = i.clone() * s.clone() + int(3);
        let r = render(&e);
        assert!(r.contains("fmt_i*fmt_S") || r.contains("fmt_S*fmt_i"), "{r}");
        assert!(r.contains("3"), "{r}");
    }

    #[test]
    fn renders_subtraction() {
        let i = sym("fmt_si");
        let e = i.clone() - int(1);
        assert_eq!(render(&e), "-1 + fmt_si"); // canonical order: const first
        // The important bit: it parses visually; just check it round-trips terms.
        assert!(render(&e).contains("fmt_si"));
    }

    #[test]
    fn renders_floordiv_and_mod_as_calls() {
        use crate::symbolic::expr::{floordiv, imod};
        let x = sym("fmt_fd");
        assert_eq!(render(&floordiv(x.clone(), int(2))), "floordiv(fmt_fd, 2)");
        assert_eq!(render(&imod(x, int(3))), "mod(fmt_fd, 3)");
    }

    #[test]
    fn renders_pow_and_funcs() {
        use crate::symbolic::expr::{func, FuncKind};
        let x = sym("fmt_x");
        let e = x.clone() * x.clone();
        assert_eq!(render(&e), "fmt_x^2");
        let l = func(FuncKind::Log2, vec![x]);
        assert_eq!(render(&l), "log2(fmt_x)");
    }
}
