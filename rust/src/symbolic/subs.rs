//! Substitution of symbols by expressions (with re-simplification).

use super::expr::{Expr, Sym};
use super::simplify::simplify;

/// Substitute `target → replacement` everywhere in `e`, then canonicalize.
pub fn subs(e: &Expr, target: Sym, replacement: &Expr) -> Expr {
    let mapped = e.map(&|x| match x {
        Expr::Sym(s) if *s == target => replacement.clone(),
        other => other.clone(),
    });
    simplify(&mapped)
}

/// Simultaneous substitution of several symbols.
pub fn subs_many(e: &Expr, pairs: &[(Sym, Expr)]) -> Expr {
    let mapped = e.map(&|x| match x {
        Expr::Sym(s) => pairs
            .iter()
            .find(|(t, _)| t == s)
            .map(|(_, r)| r.clone())
            .unwrap_or_else(|| x.clone()),
        other => other.clone(),
    });
    simplify(&mapped)
}

/// Shift a symbol by an expression: `e[s → s + delta]`. This is the core
/// "inductive step" operation: the paper's dependence tests compare an
/// access at iteration `L_var` against one at `L_var ± δ·L_stride`.
pub fn shift(e: &Expr, s: Sym, delta: &Expr) -> Expr {
    subs(e, s, &(Expr::Sym(s) + delta.clone()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::symbolic::expr::{int, psym, sym};

    #[test]
    fn basic_subs() {
        let i = Sym::new("subs_i");
        let e = Expr::Sym(i) * int(3) + int(1);
        assert_eq!(subs(&e, i, &int(4)), int(13));
    }

    #[test]
    fn shift_by_stride() {
        let i = Sym::new("subs_si");
        let s = psym("subs_stride");
        let e = Expr::Sym(i) * s.clone();
        // f(i + stride_sym) = i*s + stride_sym*s
        let shifted = shift(&e, i, &sym("subs_d"));
        let expect = Expr::Sym(i) * s.clone() + sym("subs_d") * s;
        assert_eq!(shifted, expect);
    }

    #[test]
    fn subs_inside_opaque() {
        use crate::symbolic::expr::{func, FuncKind};
        let i = Sym::new("subs_oi");
        let e = func(FuncKind::Log2, vec![Expr::Sym(i)]);
        assert_eq!(subs(&e, i, &int(8)), int(3));
    }

    #[test]
    fn simultaneous() {
        let a = Sym::new("subs_ma");
        let b = Sym::new("subs_mb");
        let e = Expr::Sym(a) + Expr::Sym(b);
        // swap a and b simultaneously — must not cascade
        let r = subs_many(&e, &[(a, Expr::Sym(b)), (b, Expr::Sym(a))]);
        assert_eq!(r, e);
    }
}
