//! Equation solving for the inductive dependence tests.
//!
//! The paper's central machinery (§3.2.2, §3.3.1): given access offsets
//! `f`, `g` and a loop `(var, stride)`, decide whether
//! `∃ δ > 0 : f(var) = g(var ± δ·stride)` and produce δ.
//!
//! We form `f − g[var → var ± δ·stride]` as a polynomial in a fresh δ
//! symbol and solve:
//! * degree 0, nonzero ⇒ no solution (accesses never collide across
//!   iterations);
//! * degree 0, zero ⇒ same address every iteration (`δ = 0`,
//!   loop-independent or all-iterations conflict — callers distinguish);
//! * degree 1 ⇒ δ = −b/a by exact polynomial division;
//! * degree 2 with constant coefficients ⇒ integer root search;
//! * anything else ⇒ `Unsolvable` (callers over-approximate, as the paper
//!   prescribes).

use super::assume::{is_positive, is_zero, Truth};
use super::expr::{Expr, Sym};
use super::poly::{to_poly, Atom, Poly};
use super::subs::subs;

/// Result of solving `f(var) = g(var + dir·δ·stride)` for δ.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DeltaSolution {
    /// No δ exists: the two accesses never alias across iterations.
    NoSolution,
    /// The accesses alias at every iteration distance (f ≡ g at δ = 0 and
    /// the shifted difference vanished identically).
    AlwaysEqual,
    /// A unique symbolic δ. `positive` reports whether δ > 0 is provable
    /// under the symbol assumptions.
    Unique { delta: Expr, positive: Truth },
    /// The equation is outside the solvable fragment; callers must
    /// over-approximate conservatively.
    Unsolvable,
}

/// Direction of the iteration shift in the dependence test.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShiftDir {
    /// `g(var + δ·stride)` — a *later* iteration (WAR / input-dependency
    /// test, paper §3.2.2).
    Later,
    /// `g(var − δ·stride)` — an *earlier* iteration (RAW / synchronization
    /// test, paper §3.3.1).
    Earlier,
}

/// Solve `f(var) = g(var ± δ·stride) ` for δ.
pub fn solve_delta(f: &Expr, g: &Expr, var: Sym, stride: &Expr, dir: ShiftDir) -> DeltaSolution {
    let delta = Sym::fresh("δ");
    let sign = match dir {
        ShiftDir::Later => Expr::Int(1),
        ShiftDir::Earlier => Expr::Int(-1),
    };
    let shift_amount = sign * Expr::Sym(delta) * stride.clone();
    let g_shifted = subs(g, var, &(Expr::Sym(var) + shift_amount));
    let diff = f.clone() - g_shifted;

    let Some(p) = to_poly(&diff) else {
        return DeltaSolution::Unsolvable;
    };
    solve_poly_for(&p, delta)
}

/// Solve polynomial equation `p = 0` for symbol `x`.
pub fn solve_poly_for(p: &Poly, x: Sym) -> DeltaSolution {
    let ax = Atom::Sym(x);
    // If x hides inside an opaque atom we cannot solve.
    for a in p.atoms() {
        if a != ax && a.depends_on(x) {
            return DeltaSolution::Unsolvable;
        }
    }
    let by_power = p.collect(&ax);
    let degree = by_power.keys().max().copied().unwrap_or(0);
    match degree {
        0 => {
            let c = by_power.get(&0).cloned().unwrap_or_else(Poly::zero);
            if c.is_zero() {
                DeltaSolution::AlwaysEqual
            } else if is_zero(&c.to_expr()) == Truth::Unknown {
                // Symbolic constant that *could* be zero ⇒ can't rule out a
                // collision; treat as unsolvable (conservative).
                DeltaSolution::Unsolvable
            } else {
                DeltaSolution::NoSolution
            }
        }
        1 => {
            let a = by_power.get(&1).cloned().unwrap_or_else(Poly::zero);
            let b = by_power.get(&0).cloned().unwrap_or_else(Poly::zero);
            if a.is_zero() {
                return DeltaSolution::Unsolvable;
            }
            // δ = -b / a  (must divide exactly over the polynomial ring —
            // otherwise there is no *uniform symbolic* integer solution).
            if b.is_zero() {
                return DeltaSolution::Unique {
                    delta: Expr::Int(0),
                    positive: Truth::No,
                };
            }
            match b.neg().div_exact(&a) {
                Some(q) => {
                    let delta = q.to_expr();
                    let positive = is_positive(&delta);
                    DeltaSolution::Unique { delta, positive }
                }
                None => {
                    // If a is a nonzero integer constant and b is constant,
                    // there is genuinely no integer solution.
                    if a.as_constant().is_some() && b.as_constant().is_some() {
                        DeltaSolution::NoSolution
                    } else if let Some(bc) = b.as_constant() {
                        // δ = -b/a with symbolic a: an integer solution
                        // needs |a| ≤ |b|; a provable lower bound on a
                        // beyond |b| rules it out (linearized multidim
                        // accesses: δ·M = c with extent M ≥ 2 > |c|).
                        let lb = super::assume::lower_bound(&a.to_expr())
                            .or_else(|| super::assume::lower_bound(&a.neg().to_expr()));
                        match lb {
                            Some(lb) if lb > bc.abs() => DeltaSolution::NoSolution,
                            _ => DeltaSolution::Unsolvable,
                        }
                    } else {
                        DeltaSolution::Unsolvable
                    }
                }
            }
        }
        2 => {
            // Constant-coefficient quadratics only: search integer roots.
            let c2 = by_power.get(&2).and_then(|p| p.as_constant());
            let c1 = by_power.get(&1).and_then(|p| p.as_constant()).or(Some(0));
            let c0 = by_power.get(&0).and_then(|p| p.as_constant()).or(Some(0));
            match (c2, c1, c0) {
                (Some(a2), Some(a1), Some(a0)) if a2 != 0 => {
                    let disc = a1 * a1 - 4 * a2 * a0;
                    if disc < 0 {
                        return DeltaSolution::NoSolution;
                    }
                    let root = (disc as f64).sqrt() as i64;
                    for r in [root - 1, root, root + 1] {
                        if r >= 0 && r * r == disc {
                            let num = -a1 + r;
                            if num % (2 * a2) == 0 {
                                let d = num / (2 * a2);
                                return DeltaSolution::Unique {
                                    delta: Expr::Int(d),
                                    positive: if d > 0 { Truth::Yes } else { Truth::No },
                                };
                            }
                            let num2 = -a1 - r;
                            if num2 % (2 * a2) == 0 {
                                let d = num2 / (2 * a2);
                                return DeltaSolution::Unique {
                                    delta: Expr::Int(d),
                                    positive: if d > 0 { Truth::Yes } else { Truth::No },
                                };
                            }
                        }
                    }
                    DeltaSolution::NoSolution
                }
                _ => DeltaSolution::Unsolvable,
            }
        }
        _ => DeltaSolution::Unsolvable,
    }
}

/// Solve the linear equation `e = 0` for symbol `x`, returning the unique
/// symbolic solution if one exists (used by pointer-increment Δ checks and
/// tests).
pub fn solve_linear(e: &Expr, x: Sym) -> Option<Expr> {
    let p = to_poly(e)?;
    match solve_poly_for(&p, x) {
        DeltaSolution::Unique { delta, .. } => Some(delta),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::symbolic::expr::{int, psym, sym, Expr};

    fn var(name: &str) -> (Sym, Expr) {
        let s = Sym::new(name);
        (s, Expr::Sym(s))
    }

    #[test]
    fn unit_stride_raw() {
        // f = i-1 (read), g = i (write of previous iterations): solve
        // f(i) = g(i - δ·1) ⇒ i-1 = i-δ ⇒ δ = 1.
        let (i, ie) = var("slv_i");
        let f = ie.clone() - int(1);
        let g = ie.clone();
        match solve_delta(&f, &g, i, &int(1), ShiftDir::Earlier) {
            DeltaSolution::Unique { delta, positive } => {
                assert_eq!(delta, int(1));
                assert_eq!(positive, Truth::Yes);
            }
            other => panic!("expected unique, got {other:?}"),
        }
    }

    #[test]
    fn parametric_stride() {
        // Accesses i*SI: f(i) = g(i - δ·1) with g = (i)*SI, f = (i-2)*SI
        // ⇒ (i-2)SI = (i-δ)SI ⇒ δ = 2 — stride symbol divides out exactly.
        let (i, ie) = var("slv_pi");
        let si = psym("slv_SI");
        let f = (ie.clone() - int(2)) * si.clone();
        let g = ie.clone() * si.clone();
        match solve_delta(&f, &g, i, &int(1), ShiftDir::Earlier) {
            DeltaSolution::Unique { delta, positive } => {
                assert_eq!(delta, int(2));
                assert_eq!(positive, Truth::Yes);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn symbolic_loop_stride() {
        // Loop stride is a parameter S; write g = i, read f = i - S.
        // f(i) = g(i - δ·S) ⇒ i - S = i - δS ⇒ δ = 1.
        let (i, ie) = var("slv_si");
        let s = psym("slv_S");
        let f = ie.clone() - s.clone();
        let g = ie.clone();
        match solve_delta(&f, &g, i, &s, ShiftDir::Earlier) {
            DeltaSolution::Unique { delta, positive } => {
                assert_eq!(delta, int(1));
                assert_eq!(positive, Truth::Yes);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn no_dependency_between_distinct_offsets() {
        // f = 2i, g = 2i+1: 2i - (2(i-δ)+1) = 2δ - 1 = 0 has no integer δ.
        let (i, ie) = var("slv_ni");
        let f = int(2) * ie.clone();
        let g = int(2) * ie.clone() + int(1);
        assert_eq!(
            solve_delta(&f, &g, i, &int(1), ShiftDir::Earlier),
            DeltaSolution::NoSolution
        );
    }

    #[test]
    fn always_equal_detected() {
        // Same loop-invariant offset on both sides: n vs n.
        let (i, _ie) = var("slv_ai");
        let n = psym("slv_n");
        assert_eq!(
            solve_delta(&n, &n, i, &int(1), ShiftDir::Earlier),
            DeltaSolution::AlwaysEqual
        );
    }

    #[test]
    fn later_iteration_war() {
        // Input dependency (paper Fig. 4: C read at k+1, written at k):
        // f = i+1 (read), g = i (write): f(i) = g(i + δ) ⇒ δ = 1.
        let (i, ie) = var("slv_wi");
        let f = ie.clone() + int(1);
        let g = ie.clone();
        match solve_delta(&f, &g, i, &int(1), ShiftDir::Later) {
            DeltaSolution::Unique { delta, positive } => {
                assert_eq!(delta, int(1));
                assert_eq!(positive, Truth::Yes);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn descending_loop() {
        // stride = -1, read f = i+1, write g = i:
        // f(i) = g(i - δ·(-1)) = i + δ ⇒ δ = 1 (works for descending order,
        // as claimed in §3.2.2).
        let (i, ie) = var("slv_di");
        let f = ie.clone() + int(1);
        let g = ie.clone();
        match solve_delta(&f, &g, i, &int(-1), ShiftDir::Earlier) {
            DeltaSolution::Unique { delta, positive } => {
                assert_eq!(delta, int(1));
                assert_eq!(positive, Truth::Yes);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn nonlinear_is_conservative() {
        use crate::symbolic::expr::{func, FuncKind};
        // a[log2(i)] (read) vs a[i] (write): δ = i - log2(i) is formally a
        // linear solution whose positivity cannot be proven — the caller
        // must treat this conservatively. The key property: never
        // `NoSolution` (which would wrongly license parallelization).
        let (i, ie) = var("slv_li");
        let f = func(FuncKind::Log2, vec![ie.clone()]);
        let g = ie.clone();
        match solve_delta(&f, &g, i, &int(1), ShiftDir::Earlier) {
            DeltaSolution::NoSolution => panic!("unsound: claimed independence"),
            DeltaSolution::Unique { positive, .. } => assert_ne!(positive, Truth::Yes),
            _ => {}
        }
    }

    #[test]
    fn log2_self_dependence_no_solution_pattern() {
        use crate::symbolic::expr::{func, FuncKind};
        // Fig. 2 left: writes a[log2(i)] with stride i (i += i). Two
        // iterations write log2(i) and log2(2i) — distinct opaque atoms,
        // solver says Unsolvable (conservative), never a wrong "parallel".
        let (i, ie) = var("slv_l2i");
        let f = func(FuncKind::Log2, vec![ie.clone()]);
        match solve_delta(&f, &f, i, &ie, ShiftDir::Earlier) {
            DeltaSolution::Unsolvable => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn quadratic_constant_coeffs() {
        // δ² - 3δ + 2 = 0 ⇒ δ ∈ {1, 2}; solver returns one positive root.
        let d = Sym::fresh("slv_q");
        let de = Expr::Sym(d);
        let p = to_poly(&(de.clone() * de.clone() - int(3) * de + int(2))).unwrap();
        match solve_poly_for(&p, d) {
            DeltaSolution::Unique { delta, positive } => {
                assert!(delta == int(1) || delta == int(2));
                assert_eq!(positive, Truth::Yes);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn solve_linear_api() {
        let (x, xe) = var("slv_lin");
        let n = psym("slv_ln");
        // 2x - 4n = 0 ⇒ x = 2n
        let sol = solve_linear(&(int(2) * xe - int(4) * n.clone()), x).unwrap();
        assert_eq!(sol, int(2) * n);
    }
}
