//! Numeric evaluation of expressions (used by the analyses, the test
//! oracles, and non-hot-path interpretation; the VM compiles expressions to
//! bytecode instead — see [`crate::lowering`]).

use anyhow::{bail, Result};

use super::expr::{Expr, FuncKind, Sym};

/// Environment mapping symbols to integer values.
pub trait Env {
    fn get(&self, s: Sym) -> Option<i64>;
}

impl Env for std::collections::HashMap<Sym, i64> {
    fn get(&self, s: Sym) -> Option<i64> {
        std::collections::HashMap::get(self, &s).copied()
    }
}

impl Env for [(Sym, i64)] {
    fn get(&self, s: Sym) -> Option<i64> {
        self.iter().find(|(x, _)| *x == s).map(|(_, v)| *v)
    }
}

impl Env for Vec<(Sym, i64)> {
    fn get(&self, s: Sym) -> Option<i64> {
        Env::get(self.as_slice(), s)
    }
}

/// Evaluate an integer-valued (index) expression. Fails on loads, reals and
/// unbound symbols.
pub fn eval_int(e: &Expr, env: &dyn Env) -> Result<i64> {
    Ok(match e {
        Expr::Int(v) => *v,
        Expr::Real(_) => bail!("real constant in index expression"),
        Expr::Sym(s) => match env.get(*s) {
            Some(v) => v,
            None => bail!("unbound symbol {} in index expression", s.name()),
        },
        Expr::Add(xs) => {
            let mut acc = 0i64;
            for x in xs {
                acc = acc.wrapping_add(eval_int(x, env)?);
            }
            acc
        }
        Expr::Mul(xs) => {
            let mut acc = 1i64;
            for x in xs {
                acc = acc.wrapping_mul(eval_int(x, env)?);
            }
            acc
        }
        Expr::Pow(b, p) => eval_int(b, env)?.pow(*p),
        Expr::FloorDiv(a, b) => {
            let (a, b) = (eval_int(a, env)?, eval_int(b, env)?);
            if b == 0 {
                bail!("division by zero");
            }
            a.div_euclid(b)
        }
        Expr::Mod(a, b) => {
            let (a, b) = (eval_int(a, env)?, eval_int(b, env)?);
            if b == 0 {
                bail!("mod by zero");
            }
            a.rem_euclid(b)
        }
        Expr::Min(a, b) => eval_int(a, env)?.min(eval_int(b, env)?),
        Expr::Max(a, b) => eval_int(a, env)?.max(eval_int(b, env)?),
        Expr::Func(FuncKind::Log2, args) => {
            let v = eval_int(&args[0], env)?;
            if v <= 0 {
                bail!("log2 of non-positive value {v}");
            }
            63 - (v as u64).leading_zeros() as i64
        }
        Expr::Func(FuncKind::Abs, args) => eval_int(&args[0], env)?.abs(),
        Expr::Func(k, _) => bail!("function {} in index expression", k.name()),
        Expr::Load(..) => bail!("load in index expression"),
    })
}

/// Memory interface for compute-expression evaluation.
pub trait Memory {
    fn load(&self, c: super::expr::ContainerId, offset: i64) -> f64;
}

/// Evaluate a real-valued compute expression against symbol bindings and a
/// memory. Integer subexpressions promote to f64.
pub fn eval_f64(e: &Expr, env: &dyn Env, mem: &dyn Memory) -> Result<f64> {
    Ok(match e {
        Expr::Int(v) => *v as f64,
        Expr::Real(b) => f64::from_bits(*b),
        Expr::Sym(s) => match env.get(*s) {
            Some(v) => v as f64,
            None => bail!("unbound symbol {}", s.name()),
        },
        Expr::Add(xs) => {
            let mut acc = 0.0;
            for x in xs {
                acc += eval_f64(x, env, mem)?;
            }
            acc
        }
        Expr::Mul(xs) => {
            let mut acc = 1.0;
            for x in xs {
                acc *= eval_f64(x, env, mem)?;
            }
            acc
        }
        Expr::Pow(b, p) => eval_f64(b, env, mem)?.powi(*p as i32),
        Expr::FloorDiv(a, b) => {
            (eval_f64(a, env, mem)? / eval_f64(b, env, mem)?).floor()
        }
        Expr::Mod(a, b) => {
            let (a, b) = (eval_f64(a, env, mem)?, eval_f64(b, env, mem)?);
            a - b * (a / b).floor()
        }
        Expr::Min(a, b) => eval_f64(a, env, mem)?.min(eval_f64(b, env, mem)?),
        Expr::Max(a, b) => eval_f64(a, env, mem)?.max(eval_f64(b, env, mem)?),
        Expr::Func(k, args) => match k {
            FuncKind::Log2 => eval_f64(&args[0], env, mem)?.log2(),
            FuncKind::Exp => eval_f64(&args[0], env, mem)?.exp(),
            FuncKind::Sqrt => eval_f64(&args[0], env, mem)?.sqrt(),
            FuncKind::Abs => eval_f64(&args[0], env, mem)?.abs(),
            FuncKind::Recip => 1.0 / eval_f64(&args[0], env, mem)?,
            FuncKind::Select => {
                if eval_f64(&args[0], env, mem)? > 0.0 {
                    eval_f64(&args[1], env, mem)?
                } else {
                    eval_f64(&args[2], env, mem)?
                }
            }
        },
        Expr::Load(c, off) => {
            let o = eval_int(off, env)?;
            mem.load(*c, o)
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::symbolic::expr::{int, load, ContainerId, Expr};

    struct ZeroMem;
    impl Memory for ZeroMem {
        fn load(&self, _c: ContainerId, offset: i64) -> f64 {
            offset as f64 * 10.0
        }
    }

    #[test]
    fn int_eval() {
        let i = Sym::new("ev_i");
        let env = vec![(i, 7i64)];
        let e = Expr::Sym(i) * int(3) + int(1);
        assert_eq!(eval_int(&e, &env).unwrap(), 22);
    }

    #[test]
    fn unbound_symbol_errors() {
        let e = Expr::Sym(Sym::new("ev_unbound"));
        let env: Vec<(Sym, i64)> = vec![];
        assert!(eval_int(&e, &env).is_err());
    }

    #[test]
    fn log2_eval() {
        use crate::symbolic::expr::func;
        let e = func(FuncKind::Log2, vec![int(1024)]);
        let env: Vec<(Sym, i64)> = vec![];
        assert_eq!(eval_int(&e, &env).unwrap(), 10);
    }

    #[test]
    fn f64_with_loads() {
        let i = Sym::new("ev_fi");
        let env = vec![(i, 3i64)];
        let c = ContainerId(0);
        // load(c, i+1) * 2.0 => (4*10) * 2
        let e = load(c, Expr::Sym(i) + int(1)) * Expr::real(2.0);
        assert_eq!(eval_f64(&e, &env, &ZeroMem).unwrap(), 80.0);
    }

    #[test]
    fn select_eval() {
        use crate::symbolic::expr::func;
        let env: Vec<(Sym, i64)> = vec![];
        let e = func(FuncKind::Select, vec![int(1), Expr::real(5.0), Expr::real(9.0)]);
        assert_eq!(eval_f64(&e, &env, &ZeroMem).unwrap(), 5.0);
        let e2 = func(FuncKind::Select, vec![int(0), Expr::real(5.0), Expr::real(9.0)]);
        assert_eq!(eval_f64(&e2, &env, &ZeroMem).unwrap(), 9.0);
    }
}
