//! Sign queries under symbol assumptions.
//!
//! The dependence tests need to decide "is δ > 0?" for symbolic δ (paper
//! §3.2.2: `∃ δ > 0 : f(L) = g(L + δ·stride)`). We answer with a sound,
//! incomplete three-valued query: `Yes` / `No` only when provable from the
//! atoms' assumptions, `Unknown` otherwise (callers treat `Unknown`
//! conservatively, exactly like the paper's over-approximation rule).

use super::expr::Expr;
use super::poly::{to_poly, Atom};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Truth {
    Yes,
    No,
    Unknown,
}

impl Truth {
    pub fn known_true(self) -> bool {
        self == Truth::Yes
    }
}

fn atom_sign(a: &Atom) -> (bool, bool) {
    // (provably_positive, provably_nonneg)
    match a {
        Atom::Sym(s) => {
            let asm = s.assumptions();
            (asm.positive, asm.nonneg || asm.positive)
        }
        Atom::Opaque(_) => (false, false),
    }
}

/// Is `e > 0` provable?
pub fn is_positive(e: &Expr) -> Truth {
    match classify(e) {
        Sign::Pos => Truth::Yes,
        Sign::Neg | Sign::Zero => Truth::No,
        Sign::NonNeg | Sign::NonPos | Sign::Unknown => Truth::Unknown,
    }
}

/// Is `e >= 0` provable?
pub fn is_nonneg(e: &Expr) -> Truth {
    match classify(e) {
        Sign::Pos | Sign::Zero | Sign::NonNeg => Truth::Yes,
        Sign::Neg => Truth::No,
        Sign::NonPos | Sign::Unknown => Truth::Unknown,
    }
}

/// Is `e == 0` provable / refutable?
pub fn is_zero(e: &Expr) -> Truth {
    match to_poly(e) {
        Some(p) => {
            if p.is_zero() {
                Truth::Yes
            } else {
                match classify(e) {
                    Sign::Pos | Sign::Neg => Truth::No,
                    _ => Truth::Unknown,
                }
            }
        }
        None => Truth::Unknown,
    }
}

/// Provable lower bound of an expression under the symbol assumptions, or
/// `None` when no bound is derivable. Sound: the true value is always
/// ≥ the returned bound.
pub fn lower_bound(e: &Expr) -> Option<i64> {
    let p = to_poly(e)?;
    poly_lower_bound(&p)
}

fn poly_lower_bound(p: &crate::symbolic::poly::Poly) -> Option<i64> {
    let mut total: i64 = 0;
    for (m, c) in &p.0 {
        if *c <= 0 {
            // A negative *constant* term only shifts the bound; negative
            // variable terms are unbounded below under our assumptions.
            if m.0.is_empty() {
                total = total.checked_add(*c)?;
                continue;
            }
            return None;
        }
        let mut mono_min: i64 = 1;
        for (a, pw) in &m.0 {
            let amin = match a {
                Atom::Sym(s) => {
                    let asm = s.assumptions();
                    if asm.min >= 1 {
                        asm.min
                    } else {
                        return None;
                    }
                }
                Atom::Opaque(_) => return None,
            };
            mono_min = mono_min.checked_mul(amin.checked_pow(*pw)?)?;
        }
        total = total.checked_add(c.checked_mul(mono_min)?)?;
    }
    Some(total)
}

/// Lower-bound after factoring out the GCD monomial: `I·J − I = I·(J−1)`
/// is nonneg when `I > 0` and `J ≥ 1` even though the raw polynomial has a
/// negative term. Returns a bound on the *quotient* sign scaled by the
/// (positive) factor's minimum — sufficient for sign queries.
fn factored_lower_bound(p: &crate::symbolic::poly::Poly) -> Option<i64> {
    use crate::symbolic::poly::Monomial;
    if p.0.is_empty() {
        return Some(0);
    }
    // GCD monomial across all terms.
    let mut it = p.0.keys();
    let first = it.next()?.clone();
    let mut gcd: Vec<(Atom, u32)> = first.0.clone();
    for m in it {
        gcd.retain(|(a, _)| m.0.iter().any(|(b, _)| b == a));
        for e in gcd.iter_mut() {
            let other = m.0.iter().find(|(b, _)| *b == e.0).map(|(_, pw)| *pw)?;
            e.1 = e.1.min(other);
        }
    }
    if gcd.is_empty() {
        return None;
    }
    // Factor must be provably positive with a known minimum.
    let mut factor_min: i64 = 1;
    for (a, pw) in &gcd {
        match a {
            Atom::Sym(s) if s.assumptions().min >= 1 => {
                factor_min = factor_min.checked_mul(s.assumptions().min.checked_pow(*pw)?)?;
            }
            _ => return None,
        }
    }
    // Quotient = divide each monomial by the gcd.
    let mut q = crate::symbolic::poly::Poly::zero();
    for (m, c) in &p.0 {
        let div = m.div(&Monomial(gcd.clone()))?;
        q.0.insert(div, *c);
    }
    let qlb = poly_lower_bound(&q)?;
    if qlb >= 0 {
        Some(factor_min.checked_mul(qlb)?)
    } else {
        None
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Sign {
    Pos,
    Neg,
    Zero,
    NonNeg,
    NonPos,
    Unknown,
}

fn classify(e: &Expr) -> Sign {
    match classify_basic(e) {
        Sign::Unknown => {
            // Factored lower-bound refinement: I·J − I ≥ I·(2−1) ≥ 1.
            if let Some(p) = to_poly(e) {
                if let Some(lb) = poly_lower_bound(&p).or_else(|| factored_lower_bound(&p)) {
                    if lb > 0 {
                        return Sign::Pos;
                    }
                    if lb == 0 {
                        return Sign::NonNeg;
                    }
                }
            }
            Sign::Unknown
        }
        s => s,
    }
}

fn classify_basic(e: &Expr) -> Sign {
    let Some(p) = to_poly(e) else {
        // Real constant
        return match e.real_value() {
            Some(v) if v > 0.0 => Sign::Pos,
            Some(v) if v < 0.0 => Sign::Neg,
            Some(_) => Sign::Zero,
            None => Sign::Unknown,
        };
    };
    if p.is_zero() {
        return Sign::Zero;
    }
    // Each monomial: sign known if all atoms nonneg/positive.
    let mut all_pos = true; // every term provably > 0
    let mut all_nonneg = true;
    let mut all_neg = true;
    let mut all_nonpos = true;
    for (m, c) in &p.0 {
        let mut mono_pos = true; // monomial (without coeff) provably > 0
        let mut mono_nonneg = true;
        for (a, _) in &m.0 {
            let (pos, nonneg) = atom_sign(a);
            mono_pos &= pos;
            mono_nonneg &= nonneg;
        }
        let term_pos = *c > 0 && mono_pos;
        let term_nonneg = (*c > 0 && mono_nonneg) || (*c >= 0 && mono_nonneg);
        let term_neg = *c < 0 && mono_pos;
        let term_nonpos = *c < 0 && mono_nonneg;
        all_pos &= term_pos;
        all_nonneg &= term_nonneg;
        all_neg &= term_neg;
        all_nonpos &= term_nonpos;
    }
    if all_pos {
        Sign::Pos
    } else if all_neg {
        Sign::Neg
    } else if all_nonneg {
        Sign::NonNeg
    } else if all_nonpos {
        Sign::NonPos
    } else {
        Sign::Unknown
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::symbolic::expr::{int, psym, sym};

    #[test]
    fn constants() {
        assert_eq!(is_positive(&int(3)), Truth::Yes);
        assert_eq!(is_positive(&int(0)), Truth::No);
        assert_eq!(is_positive(&int(-2)), Truth::No);
        assert_eq!(is_nonneg(&int(0)), Truth::Yes);
        assert_eq!(is_zero(&int(0)), Truth::Yes);
        assert_eq!(is_zero(&int(4)), Truth::No);
    }

    #[test]
    fn positive_symbols() {
        let n = psym("asm_n");
        assert_eq!(is_positive(&n), Truth::Yes);
        assert_eq!(is_positive(&(n.clone() * int(2))), Truth::Yes);
        assert_eq!(is_positive(&(n.clone() + int(1))), Truth::Yes);
        assert_eq!(is_positive(&-n), Truth::No);
    }

    #[test]
    fn unknown_symbols() {
        let x = sym("asm_x");
        assert_eq!(is_positive(&x), Truth::Unknown);
        assert_eq!(is_zero(&x), Truth::Unknown);
    }

    #[test]
    fn mixed_sums() {
        let n = psym("asm_mn");
        let x = sym("asm_mx");
        assert_eq!(is_positive(&(n.clone() + x.clone())), Truth::Unknown);
        assert_eq!(is_positive(&(n.clone() * n.clone() + n)), Truth::Yes);
        let _ = x;
    }

    #[test]
    fn product_of_positives() {
        let (a, b) = (psym("asm_pa"), psym("asm_pb"));
        assert_eq!(is_positive(&(a.clone() * b.clone())), Truth::Yes);
        assert_eq!(is_positive(&(a * b * int(-1))), Truth::No);
    }
}
