//! Native x86-64 code tier: a self-contained, std-only JIT that
//! compiles the lowered bytecode into machine code, so ptr-inc
//! schedules become real pointer arithmetic, prefetch hints become
//! `prefetcht0`, and bounds checks become branch-to-trap stubs — the
//! schedule wins the tuner models finally happen in silicon.
//!
//! The VM remains the semantic ground truth: the native tier is
//! differential-tested bitwise against it (see `rust/tests/native.rs`
//! and the extended fuzz in `rust/tests/vm_exec.rs`), and every
//! unsupported situation — non-x86-64 host, non-Linux mmap protocol,
//! a future op the emitter doesn't know — degrades to the VM, never to
//! an error. See DESIGN.md §Native tier for the ABI, the W^X buffer
//! lifecycle, and the fallback matrix.

/// Which execution backend to run a compiled kernel on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Tier {
    /// The bytecode interpreter (`exec::vm`) — always available.
    #[default]
    Vm,
    /// JIT-compiled machine code; silently falls back to [`Tier::Vm`]
    /// when unavailable for the host or program.
    Native,
    /// Inspector-executor tier (`exec::speculate`): statically
    /// unprovable sequential loops run chunk-parallel against
    /// privatized buffers with runtime conflict detection, falling back
    /// to sequential on misspeculation. Runs on the VM; degrades to
    /// [`Tier::Vm`] when the program has no speculation candidates.
    Speculative,
}

impl Tier {
    pub fn parse(s: &str) -> Result<Tier, String> {
        match s {
            "vm" => Ok(Tier::Vm),
            "native" => Ok(Tier::Native),
            "speculative" => Ok(Tier::Speculative),
            other => Err(format!(
                "unknown backend `{other}` (expected vm|native|speculative)"
            )),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Tier::Vm => "vm",
            Tier::Native => "native",
            Tier::Speculative => "speculative",
        }
    }
}

#[cfg(all(target_arch = "x86_64", target_os = "linux"))]
pub mod asm;
#[cfg(all(target_arch = "x86_64", target_os = "linux"))]
pub mod emit;
#[cfg(all(target_arch = "x86_64", target_os = "linux"))]
pub mod mem;
#[cfg(all(target_arch = "x86_64", target_os = "linux"))]
pub mod runtime;

#[cfg(all(target_arch = "x86_64", target_os = "linux"))]
mod exec;

#[cfg(all(target_arch = "x86_64", target_os = "linux"))]
pub use exec::NativeProgram;

/// Whether this host can map and execute JIT'd code. Probed once by
/// compiling and running a trivial function (sandboxes may deny
/// `PROT_EXEC` even on x86-64 Linux).
#[cfg(all(target_arch = "x86_64", target_os = "linux"))]
pub fn available() -> bool {
    static PROBE: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *PROBE.get_or_init(|| {
        let mut a = asm::Asm::new();
        a.mov_ri(asm::RAX, 0x51C0DE);
        a.ret();
        let code = match a.finish() {
            Ok(c) => c,
            Err(_) => return false,
        };
        match mem::ExecBuf::map(&code) {
            Ok(buf) => {
                let f: extern "C" fn() -> i64 = unsafe { std::mem::transmute(buf.at(0)) };
                f() == 0x51C0DE
            }
            Err(_) => false,
        }
    })
}

/// Stub for hosts without the JIT (non-x86-64 or non-Linux): the type
/// exists so the coordinator wiring compiles, but it can never be
/// constructed — every `--backend native` request silently runs on the
/// VM tier.
#[cfg(not(all(target_arch = "x86_64", target_os = "linux")))]
mod stub {
    use crate::exec::vm::{ExecLimits, VmRun};
    use crate::lowering::bytecode::ExecProgram;
    use crate::symbolic::{ContainerId, Sym};

    pub struct NativeProgram {
        _private: (),
    }

    impl NativeProgram {
        pub fn compile(_prog: &ExecProgram) -> Result<NativeProgram, String> {
            Err("native tier is only supported on x86-64 Linux".into())
        }

        pub fn run_limited(
            &self,
            _prog: &ExecProgram,
            _params: &[(Sym, i64)],
            _inputs: &[(ContainerId, &[f64])],
            _threads: usize,
            _limits: &ExecLimits,
        ) -> anyhow::Result<VmRun> {
            unreachable!("stub NativeProgram cannot be constructed")
        }
    }

    pub fn available() -> bool {
        false
    }
}

#[cfg(not(all(target_arch = "x86_64", target_os = "linux")))]
pub use stub::{available, NativeProgram};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_parse_roundtrip() {
        assert_eq!(Tier::parse("vm").unwrap(), Tier::Vm);
        assert_eq!(Tier::parse("native").unwrap(), Tier::Native);
        assert_eq!(
            Tier::parse("speculative").unwrap(),
            Tier::Speculative
        );
        assert!(Tier::parse("gpu").is_err());
        assert_eq!(Tier::Native.as_str(), "native");
        assert_eq!(Tier::Speculative.as_str(), "speculative");
        assert_eq!(Tier::default(), Tier::Vm);
    }

    #[cfg(all(target_arch = "x86_64", target_os = "linux"))]
    #[test]
    fn probe_is_stable() {
        // Whatever the sandbox says, it must say it twice.
        assert_eq!(available(), available());
    }
}
