//! Compile one [`CodeBlock`] of lowered bytecode into an x86-64
//! function.
//!
//! # ABI and register roles
//!
//! Each block becomes `extern "C" fn(*mut NativeCtx) -> i64` (return
//! codes in [`super::runtime`]). Callee-saved registers carry the
//! loop-invariant machine state so helper calls need no spills:
//!
//! | reg   | role                                  |
//! |-------|---------------------------------------|
//! | `rbp` | `*mut NativeCtx`                      |
//! | `r12` | int register file base                |
//! | `r13` | float register file base              |
//! | `r14` | container base-pointer array          |
//! | `rbx`, `r15` | pinned hot int virtual registers |
//!
//! `rax/rcx/rdx/rsi/rdi` and `xmm0/xmm1` are scratch within a single
//! op. Prologue pushes all six callee-saved registers plus `sub rsp,8`,
//! so `rsp ≡ 0 (mod 16)` at every helper call site, per the SysV ABI.
//!
//! # Pinning
//!
//! Up to two int virtual registers are held in `rbx`/`r15` for the
//! whole block, chosen by loop-depth-weighted use counts over
//! [`crate::machine::regalloc::uses_defs`] — the same use/def model the
//! register-pressure estimator is built on, so the JIT's allocation is
//! seeded from the paper's pressure analysis. Pinned values are loaded
//! once in the prologue and flushed back in the shared epilogue, which
//! every exit (fallthrough, `Halt`, and all trap stubs) funnels
//! through — the VM-visible `Frame` state is identical on every path.
//!
//! # Trap stubs
//!
//! Bounds failures jump to a per-block out-of-line stub that stores the
//! failing index, container length, and container id into the
//! `NativeCtx` trap fields and returns [`RC_OOB`]; fuel and deadline
//! stubs return their codes directly. No unwinding crosses the JIT
//! boundary.

use std::collections::HashMap;

use crate::exec::values::DEADLINE_TICK;
use crate::lowering::bytecode::Op;
use crate::machine::regalloc::uses_defs;

use super::asm::{Asm, Cc, Label, RAX, RBP, RBX, RCX, RDI, RDX, RSI, R12, R13, R14, R15, XMM0, XMM1};
use super::runtime::{
    nat_deadline_hit, nat_fexp, nat_ffloor, nat_flog2, nat_fmax, nat_fmin, nat_fpow,
    nat_ifloordiv, nat_ilog2, nat_imod, nat_ipow, CTX_BASES, CTX_FLOATS, CTX_FUEL, CTX_INTS,
    CTX_LENS, CTX_TICK, CTX_TRAP_CONT, CTX_TRAP_INDEX, CTX_TRAP_LEN, RC_FUEL, RC_OOB, RC_TIME,
};

/// Pinned int virtual registers → physical registers for one block.
struct Pins {
    map: HashMap<u16, u8>,
}

impl Pins {
    fn of(&self, vreg: u16) -> Option<u8> {
        self.map.get(&vreg).copied()
    }
}

/// Pick up to two int vregs to pin, weighting each use by
/// `4^loop-depth` so registers hot in inner flat loops win. Blocks
/// without a flat loop (straight-line bound/stride/prefetch blocks) are
/// executed once per invocation and skip pinning entirely.
fn choose_pins(ops: &[Op]) -> Pins {
    let mut map = HashMap::new();
    if !ops.iter().any(|o| matches!(o, Op::LoopCond { .. })) {
        return Pins { map };
    }
    // Depth profile: ops between a LoopCond and its exit are one level
    // deeper.
    let mut delta = vec![0i32; ops.len() + 1];
    for (pc, op) in ops.iter().enumerate() {
        if let Op::LoopCond { exit, .. } = op {
            let exit = (*exit as usize).min(ops.len());
            if exit > pc + 1 {
                delta[pc + 1] += 1;
                delta[exit] -= 1;
            }
        }
    }
    let mut weights: HashMap<u16, u64> = HashMap::new();
    let mut depth = 0i32;
    for (pc, op) in ops.iter().enumerate() {
        depth += delta[pc];
        let w = 1u64 << (2 * depth.clamp(0, 12)) as u32;
        let (int_uses, int_def, _, _) = uses_defs(op);
        for r in int_uses.into_iter().chain(int_def) {
            *weights.entry(r).or_insert(0) += w;
        }
    }
    let mut ranked: Vec<(u16, u64)> = weights.into_iter().collect();
    ranked.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    for (vreg, phys) in ranked.into_iter().zip([RBX, R15]) {
        map.insert(vreg.0, phys);
    }
    Pins { map }
}

fn disp_of(vreg: u16) -> i32 {
    vreg as i32 * 8
}

struct BlockEmitter<'a> {
    a: &'a mut Asm,
    pins: Pins,
    oob: Label,
    fuel: Label,
    time: Label,
    oob_used: bool,
    fuel_used: bool,
    time_used: bool,
}

impl BlockEmitter<'_> {
    /// Load int vreg into a physical scratch register.
    fn iload(&mut self, phys: u8, vreg: u16) {
        match self.pins.of(vreg) {
            Some(p) => self.a.mov_rr(phys, p),
            None => self.a.mov_rm(phys, R12, disp_of(vreg)),
        }
    }

    /// Store a physical register into an int vreg.
    fn istore(&mut self, vreg: u16, phys: u8) {
        match self.pins.of(vreg) {
            Some(p) => self.a.mov_rr(p, phys),
            None => self.a.mov_mr(R12, disp_of(vreg), phys),
        }
    }

    fn helper(&mut self, f: usize) {
        self.a.mov_ri(RAX, f as i64);
        self.a.call(RAX);
    }

    /// `rcx ← bases[cont]`.
    fn load_base(&mut self, cont: u16) {
        self.a.mov_rm(RCX, R14, cont as i32 * 8);
    }

    /// Effective index into `rax`: vreg `idx` plus a compile-time
    /// element offset, matching the VM's `i!(idx) + off as i64`.
    fn eff_index(&mut self, idx: u16, off: i32) {
        self.iload(RAX, idx);
        if off != 0 {
            self.a.add_ri(RAX, off);
        }
    }

    fn emit_op(&mut self, pc: usize, op: &Op, op_labels: &[Label]) -> Result<(), String> {
        let a_ptr = |l: &[Label], i: usize| -> Result<Label, String> {
            l.get(i)
                .copied()
                .ok_or_else(|| format!("branch target {i} outside block"))
        };
        match *op {
            Op::IConst { dst, val } => {
                self.a.mov_ri(RAX, val);
                self.istore(dst, RAX);
            }
            Op::ICopy { dst, src } => {
                self.iload(RAX, src);
                self.istore(dst, RAX);
            }
            Op::IAdd { dst, a, b } => {
                self.iload(RAX, a);
                self.iload(RCX, b);
                self.a.add_rr(RAX, RCX);
                self.istore(dst, RAX);
            }
            Op::IAddImm { dst, a, imm } => {
                self.iload(RAX, a);
                match i32::try_from(imm) {
                    Ok(v) => self.a.add_ri(RAX, v),
                    Err(_) => {
                        self.a.mov_ri(RCX, imm);
                        self.a.add_rr(RAX, RCX);
                    }
                }
                self.istore(dst, RAX);
            }
            Op::ISub { dst, a, b } => {
                self.iload(RAX, a);
                self.iload(RCX, b);
                self.a.sub_rr(RAX, RCX);
                self.istore(dst, RAX);
            }
            Op::IMul { dst, a, b } => {
                self.iload(RAX, a);
                self.iload(RCX, b);
                self.a.imul_rr(RAX, RCX);
                self.istore(dst, RAX);
            }
            Op::IMulImm { dst, a, imm } => {
                self.iload(RCX, a);
                match i32::try_from(imm) {
                    Ok(v) => self.a.imul_rri(RAX, RCX, v),
                    Err(_) => {
                        self.a.mov_ri(RAX, imm);
                        self.a.imul_rr(RAX, RCX);
                    }
                }
                self.istore(dst, RAX);
            }
            Op::IFloorDiv { dst, a, b } => {
                self.iload(RDI, a);
                self.iload(RSI, b);
                self.helper(nat_ifloordiv as usize);
                self.istore(dst, RAX);
            }
            Op::IMod { dst, a, b } => {
                self.iload(RDI, a);
                self.iload(RSI, b);
                self.helper(nat_imod as usize);
                self.istore(dst, RAX);
            }
            Op::IMin { dst, a, b } => {
                self.iload(RAX, a);
                self.iload(RCX, b);
                self.a.cmp_rr(RAX, RCX);
                self.a.cmovg(RAX, RCX);
                self.istore(dst, RAX);
            }
            Op::IMax { dst, a, b } => {
                self.iload(RAX, a);
                self.iload(RCX, b);
                self.a.cmp_rr(RAX, RCX);
                self.a.cmovl(RAX, RCX);
                self.istore(dst, RAX);
            }
            Op::IPow { dst, a, exp } => {
                self.iload(RDI, a);
                self.a.mov_ri(RSI, exp as i64);
                self.helper(nat_ipow as usize);
                self.istore(dst, RAX);
            }
            Op::ILog2 { dst, a } => {
                self.iload(RDI, a);
                self.helper(nat_ilog2 as usize);
                self.istore(dst, RAX);
            }
            Op::IAbs { dst, a } => {
                // Branchless |x| (wrapping at i64::MIN, like release-mode
                // `i64::abs`): t = x >> 63; (x ^ t) - t.
                self.iload(RAX, a);
                self.a.mov_rr(RCX, RAX);
                self.a.sar_ri(RCX, 63);
                self.a.xor_rr(RAX, RCX);
                self.a.sub_rr(RAX, RCX);
                self.istore(dst, RAX);
            }

            Op::FConst { dst, bits } => {
                self.a.mov_ri(RAX, bits as i64);
                self.a.mov_mr(R13, disp_of(dst), RAX);
            }
            Op::FCopy { dst, src } => {
                self.a.mov_rm(RAX, R13, disp_of(src));
                self.a.mov_mr(R13, disp_of(dst), RAX);
            }
            Op::FAdd { dst, a, b }
            | Op::FSub { dst, a, b }
            | Op::FMul { dst, a, b }
            | Op::FDiv { dst, a, b } => {
                self.a.movsd_xm(XMM0, R13, disp_of(a));
                self.a.movsd_xm(XMM1, R13, disp_of(b));
                match op {
                    Op::FAdd { .. } => self.a.addsd(XMM0, XMM1),
                    Op::FSub { .. } => self.a.subsd(XMM0, XMM1),
                    Op::FMul { .. } => self.a.mulsd(XMM0, XMM1),
                    _ => self.a.divsd(XMM0, XMM1),
                }
                self.a.movsd_mx(R13, disp_of(dst), XMM0);
            }
            Op::FMin { dst, a, b } | Op::FMax { dst, a, b } => {
                // Rust f64::min/max are NaN-ignoring; SSE minsd/maxsd are
                // not. Helper call keeps bitwise parity with the VM.
                self.a.movsd_xm(XMM0, R13, disp_of(a));
                self.a.movsd_xm(XMM1, R13, disp_of(b));
                let f = if matches!(op, Op::FMin { .. }) {
                    nat_fmin as usize
                } else {
                    nat_fmax as usize
                };
                self.helper(f);
                self.a.movsd_mx(R13, disp_of(dst), XMM0);
            }
            Op::FPow { dst, a, exp } => {
                self.a.movsd_xm(XMM0, R13, disp_of(a));
                self.a.mov_ri(RDI, exp as i64);
                self.helper(nat_fpow as usize);
                self.a.movsd_mx(R13, disp_of(dst), XMM0);
            }
            Op::FExp { dst, a } | Op::FLog2 { dst, a } | Op::FFloor { dst, a } => {
                self.a.movsd_xm(XMM0, R13, disp_of(a));
                let f = match op {
                    Op::FExp { .. } => nat_fexp as usize,
                    Op::FLog2 { .. } => nat_flog2 as usize,
                    _ => nat_ffloor as usize,
                };
                self.helper(f);
                self.a.movsd_mx(R13, disp_of(dst), XMM0);
            }
            Op::FSqrt { dst, a } => {
                // sqrtsd is IEEE-exact — same bits as Rust f64::sqrt.
                self.a.movsd_xm(XMM0, R13, disp_of(a));
                self.a.sqrtsd(XMM0, XMM0);
                self.a.movsd_mx(R13, disp_of(dst), XMM0);
            }
            Op::FAbs { dst, a } => {
                // Clear the sign bit via integer shift pair.
                self.a.mov_rm(RAX, R13, disp_of(a));
                self.a.shl1(RAX);
                self.a.shr1(RAX);
                self.a.mov_mr(R13, disp_of(dst), RAX);
            }
            Op::FSelect { dst, cond, a, b } => {
                // VM: if cond > 0.0 { a } else { b }; NaN takes b
                // (ucomisd sets PF on unordered, and `ja` is false).
                self.a.movsd_xm(XMM0, R13, disp_of(cond));
                self.a.xorpd(XMM1, XMM1);
                self.a.ucomisd(XMM0, XMM1);
                let take_a = self.a.label();
                let done = self.a.label();
                self.a.jcc(Cc::A, take_a);
                self.a.mov_rm(RAX, R13, disp_of(b));
                self.a.jmp(done);
                self.a.bind(take_a);
                self.a.mov_rm(RAX, R13, disp_of(a));
                self.a.bind(done);
                self.a.mov_mr(R13, disp_of(dst), RAX);
            }
            Op::FFromI { dst, src } => {
                // cvtsi2sd rounds exactly like `i64 as f64`.
                self.iload(RAX, src);
                self.a.cvtsi2sd(XMM0, RAX);
                self.a.movsd_mx(R13, disp_of(dst), XMM0);
            }

            Op::Load { dst, cont, idx } => {
                self.eff_index(idx, 0);
                self.load_base(cont);
                self.a.mov_rm_sib(RDX, RCX, RAX, 0);
                self.a.mov_mr(R13, disp_of(dst), RDX);
            }
            Op::LoadOff {
                dst,
                cont,
                idx,
                off,
            } => {
                self.iload(RAX, idx);
                self.load_base(cont);
                match off.checked_mul(8) {
                    Some(d) => self.a.mov_rm_sib(RDX, RCX, RAX, d),
                    None => {
                        self.a.add_ri(RAX, off);
                        self.a.mov_rm_sib(RDX, RCX, RAX, 0);
                    }
                }
                self.a.mov_mr(R13, disp_of(dst), RDX);
            }
            Op::LoadAt2 { dst, cont, a, b } => {
                self.iload(RAX, a);
                self.iload(RDX, b);
                self.a.add_rr(RAX, RDX);
                self.load_base(cont);
                self.a.mov_rm_sib(RDX, RCX, RAX, 0);
                self.a.mov_mr(R13, disp_of(dst), RDX);
            }
            Op::Store { cont, idx, src } => {
                self.eff_index(idx, 0);
                self.load_base(cont);
                self.a.mov_rm(RDX, R13, disp_of(src));
                self.a.mov_mr_sib(RCX, RAX, 0, RDX);
            }
            Op::StoreOff {
                cont,
                idx,
                off,
                src,
            } => {
                self.iload(RAX, idx);
                self.load_base(cont);
                self.a.mov_rm(RDX, R13, disp_of(src));
                match off.checked_mul(8) {
                    Some(d) => self.a.mov_mr_sib(RCX, RAX, d, RDX),
                    None => {
                        self.a.add_ri(RAX, off);
                        self.a.mov_mr_sib(RCX, RAX, 0, RDX);
                    }
                }
            }
            Op::StoreF32 { cont, idx, src } | Op::StoreOffF32 { cont, idx, src, .. } => {
                let off = match *op {
                    Op::StoreOffF32 { off, .. } => off,
                    _ => 0,
                };
                self.iload(RAX, idx);
                self.load_base(cont);
                // Round through f32 exactly like `v as f32 as f64`.
                self.a.movsd_xm(XMM0, R13, disp_of(src));
                self.a.cvtsd2ss(XMM0, XMM0);
                self.a.cvtss2sd(XMM0, XMM0);
                match off.checked_mul(8) {
                    Some(d) => self.a.movsd_mx_sib(RCX, RAX, d, XMM0),
                    None => {
                        self.a.add_ri(RAX, off);
                        self.a.movsd_mx_sib(RCX, RAX, 0, XMM0);
                    }
                }
            }
            Op::Prefetch { cont, idx, .. } => {
                // prefetcht0 never faults, so no bounds logic is needed;
                // `write` hints are folded into t0 (no prefetchw on SSE2
                // baseline).
                self.iload(RAX, idx);
                self.load_base(cont);
                self.a.prefetcht0_sib(RCX, RAX, 0);
            }
            Op::BoundsCheck { cont, idx, off } => {
                self.eff_index(idx, off);
                self.a.mov_rm(RCX, RBP, CTX_LENS);
                self.a.mov_rm(RCX, RCX, cont as i32 * 8);
                let bad = self.a.label();
                let good = self.a.label();
                self.a.test_rr(RAX, RAX);
                self.a.jcc(Cc::S, bad);
                self.a.cmp_rr(RAX, RCX);
                self.a.jcc(Cc::L, good);
                self.a.bind(bad);
                self.a.mov_ri(RDX, cont as i64);
                self.oob_used = true;
                let oob = self.oob;
                self.a.jmp(oob);
                self.a.bind(good);
            }

            Op::Jump { target } => {
                let l = a_ptr(op_labels, target as usize)?;
                self.a.jmp(l);
            }
            Op::LoopCond {
                var,
                end,
                stride,
                exit,
            } => {
                let exit_l = a_ptr(op_labels, exit as usize)?;
                self.iload(RAX, var);
                self.iload(RCX, end);
                self.iload(RDX, stride);
                // done = s == 0 || (s > 0 && v >= e) || (s < 0 && v <= e)
                self.a.test_rr(RDX, RDX);
                self.a.jcc(Cc::E, exit_l);
                let neg = self.a.label();
                let cont = self.a.label();
                self.a.jcc(Cc::S, neg);
                self.a.cmp_rr(RAX, RCX);
                self.a.jcc(Cc::Ge, exit_l);
                self.a.jmp(cont);
                self.a.bind(neg);
                self.a.cmp_rr(RAX, RCX);
                self.a.jcc(Cc::Le, exit_l);
                self.a.bind(cont);
                // Back-edge: burn one fuel unit (trap when it goes
                // negative), then the deadline tick countdown.
                self.a.mov_rm(RSI, RBP, CTX_FUEL);
                self.a.sub_mem1(RSI, 0);
                self.fuel_used = true;
                let fuel = self.fuel;
                self.a.jcc(Cc::S, fuel);
                self.a.sub_mem1(RBP, CTX_TICK);
                let after = self.a.label();
                self.a.jcc(Cc::Ne, after);
                self.a.mov_ri(RAX, DEADLINE_TICK as i64);
                self.a.mov_mr(RBP, CTX_TICK, RAX);
                self.a.mov_rr(RDI, RBP);
                self.helper(nat_deadline_hit as usize);
                self.a.test_rr(RAX, RAX);
                self.time_used = true;
                let time = self.time;
                self.a.jcc(Cc::Ne, time);
                self.a.bind(after);
            }
            Op::GuardSkip { cond, skip } => {
                // VM: if cond <= 0.0 skip the next `skip` ops. NaN compares
                // unordered (PF set) and must NOT skip — test PF first.
                let target = a_ptr(op_labels, pc + skip as usize + 1)?;
                self.a.movsd_xm(XMM0, R13, disp_of(cond));
                self.a.xorpd(XMM1, XMM1);
                self.a.ucomisd(XMM0, XMM1);
                let noskip = self.a.label();
                self.a.jcc(Cc::P, noskip);
                self.a.jcc(Cc::Be, target);
                self.a.bind(noskip);
            }
            Op::Halt => {
                let end = op_labels[op_labels.len() - 1];
                self.a.jmp(end);
            }
        }
        Ok(())
    }
}

/// Emit one block as a complete function; returns its byte offset in
/// the assembler's buffer. `Err` marks an op the backend cannot compile
/// (the caller falls back to the VM tier).
pub fn emit_block(a: &mut Asm, ops: &[Op]) -> Result<usize, String> {
    let offset = a.here();
    let pins = choose_pins(ops);

    // Labels: one per op position plus the fallthrough end.
    let op_labels: Vec<Label> = (0..=ops.len()).map(|_| a.label()).collect();
    let epilogue = a.label();
    let oob = a.label();
    let fuel = a.label();
    let time = a.label();

    // Prologue.
    for &r in &[RBP, RBX, R12, R13, R14, R15] {
        a.push(r);
    }
    a.sub_rsp8();
    a.mov_rr(RBP, RDI);
    a.mov_rm(R12, RBP, CTX_INTS);
    a.mov_rm(R13, RBP, CTX_FLOATS);
    a.mov_rm(R14, RBP, CTX_BASES);
    let pinned: Vec<(u16, u8)> = {
        let mut v: Vec<(u16, u8)> = pins.map.iter().map(|(k, p)| (*k, *p)).collect();
        v.sort();
        v
    };
    for &(vreg, phys) in &pinned {
        a.mov_rm(phys, R12, disp_of(vreg));
    }

    let mut e = BlockEmitter {
        a,
        pins,
        oob,
        fuel,
        time,
        oob_used: false,
        fuel_used: false,
        time_used: false,
    };
    for (pc, op) in ops.iter().enumerate() {
        e.a.bind(op_labels[pc]);
        e.emit_op(pc, op, &op_labels)?;
    }
    let (oob_used, fuel_used, time_used) = (e.oob_used, e.fuel_used, e.time_used);

    // Fallthrough / Halt: return RC_OK through the shared epilogue.
    a.bind(op_labels[ops.len()]);
    a.xor_rr(RAX, RAX);
    a.bind(epilogue);
    for &(vreg, phys) in &pinned {
        a.mov_mr(R12, disp_of(vreg), phys);
    }
    a.add_rsp8();
    for &r in &[R15, R14, R13, R12, RBX, RBP] {
        a.pop(r);
    }
    a.ret();

    // Trap stubs (only when referenced; unreferenced labels stay bound
    // at a dead position for `finish`).
    if oob_used {
        a.bind(oob);
        // rax = failing index, rcx = len, rdx = container id.
        a.mov_mr(RBP, CTX_TRAP_INDEX, RAX);
        a.mov_mr(RBP, CTX_TRAP_LEN, RCX);
        a.mov_mr(RBP, CTX_TRAP_CONT, RDX);
        a.mov_ri(RAX, RC_OOB);
        a.jmp(epilogue);
    } else {
        a.bind(oob);
    }
    if fuel_used {
        a.bind(fuel);
        a.mov_ri(RAX, RC_FUEL);
        a.jmp(epilogue);
    } else {
        a.bind(fuel);
    }
    if time_used {
        a.bind(time);
        a.mov_ri(RAX, RC_TIME);
        a.jmp(epilogue);
    } else {
        a.bind(time);
    }
    Ok(offset)
}
