//! The JIT ↔ Rust runtime boundary.
//!
//! Every compiled block is an `extern "C" fn(*mut NativeCtx) -> i64`
//! returning one of the [`RC_OK`]..[`RC_TIME`] codes. The context struct
//! is `#[repr(C)]` with offsets the emitter hard-codes (pinned by a
//! layout test below). Ops whose semantics SSE2 cannot reproduce
//! bit-for-bit (NaN-aware min/max, `exp`, `floor`, euclidean div/mod,
//! wrapping pow) call back into these `extern "C"` helpers, which are
//! the *same Rust expressions the VM interpreter evaluates* — bitwise
//! parity is by construction, not by approximation.

/// Per-invocation execution context handed to compiled blocks.
///
/// Field offsets (hard-coded in `emit.rs`):
/// `0x00` ints · `0x08` floats · `0x10` bases · `0x18` lens ·
/// `0x20` fuel · `0x28` deadline · `0x30` tick · `0x38` trap_cont ·
/// `0x40` trap_index · `0x48` trap_len.
#[repr(C)]
pub struct NativeCtx {
    /// Integer register file (`Frame::ints`).
    pub ints: *mut i64,
    /// Float register file (`Frame::floats`).
    pub floats: *mut f64,
    /// Per-container base pointers (`Frame::bases`).
    pub bases: *const *mut f64,
    /// Per-container lengths (`Frame::lens`) for checked-tier guards.
    pub lens: *const usize,
    /// Remaining fuel; decremented in-code at every loop back-edge.
    pub fuel: *mut i64,
    /// Borrow of `Frame::deadline` (`*const Option<Instant>`), probed
    /// via [`nat_deadline_hit`] every `DEADLINE_TICK` back-edges.
    pub deadline: *const u8,
    /// Countdown to the next deadline probe (synced with `Frame::tick`
    /// around each block invocation).
    pub tick: i64,
    /// Trap out-params, valid when the block returns [`RC_OOB`].
    pub trap_cont: i64,
    pub trap_index: i64,
    pub trap_len: i64,
}

pub const CTX_INTS: i32 = 0x00;
pub const CTX_FLOATS: i32 = 0x08;
pub const CTX_BASES: i32 = 0x10;
pub const CTX_LENS: i32 = 0x18;
pub const CTX_FUEL: i32 = 0x20;
pub const CTX_DEADLINE: i32 = 0x28;
pub const CTX_TICK: i32 = 0x30;
pub const CTX_TRAP_CONT: i32 = 0x38;
pub const CTX_TRAP_INDEX: i32 = 0x40;
pub const CTX_TRAP_LEN: i32 = 0x48;

/// Block return codes.
pub const RC_OK: i64 = 0;
pub const RC_OOB: i64 = 1;
pub const RC_FUEL: i64 = 2;
pub const RC_TIME: i64 = 3;

/// Compiled block signature.
pub type BlockFn = unsafe extern "C" fn(*mut NativeCtx) -> i64;

// ---- float helpers (xmm0/xmm1 args, xmm0 result) ----

pub extern "C" fn nat_fmin(a: f64, b: f64) -> f64 {
    a.min(b)
}

pub extern "C" fn nat_fmax(a: f64, b: f64) -> f64 {
    a.max(b)
}

pub extern "C" fn nat_fexp(a: f64) -> f64 {
    a.exp()
}

pub extern "C" fn nat_flog2(a: f64) -> f64 {
    a.log2()
}

pub extern "C" fn nat_ffloor(a: f64) -> f64 {
    a.floor()
}

/// `Op::FPow` (exp arrives in edi).
pub extern "C" fn nat_fpow(a: f64, exp: u32) -> f64 {
    a.powi(exp as i32)
}

// ---- integer helpers (rdi/rsi args, rax result) ----

pub extern "C" fn nat_ifloordiv(a: i64, b: i64) -> i64 {
    if b == 0 {
        0
    } else {
        a.div_euclid(b)
    }
}

pub extern "C" fn nat_imod(a: i64, b: i64) -> i64 {
    if b == 0 {
        0
    } else {
        a.rem_euclid(b)
    }
}

pub extern "C" fn nat_ipow(a: i64, exp: u32) -> i64 {
    a.wrapping_pow(exp)
}

pub extern "C" fn nat_ilog2(a: i64) -> i64 {
    if a > 0 {
        63 - (a as u64).leading_zeros() as i64
    } else {
        0
    }
}

/// Wall-clock probe: 1 when the deadline has passed. Called from
/// emitted code every `DEADLINE_TICK` back-edges, mirroring
/// `Frame::backedge`.
pub extern "C" fn nat_deadline_hit(ctx: *mut NativeCtx) -> i64 {
    let deadline = unsafe { &*((*ctx).deadline as *const Option<std::time::Instant>) };
    match deadline {
        Some(d) if std::time::Instant::now() >= *d => 1,
        _ => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The emitter hard-codes these offsets; a layout drift must fail
    /// loudly here rather than scribble over the wrong field at runtime.
    #[test]
    fn ctx_layout_matches_emitter_offsets() {
        let ctx = NativeCtx {
            ints: std::ptr::null_mut(),
            floats: std::ptr::null_mut(),
            bases: std::ptr::null(),
            lens: std::ptr::null(),
            fuel: std::ptr::null_mut(),
            deadline: std::ptr::null(),
            tick: 0,
            trap_cont: 0,
            trap_index: 0,
            trap_len: 0,
        };
        let base = &ctx as *const NativeCtx as usize;
        let off = |p: usize| (p - base) as i32;
        assert_eq!(off(&ctx.ints as *const _ as usize), CTX_INTS);
        assert_eq!(off(&ctx.floats as *const _ as usize), CTX_FLOATS);
        assert_eq!(off(&ctx.bases as *const _ as usize), CTX_BASES);
        assert_eq!(off(&ctx.lens as *const _ as usize), CTX_LENS);
        assert_eq!(off(&ctx.fuel as *const _ as usize), CTX_FUEL);
        assert_eq!(off(&ctx.deadline as *const _ as usize), CTX_DEADLINE);
        assert_eq!(off(&ctx.tick as *const _ as usize), CTX_TICK);
        assert_eq!(off(&ctx.trap_cont as *const _ as usize), CTX_TRAP_CONT);
        assert_eq!(off(&ctx.trap_index as *const _ as usize), CTX_TRAP_INDEX);
        assert_eq!(off(&ctx.trap_len as *const _ as usize), CTX_TRAP_LEN);
    }

    #[test]
    fn helpers_match_vm_semantics() {
        // NaN-aware min/max (SSE minsd/maxsd would get these wrong).
        assert_eq!(nat_fmin(f64::NAN, 2.0), 2.0);
        assert_eq!(nat_fmax(2.0, f64::NAN), 2.0);
        assert_eq!(nat_fmin(-0.0f64, 0.0).to_bits(), (-0.0f64).to_bits());
        // Euclidean division with the VM's divide-by-zero convention.
        assert_eq!(nat_ifloordiv(-7, 2), -4);
        assert_eq!(nat_ifloordiv(7, 0), 0);
        assert_eq!(nat_imod(-7, 2), 1);
        assert_eq!(nat_imod(7, 0), 0);
        assert_eq!(nat_ipow(3, 4), 81);
        assert_eq!(nat_ipow(i64::MAX, 2), i64::MAX.wrapping_pow(2));
        assert_eq!(nat_ilog2(1), 0);
        assert_eq!(nat_ilog2(1024), 10);
        assert_eq!(nat_ilog2(-5), 0);
        assert_eq!(nat_ilog2(0), 0);
    }
}
