//! A minimal x86-64 instruction encoder for the JIT.
//!
//! Only the forms the bytecode compiler ([`super::emit`]) actually emits
//! are supported: 64-bit GPR moves/ALU, scalar-double SSE2, rel32
//! branches with label fixups, and `prefetcht0`. Every encoding here is
//! pinned by golden-byte tests transcribed from GNU `as` + `objdump`
//! output (see the `tests` module), so a regression in the encoder is a
//! test failure, not a SIGILL.
//!
//! Register numbering follows the hardware: 0=rax 1=rcx 2=rdx 3=rbx
//! 4=rsp 5=rbp 6=rsi 7=rdi 8..=15=r8..r15, and xmm0..xmm15 likewise.

pub const RAX: u8 = 0;
pub const RCX: u8 = 1;
pub const RDX: u8 = 2;
pub const RBX: u8 = 3;
pub const RSP: u8 = 4;
pub const RBP: u8 = 5;
pub const RSI: u8 = 6;
pub const RDI: u8 = 7;
pub const R12: u8 = 12;
pub const R13: u8 = 13;
pub const R14: u8 = 14;
pub const R15: u8 = 15;

pub const XMM0: u8 = 0;
pub const XMM1: u8 = 1;

/// Condition codes (the low nibble of the `0F 8x` jcc opcodes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cc {
    E = 0x4,
    Ne = 0x5,
    L = 0xC,
    Le = 0xE,
    G = 0xF,
    Ge = 0xD,
    S = 0x8,
    Ns = 0x9,
    A = 0x7,
    Be = 0x6,
    P = 0xA,
}

/// A forward-referenceable code position. rel32 branch sites record a
/// fixup that [`Asm::finish`] patches once every label is bound.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Label(usize);

pub struct Asm {
    code: Vec<u8>,
    /// (position of the rel32 immediate, target label)
    fixups: Vec<(usize, Label)>,
    labels: Vec<Option<usize>>,
}

impl Asm {
    pub fn new() -> Asm {
        Asm {
            code: Vec::new(),
            fixups: Vec::new(),
            labels: Vec::new(),
        }
    }

    pub fn here(&self) -> usize {
        self.code.len()
    }

    pub fn label(&mut self) -> Label {
        self.labels.push(None);
        Label(self.labels.len() - 1)
    }

    pub fn bind(&mut self, l: Label) {
        debug_assert!(self.labels[l.0].is_none(), "label bound twice");
        self.labels[l.0] = Some(self.code.len());
    }

    /// Patch every recorded rel32 fixup and return the code bytes.
    pub fn finish(self) -> Result<Vec<u8>, String> {
        let mut code = self.code;
        for (pos, l) in self.fixups {
            let target = self.labels[l.0].ok_or("unbound label in emitted code")?;
            let rel = target as i64 - (pos as i64 + 4);
            let rel = i32::try_from(rel).map_err(|_| "branch displacement overflow")?;
            code[pos..pos + 4].copy_from_slice(&rel.to_le_bytes());
        }
        Ok(code)
    }

    fn b(&mut self, byte: u8) {
        self.code.push(byte);
    }

    fn b4(&mut self, v: i32) {
        self.code.extend_from_slice(&v.to_le_bytes());
    }

    /// REX prefix. `w`: 64-bit operand; `r`/`x`/`b`: extension bits of
    /// the modrm reg field, SIB index, and modrm rm / SIB base.
    fn rex(&mut self, w: bool, r: u8, x: u8, b: u8) {
        let mut v = 0x40u8;
        if w {
            v |= 8;
        }
        if r >= 8 {
            v |= 4;
        }
        if x >= 8 {
            v |= 2;
        }
        if b >= 8 {
            v |= 1;
        }
        self.b(v);
    }

    /// REX only when one of the registers needs an extension bit (SSE
    /// forms where REX.W is not wanted).
    fn rex_opt(&mut self, r: u8, x: u8, b: u8) {
        if r >= 8 || x >= 8 || b >= 8 {
            self.rex(false, r, x, b);
        }
    }

    fn modrm(&mut self, md: u8, reg: u8, rm: u8) {
        self.b((md << 6) | ((reg & 7) << 3) | (rm & 7));
    }

    /// modrm + optional SIB + displacement for `[base + disp]`.
    /// rsp/r12 bases force a SIB byte; rbp/r13 bases force at least a
    /// disp8 (their mod=00 encodings mean RIP-relative / absolute).
    fn mem(&mut self, reg: u8, base: u8, disp: i32) {
        let b7 = base & 7;
        let need_sib = b7 == 4;
        let (md, small) = if disp == 0 && b7 != 5 {
            (0u8, true)
        } else if (-128..=127).contains(&disp) {
            (1u8, true)
        } else {
            (2u8, false)
        };
        self.modrm(md, reg, if need_sib { 4 } else { base });
        if need_sib {
            self.b(0x24); // scale=0, index=none, base=rsp/r12
        }
        if md == 1 {
            self.b(disp as u8);
        } else if md == 2 || !small {
            self.b4(disp);
        }
    }

    /// modrm + SIB + displacement for `[base + index*8 + disp]`.
    /// `index` must not be rsp (no-index encoding).
    fn mem_sib(&mut self, reg: u8, base: u8, index: u8, disp: i32) {
        debug_assert!(index & 7 != 4, "rsp cannot be an index");
        let b7 = base & 7;
        let (md, has8, has32) = if disp == 0 && b7 != 5 {
            (0u8, false, false)
        } else if (-128..=127).contains(&disp) {
            (1u8, true, false)
        } else {
            (2u8, false, true)
        };
        self.modrm(md, reg, 4);
        // scale=8 (bits 11), index, base.
        self.b((3 << 6) | ((index & 7) << 3) | b7);
        if has8 {
            self.b(disp as u8);
        } else if has32 {
            self.b4(disp);
        }
    }

    // ---- stack / control ----

    pub fn push(&mut self, r: u8) {
        if r >= 8 {
            self.b(0x41);
        }
        self.b(0x50 + (r & 7));
    }

    pub fn pop(&mut self, r: u8) {
        if r >= 8 {
            self.b(0x41);
        }
        self.b(0x58 + (r & 7));
    }

    pub fn ret(&mut self) {
        self.b(0xc3);
    }

    pub fn sub_rsp8(&mut self) {
        self.code.extend_from_slice(&[0x48, 0x83, 0xec, 0x08]);
    }

    pub fn add_rsp8(&mut self) {
        self.code.extend_from_slice(&[0x48, 0x83, 0xc4, 0x08]);
    }

    pub fn call(&mut self, r: u8) {
        if r >= 8 {
            self.b(0x41);
        }
        self.b(0xff);
        self.modrm(3, 2, r);
    }

    pub fn jmp(&mut self, l: Label) {
        self.b(0xe9);
        self.fixups.push((self.code.len(), l));
        self.b4(0);
    }

    pub fn jcc(&mut self, cc: Cc, l: Label) {
        self.b(0x0f);
        self.b(0x80 + cc as u8);
        self.fixups.push((self.code.len(), l));
        self.b4(0);
    }

    // ---- 64-bit moves ----

    pub fn mov_rr(&mut self, dst: u8, src: u8) {
        self.rex(true, src, 0, dst);
        self.b(0x89);
        self.modrm(3, src, dst);
    }

    /// mov dst, [base + disp]
    pub fn mov_rm(&mut self, dst: u8, base: u8, disp: i32) {
        self.rex(true, dst, 0, base);
        self.b(0x8b);
        self.mem(dst, base, disp);
    }

    /// mov [base + disp], src
    pub fn mov_mr(&mut self, base: u8, disp: i32, src: u8) {
        self.rex(true, src, 0, base);
        self.b(0x89);
        self.mem(src, base, disp);
    }

    /// movabs dst, imm64
    pub fn mov_ri(&mut self, dst: u8, imm: i64) {
        self.rex(true, 0, 0, dst);
        self.b(0xb8 + (dst & 7));
        self.code.extend_from_slice(&imm.to_le_bytes());
    }

    /// mov dst, [base + index*8 + disp]
    pub fn mov_rm_sib(&mut self, dst: u8, base: u8, index: u8, disp: i32) {
        self.rex(true, dst, index, base);
        self.b(0x8b);
        self.mem_sib(dst, base, index, disp);
    }

    /// mov [base + index*8 + disp], src
    pub fn mov_mr_sib(&mut self, base: u8, index: u8, disp: i32, src: u8) {
        self.rex(true, src, index, base);
        self.b(0x89);
        self.mem_sib(src, base, index, disp);
    }

    /// mov qword [base + disp], imm32 (sign-extended)
    pub fn mov_mi32(&mut self, base: u8, disp: i32, imm: i32) {
        self.rex(true, 0, 0, base);
        self.b(0xc7);
        self.mem(0, base, disp);
        self.b4(imm);
    }

    // ---- 64-bit ALU ----

    pub fn add_rr(&mut self, dst: u8, src: u8) {
        self.rex(true, src, 0, dst);
        self.b(0x01);
        self.modrm(3, src, dst);
    }

    pub fn sub_rr(&mut self, dst: u8, src: u8) {
        self.rex(true, src, 0, dst);
        self.b(0x29);
        self.modrm(3, src, dst);
    }

    pub fn imul_rr(&mut self, dst: u8, src: u8) {
        self.rex(true, dst, 0, src);
        self.b(0x0f);
        self.b(0xaf);
        self.modrm(3, dst, src);
    }

    pub fn xor_rr(&mut self, dst: u8, src: u8) {
        self.rex(true, src, 0, dst);
        self.b(0x31);
        self.modrm(3, src, dst);
    }

    pub fn cmp_rr(&mut self, a: u8, b: u8) {
        self.rex(true, b, 0, a);
        self.b(0x39);
        self.modrm(3, b, a);
    }

    pub fn test_rr(&mut self, a: u8, b: u8) {
        self.rex(true, b, 0, a);
        self.b(0x85);
        self.modrm(3, b, a);
    }

    /// add dst, imm32 (sign-extended); uses the imm8 form when it fits.
    pub fn add_ri(&mut self, dst: u8, imm: i32) {
        self.alu_ri(0, dst, imm);
    }

    pub fn sub_ri(&mut self, dst: u8, imm: i32) {
        self.alu_ri(5, dst, imm);
    }

    fn alu_ri(&mut self, op: u8, dst: u8, imm: i32) {
        self.rex(true, 0, 0, dst);
        if (-128..=127).contains(&imm) {
            self.b(0x83);
            self.modrm(3, op, dst);
            self.b(imm as u8);
        } else {
            self.b(0x81);
            self.modrm(3, op, dst);
            self.b4(imm);
        }
    }

    /// imul dst, src, imm32
    pub fn imul_rri(&mut self, dst: u8, src: u8, imm: i32) {
        self.rex(true, dst, 0, src);
        if (-128..=127).contains(&imm) {
            self.b(0x6b);
            self.modrm(3, dst, src);
            self.b(imm as u8);
        } else {
            self.b(0x69);
            self.modrm(3, dst, src);
            self.b4(imm);
        }
    }

    /// sar r, imm8
    pub fn sar_ri(&mut self, r: u8, imm: u8) {
        self.rex(true, 0, 0, r);
        self.b(0xc1);
        self.modrm(3, 7, r);
        self.b(imm);
    }

    /// shl r, 1
    pub fn shl1(&mut self, r: u8) {
        self.rex(true, 0, 0, r);
        self.b(0xd1);
        self.modrm(3, 4, r);
    }

    /// shr r, 1
    pub fn shr1(&mut self, r: u8) {
        self.rex(true, 0, 0, r);
        self.b(0xd1);
        self.modrm(3, 5, r);
    }

    pub fn cmovg(&mut self, dst: u8, src: u8) {
        self.rex(true, dst, 0, src);
        self.b(0x0f);
        self.b(0x4f);
        self.modrm(3, dst, src);
    }

    pub fn cmovl(&mut self, dst: u8, src: u8) {
        self.rex(true, dst, 0, src);
        self.b(0x0f);
        self.b(0x4c);
        self.modrm(3, dst, src);
    }

    /// sub qword [base + disp], 1 — the fuel decrement (sets SF).
    pub fn sub_mem1(&mut self, base: u8, disp: i32) {
        self.rex(true, 0, 0, base);
        self.b(0x83);
        self.mem(5, base, disp);
        self.b(1);
    }

    // ---- scalar-double SSE2 ----

    fn sse(&mut self, prefix: u8, op: u8, reg: u8, rm: u8) {
        self.b(prefix);
        self.rex_opt(reg, 0, rm);
        self.b(0x0f);
        self.b(op);
        self.modrm(3, reg, rm);
    }

    /// movsd x, [base + disp]
    pub fn movsd_xm(&mut self, x: u8, base: u8, disp: i32) {
        self.b(0xf2);
        self.rex_opt(x, 0, base);
        self.b(0x0f);
        self.b(0x10);
        self.mem(x, base, disp);
    }

    /// movsd [base + disp], x
    pub fn movsd_mx(&mut self, base: u8, disp: i32, x: u8) {
        self.b(0xf2);
        self.rex_opt(x, 0, base);
        self.b(0x0f);
        self.b(0x11);
        self.mem(x, base, disp);
    }

    /// movsd x, [base + index*8 + disp]
    pub fn movsd_xm_sib(&mut self, x: u8, base: u8, index: u8, disp: i32) {
        self.b(0xf2);
        self.rex_opt(x, index, base);
        self.b(0x0f);
        self.b(0x10);
        self.mem_sib(x, base, index, disp);
    }

    /// movsd [base + index*8 + disp], x
    pub fn movsd_mx_sib(&mut self, base: u8, index: u8, disp: i32, x: u8) {
        self.b(0xf2);
        self.rex_opt(x, index, base);
        self.b(0x0f);
        self.b(0x11);
        self.mem_sib(x, base, index, disp);
    }

    pub fn addsd(&mut self, dst: u8, src: u8) {
        self.sse(0xf2, 0x58, dst, src);
    }

    pub fn subsd(&mut self, dst: u8, src: u8) {
        self.sse(0xf2, 0x5c, dst, src);
    }

    pub fn mulsd(&mut self, dst: u8, src: u8) {
        self.sse(0xf2, 0x59, dst, src);
    }

    pub fn divsd(&mut self, dst: u8, src: u8) {
        self.sse(0xf2, 0x5e, dst, src);
    }

    pub fn sqrtsd(&mut self, dst: u8, src: u8) {
        self.sse(0xf2, 0x51, dst, src);
    }

    pub fn ucomisd(&mut self, a: u8, b: u8) {
        self.sse(0x66, 0x2e, a, b);
    }

    pub fn xorpd(&mut self, dst: u8, src: u8) {
        self.sse(0x66, 0x57, dst, src);
    }

    /// cvtsi2sd x, r64
    pub fn cvtsi2sd(&mut self, x: u8, r: u8) {
        self.b(0xf2);
        self.rex(true, x, 0, r);
        self.b(0x0f);
        self.b(0x2a);
        self.modrm(3, x, r);
    }

    /// cvtsd2ss x, x (round to f32)
    pub fn cvtsd2ss(&mut self, dst: u8, src: u8) {
        self.sse(0xf2, 0x5a, dst, src);
    }

    /// cvtss2sd x, x (widen back to f64)
    pub fn cvtss2sd(&mut self, dst: u8, src: u8) {
        self.sse(0xf3, 0x5a, dst, src);
    }

    /// prefetcht0 [base + index*8 + disp]
    pub fn prefetcht0_sib(&mut self, base: u8, index: u8, disp: i32) {
        self.rex_opt(0, index, base);
        self.b(0x0f);
        self.b(0x18);
        self.mem_sib(1, base, index, disp);
    }
}

impl Default for Asm {
    fn default() -> Self {
        Asm::new()
    }
}

#[cfg(test)]
mod tests {
    //! Golden bytes transcribed from `as` (GNU Binutils) + `objdump -d`,
    //! assembled on this machine. Each case pins one encoder form.

    use super::*;

    fn enc(f: impl FnOnce(&mut Asm)) -> Vec<u8> {
        let mut a = Asm::new();
        f(&mut a);
        a.finish().unwrap()
    }

    #[test]
    fn prologue_epilogue() {
        // push rbp/rbx/r12..r15; sub rsp,8; add rsp,8; pops; ret
        let got = enc(|a| {
            for &r in &[RBP, RBX, R12, R13, R14, R15] {
                a.push(r);
            }
            a.sub_rsp8();
            a.add_rsp8();
            for &r in &[R15, R14, R13, R12, RBX, RBP] {
                a.pop(r);
            }
            a.ret();
        });
        assert_eq!(
            got,
            vec![
                0x55, 0x53, 0x41, 0x54, 0x41, 0x55, 0x41, 0x56, 0x41, 0x57, 0x48, 0x83, 0xec,
                0x08, 0x48, 0x83, 0xc4, 0x08, 0x41, 0x5f, 0x41, 0x5e, 0x41, 0x5d, 0x41, 0x5c,
                0x5b, 0x5d, 0xc3
            ]
        );
    }

    #[test]
    fn mov_reg_reg() {
        assert_eq!(enc(|a| a.mov_rr(RAX, RCX)), vec![0x48, 0x89, 0xc8]);
        assert_eq!(enc(|a| a.mov_rr(8, R15)), vec![0x4d, 0x89, 0xf8]);
        assert_eq!(enc(|a| a.mov_rr(RBP, RDI)), vec![0x48, 0x89, 0xfd]);
        assert_eq!(enc(|a| a.mov_rr(RBX, 9)), vec![0x4c, 0x89, 0xcb]);
    }

    #[test]
    fn mov_reg_mem() {
        // r12 base forces SIB; rbp/r13 bases force disp8.
        assert_eq!(enc(|a| a.mov_rm(RAX, R12, 0)), vec![0x49, 0x8b, 0x04, 0x24]);
        assert_eq!(
            enc(|a| a.mov_rm(RAX, R12, 8)),
            vec![0x49, 0x8b, 0x44, 0x24, 0x08]
        );
        assert_eq!(
            enc(|a| a.mov_rm(RCX, R12, 1024)),
            vec![0x49, 0x8b, 0x8c, 0x24, 0x00, 0x04, 0x00, 0x00]
        );
        assert_eq!(enc(|a| a.mov_rm(RDX, RBP, 0)), vec![0x48, 0x8b, 0x55, 0x00]);
        assert_eq!(enc(|a| a.mov_rm(RDX, RBP, 16)), vec![0x48, 0x8b, 0x55, 0x10]);
        assert_eq!(
            enc(|a| a.mov_rm(10, R13, 4096)),
            vec![0x4d, 0x8b, 0x95, 0x00, 0x10, 0x00, 0x00]
        );
        assert_eq!(enc(|a| a.mov_rm(R15, RBP, 48)), vec![0x4c, 0x8b, 0x7d, 0x30]);
    }

    #[test]
    fn mov_mem_reg() {
        assert_eq!(enc(|a| a.mov_mr(R12, 0, RAX)), vec![0x49, 0x89, 0x04, 0x24]);
        assert_eq!(
            enc(|a| a.mov_mr(R12, 8, RCX)),
            vec![0x49, 0x89, 0x4c, 0x24, 0x08]
        );
        assert_eq!(
            enc(|a| a.mov_mr(RBP, 1024, 9)),
            vec![0x4c, 0x89, 0x8d, 0x00, 0x04, 0x00, 0x00]
        );
        assert_eq!(enc(|a| a.mov_mr(R13, 0, RDX)), vec![0x49, 0x89, 0x55, 0x00]);
    }

    #[test]
    fn mov_imm64() {
        let mut want = vec![0x48, 0xb8];
        want.extend_from_slice(&0x123456789abcdef0u64.to_le_bytes());
        assert_eq!(enc(|a| a.mov_ri(RAX, 0x123456789abcdef0u64 as i64)), want);
        let mut want = vec![0x48, 0xb9];
        want.extend_from_slice(&(-1i64).to_le_bytes());
        assert_eq!(enc(|a| a.mov_ri(RCX, -1)), want);
        let mut want = vec![0x49, 0xbb];
        want.extend_from_slice(&42i64.to_le_bytes());
        assert_eq!(enc(|a| a.mov_ri(11, 42)), want);
    }

    #[test]
    fn mov_sib() {
        assert_eq!(
            enc(|a| a.mov_rm_sib(RAX, RCX, RAX, 0)),
            vec![0x48, 0x8b, 0x04, 0xc1]
        );
        assert_eq!(
            enc(|a| a.mov_rm_sib(RDX, RCX, RAX, 64)),
            vec![0x48, 0x8b, 0x54, 0xc1, 0x40]
        );
        assert_eq!(
            enc(|a| a.mov_rm_sib(9, 8, 10, 0)),
            vec![0x4f, 0x8b, 0x0c, 0xd0]
        );
        assert_eq!(
            enc(|a| a.mov_rm_sib(RAX, R12, RCX, 0)),
            vec![0x49, 0x8b, 0x04, 0xcc]
        );
        assert_eq!(
            enc(|a| a.mov_rm_sib(RAX, RBP, RDX, 8)),
            vec![0x48, 0x8b, 0x44, 0xd5, 0x08]
        );
        // r13 base forces disp8 even when disp == 0.
        assert_eq!(
            enc(|a| a.mov_rm_sib(RAX, R13, R15, 0)),
            vec![0x4b, 0x8b, 0x44, 0xfd, 0x00]
        );
        assert_eq!(
            enc(|a| a.mov_mr_sib(RCX, RAX, 0, RDX)),
            vec![0x48, 0x89, 0x14, 0xc1]
        );
        assert_eq!(
            enc(|a| a.mov_mr_sib(RCX, RAX, 64, 9)),
            vec![0x4c, 0x89, 0x4c, 0xc1, 0x40]
        );
        assert_eq!(
            enc(|a| a.mov_mr_sib(R12, 8, 0, RAX)),
            vec![0x4b, 0x89, 0x04, 0xc4]
        );
        assert_eq!(
            enc(|a| a.mov_mr_sib(R13, R15, 0, RDX)),
            vec![0x4b, 0x89, 0x54, 0xfd, 0x00]
        );
    }

    #[test]
    fn alu_reg_reg() {
        assert_eq!(enc(|a| a.add_rr(RAX, RCX)), vec![0x48, 0x01, 0xc8]);
        assert_eq!(enc(|a| a.add_rr(8, R15)), vec![0x4d, 0x01, 0xf8]);
        assert_eq!(enc(|a| a.sub_rr(RAX, RCX)), vec![0x48, 0x29, 0xc8]);
        assert_eq!(enc(|a| a.imul_rr(RAX, RCX)), vec![0x48, 0x0f, 0xaf, 0xc1]);
        assert_eq!(enc(|a| a.imul_rr(9, R12)), vec![0x4d, 0x0f, 0xaf, 0xcc]);
        assert_eq!(enc(|a| a.xor_rr(RAX, RAX)), vec![0x48, 0x31, 0xc0]);
        assert_eq!(enc(|a| a.xor_rr(10, 10)), vec![0x4d, 0x31, 0xd2]);
        assert_eq!(enc(|a| a.cmp_rr(RAX, RCX)), vec![0x48, 0x39, 0xc8]);
        assert_eq!(enc(|a| a.cmp_rr(R15, RBX)), vec![0x49, 0x39, 0xdf]);
        assert_eq!(enc(|a| a.test_rr(RAX, RAX)), vec![0x48, 0x85, 0xc0]);
        assert_eq!(enc(|a| a.test_rr(11, 11)), vec![0x4d, 0x85, 0xdb]);
    }

    #[test]
    fn alu_reg_imm() {
        assert_eq!(
            enc(|a| a.add_ri(RAX, 1000)),
            vec![0x48, 0x81, 0xc0, 0xe8, 0x03, 0x00, 0x00]
        );
        assert_eq!(
            enc(|a| a.add_ri(9, -1000)),
            vec![0x49, 0x81, 0xc1, 0x18, 0xfc, 0xff, 0xff]
        );
        assert_eq!(enc(|a| a.sub_ri(RAX, 123)), vec![0x48, 0x83, 0xe8, 0x7b]);
        assert_eq!(enc(|a| a.add_ri(RAX, 127)), vec![0x48, 0x83, 0xc0, 0x7f]);
        assert_eq!(enc(|a| a.add_ri(RAX, -128)), vec![0x48, 0x83, 0xc0, 0x80]);
        assert_eq!(
            enc(|a| a.imul_rri(RAX, RCX, 1000)),
            vec![0x48, 0x69, 0xc1, 0xe8, 0x03, 0x00, 0x00]
        );
        assert_eq!(
            enc(|a| a.imul_rri(9, 9, -7)),
            vec![0x4d, 0x6b, 0xc9, 0xf9]
        );
    }

    #[test]
    fn unary_and_cmov() {
        assert_eq!(enc(|a| a.sar_ri(RAX, 63)), vec![0x48, 0xc1, 0xf8, 0x3f]);
        assert_eq!(enc(|a| a.sar_ri(9, 63)), vec![0x49, 0xc1, 0xf9, 0x3f]);
        assert_eq!(enc(|a| a.shl1(RAX)), vec![0x48, 0xd1, 0xe0]);
        assert_eq!(enc(|a| a.shr1(RAX)), vec![0x48, 0xd1, 0xe8]);
        assert_eq!(enc(|a| a.shl1(10)), vec![0x49, 0xd1, 0xe2]);
        assert_eq!(enc(|a| a.shr1(11)), vec![0x49, 0xd1, 0xeb]);
        assert_eq!(enc(|a| a.cmovg(RAX, RCX)), vec![0x48, 0x0f, 0x4f, 0xc1]);
        assert_eq!(enc(|a| a.cmovl(RAX, RCX)), vec![0x48, 0x0f, 0x4c, 0xc1]);
        assert_eq!(enc(|a| a.cmovg(9, R12)), vec![0x4d, 0x0f, 0x4f, 0xcc]);
        assert_eq!(enc(|a| a.cmovl(RBX, 8)), vec![0x49, 0x0f, 0x4c, 0xd8]);
        assert_eq!(enc(|a| a.cmovg(RBX, 8)), vec![0x49, 0x0f, 0x4f, 0xd8]);
        assert_eq!(enc(|a| a.cmovl(R15, RAX)), vec![0x4c, 0x0f, 0x4c, 0xf8]);
    }

    #[test]
    fn control_flow() {
        // jmp / all jcc forms to an immediately-following label → rel32 0.
        let got = enc(|a| {
            let l = a.label();
            a.jmp(l);
            for cc in [
                Cc::E,
                Cc::Ne,
                Cc::L,
                Cc::Le,
                Cc::G,
                Cc::Ge,
                Cc::S,
                Cc::Ns,
                Cc::A,
                Cc::Be,
                Cc::P,
            ] {
                a.jcc(cc, l);
            }
            a.bind(l);
        });
        let mut want = vec![0xe9];
        // label sits at the end; each site's rel32 = distance to it.
        let end = 5 + 11 * 6;
        want.extend_from_slice(&((end - 5) as i32).to_le_bytes());
        for (i, op) in [
            0x84u8, 0x85, 0x8c, 0x8e, 0x8f, 0x8d, 0x88, 0x89, 0x87, 0x86, 0x8a,
        ]
        .iter()
        .enumerate()
        {
            want.push(0x0f);
            want.push(*op);
            let pos = 5 + i * 6 + 6;
            want.extend_from_slice(&((end - pos) as i32).to_le_bytes());
        }
        assert_eq!(got, want);
    }

    #[test]
    fn backward_branch() {
        let got = enc(|a| {
            let l = a.label();
            a.bind(l);
            a.xor_rr(RAX, RAX); // 3 bytes
            a.jmp(l);
        });
        // jmp rel32 back over 3 + 5 bytes.
        let mut want = vec![0x48, 0x31, 0xc0, 0xe9];
        want.extend_from_slice(&(-8i32).to_le_bytes());
        assert_eq!(got, want);
    }

    #[test]
    fn call_and_mem_rmw() {
        assert_eq!(enc(|a| a.call(RAX)), vec![0xff, 0xd0]);
        assert_eq!(enc(|a| a.call(11)), vec![0x41, 0xff, 0xd3]);
        assert_eq!(enc(|a| a.sub_mem1(RSI, 0)), vec![0x48, 0x83, 0x2e, 0x01]);
        assert_eq!(enc(|a| a.sub_mem1(9, 0)), vec![0x49, 0x83, 0x29, 0x01]);
        assert_eq!(
            enc(|a| a.sub_mem1(RBP, 0x30)),
            vec![0x48, 0x83, 0x6d, 0x30, 0x01]
        );
        assert_eq!(
            enc(|a| a.sub_mem1(R12, 0)),
            vec![0x49, 0x83, 0x2c, 0x24, 0x01]
        );
        assert_eq!(
            enc(|a| a.sub_mem1(R13, 8)),
            vec![0x49, 0x83, 0x6d, 0x08, 0x01]
        );
        assert_eq!(
            enc(|a| a.mov_mi32(RBP, 64, 4096)),
            vec![0x48, 0xc7, 0x45, 0x40, 0x00, 0x10, 0x00, 0x00]
        );
        assert_eq!(
            enc(|a| a.mov_mi32(R12, 8, -1)),
            vec![0x49, 0xc7, 0x44, 0x24, 0x08, 0xff, 0xff, 0xff, 0xff]
        );
    }

    #[test]
    fn sse_moves() {
        assert_eq!(
            enc(|a| a.movsd_xm(XMM0, R13, 8)),
            vec![0xf2, 0x41, 0x0f, 0x10, 0x45, 0x08]
        );
        assert_eq!(
            enc(|a| a.movsd_xm(XMM1, R13, 0)),
            vec![0xf2, 0x41, 0x0f, 0x10, 0x4d, 0x00]
        );
        assert_eq!(
            enc(|a| a.movsd_xm(XMM1, R13, 4096)),
            vec![0xf2, 0x41, 0x0f, 0x10, 0x8d, 0x00, 0x10, 0x00, 0x00]
        );
        assert_eq!(
            enc(|a| a.movsd_xm(7, RBP, 0)),
            vec![0xf2, 0x0f, 0x10, 0x7d, 0x00]
        );
        assert_eq!(
            enc(|a| a.movsd_mx(R13, 8, XMM0)),
            vec![0xf2, 0x41, 0x0f, 0x11, 0x45, 0x08]
        );
        assert_eq!(
            enc(|a| a.movsd_mx(R13, 4096, 2)),
            vec![0xf2, 0x41, 0x0f, 0x11, 0x95, 0x00, 0x10, 0x00, 0x00]
        );
        assert_eq!(
            enc(|a| a.movsd_xm_sib(XMM0, RCX, RAX, 0)),
            vec![0xf2, 0x0f, 0x10, 0x04, 0xc1]
        );
        assert_eq!(
            enc(|a| a.movsd_xm_sib(XMM0, RCX, RAX, 64)),
            vec![0xf2, 0x0f, 0x10, 0x44, 0xc1, 0x40]
        );
        assert_eq!(
            enc(|a| a.movsd_xm_sib(5, R12, RCX, 16)),
            vec![0xf2, 0x41, 0x0f, 0x10, 0x6c, 0xcc, 0x10]
        );
        assert_eq!(
            enc(|a| a.movsd_mx_sib(RCX, RAX, 0, XMM0)),
            vec![0xf2, 0x0f, 0x11, 0x04, 0xc1]
        );
        assert_eq!(
            enc(|a| a.movsd_mx_sib(RCX, RAX, 64, XMM1)),
            vec![0xf2, 0x0f, 0x11, 0x4c, 0xc1, 0x40]
        );
        assert_eq!(
            enc(|a| a.movsd_mx_sib(R12, RCX, 0, 5)),
            vec![0xf2, 0x41, 0x0f, 0x11, 0x2c, 0xcc]
        );
        assert_eq!(
            enc(|a| a.movsd_mx_sib(R13, R15, 0, XMM0)),
            vec![0xf2, 0x43, 0x0f, 0x11, 0x44, 0xfd, 0x00]
        );
    }

    #[test]
    fn sse_arith() {
        assert_eq!(enc(|a| a.addsd(XMM0, XMM1)), vec![0xf2, 0x0f, 0x58, 0xc1]);
        assert_eq!(enc(|a| a.subsd(XMM0, XMM1)), vec![0xf2, 0x0f, 0x5c, 0xc1]);
        assert_eq!(enc(|a| a.mulsd(XMM0, XMM1)), vec![0xf2, 0x0f, 0x59, 0xc1]);
        assert_eq!(enc(|a| a.divsd(XMM0, XMM1)), vec![0xf2, 0x0f, 0x5e, 0xc1]);
        assert_eq!(enc(|a| a.sqrtsd(XMM0, XMM1)), vec![0xf2, 0x0f, 0x51, 0xc1]);
        assert_eq!(enc(|a| a.sqrtsd(XMM0, XMM0)), vec![0xf2, 0x0f, 0x51, 0xc0]);
        assert_eq!(enc(|a| a.ucomisd(XMM0, XMM1)), vec![0x66, 0x0f, 0x2e, 0xc1]);
        assert_eq!(
            enc(|a| a.ucomisd(9, 8)),
            vec![0x66, 0x45, 0x0f, 0x2e, 0xc8]
        );
        assert_eq!(enc(|a| a.xorpd(XMM1, XMM1)), vec![0x66, 0x0f, 0x57, 0xc9]);
        assert_eq!(enc(|a| a.xorpd(XMM0, XMM0)), vec![0x66, 0x0f, 0x57, 0xc0]);
    }

    #[test]
    fn sse_convert() {
        assert_eq!(
            enc(|a| a.cvtsi2sd(XMM0, RAX)),
            vec![0xf2, 0x48, 0x0f, 0x2a, 0xc0]
        );
        assert_eq!(
            enc(|a| a.cvtsi2sd(XMM0, 9)),
            vec![0xf2, 0x49, 0x0f, 0x2a, 0xc1]
        );
        assert_eq!(
            enc(|a| a.cvtsi2sd(XMM1, RBX)),
            vec![0xf2, 0x48, 0x0f, 0x2a, 0xcb]
        );
        assert_eq!(
            enc(|a| a.cvtsi2sd(XMM0, R15)),
            vec![0xf2, 0x49, 0x0f, 0x2a, 0xc7]
        );
        assert_eq!(
            enc(|a| a.cvtsd2ss(XMM0, XMM0)),
            vec![0xf2, 0x0f, 0x5a, 0xc0]
        );
        assert_eq!(
            enc(|a| a.cvtss2sd(XMM0, XMM0)),
            vec![0xf3, 0x0f, 0x5a, 0xc0]
        );
    }

    #[test]
    fn prefetch() {
        assert_eq!(
            enc(|a| a.prefetcht0_sib(RCX, RAX, 0)),
            vec![0x0f, 0x18, 0x0c, 0xc1]
        );
        assert_eq!(
            enc(|a| a.prefetcht0_sib(RCX, RAX, 256)),
            vec![0x0f, 0x18, 0x8c, 0xc1, 0x00, 0x01, 0x00, 0x00]
        );
        assert_eq!(
            enc(|a| a.prefetcht0_sib(8, 9, 0)),
            vec![0x43, 0x0f, 0x18, 0x0c, 0xc8]
        );
        assert_eq!(
            enc(|a| a.prefetcht0_sib(RCX, 9, 0)),
            vec![0x42, 0x0f, 0x18, 0x0c, 0xc9]
        );
        assert_eq!(
            enc(|a| a.prefetcht0_sib(RAX, RCX, 64)),
            vec![0x0f, 0x18, 0x4c, 0xc8, 0x40]
        );
    }

    #[test]
    fn unbound_label_is_error() {
        let mut a = Asm::new();
        let l = a.label();
        a.jmp(l);
        assert!(a.finish().is_err());
    }
}
