//! Executable memory for the JIT: an mmap'd buffer with a strict W^X
//! lifecycle, implemented with raw Linux syscalls so the crate stays
//! std-only (no libc dependency).
//!
//! Protocol: `mmap(PROT_READ|PROT_WRITE)` → copy code bytes →
//! `mprotect(PROT_READ|PROT_EXEC)` → execute. The buffer is never
//! writable and executable at the same time, and `munmap` runs on drop.
//! Every failure is surfaced as `Err(String)` so callers can fall back
//! to the VM tier instead of aborting.

use std::arch::asm;

const SYS_MMAP: i64 = 9;
const SYS_MPROTECT: i64 = 10;
const SYS_MUNMAP: i64 = 11;

const PROT_READ: i64 = 1;
const PROT_WRITE: i64 = 2;
const PROT_EXEC: i64 = 4;
const MAP_PRIVATE: i64 = 2;
const MAP_ANONYMOUS: i64 = 0x20;

const PAGE: usize = 4096;

/// `syscall` returns a negative errno in rax on failure; the kernel
/// reserves the top 4095 values of the address space for that encoding.
fn syscall_failed(ret: i64) -> Option<i64> {
    if (ret as u64) >= (-4095i64) as u64 {
        Some(-ret)
    } else {
        None
    }
}

#[inline]
unsafe fn sys3(n: i64, a: i64, b: i64, c: i64) -> i64 {
    let ret: i64;
    asm!(
        "syscall",
        inlateout("rax") n => ret,
        in("rdi") a,
        in("rsi") b,
        in("rdx") c,
        lateout("rcx") _,
        lateout("r11") _,
        options(nostack),
    );
    ret
}

#[inline]
unsafe fn sys6(n: i64, a: i64, b: i64, c: i64, d: i64, e: i64, f: i64) -> i64 {
    let ret: i64;
    asm!(
        "syscall",
        inlateout("rax") n => ret,
        in("rdi") a,
        in("rsi") b,
        in("rdx") c,
        in("r10") d,
        in("r8") e,
        in("r9") f,
        lateout("rcx") _,
        lateout("r11") _,
        options(nostack),
    );
    ret
}

/// An executable code buffer. Immutable (RX) once constructed.
pub struct ExecBuf {
    ptr: *mut u8,
    len: usize,
}

// The mapping is read+execute only after construction and freed only in
// `drop`; sharing the raw pointer across threads is sound.
unsafe impl Send for ExecBuf {}
unsafe impl Sync for ExecBuf {}

impl ExecBuf {
    /// Map `code` into fresh executable memory (W^X: written while RW,
    /// flipped to RX before the pointer is ever handed out).
    pub fn map(code: &[u8]) -> Result<ExecBuf, String> {
        if code.is_empty() {
            return Err("empty code buffer".into());
        }
        let len = code.len().div_ceil(PAGE) * PAGE;
        let ptr = unsafe {
            sys6(
                SYS_MMAP,
                0,
                len as i64,
                PROT_READ | PROT_WRITE,
                MAP_PRIVATE | MAP_ANONYMOUS,
                -1,
                0,
            )
        };
        if let Some(errno) = syscall_failed(ptr) {
            return Err(format!("mmap failed (errno {errno})"));
        }
        let ptr = ptr as *mut u8;
        unsafe {
            std::ptr::copy_nonoverlapping(code.as_ptr(), ptr, code.len());
        }
        let rc = unsafe { sys3(SYS_MPROTECT, ptr as i64, len as i64, PROT_READ | PROT_EXEC) };
        if let Some(errno) = syscall_failed(rc) {
            unsafe { sys3(SYS_MUNMAP, ptr as i64, len as i64, 0) };
            return Err(format!("mprotect(PROT_EXEC) failed (errno {errno})"));
        }
        Ok(ExecBuf { ptr, len })
    }

    /// Pointer to the code at byte offset `off`.
    ///
    /// # Safety-relevant contract
    /// The caller transmutes this into a function pointer; `off` must be
    /// the start of a function emitted into this buffer.
    pub fn at(&self, off: usize) -> *const u8 {
        debug_assert!(off < self.len);
        unsafe { self.ptr.add(off) }
    }
}

impl Drop for ExecBuf {
    fn drop(&mut self) {
        unsafe { sys3(SYS_MUNMAP, self.ptr as i64, self.len as i64, 0) };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_and_execute_trivial_fn() {
        // movabs rax, 0x51C0DE; ret
        let mut code = vec![0x48, 0xb8];
        code.extend_from_slice(&0x51C0DEi64.to_le_bytes());
        code.push(0xc3);
        let buf = ExecBuf::map(&code).expect("map");
        let f: extern "C" fn() -> i64 = unsafe { std::mem::transmute(buf.at(0)) };
        assert_eq!(f(), 0x51C0DE);
    }

    #[test]
    fn empty_code_rejected() {
        assert!(ExecBuf::map(&[]).is_err());
    }
}
