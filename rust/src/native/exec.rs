//! The native program: compiled blocks plus a tree walker that mirrors
//! [`crate::exec::vm`] step for step.
//!
//! The loop *tree* (bounds evaluation, sequential/DOALL/DOACROSS
//! dispatch, fuel sharing, privatization) stays in Rust and reuses the
//! VM's `Frame`; only the flat bytecode blocks — where all the
//! iteration time goes — run as machine code. This keeps the two tiers'
//! observable semantics identical by construction: same iteration
//! order, same fuel accounting, same trap kinds and payloads, same
//! parallel synchronization (the DOALL chunking and DOACROSS
//! wait/release protocol are literal mirrors of `exec::parallel`).

use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};

use anyhow::Result;

use crate::exec::values::{Frame, Storage};
use crate::exec::vm::{ExecLimits, VmRun};
use crate::exec::Trap;
use crate::lowering::bytecode::{CodeBlock, ExecNode, ExecProgram, ExecSchedule, LoopExec};
use crate::symbolic::{ContainerId, Sym};

use super::asm::Asm;
use super::emit::emit_block;
use super::mem::ExecBuf;
use super::runtime::{BlockFn, NativeCtx, RC_FUEL, RC_OK, RC_OOB, RC_TIME};

/// Sentinel for an empty block: skipped at run time instead of paying a
/// call into a function that would do nothing (the VM's interpreter
/// loop falls straight through on empty op lists).
const NO_BLOCK: usize = usize::MAX;

/// Mirror of [`ExecNode`] with blocks resolved to function indices.
enum NNode {
    Code(usize),
    Loop(Box<NLoop>),
}

/// Mirror of [`LoopExec`].
struct NLoop {
    var_reg: u16,
    start: usize,
    start_reg: u16,
    end: usize,
    end_reg: u16,
    stride: usize,
    stride_reg: u16,
    schedule: ExecSchedule,
    body: Vec<NNode>,
    pre_body: usize,
    prefetch: usize,
    post_body: usize,
    post_loop: usize,
}

/// A fully-compiled native program. Holds the executable buffer for its
/// lifetime; the block function pointers index into it.
pub struct NativeProgram {
    fns: Vec<BlockFn>,
    root: Vec<NNode>,
    _buf: ExecBuf,
}

struct Compiler {
    asm: Asm,
    offsets: Vec<usize>,
}

impl Compiler {
    fn block(&mut self, b: &CodeBlock) -> Result<usize, String> {
        if b.ops.is_empty() {
            return Ok(NO_BLOCK);
        }
        let off = emit_block(&mut self.asm, &b.ops)?;
        self.offsets.push(off);
        Ok(self.offsets.len() - 1)
    }

    fn nodes(&mut self, nodes: &[ExecNode]) -> Result<Vec<NNode>, String> {
        nodes.iter().map(|n| self.node(n)).collect()
    }

    fn node(&mut self, n: &ExecNode) -> Result<NNode, String> {
        match n {
            ExecNode::Code(b) => Ok(NNode::Code(self.block(b)?)),
            ExecNode::Loop(l) => Ok(NNode::Loop(Box::new(self.tree_loop(l)?))),
        }
    }

    fn tree_loop(&mut self, l: &LoopExec) -> Result<NLoop, String> {
        Ok(NLoop {
            var_reg: l.var_reg,
            start: self.block(&l.start)?,
            start_reg: l.start_reg,
            end: self.block(&l.end)?,
            end_reg: l.end_reg,
            stride: self.block(&l.stride)?,
            stride_reg: l.stride_reg,
            schedule: l.schedule.clone(),
            body: self.nodes(&l.body)?,
            pre_body: self.block(&l.pre_body)?,
            prefetch: self.block(&l.prefetch)?,
            post_body: self.block(&l.post_body)?,
            post_loop: self.block(&l.post_loop)?,
        })
    }
}

impl NativeProgram {
    /// Compile every block of `prog` into one executable buffer.
    /// `Err` means the program (or host) is outside what the backend
    /// supports — callers fall back to the VM tier.
    pub fn compile(prog: &ExecProgram) -> Result<NativeProgram, String> {
        if !super::available() {
            return Err("native tier unavailable on this host".into());
        }
        let mut c = Compiler {
            asm: Asm::new(),
            offsets: Vec::new(),
        };
        let root = c.nodes(&prog.root)?;
        let Compiler { asm, offsets } = c;
        if offsets.is_empty() {
            // Degenerate but valid: a program with no code at all.
            return Ok(NativeProgram {
                fns: Vec::new(),
                root,
                _buf: ExecBuf::map(&[0xc3])?,
            });
        }
        let code = asm.finish()?;
        let buf = ExecBuf::map(&code)?;
        let fns = offsets
            .iter()
            .map(|&off| unsafe { std::mem::transmute::<*const u8, BlockFn>(buf.at(off)) })
            .collect();
        Ok(NativeProgram {
            fns,
            root,
            _buf: buf,
        })
    }

    /// Run under limits — the native counterpart of
    /// `Vm::run_limited`, with identical storage allocation, fuel
    /// accounting, and trap surfacing (including the container-name
    /// context on bounds traps).
    pub fn run_limited(
        &self,
        prog: &ExecProgram,
        params: &[(Sym, i64)],
        inputs: &[(ContainerId, &[f64])],
        threads: usize,
        limits: &ExecLimits,
    ) -> Result<VmRun> {
        let mut storage = Storage::allocate(prog, params)?;
        for (c, data) in inputs {
            storage.set(*c, data)?;
        }
        let lens: Vec<usize> = storage.arrays.iter().map(|a| a.len()).collect();
        let mut frame = Frame::new(prog, &mut storage, params);
        let initial_fuel = match limits.fuel {
            Some(f) => {
                frame.metered = true;
                i64::try_from(f).unwrap_or(i64::MAX).max(1)
            }
            None => i64::MAX,
        };
        frame.fuel = initial_fuel;
        frame.deadline = limits.wall.map(|w| std::time::Instant::now() + w);
        let res = self.exec_nnodes(prog, &self.root, &mut frame, &lens, threads);
        let fuel_used = initial_fuel.saturating_sub(frame.fuel.max(0)) as u64;
        drop(frame);
        match res {
            Ok(()) => Ok(VmRun { storage, fuel_used }),
            Err(trap @ Trap::OutOfBounds { cont, .. }) => {
                let name = prog
                    .containers
                    .get(cont as usize)
                    .map(|c| c.name.clone())
                    .unwrap_or_else(|| format!("#{cont}"));
                Err(anyhow::Error::new(trap).context(format!("in container `{name}`")))
            }
            Err(trap) => Err(anyhow::Error::new(trap)),
        }
    }

    /// Invoke one compiled block on `frame`.
    fn call(&self, idx: usize, frame: &mut Frame) -> Result<(), Trap> {
        if idx == NO_BLOCK {
            return Ok(());
        }
        let mut ctx = NativeCtx {
            ints: frame.ints.as_mut_ptr(),
            floats: frame.floats.as_mut_ptr(),
            bases: frame.bases.as_ptr(),
            lens: frame.lens.as_ptr(),
            fuel: &mut frame.fuel,
            deadline: &frame.deadline as *const Option<std::time::Instant> as *const u8,
            tick: frame.tick as i64,
            trap_cont: 0,
            trap_index: 0,
            trap_len: 0,
        };
        // Safety: the block was compiled for this program shape; all
        // pointers are live for the duration of the call, and the
        // emitted code only indexes register files within `n_int` /
        // `n_float` and containers through the checked `bases`/`lens`.
        let rc = unsafe { (self.fns[idx])(&mut ctx) };
        frame.tick = ctx.tick as u32;
        match rc {
            RC_OK => Ok(()),
            RC_OOB => Err(Trap::OutOfBounds {
                cont: ctx.trap_cont as u16,
                index: ctx.trap_index,
                len: ctx.trap_len as usize,
            }),
            RC_FUEL => Err(Trap::FuelExhausted),
            RC_TIME => Err(Trap::TimeLimit),
            other => unreachable!("native block returned unknown code {other}"),
        }
    }

    fn exec_nnodes(
        &self,
        prog: &ExecProgram,
        nodes: &[NNode],
        frame: &mut Frame,
        lens: &[usize],
        threads: usize,
    ) -> Result<(), Trap> {
        for n in nodes {
            match n {
                NNode::Code(idx) => self.call(*idx, frame)?,
                NNode::Loop(l) => self.exec_loop(prog, l, frame, lens, threads)?,
            }
        }
        Ok(())
    }

    fn exec_loop(
        &self,
        prog: &ExecProgram,
        l: &NLoop,
        frame: &mut Frame,
        lens: &[usize],
        threads: usize,
    ) -> Result<(), Trap> {
        self.call(l.start, frame)?;
        let start_val = frame.ints[l.start_reg as usize];
        self.call(l.end, frame)?;
        let end_val = frame.ints[l.end_reg as usize];

        let effective_threads = match l.schedule {
            ExecSchedule::Seq => 1,
            _ => threads,
        };

        if effective_threads <= 1 {
            let mut v = start_val;
            loop {
                frame.ints[l.var_reg as usize] = v;
                self.call(l.stride, frame)?;
                let s = frame.ints[l.stride_reg as usize];
                if s == 0 || (s > 0 && v >= end_val) || (s < 0 && v <= end_val) {
                    break;
                }
                frame.backedge()?;
                self.call(l.pre_body, frame)?;
                self.call(l.prefetch, frame)?;
                self.exec_nnodes(prog, &l.body, frame, lens, threads)?;
                self.call(l.post_body, frame)?;
                v += s;
            }
            self.call(l.post_loop, frame)?;
            return Ok(());
        }

        match &l.schedule {
            ExecSchedule::Par => {
                self.run_par(prog, l, frame, lens, start_val, end_val, threads)?;
                self.call(l.post_loop, frame)?;
            }
            ExecSchedule::Doacross {
                waits,
                release_after,
            } => {
                self.run_doacross(
                    prog,
                    l,
                    frame,
                    lens,
                    start_val,
                    end_val,
                    threads,
                    waits,
                    *release_after,
                )?;
                self.call(l.post_loop, frame)?;
            }
            ExecSchedule::Seq => unreachable!(),
        }
        Ok(())
    }

    /// Mirror of `exec::parallel::stride_and_trip_count`.
    fn stride_and_trip_count(
        &self,
        l: &NLoop,
        frame: &mut Frame,
        start_val: i64,
        end_val: i64,
    ) -> Result<(i64, usize), Trap> {
        frame.ints[l.var_reg as usize] = start_val;
        self.call(l.stride, frame)?;
        let s = frame.ints[l.stride_reg as usize];
        let count: u128 = if s > 0 && start_val < end_val {
            let span = (end_val as i128 - start_val as i128) as u128;
            span.div_ceil(s as u128)
        } else if s < 0 && start_val > end_val {
            let span = (start_val as i128 - end_val as i128) as u128;
            span.div_ceil((s as i128).unsigned_abs())
        } else {
            0
        };
        Ok((s, usize::try_from(count).unwrap_or(usize::MAX)))
    }

    /// Mirror of `exec::parallel::run_par` (DOALL), calling compiled
    /// blocks instead of the interpreter.
    #[allow(clippy::too_many_arguments)]
    fn run_par(
        &self,
        prog: &ExecProgram,
        l: &NLoop,
        frame: &mut Frame,
        lens: &[usize],
        start_val: i64,
        end_val: i64,
        threads: usize,
    ) -> Result<(), Trap> {
        let (s, count) = self.stride_and_trip_count(l, frame, start_val, end_val)?;
        if count == 0 {
            return Ok(());
        }
        let nthreads = threads.min(count).max(1);
        let chunk = count.div_ceil(nthreads);
        let share = fuel_share(frame, nthreads);
        let mut results: Vec<Result<i64, Trap>> = Vec::new();
        let mut handed_out = 0usize;
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for t in 0..nthreads {
                let lo = t * chunk;
                let hi = ((t + 1) * chunk).min(count);
                if lo >= hi {
                    continue;
                }
                let mut my_frame = frame.fork(prog, lens);
                my_frame.fuel = share;
                handed_out += 1;
                handles.push(scope.spawn(move || -> Result<i64, Trap> {
                    for idx in lo..hi {
                        let v = start_val + (idx as i64) * s;
                        my_frame.ints[l.var_reg as usize] = v;
                        my_frame.backedge()?;
                        self.call(l.pre_body, &mut my_frame)?;
                        self.call(l.prefetch, &mut my_frame)?;
                        self.exec_nnodes(prog, &l.body, &mut my_frame, lens, 1)?;
                        self.call(l.post_body, &mut my_frame)?;
                    }
                    Ok(my_frame.fuel)
                }));
            }
            for h in handles {
                results.push(h.join().expect("parallel worker panicked"));
            }
        });
        settle(frame, share, handed_out, results)
    }

    /// Mirror of `exec::parallel::run_doacross`: round-robin iteration
    /// assignment with per-iteration release flags and abort polling.
    #[allow(clippy::too_many_arguments)]
    fn run_doacross(
        &self,
        prog: &ExecProgram,
        l: &NLoop,
        frame: &mut Frame,
        lens: &[usize],
        start_val: i64,
        end_val: i64,
        threads: usize,
        waits: &[(usize, i64)],
        release_after: Option<usize>,
    ) -> Result<(), Trap> {
        let (s, count) = self.stride_and_trip_count(l, frame, start_val, end_val)?;
        if count == 0 {
            return Ok(());
        }
        let nthreads = threads.min(count).max(1);
        let flags: Vec<AtomicU8> = (0..count).map(|_| AtomicU8::new(0)).collect();
        let flags = &flags;
        let aborted = AtomicBool::new(false);
        let aborted = &aborted;
        let share = fuel_share(frame, nthreads);
        let mut results: Vec<Result<i64, Trap>> = Vec::new();

        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for tid in 0..nthreads {
                let mut my_frame = frame.fork(prog, lens);
                my_frame.fuel = share;
                handles.push(scope.spawn(move || -> Result<i64, Trap> {
                    let mut t = tid;
                    let mut run = || -> Result<i64, Trap> {
                        while t < count {
                            let v = start_val + (t as i64) * s;
                            my_frame.ints[l.var_reg as usize] = v;
                            my_frame.backedge()?;
                            self.call(l.pre_body, &mut my_frame)?;
                            self.call(l.prefetch, &mut my_frame)?;
                            for (ei, node) in l.body.iter().enumerate() {
                                for (w_elem, delta) in waits {
                                    if *w_elem == ei && t as i64 - delta >= 0 {
                                        let target = t - *delta as usize;
                                        while flags[target].load(Ordering::Acquire) == 0 {
                                            if aborted.load(Ordering::Acquire) {
                                                return Ok(my_frame.fuel);
                                            }
                                            std::thread::yield_now();
                                        }
                                    }
                                }
                                self.exec_nnodes(
                                    prog,
                                    std::slice::from_ref(node),
                                    &mut my_frame,
                                    lens,
                                    1,
                                )?;
                                if release_after == Some(ei) {
                                    flags[t].store(1, Ordering::Release);
                                }
                            }
                            self.call(l.post_body, &mut my_frame)?;
                            if release_after.is_none() {
                                flags[t].store(1, Ordering::Release);
                            }
                            t += nthreads;
                        }
                        Ok(my_frame.fuel)
                    };
                    let out = run();
                    if out.is_err() {
                        aborted.store(true, Ordering::Release);
                    }
                    out
                }));
            }
            for h in handles {
                results.push(h.join().expect("doacross worker panicked"));
            }
        });
        settle(frame, share, nthreads, results)
    }
}

/// Mirror of `exec::parallel::fuel_share`.
fn fuel_share(frame: &Frame, nthreads: usize) -> i64 {
    if frame.metered {
        frame.fuel.max(0) / nthreads as i64
    } else {
        i64::MAX
    }
}

/// Mirror of `exec::parallel::settle`.
fn settle(
    frame: &mut Frame,
    share: i64,
    shares_handed_out: usize,
    results: Vec<Result<i64, Trap>>,
) -> Result<(), Trap> {
    if frame.metered {
        let distributed = share.saturating_mul(shares_handed_out as i64);
        let mut remaining = frame.fuel.saturating_sub(distributed);
        for r in &results {
            if let Ok(leftover) = r {
                remaining = remaining.saturating_add((*leftover).max(0));
            }
        }
        frame.fuel = remaining;
    }
    for r in results {
        r?;
    }
    Ok(())
}
