//! Memory schedules (paper §4): per-access properties realized at
//! lowering, never by rewriting the loop tree — "a memory schedule does
//! not directly modify the IR".
//!
//! Two schedules are implemented:
//!
//! * **software prefetch** ([`prefetch`], §4.1) — hints placed where the
//!   hardware stream prefetcher mispredicts (stride discontinuities at
//!   tile/window boundaries), parameterized by a prefetch *distance*;
//! * **pointer incrementation** ([`ptr_inc`], §4.2) — per-access offset
//!   arithmetic replaced by a cursor with per-loop increment/reset
//!   deltas, scheduled program-wide ([`schedule_all_ptr_inc`]) or per
//!   nest ([`schedule_ptr_inc_in`]).
//!
//! Both are ordinary pipeline stages (`transforms::pipeline`), optionally
//! gated by the `machine::cost` model, and both are axes of the
//! autotuner's search space (`tuner::space`): the tuner picks the
//! prefetch distance and the per-nest ptr-inc plans the cost model
//! favors.

pub mod prefetch;
pub mod ptr_inc;

pub use prefetch::{
    clear_prefetches, hinted_loops, schedule_prefetches, schedule_prefetches_dist,
};
pub use ptr_inc::{
    all_plans, plan_ptr_inc, schedule_all_ptr_inc, schedule_ptr_inc_in, LoopDelta, PtrPlan,
};
