//! Memory schedules (paper §4): per-access properties realized at lowering
//! — software prefetch hints and pointer incrementation.

pub mod prefetch;
pub mod ptr_inc;

pub use prefetch::{clear_prefetches, hinted_loops, schedule_prefetches};
pub use ptr_inc::{all_plans, plan_ptr_inc, schedule_all_ptr_inc, LoopDelta, PtrPlan};
