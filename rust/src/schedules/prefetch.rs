//! Automatic software-prefetch placement (paper §4.1).
//!
//! Hardware stream prefetchers learn constant strides but mispredict at
//! *sudden* stride changes. §4.1.2's rule: such a change happens whenever a
//! data access uses a loop variable `w` whose loop's **starting value
//! depends on a surrounding loop's variable** (tiled loops, sliding
//! windows, Fig. 6). The fix: at the top of each iteration of the
//! surrounding loop `S`, prefetch the address of the *first* access a
//! later `S`-iteration will make — offset obtained by substituting inner
//! vars with their start expressions and `S`'s var with
//! `var + dist·stride`. The paper's rule is distance 1
//! ([`schedule_prefetches`]); the autotuner also searches larger
//! distances ([`schedule_prefetches_dist`]) to cover deeper memory
//! tiers.

use crate::ir::{Loop, LoopSchedule, Node, PrefetchHint, Program};
use crate::symbolic::{subs, ContainerId, Expr};

/// Generate prefetch hints for the whole program at distance 1 (the next
/// iteration of the hint-hosting loop). Returns hints added.
pub fn schedule_prefetches(p: &mut Program) -> usize {
    schedule_prefetches_dist(p, 1)
}

/// Generate prefetch hints for the whole program, targeting `dist`
/// iterations of the hint-hosting loop ahead. Returns hints added.
///
/// Rule (§4.1.2): a stride discontinuity happens at loop `W` when `W`'s
/// starting value depends on any surrounding loop variable (tiled loops,
/// sliding windows, staged tile copies). The hint goes on `W`'s *parent*
/// loop — "the lowest one in the hierarchy (closest to the access)" — and
/// prefetches where the first access of the parent's `dist`-away
/// iteration will land: `W`-subtree variables replaced by their starts,
/// the parent's variable shifted by `dist` strides. Distance 1 is §4.1.2
/// verbatim; the autotuner searches larger distances to cover deeper
/// memory tiers. Parallel parents are skipped.
pub fn schedule_prefetches_dist(p: &mut Program, dist: i64) -> usize {
    let mut hints: Vec<PrefetchHint> = Vec::new();
    // Walk every statement with its enclosing loop chain.
    fn walk<'a>(
        nodes: &'a [Node],
        chain: &mut Vec<&'a Loop>,
        p: &Program,
        dist: i64,
        hints: &mut Vec<PrefetchHint>,
    ) {
        for n in nodes {
            match n {
                Node::Stmt(st) => {
                    let mut consider = |c: ContainerId, off: &Expr, is_write: bool| {
                        hint_for_access(c, off, is_write, chain, p, dist, hints);
                    };
                    for r in st.reads() {
                        consider(r.container, &r.offset, false);
                    }
                    consider(st.write.container, &st.write.offset, true);
                }
                Node::Loop(l) => {
                    chain.push(l);
                    walk(&l.body, chain, p, dist, hints);
                    chain.pop();
                }
            }
        }
    }
    let mut chain = Vec::new();
    walk(&p.body, &mut chain, p, dist.max(1), &mut hints);
    // Deduplicate (same loop, container, offset).
    hints.dedup_by(|a, b| {
        a.at_loop == b.at_loop && a.container == b.container && a.offset == b.offset
    });
    let mut added = 0;
    for h in hints {
        if !p
            .schedules
            .prefetches
            .iter()
            .any(|e| e.at_loop == h.at_loop && e.container == h.container && e.offset == h.offset)
        {
            p.schedules.prefetches.push(h);
            added += 1;
        }
    }
    added
}

/// One hint per access (§4.1.2): `W` = the innermost enclosing loop whose
/// variable the offset uses; a stride discontinuity exists when `W`'s
/// start depends on a surrounding loop variable. The hint goes on `W`'s
/// parent ("the lowest one in the hierarchy, closest to the access") and
/// targets the first access of the parent's `dist`-away iteration.
fn hint_for_access(
    c: ContainerId,
    off: &Expr,
    is_write: bool,
    chain: &[&Loop],
    p: &Program,
    dist: i64,
    hints: &mut Vec<PrefetchHint>,
) {
    // Small constant-size buffers (staged tiles) live in cache — never
    // worth a hint.
    if let Some(n) = p.container(c).size.as_int() {
        if n <= 4096 {
            return;
        }
    }
    // Innermost involved loop W and its position.
    let Some(wpos) = chain.iter().rposition(|l| off.depends_on(l.var)) else {
        return;
    };
    if wpos == 0 {
        return; // no parent to host the hint
    }
    let w = chain[wpos];
    let parent = chain[wpos - 1];
    // Discontinuity: W's start depends on some enclosing loop variable.
    if !chain[..wpos].iter().any(|l| w.start.depends_on(l.var)) {
        return;
    }
    if !matches!(parent.schedule, LoopSchedule::Sequential) {
        return; // §4.1.2: parallel loops get no hints
    }
    // Offset of the first access in the parent's dist-away iteration:
    // W → its start, then parent.var → parent.var + dist·stride.
    let at_start = subs(off, w.var, &w.start);
    let step = if dist == 1 {
        parent.stride.clone()
    } else {
        Expr::Int(dist) * parent.stride.clone()
    };
    let next = subs(&at_start, parent.var, &(Expr::Sym(parent.var) + step));
    hints.push(PrefetchHint {
        at_loop: parent.id,
        container: c,
        offset: next,
        for_write: is_write,
    });
}

/// Convenience for experiments: strip all prefetch hints (the "No
/// Prefetch" column of Table 1).
pub fn clear_prefetches(p: &mut Program) {
    p.schedules.prefetches.clear();
}

/// Which loops carry at least one hint (reporting).
pub fn hinted_loops(p: &Program) -> Vec<crate::ir::LoopId> {
    let mut out = Vec::new();
    for h in &p.schedules.prefetches {
        if !out.contains(&h.at_loop) {
            out.push(h.at_loop);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::ProgramBuilder;
    use crate::symbolic::{int, load, sym_eq};

    /// Fig. 6 shape: for i { for j = START_J(i): A[g(j)] } — hint on the
    /// i loop, offset at next i's first j.
    #[test]
    fn tiled_start_triggers_hint() {
        let mut b = ProgramBuilder::new("pf1");
        let n = b.param_positive("pf1_N");
        let a = b.array("A", Expr::Sym(n) * int(4) + int(64));
        let o = b.array("O", Expr::Sym(n) * int(4) + int(64));
        let i = b.sym("pf1_i");
        let j = b.sym("pf1_j");
        let il = b.for_id(i, int(0), Expr::Sym(n), int(1), |b| {
            // j starts at 4*i — start depends on i (tile transition).
            b.for_(j, int(4) * Expr::Sym(i), int(4) * Expr::Sym(i) + int(4), int(1), |b| {
                b.assign(o, Expr::Sym(j), load(a, Expr::Sym(j) * int(2)));
            });
        });
        let mut p = b.finish();
        let added = schedule_prefetches(&mut p);
        assert!(added >= 1, "expected at least the A hint");
        let h = p
            .schedules
            .prefetches
            .iter()
            .find(|h| h.container == a)
            .unwrap();
        assert_eq!(h.at_loop, il);
        assert!(!h.for_write);
        // offset: j→4i, then i→i+1 ⇒ 2*(4(i+1)) = 8i + 8.
        let expect = int(8) * Expr::Sym(i) + int(8);
        assert!(sym_eq(&h.offset, &expect), "got {}", h.offset);
    }

    /// Distance-`d` hints land `d` parent strides ahead of the distance-1
    /// target.
    #[test]
    fn prefetch_distance_scales_the_target() {
        let build = || {
            let mut b = ProgramBuilder::new("pf5");
            let n = b.param_positive("pf5_N");
            let a = b.array("A", Expr::Sym(n) * int(4) + int(64));
            let o = b.array("O", Expr::Sym(n) * int(4) + int(64));
            let i = b.sym("pf5_i");
            let j = b.sym("pf5_j");
            b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
                b.for_(j, int(4) * Expr::Sym(i), int(4) * Expr::Sym(i) + int(4), int(1), |b| {
                    b.assign(o, Expr::Sym(j), load(a, Expr::Sym(j) * int(2)));
                });
            });
            (b.finish(), a, i)
        };
        let (mut p, a, i) = build();
        assert!(schedule_prefetches_dist(&mut p, 3) >= 1);
        let h = p
            .schedules
            .prefetches
            .iter()
            .find(|h| h.container == a)
            .unwrap();
        // offset: j→4i, then i→i+3 ⇒ 2·4(i+3) = 8i + 24.
        let expect = int(8) * Expr::Sym(i) + int(24);
        assert!(sym_eq(&h.offset, &expect), "got {}", h.offset);
    }

    /// Plain rectangular nest: no start-dependency ⇒ no hints.
    #[test]
    fn rectangular_nest_no_hints() {
        let mut b = ProgramBuilder::new("pf2");
        let n = b.param_positive("pf2_N");
        let a = b.array("A", Expr::Sym(n) * Expr::Sym(n));
        let i = b.sym("pf2_i");
        let j = b.sym("pf2_j");
        b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
            b.for_(j, int(0), Expr::Sym(n), int(1), |b| {
                b.assign(a, Expr::Sym(i) * Expr::Sym(n) + Expr::Sym(j), Expr::real(1.0));
            });
        });
        let mut p = b.finish();
        assert_eq!(schedule_prefetches(&mut p), 0);
    }

    /// Parallel surrounding loop ⇒ hint omitted (§4.1.2).
    #[test]
    fn parallel_loop_skipped() {
        use crate::ir::LoopSchedule;
        let mut b = ProgramBuilder::new("pf3");
        let n = b.param_positive("pf3_N");
        let a = b.array("A", Expr::Sym(n) * int(8));
        let i = b.sym("pf3_i");
        let j = b.sym("pf3_j");
        let il = b.for_id(i, int(0), Expr::Sym(n), int(1), |b| {
            b.for_(j, int(4) * Expr::Sym(i), int(4) * Expr::Sym(i) + int(4), int(1), |b| {
                b.assign(a, Expr::Sym(j), Expr::real(1.0));
            });
        });
        let mut p = b.finish();
        p.visit_mut(&mut |n| {
            if let Node::Loop(l) = n {
                if l.id == il {
                    l.schedule = LoopSchedule::Parallel;
                }
            }
        });
        assert_eq!(schedule_prefetches(&mut p), 0);
    }

    /// Tiling a loop then scheduling produces a tile-boundary hint — the
    /// Table 1 mechanism.
    #[test]
    fn tiling_then_prefetch() {
        let mut b = ProgramBuilder::new("pf4");
        let n = b.param_positive("pf4_N");
        let a = b.array("A", Expr::Sym(n));
        let o = b.array("O", Expr::Sym(n));
        let i = b.sym("pf4_i");
        let il = b.for_id(i, int(0), Expr::Sym(n), int(1), |b| {
            b.assign(o, Expr::Sym(i), load(a, Expr::Sym(i)));
        });
        let mut p = b.finish();
        let tile_loop = crate::transforms::tile(&mut p, il, 64).unwrap();
        let added = schedule_prefetches(&mut p);
        assert!(added >= 1);
        assert!(p.schedules.prefetches.iter().all(|h| h.at_loop == tile_loop));
    }
}
