//! Pointer-incrementation memory schedule (paper §4.2).
//!
//! For a scheduled access `D[f]` the lowering replaces per-access offset
//! arithmetic with a *cursor*: initialized once (§4.2.1), incremented by
//! `Δᵢ = f(var + stride) − f(var)` at the end of each involved loop
//! iteration (§4.2.2), reset by `Δᵣ = f(end) − f(start)` when an inner
//! involved loop completes, and dereferenced with a constant offset
//! (§4.2.3) when several accesses sit a compile-time-constant distance
//! apart.

use anyhow::{bail, Result};

use crate::ir::{LoopId, LoopSchedule, Program, Stmt, StmtId};
use crate::symbolic::{poly_diff, shift, simplify, subs, ContainerId, Expr, Sym};

/// Per-loop increment plan for one cursor.
#[derive(Debug, Clone)]
pub struct LoopDelta {
    pub loop_id: LoopId,
    /// Δᵢ: added after each iteration of this loop.
    pub inc: Expr,
    /// Δᵣ: subtracted when this loop finishes, restoring the cursor to
    /// its value before the loop entered (always emitted — enclosing
    /// uninvolved loops may re-enter the managed nest without re-running
    /// the initialization).
    pub reset: Option<Expr>,
}

/// How one access's offset relates to the cursor's base offset.
#[derive(Debug, Clone, PartialEq)]
pub enum AccessDelta {
    /// Compile-time constant distance (§4.2.3): `cursor + c` folds into
    /// the addressing mode with zero register cost.
    Const(i64),
    /// Loop-invariant symbolic distance (e.g. `±isI` in the Laplace star):
    /// lowered to `cursor + delta_reg` (x86 base+index addressing). The
    /// delta register is hoisted to program start and shared by every
    /// access with the same distance.
    Sym(Expr),
}

/// Complete lowering plan for one `(stmt, container)` ptr-inc schedule.
#[derive(Debug, Clone)]
pub struct PtrPlan {
    pub stmt: StmtId,
    pub container: ContainerId,
    /// The *base* offset expression the cursor tracks.
    pub base_offset: Expr,
    /// Cursor initialization expression: `base_offset` with every managed
    /// loop variable replaced by that loop's start expression. Evaluated at
    /// `init_at` (see below).
    pub init: Expr,
    /// Loop whose body the initialization runs at the top of; `None` means
    /// "before the outermost managed loop" (§4.2.1's placement rule,
    /// respecting parallel loops — cursors are thread-local).
    pub init_inside: Option<LoopId>,
    /// Outermost managed loop (the cursor is initialized just before it
    /// when `init_inside` is None).
    pub outermost: LoopId,
    /// Increment/reset amounts per managed (sequential, involved) loop,
    /// ordered outermost → innermost.
    pub deltas: Vec<LoopDelta>,
    /// Accesses served by this cursor: `(original offset, delta)` — each is
    /// dereferenced as `cursor + delta` (§4.2.3, extended to loop-invariant
    /// symbolic deltas).
    pub accesses: Vec<(Expr, AccessDelta)>,
}

/// Schedule every array access inside loops for pointer incrementation
/// (the paper's §6.3 methodology: "schedule all memory accesses to arrays
/// inside of loops with pointer incrementation"). Scalars and accesses
/// whose offsets mention no loop variable are skipped (nothing to
/// increment).
pub fn schedule_all_ptr_inc(p: &mut Program) -> usize {
    schedule_ptr_inc_filtered(p, None)
}

/// Schedule pointer incrementation only for statements nested (at any
/// depth) inside loop `root` — the per-nest granularity the autotuner's
/// refinement decides at. Returns marks added.
pub fn schedule_ptr_inc_in(p: &mut Program, root: LoopId) -> usize {
    schedule_ptr_inc_filtered(p, Some(root))
}

fn schedule_ptr_inc_filtered(p: &mut Program, root: Option<LoopId>) -> usize {
    let mut added = 0;
    let stmt_parents = p.stmt_parents();
    let mut marks: Vec<(StmtId, ContainerId)> = Vec::new();
    for s in p.stmts() {
        let Some(chain) = stmt_parents.get(&s.id) else {
            continue;
        };
        if chain.is_empty() {
            continue;
        }
        if let Some(r) = root {
            if !chain.contains(&r) {
                continue;
            }
        }
        let loop_vars: Vec<Sym> = chain
            .iter()
            .filter_map(|lid| p.find_loop(*lid).map(|l| l.var))
            .collect();
        let mut containers: Vec<ContainerId> = Vec::new();
        let mut consider = |c: ContainerId, off: &Expr| {
            if p.container(c).is_scalar() {
                return;
            }
            if !loop_vars.iter().any(|v| off.depends_on(*v)) {
                return;
            }
            if !containers.contains(&c) {
                containers.push(c);
            }
        };
        consider(s.write.container, &s.write.offset);
        for r in s.reads() {
            consider(r.container, &r.offset);
        }
        for c in containers {
            if !p.schedules.has_ptr_inc(s.id, c) {
                marks.push((s.id, c));
            }
        }
    }
    for m in marks {
        p.schedules.ptr_inc.push(m);
        added += 1;
    }
    added
}

/// Compute the lowering plan for one scheduled `(stmt, container)` pair.
/// Returns `None` when the schedule is not realizable (e.g. an involved
/// loop's Δᵢ is not loop-invariant in a way we can re-evaluate) — the
/// lowering then falls back to the default schedule, which is always
/// semantically safe.
pub fn plan_ptr_inc(
    p: &Program,
    stmt_id: StmtId,
    container: ContainerId,
) -> Result<Option<PtrPlan>> {
    let Some(stmt) = p.find_stmt(stmt_id) else {
        bail!("ptr-inc plan for missing stmt s{}", stmt_id.0);
    };
    let stmt_parents = p.stmt_parents();
    let chain = stmt_parents.get(&stmt_id).cloned().unwrap_or_default();
    if chain.is_empty() {
        return Ok(None);
    }

    // All offsets this statement uses on `container`.
    let mut offsets: Vec<Expr> = Vec::new();
    if stmt.write.container == container {
        offsets.push(stmt.write.offset.clone());
    }
    for r in stmt.reads() {
        if r.container == container && !offsets.contains(&r.offset) {
            offsets.push(r.offset);
        }
    }
    if offsets.is_empty() {
        return Ok(None);
    }

    // §4.2.3: group all accesses onto one cursor. Constant distances fold
    // into the addressing mode; loop-invariant symbolic distances become
    // hoisted delta registers; anything else keeps the default path.
    let chain_vars: Vec<Sym> = chain
        .iter()
        .filter_map(|lid| p.find_loop(*lid).map(|l| l.var))
        .collect();
    let base = offsets[0].clone();
    let mut accesses: Vec<(Expr, AccessDelta)> = vec![(base.clone(), AccessDelta::Const(0))];
    for off in offsets.iter().skip(1) {
        if let Some(d) = poly_diff(off, &base) {
            let de = d.to_expr();
            if let Some(c) = d.as_constant() {
                accesses.push((off.clone(), AccessDelta::Const(c)));
            } else if !chain_vars.iter().any(|v| de.depends_on(*v)) {
                // Loop-invariant symbolic distance: hoistable.
                accesses.push((off.clone(), AccessDelta::Sym(de)));
            }
            // else: served by its own (default) access path.
        }
    }

    // Involved loops: enclosing loops whose variable appears in the base
    // offset (§4.2.1), ordered outermost → innermost.
    let involved: Vec<&crate::ir::Loop> = chain
        .iter()
        .filter_map(|lid| p.find_loop(*lid))
        .filter(|l| base.depends_on(l.var))
        .collect();
    if involved.is_empty() {
        return Ok(None);
    }

    // Managed loops: the *sequential* involved loops below the innermost
    // parallel involved loop. Parallel loop variables stay symbolic in the
    // init expression (each thread initializes its own cursor).
    let last_parallel = involved
        .iter()
        .rposition(|l| !matches!(l.schedule, LoopSchedule::Sequential));
    let managed: Vec<&crate::ir::Loop> = match last_parallel {
        Some(idx) => involved[idx + 1..].to_vec(),
        None => involved.clone(),
    };
    if managed.is_empty() {
        // Offset only depends on parallel loop vars: a cursor would never
        // be incremented — no benefit.
        return Ok(None);
    }
    let init_inside = last_parallel.map(|idx| involved[idx].id);
    let outermost = managed[0].id;

    // §4.2.1: init = base with each managed var → its loop's start expr.
    // Substitute innermost-first so starts that reference outer managed
    // vars (triangular nests) resolve too.
    let mut init = base.clone();
    for l in managed.iter().rev() {
        init = subs(&init, l.var, &l.start);
    }

    // §4.2.2: Δᵢ and Δᵣ per managed loop. Both are computed on gₘ — the
    // base offset with every *inner* managed variable substituted by its
    // loop's start expression (innermost-first). For rectangular nests
    // gₘ ≡ base; for triangular/tiled nests (inner start depends on this
    // loop's variable) the substitution folds the start shift into Δᵢ —
    // the cursor must advance by the inter-iteration distance of the
    // *first* inner access, not of the raw offset.
    let mut deltas = Vec::new();
    for (pos, l) in managed.iter().enumerate() {
        let mut g = base.clone();
        for inner in managed.iter().skip(pos + 1).rev() {
            g = subs(&g, inner.var, &inner.start);
        }
        let inc = simplify(&(shift(&g, l.var, &l.stride) - g.clone()));
        if inc.depends_on(l.var) {
            // Δᵢ varies with the iteration (non-affine in this var):
            // realizable only by re-evaluating — we bail out to the default
            // schedule for safety.
            return Ok(None);
        }
        // Δᵣ telescopes the loop's own increments: g at `end` minus g at
        // `start` (exact when the trip divides evenly — guaranteed for
        // unit strides; tiled presets keep multiples of the tile). Emitted
        // for *every* managed loop, including the outermost: an enclosing
        // uninvolved loop (gemm's j around the k loop) re-enters the
        // managed nest without re-running the initialization, so the
        // cursor must return to its pre-loop value unconditionally.
        let reset = {
            let at_end = subs(&g, l.var, &l.end);
            let at_start = subs(&g, l.var, &l.start);
            Some(simplify(&(at_end - at_start)))
        };
        deltas.push(LoopDelta {
            loop_id: l.id,
            inc,
            reset,
        });
    }

    Ok(Some(PtrPlan {
        stmt: stmt_id,
        container,
        base_offset: base,
        init,
        init_inside,
        outermost,
        deltas,
        accesses,
    }))
}

/// All realizable plans for a program's ptr-inc schedule set.
pub fn all_plans(p: &Program) -> Vec<PtrPlan> {
    let mut out = Vec::new();
    for (sid, cid) in &p.schedules.ptr_inc {
        if let Ok(Some(plan)) = plan_ptr_inc(p, *sid, *cid) {
            out.push(plan);
        }
    }
    out
}

/// Register-pressure accounting helper: how many live index temporaries the
/// *naive* offset computation of `stmt` on `container` needs vs. the
/// cursor-based schedule (cursor + constant folds). Used by the regalloc
/// model (Fig. 1 / Fig. 10 spill counts).
pub fn naive_index_temps(stmt: &Stmt, container: ContainerId) -> usize {
    let mut temps = 0;
    let mut count = |off: &Expr| {
        // One temp per multiply/add node in the offset tree (models the
        // address-computation chain the compiler must keep live).
        let mut n = 0;
        off.visit(&mut |e| match e {
            Expr::Add(xs) | Expr::Mul(xs) => n += xs.len() - 1,
            Expr::FloorDiv(..) | Expr::Mod(..) | Expr::Func(..) => n += 1,
            _ => {}
        });
        temps += n.max(1);
    };
    if stmt.write.container == container {
        count(&stmt.write.offset);
    }
    for r in stmt.reads() {
        if r.container == container {
            count(&r.offset);
        }
    }
    temps
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::ProgramBuilder;
    use crate::symbolic::{int, load};

    /// Fig. 7: A[(2+j)*SJ + 2*i*SI] inside for(i) for(j=2; j<J; ++j).
    #[test]
    fn fig7_plan() {
        let mut b = ProgramBuilder::new("pi1");
        let ii = b.param_positive("pi1_I");
        let jj = b.param_positive("pi1_J");
        let si = b.param_positive("pi1_SI");
        let sj = b.param_positive("pi1_SJ");
        let a = b.array("A", Expr::Sym(ii) * Expr::Sym(si) + Expr::Sym(jj) * Expr::Sym(sj));
        let out = b.array("Out", Expr::Sym(ii) * Expr::Sym(jj));
        let i = b.sym("pi1_i");
        let j = b.sym("pi1_j");
        let mut sid = None;
        b.for_(i, int(0), Expr::Sym(ii), int(1), |b| {
            b.for_(j, int(2), Expr::Sym(jj), int(1), |b| {
                let off = Expr::Sym(j) * Expr::Sym(sj) + int(2) * Expr::Sym(i) * Expr::Sym(si);
                sid = Some(b.assign(
                    out,
                    Expr::Sym(i) * Expr::Sym(jj) + Expr::Sym(j),
                    load(a, off),
                ));
            });
        });
        let mut p = b.finish();
        p.schedules.ptr_inc.push((sid.unwrap(), a));
        let plan = plan_ptr_inc(&p, sid.unwrap(), a).unwrap().unwrap();
        // Managed loops: i then j. Δᵢ(j-loop) = SJ, Δᵢ(i-loop) = 2*SI.
        assert_eq!(plan.deltas.len(), 2);
        assert_eq!(plan.deltas[0].inc, int(2) * Expr::Sym(si));
        assert_eq!(plan.deltas[1].inc, Expr::Sym(sj));
        // Reset of the j loop: (J - 2) * SJ.
        let expect_reset = (Expr::Sym(jj) - int(2)) * Expr::Sym(sj);
        assert_eq!(plan.deltas[1].reset.clone().unwrap(), expect_reset);
        // The outer loop now also resets (restores the pre-loop cursor).
        assert!(plan.deltas[0].reset.is_some());
        // Init: j→2, i→0 ⇒ 2*SJ.
        assert_eq!(plan.init, int(2) * Expr::Sym(sj));
    }

    /// Constant-distance accesses share a cursor (§4.2.3): the Laplace
    /// 5-point star on unit strides.
    #[test]
    fn shared_cursor_constant_offsets() {
        let mut b = ProgramBuilder::new("pi2");
        let n = b.param_positive("pi2_N");
        let a = b.array("A", (Expr::Sym(n) + int(2)) * (Expr::Sym(n) + int(2)));
        let o = b.array("O", Expr::Sym(n) * Expr::Sym(n));
        let i = b.sym("pi2_i");
        let mut sid = None;
        let w = Expr::Sym(n) + int(2);
        b.for_(i, int(1), Expr::Sym(n) + int(1), int(1), |b| {
            let c = Expr::Sym(i) * w.clone();
            sid = Some(b.assign(
                o,
                Expr::Sym(i),
                load(a, c.clone() - int(1)) + load(a, c.clone() + int(1)) + load(a, c.clone()),
            ));
        });
        let mut p = b.finish();
        p.schedules.ptr_inc.push((sid.unwrap(), a));
        let plan = plan_ptr_inc(&p, sid.unwrap(), a).unwrap().unwrap();
        assert_eq!(plan.accesses.len(), 3);
        assert!(plan
            .accesses
            .iter()
            .all(|(_, d)| matches!(d, AccessDelta::Const(_))));
    }

    /// Symbolic (loop-invariant) distances are hoistable delta registers:
    /// the Fig. 1 Laplace star with parametric strides.
    #[test]
    fn symbolic_delta_accesses_share_cursor() {
        let mut b = ProgramBuilder::new("pi5");
        let n = b.param_positive("pi5_N");
        let si = b.param_positive("pi5_SI");
        let sj = b.param_positive("pi5_SJ");
        let a = b.array("A", (Expr::Sym(n) + int(2)) * (Expr::Sym(si) + Expr::Sym(sj)));
        let o = b.array("O", Expr::Sym(n) * Expr::Sym(n));
        let i = b.sym("pi5_i");
        let j = b.sym("pi5_j");
        let mut sid = None;
        b.for_(i, int(1), Expr::Sym(n), int(1), |b| {
            b.for_(j, int(1), Expr::Sym(n), int(1), |b| {
                let at = |di: i64, dj: i64| {
                    (Expr::Sym(i) + int(di)) * Expr::Sym(si)
                        + (Expr::Sym(j) + int(dj)) * Expr::Sym(sj)
                };
                sid = Some(b.assign(
                    o,
                    Expr::Sym(i) * Expr::Sym(n) + Expr::Sym(j),
                    load(a, at(0, 0)) + load(a, at(1, 0)) + load(a, at(-1, 0))
                        + load(a, at(0, 1))
                        + load(a, at(0, -1)),
                ));
            });
        });
        let mut p = b.finish();
        p.schedules.ptr_inc.push((sid.unwrap(), a));
        let plan = plan_ptr_inc(&p, sid.unwrap(), a).unwrap().unwrap();
        // All five star points served by one cursor: one Const(0) + four
        // symbolic deltas (±SI, ±SJ).
        assert_eq!(plan.accesses.len(), 5);
        let sym_count = plan
            .accesses
            .iter()
            .filter(|(_, d)| matches!(d, AccessDelta::Sym(_)))
            .count();
        assert_eq!(sym_count, 4);
    }

    /// Variable-stride loop (Fig. 2, `i += i`): Δᵢ depends on the variable —
    /// plan falls back to None (default schedule).
    #[test]
    fn variable_stride_unrealizable() {
        let mut b = ProgramBuilder::new("pi3");
        let n = b.param_positive("pi3_N");
        let a = b.array("A", Expr::Sym(n));
        let i = b.sym("pi3_i");
        let mut sid = None;
        b.for_(i, int(1), Expr::Sym(n), Expr::Sym(i), |b| {
            sid = Some(b.assign(a, Expr::Sym(i), Expr::real(1.0)));
        });
        let mut p = b.finish();
        p.schedules.ptr_inc.push((sid.unwrap(), a));
        assert!(plan_ptr_inc(&p, sid.unwrap(), a).unwrap().is_none());
    }

    /// Per-nest scheduling marks only the requested nest's statements.
    #[test]
    fn schedule_in_restricts_to_one_nest() {
        let mut b = ProgramBuilder::new("pi6");
        let n = b.param_positive("pi6_N");
        let a = b.array("A", Expr::Sym(n));
        let o = b.array("O", Expr::Sym(n));
        let i = b.sym("pi6_i");
        let j = b.sym("pi6_j");
        let il = b.for_id(i, int(0), Expr::Sym(n), int(1), |b| {
            b.assign(a, Expr::Sym(i), Expr::real(1.0));
        });
        let jl = b.for_id(j, int(0), Expr::Sym(n), int(1), |b| {
            b.assign(o, Expr::Sym(j), load(a, Expr::Sym(j)));
        });
        let mut p = b.finish();
        let added = schedule_ptr_inc_in(&mut p, jl);
        assert_eq!(added, 2, "O write + A read in the j nest");
        let _ = il;
        // All marked statements live under the j loop.
        let parents = p.stmt_parents();
        assert!(p
            .schedules
            .ptr_inc
            .iter()
            .all(|(s, _)| parents.get(s).map(|c| c.contains(&jl)).unwrap_or(false)));
        // The full sweep adds the remaining (i-nest) mark.
        assert_eq!(schedule_all_ptr_inc(&mut p), 1);
    }

    #[test]
    fn schedule_all_marks_array_accesses_only() {
        let mut b = ProgramBuilder::new("pi4");
        let n = b.param_positive("pi4_N");
        let a = b.array("A", Expr::Sym(n));
        let s = b.scalar("s");
        let i = b.sym("pi4_i");
        b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
            b.assign(s, int(0), load(a, Expr::Sym(i)));
        });
        let mut p = b.finish();
        let added = schedule_all_ptr_inc(&mut p);
        assert_eq!(added, 1); // only A, not the scalar s
        assert_eq!(p.schedules.ptr_inc.len(), 1);
    }
}
