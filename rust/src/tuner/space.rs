//! The schedule search space: everything the [`Pipeline`] building blocks
//! can express, factored into four independent axes.
//!
//! A [`Candidate`] is one point in the cross product
//! `strategy × tile × prefetch-distance × ptr-inc`:
//!
//! * **strategy** — which paper parallelization prefix to run
//!   ([`ParallelStrategy::Doall`] is cfg1's `dep-elim → fusion →
//!   interchange → doall`; [`ParallelStrategy::Doacross`] is cfg2's
//!   `dep-elim → fusion → doacross → doall`);
//! * **tile** — locality strip-mining factor for innermost sequential
//!   loops (`None` = no tiling);
//! * **prefetch distance** — how many iterations of the hint-hosting loop
//!   ahead software prefetches target (§4.1; `None` = no hints), always
//!   cost-model-gated;
//! * **ptr-inc** — cost-model-gated pointer incrementation (§4.2).
//!
//! The default space ([`SearchSpace::paper`]) contains the three named
//! configurations as exact points: cfg1 = `(Doall, -, -, -)`, cfg2 =
//! `(Doacross, -, -, -)`, cfg3 = `(Doacross, tile 32, prefetch d1,
//! ptr-inc)`. The autotuner's minimum over the space is therefore never
//! worse (under the cost model) than the best hand-written configuration.

use crate::transforms::{
    DepElimPass, DoacrossPass, DoallPass, FusionPass, Pipeline, PrefetchPass, PtrIncPass,
    SinkSequentialPass, TilingPass,
};

/// Which §6.1 parallelization prefix a candidate starts from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParallelStrategy {
    /// cfg1's prefix: surface one DOALL dimension (fusion + interchange).
    Doall,
    /// cfg2's prefix: DOACROSS-pipeline the remaining RAW loops, then
    /// DOALL the inner dimensions.
    Doacross,
}

impl ParallelStrategy {
    /// Spec-style name of the prefix (`cfg1` / `cfg2`).
    pub fn name(self) -> &'static str {
        match self {
            ParallelStrategy::Doall => "cfg1",
            ParallelStrategy::Doacross => "cfg2",
        }
    }

    /// The shared pass prefix for this strategy. Candidates with the same
    /// strategy reuse one run of this pipeline (and its analysis cache).
    pub fn prefix(self) -> Pipeline {
        match self {
            ParallelStrategy::Doall => Pipeline::new()
                .with(DepElimPass)
                .with(FusionPass)
                .with(SinkSequentialPass)
                .with(DoallPass),
            ParallelStrategy::Doacross => Pipeline::new()
                .with(DepElimPass)
                .with(FusionPass)
                .with(DoacrossPass)
                .with(DoallPass),
        }
    }
}

/// One point in the schedule search space.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Candidate {
    pub strategy: ParallelStrategy,
    /// Tiling factor for innermost sequential loops (`None` = no tiling).
    pub tile: Option<i64>,
    /// Prefetch distance in iterations of the hint-hosting loop (`None` =
    /// no prefetch stage). Hints are cost-model-gated as in cfg3.
    pub prefetch_dist: Option<i64>,
    /// Cost-model-gated pointer incrementation (§4.2).
    pub ptr_inc: bool,
}

impl Candidate {
    /// The schedule tail applied after the strategy prefix, in cfg3's
    /// stage order: tiling → prefetch → ptr-inc.
    pub fn tail(&self) -> Pipeline {
        let mut pl = Pipeline::new();
        if let Some(factor) = self.tile {
            pl = pl.with(TilingPass { factor });
        }
        if let Some(dist) = self.prefetch_dist {
            pl = pl.with(PrefetchPass { gated: true, dist });
        }
        if self.ptr_inc {
            pl = pl.with(PtrIncPass { gated: true });
        }
        pl
    }

    /// The complete pipeline (prefix + tail) this candidate denotes.
    pub fn pipeline(&self) -> Pipeline {
        self.strategy.prefix().append(self.tail())
    }

    /// Human-readable spec, e.g. `cfg2+tile32+pf1+ptr-inc`. The named
    /// configurations print as themselves (`cfg3` ≡ `cfg2+tile32+pf1+
    /// ptr-inc`).
    pub fn spec(&self) -> String {
        let mut s = self.strategy.name().to_string();
        if let Some(f) = self.tile {
            s.push_str(&format!("+tile{f}"));
        }
        if let Some(d) = self.prefetch_dist {
            s.push_str(&format!("+pf{d}"));
        }
        if self.ptr_inc {
            s.push_str("+ptr-inc");
        }
        s
    }
}

/// The set of candidate axes the tuner enumerates (cross product).
#[derive(Debug, Clone)]
pub struct SearchSpace {
    pub strategies: Vec<ParallelStrategy>,
    pub tiles: Vec<Option<i64>>,
    pub prefetch_dists: Vec<Option<i64>>,
    pub ptr_inc: Vec<bool>,
}

impl SearchSpace {
    /// The default space: both §6.1 strategies, tile factors
    /// {off, 16, 32, 64}, prefetch distances {off, 1, 4}, ptr-inc
    /// {off, gated} — 48 candidates containing cfg1/cfg2/cfg3 exactly.
    pub fn paper() -> SearchSpace {
        SearchSpace {
            strategies: vec![ParallelStrategy::Doall, ParallelStrategy::Doacross],
            tiles: vec![None, Some(16), Some(32), Some(64)],
            prefetch_dists: vec![None, Some(1), Some(4)],
            ptr_inc: vec![false, true],
        }
    }

    /// A minimal space (strategies only, no schedule tail) for cheap
    /// smoke runs.
    pub fn strategies_only() -> SearchSpace {
        SearchSpace {
            strategies: vec![ParallelStrategy::Doall, ParallelStrategy::Doacross],
            tiles: vec![None],
            prefetch_dists: vec![None],
            ptr_inc: vec![false],
        }
    }

    /// All candidates in deterministic order. Simpler schedules enumerate
    /// first on every axis, so cost ties resolve toward fewer stages
    /// (the tuner keeps the earliest minimum).
    pub fn candidates(&self) -> Vec<Candidate> {
        let mut out = Vec::new();
        for &strategy in &self.strategies {
            for &tile in &self.tiles {
                for &prefetch_dist in &self.prefetch_dists {
                    for &ptr_inc in &self.ptr_inc {
                        out.push(Candidate {
                            strategy,
                            tile,
                            prefetch_dist,
                            ptr_inc,
                        });
                    }
                }
            }
        }
        out
    }

    pub fn len(&self) -> usize {
        self.strategies.len() * self.tiles.len() * self.prefetch_dists.len() * self.ptr_inc.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for SearchSpace {
    fn default() -> SearchSpace {
        SearchSpace::paper()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_space_contains_named_configs() {
        let cands = SearchSpace::paper().candidates();
        assert_eq!(cands.len(), 48);
        let cfg1 = Candidate {
            strategy: ParallelStrategy::Doall,
            tile: None,
            prefetch_dist: None,
            ptr_inc: false,
        };
        let cfg3 = Candidate {
            strategy: ParallelStrategy::Doacross,
            tile: Some(32),
            prefetch_dist: Some(1),
            ptr_inc: true,
        };
        assert!(cands.contains(&cfg1));
        assert!(cands.contains(&cfg3));
        // The first candidate is the simplest one (tie-break target).
        assert_eq!(cands[0], cfg1);
        assert_eq!(cfg3.spec(), "cfg2+tile32+pf1+ptr-inc");
    }

    #[test]
    fn candidate_pipelines_match_named_configs() {
        let cfg1 = Candidate {
            strategy: ParallelStrategy::Doall,
            tile: None,
            prefetch_dist: None,
            ptr_inc: false,
        };
        assert_eq!(cfg1.pipeline().pass_names(), Pipeline::cfg1().pass_names());
        let cfg3 = Candidate {
            strategy: ParallelStrategy::Doacross,
            tile: Some(32),
            prefetch_dist: Some(1),
            ptr_inc: true,
        };
        assert_eq!(cfg3.pipeline().pass_names(), Pipeline::cfg3().pass_names());
    }
}
