//! Cost-model-driven schedule autotuning (`--pipeline auto`,
//! [`Pipeline::autotuned`](crate::transforms::Pipeline::autotuned)).
//!
//! The paper's 12× speedups come from *choosing* schedules, not merely
//! having them: per kernel, the right combination of parallelization
//! (DOALL vs DOACROSS pipelining), locality tiling, software-prefetch
//! distance, and pointer-increment plans. This subsystem makes that
//! choice automatically:
//!
//! 1. **Space** ([`space`]) — candidates are the cross product of the
//!    cfg1/cfg2 pass prefixes with tile factors, prefetch distances, and
//!    gated pointer incrementation. The named paper configurations are
//!    exact points of the default space, so the search can never pick
//!    something the cost model ranks worse than cfg1/cfg2/cfg3.
//! 2. **Cost** ([`cost`]) — each candidate is scored with the `machine/`
//!    model: cycles per iteration of the worst innermost loop (op mix +
//!    register-pressure spills from `machine/regalloc.rs`) divided by the
//!    modeled parallel speedup of the scheduled loop tree.
//! 3. **Search** ([`search`]) — candidates sharing a strategy reuse one
//!    prefix run against a single memoized
//!    [`AnalysisCache`](crate::analysis::AnalysisCache) (dependence and
//!    visibility analyses are computed once per strategy, not per
//!    candidate); schedule tails are evaluated in parallel on worker
//!    threads; the earliest strict minimum wins, so the result is
//!    deterministic for a fixed cost model regardless of worker count.
//!    A final refinement re-derives the pointer-increment schedule one
//!    top-level nest at a time, keeping it only where the model agrees.
//!
//! Entry points: [`autotune_program`] / [`autotune_kernel`] here,
//! [`Pipeline::autotuned`](crate::transforms::Pipeline::autotuned) on the
//! pipeline API, `--pipeline auto` (and the `tune` subcommand) on the
//! CLI, and `cargo bench --bench bench_autotune` for the
//! auto-vs-cfg1/2/3 comparison (`BENCH_autotune.json`).

pub mod cost;
pub mod search;
pub mod space;

use anyhow::{ensure, Result};

use crate::ir::Program;
use crate::machine::{clang, intel_node, CompilerModel, NodeModel};
use crate::symbolic::Sym;
use crate::transforms::PipelineReport;

pub use cost::{
    parallel_speedup, schedule_cost, schedule_cost_with, CalEwma, CostCalibration, ScheduleCost,
};
pub use search::CandidateResult;
pub use space::{Candidate, ParallelStrategy, SearchSpace};

/// Tuning knobs. [`TuneOptions::default`] reproduces the paper setting:
/// the full search space scored with the clang compiler model on the
/// Intel node, evaluated on up to 8 worker threads.
#[derive(Debug, Clone)]
pub struct TuneOptions {
    pub space: SearchSpace,
    /// Worker threads for candidate evaluation; 0 = auto (available
    /// parallelism, capped at 8). The choice of schedule is independent
    /// of this value.
    pub workers: usize,
    pub compiler: CompilerModel,
    pub node: NodeModel,
    /// Run the per-loop pointer-increment refinement on the winner.
    pub per_loop_ptr_inc: bool,
    /// Concrete parameter binding for the inspector: when set, loops the
    /// static dependence test left sequential are enumerated under this
    /// binding ([`crate::inspect`]) and a certified DOALL/DOACROSS
    /// schedule competes against the winner in the same cost model. The
    /// certified schedule is a theorem about *this* binding only, so it
    /// is opt-in, never part of the parameter-free default search.
    pub inspect_params: Option<Vec<(Sym, i64)>>,
    /// Measured-cycles calibration applied to every candidate's serial
    /// term ([`schedule_cost_with`]). One shared factor never changes the
    /// *ranking* — it pins absolute predictions to reality. The daemon
    /// feeds its live measured/modeled drift in here so cached compiles
    /// report honest costs (DESIGN.md §Observability).
    pub calibration: CostCalibration,
}

impl Default for TuneOptions {
    fn default() -> TuneOptions {
        TuneOptions {
            space: SearchSpace::paper(),
            workers: 0,
            compiler: clang(),
            node: intel_node(),
            per_loop_ptr_inc: true,
            inspect_params: None,
            calibration: CostCalibration::identity(),
        }
    }
}

impl TuneOptions {
    pub(crate) fn resolved_workers(&self) -> usize {
        if self.workers > 0 {
            return self.workers;
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(8)
    }
}

/// What the tuner decided and everything it looked at on the way.
#[derive(Debug, Clone)]
pub struct TuneOutcome {
    /// Name of the tuned program.
    pub kernel: String,
    /// The winning candidate (pre-refinement cost).
    pub best: CandidateResult,
    /// Final modeled cost of [`TuneOutcome::program`] (after the
    /// per-loop ptr-inc refinement, when it was kept).
    pub cost: ScheduleCost,
    /// The optimized program under the winning schedule.
    pub program: Program,
    /// Every evaluated candidate, in deterministic enumeration order.
    pub candidates: Vec<CandidateResult>,
    /// Analysis-cache hits/misses across the shared prefix runs.
    pub analysis_hits: u64,
    pub analysis_misses: u64,
    /// Top-level nests that kept the per-loop ptr-inc schedule (0 when
    /// the refinement was disabled or did not pay).
    pub refined_nests: usize,
    /// An inspector certificate ([`TuneOptions::inspect_params`]) was
    /// applied to the winner and improved its modeled score.
    pub inspector_certified: bool,
}

impl TuneOutcome {
    /// The winner's pass log plus a summary entry, shaped like any other
    /// pipeline report so the driver/CLI render it uniformly.
    pub fn report(&self) -> PipelineReport {
        let mut rep = PipelineReport {
            log: self.best.log.clone(),
            ..Default::default()
        };
        rep.push(
            "auto",
            format!(
                "selected {} (modeled score {:.3}, {} candidates, {} analysis hits)",
                self.best.candidate.spec(),
                self.cost.score,
                self.candidates.len(),
                self.analysis_hits
            ),
        );
        if self.refined_nests > 0 {
            rep.push(
                "auto",
                format!("per-loop ptr-inc kept on {} nest(s)", self.refined_nests),
            );
        }
        if self.inspector_certified {
            rep.push(
                "auto",
                format!(
                    "inspector certificate applied (modeled score {:.3})",
                    self.cost.score
                ),
            );
        }
        rep
    }

    /// Candidate table sorted by score (best first), for the CLI `tune`
    /// subcommand and the examples.
    pub fn summary_table(&self) -> String {
        let mut idx: Vec<usize> = (0..self.candidates.len()).collect();
        idx.sort_by(|&a, &b| {
            self.candidates[a]
                .cost
                .score
                .partial_cmp(&self.candidates[b].cost.score)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let mut out = format!(
            "{:<28} {:>10} {:>10} {:>9} {:>7}\n",
            "candidate", "score", "cyc/iter", "speedup", "spills"
        );
        for &i in &idx {
            let c = &self.candidates[i];
            out.push_str(&format!(
                "{:<28} {:>10.3} {:>10.2} {:>8.1}x {:>7}\n",
                c.candidate.spec(),
                c.cost.score,
                c.cost.cycles_per_iter,
                c.cost.parallel_speedup,
                c.cost.spills
            ));
        }
        out
    }

    /// Why the argmin won (`silo tune --explain`): the winner's score
    /// decomposition, then every losing candidate's margin and which
    /// component (serial cycles vs modeled parallelism) lost it.
    pub fn explain(&self) -> String {
        let mut idx: Vec<usize> = (0..self.candidates.len()).collect();
        idx.sort_by(|&a, &b| {
            self.candidates[a]
                .cost
                .score
                .partial_cmp(&self.candidates[b].cost.score)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let mut out = String::new();
        let Some(&wi) = idx.first() else {
            return out;
        };
        let w = &self.candidates[wi];
        out.push_str(&format!(
            "winner: {}\n  score {:.3} = {:.2} cyc/iter ÷ {:.1}x modeled speedup ({} spills)\n",
            w.candidate.spec(),
            w.cost.score,
            w.cost.cycles_per_iter,
            w.cost.parallel_speedup,
            w.cost.spills
        ));
        out.push_str(&format!(
            "  argmin over {} candidates; ties break to the earliest \
             (simplest) enumeration point\n",
            self.candidates.len()
        ));
        if self.refined_nests > 0 {
            out.push_str(&format!(
                "  per-loop ptr-inc refinement kept {} nest(s) \
                 (final score {:.3})\n",
                self.refined_nests, self.cost.score
            ));
        }
        if self.inspector_certified {
            out.push_str(&format!(
                "  inspector certificate beat the static winner \
                 (final score {:.3})\n",
                self.cost.score
            ));
        }
        out.push_str("losing candidates (vs the winner):\n");
        for &i in idx.iter().skip(1) {
            let c = &self.candidates[i];
            let margin = if w.cost.score > 0.0 {
                (c.cost.score / w.cost.score - 1.0) * 100.0
            } else {
                0.0
            };
            let serial = c.cost.cycles_per_iter / w.cost.cycles_per_iter.max(f64::MIN_POSITIVE);
            let par = w.cost.parallel_speedup / c.cost.parallel_speedup.max(f64::MIN_POSITIVE);
            let why = if margin.abs() <= 1e-9 {
                "exact tie — lost on enumeration order"
            } else if serial >= par {
                "loses on serial cycles/iter"
            } else {
                "loses on modeled parallelism"
            };
            out.push_str(&format!(
                "  {:<28} +{:>6.1}%  {}\n",
                c.candidate.spec(),
                margin,
                why
            ));
        }
        out
    }
}

/// Search the schedule space for `base` and return the best schedule the
/// cost model can find, with the full candidate table.
pub fn autotune_program(base: &Program, opts: &TuneOptions) -> Result<TuneOutcome> {
    let mut sp = crate::obs::span("tune", || format!("autotune:{}", base.name));
    let cands = opts.space.candidates();
    ensure!(!cands.is_empty(), "autotuner invoked with an empty search space");
    sp.arg("candidates", || cands.len().to_string());
    let prefixes = search::run_prefixes(base, &opts.space.strategies)?;
    let analysis_hits: u64 = prefixes.iter().map(|p| p.hits).sum();
    let analysis_misses: u64 = prefixes.iter().map(|p| p.misses).sum();

    let evaluated = search::evaluate_all(&cands, &prefixes, opts)?;

    // Deterministic argmin: strict `<`, so the earliest (simplest)
    // candidate wins ties — identical inputs always pick the same point.
    let mut best_i = 0usize;
    for i in 1..evaluated.len() {
        if evaluated[i].0.cost.score < evaluated[best_i].0.cost.score {
            best_i = i;
        }
    }
    let candidates: Vec<CandidateResult> = evaluated.iter().map(|(r, _)| r.clone()).collect();
    let best = candidates[best_i].clone();
    let mut program = evaluated[best_i].1.clone();
    let mut cost = best.cost;

    let mut refined_nests = 0usize;
    if opts.per_loop_ptr_inc && best.candidate.ptr_inc {
        let (p2, c2, kept) = search::refine_ptr_inc_per_loop(
            &program,
            &opts.compiler,
            &opts.node,
            opts.calibration,
        )?;
        if c2.score <= cost.score {
            program = p2;
            cost = c2;
            refined_nests = kept;
        }
    }

    // Inspector-certified candidate (DESIGN.md §Inspector & Speculation):
    // under a concrete parameter binding, a loop the static dependence
    // test left sequential can carry a runtime DOALL/DOACROSS
    // certificate. Applying it to the winner lets certified parallelism
    // compete in the same cost model as the static candidates; the
    // strict `<` keeps ties with the binding-free winner deterministic.
    let mut inspector_certified = false;
    if let Some(binding) = &opts.inspect_params {
        let rep =
            crate::inspect::inspect_program(&program, binding, crate::inspect::DEFAULT_BUDGET);
        if let Some(certified) = crate::inspect::apply_certificates(&program, &rep) {
            let c2 = schedule_cost_with(&certified, &opts.compiler, &opts.node, opts.calibration)?;
            if c2.score < cost.score {
                program = certified;
                cost = c2;
                inspector_certified = true;
            }
        }
    }
    crate::ir::validate::validate(&program)?;
    sp.arg("winner", || best.candidate.spec());
    sp.arg("score", || format!("{:.3}", cost.score));

    Ok(TuneOutcome {
        kernel: base.name.clone(),
        best,
        cost,
        program,
        candidates,
        analysis_hits,
        analysis_misses,
        refined_nests,
        inspector_certified,
    })
}

/// Autotune vs the named configurations on one kernel build — the shared
/// protocol behind the autotune experiment, `bench_autotune`, and the
/// acceptance tests, kept in one place so the three surfaces cannot
/// drift.
#[derive(Debug, Clone)]
pub struct NamedComparison {
    /// Modeled scores of cfg1/cfg2/cfg3 under `opts`' cost model.
    pub cfg_scores: [f64; 3],
    /// The best (lowest) of the three named scores.
    pub best_cfg: f64,
    pub outcome: TuneOutcome,
}

impl NamedComparison {
    /// The acceptance criterion: auto's score is no worse than the best
    /// named configuration (small tolerance for float accumulation).
    pub fn auto_never_worse(&self) -> bool {
        self.outcome.cost.score <= self.best_cfg + 1e-9
    }
}

/// Score cfg1/cfg2/cfg3 and the autotuner on fresh builds from `build`,
/// all under the same cost model.
pub fn compare_with_named_configs(
    build: fn() -> Program,
    opts: &TuneOptions,
) -> Result<NamedComparison> {
    let mut cfg_scores = [0.0f64; 3];
    for (i, spec) in ["cfg1", "cfg2", "cfg3"].iter().enumerate() {
        let mut p = build();
        crate::transforms::Pipeline::from_spec(spec)?.run(&mut p)?;
        cfg_scores[i] = schedule_cost(&p, &opts.compiler, &opts.node)?.score;
    }
    let outcome = autotune_program(&build(), opts)?;
    let best_cfg = cfg_scores.iter().copied().fold(f64::INFINITY, f64::min);
    Ok(NamedComparison {
        cfg_scores,
        best_cfg,
        outcome,
    })
}

/// [`autotune_program`] for a registered kernel name or a `.silo` path
/// (resolution through [`crate::kernels::resolve`], did-you-mean
/// suggestions included).
pub fn autotune_kernel(name: &str, opts: &TuneOptions) -> Result<TuneOutcome> {
    let kernel = crate::kernels::resolve(name)?;
    autotune_program(&kernel.program(), opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::ProgramBuilder;
    use crate::symbolic::{int, load, Expr};

    fn stream_loop() -> Program {
        let mut b = ProgramBuilder::new("tu1");
        let n = b.param_positive("tu1_N");
        let a = b.array("A", Expr::Sym(n));
        let x = b.array("X", Expr::Sym(n));
        let i = b.sym("tu1_i");
        b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
            b.assign(a, Expr::Sym(i), load(x, Expr::Sym(i)) * Expr::real(2.0));
        });
        b.finish()
    }

    #[test]
    fn best_is_global_minimum_of_candidate_table() {
        let outcome = autotune_program(&stream_loop(), &TuneOptions::default()).unwrap();
        assert_eq!(outcome.candidates.len(), 48);
        for c in &outcome.candidates {
            assert!(
                outcome.best.cost.score <= c.cost.score,
                "{} beat the winner {}",
                c.candidate.spec(),
                outcome.best.candidate.spec()
            );
        }
        // Refinement never regresses the final cost.
        assert!(outcome.cost.score <= outcome.best.cost.score);
    }

    #[test]
    fn worker_count_does_not_change_the_choice() {
        let p = stream_loop();
        let serial = autotune_program(
            &p,
            &TuneOptions {
                workers: 1,
                ..TuneOptions::default()
            },
        )
        .unwrap();
        let parallel = autotune_program(
            &p,
            &TuneOptions {
                workers: 4,
                ..TuneOptions::default()
            },
        )
        .unwrap();
        assert_eq!(serial.best.candidate, parallel.best.candidate);
        assert_eq!(serial.cost.score.to_bits(), parallel.cost.score.to_bits());
    }

    #[test]
    fn prefix_analyses_are_shared() {
        let outcome = autotune_program(&stream_loop(), &TuneOptions::default()).unwrap();
        assert!(
            outcome.analysis_hits > 0,
            "strategy prefixes shared no analyses"
        );
    }

    #[test]
    fn unknown_kernel_is_rejected() {
        assert!(autotune_kernel("no_such_kernel", &TuneOptions::default()).is_err());
    }

    /// `A[(5·i) mod N] = X[i]` defeats the static dependence test (a mod
    /// bijection is invisible symbolically) but is disjoint under N=64,
    /// so the inspector certifies DOALL and the certified schedule must
    /// beat the binding-free winner in the same cost model.
    #[test]
    fn inspector_certificate_enters_candidate_space() {
        use crate::symbolic::imod;
        let mut b = ProgramBuilder::new("tu_insp");
        let n = b.param_positive("tu_insp_N");
        let a = b.array("A", Expr::Sym(n));
        let x = b.array("X", Expr::Sym(n));
        let i = b.sym("tu_insp_i");
        b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
            b.assign(a, imod(Expr::Sym(i) * int(5), Expr::Sym(n)), load(x, Expr::Sym(i)));
        });
        let p = b.finish();
        let plain = autotune_program(&p, &TuneOptions::default()).unwrap();
        assert!(!plain.inspector_certified);
        let insp = autotune_program(
            &p,
            &TuneOptions {
                inspect_params: Some(vec![(n, 64)]),
                ..TuneOptions::default()
            },
        )
        .unwrap();
        assert!(
            insp.inspector_certified,
            "certified DOALL did not improve the modeled score"
        );
        assert!(insp.cost.score < plain.cost.score);
        // The certificate shows up in the pass log the CLI renders.
        assert!(insp.report().log.iter().any(|l| l.detail.contains("inspector")));
    }
}
