//! The tuner's objective function: modeled cycles per innermost-loop
//! iteration divided by the modeled parallel speedup of the schedule.
//!
//! The serial term reuses the `machine/` cost model end to end — op-mix
//! issue cost and register-pressure spill penalties from
//! [`crate::machine::cycles_per_iteration`] (which runs linear-scan
//! liveness over the *actual lowered bytecode*, `machine/regalloc.rs`).
//! The parallel term walks the scheduled loop tree: a DOALL loop scales
//! by `0.95 × cores`, a DOACROSS pipeline by `0.5 × cores` (fill/drain +
//! wait overhead), factors multiply down a nest and the product is capped
//! at the node's core count. Memory schedules are priced at their issue
//! cost only — the latency they hide is measured by the trace-driven
//! cache simulator in the experiments, never double-counted here (the
//! same stance as the cfg3 gates in `transforms/pipeline.rs`).

use anyhow::Result;

use crate::ir::{Loop, LoopSchedule, Node, Program};
use crate::machine::{self, cycles_per_iteration, CompilerModel, NodeModel};

/// Modeled cost of one scheduled program.
#[derive(Debug, Clone, Copy)]
pub struct ScheduleCost {
    /// Cycles per iteration of the worst innermost loop (op mix + spills).
    pub cycles_per_iter: f64,
    /// Spill count of that loop under the compiler model.
    pub spills: usize,
    /// Modeled speedup from the parallel schedule (1.0 = sequential).
    pub parallel_speedup: f64,
    /// The scalar objective the tuner minimizes:
    /// `cycles_per_iter / parallel_speedup`.
    pub score: f64,
}

/// Calibration of the modeled cycle count against *measured* execution —
/// the native code tier finally makes the model's unit (cycles per
/// innermost iteration) directly observable, so a measured run can pin
/// the model's absolute scale instead of leaving it a paper constant.
/// Scaling every candidate by one factor never changes the tuner's
/// *ranking*; what it buys is honest absolute predictions (reports,
/// budget estimates) and a place to fold in future per-op refits.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostCalibration {
    /// Multiplier applied to modeled cycles per iteration
    /// (1.0 = trust the model as-is).
    pub scale: f64,
}

impl CostCalibration {
    /// The uncalibrated model (what [`schedule_cost`] uses).
    pub fn identity() -> CostCalibration {
        CostCalibration { scale: 1.0 }
    }

    /// Pin the model to one measured kernel: `measured / modeled` cycles
    /// per innermost iteration (`benches/bench_native.rs` derives the
    /// measurement from a native-tier wall-clock run). Degenerate
    /// measurements (zero, negative, NaN, or a zero model) fall back to
    /// identity — an uncalibrated ranking still beats a poisoned one.
    pub fn from_measurement(modeled: f64, measured: f64) -> CostCalibration {
        let scale = measured / modeled;
        if scale.is_finite() && scale > 0.0 {
            CostCalibration { scale }
        } else {
            CostCalibration::identity()
        }
    }
}

/// Exponentially-weighted running estimate of measured÷modeled cycle
/// drift — the feedback half of the observe→act loop. Every measured
/// run [`CalEwma::fold`]s its ratio in; [`CalEwma::calibration`] turns
/// the current estimate into the [`CostCalibration`] the next compile
/// of the *same* kernel uses. The daemon keeps one per cached artifact
/// (per-kernel calibration, keyed by content id) plus a fuel-weighted
/// aggregate for the global `model_drift` gauge.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CalEwma {
    /// Current drift estimate (measured ÷ modeled; 1.0 = model exact).
    pub ratio: f64,
    /// Measured runs folded in so far.
    pub samples: u64,
}

impl Default for CalEwma {
    fn default() -> CalEwma {
        CalEwma {
            ratio: 1.0,
            samples: 0,
        }
    }
}

impl CalEwma {
    /// EWMA smoothing: how much one new measurement moves the estimate.
    /// 0.3 converges in a handful of runs while one cold-cache outlier
    /// can't whipsaw the calibration.
    const ALPHA: f64 = 0.3;

    /// Fold one measured÷modeled ratio in. Non-finite or non-positive
    /// ratios are rejected outright (a poisoned sample must not poison
    /// the estimate); the first accepted sample seeds the EWMA.
    pub fn fold(&mut self, ratio: f64) -> bool {
        if !ratio.is_finite() || ratio <= 0.0 {
            return false;
        }
        self.ratio = if self.samples == 0 {
            ratio
        } else {
            (1.0 - Self::ALPHA) * self.ratio + Self::ALPHA * ratio
        };
        self.samples += 1;
        true
    }

    /// The calibration a recompile should use: identity until at least
    /// one sample exists, then the estimate clamped to [1e-3, 1e3] so a
    /// wild measurement can't collapse or explode every candidate score.
    pub fn calibration(&self) -> CostCalibration {
        if self.samples == 0 {
            CostCalibration::identity()
        } else {
            CostCalibration {
                scale: self.ratio.clamp(1e-3, 1e3),
            }
        }
    }
}

/// Score `p`'s current schedule under a compiler + node model.
pub fn schedule_cost(p: &Program, cm: &CompilerModel, node: &NodeModel) -> Result<ScheduleCost> {
    schedule_cost_with(p, cm, node, CostCalibration::identity())
}

/// [`schedule_cost`] with a measured-cycles calibration applied to the
/// serial term (and hence the score).
pub fn schedule_cost_with(
    p: &Program,
    cm: &CompilerModel,
    node: &NodeModel,
    cal: CostCalibration,
) -> Result<ScheduleCost> {
    let prog = crate::lowering::lower(p)?;
    let cycles_per_iter = cycles_per_iteration(&prog, cm) * cal.scale;
    let spills = machine::analyze(&prog).worst_spills(cm);
    let parallel_speedup = parallel_speedup(p, node);
    Ok(ScheduleCost {
        cycles_per_iter,
        spills,
        parallel_speedup,
        score: cycles_per_iter / parallel_speedup,
    })
}

/// Modeled speedup of the loop schedule on `node`: the best root-to-leaf
/// product of per-loop factors (DOALL `0.95·cores`, DOACROSS `0.5·cores`,
/// sequential 1), capped at the core count. Nesting a DOALL plane inside
/// a DOACROSS K pipeline therefore saturates the node — the Fig. 9
/// mechanism — while either dimension alone falls short of the cap.
pub fn parallel_speedup(p: &Program, node: &NodeModel) -> f64 {
    let cores = node.cores as f64;
    fn nest(l: &Loop, cores: f64) -> f64 {
        let own = match &l.schedule {
            LoopSchedule::Sequential => 1.0,
            LoopSchedule::Parallel => 0.95 * cores,
            LoopSchedule::Doacross { .. } => 0.5 * cores,
        };
        let inner = l
            .body
            .iter()
            .filter_map(|n| match n {
                Node::Loop(c) => Some(nest(c, cores)),
                _ => None,
            })
            .fold(1.0f64, f64::max);
        own * inner
    }
    let best = p
        .body
        .iter()
        .filter_map(|n| match n {
            Node::Loop(l) => Some(nest(l, cores)),
            _ => None,
        })
        .fold(1.0f64, f64::max);
    best.min(cores).max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::ProgramBuilder;
    use crate::machine::{clang, intel_node};
    use crate::symbolic::{int, load, Expr};
    use crate::transforms::Pipeline;

    fn stream_loop() -> Program {
        let mut b = ProgramBuilder::new("tc1");
        let n = b.param_positive("tc1_N");
        let a = b.array("A", Expr::Sym(n));
        let x = b.array("X", Expr::Sym(n));
        let i = b.sym("tc1_i");
        b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
            b.assign(a, Expr::Sym(i), load(x, Expr::Sym(i)) * Expr::real(2.0));
        });
        b.finish()
    }

    #[test]
    fn parallelization_improves_score() {
        let node = intel_node();
        let cm = clang();
        let p = stream_loop();
        let seq = schedule_cost(&p, &cm, &node).unwrap();
        assert_eq!(seq.parallel_speedup, 1.0);

        let mut par = stream_loop();
        Pipeline::from_spec("doall").unwrap().run(&mut par).unwrap();
        let opt = schedule_cost(&par, &cm, &node).unwrap();
        assert!(opt.parallel_speedup > 1.0);
        assert!(opt.score < seq.score, "{} vs {}", opt.score, seq.score);
    }

    #[test]
    fn speedup_caps_at_core_count() {
        let node = intel_node();
        let mut p = stream_loop();
        Pipeline::from_spec("doall").unwrap().run(&mut p).unwrap();
        assert!(parallel_speedup(&p, &node) <= node.cores as f64);
    }

    /// Calibration scales the absolute numbers but never the ranking,
    /// and degenerate measurements collapse to identity.
    #[test]
    fn calibration_scales_without_reranking() {
        let node = intel_node();
        let cm = clang();
        let p = stream_loop();
        let mut par = stream_loop();
        Pipeline::from_spec("doall").unwrap().run(&mut par).unwrap();

        let base = schedule_cost(&p, &cm, &node).unwrap();
        let cal = CostCalibration::from_measurement(2.0, 5.0);
        assert!((cal.scale - 2.5).abs() < 1e-12);
        let scaled = schedule_cost_with(&p, &cm, &node, cal).unwrap();
        assert!((scaled.cycles_per_iter - base.cycles_per_iter * 2.5).abs() < 1e-9);
        assert!((scaled.score - base.score * 2.5).abs() < 1e-9);

        // Same factor on both candidates ⇒ same winner.
        let seq = schedule_cost_with(&p, &cm, &node, cal).unwrap();
        let opt = schedule_cost_with(&par, &cm, &node, cal).unwrap();
        assert!(opt.score < seq.score);

        for (modeled, measured) in [(0.0, 1.0), (1.0, 0.0), (1.0, -3.0), (1.0, f64::NAN)] {
            assert_eq!(
                CostCalibration::from_measurement(modeled, measured),
                CostCalibration::identity()
            );
        }
    }

    /// The EWMA seeds on the first sample, smooths afterwards, rejects
    /// poisoned ratios, and clamps the derived calibration.
    #[test]
    fn ewma_folds_and_clamps() {
        let mut e = CalEwma::default();
        assert_eq!(e.calibration(), CostCalibration::identity());

        assert!(e.fold(2.0));
        assert_eq!(e.samples, 1);
        assert!((e.ratio - 2.0).abs() < 1e-12, "first sample seeds");
        assert!(e.fold(4.0));
        assert!((e.ratio - (0.7 * 2.0 + 0.3 * 4.0)).abs() < 1e-12);

        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let before = e;
            assert!(!e.fold(bad));
            assert_eq!(e, before, "rejected samples leave the estimate alone");
        }

        let mut wild = CalEwma::default();
        wild.fold(1e9);
        assert_eq!(wild.calibration().scale, 1e3);
        let mut tiny = CalEwma::default();
        tiny.fold(1e-9);
        assert_eq!(tiny.calibration().scale, 1e-3);
    }
}
