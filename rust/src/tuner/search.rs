//! The search engine: shared strategy prefixes, worker-thread candidate
//! evaluation, and the per-loop pointer-increment refinement.
//!
//! Candidates are organized as a prefix tree. All candidates with the
//! same [`ParallelStrategy`] share one run of that strategy's pass prefix
//! (dep-elim → fusion → parallelization), executed once against a single
//! memoized [`AnalysisCache`] — the expensive dependence/visibility
//! analyses are computed once per strategy, not once per candidate. The
//! schedule tails (tiling, prefetch, ptr-inc) then run on clones of the
//! prefix program, fanned out across worker threads. Selection is
//! deterministic regardless of worker count: results are collected by
//! candidate index and the earliest strict minimum wins.

use std::sync::atomic::{AtomicUsize, Ordering};

use anyhow::{Context, Result};

use crate::analysis::AnalysisCache;
use crate::ir::{LoopId, Node, Program};
use crate::machine::{CompilerModel, NodeModel};
use crate::transforms::PassLog;

use super::cost::{schedule_cost_with, CostCalibration, ScheduleCost};
use super::space::{Candidate, ParallelStrategy};
use super::TuneOptions;

/// One evaluated candidate: its point in the space, its modeled cost, and
/// the pass log of the full (prefix + tail) pipeline run.
#[derive(Debug, Clone)]
pub struct CandidateResult {
    pub candidate: Candidate,
    pub cost: ScheduleCost,
    pub log: Vec<PassLog>,
}

/// A strategy prefix run once and shared by every candidate tail.
pub(super) struct PrefixRun {
    pub strategy: ParallelStrategy,
    pub program: Program,
    pub log: Vec<PassLog>,
    pub hits: u64,
    pub misses: u64,
}

/// Run each distinct strategy prefix once (one shared [`AnalysisCache`]
/// per prefix).
pub(super) fn run_prefixes(
    base: &Program,
    strategies: &[ParallelStrategy],
) -> Result<Vec<PrefixRun>> {
    let mut out: Vec<PrefixRun> = Vec::new();
    for &strategy in strategies {
        if out.iter().any(|r| r.strategy == strategy) {
            continue;
        }
        let _sp = crate::obs::span("tune", || format!("prefix:{}", strategy.name()));
        let mut program = base.clone();
        let mut cache = AnalysisCache::new();
        let rep = strategy
            .prefix()
            .run_with(&mut program, &mut cache)
            .with_context(|| format!("{} prefix on {}", strategy.name(), base.name))?;
        out.push(PrefixRun {
            strategy,
            program,
            log: rep.log,
            hits: cache.hits(),
            misses: cache.misses(),
        });
    }
    Ok(out)
}

/// Evaluate one candidate: clone its strategy's prefix program, run the
/// schedule tail, and score the result.
fn evaluate(
    cand: &Candidate,
    prefixes: &[PrefixRun],
    cm: &CompilerModel,
    node: &NodeModel,
    cal: CostCalibration,
) -> Result<(CandidateResult, Program)> {
    let mut sp = crate::obs::span("tune", || format!("candidate:{}", cand.spec()));
    let prefix = prefixes
        .iter()
        .find(|r| r.strategy == cand.strategy)
        .expect("strategy prefix missing for candidate");
    let mut program = prefix.program.clone();
    let rep = cand
        .tail()
        .run(&mut program)
        .with_context(|| format!("schedule tail {}", cand.spec()))?;
    let cost = schedule_cost_with(&program, cm, node, cal)?;
    sp.arg("score", || format!("{:.3}", cost.score));
    let mut log = prefix.log.clone();
    log.extend(rep.log);
    Ok((
        CandidateResult {
            candidate: *cand,
            cost,
            log,
        },
        program,
    ))
}

/// Evaluate every candidate, fanned out over worker threads. Results come
/// back in candidate order whatever the interleaving.
pub(super) fn evaluate_all(
    cands: &[Candidate],
    prefixes: &[PrefixRun],
    opts: &TuneOptions,
) -> Result<Vec<(CandidateResult, Program)>> {
    let workers = opts.resolved_workers().min(cands.len()).max(1);
    if workers == 1 {
        return cands
            .iter()
            .map(|c| evaluate(c, prefixes, &opts.compiler, &opts.node, opts.calibration))
            .collect();
    }
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<Result<(CandidateResult, Program)>>> =
        (0..cands.len()).map(|_| None).collect();
    std::thread::scope(|scope| {
        let next = &next;
        let mut handles = Vec::new();
        for _ in 0..workers {
            handles.push(scope.spawn(move || {
                let mut got = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= cands.len() {
                        break;
                    }
                    got.push((
                        i,
                        evaluate(&cands[i], prefixes, &opts.compiler, &opts.node, opts.calibration),
                    ));
                }
                got
            }));
        }
        for h in handles {
            for (i, r) in h.join().expect("tuner worker panicked") {
                slots[i] = Some(r);
            }
        }
    });
    slots
        .into_iter()
        .map(|s| s.expect("candidate left unevaluated"))
        .collect()
}

/// Per-loop pointer-increment refinement (§4.2 as a per-nest decision):
/// starting from the winner with all ptr-inc marks cleared, re-add the
/// schedule one top-level nest at a time and keep a nest's marks only
/// when the modeled score does not regress. Returns the refined program,
/// its cost, and how many nests kept the schedule.
pub(super) fn refine_ptr_inc_per_loop(
    winner: &Program,
    cm: &CompilerModel,
    node: &NodeModel,
    cal: CostCalibration,
) -> Result<(Program, ScheduleCost, usize)> {
    let mut p = winner.clone();
    p.schedules.ptr_inc.clear();
    let mut cur = schedule_cost_with(&p, cm, node, cal)?;
    let mut kept = 0usize;
    let tops: Vec<LoopId> = p
        .body
        .iter()
        .filter_map(|n| match n {
            Node::Loop(l) => Some(l.id),
            _ => None,
        })
        .collect();
    for lid in tops {
        let mut trial = p.clone();
        if crate::schedules::schedule_ptr_inc_in(&mut trial, lid) == 0 {
            continue;
        }
        let c = schedule_cost_with(&trial, cm, node, cal)?;
        if c.score <= cur.score {
            p = trial;
            cur = c;
            kept += 1;
        }
    }
    Ok((p, cur, kept))
}
