//! SILO-RS — Symbolic Inductive Loop Optimization.
//!
//! Reproduction of "Inductive Loop Analysis for Practical HPC Application
//! Optimization" (CS.DC 2025). See DESIGN.md for the system inventory and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod analysis;
pub mod baselines;
pub mod bench;
pub mod coordinator;
pub mod dataflow;
pub mod exec;
pub mod ir;
pub mod kernels;
pub mod lowering;
pub mod machine;
pub mod proptest_lite;
pub mod runtime;
pub mod symbolic;
pub mod schedules;
pub mod transforms;
