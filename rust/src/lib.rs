//! SILO-RS — Symbolic Inductive Loop Optimization.
//!
//! Reproduction of "Inductive Loop Analysis for Practical HPC Application
//! Optimization" (CS.DC 2025). See DESIGN.md for the system inventory and
//! EXPERIMENTS.md for paper-vs-measured results.

// Stylistic lints the analysis/transform code trips by design: index-led
// loops mirror the paper's iteration-vector notation, and the symbolic
// types get large without boxing.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::large_enum_variant,
    clippy::result_large_err
)]

pub mod analysis;
pub mod baselines;
pub mod bench;
pub mod coordinator;
pub mod dataflow;
pub mod exec;
pub mod extract;
pub mod frontend;
pub mod inspect;
pub mod ir;
pub mod kernels;
pub mod lowering;
pub mod machine;
pub mod native;
pub mod obs;
pub mod proptest_lite;
pub mod runtime;
pub mod service;
pub mod symbolic;
pub mod schedules;
pub mod transforms;
pub mod tuner;
pub mod verify;
