//! Minimal timing harness (no external bench crates in the vendored set;
//! `cargo bench` targets use this with `harness = false`).

use std::time::{Duration, Instant};

/// Timing statistics over repeated runs.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: u32,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Stats {
    pub fn mean_ms(&self) -> f64 {
        self.mean.as_secs_f64() * 1e3
    }
}

/// Run `f` for `warmup` + `iters` iterations and report wall-clock stats.
pub fn time<F: FnMut()>(warmup: u32, iters: u32, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut min = Duration::MAX;
    let mut max = Duration::ZERO;
    let mut total = Duration::ZERO;
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed();
        min = min.min(dt);
        max = max.max(dt);
        total += dt;
    }
    Stats {
        iters,
        mean: total / iters.max(1),
        min,
        max,
    }
}

/// Auto-calibrating variant: picks an iteration count so the measurement
/// lasts roughly `budget`.
pub fn time_budgeted<F: FnMut()>(budget: Duration, mut f: F) -> Stats {
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().max(Duration::from_micros(1));
    let iters = (budget.as_secs_f64() / once.as_secs_f64()).clamp(1.0, 1000.0) as u32;
    time(0, iters, f)
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}
