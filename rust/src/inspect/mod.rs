//! Inspector pass: runtime certification of loop parallelism.
//!
//! The paper's dependence test is *static* — symbolic δ-solving over the
//! access functions. Mod-strided and parameter-dependent subscripts
//! (`csr_gather`, `gather_stride` under `--pipeline none`) defeat it and
//! run sequentially even when, for the concrete parameters of *this*
//! invocation, no two iterations ever touch the same element. The
//! inspector (Baghdadi et al., PAPERS.md arXiv 1111.6756; DESIGN.md
//! §Inspector & Speculation) recovers that parallelism dynamically: it
//! evaluates the symbolic access functions over the concrete iteration
//! space — cheap, since the expressions are exactly what the VM already
//! interprets — and issues a per-(loop, parameter-set) certificate:
//!
//! * [`Certificate::Doall`] — no cross-iteration dependence at all;
//! * [`Certificate::Doacross`] — every cross-iteration dependence
//!   distance is a multiple of the *exact* computed `delta ≥ 2`;
//! * [`Certificate::Sequential`] — dependences at unit/irregular
//!   distance: no parallel schedule is licensed;
//! * [`Certificate::InputDependent`] — a subscript or guard reads array
//!   *data*, so the footprint is not a function of the parameters alone
//!   (the speculative tier's territory — see `exec::speculate`);
//! * [`Certificate::BudgetExceeded`] — the iteration space is too large
//!   to enumerate within the inspection budget.
//!
//! Certificates are *theorems about one parameter binding*: the daemon
//! memoizes them per (kernel, param-set) in its content-addressed cache
//! (`service/server.rs`), and [`apply_certificates`] re-schedules a
//! program clone (`Doall → LoopSchedule::Parallel`, `Doacross{δ≥2} →
//! LoopSchedule::Doacross`) for exactly that binding.
//!
//! Dependence distances are exact, not approximate: per touched element
//! the inspector folds a running gcd over a generator set of the actual
//! dependence-pair distances (first-write anchor + consecutive-write
//! gaps), which spans the same lattice as the full pairwise set — the
//! brute-force conflict oracle in `rust/tests/inspect.rs` pins equality
//! on the whole corpus plus fuzzed programs.

use std::collections::HashMap;

use crate::ir::{
    AccessKind, ContainerKind, Loop, LoopId, LoopSchedule, Node, Program, ReleaseSpec, WaitSpec,
};
use crate::symbolic::eval::eval_int;
use crate::symbolic::{ContainerId, Expr, Sym};

/// Default cap on footprint evaluations per program inspection. Beyond
/// this the inspector reports [`Certificate::BudgetExceeded`] instead of
/// stalling the daemon: inspection must stay cheap relative to the run.
pub const DEFAULT_BUDGET: usize = 1 << 20;

/// What the inspector concluded about one loop under one param binding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Certificate {
    /// No element is touched by two different iterations with a write
    /// involved: every iteration is independent.
    Doall,
    /// Cross-iteration dependences exist, but every dependence distance
    /// is a multiple of `delta` (the exact gcd of all distances).
    Doacross { delta: i64 },
    /// Dependences at distance gcd 1 — nothing better than source order.
    Sequential,
    /// A subscript or guard contains a data load (or a non-integer
    /// guard), so the footprint cannot be enumerated from parameters.
    InputDependent { reason: String },
    /// Enumeration exceeded the inspection budget.
    BudgetExceeded,
}

impl Certificate {
    /// Does this certificate license a parallel schedule?
    pub fn parallelizable(&self) -> bool {
        match self {
            Certificate::Doall => true,
            Certificate::Doacross { delta } => *delta >= 2,
            _ => false,
        }
    }

    /// Compact wire/CLI label (`doall`, `doacross(4)`, …).
    pub fn label(&self) -> String {
        match self {
            Certificate::Doall => "doall".to_string(),
            Certificate::Doacross { delta } => format!("doacross({delta})"),
            Certificate::Sequential => "sequential".to_string(),
            Certificate::InputDependent { .. } => "input-dependent".to_string(),
            Certificate::BudgetExceeded => "budget-exceeded".to_string(),
        }
    }
}

/// One inspected loop.
#[derive(Debug, Clone)]
pub struct LoopInspection {
    pub loop_id: LoopId,
    pub var: Sym,
    /// Trip count actually enumerated (0 for uncertified loops).
    pub iters: usize,
    pub certificate: Certificate,
}

/// The inspector's result for one program under one parameter binding.
#[derive(Debug, Clone)]
pub struct InspectReport {
    pub kernel: String,
    pub params: Vec<(Sym, i64)>,
    /// Top-level sequential loops, in source order.
    pub loops: Vec<LoopInspection>,
    /// Footprint evaluations spent across all loops.
    pub evals: usize,
}

impl InspectReport {
    /// Loops whose certificate licenses a parallel schedule.
    pub fn certified(&self) -> usize {
        self.loops.iter().filter(|l| l.certificate.parallelizable()).count()
    }

    /// Human-readable per-loop table (CLI `silo inspect`).
    pub fn summary(&self) -> String {
        let mut out = String::new();
        if self.loops.is_empty() {
            out.push_str("no sequential top-level loops to inspect\n");
            return out;
        }
        for l in &self.loops {
            let extra = match &l.certificate {
                Certificate::InputDependent { reason } => format!(" ({reason})"),
                _ => format!(" ({} iteration(s))", l.iters),
            };
            out.push_str(&format!(
                "L{} {}: {}{extra}\n",
                l.loop_id.0,
                l.var.name(),
                l.certificate.label()
            ));
        }
        out.push_str(&format!(
            "{} loop(s) inspected, {} certified parallel, {} footprint eval(s)\n",
            self.loops.len(),
            self.certified(),
            self.evals
        ));
        out
    }
}

/// Per-element dependence-distance state. `g` accumulates the gcd of a
/// generator set of actual dependence distances (see module docs).
#[derive(Default)]
struct ElemState {
    /// First read iteration seen before any write.
    pre_r0: Option<i64>,
    /// gcd of (read_iter − pre_r0) over pre-write reads (read-read gaps;
    /// only ever *combined* with the first-write anchor, which restores
    /// exactness — the combined value is gcd{|first_write − read|}).
    pre_g: i64,
    first_write: Option<i64>,
    last_write: i64,
}

fn gcd(a: i64, b: i64) -> i64 {
    let (mut a, mut b) = (a.abs(), b.abs());
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

/// Cross-iteration dependence tracker for one loop enumeration.
struct Footprint {
    elems: HashMap<(ContainerId, i64), ElemState>,
    /// Running gcd of all cross-iteration dependence distances; 0 = none.
    g: i64,
}

impl Footprint {
    fn new() -> Footprint {
        Footprint { elems: HashMap::new(), g: 0 }
    }

    fn read(&mut self, c: ContainerId, at: i64, iter: i64) {
        let e = self.elems.entry((c, at)).or_default();
        match e.first_write {
            Some(w0) => {
                if iter != w0 {
                    self.g = gcd(self.g, iter - w0);
                }
            }
            None => match e.pre_r0 {
                Some(r0) => e.pre_g = gcd(e.pre_g, iter - r0),
                None => e.pre_r0 = Some(iter),
            },
        }
    }

    fn write(&mut self, c: ContainerId, at: i64, iter: i64) {
        let e = self.elems.entry((c, at)).or_default();
        match e.first_write {
            Some(_) => {
                if iter != e.last_write {
                    self.g = gcd(self.g, iter - e.last_write);
                }
                e.last_write = iter;
            }
            None => {
                e.first_write = Some(iter);
                e.last_write = iter;
                if let Some(r0) = e.pre_r0 {
                    // gcd{first_write − pre_read} == gcd(w − r0, pre_g).
                    let pre = gcd(iter - r0, e.pre_g);
                    if pre != 0 {
                        self.g = gcd(self.g, pre);
                    }
                }
            }
        }
    }
}

/// Why a loop could not be enumerated — distinguished from a completed
/// enumeration so the two uncertified verdicts stay separate.
enum Obstacle {
    InputDependent(String),
    Budget,
}

struct Enumerator<'a> {
    p: &'a Program,
    env: Vec<(Sym, i64)>,
    fp: Footprint,
    evals: usize,
    budget: usize,
    /// Containers the loop ever writes (superset under guards) — reads
    /// of never-written containers carry no dependence and are skipped.
    written: Vec<bool>,
}

impl Enumerator<'_> {
    fn charge(&mut self) -> Result<(), Obstacle> {
        self.evals += 1;
        if self.evals > self.budget {
            return Err(Obstacle::Budget);
        }
        Ok(())
    }

    fn eval(&mut self, e: &Expr, what: &str) -> Result<i64, Obstacle> {
        self.charge()?;
        if e.contains_load() {
            return Err(Obstacle::InputDependent(format!("{what} reads array data")));
        }
        eval_int(e, &self.env)
            .map_err(|err| Obstacle::InputDependent(format!("{what} not evaluable: {err}")))
    }

    /// Record one statement's accesses under outer-loop iteration `iter`.
    fn stmt(&mut self, s: &crate::ir::Stmt, iter: i64) -> Result<(), Obstacle> {
        if let Some(g) = &s.guard {
            if self.eval(g, "guard")? <= 0 {
                return Ok(());
            }
        }
        for a in s.accesses() {
            let tracked = self.written[a.container.0 as usize]
                && self.p.container(a.container).kind != ContainerKind::Register;
            if !tracked {
                self.charge()?;
                continue;
            }
            let at = self.eval(&a.offset, "subscript")?;
            match a.kind {
                AccessKind::Read => self.fp.read(a.container, at, iter),
                AccessKind::Write => self.fp.write(a.container, at, iter),
            }
        }
        Ok(())
    }

    /// Enumerate one node's footprint under outer-loop iteration `iter`.
    fn node(&mut self, n: &Node, iter: i64) -> Result<(), Obstacle> {
        match n {
            Node::Stmt(s) => self.stmt(s, iter),
            Node::Loop(l) => {
                let start = self.eval(&l.start, "loop start")?;
                let end = self.eval(&l.end, "loop end")?;
                let mut v = start;
                loop {
                    self.env.push((l.var, v));
                    let s = self.eval(&l.stride, "loop stride");
                    let s = match s {
                        Ok(s) => s,
                        Err(e) => {
                            self.env.pop();
                            return Err(e);
                        }
                    };
                    if s == 0 || (s > 0 && v >= end) || (s < 0 && v <= end) {
                        self.env.pop();
                        break;
                    }
                    let r = l.body.iter().try_for_each(|c| self.node(c, iter));
                    self.env.pop();
                    r?;
                    v += s;
                }
                Ok(())
            }
        }
    }
}

/// Inspect one top-level loop under `params`.
fn inspect_loop(p: &Program, l: &Loop, params: &[(Sym, i64)], budget: usize) -> (LoopInspection, usize) {
    let mut written = vec![false; p.containers.len()];
    for n in &l.body {
        n.visit(&mut |m| {
            if let Node::Stmt(s) = m {
                written[s.write.container.0 as usize] = true;
            }
        });
    }
    let mut e = Enumerator {
        p,
        env: params.to_vec(),
        fp: Footprint::new(),
        evals: 0,
        budget,
        written,
    };
    let done = (|| -> Result<usize, Obstacle> {
        let start = e.eval(&l.start, "loop start")?;
        let end = e.eval(&l.end, "loop end")?;
        let mut v = start;
        let mut iters = 0i64;
        loop {
            e.env.push((l.var, v));
            let s = e.eval(&l.stride, "loop stride");
            let s = match s {
                Ok(s) => s,
                Err(err) => {
                    e.env.pop();
                    return Err(err);
                }
            };
            if s == 0 || (s > 0 && v >= end) || (s < 0 && v <= end) {
                e.env.pop();
                break;
            }
            let r = l.body.iter().try_for_each(|c| e.node(c, iters));
            e.env.pop();
            r?;
            iters += 1;
            v += s;
        }
        Ok(iters as usize)
    })();
    let (iters, certificate) = match done {
        Ok(iters) => {
            let cert = match e.fp.g {
                0 => Certificate::Doall,
                1 => Certificate::Sequential,
                d => Certificate::Doacross { delta: d },
            };
            (iters, cert)
        }
        Err(Obstacle::InputDependent(reason)) => (0, Certificate::InputDependent { reason }),
        Err(Obstacle::Budget) => (0, Certificate::BudgetExceeded),
    };
    (
        LoopInspection {
            loop_id: l.id,
            var: l.var,
            iters,
            certificate,
        },
        e.evals,
    )
}

/// Inspect every top-level [`LoopSchedule::Sequential`] loop of `p`
/// under the concrete `params` binding. Loops already scheduled parallel
/// (statically proven) are left alone; nested loops are enumerated as
/// part of their top-level ancestor's footprint.
pub fn inspect_program(p: &Program, params: &[(Sym, i64)], budget: usize) -> InspectReport {
    let mut loops = Vec::new();
    let mut evals = 0usize;
    for n in &p.body {
        let Some(l) = n.as_loop() else { continue };
        if l.schedule != LoopSchedule::Sequential {
            continue;
        }
        let remaining = budget.saturating_sub(evals).max(1);
        let (insp, spent) = inspect_loop(p, l, params, remaining);
        evals += spent;
        loops.push(insp);
    }
    InspectReport {
        kernel: p.name.clone(),
        params: params.to_vec(),
        loops,
        evals,
    }
}

/// Re-schedule a clone of `p` according to `report`: `Doall` loops
/// become [`LoopSchedule::Parallel`]; `Doacross{δ≥2}` loops become
/// [`LoopSchedule::Doacross`] waiting `δ` iterations before their first
/// body statement (only when the body *starts* with a statement — the
/// lowered wait anchors on a direct child). Everything else is left
/// untouched. Returns `None` when no certificate changes a schedule.
pub fn apply_certificates(p: &Program, report: &InspectReport) -> Option<Program> {
    let mut q = p.clone();
    let mut changed = false;
    for insp in &report.loops {
        for n in &mut q.body {
            let Node::Loop(l) = n else { continue };
            if l.id != insp.loop_id {
                continue;
            }
            match &insp.certificate {
                Certificate::Doall => {
                    l.schedule = LoopSchedule::Parallel;
                    changed = true;
                }
                Certificate::Doacross { delta } if *delta >= 2 => {
                    let first_stmt = l.body.first().and_then(|c| c.as_stmt()).map(|s| s.id);
                    if let Some(sid) = first_stmt {
                        l.schedule = LoopSchedule::Doacross {
                            waits: vec![WaitSpec {
                                before_stmt: sid,
                                delta: *delta,
                            }],
                            release: ReleaseSpec::EndOfBody,
                        };
                        changed = true;
                    }
                }
                _ => {}
            }
        }
    }
    if changed {
        Some(q)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::ProgramBuilder;
    use crate::symbolic::{imod, int, load, Expr};

    /// `A[i mod 8] = …` over 32 iterations: every element is rewritten
    /// at stride-8 distance — an exact DOACROSS certificate.
    #[test]
    fn mod_strided_writes_certify_doacross_with_exact_distance() {
        let mut b = ProgramBuilder::new("ins_mod");
        let a = b.array("A", int(8));
        let x = b.array("X", int(32));
        let i = b.sym("ins_i");
        b.for_(i, int(0), int(32), int(1), |b| {
            b.assign(a, imod(Expr::Sym(i), int(8)), load(x, Expr::Sym(i)));
        });
        let p = b.finish();
        let rep = inspect_program(&p, &[], DEFAULT_BUDGET);
        assert_eq!(rep.loops.len(), 1);
        assert_eq!(rep.loops[0].certificate, Certificate::Doacross { delta: 8 });
        assert_eq!(rep.loops[0].iters, 32);
    }

    /// Disjoint writes certify DOALL; the re-scheduled clone flips only
    /// the certified loop.
    #[test]
    fn disjoint_writes_certify_doall_and_apply_flips_the_schedule() {
        let mut b = ProgramBuilder::new("ins_doall");
        let a = b.array("A", int(64));
        let x = b.array("X", int(64));
        let i = b.sym("ins_j");
        b.for_(i, int(0), int(64), int(1), |b| {
            b.assign(a, Expr::Sym(i), load(x, Expr::Sym(i)));
        });
        let p = b.finish();
        let rep = inspect_program(&p, &[], DEFAULT_BUDGET);
        assert_eq!(rep.loops[0].certificate, Certificate::Doall);
        let q = apply_certificates(&p, &rep).expect("a certificate applied");
        assert_eq!(q.body[0].as_loop().unwrap().schedule, LoopSchedule::Parallel);
        // The original program is untouched.
        assert_eq!(p.body[0].as_loop().unwrap().schedule, LoopSchedule::Sequential);
    }

    /// A value-dependent subscript (`A[X[i]] = …`) is not enumerable from
    /// parameters: the inspector must refuse, never guess.
    #[test]
    fn value_dependent_subscripts_are_input_dependent() {
        let mut b = ProgramBuilder::new("ins_vdep");
        let a = b.array("A", int(64));
        let x = b.array("X", int(64));
        let i = b.sym("ins_k");
        b.for_(i, int(0), int(64), int(1), |b| {
            b.assign(a, load(x, Expr::Sym(i)), Expr::real(1.0));
        });
        let p = b.finish();
        let rep = inspect_program(&p, &[], DEFAULT_BUDGET);
        assert!(
            matches!(rep.loops[0].certificate, Certificate::InputDependent { .. }),
            "{:?}",
            rep.loops[0].certificate
        );
        assert!(apply_certificates(&p, &rep).is_none());
    }

    /// An accumulator read+written every iteration has unit distance:
    /// sequential, never a false DOALL.
    #[test]
    fn reductions_stay_sequential() {
        let mut b = ProgramBuilder::new("ins_red");
        let acc = b.array("ACC", int(1));
        let x = b.array("X", int(16));
        let i = b.sym("ins_r");
        b.for_(i, int(0), int(16), int(1), |b| {
            b.assign(acc, int(0), load(acc, int(0)) + load(x, Expr::Sym(i)));
        });
        let p = b.finish();
        let rep = inspect_program(&p, &[], DEFAULT_BUDGET);
        assert_eq!(rep.loops[0].certificate, Certificate::Sequential);
    }

    /// The budget is a hard cap, reported as such.
    #[test]
    fn budget_exhaustion_is_reported_not_stalled() {
        let mut b = ProgramBuilder::new("ins_budget");
        let a = b.array("A", int(1 << 16));
        let i = b.sym("ins_b");
        b.for_(i, int(0), int(1 << 16), int(1), |b| {
            b.assign(a, Expr::Sym(i), Expr::real(0.0));
        });
        let p = b.finish();
        let rep = inspect_program(&p, &[], 64);
        assert_eq!(rep.loops[0].certificate, Certificate::BudgetExceeded);
    }
}
