//! PJRT runtime: loads `artifacts/*.hlo.txt` (AOT-lowered by
//! python/compile/aot.py) and executes them as numerical oracles.

pub mod pjrt;

pub use pjrt::{ArtifactMeta, Executable, Oracle};
