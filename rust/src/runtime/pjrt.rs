//! PJRT oracle client: loads AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT plugin.
//!
//! This is the runtime half of the three-layer architecture: python runs
//! once at build time (`make artifacts`); the rust coordinator uses the
//! compiled executables as *numerical oracles* for the optimizer's output
//! (and as the end-to-end validation path in examples/). Interchange is
//! HLO **text** — see /opt/xla-example/README.md for why serialized protos
//! from jax ≥ 0.5 are rejected by xla_extension 0.5.1.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

/// A loaded, compiled artifact.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
    pub input_shapes: Vec<Vec<i64>>,
}

/// The PJRT client plus the artifact registry.
pub struct Oracle {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: HashMap<String, ArtifactMeta>,
    cache: HashMap<String, std::rc::Rc<Executable>>,
}

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub inputs: Vec<Vec<i64>>,
    pub path: String,
}

impl Oracle {
    /// Open the artifact directory (default `./artifacts`, override with
    /// `SILO_ARTIFACTS`).
    pub fn open_default() -> Result<Oracle> {
        let dir = std::env::var("SILO_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Oracle::open(Path::new(&dir))
    }

    pub fn open(dir: &Path) -> Result<Oracle> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        let manifest_path = dir.join("manifest.json");
        let manifest = if manifest_path.exists() {
            parse_manifest(&std::fs::read_to_string(&manifest_path)?)?
        } else {
            HashMap::new()
        };
        Ok(Oracle {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: HashMap::new(),
        })
    }

    pub fn available(&self) -> Vec<String> {
        let mut v: Vec<String> = self.manifest.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn has(&self, name: &str) -> bool {
        self.manifest.contains_key(name)
    }

    /// Load + compile an artifact (cached).
    pub fn load(&mut self, name: &str) -> Result<std::rc::Rc<Executable>> {
        if let Some(e) = self.cache.get(name) {
            return Ok(e.clone());
        }
        let meta = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} not in manifest (run `make artifacts`)"))?
            .clone();
        let path = self.dir.join(&meta.path);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        let exec = std::rc::Rc::new(Executable {
            exe,
            name: name.to_string(),
            input_shapes: meta.inputs.clone(),
        });
        self.cache.insert(name.to_string(), exec.clone());
        Ok(exec)
    }

    /// Execute an artifact on f64 inputs; returns the tuple elements as
    /// flat f64 vectors.
    pub fn run(&mut self, name: &str, inputs: &[&[f64]]) -> Result<Vec<Vec<f64>>> {
        let exec = self.load(name)?;
        if inputs.len() != exec.input_shapes.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                exec.input_shapes.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs.iter().zip(&exec.input_shapes) {
            let expect: i64 = shape.iter().product();
            if expect != data.len() as i64 {
                bail!("{name}: input length {} != shape {:?}", data.len(), shape);
            }
            let lit = xla::Literal::vec1(data)
                .reshape(shape)
                .map_err(|e| anyhow!("reshape: {e:?}"))?;
            literals.push(lit);
        }
        let result = exec
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e:?}"))?;
        // aot.py lowers with return_tuple=True.
        let parts = lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f64>().map_err(|e| anyhow!("to_vec: {e:?}"))?);
        }
        Ok(out)
    }
}

/// Minimal JSON parsing for the manifest (no serde in the vendored set).
/// Format written by aot.py:
/// `{"name": {"inputs": [[..],..], "dtype": "float64", "path": "..."}}`.
fn parse_manifest(text: &str) -> Result<HashMap<String, ArtifactMeta>> {
    let mut out = HashMap::new();
    let mut rest = text;
    while let Some(kstart) = rest.find('"') {
        let after = &rest[kstart + 1..];
        let Some(kend) = after.find('"') else { break };
        let key = &after[..kend];
        let after_key = &after[kend + 1..];
        // Values we need: inputs [[...]] and path "..."
        let Some(obj_start) = after_key.find('{') else {
            break;
        };
        let Some(obj_end) = after_key.find('}') else {
            break;
        };
        let obj = &after_key[obj_start..obj_end];
        let inputs = parse_inputs(obj)?;
        let path = obj
            .split("\"path\"")
            .nth(1)
            .and_then(|s| s.split('"').nth(1))
            .ok_or_else(|| anyhow!("manifest entry {key} missing path"))?
            .to_string();
        out.insert(key.to_string(), ArtifactMeta { inputs, path });
        rest = &after_key[obj_end + 1..];
    }
    Ok(out)
}

fn parse_inputs(obj: &str) -> Result<Vec<Vec<i64>>> {
    let seg = obj
        .split("\"inputs\"")
        .nth(1)
        .ok_or_else(|| anyhow!("manifest entry missing inputs"))?;
    let start = seg.find('[').ok_or_else(|| anyhow!("bad inputs"))?;
    // Find matching close bracket.
    let mut depth = 0;
    let mut end = start;
    for (i, ch) in seg[start..].char_indices() {
        match ch {
            '[' => depth += 1,
            ']' => {
                depth -= 1;
                if depth == 0 {
                    end = start + i;
                    break;
                }
            }
            _ => {}
        }
    }
    let inner = &seg[start + 1..end];
    let mut out = Vec::new();
    for shape in inner.split('[').skip(1) {
        let nums = shape.split(']').next().unwrap_or("");
        let dims: Result<Vec<i64>, _> = nums
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .map(|s| s.trim().parse::<i64>())
            .collect();
        out.push(dims?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let text = r#"{
  "vadv_tiny": {"inputs": [[8, 5, 6], [8, 5, 6], [8, 5, 6], [8, 5, 6]], "dtype": "float64", "path": "vadv_tiny.hlo.txt"},
  "laplace_tiny": {"inputs": [[14, 16]], "dtype": "float64", "path": "laplace_tiny.hlo.txt"}
}"#;
        let m = parse_manifest(text).unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m["vadv_tiny"].inputs.len(), 4);
        assert_eq!(m["vadv_tiny"].inputs[0], vec![8, 5, 6]);
        assert_eq!(m["laplace_tiny"].path, "laplace_tiny.hlo.txt");
    }
}
