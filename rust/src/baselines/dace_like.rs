//! DaCe-auto-opt-like baseline (§6.1: "DaCe fails to perform any tiling or
//! vectorization, but fuses many loops together, which results in some
//! arrays being converted to temporary scalars … and consequently only
//! extracts parallelism across the I and J dimensions").
//!
//! Pipeline: fusion + scalarization, then DOALL marking. Crucially it does
//! **not** run SILO's privatization/input-copy passes, so WAW/RAW-carrying
//! loops (the K dimension) stay sequential.

use anyhow::Result;

use crate::ir::Program;
use crate::transforms::{fuse_program, parallelize_doall, PipelineReport};

/// Run the DaCe-like auto optimizer.
pub fn dace_auto_optimize(p: &mut Program) -> Result<PipelineReport> {
    let mut report = PipelineReport::default();
    let fu = fuse_program(p)?;
    if fu.fused > 0 || !fu.scalarized.is_empty() {
        report.log.push(crate::transforms::pass::PassLog {
            pass: "fusion".into(),
            detail: format!(
                "fused {} loops, scalarized {}",
                fu.fused,
                fu.scalarized.len()
            ),
        });
    }
    let da = parallelize_doall(p, true)?;
    if !da.parallelized.is_empty() {
        report.log.push(crate::transforms::pass::PassLog {
            pass: "doall".into(),
            detail: format!("{} loops", da.parallelized.len()),
        });
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{LoopSchedule, ProgramBuilder};
    use crate::symbolic::{int, load, Expr};

    /// On a vadv-shaped nest, DaCe parallelizes I but leaves K sequential
    /// (no privatization pass).
    #[test]
    fn k_dimension_stays_sequential() {
        let mut b = ProgramBuilder::new("dace1");
        let n = b.param_positive("dace1_N");
        let m = b.dim_param("dace1_M");
        let a = b.transient("A", Expr::Sym(n));
        let bb = b.array("B", Expr::Sym(n) * Expr::Sym(m));
        let k = b.sym("dace1_k");
        let i = b.sym("dace1_i");
        b.for_(k, int(1), Expr::Sym(m), int(1), |b| {
            b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
                let off = Expr::Sym(i) * Expr::Sym(m) + Expr::Sym(k);
                b.assign(a, Expr::Sym(i), load(bb, off.clone() - int(1)) * Expr::real(0.3));
                b.assign(bb, off, load(a, Expr::Sym(i)));
            });
        });
        let mut p = b.finish();
        dace_auto_optimize(&mut p).unwrap();
        let loops = p.loops();
        // K sequential (WAW on A), I parallel? The WAW on A also blocks I?
        // No: within one i-iteration A[i] is written then read (self-
        // contained), and distinct i's touch distinct A[i] ⇒ i is DOALL.
        assert!(matches!(loops[0].schedule, LoopSchedule::Sequential));
        assert!(loops[1].is_parallel());
    }
}
