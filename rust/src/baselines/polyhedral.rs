//! Polly-like and Pluto-like baselines.
//!
//! Both demand a SCoP: constant integer strides and affine bounds/accesses
//! (see [`crate::analysis::affine`]). Outside a SCoP they perform **no
//! optimization** — Fig. 1's "No optimization (multivariate polynomial)".
//! Inside a SCoP they tile and parallelize dependence-free dimensions but
//! never change data allocation, so WAW/WAR-carrying loops stay
//! sequential (the §6.1 failure mode on vertical advection).

use anyhow::Result;

use crate::analysis::classify_program;
use crate::ir::{LoopId, LoopSchedule, Node, Program};
use crate::transforms::{parallelize_doall, tile};

/// What the polyhedral tool did.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PolyhedralOutcome {
    /// Not a SCoP: tool bails, program untouched (Fig. 1 / Fig. 2).
    Rejected { reason: String },
    /// SCoP detected and optimized.
    Optimized {
        parallelized: Vec<LoopId>,
        tiled: Vec<LoopId>,
    },
}

/// Polly-like: SCoP check → (optional) tiling of parallel band → DOALL
/// marking of dependence-free loops. `-polly-parallel` behavior.
pub fn polly_like(p: &mut Program) -> Result<PolyhedralOutcome> {
    run_polyhedral(p, /*tile_size*/ Some(32), /*multipar*/ false)
}

/// Pluto-like (`--parallel --multipar`): same SCoP restriction, tiles and
/// parallelizes *multiple* dependence-free dimensions where present.
pub fn pluto_like(p: &mut Program) -> Result<PolyhedralOutcome> {
    run_polyhedral(p, Some(32), true)
}

fn run_polyhedral(
    p: &mut Program,
    tile_size: Option<i64>,
    multipar: bool,
) -> Result<PolyhedralOutcome> {
    let report = classify_program(p);
    if !report.is_scop() {
        return Ok(PolyhedralOutcome::Rejected {
            reason: format!("{:?}", report.violations[0]),
        });
    }

    // Parallelism is decided on the *original* nest (the polyhedral
    // schedule legality is computed before tiling); Pluto's --multipar
    // additionally parallelizes nested free dimensions.
    let rep = parallelize_doall(p, !multipar)?;

    // Then tile the parallel bands for locality (the tile loop keeps the
    // parallel schedule; the intra-tile loop runs sequentially).
    let mut tiled = Vec::new();
    if let Some(ts) = tile_size {
        let candidates: Vec<LoopId> = p
            .loops()
            .iter()
            .filter(|l| l.is_parallel() && l.stride.as_int() == Some(1))
            .map(|l| l.id)
            .collect();
        // Tile at most the two outermost parallel loops (rectangular
        // tiling; deeper tiling rarely changes the comparison).
        for id in candidates.into_iter().take(2) {
            if let Ok(tl) = tile(p, id, ts) {
                tiled.push(tl);
            }
        }
    }
    Ok(PolyhedralOutcome::Optimized {
        parallelized: rep.parallelized,
        tiled,
    })
}

/// Did the baseline leave every loop over container-carried dependencies
/// sequential? (Test/report helper.)
pub fn sequential_loop_count(p: &Program) -> usize {
    p.loops()
        .iter()
        .filter(|l| matches!(l.schedule, LoopSchedule::Sequential))
        .count()
}

/// All loops in the program (report helper).
pub fn parallel_loop_count(p: &Program) -> usize {
    p.loops().iter().filter(|l| l.is_parallel()).count()
}

/// Does any statement sit under a parallel loop? (coarse coverage check)
pub fn has_parallel_coverage(p: &Program) -> bool {
    fn walk(nodes: &[Node], under: bool) -> bool {
        for n in nodes {
            match n {
                Node::Stmt(_) if under => return true,
                Node::Stmt(_) => {}
                Node::Loop(l) => {
                    if walk(&l.body, under || l.is_parallel()) {
                        return true;
                    }
                }
            }
        }
        false
    }
    walk(&p.body, false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::ProgramBuilder;
    use crate::symbolic::{int, load, Expr};

    /// Fig. 1: parametric-stride Laplace is rejected outright.
    #[test]
    fn parametric_strides_rejected() {
        let mut b = ProgramBuilder::new("poly1");
        let n = b.param_positive("poly1_N");
        let is_i = b.param_positive("poly1_isI");
        let a = b.array("A", (Expr::Sym(n) + int(2)) * Expr::Sym(is_i));
        let i = b.sym("poly1_i");
        b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
            b.assign(a, Expr::Sym(i) * Expr::Sym(is_i), Expr::real(1.0));
        });
        let mut p = b.finish();
        let before = p.clone();
        match polly_like(&mut p).unwrap() {
            PolyhedralOutcome::Rejected { .. } => {}
            other => panic!("expected rejection, got {other:?}"),
        }
        // Untouched.
        assert_eq!(p.loops().len(), before.loops().len());
        assert_eq!(sequential_loop_count(&p), 1);
    }

    /// Affine stencil: accepted, tiled, parallelized.
    #[test]
    fn affine_scop_optimized() {
        let mut b = ProgramBuilder::new("poly2");
        let n = b.param_positive("poly2_N");
        let a = b.array("A", Expr::Sym(n) * int(512));
        let x = b.array("X", Expr::Sym(n) * int(512));
        let i = b.sym("poly2_i");
        let j = b.sym("poly2_j");
        b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
            b.for_(j, int(0), int(512), int(1), |b| {
                let off = int(512) * Expr::Sym(i) + Expr::Sym(j);
                b.assign(a, off.clone(), load(x, off) * Expr::real(2.0));
            });
        });
        let mut p = b.finish();
        match pluto_like(&mut p).unwrap() {
            PolyhedralOutcome::Optimized { parallelized, tiled } => {
                assert!(!parallelized.is_empty());
                assert!(!tiled.is_empty());
            }
            other => panic!("{other:?}"),
        }
        crate::ir::validate::validate(&p).unwrap();
    }

    /// Vertical-advection shape: SCoP accepted (multidim notation — the
    /// row stride is the declared extent N) but the K recurrence keeps K
    /// sequential — only I parallelizes (the §6.1 baseline behavior).
    #[test]
    fn waw_keeps_k_sequential() {
        let mut b = ProgramBuilder::new("poly3");
        let n = b.dim_param("poly3_N");
        let kk = b.dim_param("poly3_K");
        let a = b.array("A", Expr::Sym(kk) * Expr::Sym(n));
        let k = b.sym("poly3_k");
        let i = b.sym("poly3_i");
        b.for_(k, int(1), Expr::Sym(kk), int(1), |b| {
            b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
                // A[k][i] / A[k-1][i] in multidim notation.
                let cur = Expr::Sym(n) * Expr::Sym(k) + Expr::Sym(i);
                let prev = Expr::Sym(n) * (Expr::Sym(k) - int(1)) + Expr::Sym(i);
                b.assign(a, cur, load(a, prev) * Expr::real(0.5));
            });
        });
        let mut p = b.finish();
        match pluto_like(&mut p).unwrap() {
            PolyhedralOutcome::Optimized { parallelized, .. } => {
                // i parallelized, k not.
                let k_loop = p.loops()[0].clone();
                assert!(matches!(k_loop.schedule, LoopSchedule::Sequential));
                assert!(!parallelized.is_empty());
            }
            other => panic!("{other:?}"),
        }
    }
}
