//! Baseline optimizer models (DESIGN.md §Substitutions).
//!
//! Each baseline is a pass pipeline over the same IR that enforces the
//! corresponding tool's *documented restrictions* — the paper's
//! comparisons hinge on what each tool refuses to do (reject non-affine
//! strides, never change data allocation), so encoding the refusal rules
//! reproduces the crossovers without shipping LLVM/Pluto/ICC.

pub mod dace_like;
pub mod icc_like;
pub mod polyhedral;

pub use dace_like::dace_auto_optimize;
pub use icc_like::icc_auto_parallelize;
pub use polyhedral::{pluto_like, polly_like, PolyhedralOutcome};
