//! icc-like auto-parallelization baseline: `-parallel` behavior — outer
//! loops whose dependence test proves independence get DOALL; any
//! *possible* dependence (including symbolic strides it cannot reason
//! about) reports "loop was not parallelized: existence of parallel
//! dependence" and stays sequential. No data-allocation changes, no
//! pipelining.

use anyhow::Result;

use crate::analysis::{loop_deps, DepDistance};
use crate::ir::{LoopId, LoopSchedule, Node, Program};

/// Outcome per considered loop.
#[derive(Debug, Clone)]
pub struct IccReport {
    pub parallelized: Vec<LoopId>,
    pub refused: Vec<(LoopId, &'static str)>,
}

/// Run the icc model. Unlike SILO it additionally *refuses* loops whose
/// bounds or strides are not compile-time analyzable (symbolic stride
/// expressions defeat its dependence test — Fig. 1's "Fails
/// parallelization").
pub fn icc_auto_parallelize(p: &mut Program) -> Result<IccReport> {
    let mut report = IccReport {
        parallelized: Vec::new(),
        refused: Vec::new(),
    };
    let containers = p.containers.clone();
    let dim_syms = p.dim_syms.clone();
    fn walk(
        nodes: &mut [Node],
        containers: &[crate::ir::Container],
        dim_syms: &[crate::symbolic::Sym],
        under_parallel: bool,
        report: &mut IccReport,
    ) {
        for n in nodes {
            if let Node::Loop(l) = n {
                let mut now_parallel = under_parallel;
                if !under_parallel && matches!(l.schedule, LoopSchedule::Sequential) {
                    // icc's test: constant stride required.
                    if l.stride.as_int().is_none() {
                        report.refused.push((l.id, "non-constant stride"));
                    } else {
                        let deps = loop_deps(l, containers);
                        if deps.is_doall() {
                            // Parametric-stride offsets: icc's dependence
                            // test gives up on symbolic coefficient
                            // products even when independent — model via
                            // the affinity classifier.
                            let affine =
                                crate::analysis::affine::classify_nest_with(l, &[], dim_syms)
                                    .is_scop();
                            if affine {
                                l.schedule = LoopSchedule::Parallel;
                                report.parallelized.push(l.id);
                                now_parallel = true;
                            } else {
                                report.refused.push((l.id, "unanalyzable subscripts"));
                            }
                        } else if deps
                            .deps
                            .iter()
                            .all(|d| matches!(d.distance, DepDistance::Constant(_)))
                        {
                            report.refused.push((l.id, "parallel dependence"));
                        } else {
                            report.refused.push((l.id, "assumed dependence"));
                        }
                    }
                }
                walk(&mut l.body, containers, dim_syms, now_parallel, report);
            }
        }
    }
    walk(&mut p.body, &containers, &dim_syms, false, &mut report);
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::ProgramBuilder;
    use crate::symbolic::{int, load, Expr};

    #[test]
    fn parallelizes_clean_affine_loop() {
        let mut b = ProgramBuilder::new("icc1");
        let n = b.param_positive("icc1_N");
        let a = b.array("A", Expr::Sym(n));
        let x = b.array("X", Expr::Sym(n));
        let i = b.sym("icc1_i");
        b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
            b.assign(a, Expr::Sym(i), load(x, Expr::Sym(i)));
        });
        let mut p = b.finish();
        let rep = icc_auto_parallelize(&mut p).unwrap();
        assert_eq!(rep.parallelized.len(), 1);
    }

    #[test]
    fn refuses_parametric_strides_even_when_independent() {
        // Fig. 1: independent but multivariate-polynomial subscripts.
        let mut b = ProgramBuilder::new("icc2");
        let n = b.param_positive("icc2_N");
        let s = b.param_positive("icc2_S");
        let a = b.array("A", Expr::Sym(n) * Expr::Sym(s));
        let i = b.sym("icc2_i");
        b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
            b.assign(a, Expr::Sym(i) * Expr::Sym(s), Expr::real(1.0));
        });
        let mut p = b.finish();
        let rep = icc_auto_parallelize(&mut p).unwrap();
        assert!(rep.parallelized.is_empty());
        assert_eq!(rep.refused[0].1, "unanalyzable subscripts");
    }

    #[test]
    fn refuses_recurrence() {
        let mut b = ProgramBuilder::new("icc3");
        let n = b.param_positive("icc3_N");
        let a = b.array("A", Expr::Sym(n));
        let i = b.sym("icc3_i");
        b.for_(i, int(1), Expr::Sym(n), int(1), |b| {
            b.assign(a, Expr::Sym(i), load(a, Expr::Sym(i) - int(1)));
        });
        let mut p = b.finish();
        let rep = icc_auto_parallelize(&mut p).unwrap();
        assert!(rep.parallelized.is_empty());
        assert_eq!(rep.refused[0].1, "parallel dependence");
    }
}
