//! Vertical advection — the paper's headline workload (§6.1, Fig. 8/9).
//!
//! A Thomas-algorithm tridiagonal solve over an `I × J × K` domain (K
//! vertical): a forward sweep with the classic `cp/dp` recurrence across
//! K, a column-buffer output stage, and a backward substitution. The
//! dependence structure is exactly the one the paper evaluates:
//!
//! * `cp`, `dp`, `x`: **RAW δ=1** across K (forward and backward) — the
//!   sequential chains cfg2 pipelines with wait/release;
//! * `col` (a 2-D scratch overwritten every K step): **WAW** across K —
//!   what keeps Polly/Pluto/icc/DaCe from touching the K dimension and
//!   what cfg1's privatization (§3.2.1) removes;
//! * the I/J nests inside each stage are embarrassingly DOALL.

use crate::ir::{Program, ProgramBuilder};
use crate::symbolic::{fdiv, int, load, Expr, Sym};

use super::Preset;

/// Arrays are `[I][J][K]` with **K contiguous** (NPBench's layout — the
/// reason moving K innermost pays: K-outer sweeps touch every cache line
/// of the volume once per k step, K-inner streams each line once).
/// Extents are dim-params so the polyhedral baselines accept the kernel
/// as a SCoP (§6.1's "compatible multidimensional array notation").
pub fn build() -> Program {
    let mut b = ProgramBuilder::new("vadv");
    let ii = b.dim_param("vadv_I");
    let jj = b.dim_param("vadv_J");
    let kk = b.dim_param("vadv_K");
    let (iie, jje, kke) = (Expr::Sym(ii), Expr::Sym(jj), Expr::Sym(kk));
    let vol = kke.clone() * jje.clone() * iie.clone();
    let plane = jje.clone() * iie.clone();
    let row = kke.clone(); // K contiguous
    let slab = jje.clone() * kke.clone();

    let a = b.array("a", vol.clone());
    let bb = b.array("b", vol.clone());
    let c = b.array("c", vol.clone());
    let d = b.array("d", vol.clone());
    let cp = b.transient("cp", vol.clone());
    let dp = b.transient("dp", vol.clone());
    let col = b.transient("col", plane.clone());
    let utens = b.array("utens", vol.clone());
    let x = b.array("x", vol.clone());

    let _k = b.sym("vadv_k");
    let j = b.sym("vadv_j");
    let i = b.sym("vadv_i");
    let at = |kv: Expr, jv: Expr, iv: Expr| iv * slab.clone() + jv * row.clone() + kv;

    // --- k = 0 boundary: cp[0] = c/b, dp[0] = d/b -------------------------
    b.for_(j, int(0), jje.clone(), int(1), |b| {
        b.for_(i, int(0), iie.clone(), int(1), |b| {
            let o = at(int(0), Expr::Sym(j), Expr::Sym(i));
            b.assign(cp, o.clone(), fdiv(load(c, o.clone()), load(bb, o.clone())));
        });
    });
    b.for_(j, int(0), jje.clone(), int(1), |b| {
        b.for_(i, int(0), iie.clone(), int(1), |b| {
            let o = at(int(0), Expr::Sym(j), Expr::Sym(i));
            b.assign(dp, o.clone(), fdiv(load(d, o.clone()), load(bb, o.clone())));
        });
    });

    // --- forward sweep: k = 1 .. K ---------------------------------------
    // Sibling nests reuse the same j/i variables (as real code does) so
    // the cross-nest analyses unify their normalized iteration spaces.
    let kf = b.sym("vadv_kf");
    let (jf1, if1) = (j, i);
    let (jf2, if2) = (j, i);
    let (jf3, if3) = (j, i);
    let (jf4, if4) = (j, i);
    b.for_(kf, int(1), kke.clone(), int(1), |b| {
        let kv = Expr::Sym(kf);
        // Nest A: cp[k] = c[k] / (b[k] − a[k]·cp[k−1])   (RAW δ=1 on cp)
        b.for_(jf1, int(0), jje.clone(), int(1), |b| {
            b.for_(if1, int(0), iie.clone(), int(1), |b| {
                let o = at(kv.clone(), Expr::Sym(jf1), Expr::Sym(if1));
                let prev = at(kv.clone() - int(1), Expr::Sym(jf1), Expr::Sym(if1));
                let den = load(bb, o.clone()) - load(a, o.clone()) * load(cp, prev);
                b.assign(cp, o.clone(), fdiv(load(c, o.clone()), den));
            });
        });
        // Nest B: dp[k] = (d[k] − a[k]·dp[k−1]) / (b[k] − a[k]·cp[k−1])
        b.for_(jf2, int(0), jje.clone(), int(1), |b| {
            b.for_(if2, int(0), iie.clone(), int(1), |b| {
                let o = at(kv.clone(), Expr::Sym(jf2), Expr::Sym(if2));
                let prev = at(kv.clone() - int(1), Expr::Sym(jf2), Expr::Sym(if2));
                let den = load(bb, o.clone()) - load(a, o.clone()) * load(cp, prev.clone());
                b.assign(
                    dp,
                    o.clone(),
                    fdiv(load(d, o.clone()) - load(a, o.clone()) * load(dp, prev), den),
                );
            });
        });
        // Nest C: col[j,i] = 0.25·a[k] + 0.5·b[k]   (2-D scratch → WAW over k)
        b.for_(jf3, int(0), jje.clone(), int(1), |b| {
            b.for_(if3, int(0), iie.clone(), int(1), |b| {
                let o = at(kv.clone(), Expr::Sym(jf3), Expr::Sym(if3));
                let po = Expr::Sym(jf3) * iie.clone() + Expr::Sym(if3);
                b.assign(
                    col,
                    po,
                    Expr::real(0.25) * load(a, o.clone()) + Expr::real(0.5) * load(bb, o),
                );
            });
        });
        // Nest D: utens[k] = 0.1·dp[k] + col[j,i]   (consumes the scratch)
        b.for_(jf4, int(0), jje.clone(), int(1), |b| {
            b.for_(if4, int(0), iie.clone(), int(1), |b| {
                let o = at(kv.clone(), Expr::Sym(jf4), Expr::Sym(if4));
                let po = Expr::Sym(jf4) * iie.clone() + Expr::Sym(if4);
                b.assign(
                    utens,
                    o.clone(),
                    Expr::real(0.1) * load(dp, o) + load(col, po),
                );
            });
        });
    });

    // --- backward substitution: x[K−1] = dp[K−1]; descending recurrence --
    let (jb0, ib0) = (j, i);
    b.for_(jb0, int(0), jje.clone(), int(1), |b| {
        b.for_(ib0, int(0), iie.clone(), int(1), |b| {
            let o = at(kke.clone() - int(1), Expr::Sym(jb0), Expr::Sym(ib0));
            b.assign(x, o.clone(), load(dp, o));
        });
    });
    let kb = b.sym("vadv_kb");
    let (jb, ib) = (j, i);
    b.for_(kb, kke.clone() - int(2), int(-1), int(-1), |b| {
        let kv = Expr::Sym(kb);
        b.for_(jb, int(0), jje.clone(), int(1), |b| {
            b.for_(ib, int(0), iie.clone(), int(1), |b| {
                let o = at(kv.clone(), Expr::Sym(jb), Expr::Sym(ib));
                let next = at(kv.clone() + int(1), Expr::Sym(jb), Expr::Sym(ib));
                b.assign(x, o.clone(), load(dp, o.clone()) - load(cp, o) * load(x, next));
            });
        });
    });
    b.finish()
}

pub fn preset(p: Preset) -> Vec<(Sym, i64)> {
    let (i, j, k) = match p {
        Preset::Tiny => (6, 5, 8),
        Preset::Small => (32, 32, 45),
        Preset::Medium => (64, 64, 90),
    };
    vec![
        (Sym::new("vadv_I"), i),
        (Sym::new("vadv_J"), j),
        (Sym::new("vadv_K"), k),
    ]
}

/// Diagonally dominant tridiagonal system: |b| > |a| + |c| keeps the
/// Thomas recurrence well conditioned.
pub fn init(name: &str, i: usize) -> f64 {
    let pat = super::default_init(name, i); // in [-0.5, 0.5)
    match name {
        "b" => 2.5 + pat,         // ≥ 2.0
        "a" | "c" => 0.4 * pat,   // |·| ≤ 0.2
        _ => pat,
    }
}

/// Pure-Rust oracle computing the same Thomas solve (used by tests and the
/// e2e example to validate the VM against an independent implementation;
/// the PJRT artifact provides a second, JAX-derived oracle).
pub fn reference(
    iv: usize,
    jv: usize,
    kv: usize,
    a: &[f64],
    b: &[f64],
    c: &[f64],
    d: &[f64],
) -> (Vec<f64>, Vec<f64>) {
    let plane = iv * jv;
    let vol = plane * kv;
    // [I][J][K], K contiguous.
    let at = |k: usize, j: usize, i: usize| (i * jv + j) * kv + k;
    let mut cp = vec![0.0; vol];
    let mut dp = vec![0.0; vol];
    let mut col = vec![0.0; plane];
    let mut utens = vec![0.0; vol];
    let mut x = vec![0.0; vol];
    for j in 0..jv {
        for i in 0..iv {
            let o = at(0, j, i);
            cp[o] = c[o] / b[o];
            dp[o] = d[o] / b[o];
        }
    }
    for k in 1..kv {
        for j in 0..jv {
            for i in 0..iv {
                let o = at(k, j, i);
                let p = at(k - 1, j, i);
                let den = b[o] - a[o] * cp[p];
                cp[o] = c[o] / den;
            }
        }
        for j in 0..jv {
            for i in 0..iv {
                let o = at(k, j, i);
                let p = at(k - 1, j, i);
                let den = b[o] - a[o] * cp[p];
                dp[o] = (d[o] - a[o] * dp[p]) / den;
            }
        }
        for j in 0..jv {
            for i in 0..iv {
                let o = at(k, j, i);
                col[j * iv + i] = 0.25 * a[o] + 0.5 * b[o];
            }
        }
        for j in 0..jv {
            for i in 0..iv {
                let o = at(k, j, i);
                utens[o] = 0.1 * dp[o] + col[j * iv + i];
            }
        }
    }
    for j in 0..jv {
        for i in 0..iv {
            let o = at(kv - 1, j, i);
            x[o] = dp[o];
        }
    }
    for k in (0..kv - 1).rev() {
        for j in 0..jv {
            for i in 0..iv {
                let o = at(k, j, i);
                let n = at(k + 1, j, i);
                x[o] = dp[o] - cp[o] * x[n];
            }
        }
    }
    (x, utens)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{loop_deps, DepKind};
    use crate::exec::Vm;
    use crate::kernels::gen_inputs;
    use crate::transforms::{silo_cfg1, silo_cfg2};

    fn run(p: &Program, threads: usize) -> (Vec<f64>, Vec<f64>) {
        let params = preset(Preset::Tiny);
        let inputs = gen_inputs(p, &params, init).unwrap();
        let refs: Vec<(crate::symbolic::ContainerId, &[f64])> = inputs
            .iter()
            .map(|(c, v)| (*c, v.as_slice()))
            .collect();
        let vm = Vm::compile(p).unwrap();
        let out = vm.run(&params, &refs, threads).unwrap();
        (
            out.by_name("x").unwrap().to_vec(),
            out.by_name("utens").unwrap().to_vec(),
        )
    }

    #[test]
    fn vm_matches_rust_reference() {
        let p = build();
        let params = preset(Preset::Tiny);
        let (iv, jv, kv) = (6usize, 5, 8);
        let vol = iv * jv * kv;
        let mk = |n: &str| (0..vol).map(|i| init(n, i)).collect::<Vec<f64>>();
        let (a, b, c, d) = (mk("a"), mk("b"), mk("c"), mk("d"));
        let (x_ref, ut_ref) = reference(iv, jv, kv, &a, &b, &c, &d);
        let (x, ut) = run(&p, 1);
        let _ = params;
        for (g, e) in x.iter().zip(&x_ref) {
            assert!((g - e).abs() < 1e-9, "{g} vs {e}");
        }
        // k = 0 of utens is never written (k starts at 1): the VM keeps
        // the input pattern, the reference keeps zeros — skip those slots
        // (every K-th element in the K-contiguous layout).
        for (o, (g, e)) in ut.iter().zip(&ut_ref).enumerate() {
            if o % kv == 0 {
                continue;
            }
            assert!((g - e).abs() < 1e-9);
        }
    }

    #[test]
    fn dependence_structure_matches_paper() {
        let p = build();
        // The forward-sweep k loop (first loop with 4 nests inside).
        let kf = p
            .loops()
            .into_iter()
            .find(|l| l.var.name() == "vadv_kf")
            .unwrap();
        let deps = loop_deps(kf, &p.containers);
        assert!(deps.has(DepKind::Raw), "cp/dp recurrences");
        assert!(deps.has(DepKind::Waw), "col scratch");
    }

    #[test]
    fn cfg1_removes_waw_cfg2_pipelines() {
        let mut p1 = build();
        silo_cfg1(&mut p1).unwrap();
        let kf = p1
            .loops()
            .into_iter()
            .find(|l| l.var.name() == "vadv_kf")
            .map(|l| l.clone());
        if let Some(kf) = kf {
            let deps = loop_deps(&kf, &p1.containers);
            assert!(!deps.has(DepKind::Waw), "privatization must clear col WAW");
        }
        let mut p2 = build();
        silo_cfg2(&mut p2).unwrap();
        assert!(
            p2.loops()
                .iter()
                .any(|l| matches!(l.schedule, crate::ir::LoopSchedule::Doacross { .. })),
            "cfg2 must pipeline a K loop"
        );
    }

    #[test]
    fn optimized_variants_agree_with_baseline() {
        let base = run(&build(), 1);
        type OptFn = fn(&mut Program) -> anyhow::Result<crate::transforms::PipelineReport>;
        for (name, f) in [("cfg1", silo_cfg1 as OptFn), ("cfg2", silo_cfg2)] {
            let mut p = build();
            f(&mut p).unwrap();
            for threads in [1, 3] {
                let got = run(&p, threads);
                assert_eq!(base.0, got.0, "{name} x mismatch @ {threads}t");
                assert_eq!(base.1, got.1, "{name} utens mismatch @ {threads}t");
            }
        }
    }
}
