//! The 20-kernel NPBench corpus of Fig. 10.

pub mod blas;
pub mod misc;
pub mod stencils;

use super::{default_init, KernelEntry};

/// Positive-weight initializer for floyd_warshall (distances) and
/// diagonally-safe values for the recurrence kernels.
fn positive_init(name: &str, i: usize) -> f64 {
    default_init(name, i) + 1.0 // in [0.5, 1.5)
}

/// All 20 Fig. 10 kernels.
pub fn corpus() -> Vec<KernelEntry> {
    vec![
        KernelEntry {
            name: "gemm",
            build: blas::gemm,
            preset: blas::gemm_preset,
            init: default_init,
        },
        KernelEntry {
            name: "2mm",
            build: blas::k2mm,
            preset: blas::k2mm_preset,
            init: default_init,
        },
        KernelEntry {
            name: "3mm",
            build: blas::k3mm,
            preset: blas::k3mm_preset,
            init: default_init,
        },
        KernelEntry {
            name: "atax",
            build: blas::atax,
            preset: blas::atax_preset,
            init: default_init,
        },
        KernelEntry {
            name: "bicg",
            build: blas::bicg,
            preset: blas::bicg_preset,
            init: default_init,
        },
        KernelEntry {
            name: "mvt",
            build: blas::mvt,
            preset: blas::mvt_preset,
            init: default_init,
        },
        KernelEntry {
            name: "gemver",
            build: blas::gemver,
            preset: blas::gemver_preset,
            init: default_init,
        },
        KernelEntry {
            name: "gesummv",
            build: blas::gesummv,
            preset: blas::gesummv_preset,
            init: default_init,
        },
        KernelEntry {
            name: "syrk",
            build: blas::syrk,
            preset: blas::syrk_preset,
            init: default_init,
        },
        KernelEntry {
            name: "syr2k",
            build: blas::syr2k,
            preset: blas::syr2k_preset,
            init: default_init,
        },
        KernelEntry {
            name: "trmm",
            build: blas::trmm,
            preset: blas::trmm_preset,
            init: default_init,
        },
        KernelEntry {
            name: "doitgen",
            build: blas::doitgen,
            preset: blas::doitgen_preset,
            init: default_init,
        },
        KernelEntry {
            name: "jacobi_1d",
            build: stencils::jacobi_1d,
            preset: stencils::jacobi_1d_preset,
            init: default_init,
        },
        KernelEntry {
            name: "jacobi_2d",
            build: stencils::jacobi_2d,
            preset: stencils::jacobi_2d_preset,
            init: default_init,
        },
        KernelEntry {
            name: "seidel_2d",
            build: stencils::seidel_2d,
            preset: stencils::seidel_2d_preset,
            init: default_init,
        },
        KernelEntry {
            name: "heat_3d",
            build: stencils::heat_3d,
            preset: stencils::heat_3d_preset,
            init: default_init,
        },
        KernelEntry {
            name: "fdtd_2d",
            build: stencils::fdtd_2d,
            preset: stencils::fdtd_2d_preset,
            init: default_init,
        },
        KernelEntry {
            name: "conv2d",
            build: stencils::conv2d,
            preset: stencils::conv2d_preset,
            init: default_init,
        },
        KernelEntry {
            name: "softmax",
            build: misc::softmax,
            preset: misc::softmax_preset,
            init: default_init,
        },
        KernelEntry {
            name: "floyd_warshall",
            build: misc::floyd_warshall,
            preset: misc::floyd_warshall_preset,
            init: positive_init,
        },
    ]
}

/// Extension kernels beyond the Fig. 10 set (ablations / extra coverage).
pub fn extras() -> Vec<KernelEntry> {
    vec![
        KernelEntry {
            name: "durbin",
            build: misc::durbin,
            preset: misc::durbin_preset,
            init: default_init,
        },
        KernelEntry {
            name: "cholesky_update",
            build: misc::cholesky_update,
            preset: misc::cholesky_preset,
            init: default_init,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::Vm;
    use crate::kernels::{gen_inputs, Preset};

    /// Every corpus kernel validates, lowers, executes at Tiny size, and
    /// produces identical results with pointer incrementation scheduled —
    /// the Fig. 10 precondition.
    #[test]
    fn corpus_executes_and_ptr_inc_is_equivalent() {
        for entry in corpus().into_iter().chain(extras()) {
            let p = (entry.build)();
            crate::ir::validate::validate(&p).unwrap_or_else(|e| panic!("{}: {e}", entry.name));
            let params = (entry.preset)(Preset::Tiny);
            let inputs = gen_inputs(&p, &params, entry.init).unwrap();
            let refs: Vec<_> = inputs.iter().map(|(c, v)| (*c, v.as_slice())).collect();
            let vm = Vm::compile(&p).unwrap_or_else(|e| panic!("{}: {e}", entry.name));
            let base = vm
                .run(&params, &refs, 1)
                .unwrap_or_else(|e| panic!("{}: {e}", entry.name));

            let mut p2 = (entry.build)();
            crate::schedules::schedule_all_ptr_inc(&mut p2);
            let inputs2 = gen_inputs(&p2, &params, entry.init).unwrap();
            let refs2: Vec<_> = inputs2.iter().map(|(c, v)| (*c, v.as_slice())).collect();
            let vm2 = Vm::compile(&p2).unwrap();
            let opt = vm2.run(&params, &refs2, 1).unwrap();
            for (i, (a, b)) in base.arrays.iter().zip(&opt.arrays).enumerate() {
                assert_eq!(a, b, "{} container {} mismatch under ptr-inc", entry.name, i);
            }
        }
    }

    /// gemm numeric spot-check against a plain Rust implementation.
    #[test]
    fn gemm_matches_reference() {
        let entry = corpus().into_iter().find(|k| k.name == "gemm").unwrap();
        let p = (entry.build)();
        let params = (entry.preset)(Preset::Tiny);
        let n = 12usize;
        let inputs = gen_inputs(&p, &params, entry.init).unwrap();
        let refs: Vec<_> = inputs.iter().map(|(c, v)| (*c, v.as_slice())).collect();
        let vm = Vm::compile(&p).unwrap();
        let out = vm.run(&params, &refs, 1).unwrap();
        let got = out.by_name("C").unwrap();
        let (a, bb, c0) = (&inputs[0].1, &inputs[1].1, &inputs[2].1);
        let mut expect = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut acc = 1.2 * c0[i * n + j];
                for k in 0..n {
                    acc += 1.5 * a[i * n + k] * bb[k * n + j];
                }
                expect[i * n + j] = acc;
            }
        }
        for (g, e) in got.iter().zip(&expect) {
            assert!((g - e).abs() < 1e-9, "{g} vs {e}");
        }
    }

    /// softmax numeric spot-check (rows sum to 1).
    #[test]
    fn softmax_matches_reference() {
        let entry = corpus().into_iter().find(|k| k.name == "softmax").unwrap();
        let p = (entry.build)();
        let params = (entry.preset)(Preset::Tiny);
        let inputs = gen_inputs(&p, &params, entry.init).unwrap();
        let refs: Vec<_> = inputs.iter().map(|(c, v)| (*c, v.as_slice())).collect();
        let vm = Vm::compile(&p).unwrap();
        let out = vm.run(&params, &refs, 1).unwrap();
        let got = out.by_name("out").unwrap();
        let expect = super::misc::softmax_reference(8, 10, &inputs[0].1);
        for (g, e) in got.iter().zip(&expect) {
            assert!((g - e).abs() < 1e-12);
        }
        for i in 0..8 {
            let s: f64 = got[i * 10..(i + 1) * 10].iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
        }
    }

    /// jacobi_1d matches a hand-rolled reference (the Fig. 10 headline).
    #[test]
    fn jacobi_1d_matches_reference() {
        let entry = corpus().into_iter().find(|k| k.name == "jacobi_1d").unwrap();
        let p = (entry.build)();
        let params = (entry.preset)(Preset::Tiny);
        let inputs = gen_inputs(&p, &params, entry.init).unwrap();
        let refs: Vec<_> = inputs.iter().map(|(c, v)| (*c, v.as_slice())).collect();
        let vm = Vm::compile(&p).unwrap();
        let out = vm.run(&params, &refs, 1).unwrap();
        let (n, t) = (30usize, 4usize);
        let mut a = inputs[0].1.clone();
        let mut bvec = inputs[1].1.clone();
        for _ in 0..t {
            for i in 1..n - 1 {
                bvec[i] = (a[i - 1] + a[i] + a[i + 1]) / 3.0;
            }
            for i in 1..n - 1 {
                a[i] = (bvec[i - 1] + bvec[i] + bvec[i + 1]) / 3.0;
            }
        }
        for (g, e) in out.by_name("A").unwrap().iter().zip(&a) {
            // Canonicalized sums evaluate in a different association order
            // than the source-order reference: compare with tolerance.
            assert!((g - e).abs() < 1e-12, "{g} vs {e}");
        }
    }
}
