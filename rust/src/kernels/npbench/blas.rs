//! NPBench/PolyBench-style dense linear-algebra kernels (Fig. 10 corpus).

use crate::ir::{Program, ProgramBuilder};
use crate::symbolic::{int, load, Expr, Sym};

use crate::kernels::Preset;

fn n_of(p: Preset, tiny: i64, small: i64, medium: i64) -> i64 {
    match p {
        Preset::Tiny => tiny,
        Preset::Small => small,
        Preset::Medium => medium,
    }
}

/// C = α·A@B + β·C
pub fn gemm() -> Program {
    let mut b = ProgramBuilder::new("gemm");
    let n = b.dim_param("gemm_N");
    let ne = Expr::Sym(n);
    let a = b.array("A", ne.clone() * ne.clone());
    let bb = b.array("B", ne.clone() * ne.clone());
    let c = b.array("C", ne.clone() * ne.clone());
    let (i0, j0) = (b.sym("gemm_i0"), b.sym("gemm_j0"));
    b.for_(i0, int(0), ne.clone(), int(1), |b| {
        b.for_(j0, int(0), ne.clone(), int(1), |b| {
            let off = Expr::Sym(i0) * ne.clone() + Expr::Sym(j0);
            b.assign(c, off.clone(), Expr::real(1.2) * load(c, off));
        });
    });
    let (i, j, k) = (b.sym("gemm_i"), b.sym("gemm_j"), b.sym("gemm_k"));
    b.for_(i, int(0), ne.clone(), int(1), |b| {
        b.for_(j, int(0), ne.clone(), int(1), |b| {
            b.for_(k, int(0), ne.clone(), int(1), |b| {
                let off = Expr::Sym(i) * ne.clone() + Expr::Sym(j);
                b.assign(
                    c,
                    off.clone(),
                    load(c, off)
                        + Expr::real(1.5)
                            * load(a, Expr::Sym(i) * ne.clone() + Expr::Sym(k))
                            * load(bb, Expr::Sym(k) * ne.clone() + Expr::Sym(j)),
                );
            });
        });
    });
    b.finish()
}

pub fn gemm_preset(p: Preset) -> Vec<(Sym, i64)> {
    vec![(Sym::new("gemm_N"), n_of(p, 12, 70, 140))]
}

/// tmp = α·A@B ; D = tmp@C + β·D
pub fn k2mm() -> Program {
    let mut b = ProgramBuilder::new("k2mm");
    let n = b.dim_param("k2mm_N");
    let ne = Expr::Sym(n);
    let a = b.array("A", ne.clone() * ne.clone());
    let bb = b.array("B", ne.clone() * ne.clone());
    let c = b.array("C", ne.clone() * ne.clone());
    let d = b.array("D", ne.clone() * ne.clone());
    let tmp = b.transient("tmp", ne.clone() * ne.clone());
    let (i0, j0, k0) = (b.sym("k2mm_i0"), b.sym("k2mm_j0"), b.sym("k2mm_k0"));
    b.for_(i0, int(0), ne.clone(), int(1), |b| {
        b.for_(j0, int(0), ne.clone(), int(1), |b| {
            b.assign(tmp, Expr::Sym(i0) * ne.clone() + Expr::Sym(j0), Expr::real(0.0));
            let _ = k0;
        });
    });
    let (i1, j1, k1) = (b.sym("k2mm_i1"), b.sym("k2mm_j1"), b.sym("k2mm_k1"));
    b.for_(i1, int(0), ne.clone(), int(1), |b| {
        b.for_(j1, int(0), ne.clone(), int(1), |b| {
            b.for_(k1, int(0), ne.clone(), int(1), |b| {
                let off = Expr::Sym(i1) * ne.clone() + Expr::Sym(j1);
                b.assign(
                    tmp,
                    off.clone(),
                    load(tmp, off)
                        + Expr::real(1.5)
                            * load(a, Expr::Sym(i1) * ne.clone() + Expr::Sym(k1))
                            * load(bb, Expr::Sym(k1) * ne.clone() + Expr::Sym(j1)),
                );
            });
        });
    });
    let (i2, j2, k2) = (b.sym("k2mm_i2"), b.sym("k2mm_j2"), b.sym("k2mm_k2"));
    b.for_(i2, int(0), ne.clone(), int(1), |b| {
        b.for_(j2, int(0), ne.clone(), int(1), |b| {
            let off = Expr::Sym(i2) * ne.clone() + Expr::Sym(j2);
            b.assign(d, off.clone(), Expr::real(1.2) * load(d, off));
        });
    });
    b.for_(k2, int(0), ne.clone(), int(1), |_b| {});
    let (i3, j3, k3) = (b.sym("k2mm_i3"), b.sym("k2mm_j3"), b.sym("k2mm_k3"));
    b.for_(i3, int(0), ne.clone(), int(1), |b| {
        b.for_(j3, int(0), ne.clone(), int(1), |b| {
            b.for_(k3, int(0), ne.clone(), int(1), |b| {
                let off = Expr::Sym(i3) * ne.clone() + Expr::Sym(j3);
                b.assign(
                    d,
                    off.clone(),
                    load(d, off)
                        + load(tmp, Expr::Sym(i3) * ne.clone() + Expr::Sym(k3))
                            * load(c, Expr::Sym(k3) * ne.clone() + Expr::Sym(j3)),
                );
            });
        });
    });
    b.finish()
}

pub fn k2mm_preset(p: Preset) -> Vec<(Sym, i64)> {
    vec![(Sym::new("k2mm_N"), n_of(p, 10, 50, 100))]
}

/// E = A@B ; F = C@D ; G = E@F
pub fn k3mm() -> Program {
    let mut b = ProgramBuilder::new("k3mm");
    let n = b.dim_param("k3mm_N");
    let ne = Expr::Sym(n);
    let names = ["A", "B", "C", "D"];
    let args: Vec<_> = names
        .iter()
        .map(|nm| b.array(nm, ne.clone() * ne.clone()))
        .collect();
    let e = b.transient("E", ne.clone() * ne.clone());
    let f = b.transient("F", ne.clone() * ne.clone());
    let g = b.array("G", ne.clone() * ne.clone());
    for (idx, (dst, (x, y))) in [(e, (args[0], args[1])), (f, (args[2], args[3]))]
        .into_iter()
        .enumerate()
    {
        let (i, j, k) = (
            b.sym(&format!("k3mm_i{idx}")),
            b.sym(&format!("k3mm_j{idx}")),
            b.sym(&format!("k3mm_k{idx}")),
        );
        let ne2 = ne.clone();
        b.for_(i, int(0), ne2.clone(), int(1), |b| {
            b.for_(j, int(0), ne2.clone(), int(1), |b| {
                b.assign(dst, Expr::Sym(i) * ne2.clone() + Expr::Sym(j), Expr::real(0.0));
            });
        });
        let (i2, j2) = (
            b.sym(&format!("k3mm_ii{idx}")),
            b.sym(&format!("k3mm_jj{idx}")),
        );
        b.for_(i2, int(0), ne2.clone(), int(1), |b| {
            b.for_(j2, int(0), ne2.clone(), int(1), |b| {
                b.for_(k, int(0), ne2.clone(), int(1), |b| {
                    let off = Expr::Sym(i2) * ne2.clone() + Expr::Sym(j2);
                    b.assign(
                        dst,
                        off.clone(),
                        load(dst, off)
                            + load(x, Expr::Sym(i2) * ne2.clone() + Expr::Sym(k))
                                * load(y, Expr::Sym(k) * ne2.clone() + Expr::Sym(j2)),
                    );
                });
            });
        });
    }
    let (gi, gj, gk) = (b.sym("k3mm_gi"), b.sym("k3mm_gj"), b.sym("k3mm_gk"));
    b.for_(gi, int(0), ne.clone(), int(1), |b| {
        b.for_(gj, int(0), ne.clone(), int(1), |b| {
            b.assign(g, Expr::Sym(gi) * ne.clone() + Expr::Sym(gj), Expr::real(0.0));
        });
    });
    let (gi2, gj2) = (b.sym("k3mm_gi2"), b.sym("k3mm_gj2"));
    b.for_(gi2, int(0), ne.clone(), int(1), |b| {
        b.for_(gj2, int(0), ne.clone(), int(1), |b| {
            b.for_(gk, int(0), ne.clone(), int(1), |b| {
                let off = Expr::Sym(gi2) * ne.clone() + Expr::Sym(gj2);
                b.assign(
                    g,
                    off.clone(),
                    load(g, off)
                        + load(e, Expr::Sym(gi2) * ne.clone() + Expr::Sym(gk))
                            * load(f, Expr::Sym(gk) * ne.clone() + Expr::Sym(gj2)),
                );
            });
        });
    });
    b.finish()
}

pub fn k3mm_preset(p: Preset) -> Vec<(Sym, i64)> {
    vec![(Sym::new("k3mm_N"), n_of(p, 10, 40, 80))]
}

/// y = Aᵀ(Ax)
pub fn atax() -> Program {
    let mut b = ProgramBuilder::new("atax");
    let n = b.dim_param("atax_N");
    let ne = Expr::Sym(n);
    let a = b.array("A", ne.clone() * ne.clone());
    let x = b.array("x", ne.clone());
    let y = b.array("y", ne.clone());
    let tmp = b.transient("tmp", ne.clone());
    let (i0, i1, j1, i2, j2) = (
        b.sym("atax_i0"),
        b.sym("atax_i1"),
        b.sym("atax_j1"),
        b.sym("atax_i2"),
        b.sym("atax_j2"),
    );
    b.for_(i0, int(0), ne.clone(), int(1), |b| {
        b.assign(y, Expr::Sym(i0), Expr::real(0.0));
        b.assign(tmp, Expr::Sym(i0), Expr::real(0.0));
    });
    b.for_(i1, int(0), ne.clone(), int(1), |b| {
        b.for_(j1, int(0), ne.clone(), int(1), |b| {
            b.assign(
                tmp,
                Expr::Sym(i1),
                load(tmp, Expr::Sym(i1))
                    + load(a, Expr::Sym(i1) * ne.clone() + Expr::Sym(j1)) * load(x, Expr::Sym(j1)),
            );
        });
    });
    b.for_(i2, int(0), ne.clone(), int(1), |b| {
        b.for_(j2, int(0), ne.clone(), int(1), |b| {
            b.assign(
                y,
                Expr::Sym(j2),
                load(y, Expr::Sym(j2))
                    + load(a, Expr::Sym(i2) * ne.clone() + Expr::Sym(j2))
                        * load(tmp, Expr::Sym(i2)),
            );
        });
    });
    b.finish()
}

pub fn atax_preset(p: Preset) -> Vec<(Sym, i64)> {
    vec![(Sym::new("atax_N"), n_of(p, 16, 250, 500))]
}

/// s = Aᵀr ; q = Ap
pub fn bicg() -> Program {
    let mut b = ProgramBuilder::new("bicg");
    let n = b.dim_param("bicg_N");
    let ne = Expr::Sym(n);
    let a = b.array("A", ne.clone() * ne.clone());
    let r = b.array("r", ne.clone());
    let pp = b.array("p", ne.clone());
    let s = b.array("s", ne.clone());
    let q = b.array("q", ne.clone());
    let (i0, i1, j1, i2, j2) = (
        b.sym("bicg_i0"),
        b.sym("bicg_i1"),
        b.sym("bicg_j1"),
        b.sym("bicg_i2"),
        b.sym("bicg_j2"),
    );
    b.for_(i0, int(0), ne.clone(), int(1), |b| {
        b.assign(s, Expr::Sym(i0), Expr::real(0.0));
        b.assign(q, Expr::Sym(i0), Expr::real(0.0));
    });
    b.for_(i1, int(0), ne.clone(), int(1), |b| {
        b.for_(j1, int(0), ne.clone(), int(1), |b| {
            b.assign(
                s,
                Expr::Sym(j1),
                load(s, Expr::Sym(j1))
                    + load(r, Expr::Sym(i1)) * load(a, Expr::Sym(i1) * ne.clone() + Expr::Sym(j1)),
            );
        });
    });
    b.for_(i2, int(0), ne.clone(), int(1), |b| {
        b.for_(j2, int(0), ne.clone(), int(1), |b| {
            b.assign(
                q,
                Expr::Sym(i2),
                load(q, Expr::Sym(i2))
                    + load(a, Expr::Sym(i2) * ne.clone() + Expr::Sym(j2)) * load(pp, Expr::Sym(j2)),
            );
        });
    });
    b.finish()
}

pub fn bicg_preset(p: Preset) -> Vec<(Sym, i64)> {
    vec![(Sym::new("bicg_N"), n_of(p, 16, 250, 500))]
}

/// x1 += A·y1 ; x2 += Aᵀ·y2
pub fn mvt() -> Program {
    let mut b = ProgramBuilder::new("mvt");
    let n = b.dim_param("mvt_N");
    let ne = Expr::Sym(n);
    let a = b.array("A", ne.clone() * ne.clone());
    let x1 = b.array("x1", ne.clone());
    let x2 = b.array("x2", ne.clone());
    let y1 = b.array("y1", ne.clone());
    let y2 = b.array("y2", ne.clone());
    let (i1, j1, i2, j2) = (
        b.sym("mvt_i1"),
        b.sym("mvt_j1"),
        b.sym("mvt_i2"),
        b.sym("mvt_j2"),
    );
    b.for_(i1, int(0), ne.clone(), int(1), |b| {
        b.for_(j1, int(0), ne.clone(), int(1), |b| {
            b.assign(
                x1,
                Expr::Sym(i1),
                load(x1, Expr::Sym(i1))
                    + load(a, Expr::Sym(i1) * ne.clone() + Expr::Sym(j1)) * load(y1, Expr::Sym(j1)),
            );
        });
    });
    b.for_(i2, int(0), ne.clone(), int(1), |b| {
        b.for_(j2, int(0), ne.clone(), int(1), |b| {
            b.assign(
                x2,
                Expr::Sym(i2),
                load(x2, Expr::Sym(i2))
                    + load(a, Expr::Sym(j2) * ne.clone() + Expr::Sym(i2)) * load(y2, Expr::Sym(j2)),
            );
        });
    });
    b.finish()
}

pub fn mvt_preset(p: Preset) -> Vec<(Sym, i64)> {
    vec![(Sym::new("mvt_N"), n_of(p, 16, 250, 500))]
}

/// A += u1·v1ᵀ + u2·v2ᵀ ; x += β·Aᵀ·y ; x += z ; w += α·A·x
pub fn gemver() -> Program {
    let mut b = ProgramBuilder::new("gemver");
    let n = b.dim_param("gemver_N");
    let ne = Expr::Sym(n);
    let a = b.array("A", ne.clone() * ne.clone());
    let (u1, v1, u2, v2) = (
        b.array("u1", ne.clone()),
        b.array("v1", ne.clone()),
        b.array("u2", ne.clone()),
        b.array("v2", ne.clone()),
    );
    let (x, y, z, w) = (
        b.array("x", ne.clone()),
        b.array("y", ne.clone()),
        b.array("z", ne.clone()),
        b.array("w", ne.clone()),
    );
    let (i1, j1) = (b.sym("gemver_i1"), b.sym("gemver_j1"));
    b.for_(i1, int(0), ne.clone(), int(1), |b| {
        b.for_(j1, int(0), ne.clone(), int(1), |b| {
            let off = Expr::Sym(i1) * ne.clone() + Expr::Sym(j1);
            b.assign(
                a,
                off.clone(),
                load(a, off)
                    + load(u1, Expr::Sym(i1)) * load(v1, Expr::Sym(j1))
                    + load(u2, Expr::Sym(i1)) * load(v2, Expr::Sym(j1)),
            );
        });
    });
    let (i2, j2) = (b.sym("gemver_i2"), b.sym("gemver_j2"));
    b.for_(i2, int(0), ne.clone(), int(1), |b| {
        b.for_(j2, int(0), ne.clone(), int(1), |b| {
            b.assign(
                x,
                Expr::Sym(i2),
                load(x, Expr::Sym(i2))
                    + Expr::real(1.2)
                        * load(a, Expr::Sym(j2) * ne.clone() + Expr::Sym(i2))
                        * load(y, Expr::Sym(j2)),
            );
        });
    });
    let i3 = b.sym("gemver_i3");
    b.for_(i3, int(0), ne.clone(), int(1), |b| {
        b.assign(x, Expr::Sym(i3), load(x, Expr::Sym(i3)) + load(z, Expr::Sym(i3)));
    });
    let (i4, j4) = (b.sym("gemver_i4"), b.sym("gemver_j4"));
    b.for_(i4, int(0), ne.clone(), int(1), |b| {
        b.for_(j4, int(0), ne.clone(), int(1), |b| {
            b.assign(
                w,
                Expr::Sym(i4),
                load(w, Expr::Sym(i4))
                    + Expr::real(1.5)
                        * load(a, Expr::Sym(i4) * ne.clone() + Expr::Sym(j4))
                        * load(x, Expr::Sym(j4)),
            );
        });
    });
    b.finish()
}

pub fn gemver_preset(p: Preset) -> Vec<(Sym, i64)> {
    vec![(Sym::new("gemver_N"), n_of(p, 16, 200, 400))]
}

/// y = α·A·x + β·B·x
pub fn gesummv() -> Program {
    let mut b = ProgramBuilder::new("gesummv");
    let n = b.dim_param("gesummv_N");
    let ne = Expr::Sym(n);
    let a = b.array("A", ne.clone() * ne.clone());
    let bb = b.array("B", ne.clone() * ne.clone());
    let x = b.array("x", ne.clone());
    let y = b.array("y", ne.clone());
    let tmp = b.transient("tmp", ne.clone());
    let (i0, i, j) = (b.sym("gesummv_i0"), b.sym("gesummv_i"), b.sym("gesummv_j"));
    b.for_(i0, int(0), ne.clone(), int(1), |b| {
        b.assign(tmp, Expr::Sym(i0), Expr::real(0.0));
        b.assign(y, Expr::Sym(i0), Expr::real(0.0));
    });
    b.for_(i, int(0), ne.clone(), int(1), |b| {
        b.for_(j, int(0), ne.clone(), int(1), |b| {
            let off = Expr::Sym(i) * ne.clone() + Expr::Sym(j);
            b.assign(
                tmp,
                Expr::Sym(i),
                load(tmp, Expr::Sym(i)) + load(a, off.clone()) * load(x, Expr::Sym(j)),
            );
            b.assign(
                y,
                Expr::Sym(i),
                load(y, Expr::Sym(i)) + load(bb, off) * load(x, Expr::Sym(j)),
            );
        });
    });
    let i2 = b.sym("gesummv_i2");
    b.for_(i2, int(0), ne.clone(), int(1), |b| {
        b.assign(
            y,
            Expr::Sym(i2),
            Expr::real(1.5) * load(tmp, Expr::Sym(i2)) + Expr::real(1.2) * load(y, Expr::Sym(i2)),
        );
    });
    b.finish()
}

pub fn gesummv_preset(p: Preset) -> Vec<(Sym, i64)> {
    vec![(Sym::new("gesummv_N"), n_of(p, 16, 250, 500))]
}

/// C = α·A·Aᵀ + β·C (lower triangle)
pub fn syrk() -> Program {
    let mut b = ProgramBuilder::new("syrk");
    let n = b.dim_param("syrk_N");
    let ne = Expr::Sym(n);
    let a = b.array("A", ne.clone() * ne.clone());
    let c = b.array("C", ne.clone() * ne.clone());
    let (i, j) = (b.sym("syrk_i"), b.sym("syrk_j"));
    b.for_(i, int(0), ne.clone(), int(1), |b| {
        b.for_(j, int(0), Expr::Sym(i) + int(1), int(1), |b| {
            let off = Expr::Sym(i) * ne.clone() + Expr::Sym(j);
            b.assign(c, off.clone(), Expr::real(1.2) * load(c, off));
        });
    });
    let (i2, j2, k2) = (b.sym("syrk_i2"), b.sym("syrk_j2"), b.sym("syrk_k2"));
    b.for_(i2, int(0), ne.clone(), int(1), |b| {
        b.for_(j2, int(0), Expr::Sym(i2) + int(1), int(1), |b| {
            b.for_(k2, int(0), ne.clone(), int(1), |b| {
                let off = Expr::Sym(i2) * ne.clone() + Expr::Sym(j2);
                b.assign(
                    c,
                    off.clone(),
                    load(c, off)
                        + Expr::real(1.5)
                            * load(a, Expr::Sym(i2) * ne.clone() + Expr::Sym(k2))
                            * load(a, Expr::Sym(j2) * ne.clone() + Expr::Sym(k2)),
                );
            });
        });
    });
    b.finish()
}

pub fn syrk_preset(p: Preset) -> Vec<(Sym, i64)> {
    vec![(Sym::new("syrk_N"), n_of(p, 12, 70, 140))]
}

/// C = α·(A·Bᵀ + B·Aᵀ) + β·C (lower triangle)
pub fn syr2k() -> Program {
    let mut b = ProgramBuilder::new("syr2k");
    let n = b.dim_param("syr2k_N");
    let ne = Expr::Sym(n);
    let a = b.array("A", ne.clone() * ne.clone());
    let bb = b.array("B", ne.clone() * ne.clone());
    let c = b.array("C", ne.clone() * ne.clone());
    let (i, j) = (b.sym("syr2k_i"), b.sym("syr2k_j"));
    b.for_(i, int(0), ne.clone(), int(1), |b| {
        b.for_(j, int(0), Expr::Sym(i) + int(1), int(1), |b| {
            let off = Expr::Sym(i) * ne.clone() + Expr::Sym(j);
            b.assign(c, off.clone(), Expr::real(1.2) * load(c, off));
        });
    });
    let (i2, j2, k2) = (b.sym("syr2k_i2"), b.sym("syr2k_j2"), b.sym("syr2k_k2"));
    b.for_(i2, int(0), ne.clone(), int(1), |b| {
        b.for_(j2, int(0), Expr::Sym(i2) + int(1), int(1), |b| {
            b.for_(k2, int(0), ne.clone(), int(1), |b| {
                let off = Expr::Sym(i2) * ne.clone() + Expr::Sym(j2);
                b.assign(
                    c,
                    off.clone(),
                    load(c, off)
                        + Expr::real(1.5)
                            * (load(a, Expr::Sym(i2) * ne.clone() + Expr::Sym(k2))
                                * load(bb, Expr::Sym(j2) * ne.clone() + Expr::Sym(k2))
                                + load(bb, Expr::Sym(i2) * ne.clone() + Expr::Sym(k2))
                                    * load(a, Expr::Sym(j2) * ne.clone() + Expr::Sym(k2))),
                );
            });
        });
    });
    b.finish()
}

pub fn syr2k_preset(p: Preset) -> Vec<(Sym, i64)> {
    vec![(Sym::new("syr2k_N"), n_of(p, 12, 60, 120))]
}

/// B = α·Aᵀ·B, A unit lower triangular — the inner k loop *starts at i+1*:
/// the §4.1 stride-discontinuity pattern.
pub fn trmm() -> Program {
    let mut b = ProgramBuilder::new("trmm");
    let n = b.dim_param("trmm_N");
    let ne = Expr::Sym(n);
    let a = b.array("A", ne.clone() * ne.clone());
    let bb = b.array("B", ne.clone() * ne.clone());
    let (i, j, k) = (b.sym("trmm_i"), b.sym("trmm_j"), b.sym("trmm_k"));
    b.for_(i, int(0), ne.clone(), int(1), |b| {
        b.for_(j, int(0), ne.clone(), int(1), |b| {
            b.for_(k, Expr::Sym(i) + int(1), ne.clone(), int(1), |b| {
                let off = Expr::Sym(i) * ne.clone() + Expr::Sym(j);
                b.assign(
                    bb,
                    off.clone(),
                    load(bb, off)
                        + load(a, Expr::Sym(k) * ne.clone() + Expr::Sym(i))
                            * load(bb, Expr::Sym(k) * ne.clone() + Expr::Sym(j)),
                );
            });
        });
    });
    let (i2, j2) = (b.sym("trmm_i2"), b.sym("trmm_j2"));
    b.for_(i2, int(0), ne.clone(), int(1), |b| {
        b.for_(j2, int(0), ne.clone(), int(1), |b| {
            let off = Expr::Sym(i2) * ne.clone() + Expr::Sym(j2);
            b.assign(bb, off.clone(), Expr::real(1.5) * load(bb, off));
        });
    });
    b.finish()
}

pub fn trmm_preset(p: Preset) -> Vec<(Sym, i64)> {
    vec![(Sym::new("trmm_N"), n_of(p, 12, 70, 140))]
}

/// sum[r,q,p] = Σ_s A[r,q,s]·C4[s,p]; A[r,q,:] = sum
pub fn doitgen() -> Program {
    let mut b = ProgramBuilder::new("doitgen");
    let nr = b.dim_param("doitgen_R");
    let np = b.dim_param("doitgen_P");
    let (re, pe) = (Expr::Sym(nr), Expr::Sym(np));
    let a = b.array("A", re.clone() * re.clone() * pe.clone());
    let c4 = b.array("C4", pe.clone() * pe.clone());
    let sum = b.transient("sum", pe.clone());
    let (r, q, p0, p, s, p2) = (
        b.sym("doitgen_r"),
        b.sym("doitgen_q"),
        b.sym("doitgen_p0"),
        b.sym("doitgen_p"),
        b.sym("doitgen_s"),
        b.sym("doitgen_p2"),
    );
    b.for_(r, int(0), re.clone(), int(1), |b| {
        b.for_(q, int(0), re.clone(), int(1), |b| {
            b.for_(p0, int(0), pe.clone(), int(1), |b| {
                b.assign(sum, Expr::Sym(p0), Expr::real(0.0));
            });
            b.for_(p, int(0), pe.clone(), int(1), |b| {
                b.for_(s, int(0), pe.clone(), int(1), |b| {
                    let aoff =
                        (Expr::Sym(r) * re.clone() + Expr::Sym(q)) * pe.clone() + Expr::Sym(s);
                    b.assign(
                        sum,
                        Expr::Sym(p),
                        load(sum, Expr::Sym(p))
                            + load(a, aoff) * load(c4, Expr::Sym(s) * pe.clone() + Expr::Sym(p)),
                    );
                });
            });
            b.for_(p2, int(0), pe.clone(), int(1), |b| {
                let aoff = (Expr::Sym(r) * re.clone() + Expr::Sym(q)) * pe.clone() + Expr::Sym(p2);
                b.assign(a, aoff, load(sum, Expr::Sym(p2)));
            });
        });
    });
    b.finish()
}

pub fn doitgen_preset(p: Preset) -> Vec<(Sym, i64)> {
    let (r, pp) = match p {
        Preset::Tiny => (6, 8),
        Preset::Small => (30, 40),
        Preset::Medium => (60, 80),
    };
    vec![(Sym::new("doitgen_R"), r), (Sym::new("doitgen_P"), pp)]
}
