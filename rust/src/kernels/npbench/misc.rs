//! Remaining Fig. 10 corpus kernels: softmax (the 3.62× icc example),
//! floyd_warshall, durbin-style recurrence, and cholesky-like updates.

use crate::ir::{Program, ProgramBuilder};
use crate::symbolic::{fdiv, func, int, load, max, Expr, FuncKind, Sym};

use crate::kernels::Preset;

fn n_of(p: Preset, tiny: i64, small: i64, medium: i64) -> i64 {
    match p {
        Preset::Tiny => tiny,
        Preset::Small => small,
        Preset::Medium => medium,
    }
}

/// softmax over rows of an `N×M` matrix: rowmax → exp/sum → normalize.
pub fn softmax() -> Program {
    let mut b = ProgramBuilder::new("softmax");
    let n = b.dim_param("sm_N");
    let m = b.dim_param("sm_M");
    let (ne, me) = (Expr::Sym(n), Expr::Sym(m));
    let x = b.array("x", ne.clone() * me.clone());
    let out = b.array("out", ne.clone() * me.clone());
    let rowmax = b.transient("rowmax", ne.clone());
    let rowsum = b.transient("rowsum", ne.clone());
    let (i0, i1, j1, i2, j2, i3, j3) = (
        b.sym("sm_i0"),
        b.sym("sm_i1"),
        b.sym("sm_j1"),
        b.sym("sm_i2"),
        b.sym("sm_j2"),
        b.sym("sm_i3"),
        b.sym("sm_j3"),
    );
    b.for_(i0, int(0), ne.clone(), int(1), |b| {
        b.assign(rowmax, Expr::Sym(i0), Expr::real(-1e30));
        b.assign(rowsum, Expr::Sym(i0), Expr::real(0.0));
    });
    b.for_(i1, int(0), ne.clone(), int(1), |b| {
        b.for_(j1, int(0), me.clone(), int(1), |b| {
            b.assign(
                rowmax,
                Expr::Sym(i1),
                max(
                    load(rowmax, Expr::Sym(i1)),
                    load(x, Expr::Sym(i1) * me.clone() + Expr::Sym(j1)),
                ),
            );
        });
    });
    b.for_(i2, int(0), ne.clone(), int(1), |b| {
        b.for_(j2, int(0), me.clone(), int(1), |b| {
            let e = func(
                FuncKind::Exp,
                vec![
                    load(x, Expr::Sym(i2) * me.clone() + Expr::Sym(j2))
                        - load(rowmax, Expr::Sym(i2)),
                ],
            );
            b.assign(out, Expr::Sym(i2) * me.clone() + Expr::Sym(j2), e.clone());
            b.assign(rowsum, Expr::Sym(i2), load(rowsum, Expr::Sym(i2)) + e);
        });
    });
    b.for_(i3, int(0), ne.clone(), int(1), |b| {
        b.for_(j3, int(0), me.clone(), int(1), |b| {
            let off = Expr::Sym(i3) * me.clone() + Expr::Sym(j3);
            b.assign(out, off.clone(), fdiv(load(out, off), load(rowsum, Expr::Sym(i3))));
        });
    });
    b.finish()
}

pub fn softmax_preset(p: Preset) -> Vec<(Sym, i64)> {
    let (n, m) = match p {
        Preset::Tiny => (8, 10),
        Preset::Small => (128, 128),
        Preset::Medium => (256, 256),
    };
    vec![(Sym::new("sm_N"), n), (Sym::new("sm_M"), m)]
}

/// Rust oracle for softmax.
pub fn softmax_reference(n: usize, m: usize, x: &[f64]) -> Vec<f64> {
    let mut out = vec![0.0; n * m];
    for i in 0..n {
        let mut mx = -1e30f64;
        for j in 0..m {
            mx = mx.max(x[i * m + j]);
        }
        let mut s = 0.0;
        for j in 0..m {
            out[i * m + j] = (x[i * m + j] - mx).exp();
            s += out[i * m + j];
        }
        for j in 0..m {
            out[i * m + j] /= s;
        }
    }
    out
}

/// floyd_warshall all-pairs shortest paths (min updates).
pub fn floyd_warshall() -> Program {
    let mut b = ProgramBuilder::new("floyd_warshall");
    let n = b.dim_param("fw_N");
    let ne = Expr::Sym(n);
    let d = b.array("D", ne.clone() * ne.clone());
    let (k, i, j) = (b.sym("fw_k"), b.sym("fw_i"), b.sym("fw_j"));
    b.for_(k, int(0), ne.clone(), int(1), |b| {
        b.for_(i, int(0), ne.clone(), int(1), |b| {
            b.for_(j, int(0), ne.clone(), int(1), |b| {
                let off = Expr::Sym(i) * ne.clone() + Expr::Sym(j);
                b.assign(
                    d,
                    off.clone(),
                    crate::symbolic::min(
                        load(d, off),
                        load(d, Expr::Sym(i) * ne.clone() + Expr::Sym(k))
                            + load(d, Expr::Sym(k) * ne.clone() + Expr::Sym(j)),
                    ),
                );
            });
        });
    });
    b.finish()
}

pub fn floyd_warshall_preset(p: Preset) -> Vec<(Sym, i64)> {
    vec![(Sym::new("fw_N"), n_of(p, 12, 80, 160))]
}

/// durbin-style first-order recurrence chain (Levinson-Durbin inner
/// structure, simplified to the loop-carried shape that matters).
pub fn durbin() -> Program {
    let mut b = ProgramBuilder::new("durbin");
    let n = b.dim_param("dur_N");
    let ne = Expr::Sym(n);
    let r = b.array("r", ne.clone());
    let y = b.array("y", ne.clone());
    let i = b.sym("dur_i");
    b.assign(y, int(0), Expr::real(0.0) - load(r, int(0)));
    b.for_(i, int(1), ne.clone(), int(1), |b| {
        // y[i] = -(r[i] + 0.5·y[i-1]) / (1 + 0.1·y[i-1])  — RAW δ=1 chain.
        let prev = load(y, Expr::Sym(i) - int(1));
        b.assign(
            y,
            Expr::Sym(i),
            fdiv(
                Expr::real(0.0) - (load(r, Expr::Sym(i)) + Expr::real(0.5) * prev.clone()),
                Expr::real(1.0) + Expr::real(0.1) * prev,
            ),
        );
    });
    b.finish()
}

pub fn durbin_preset(p: Preset) -> Vec<(Sym, i64)> {
    vec![(Sym::new("dur_N"), n_of(p, 32, 4000, 16000))]
}

/// cholesky-like in-place column update (lower-triangular sweep with the
/// triangular-bound prefetch pattern; guards keep it single-assignment).
pub fn cholesky_update() -> Program {
    let mut b = ProgramBuilder::new("cholesky_update");
    let n = b.dim_param("chol_N");
    let ne = Expr::Sym(n);
    let a = b.array("A", ne.clone() * ne.clone());
    let (i, j, k) = (b.sym("chol_i"), b.sym("chol_j"), b.sym("chol_k"));
    // A[i,j] -= A[i,k]·A[j,k] for k < j ≤ i  (the O(N³) update sweep).
    b.for_(i, int(0), ne.clone(), int(1), |b| {
        b.for_(j, int(0), Expr::Sym(i) + int(1), int(1), |b| {
            b.for_(k, int(0), Expr::Sym(j), int(1), |b| {
                let off = Expr::Sym(i) * ne.clone() + Expr::Sym(j);
                b.assign(
                    a,
                    off.clone(),
                    load(a, off)
                        - load(a, Expr::Sym(i) * ne.clone() + Expr::Sym(k))
                            * load(a, Expr::Sym(j) * ne.clone() + Expr::Sym(k)),
                );
            });
        });
    });
    b.finish()
}

pub fn cholesky_preset(p: Preset) -> Vec<(Sym, i64)> {
    vec![(Sym::new("chol_N"), n_of(p, 12, 70, 140))]
}
