//! NPBench stencil kernels (Fig. 10 corpus).

use crate::ir::{Program, ProgramBuilder};
use crate::symbolic::{int, load, Expr, Sym};

use crate::kernels::Preset;

fn n_of(p: Preset, tiny: i64, small: i64, medium: i64) -> i64 {
    match p {
        Preset::Tiny => tiny,
        Preset::Small => small,
        Preset::Medium => medium,
    }
}

/// jacobi_1d: TSTEPS of A→B→A three-point averaging (the paper's star
/// Fig. 10 example: 1.76× with clang under pointer incrementation).
pub fn jacobi_1d() -> Program {
    let mut b = ProgramBuilder::new("jacobi_1d");
    let n = b.dim_param("j1d_N");
    let ts = b.param_positive("j1d_T");
    let ne = Expr::Sym(n);
    let a = b.array("A", ne.clone());
    let bb = b.array("B", ne.clone());
    let t = b.sym("j1d_t");
    let (i1, i2) = (b.sym("j1d_i1"), b.sym("j1d_i2"));
    let third = Expr::real(1.0 / 3.0);
    b.for_(t, int(0), Expr::Sym(ts), int(1), |b| {
        b.for_(i1, int(1), ne.clone() - int(1), int(1), |b| {
            b.assign(
                bb,
                Expr::Sym(i1),
                third.clone()
                    * (load(a, Expr::Sym(i1) - int(1))
                        + load(a, Expr::Sym(i1))
                        + load(a, Expr::Sym(i1) + int(1))),
            );
        });
        b.for_(i2, int(1), ne.clone() - int(1), int(1), |b| {
            b.assign(
                a,
                Expr::Sym(i2),
                third.clone()
                    * (load(bb, Expr::Sym(i2) - int(1))
                        + load(bb, Expr::Sym(i2))
                        + load(bb, Expr::Sym(i2) + int(1))),
            );
        });
    });
    b.finish()
}

pub fn jacobi_1d_preset(p: Preset) -> Vec<(Sym, i64)> {
    vec![
        (Sym::new("j1d_N"), n_of(p, 30, 4000, 16000)),
        (Sym::new("j1d_T"), n_of(p, 4, 50, 100)),
    ]
}

/// jacobi_2d: five-point averaging, two buffers.
pub fn jacobi_2d() -> Program {
    let mut b = ProgramBuilder::new("jacobi_2d");
    let n = b.dim_param("j2d_N");
    let ts = b.param_positive("j2d_T");
    let ne = Expr::Sym(n);
    let a = b.array("A", ne.clone() * ne.clone());
    let bb = b.array("B", ne.clone() * ne.clone());
    let t = b.sym("j2d_t");
    let (i1, j1, i2, j2) = (
        b.sym("j2d_i1"),
        b.sym("j2d_j1"),
        b.sym("j2d_i2"),
        b.sym("j2d_j2"),
    );
    let fifth = Expr::real(0.2);
    b.for_(t, int(0), Expr::Sym(ts), int(1), |b| {
        b.for_(i1, int(1), ne.clone() - int(1), int(1), |b| {
            b.for_(j1, int(1), ne.clone() - int(1), int(1), |b| {
                let at = |di: i64, dj: i64| {
                    (Expr::Sym(i1) + int(di)) * ne.clone() + Expr::Sym(j1) + int(dj)
                };
                b.assign(
                    bb,
                    at(0, 0),
                    fifth.clone()
                        * (load(a, at(0, 0))
                            + load(a, at(0, -1))
                            + load(a, at(0, 1))
                            + load(a, at(1, 0))
                            + load(a, at(-1, 0))),
                );
            });
        });
        b.for_(i2, int(1), ne.clone() - int(1), int(1), |b| {
            b.for_(j2, int(1), ne.clone() - int(1), int(1), |b| {
                let at = |di: i64, dj: i64| {
                    (Expr::Sym(i2) + int(di)) * ne.clone() + Expr::Sym(j2) + int(dj)
                };
                b.assign(
                    a,
                    at(0, 0),
                    fifth.clone()
                        * (load(bb, at(0, 0))
                            + load(bb, at(0, -1))
                            + load(bb, at(0, 1))
                            + load(bb, at(1, 0))
                            + load(bb, at(-1, 0))),
                );
            });
        });
    });
    b.finish()
}

pub fn jacobi_2d_preset(p: Preset) -> Vec<(Sym, i64)> {
    vec![
        (Sym::new("j2d_N"), n_of(p, 12, 90, 180)),
        (Sym::new("j2d_T"), n_of(p, 3, 20, 40)),
    ]
}

/// seidel_2d: in-place Gauss-Seidel — genuinely sequential (RAW in both
/// dimensions); exercises the "no parallelization possible" path.
pub fn seidel_2d() -> Program {
    let mut b = ProgramBuilder::new("seidel_2d");
    let n = b.dim_param("s2d_N");
    let ts = b.param_positive("s2d_T");
    let ne = Expr::Sym(n);
    let a = b.array("A", ne.clone() * ne.clone());
    let t = b.sym("s2d_t");
    let (i, j) = (b.sym("s2d_i"), b.sym("s2d_j"));
    let ninth = Expr::real(1.0 / 9.0);
    b.for_(t, int(0), Expr::Sym(ts), int(1), |b| {
        b.for_(i, int(1), ne.clone() - int(1), int(1), |b| {
            b.for_(j, int(1), ne.clone() - int(1), int(1), |b| {
                let at = |di: i64, dj: i64| {
                    (Expr::Sym(i) + int(di)) * ne.clone() + Expr::Sym(j) + int(dj)
                };
                b.assign(
                    a,
                    at(0, 0),
                    ninth.clone()
                        * (load(a, at(-1, -1))
                            + load(a, at(-1, 0))
                            + load(a, at(-1, 1))
                            + load(a, at(0, -1))
                            + load(a, at(0, 0))
                            + load(a, at(0, 1))
                            + load(a, at(1, -1))
                            + load(a, at(1, 0))
                            + load(a, at(1, 1))),
                );
            });
        });
    });
    b.finish()
}

pub fn seidel_2d_preset(p: Preset) -> Vec<(Sym, i64)> {
    vec![
        (Sym::new("s2d_N"), n_of(p, 12, 60, 120)),
        (Sym::new("s2d_T"), n_of(p, 3, 10, 20)),
    ]
}

/// heat_3d: 7-point 3-D stencil, two buffers.
pub fn heat_3d() -> Program {
    let mut b = ProgramBuilder::new("heat_3d");
    let n = b.dim_param("h3d_N");
    let ts = b.param_positive("h3d_T");
    let ne = Expr::Sym(n);
    let a = b.array("A", ne.clone() * ne.clone() * ne.clone());
    let bb = b.array("B", ne.clone() * ne.clone() * ne.clone());
    let t = b.sym("h3d_t");
    let vars1 = (b.sym("h3d_i1"), b.sym("h3d_j1"), b.sym("h3d_k1"));
    let vars2 = (b.sym("h3d_i2"), b.sym("h3d_j2"), b.sym("h3d_k2"));
    let stencil = |src: crate::symbolic::ContainerId,
                   iv: Expr,
                   jv: Expr,
                   kv: Expr,
                   ne: Expr|
     -> Expr {
        let at = |di: i64, dj: i64, dk: i64| {
            ((iv.clone() + int(di)) * ne.clone() + jv.clone() + int(dj)) * ne.clone()
                + kv.clone()
                + int(dk)
        };
        Expr::real(0.125)
            * (load(src, at(1, 0, 0)) - Expr::real(2.0) * load(src, at(0, 0, 0))
                + load(src, at(-1, 0, 0)))
            + Expr::real(0.125)
                * (load(src, at(0, 1, 0)) - Expr::real(2.0) * load(src, at(0, 0, 0))
                    + load(src, at(0, -1, 0)))
            + Expr::real(0.125)
                * (load(src, at(0, 0, 1)) - Expr::real(2.0) * load(src, at(0, 0, 0))
                    + load(src, at(0, 0, -1)))
            + load(src, at(0, 0, 0))
    };
    b.for_(t, int(0), Expr::Sym(ts), int(1), |b| {
        let (i1, j1, k1) = vars1;
        b.for_(i1, int(1), ne.clone() - int(1), int(1), |b| {
            b.for_(j1, int(1), ne.clone() - int(1), int(1), |b| {
                b.for_(k1, int(1), ne.clone() - int(1), int(1), |b| {
                    let off = (Expr::Sym(i1) * ne.clone() + Expr::Sym(j1)) * ne.clone()
                        + Expr::Sym(k1);
                    b.assign(
                        bb,
                        off,
                        stencil(a, Expr::Sym(i1), Expr::Sym(j1), Expr::Sym(k1), ne.clone()),
                    );
                });
            });
        });
        let (i2, j2, k2) = vars2;
        b.for_(i2, int(1), ne.clone() - int(1), int(1), |b| {
            b.for_(j2, int(1), ne.clone() - int(1), int(1), |b| {
                b.for_(k2, int(1), ne.clone() - int(1), int(1), |b| {
                    let off = (Expr::Sym(i2) * ne.clone() + Expr::Sym(j2)) * ne.clone()
                        + Expr::Sym(k2);
                    b.assign(
                        a,
                        off,
                        stencil(bb, Expr::Sym(i2), Expr::Sym(j2), Expr::Sym(k2), ne.clone()),
                    );
                });
            });
        });
    });
    b.finish()
}

pub fn heat_3d_preset(p: Preset) -> Vec<(Sym, i64)> {
    vec![
        (Sym::new("h3d_N"), n_of(p, 8, 25, 50)),
        (Sym::new("h3d_T"), n_of(p, 3, 10, 20)),
    ]
}

/// fdtd_2d: 2-D finite-difference time domain (ey/ex/hz updates).
pub fn fdtd_2d() -> Program {
    let mut b = ProgramBuilder::new("fdtd_2d");
    let n = b.dim_param("fdtd_N");
    let ts = b.param_positive("fdtd_T");
    let ne = Expr::Sym(n);
    let ex = b.array("ex", ne.clone() * ne.clone());
    let ey = b.array("ey", ne.clone() * ne.clone());
    let hz = b.array("hz", ne.clone() * ne.clone());
    let fict = b.array("fict", Expr::Sym(ts));
    let t = b.sym("fdtd_t");
    let (j0, i1, j1, i2, j2, i3, j3) = (
        b.sym("fdtd_j0"),
        b.sym("fdtd_i1"),
        b.sym("fdtd_j1"),
        b.sym("fdtd_i2"),
        b.sym("fdtd_j2"),
        b.sym("fdtd_i3"),
        b.sym("fdtd_j3"),
    );
    b.for_(t, int(0), Expr::Sym(ts), int(1), |b| {
        b.for_(j0, int(0), ne.clone(), int(1), |b| {
            b.assign(ey, Expr::Sym(j0), load(fict, Expr::Sym(t)));
        });
        b.for_(i1, int(1), ne.clone(), int(1), |b| {
            b.for_(j1, int(0), ne.clone(), int(1), |b| {
                let off = Expr::Sym(i1) * ne.clone() + Expr::Sym(j1);
                b.assign(
                    ey,
                    off.clone(),
                    load(ey, off.clone())
                        - Expr::real(0.5)
                            * (load(hz, off)
                                - load(hz, (Expr::Sym(i1) - int(1)) * ne.clone() + Expr::Sym(j1))),
                );
            });
        });
        b.for_(i2, int(0), ne.clone(), int(1), |b| {
            b.for_(j2, int(1), ne.clone(), int(1), |b| {
                let off = Expr::Sym(i2) * ne.clone() + Expr::Sym(j2);
                b.assign(
                    ex,
                    off.clone(),
                    load(ex, off.clone())
                        - Expr::real(0.5) * (load(hz, off.clone()) - load(hz, off - int(1))),
                );
            });
        });
        b.for_(i3, int(0), ne.clone() - int(1), int(1), |b| {
            b.for_(j3, int(0), ne.clone() - int(1), int(1), |b| {
                let off = Expr::Sym(i3) * ne.clone() + Expr::Sym(j3);
                b.assign(
                    hz,
                    off.clone(),
                    load(hz, off.clone())
                        - Expr::real(0.7)
                            * (load(ex, off.clone() + int(1)) - load(ex, off.clone())
                                + load(ey, (Expr::Sym(i3) + int(1)) * ne.clone() + Expr::Sym(j3))
                                - load(ey, off)),
                );
            });
        });
    });
    b.finish()
}

pub fn fdtd_2d_preset(p: Preset) -> Vec<(Sym, i64)> {
    vec![
        (Sym::new("fdtd_N"), n_of(p, 12, 80, 160)),
        (Sym::new("fdtd_T"), n_of(p, 3, 20, 40)),
    ]
}

/// conv2d: 3×3 valid convolution.
pub fn conv2d() -> Program {
    let mut b = ProgramBuilder::new("conv2d");
    let n = b.dim_param("conv_N");
    let ne = Expr::Sym(n);
    let input = b.array("in", ne.clone() * ne.clone());
    let w = b.array("w", int(9));
    let out = b.array("out", (ne.clone() - int(2)) * (ne.clone() - int(2)));
    let (i, j) = (b.sym("conv_i"), b.sym("conv_j"));
    b.for_(i, int(0), ne.clone() - int(2), int(1), |b| {
        b.for_(j, int(0), ne.clone() - int(2), int(1), |b| {
            let mut acc = Expr::real(0.0);
            for di in 0..3i64 {
                for dj in 0..3i64 {
                    acc = acc
                        + load(w, int(di * 3 + dj))
                            * load(
                                input,
                                (Expr::Sym(i) + int(di)) * ne.clone() + Expr::Sym(j) + int(dj),
                            );
                }
            }
            b.assign(out, Expr::Sym(i) * (ne.clone() - int(2)) + Expr::Sym(j), acc);
        });
    });
    b.finish()
}

pub fn conv2d_preset(p: Preset) -> Vec<(Sym, i64)> {
    vec![(Sym::new("conv_N"), n_of(p, 12, 130, 260))]
}
