//! The file-backed kernel corpus: SILO-Text sources under `corpus/*.silo`,
//! embedded at build time and elaborated through the frontend.
//!
//! Two groups:
//!
//! * **Registered** corpus kernels ([`corpus_kernels`]) — workloads that
//!   exist *only* as text (the Fig. 2 loops plus kernels no Rust builder
//!   expresses) and join [`super::all_kernels`], so every harness
//!   (autotuner, experiments, VM validation, benches) runs over parsed
//!   programs with zero special cases.
//! * **Mirror** sources ([`mirror_sources`]) — textual transcriptions of
//!   kernels that already have Rust builders (`laplace2d`, `vadv`,
//!   `matmul_tiled`). They are not registered twice; instead
//!   `rust/tests/frontend.rs` pins `parse(text) == build()`, which
//!   cross-validates the parser against the builders statement by
//!   statement.

use crate::frontend::{parse_str, ParsedKernel};
use crate::ir::Program;
use crate::symbolic::Sym;

use super::{KernelEntry, Preset};

/// `(kernel name, embedded SILO-Text source)` for every corpus file.
pub fn embedded_sources() -> Vec<(&'static str, &'static str)> {
    let mut v = mirror_sources();
    v.extend(registered_sources());
    v
}

/// Corpus files that mirror Rust-builder kernels (parser cross-checks).
pub fn mirror_sources() -> Vec<(&'static str, &'static str)> {
    vec![
        ("laplace2d", include_str!("../../../corpus/laplace.silo")),
        ("vadv", include_str!("../../../corpus/vadv.silo")),
        (
            "matmul_tiled",
            include_str!("../../../corpus/matmul_tiled.silo"),
        ),
    ]
}

/// Corpus files registered as kernels in their own right.
pub fn registered_sources() -> Vec<(&'static str, &'static str)> {
    vec![
        ("fig2_log2", include_str!("../../../corpus/fig2_log2.silo")),
        ("fig2_tri", include_str!("../../../corpus/fig2_tri.silo")),
        (
            "gather_stride",
            include_str!("../../../corpus/gather_stride.silo"),
        ),
        (
            "stencil_time",
            include_str!("../../../corpus/stencil_time.silo"),
        ),
        ("blur_guard", include_str!("../../../corpus/blur_guard.silo")),
        ("hdiff", include_str!("../../../corpus/hdiff.silo")),
        ("csr_gather", include_str!("../../../corpus/csr_gather.silo")),
    ]
}

fn parse_embedded(name: &'static str) -> ParsedKernel {
    let src = embedded_sources()
        .into_iter()
        .find(|(n, _)| *n == name)
        .unwrap_or_else(|| panic!("no embedded corpus source named {name}"))
        .1;
    parse_str(src).unwrap_or_else(|e| panic!("embedded corpus kernel {name}: {e}"))
}

macro_rules! corpus_entry {
    ($build:ident, $preset:ident, $name:literal) => {
        fn $build() -> Program {
            parse_embedded($name).program
        }

        fn $preset(p: Preset) -> Vec<(Sym, i64)> {
            parse_embedded($name)
                .params_for(p)
                .unwrap_or_else(|e| panic!("embedded corpus kernel {}: {e}", $name))
        }
    };
}

corpus_entry!(build_fig2_log2, preset_fig2_log2, "fig2_log2");
corpus_entry!(build_fig2_tri, preset_fig2_tri, "fig2_tri");
corpus_entry!(build_gather, preset_gather, "gather_stride");
corpus_entry!(build_stencil_time, preset_stencil_time, "stencil_time");
corpus_entry!(build_blur_guard, preset_blur_guard, "blur_guard");
corpus_entry!(build_hdiff, preset_hdiff, "hdiff");
corpus_entry!(build_csr_gather, preset_csr_gather, "csr_gather");

/// Kernel entries for the registered corpus files. Registered corpus
/// kernels use [`super::default_init`] (enforced by `tests/frontend.rs`:
/// `init(...)` annotations are reserved for mirror files, whose registered
/// twins carry their own Rust init functions).
pub fn corpus_kernels() -> Vec<KernelEntry> {
    vec![
        KernelEntry {
            name: "fig2_log2",
            build: build_fig2_log2,
            preset: preset_fig2_log2,
            init: super::default_init,
        },
        KernelEntry {
            name: "fig2_tri",
            build: build_fig2_tri,
            preset: preset_fig2_tri,
            init: super::default_init,
        },
        KernelEntry {
            name: "gather_stride",
            build: build_gather,
            preset: preset_gather,
            init: super::default_init,
        },
        KernelEntry {
            name: "stencil_time",
            build: build_stencil_time,
            preset: preset_stencil_time,
            init: super::default_init,
        },
        KernelEntry {
            name: "blur_guard",
            build: build_blur_guard,
            preset: preset_blur_guard,
            init: super::default_init,
        },
        KernelEntry {
            name: "hdiff",
            build: build_hdiff,
            preset: preset_hdiff,
            init: super::default_init,
        },
        KernelEntry {
            name: "csr_gather",
            build: build_csr_gather,
            preset: preset_csr_gather,
            init: super::default_init,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_embedded_source_parses_and_validates() {
        for (name, src) in embedded_sources() {
            let k = parse_str(src).unwrap_or_else(|e| panic!("{name}: {e}"));
            crate::ir::validate::validate(&k.program).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(k.program.name, name, "file name / program name drift");
        }
    }

    #[test]
    fn registered_corpus_kernels_build_and_bind_presets() {
        for entry in corpus_kernels() {
            let p = (entry.build)();
            assert!(!p.stmts().is_empty(), "{}", entry.name);
            for preset in [Preset::Tiny, Preset::Small, Preset::Medium] {
                let params = (entry.preset)(preset);
                assert_eq!(params.len(), p.params.len(), "{}", entry.name);
            }
        }
    }
}
