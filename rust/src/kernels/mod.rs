//! The kernel corpus: every workload the paper's evaluation touches,
//! expressed in the loop IR (DESIGN.md §Per-experiment index).

pub mod fig2;
pub mod laplace;
pub mod matmul;
pub mod npbench;
pub mod vadv;

use crate::ir::{ContainerKind, Program};
use crate::symbolic::eval::eval_int;
use crate::symbolic::{ContainerId, Sym};

/// Problem-size presets. `Tiny` is for tests; `Small`/`Medium` scale the
/// paper's sizes down to this sandbox (DESIGN.md §Substitutions).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Preset {
    Tiny,
    Small,
    Medium,
}

/// A registered kernel: builder + presets + deterministic input generator.
pub struct KernelEntry {
    pub name: &'static str,
    pub build: fn() -> Program,
    pub preset: fn(Preset) -> Vec<(Sym, i64)>,
    /// Deterministic element initializer: `(container name, index) → value`.
    pub init: fn(&str, usize) -> f64,
}

/// Default initializer: a smooth, bounded, container-dependent pattern.
pub fn default_init(name: &str, i: usize) -> f64 {
    let seed = name.bytes().fold(0u64, |a, b| a.wrapping_mul(31).wrapping_add(b as u64));
    let x = (i as u64).wrapping_mul(2654435761).wrapping_add(seed) % 1024;
    (x as f64) / 1024.0 - 0.5
}

/// Generate inputs for every argument container of `p`.
pub fn gen_inputs(
    p: &Program,
    params: &[(Sym, i64)],
    init: fn(&str, usize) -> f64,
) -> anyhow::Result<Vec<(ContainerId, Vec<f64>)>> {
    let mut out = Vec::new();
    for c in &p.containers {
        if c.kind != ContainerKind::Argument {
            continue;
        }
        let n = eval_int(&c.size, &params.to_vec())? as usize;
        let data: Vec<f64> = (0..n).map(|i| init(&c.name, i)).collect();
        out.push((c.id, data));
    }
    Ok(out)
}

/// The NPBench corpus evaluated in Fig. 10 (20 kernels).
pub fn npbench_corpus() -> Vec<KernelEntry> {
    npbench::corpus()
}

/// Every kernel in the repository (corpus + the headline workloads).
pub fn all_kernels() -> Vec<KernelEntry> {
    let mut v = npbench_corpus();
    v.push(KernelEntry {
        name: "vadv",
        build: vadv::build,
        preset: vadv::preset,
        init: vadv::init,
    });
    v.push(KernelEntry {
        name: "laplace2d",
        build: laplace::build,
        preset: laplace::preset,
        init: default_init,
    });
    v.push(KernelEntry {
        name: "matmul_tiled",
        build: matmul::build_tiled,
        preset: matmul::preset,
        init: default_init,
    });
    v
}

/// Find a kernel by name.
pub fn kernel(name: &str) -> Option<KernelEntry> {
    all_kernels().into_iter().find(|k| k.name == name)
}
