//! The kernel corpus: every workload the paper's evaluation touches,
//! expressed in the loop IR (DESIGN.md §Per-experiment index).

pub mod corpus;
pub mod fig2;
pub mod laplace;
pub mod matmul;
pub mod npbench;
pub mod vadv;

use anyhow::{bail, Result};

use crate::ir::{ContainerKind, Program};
use crate::symbolic::eval::eval_int;
use crate::symbolic::{ContainerId, Sym};

/// Problem-size presets. `Tiny` is for tests; `Small`/`Medium` scale the
/// paper's sizes down to this sandbox (DESIGN.md §Substitutions).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Preset {
    Tiny,
    Small,
    Medium,
}

impl Preset {
    /// Parse a CLI/wire preset name — the one mapping the CLI, the
    /// service daemon, and the client all share.
    pub fn parse(s: &str) -> Result<Preset> {
        match s {
            "tiny" => Ok(Preset::Tiny),
            "small" => Ok(Preset::Small),
            "medium" => Ok(Preset::Medium),
            other => bail!("unknown preset `{other}` (tiny|small|medium)"),
        }
    }
}

/// A registered kernel: builder + presets + deterministic input generator.
#[derive(Clone, Copy)]
pub struct KernelEntry {
    pub name: &'static str,
    pub build: fn() -> Program,
    pub preset: fn(Preset) -> Vec<(Sym, i64)>,
    /// Deterministic element initializer: `(container name, index) → value`.
    pub init: fn(&str, usize) -> f64,
}

/// Default initializer: a smooth, bounded, container-dependent pattern.
pub fn default_init(name: &str, i: usize) -> f64 {
    let seed = name.bytes().fold(0u64, |a, b| a.wrapping_mul(31).wrapping_add(b as u64));
    let x = (i as u64).wrapping_mul(2654435761).wrapping_add(seed) % 1024;
    (x as f64) / 1024.0 - 0.5
}

/// Generate inputs for every argument container of `p`.
pub fn gen_inputs(
    p: &Program,
    params: &[(Sym, i64)],
    init: fn(&str, usize) -> f64,
) -> anyhow::Result<Vec<(ContainerId, Vec<f64>)>> {
    gen_inputs_with(p, params, init)
}

/// [`gen_inputs`] over an arbitrary initializer closure (used for parsed
/// `.silo` kernels, whose `init(shift, scale)` annotations are data, not
/// function pointers).
pub fn gen_inputs_with(
    p: &Program,
    params: &[(Sym, i64)],
    init: impl Fn(&str, usize) -> f64,
) -> anyhow::Result<Vec<(ContainerId, Vec<f64>)>> {
    let mut out = Vec::new();
    for c in &p.containers {
        if c.kind != ContainerKind::Argument {
            continue;
        }
        let n = eval_int(&c.size, &params.to_vec())? as usize;
        let data: Vec<f64> = (0..n).map(|i| init(&c.name, i)).collect();
        out.push((c.id, data));
    }
    Ok(out)
}

/// The NPBench corpus evaluated in Fig. 10 (20 kernels).
pub fn npbench_corpus() -> Vec<KernelEntry> {
    npbench::corpus()
}

/// Every kernel in the repository: the NPBench corpus, the headline
/// workloads, and the parsed `corpus/*.silo` kernels.
pub fn all_kernels() -> Vec<KernelEntry> {
    let mut v = npbench_corpus();
    v.push(KernelEntry {
        name: "vadv",
        build: vadv::build,
        preset: vadv::preset,
        init: vadv::init,
    });
    v.push(KernelEntry {
        name: "laplace2d",
        build: laplace::build,
        preset: laplace::preset,
        init: default_init,
    });
    v.push(KernelEntry {
        name: "matmul_tiled",
        build: matmul::build_tiled,
        preset: matmul::preset,
        init: default_init,
    });
    v.extend(corpus::corpus_kernels());
    v
}

/// Find a kernel by name.
pub fn kernel(name: &str) -> Option<KernelEntry> {
    all_kernels().into_iter().find(|k| k.name == name)
}

/// [`kernel`], with an actionable error: a "did you mean" suggestion when
/// the name is a near miss, plus the full registry listing.
pub fn lookup(name: &str) -> Result<KernelEntry> {
    if let Some(k) = kernel(name) {
        return Ok(k);
    }
    let names: Vec<&'static str> = all_kernels().iter().map(|k| k.name).collect();
    let hint = suggestion(name)
        .map(|s| format!(" — did you mean `{s}`?"))
        .unwrap_or_default();
    bail!(
        "unknown kernel `{name}`{hint}\navailable kernels: {}\n\
         (a path to a .silo file also works, e.g. `corpus/stencil_time.silo`)",
        names.join(", ")
    )
}

/// Closest registered kernel name within a small edit distance.
pub fn suggestion(name: &str) -> Option<&'static str> {
    // Nothing is "near" the empty string — without this guard the
    // near-miss threshold (`max(2)`) would accept any short kernel name
    // as a suggestion for no input at all.
    if name.is_empty() {
        return None;
    }
    let mut best: Option<(usize, &'static str)> = None;
    for k in all_kernels() {
        let d = edit_distance(name, k.name);
        let better = match best {
            None => true,
            Some((bd, _)) => d < bd,
        };
        if better {
            best = Some((d, k.name));
        }
    }
    let (d, n) = best?;
    // Accept near misses only: a third of the name, at least 2 edits.
    if d <= (name.len() / 3).max(2) {
        Some(n)
    } else {
        None
    }
}

/// Plain Levenshtein distance (two-row dynamic program).
fn edit_distance(a: &str, b: &str) -> usize {
    let (a, b): (Vec<char>, Vec<char>) = (a.chars().collect(), b.chars().collect());
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for i in 1..=a.len() {
        cur[0] = i;
        for j in 1..=b.len() {
            let sub = prev[j - 1] + usize::from(a[i - 1] != b[j - 1]);
            cur[j] = sub.min(prev[j] + 1).min(cur[j - 1] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// A kernel resolved from either the registry (by name) or a `.silo` file
/// (by path) — the single intake the driver, tuner, and CLI share, so
/// parsed files flow through every harness with zero special cases.
pub enum ResolvedKernel {
    Registry(KernelEntry),
    File {
        name: String,
        parsed: crate::frontend::ParsedKernel,
    },
}

/// Resolve a kernel name or `.silo` path. Registry names win; anything
/// with a path separator or a `.silo` suffix is read from disk.
pub fn resolve(spec: &str) -> Result<ResolvedKernel> {
    // Guard the degenerate input up front: an empty spec must produce a
    // plain actionable error, never reach the did-you-mean machinery
    // (whose near-miss threshold is meaningless for zero-length names)
    // or probe the filesystem for "".
    if spec.trim().is_empty() {
        bail!("empty kernel name (pass a registered name — see `silo list` — or a .silo path)");
    }
    let looks_like_path =
        spec.contains('/') || spec.contains('\\') || spec.ends_with(".silo");
    if !looks_like_path {
        if let Some(entry) = kernel(spec) {
            return Ok(ResolvedKernel::Registry(entry));
        }
    }
    let path = std::path::Path::new(spec);
    if path.is_file() {
        let parsed = crate::frontend::parse_file(path)?;
        return Ok(ResolvedKernel::File {
            name: parsed.program.name.clone(),
            parsed,
        });
    }
    if looks_like_path {
        bail!("no such file: {spec}");
    }
    // Not a file either — fall through to the registry error with its
    // did-you-mean hint.
    lookup(spec).map(ResolvedKernel::Registry)
}

impl ResolvedKernel {
    pub fn name(&self) -> &str {
        match self {
            ResolvedKernel::Registry(e) => e.name,
            ResolvedKernel::File { name, .. } => name,
        }
    }

    /// A pristine (unoptimized) copy of the program.
    pub fn program(&self) -> Program {
        match self {
            ResolvedKernel::Registry(e) => (e.build)(),
            ResolvedKernel::File { parsed, .. } => parsed.program.clone(),
        }
    }

    /// Parameter bindings for `preset`.
    pub fn params(&self, preset: Preset) -> Result<Vec<(Sym, i64)>> {
        match self {
            ResolvedKernel::Registry(e) => Ok((e.preset)(preset)),
            ResolvedKernel::File { parsed, .. } => parsed.params_for(preset),
        }
    }

    /// Deterministic inputs for every argument container of `p`.
    pub fn inputs(
        &self,
        p: &Program,
        params: &[(Sym, i64)],
    ) -> Result<Vec<(ContainerId, Vec<f64>)>> {
        match self {
            ResolvedKernel::Registry(e) => gen_inputs(p, params, e.init),
            ResolvedKernel::File { parsed, .. } => {
                gen_inputs_with(p, params, |name, i| parsed.init_value(name, i))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Empty and whitespace-only specs fail with the plain guard error —
    /// no panic, no filesystem probe, no nonsense suggestion.
    #[test]
    fn empty_spec_is_a_plain_error() {
        for spec in ["", "  ", "\t"] {
            let err = resolve(spec).unwrap_err().to_string();
            assert!(err.contains("empty kernel name"), "{spec:?}: {err}");
            assert!(!err.contains("did you mean"), "{spec:?}: {err}");
        }
        assert!(suggestion("").is_none());
    }

    /// Near misses still get their suggestion after the guard.
    #[test]
    fn near_miss_still_suggests() {
        assert_eq!(suggestion("vadw"), Some("vadv"));
        let err = resolve("vadw").unwrap_err().to_string();
        assert!(err.contains("did you mean `vadv`"), "{err}");
    }
}
