//! The two didactic loops of Fig. 2 — variable strides that eject both
//! nests from the polyhedral model while SILO's representation captures
//! them exactly.

use crate::ir::{Program, ProgramBuilder};
use crate::symbolic::{func, int, Expr, FuncKind, Sym};

use super::Preset;

/// `for (i=1; i <= n; i += i) a[log2(i)] = 1.0;`
pub fn build_log2() -> Program {
    let mut b = ProgramBuilder::new("fig2_log2");
    let n = b.param_positive("fig2a_N");
    let a = b.array("A", int(64));
    let i = b.sym("fig2a_i");
    b.for_(i, int(1), Expr::Sym(n) + int(1), Expr::Sym(i), |b| {
        b.assign(a, func(FuncKind::Log2, vec![Expr::Sym(i)]), Expr::real(1.0));
    });
    b.finish()
}

/// `for (i=0; i <= n/2+1; ++i) for (j=i; j <= n; j += i+1) a[j] = 0.0;`
pub fn build_triangular() -> Program {
    let mut b = ProgramBuilder::new("fig2_tri");
    let n = b.param_positive("fig2b_N");
    let a = b.array("A", Expr::Sym(n) + int(2));
    let i = b.sym("fig2b_i");
    let j = b.sym("fig2b_j");
    b.for_(
        i,
        int(0),
        crate::symbolic::floordiv(Expr::Sym(n), int(2)) + int(2),
        int(1),
        |b| {
            b.for_(j, Expr::Sym(i), Expr::Sym(n) + int(1), Expr::Sym(i) + int(1), |b| {
                b.assign(a, Expr::Sym(j), Expr::real(0.0));
            });
        },
    );
    b.finish()
}

pub fn preset(p: Preset) -> Vec<(Sym, i64)> {
    let n = match p {
        Preset::Tiny => 16,
        Preset::Small => 1 << 10,
        Preset::Medium => 1 << 20,
    };
    vec![(Sym::new("fig2a_N"), n), (Sym::new("fig2b_N"), n)]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{classify_program, AffineViolation};
    use crate::exec::Vm;

    #[test]
    fn both_rejected_by_polyhedral_model() {
        for p in [build_log2(), build_triangular()] {
            let r = classify_program(&p);
            assert!(
                r.violations
                    .iter()
                    .any(|v| matches!(v, AffineViolation::NonConstantStride { .. })),
                "{}: {:?}",
                p.name,
                r.violations
            );
        }
    }

    #[test]
    fn silo_analyzes_and_executes_both() {
        // log2 loop: executes, sets a[0..log2(n)] = 1.
        let p = build_log2();
        let vm = Vm::compile(&p).unwrap();
        let out = vm
            .run(&[(Sym::new("fig2a_N"), 16)], &[], 1)
            .unwrap();
        let a = out.by_name("A").unwrap();
        assert_eq!(&a[0..5], &[1.0; 5]);
        assert_eq!(a[5], 0.0);

        // triangular loop: every index 0..=n written (each j reachable:
        // for i=0, stride 1 covers all).
        let p = build_triangular();
        let vm = Vm::compile(&p).unwrap();
        let out = vm
            .run(&[(Sym::new("fig2b_N"), 16)], &[], 1)
            .unwrap();
        let a = out.by_name("A").unwrap();
        assert!(a[0..17].iter().all(|v| *v == 0.0));
    }

    #[test]
    fn visibility_over_approximates_log2_loop() {
        let p = build_log2();
        let l = p.loops()[0];
        let (_, writes) = crate::analysis::loop_summary(l, &p.containers);
        assert!(writes[0].whole, "variable stride ⇒ whole-container");
    }
}
