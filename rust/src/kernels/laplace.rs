//! The Fig. 1 kernel: 2-D Laplace operator with *parametric strides*.
//!
//! `lap[i*lsI + j*lsJ] = 4·in[i*isI + j*isJ] − in[(i±1)·isI + j·isJ] −
//! in[i·isI + (j±1)·isJ]` — the access strides `isI/isJ/lsI/lsJ` are plain
//! parameters (custom padding), which makes every offset a multivariate
//! polynomial: polyhedral tools reject the nest, icc fails its dependence
//! test, and general-purpose compilers drown in index-arithmetic register
//! pressure. SILO analyzes it inductively and schedules the accesses with
//! pointer incrementation.

use crate::ir::{Program, ProgramBuilder};
use crate::symbolic::{int, load, Expr, Sym};

use super::Preset;

pub fn build() -> Program {
    let mut b = ProgramBuilder::new("laplace2d");
    // NOT dim_params: the strides are opaque padding parameters (Fig. 1).
    let ii = b.param_positive("lap_I");
    let jj = b.param_positive("lap_J");
    let isi = b.param_positive("lap_isI");
    let isj = b.param_positive("lap_isJ");
    let lsi = b.param_positive("lap_lsI");
    let lsj = b.param_positive("lap_lsJ");
    let (iie, jje) = (Expr::Sym(ii), Expr::Sym(jj));
    let input = b.array(
        "in",
        (iie.clone() + int(2)) * Expr::Sym(isi) + (jje.clone() + int(2)) * Expr::Sym(isj) + int(1),
    );
    let lap = b.array(
        "lap",
        (iie.clone() + int(2)) * Expr::Sym(lsi) + (jje.clone() + int(2)) * Expr::Sym(lsj) + int(1),
    );
    let j = b.sym("lap_j");
    let i = b.sym("lap_i");
    b.for_(j, int(1), jje.clone() - int(1), int(1), |b| {
        b.for_(i, int(1), iie.clone() - int(1), int(1), |b| {
            let at = |di: i64, dj: i64| {
                (Expr::Sym(i) + int(di)) * Expr::Sym(isi)
                    + (Expr::Sym(j) + int(dj)) * Expr::Sym(isj)
            };
            b.assign(
                lap,
                Expr::Sym(i) * Expr::Sym(lsi) + Expr::Sym(j) * Expr::Sym(lsj),
                Expr::real(4.0) * load(input, at(0, 0))
                    - load(input, at(1, 0))
                    - load(input, at(-1, 0))
                    - load(input, at(0, 1))
                    - load(input, at(0, -1)),
            );
        });
    });
    b.finish()
}

pub fn preset(p: Preset) -> Vec<(Sym, i64)> {
    // Row-major with one element of padding per row: isI = 1, isJ = I+2.
    let (i, j) = match p {
        Preset::Tiny => (14, 12),
        Preset::Small => (254, 254),
        Preset::Medium => (1022, 1022),
    };
    vec![
        (Sym::new("lap_I"), i),
        (Sym::new("lap_J"), j),
        (Sym::new("lap_isI"), 1),
        (Sym::new("lap_isJ"), i + 2),
        (Sym::new("lap_lsI"), 1),
        (Sym::new("lap_lsJ"), i + 2),
    ]
}

/// Rust oracle.
pub fn reference(iv: usize, jv: usize, input: &[f64]) -> Vec<f64> {
    let (isi, isj, lsi, lsj) = (1usize, iv + 2, 1usize, iv + 2);
    let mut lap = vec![0.0; (iv + 2) * lsi + (jv + 2) * lsj + 1];
    for j in 1..jv - 1 {
        for i in 1..iv - 1 {
            let at = |di: i64, dj: i64| {
                ((i as i64 + di) as usize) * isi + ((j as i64 + dj) as usize) * isj
            };
            lap[i * lsi + j * lsj] = 4.0 * input[at(0, 0)]
                - input[at(1, 0)]
                - input[at(-1, 0)]
                - input[at(0, 1)]
                - input[at(0, -1)];
        }
    }
    lap
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::classify_program;
    use crate::exec::Vm;
    use crate::kernels::{default_init, gen_inputs};

    #[test]
    fn rejected_by_polyhedral_model() {
        let p = build();
        assert!(!classify_program(&p).is_scop(), "Fig. 1's whole point");
    }

    #[test]
    fn silo_parallelizes_it() {
        let mut p = build();
        crate::transforms::silo_cfg1(&mut p).unwrap();
        assert!(p.loops().iter().any(|l| l.is_parallel()));
    }

    #[test]
    fn vm_matches_reference() {
        let p = build();
        let params = preset(Preset::Tiny);
        let inputs = gen_inputs(&p, &params, default_init).unwrap();
        let refs: Vec<_> = inputs.iter().map(|(c, v)| (*c, v.as_slice())).collect();
        let vm = Vm::compile(&p).unwrap();
        let out = vm.run(&params, &refs, 1).unwrap();
        let got = out.by_name("lap").unwrap();
        let in_data = &inputs[0].1;
        let expect = reference(14, 12, in_data);
        // Compare only the interior the kernel writes (unwritten positions
        // keep the generated input pattern, the reference keeps zeros).
        let (iv, jv, lsi, lsj) = (14usize, 12usize, 1usize, 16usize);
        for j in 1..jv - 1 {
            for i in 1..iv - 1 {
                let o = i * lsi + j * lsj;
                assert!((got[o] - expect[o]).abs() < 1e-9, "{} vs {}", got[o], expect[o]);
            }
        }
    }

    #[test]
    fn ptr_inc_matches_naive() {
        let params = preset(Preset::Tiny);
        let run = |ptr_inc: bool| {
            let mut p = build();
            if ptr_inc {
                crate::schedules::schedule_all_ptr_inc(&mut p);
            }
            let inputs = gen_inputs(&p, &params, default_init).unwrap();
            let refs: Vec<_> = inputs.iter().map(|(c, v)| (*c, v.as_slice())).collect();
            let vm = Vm::compile(&p).unwrap();
            let out = vm.run(&params, &refs, 1).unwrap();
            out.by_name("lap").unwrap().to_vec()
        };
        assert_eq!(run(false), run(true));
    }
}
