//! Tiled matrix multiplication — the Table 1 workload.
//!
//! Mirrors the DaCe optimization recipe the paper starts from: the product
//! is tiled twice, with a buffer for the output tile and a buffer for one
//! input tile. The tile-boundary stride jumps are exactly where §4.1's
//! automatic software prefetching fires.

use crate::ir::{Program, ProgramBuilder};
use crate::symbolic::{int, load, min, Expr, Sym};

use super::Preset;

pub const TILE: i64 = 32;

/// Twice-tiled `C = A @ B` (square `N×N`, row-major, N a multiple of the
/// tile for simplicity — presets guarantee it).
pub fn build_tiled() -> Program {
    let mut b = ProgramBuilder::new("matmul_tiled");
    let n = b.dim_param("mm_N");
    let ne = Expr::Sym(n);
    let a = b.array("A", ne.clone() * ne.clone());
    let bb = b.array("B", ne.clone() * ne.clone());
    let c = b.array("C", ne.clone() * ne.clone());
    let cbuf = b.transient("Cbuf", int(TILE * TILE));
    let bbuf = b.transient("Bbuf", int(TILE * TILE));

    let it = b.sym("mm_it");
    let jt = b.sym("mm_jt");
    let kt = b.sym("mm_kt");
    let (zi, zj) = (b.sym("mm_zi"), b.sym("mm_zj"));
    let (ci, cj) = (b.sym("mm_ci"), b.sym("mm_cj"));
    let (bk, bj) = (b.sym("mm_bk"), b.sym("mm_bj"));
    let (mi, mk, mj) = (b.sym("mm_mi"), b.sym("mm_mk"), b.sym("mm_mj"));

    let t = int(TILE);
    // Upper bound of the intra-tile loop starting at tile variable `v`.
    let hi = |v: Sym| min(Expr::Sym(v) + int(TILE), ne.clone());
    b.for_(it, int(0), ne.clone(), t.clone(), |b| {
        b.for_(jt, int(0), ne.clone(), t.clone(), |b| {
            // Zero the output-tile buffer.
            b.for_(zi, int(0), t.clone(), int(1), |b| {
                b.for_(zj, int(0), t.clone(), int(1), |b| {
                    b.assign(cbuf, Expr::Sym(zi) * t.clone() + Expr::Sym(zj), Expr::real(0.0));
                });
            });
            // Accumulate over k tiles.
            b.for_(kt, int(0), ne.clone(), t.clone(), |b| {
                // Stage the B tile (tile-boundary stride jump → prefetch).
                b.for_(bk, Expr::Sym(kt), hi(kt), int(1), |b| {
                    b.for_(bj, Expr::Sym(jt), hi(jt), int(1), |b| {
                        b.assign(
                            bbuf,
                            (Expr::Sym(bk) - Expr::Sym(kt)) * t.clone()
                                + (Expr::Sym(bj) - Expr::Sym(jt)),
                            load(bb, Expr::Sym(bk) * ne.clone() + Expr::Sym(bj)),
                        );
                    });
                });
                // Micro-kernel: i-k-j over the tile.
                b.for_(mi, Expr::Sym(it), hi(it), int(1), |b| {
                    b.for_(mk, Expr::Sym(kt), hi(kt), int(1), |b| {
                        b.for_(mj, Expr::Sym(jt), hi(jt), int(1), |b| {
                            let coff = (Expr::Sym(mi) - Expr::Sym(it)) * t.clone()
                                + (Expr::Sym(mj) - Expr::Sym(jt));
                            b.assign(
                                cbuf,
                                coff.clone(),
                                load(cbuf, coff)
                                    + load(a, Expr::Sym(mi) * ne.clone() + Expr::Sym(mk))
                                        * load(
                                            bbuf,
                                            (Expr::Sym(mk) - Expr::Sym(kt)) * t.clone()
                                                + (Expr::Sym(mj) - Expr::Sym(jt)),
                                        ),
                            );
                        });
                    });
                });
            });
            // Write the tile back.
            b.for_(ci, Expr::Sym(it), hi(it), int(1), |b| {
                b.for_(cj, Expr::Sym(jt), hi(jt), int(1), |b| {
                    b.assign(
                        c,
                        Expr::Sym(ci) * ne.clone() + Expr::Sym(cj),
                        load(
                            cbuf,
                            (Expr::Sym(ci) - Expr::Sym(it)) * t.clone()
                                + (Expr::Sym(cj) - Expr::Sym(jt)),
                        ),
                    );
                });
            });
        });
    });
    b.finish()
}

/// Untitled naive `C = A @ B` (reference structure for tests/benches).
pub fn build_naive() -> Program {
    let mut b = ProgramBuilder::new("matmul_naive");
    let n = b.dim_param("mmn_N");
    let ne = Expr::Sym(n);
    let a = b.array("A", ne.clone() * ne.clone());
    let bb = b.array("B", ne.clone() * ne.clone());
    let c = b.array("C", ne.clone() * ne.clone());
    let (i, j, k) = (b.sym("mmn_i"), b.sym("mmn_j"), b.sym("mmn_k"));
    b.for_(i, int(0), ne.clone(), int(1), |b| {
        b.for_(j, int(0), ne.clone(), int(1), |b| {
            b.for_(k, int(0), ne.clone(), int(1), |b| {
                let coff = Expr::Sym(i) * ne.clone() + Expr::Sym(j);
                b.assign(
                    c,
                    coff.clone(),
                    load(c, coff)
                        + load(a, Expr::Sym(i) * ne.clone() + Expr::Sym(k))
                            * load(bb, Expr::Sym(k) * ne.clone() + Expr::Sym(j)),
                );
            });
        });
    });
    b.finish()
}

pub fn preset(p: Preset) -> Vec<(Sym, i64)> {
    let n = match p {
        Preset::Tiny => 64,
        Preset::Small => 128,
        Preset::Medium => 256,
    };
    vec![(Sym::new("mm_N"), n)]
}

/// Rust oracle.
pub fn reference(n: usize, a: &[f64], b: &[f64]) -> Vec<f64> {
    let mut c = vec![0.0; n * n];
    for i in 0..n {
        for k in 0..n {
            let aik = a[i * n + k];
            for j in 0..n {
                c[i * n + j] += aik * b[k * n + j];
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::Vm;
    use crate::kernels::{default_init, gen_inputs};

    #[test]
    fn tiled_matches_reference() {
        let p = build_tiled();
        let params = preset(Preset::Tiny);
        let inputs = gen_inputs(&p, &params, default_init).unwrap();
        let refs: Vec<_> = inputs.iter().map(|(c, v)| (*c, v.as_slice())).collect();
        let vm = Vm::compile(&p).unwrap();
        let out = vm.run(&params, &refs, 1).unwrap();
        let got = out.by_name("C").unwrap();
        let n = 64usize;
        let expect = reference(n, &inputs[0].1, &inputs[1].1);
        for (g, e) in got.iter().zip(&expect) {
            assert!((g - e).abs() < 1e-9, "{g} vs {e}");
        }
    }

    #[test]
    fn prefetch_hints_generated_at_tile_boundaries() {
        let mut p = build_tiled();
        let added = crate::schedules::schedule_prefetches(&mut p);
        assert!(added >= 2, "expected tile-boundary hints, got {added}");
    }
}
