//! SILO-Text — the textual frontend.
//!
//! A small loop-nest DSL that elaborates into the existing [`crate::ir`]:
//! `param`/`array` declarations, C-style `for (var = start; var < end;
//! var += stride)` nests with fully symbolic bounds and strides, and
//! guarded single-assignment statements over subscripted containers. The
//! canonical printer ([`crate::ir::pretty`]) emits this exact grammar, so
//! `parse ∘ print` round-trips on every registered kernel (pinned by
//! `rust/tests/frontend.rs`).
//!
//! ```text
//! program stencil_time {
//!   param st_T = { tiny: 4, small: 16, medium: 64 };   // presets bind at run time
//!   param st_N = { tiny: 64, small: 4096, medium: 65536 };
//!   array u[st_N];            // argument container (externally visible)
//!   transient tmp[st_N];      // program-allocated scratch
//!   for (t = 0; t < st_T; t += 1) {
//!     for (i = 1; i < st_N - 1; i += 1) {
//!       tmp[i] = 0.25*u[i - 1] + 0.5*u[i] + 0.25*u[i + 1];
//!     }
//!     for (j = 1; j < st_N - 1; j += 1) {
//!       u[j] = tmp[j];
//!     }
//!   }
//! }
//! ```
//!
//! Diagnostics carry `line:column` spans and name what was expected;
//! duplicate/undeclared-symbol structure is double-checked through
//! [`crate::ir::validate`] after elaboration. See DESIGN.md §SILO-Text for
//! the full grammar (EBNF).
//!
//! Two scoping caveats, inherited from the crate's design:
//!
//! * Symbols are interned in a **process-global table** (like the Rust
//!   kernel builders): `param N;` registers `N` as strictly positive for
//!   the whole process, so two programs parsed in one process that reuse
//!   a name share one symbol *and its assumptions*. Corpus files follow
//!   the builders' convention of kernel-prefixed names (`st_N`, `gs_S`);
//!   do the same when parsing multiple programs in one process. Preset
//!   bindings are checked against the assumed floor at parse time.
//! * Presets and `init(...)` annotations live on [`ParsedKernel`], not
//!   on the [`Program`] — the canonical printer round-trips the program
//!   structure exactly, but its output carries no preset bindings (add
//!   them before running a printed file; the runtime error names the
//!   param and the syntax).

pub mod lexer;
pub mod parser;

use crate::ir::Program;
use crate::kernels::Preset;
use crate::symbolic::Sym;

/// Source position (1-based line and column) of a token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    pub line: u32,
    pub col: u32,
}

/// A parse/elaboration failure with its source position.
#[derive(Debug, Clone)]
pub struct ParseError {
    span: Span,
    msg: String,
}

impl ParseError {
    pub(crate) fn new(span: Span, msg: String) -> ParseError {
        ParseError { span, msg }
    }

    /// 1-based source line of the error.
    pub fn line(&self) -> u32 {
        self.span.line
    }

    /// 1-based source column of the error.
    pub fn col(&self) -> u32 {
        self.span.col
    }

    /// The bare message (without the position prefix).
    pub fn message(&self) -> &str {
        &self.msg
    }
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "parse error at line {}, column {}: {}",
            self.span.line, self.span.col, self.msg
        )
    }
}

impl std::error::Error for ParseError {}

/// Per-preset integer bindings of one `param`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PresetBindings {
    pub tiny: Option<i64>,
    pub small: Option<i64>,
    pub medium: Option<i64>,
}

impl PresetBindings {
    pub fn get(&self, p: Preset) -> Option<i64> {
        match p {
            Preset::Tiny => self.tiny,
            Preset::Small => self.small,
            Preset::Medium => self.medium,
        }
    }
}

/// Deterministic input annotation on an argument container:
/// `value = shift + scale · default_init(name, index)`.
#[derive(Debug, Clone, PartialEq)]
pub struct InitSpec {
    pub container: String,
    pub shift: f64,
    pub scale: f64,
}

/// A parsed SILO-Text module: the elaborated program plus the run-time
/// annotations (presets, input initialization) that live outside the IR.
#[derive(Debug, Clone)]
pub struct ParsedKernel {
    pub program: Program,
    pub presets: Vec<(Sym, PresetBindings)>,
    pub inits: Vec<InitSpec>,
}

impl ParsedKernel {
    /// Bind every program param for `preset`. Errors name the param that
    /// has no binding (so `silo run file.silo` failures are actionable).
    pub fn params_for(&self, preset: Preset) -> anyhow::Result<Vec<(Sym, i64)>> {
        let mut out = Vec::new();
        for sym in &self.program.params {
            let bound = self
                .presets
                .iter()
                .find(|(s, _)| s == sym)
                .and_then(|(_, b)| b.get(preset));
            match bound {
                Some(v) => out.push((*sym, v)),
                None => anyhow::bail!(
                    "param `{}` of program `{}` has no {:?} preset binding; annotate it, \
                     e.g. `param {} = {{ tiny: 16, small: 1024, medium: 1048576 }};`",
                    sym.name(),
                    self.program.name,
                    preset,
                    sym.name()
                ),
            }
        }
        Ok(out)
    }

    /// Element initializer honoring `init(shift, scale)` annotations;
    /// containers without one use [`crate::kernels::default_init`].
    pub fn init_value(&self, name: &str, i: usize) -> f64 {
        init_value_with(&self.inits, name, i)
    }
}

/// [`ParsedKernel::init_value`] over a bare annotation list — for
/// callers (the service daemon) that keep the annotations without the
/// rest of the parse.
pub fn init_value_with(inits: &[InitSpec], name: &str, i: usize) -> f64 {
    let base = crate::kernels::default_init(name, i);
    match inits.iter().find(|s| s.container == name) {
        Some(s) => s.shift + s.scale * base,
        None => base,
    }
}

/// Parse a SILO-Text module from a string.
pub fn parse_str(src: &str) -> Result<ParsedKernel, ParseError> {
    parser::parse(src)
}

/// Parse a SILO-Text module from a file path (errors are prefixed with the
/// path so CLI messages stay readable).
pub fn parse_file(path: &std::path::Path) -> anyhow::Result<ParsedKernel> {
    let src = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read {}: {e}", path.display()))?;
    parse_str(&src).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_minimal_program() {
        let src = "program t {\n  param fe_N;\n  array A[fe_N];\n  for (fe_i = 0; fe_i < fe_N; \
                   fe_i += 1) {\n    A[fe_i] = 2.0*A[fe_i];\n  }\n}\n";
        let k = parse_str(src).unwrap();
        assert_eq!(k.program.name, "t");
        assert_eq!(k.program.loops().len(), 1);
        assert_eq!(k.program.stmts().len(), 1);
        crate::ir::validate::validate(&k.program).unwrap();
    }

    #[test]
    fn error_carries_line_and_column() {
        let src = "program t {\n  array A[8];\n  A[0] = ;\n}\n";
        let e = parse_str(src).unwrap_err();
        assert_eq!(e.line(), 3);
        assert!(e.col() > 0);
        assert!(e.to_string().contains("expected an expression"), "{e}");
    }

    #[test]
    fn undeclared_symbol_is_reported_with_span() {
        let src = "program t {\n  array A[8];\n  for (i = 0; i < 8; i += 1) {\n    A[i] = \
                   1.0 + rogue;\n  }\n}\n";
        let e = parse_str(src).unwrap_err();
        assert_eq!(e.line(), 4);
        assert!(e.message().contains("undeclared symbol `rogue`"), "{e}");
    }

    #[test]
    fn presets_bind_per_size() {
        let src = "program t {\n  param pe_N = { tiny: 4, small: 8, medium: 16 };\n  \
                   param pe_M = 3;\n  array A[pe_N*pe_M];\n}\n";
        let k = parse_str(src).unwrap();
        let tiny = k.params_for(Preset::Tiny).unwrap();
        assert!(tiny.contains(&(Sym::new("pe_N"), 4)));
        assert!(tiny.contains(&(Sym::new("pe_M"), 3)));
        let med = k.params_for(Preset::Medium).unwrap();
        assert!(med.contains(&(Sym::new("pe_N"), 16)));
    }

    #[test]
    fn non_positive_preset_bindings_rejected() {
        // Params are interned strictly positive; a binding below the floor
        // would hand the analyses a false invariant.
        let src = "program t {\n  param bp_N = { tiny: 0, small: 8, medium: 16 };\n  \
                   array A[bp_N];\n}\n";
        let e = parse_str(src).unwrap_err();
        assert!(e.message().contains("below its assumed minimum"), "{e}");
        let src = "program t {\n  param bp_M: dim = 1;\n  array A[bp_M];\n}\n";
        let e = parse_str(src).unwrap_err();
        assert!(e.message().contains("minimum 2"), "{e}");
    }

    #[test]
    fn missing_preset_binding_is_actionable() {
        let src = "program t {\n  param pm_N;\n  array A[pm_N];\n}\n";
        let k = parse_str(src).unwrap();
        let e = k.params_for(Preset::Tiny).unwrap_err();
        assert!(e.to_string().contains("pm_N"), "{e}");
    }
}
