//! Tokenizer for SILO-Text. Every token carries its source position so the
//! parser can report `line:col` diagnostics.

use super::{ParseError, Span};

/// A lexical token. Keywords are not distinguished here — the parser matches
/// identifier spellings contextually (`program`, `param`, `for`, …), which
/// keeps the keyword set open for future extensions.
#[derive(Debug, Clone, PartialEq)]
pub enum Tok {
    /// Identifier (also covers keywords and function names).
    Ident(String),
    /// Double-quoted string (container names with non-identifier characters).
    Str(String),
    Int(i64),
    Real(f64),
    LBrace,
    RBrace,
    LParen,
    RParen,
    LBracket,
    RBracket,
    Semi,
    Comma,
    Colon,
    Assign,
    Plus,
    PlusAssign,
    Minus,
    Star,
    Slash,
    Caret,
    Lt,
    Le,
    Gt,
    Ge,
    /// `<>` — the printer's "direction decided by the stride sign" comparator.
    AnyDir,
    Eof,
}

impl Tok {
    /// Human-readable token description for "expected X, found Y" messages.
    pub fn describe(&self) -> String {
        match self {
            Tok::Ident(s) => format!("`{s}`"),
            Tok::Str(s) => format!("\"{s}\""),
            Tok::Int(v) => format!("`{v}`"),
            Tok::Real(v) => format!("`{v}`"),
            Tok::LBrace => "`{`".into(),
            Tok::RBrace => "`}`".into(),
            Tok::LParen => "`(`".into(),
            Tok::RParen => "`)`".into(),
            Tok::LBracket => "`[`".into(),
            Tok::RBracket => "`]`".into(),
            Tok::Semi => "`;`".into(),
            Tok::Comma => "`,`".into(),
            Tok::Colon => "`:`".into(),
            Tok::Assign => "`=`".into(),
            Tok::Plus => "`+`".into(),
            Tok::PlusAssign => "`+=`".into(),
            Tok::Minus => "`-`".into(),
            Tok::Star => "`*`".into(),
            Tok::Slash => "`/`".into(),
            Tok::Caret => "`^`".into(),
            Tok::Lt => "`<`".into(),
            Tok::Le => "`<=`".into(),
            Tok::Gt => "`>`".into(),
            Tok::Ge => "`>=`".into(),
            Tok::AnyDir => "`<>`".into(),
            Tok::Eof => "end of input".into(),
        }
    }
}

/// A token plus the position of its first character.
#[derive(Debug, Clone)]
pub struct Token {
    pub tok: Tok,
    pub span: Span,
}

/// Tokenize an entire source string. `//` comments run to end of line.
pub fn lex(src: &str) -> Result<Vec<Token>, ParseError> {
    let bytes = src.as_bytes();
    let mut toks = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    let mut col = 1u32;

    macro_rules! push {
        ($tok:expr, $len:expr) => {{
            toks.push(Token {
                tok: $tok,
                span: Span { line, col },
            });
            let n: usize = $len;
            i += n;
            col += n as u32;
        }};
    }

    while i < bytes.len() {
        let c = bytes[i] as char;
        match c {
            '\n' => {
                i += 1;
                line += 1;
                col = 1;
            }
            ' ' | '\t' | '\r' => {
                i += 1;
                col += 1;
            }
            '/' if bytes.get(i + 1) == Some(&b'/') => {
                while i < bytes.len() && bytes[i] != b'\n' {
                    i += 1;
                }
                // Newline handled by the main loop (keeps line counting in
                // one place).
            }
            '{' => push!(Tok::LBrace, 1),
            '}' => push!(Tok::RBrace, 1),
            '(' => push!(Tok::LParen, 1),
            ')' => push!(Tok::RParen, 1),
            '[' => push!(Tok::LBracket, 1),
            ']' => push!(Tok::RBracket, 1),
            ';' => push!(Tok::Semi, 1),
            ',' => push!(Tok::Comma, 1),
            ':' => push!(Tok::Colon, 1),
            '=' => push!(Tok::Assign, 1),
            '*' => push!(Tok::Star, 1),
            '/' => push!(Tok::Slash, 1),
            '^' => push!(Tok::Caret, 1),
            '-' => push!(Tok::Minus, 1),
            '+' => {
                if bytes.get(i + 1) == Some(&b'=') {
                    push!(Tok::PlusAssign, 2);
                } else {
                    push!(Tok::Plus, 1);
                }
            }
            '<' => match bytes.get(i + 1) {
                Some(&b'=') => push!(Tok::Le, 2),
                Some(&b'>') => push!(Tok::AnyDir, 2),
                _ => push!(Tok::Lt, 1),
            },
            '>' => {
                if bytes.get(i + 1) == Some(&b'=') {
                    push!(Tok::Ge, 2);
                } else {
                    push!(Tok::Gt, 1);
                }
            }
            '"' => {
                let start = i + 1;
                let mut j = start;
                while j < bytes.len() && bytes[j] != b'"' && bytes[j] != b'\n' {
                    j += 1;
                }
                if j >= bytes.len() || bytes[j] != b'"' {
                    return Err(ParseError::new(
                        Span { line, col },
                        "unterminated string literal".into(),
                    ));
                }
                let s = src[start..j].to_string();
                let len = j + 1 - i;
                push!(Tok::Str(s), len);
            }
            _ if c.is_ascii_digit() => {
                let start = i;
                let mut j = i;
                while j < bytes.len() && bytes[j].is_ascii_digit() {
                    j += 1;
                }
                let mut is_real = false;
                if j < bytes.len()
                    && bytes[j] == b'.'
                    && bytes.get(j + 1).is_some_and(u8::is_ascii_digit)
                {
                    is_real = true;
                    j += 1;
                    while j < bytes.len() && bytes[j].is_ascii_digit() {
                        j += 1;
                    }
                }
                if j < bytes.len()
                    && (bytes[j] == b'e' || bytes[j] == b'E')
                    && (bytes.get(j + 1).is_some_and(u8::is_ascii_digit)
                        || ((bytes.get(j + 1) == Some(&b'+') || bytes.get(j + 1) == Some(&b'-'))
                            && bytes.get(j + 2).is_some_and(u8::is_ascii_digit)))
                {
                    is_real = true;
                    j += 1;
                    if bytes[j] == b'+' || bytes[j] == b'-' {
                        j += 1;
                    }
                    while j < bytes.len() && bytes[j].is_ascii_digit() {
                        j += 1;
                    }
                }
                let text = &src[start..j];
                let len = j - start;
                if is_real {
                    let v: f64 = text.parse().map_err(|_| {
                        ParseError::new(
                            Span { line, col },
                            format!("malformed number `{text}`"),
                        )
                    })?;
                    push!(Tok::Real(v), len);
                } else if let Ok(v) = text.parse::<i64>() {
                    push!(Tok::Int(v), len);
                } else {
                    // Integer literal too large for i64: fall back to a real
                    // (the printer writes large real constants without a dot).
                    let v: f64 = text.parse().map_err(|_| {
                        ParseError::new(
                            Span { line, col },
                            format!("malformed number `{text}`"),
                        )
                    })?;
                    push!(Tok::Real(v), len);
                }
            }
            _ if c.is_ascii_alphabetic() || c == '_' => {
                let start = i;
                let mut j = i;
                while j < bytes.len()
                    && ((bytes[j] as char).is_ascii_alphanumeric()
                        || bytes[j] == b'_'
                        || bytes[j] == b'#')
                {
                    j += 1;
                }
                let s = src[start..j].to_string();
                let len = j - start;
                push!(Tok::Ident(s), len);
            }
            other => {
                return Err(ParseError::new(
                    Span { line, col },
                    format!("unexpected character `{other}`"),
                ));
            }
        }
    }
    toks.push(Token {
        tok: Tok::Eof,
        span: Span { line, col },
    });
    Ok(toks)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexes_basic_stream() {
        let toks = lex("for (i = 0; i < n; i += 1) { }").unwrap();
        assert!(matches!(toks[0].tok, Tok::Ident(ref s) if s == "for"));
        assert!(toks.iter().any(|t| t.tok == Tok::PlusAssign));
        assert!(toks.iter().any(|t| t.tok == Tok::Lt));
        assert_eq!(toks.last().unwrap().tok, Tok::Eof);
    }

    #[test]
    fn tracks_line_and_column() {
        let toks = lex("a\n  bb").unwrap();
        assert_eq!((toks[0].span.line, toks[0].span.col), (1, 1));
        assert_eq!((toks[1].span.line, toks[1].span.col), (2, 3));
    }

    #[test]
    fn comments_are_skipped() {
        let toks = lex("a // comment <>\nb").unwrap();
        let idents: Vec<_> = toks
            .iter()
            .filter_map(|t| match &t.tok {
                Tok::Ident(s) => Some(s.as_str()),
                _ => None,
            })
            .collect();
        assert_eq!(idents, ["a", "b"]);
    }

    #[test]
    fn numbers_int_real_and_overflow() {
        let toks = lex("42 4.25 1e3 99999999999999999999999").unwrap();
        assert_eq!(toks[0].tok, Tok::Int(42));
        assert_eq!(toks[1].tok, Tok::Real(4.25));
        assert_eq!(toks[2].tok, Tok::Real(1000.0));
        assert!(matches!(toks[3].tok, Tok::Real(_)));
    }

    #[test]
    fn strings_and_errors() {
        let toks = lex("\"cp col\"").unwrap();
        assert_eq!(toks[0].tok, Tok::Str("cp col".into()));
        let err = lex("\"open").unwrap_err();
        assert!(err.to_string().contains("unterminated"), "{err}");
        let err = lex("@").unwrap_err();
        assert!(err.to_string().contains("unexpected character"), "{err}");
    }
}
