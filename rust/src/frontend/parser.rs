//! Recursive-descent parser + elaboration for SILO-Text.
//!
//! The parser builds [`crate::ir::Program`] directly (no separate AST):
//! every expression is constructed through the same simplifying operators
//! the Rust kernel builders use, so a parsed program is structurally equal
//! to the equivalent builder-constructed program — the property the
//! `parse ∘ print` round-trip tests pin.

use std::collections::{HashMap, HashSet};

use crate::ir::nest::{Loop, LoopId, LoopSchedule, Node, Stmt, StmtId};
use crate::ir::{Access, ContainerKind, DType, Program};
use crate::symbolic::{fdiv, floordiv, func, imod, load, max, min, simplify};
use crate::symbolic::{ContainerId, Expr, FuncKind, Sym};

use super::lexer::{lex, Tok, Token};
use super::{InitSpec, ParseError, ParsedKernel, PresetBindings, Span};

/// Parse a complete SILO-Text module.
pub fn parse(src: &str) -> Result<ParsedKernel, ParseError> {
    Parser::new(lex(src)?).parse_program()
}

struct Parser {
    toks: Vec<Token>,
    pos: usize,
    prog: Program,
    params: HashMap<String, Sym>,
    containers: HashMap<String, ContainerId>,
    /// Enclosing loop variables, outermost first.
    scopes: Vec<(String, Sym)>,
    presets: Vec<(Sym, PresetBindings)>,
    inits: Vec<InitSpec>,
    used_loop_ids: HashSet<u32>,
    used_stmt_ids: HashSet<u32>,
    next_loop: u32,
    next_stmt: u32,
    /// Live recursion depth across nested loops/exprs — capped so a
    /// hostile source (the service daemon parses network input) errors
    /// instead of overflowing the stack.
    depth: u32,
}

impl Parser {
    fn new(toks: Vec<Token>) -> Parser {
        Parser {
            toks,
            pos: 0,
            prog: Program::new(""),
            params: HashMap::new(),
            containers: HashMap::new(),
            scopes: Vec::new(),
            presets: Vec::new(),
            inits: Vec::new(),
            used_loop_ids: HashSet::new(),
            used_stmt_ids: HashSet::new(),
            next_loop: 0,
            next_stmt: 0,
            depth: 0,
        }
    }

    /// Bump the recursion depth; errors past the cap (deeply nested
    /// parens/unary chains/loops cannot be legitimate kernels).
    fn enter(&mut self) -> Result<(), ParseError> {
        self.depth += 1;
        if self.depth > 512 {
            return self.err(self.span(), "nesting too deep (max 512 levels)".into());
        }
        Ok(())
    }

    // -- token plumbing ----------------------------------------------------

    fn peek(&self) -> &Tok {
        &self.toks[self.pos].tok
    }

    fn peek2(&self) -> &Tok {
        let i = (self.pos + 1).min(self.toks.len() - 1);
        &self.toks[i].tok
    }

    fn span(&self) -> Span {
        self.toks[self.pos].span
    }

    fn bump(&mut self) -> Token {
        let t = self.toks[self.pos].clone();
        if self.pos + 1 < self.toks.len() {
            self.pos += 1;
        }
        t
    }

    fn err<T>(&self, span: Span, msg: String) -> Result<T, ParseError> {
        Err(ParseError::new(span, msg))
    }

    fn expect(&mut self, want: Tok, ctx: &str) -> Result<Token, ParseError> {
        if *self.peek() == want {
            Ok(self.bump())
        } else {
            self.err(
                self.span(),
                format!(
                    "expected {} {ctx}, found {}",
                    want.describe(),
                    self.peek().describe()
                ),
            )
        }
    }

    /// Consume an identifier with the exact spelling `kw`.
    fn expect_kw(&mut self, kw: &str) -> Result<Token, ParseError> {
        match self.peek() {
            Tok::Ident(s) if s == kw => Ok(self.bump()),
            other => self.err(
                self.span(),
                format!("expected `{kw}`, found {}", other.describe()),
            ),
        }
    }

    fn at_kw(&self, kw: &str) -> bool {
        matches!(self.peek(), Tok::Ident(s) if s == kw)
    }

    fn expect_ident(&mut self, ctx: &str) -> Result<(String, Span), ParseError> {
        let span = self.span();
        match self.bump().tok {
            Tok::Ident(s) => Ok((s, span)),
            other => self.err(span, format!("expected {ctx}, found {}", other.describe())),
        }
    }

    /// Identifier or quoted string (container names may be quoted).
    fn expect_name(&mut self, ctx: &str) -> Result<(String, Span), ParseError> {
        let span = self.span();
        match self.bump().tok {
            Tok::Ident(s) | Tok::Str(s) => Ok((s, span)),
            other => self.err(span, format!("expected {ctx}, found {}", other.describe())),
        }
    }

    /// Signed integer literal.
    fn expect_int(&mut self, ctx: &str) -> Result<i64, ParseError> {
        let neg = if *self.peek() == Tok::Minus {
            self.bump();
            true
        } else {
            false
        };
        let span = self.span();
        match self.bump().tok {
            Tok::Int(v) => Ok(if neg { -v } else { v }),
            other => self.err(
                span,
                format!("expected integer {ctx}, found {}", other.describe()),
            ),
        }
    }

    /// Signed numeric literal (integers promote to f64).
    fn expect_number(&mut self, ctx: &str) -> Result<f64, ParseError> {
        let neg = if *self.peek() == Tok::Minus {
            self.bump();
            true
        } else {
            false
        };
        let span = self.span();
        let v = match self.bump().tok {
            Tok::Int(v) => v as f64,
            Tok::Real(v) => v,
            other => {
                return self.err(
                    span,
                    format!("expected number {ctx}, found {}", other.describe()),
                )
            }
        };
        Ok(if neg { -v } else { v })
    }

    // -- program -----------------------------------------------------------

    fn parse_program(mut self) -> Result<ParsedKernel, ParseError> {
        let prog_span = self.span();
        self.expect_kw("program")?;
        let (name, _) = self.expect_name("a program name after `program`")?;
        self.prog.name = name;
        self.expect(Tok::LBrace, "to open the program body")?;

        // Declarations first, then the loop nest.
        loop {
            if self.at_kw("param") {
                self.parse_param_decl()?;
            } else if self.at_kw("array") || self.at_kw("transient") || self.at_kw("register") {
                self.parse_container_decl()?;
            } else {
                break;
            }
        }
        while *self.peek() != Tok::RBrace {
            if *self.peek() == Tok::Eof {
                return self.err(self.span(), "unexpected end of input (missing `}`)".into());
            }
            let n = self.parse_node()?;
            self.prog.body.push(n);
        }
        self.expect(Tok::RBrace, "to close the program")?;
        if *self.peek() != Tok::Eof {
            return self.err(
                self.span(),
                format!("trailing input after program: {}", self.peek().describe()),
            );
        }

        self.prog.reserve_ids(
            self.used_loop_ids.iter().max().map_or(0, |m| m + 1),
            self.used_stmt_ids.iter().max().map_or(0, |m| m + 1),
        );
        crate::ir::validate::validate(&self.prog)
            .map_err(|e| ParseError::new(prog_span, format!("program validation failed: {e}")))?;
        Ok(ParsedKernel {
            program: self.prog,
            presets: self.presets,
            inits: self.inits,
        })
    }

    // -- declarations ------------------------------------------------------

    fn parse_param_decl(&mut self) -> Result<(), ParseError> {
        self.expect_kw("param")?;
        let (name, span) = self.expect_ident("a parameter name")?;
        if self.params.contains_key(&name) {
            return self.err(span, format!("duplicate param `{name}`"));
        }
        let mut dim = false;
        if *self.peek() == Tok::Colon {
            self.bump();
            let (kind, kspan) = self.expect_ident("`dim` after `:`")?;
            match kind.as_str() {
                "dim" => dim = true,
                other => {
                    return self.err(
                        kspan,
                        format!("unknown param kind `{other}` (expected `dim`)"),
                    )
                }
            }
        }
        let sym = if dim {
            Sym::positive_min(&name, 2)
        } else {
            Sym::positive(&name)
        };
        self.params.insert(name, sym);
        if !self.prog.params.contains(&sym) {
            self.prog.params.push(sym);
        }
        if dim && !self.prog.dim_syms.contains(&sym) {
            self.prog.dim_syms.push(sym);
        }
        if *self.peek() == Tok::Assign {
            self.bump();
            let vspan = self.span();
            let bindings = self.parse_preset_bindings()?;
            // Params are interned with positivity assumptions the symbolic
            // analyses rely on (dependence directions, §3.2); a run-time
            // binding below the assumed floor would let a transform through
            // under a false invariant and silently corrupt parallel output.
            let floor = if dim { 2 } else { 1 };
            for v in [bindings.tiny, bindings.small, bindings.medium] {
                if let Some(v) = v {
                    if v < floor {
                        return self.err(
                            vspan,
                            format!(
                                "preset value {v} for param `{}` is below its assumed \
                                 minimum {floor} ({})",
                                sym.name(),
                                if dim {
                                    "`: dim` params are array extents ≥ 2"
                                } else {
                                    "params are strictly positive sizes/strides"
                                }
                            ),
                        );
                    }
                }
            }
            self.presets.push((sym, bindings));
        }
        self.expect(Tok::Semi, "after the param declaration")?;
        Ok(())
    }

    fn parse_preset_bindings(&mut self) -> Result<PresetBindings, ParseError> {
        if *self.peek() != Tok::LBrace {
            // Single value bound for every preset.
            let v = self.expect_int("preset value")?;
            return Ok(PresetBindings {
                tiny: Some(v),
                small: Some(v),
                medium: Some(v),
            });
        }
        self.bump();
        let mut b = PresetBindings::default();
        loop {
            let (key, kspan) = self.expect_ident("a preset name (`tiny`, `small`, `medium`)")?;
            self.expect(Tok::Colon, "after the preset name")?;
            let v = self.expect_int("preset value")?;
            let slot = match key.as_str() {
                "tiny" => &mut b.tiny,
                "small" => &mut b.small,
                "medium" => &mut b.medium,
                other => {
                    return self.err(
                        kspan,
                        format!("unknown preset `{other}` (expected tiny/small/medium)"),
                    )
                }
            };
            if slot.replace(v).is_some() {
                return self.err(kspan, format!("preset `{key}` given twice"));
            }
            if *self.peek() == Tok::Comma {
                self.bump();
                continue;
            }
            break;
        }
        self.expect(Tok::RBrace, "to close the preset bindings")?;
        Ok(b)
    }

    fn parse_container_decl(&mut self) -> Result<(), ParseError> {
        let (kw, _) = self.expect_ident("a declaration keyword")?;
        let kind = match kw.as_str() {
            "array" => ContainerKind::Argument,
            "transient" => ContainerKind::Transient,
            "register" => ContainerKind::Register,
            _ => unreachable!("caller checked the keyword"),
        };
        let (name, span) = self.expect_name("a container name")?;
        if self.containers.contains_key(&name) {
            return self.err(span, format!("duplicate container `{name}`"));
        }
        self.expect(Tok::LBracket, "to open the container size")?;
        let size = self.parse_expr()?;
        self.expect(Tok::RBracket, "to close the container size")?;
        let mut dtype = DType::F64;
        if *self.peek() == Tok::Colon {
            self.bump();
            let (t, tspan) = self.expect_ident("a dtype (`f64`, `f32`, `i64`)")?;
            dtype = match t.as_str() {
                "f64" => DType::F64,
                "f32" => DType::F32,
                "i64" => DType::I64,
                other => {
                    return self.err(tspan, format!("unknown dtype `{other}`"));
                }
            };
        }
        if self.at_kw("init") {
            self.bump();
            self.expect(Tok::LParen, "after `init`")?;
            let shift = self.expect_number("(init shift)")?;
            self.expect(Tok::Comma, "between init shift and scale")?;
            let scale = self.expect_number("(init scale)")?;
            self.expect(Tok::RParen, "to close `init(...)`")?;
            self.inits.push(InitSpec {
                container: name.clone(),
                shift,
                scale,
            });
        }
        self.expect(Tok::Semi, "after the container declaration")?;
        let id = self.prog.add_container(&name, size, dtype, kind);
        self.containers.insert(name, id);
        Ok(())
    }

    // -- loop nest ---------------------------------------------------------

    /// `L<n>:` / `s<n>:` labels ahead of loops and statements. Returns the
    /// explicit id and whether it is a loop (`L`) label.
    fn try_label(&mut self) -> Result<Option<(u32, bool)>, ParseError> {
        let (is_label, id, is_loop) = match (self.peek(), self.peek2()) {
            (Tok::Ident(s), Tok::Colon) => {
                let (head, digits) = (s.chars().next(), &s[1..]);
                let numeric = !digits.is_empty() && digits.bytes().all(|b| b.is_ascii_digit());
                match head {
                    Some('L') | Some('s') if numeric => {
                        (true, digits.parse::<u32>().ok(), head == Some('L'))
                    }
                    _ => (false, None, false),
                }
            }
            _ => (false, None, false),
        };
        if !is_label {
            return Ok(None);
        }
        let span = self.span();
        let Some(id) = id else {
            return self.err(span, "label id does not fit in 32 bits".into());
        };
        self.bump(); // label
        self.bump(); // colon
        Ok(Some((id, is_loop)))
    }

    fn alloc_loop_id(&mut self, explicit: Option<u32>, span: Span) -> Result<LoopId, ParseError> {
        let id = match explicit {
            Some(n) => {
                if !self.used_loop_ids.insert(n) {
                    return self.err(span, format!("duplicate loop label `L{n}`"));
                }
                n
            }
            None => {
                while self.used_loop_ids.contains(&self.next_loop) {
                    self.next_loop += 1;
                }
                let n = self.next_loop;
                self.used_loop_ids.insert(n);
                n
            }
        };
        Ok(LoopId(id))
    }

    fn alloc_stmt_id(&mut self, explicit: Option<u32>, span: Span) -> Result<StmtId, ParseError> {
        let id = match explicit {
            Some(n) => {
                if !self.used_stmt_ids.insert(n) {
                    return self.err(span, format!("duplicate statement label `s{n}`"));
                }
                n
            }
            None => {
                while self.used_stmt_ids.contains(&self.next_stmt) {
                    self.next_stmt += 1;
                }
                let n = self.next_stmt;
                self.used_stmt_ids.insert(n);
                n
            }
        };
        Ok(StmtId(id))
    }

    fn parse_node(&mut self) -> Result<Node, ParseError> {
        self.enter()?;
        let r = self.parse_node_inner();
        self.depth -= 1;
        r
    }

    fn parse_node_inner(&mut self) -> Result<Node, ParseError> {
        if self.at_kw("param")
            || self.at_kw("array")
            || self.at_kw("transient")
            || self.at_kw("register")
        {
            return self.err(
                self.span(),
                "declarations must precede the loop nest".into(),
            );
        }
        // Guard prefix: `if (expr) <statement>`.
        if self.at_kw("if") {
            let span = self.span();
            self.bump();
            self.expect(Tok::LParen, "after `if`")?;
            let guard = self.parse_expr()?;
            self.expect(Tok::RParen, "to close the guard")?;
            let label = self.try_label()?;
            if let Some((_, true)) = label {
                return self.err(span, "guards apply to statements, not loops".into());
            }
            if self.at_kw("for") {
                return self.err(span, "guards apply to statements, not loops".into());
            }
            return self.parse_stmt(label.map(|(n, _)| n), Some(guard));
        }
        let label = self.try_label()?;
        if self.at_kw("for") {
            match label {
                Some((_, false)) => self.err(
                    self.span(),
                    "statement label `s<n>:` ahead of a loop (use `L<n>:`)".into(),
                ),
                other => self.parse_loop(other.map(|(n, _)| n)),
            }
        } else {
            match label {
                Some((_, true)) => self.err(
                    self.span(),
                    "loop label `L<n>:` ahead of a statement (use `s<n>:`)".into(),
                ),
                other => self.parse_stmt(other.map(|(n, _)| n), None),
            }
        }
    }

    fn parse_loop(&mut self, explicit_id: Option<u32>) -> Result<Node, ParseError> {
        let for_span = self.span();
        self.expect_kw("for")?;
        let id = self.alloc_loop_id(explicit_id, for_span)?;
        self.expect(Tok::LParen, "after `for`")?;
        let (var_name, vspan) = self.expect_ident("a loop variable")?;
        if self.scopes.iter().any(|(n, _)| *n == var_name) {
            return self.err(
                vspan,
                format!("loop variable `{var_name}` shadows an enclosing loop variable"),
            );
        }
        if self.params.contains_key(&var_name) {
            return self.err(
                vspan,
                format!("loop variable `{var_name}` collides with a param of the same name"),
            );
        }
        let var = Sym::new(&var_name);
        // The variable is in scope for the whole header: strides may
        // reference it (Fig. 2's `i += i`).
        self.scopes.push((var_name.clone(), var));
        let header = (|| -> Result<(Expr, Expr, Expr), ParseError> {
            self.expect(Tok::Assign, "after the loop variable")?;
            let start = self.parse_expr()?;
            self.expect(Tok::Semi, "after the loop start")?;
            let (cond_var, cspan) = self.expect_ident("the loop variable in the condition")?;
            if cond_var != var_name {
                return self.err(
                    cspan,
                    format!("loop condition must test `{var_name}`, found `{cond_var}`"),
                );
            }
            let cmp = self.bump();
            let raw_end = self.parse_expr()?;
            let end = match cmp.tok {
                Tok::Lt | Tok::Gt | Tok::AnyDir => raw_end,
                // Inclusive bounds normalize onto the exclusive IR form.
                Tok::Le => raw_end + Expr::Int(1),
                Tok::Ge => raw_end - Expr::Int(1),
                other => {
                    return self.err(
                        cmp.span,
                        format!(
                            "expected a comparison (`<`, `<=`, `>`, `>=`, `<>`), found {}",
                            other.describe()
                        ),
                    )
                }
            };
            self.expect(Tok::Semi, "after the loop condition")?;
            let (step_var, sspan) = self.expect_ident("the loop variable in the step")?;
            if step_var != var_name {
                return self.err(
                    sspan,
                    format!("loop step must update `{var_name}`, found `{step_var}`"),
                );
            }
            self.expect(Tok::PlusAssign, "in the loop step")?;
            let stride = self.parse_expr()?;
            Ok((start, end, stride))
        })();
        let (start, end, stride) = match header {
            Ok(h) => h,
            Err(e) => {
                self.scopes.pop();
                return Err(e);
            }
        };
        let body = (|| -> Result<Vec<Node>, ParseError> {
            self.expect(Tok::RParen, "to close the loop header")?;
            self.expect(Tok::LBrace, "to open the loop body")?;
            let mut body = Vec::new();
            while *self.peek() != Tok::RBrace {
                if *self.peek() == Tok::Eof {
                    return self.err(
                        self.span(),
                        "unexpected end of input inside a loop body".into(),
                    );
                }
                body.push(self.parse_node()?);
            }
            self.expect(Tok::RBrace, "to close the loop body")?;
            Ok(body)
        })();
        self.scopes.pop();
        Ok(Node::Loop(Loop {
            id,
            var,
            start,
            end,
            stride,
            schedule: LoopSchedule::Sequential,
            body: body?,
        }))
    }

    fn parse_stmt(
        &mut self,
        explicit_id: Option<u32>,
        guard: Option<Expr>,
    ) -> Result<Node, ParseError> {
        let span = self.span();
        let (name, nspan) = self.expect_name("a container name to assign to")?;
        let Some(&cid) = self.containers.get(&name) else {
            let declared: Vec<&str> = self.container_names();
            let hint = if self.params.contains_key(&name) {
                format!("`{name}` is a param, not a container")
            } else {
                format!("declared containers: {}", declared.join(", "))
            };
            return self.err(nspan, format!("undeclared container `{name}` ({hint})"));
        };
        let id = self.alloc_stmt_id(explicit_id, span)?;
        self.expect(Tok::LBracket, "to open the write offset")?;
        let offset = self.parse_expr()?;
        self.expect(Tok::RBracket, "to close the write offset")?;
        self.expect(Tok::Assign, "in the assignment")?;
        let rhs = self.parse_expr()?;
        self.expect(Tok::Semi, "after the statement")?;
        Ok(Node::Stmt(Stmt {
            id,
            write: Access::write(cid, simplify(&offset)),
            rhs: simplify(&rhs),
            guard: guard.map(|g| simplify(&g)),
        }))
    }

    fn container_names(&self) -> Vec<&str> {
        self.prog
            .containers
            .iter()
            .map(|c| c.name.as_str())
            .collect()
    }

    // -- expressions -------------------------------------------------------

    fn parse_expr(&mut self) -> Result<Expr, ParseError> {
        self.enter()?;
        let r = self.parse_expr_inner();
        self.depth -= 1;
        r
    }

    fn parse_expr_inner(&mut self) -> Result<Expr, ParseError> {
        let mut e = self.parse_term()?;
        loop {
            match self.peek() {
                Tok::Plus => {
                    self.bump();
                    e = e + self.parse_term()?;
                }
                Tok::Minus => {
                    self.bump();
                    e = e - self.parse_term()?;
                }
                _ => return Ok(e),
            }
        }
    }

    fn parse_term(&mut self) -> Result<Expr, ParseError> {
        let mut e = self.parse_unary()?;
        loop {
            match self.peek() {
                Tok::Star => {
                    self.bump();
                    e = e * self.parse_unary()?;
                }
                // `/` is compute division: `a * recip(b)`, exactly the
                // builders' `fdiv`. Integer division is `floordiv(a, b)`.
                Tok::Slash => {
                    self.bump();
                    let rhs = self.parse_unary()?;
                    e = fdiv(e, rhs);
                }
                _ => return Ok(e),
            }
        }
    }

    fn parse_unary(&mut self) -> Result<Expr, ParseError> {
        self.enter()?;
        let r = if *self.peek() == Tok::Minus {
            self.bump();
            self.parse_unary().map(|e| -e)
        } else {
            self.parse_power()
        };
        self.depth -= 1;
        r
    }

    fn parse_power(&mut self) -> Result<Expr, ParseError> {
        let base = self.parse_primary()?;
        if *self.peek() == Tok::Caret {
            self.bump();
            let span = self.span();
            match self.bump().tok {
                Tok::Int(v) if (0..=u32::MAX as i64).contains(&v) => {
                    return Ok(simplify(&Expr::Pow(Box::new(base), v as u32)));
                }
                other => {
                    return self.err(
                        span,
                        format!(
                            "exponent must be a non-negative integer, found {}",
                            other.describe()
                        ),
                    )
                }
            }
        }
        Ok(base)
    }

    fn parse_primary(&mut self) -> Result<Expr, ParseError> {
        let span = self.span();
        match self.peek().clone() {
            Tok::Int(v) => {
                self.bump();
                Ok(Expr::Int(v))
            }
            Tok::Real(v) => {
                self.bump();
                Ok(Expr::real(v))
            }
            Tok::LParen => {
                self.bump();
                let e = self.parse_expr()?;
                self.expect(Tok::RParen, "to close the parenthesized expression")?;
                Ok(e)
            }
            Tok::Str(name) => {
                self.bump();
                self.parse_load(&name, span)
            }
            Tok::Ident(name) => {
                self.bump();
                if *self.peek() == Tok::LBracket {
                    return self.parse_load(&name, span);
                }
                if *self.peek() == Tok::LParen {
                    return self.parse_call(&name, span);
                }
                // Loop variables shadow params (distinct names are enforced
                // at declaration, so this is just innermost-out lookup).
                if let Some((_, sym)) = self.scopes.iter().rev().find(|(n, _)| *n == name) {
                    return Ok(Expr::Sym(*sym));
                }
                if let Some(sym) = self.params.get(&name) {
                    return Ok(Expr::Sym(*sym));
                }
                if self.containers.contains_key(&name) {
                    return self.err(
                        span,
                        format!("container `{name}` must be subscripted (`{name}[...]`)"),
                    );
                }
                let in_scope: Vec<String> = self
                    .scopes
                    .iter()
                    .map(|(n, _)| n.clone())
                    .chain(self.params.keys().cloned())
                    .collect();
                self.err(
                    span,
                    format!(
                        "undeclared symbol `{name}` (params and loop variables in scope: {})",
                        if in_scope.is_empty() {
                            "none".to_string()
                        } else {
                            in_scope.join(", ")
                        }
                    ),
                )
            }
            other => self.err(
                span,
                format!("expected an expression, found {}", other.describe()),
            ),
        }
    }

    fn parse_load(&mut self, name: &str, span: Span) -> Result<Expr, ParseError> {
        let Some(&cid) = self.containers.get(name) else {
            return self.err(
                span,
                format!(
                    "undeclared container `{name}` (declared containers: {})",
                    self.container_names().join(", ")
                ),
            );
        };
        self.expect(Tok::LBracket, "to open the access offset")?;
        let off = self.parse_expr()?;
        self.expect(Tok::RBracket, "to close the access offset")?;
        Ok(load(cid, off))
    }

    fn parse_call(&mut self, name: &str, span: Span) -> Result<Expr, ParseError> {
        self.expect(Tok::LParen, "to open the argument list")?;
        let mut args = vec![self.parse_expr()?];
        while *self.peek() == Tok::Comma {
            self.bump();
            args.push(self.parse_expr()?);
        }
        self.expect(Tok::RParen, "to close the argument list")?;
        let got = args.len();
        let arity = move |want: usize| -> Result<(), ParseError> {
            if got == want {
                Ok(())
            } else {
                Err(ParseError::new(
                    span,
                    format!("`{name}` takes {want} argument(s), found {got}"),
                ))
            }
        };
        match name {
            "min" => {
                arity(2)?;
                let b = args.pop().unwrap();
                let a = args.pop().unwrap();
                Ok(min(a, b))
            }
            "max" => {
                arity(2)?;
                let b = args.pop().unwrap();
                let a = args.pop().unwrap();
                Ok(max(a, b))
            }
            "floordiv" => {
                arity(2)?;
                let b = args.pop().unwrap();
                let a = args.pop().unwrap();
                Ok(floordiv(a, b))
            }
            "mod" => {
                arity(2)?;
                let b = args.pop().unwrap();
                let a = args.pop().unwrap();
                Ok(imod(a, b))
            }
            "log2" => {
                arity(1)?;
                Ok(func(FuncKind::Log2, args))
            }
            "exp" => {
                arity(1)?;
                Ok(func(FuncKind::Exp, args))
            }
            "sqrt" => {
                arity(1)?;
                Ok(func(FuncKind::Sqrt, args))
            }
            "abs" => {
                arity(1)?;
                Ok(func(FuncKind::Abs, args))
            }
            "recip" => {
                arity(1)?;
                Ok(func(FuncKind::Recip, args))
            }
            "select" => {
                arity(3)?;
                Ok(func(FuncKind::Select, args))
            }
            other => self.err(
                span,
                format!(
                    "unknown function `{other}` (available: min, max, floordiv, mod, \
                     log2, exp, sqrt, abs, recip, select)"
                ),
            ),
        }
    }
}
