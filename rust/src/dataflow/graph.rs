//! Loop-body dataflow graph construction.

use crate::ir::{Access, AccessKind, LoopId, Node, StmtId};
use crate::symbolic::{sym_eq, ContainerId};

/// Reference to a top-level element of a loop body.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeRef {
    Stmt(StmtId),
    Loop(LoopId),
}

/// A dataflow-graph node: one top-level body element with its (possibly
/// summarized) reads and writes.
#[derive(Debug, Clone)]
pub struct GraphNode {
    pub index: usize,
    pub node: NodeRef,
    pub reads: Vec<Access>,
    pub writes: Vec<Access>,
    /// Guarded statements may not execute; they neither dominate nor
    /// post-dominate for the purposes of §3.1/§3.3.2.
    pub guarded: bool,
}

/// How confident the edge is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeKind {
    /// Offsets are symbolically equal — the value definitely flows.
    Definite,
    /// Same container, offsets not provably equal/unequal — may alias.
    Possible,
}

/// Dataflow edge `src → dst` carrying container data.
#[derive(Debug, Clone)]
pub struct Edge {
    pub src: usize,
    pub dst: usize,
    pub container: ContainerId,
    pub kind: EdgeKind,
}

/// Dataflow graph over one loop body (element sequence).
#[derive(Debug, Clone)]
pub struct BodyGraph {
    pub nodes: Vec<GraphNode>,
    pub edges: Vec<Edge>,
}

impl BodyGraph {
    /// Build the graph for a body. `summarize` maps a *nested loop* node to
    /// its externally visible (reads, writes) — the visibility analysis
    /// supplies the propagated version; tests may pass a syntactic one.
    pub fn build(
        body: &[Node],
        summarize: &dyn Fn(&Node) -> (Vec<Access>, Vec<Access>),
    ) -> BodyGraph {
        let mut nodes: Vec<GraphNode> = Vec::with_capacity(body.len());
        for (i, n) in body.iter().enumerate() {
            match n {
                Node::Stmt(s) => nodes.push(GraphNode {
                    index: i,
                    node: NodeRef::Stmt(s.id),
                    reads: s.reads(),
                    writes: vec![s.write.clone()],
                    guarded: s.guard.is_some(),
                }),
                Node::Loop(l) => {
                    let (reads, writes) = summarize(n);
                    nodes.push(GraphNode {
                        index: i,
                        node: NodeRef::Loop(l.id),
                        reads,
                        writes,
                        guarded: false,
                    });
                }
            }
        }
        let mut edges = Vec::new();
        for dst in 0..nodes.len() {
            for src in 0..dst {
                for w in &nodes[src].writes {
                    for r in &nodes[dst].reads {
                        if w.container != r.container {
                            continue;
                        }
                        let kind = if sym_eq(&w.offset, &r.offset) {
                            EdgeKind::Definite
                        } else {
                            EdgeKind::Possible
                        };
                        edges.push(Edge {
                            src,
                            dst,
                            container: w.container,
                            kind,
                        });
                    }
                }
            }
        }
        BodyGraph { nodes, edges }
    }

    /// Is the read `(dst_index, access)` *self-contained* (paper §3.1): is
    /// there an earlier, unguarded write to the same container with a
    /// symbolically equivalent offset that dominates it?
    pub fn is_self_contained(&self, dst_index: usize, read: &Access) -> bool {
        debug_assert_eq!(read.kind, AccessKind::Read);
        for src in (0..dst_index).rev() {
            let n = &self.nodes[src];
            if n.guarded {
                continue;
            }
            // Summarized loops write ranges, not single offsets; only exact
            // statement writes dominate (conservative).
            if matches!(n.node, NodeRef::Loop(_)) {
                continue;
            }
            for w in &n.writes {
                if w.container == read.container && sym_eq(&w.offset, &read.offset) {
                    return true;
                }
            }
        }
        false
    }

    /// Indices of nodes that write container `c`.
    pub fn writers_of(&self, c: ContainerId) -> Vec<usize> {
        self.nodes
            .iter()
            .filter(|n| n.writes.iter().any(|w| w.container == c))
            .map(|n| n.index)
            .collect()
    }

    /// Indices of nodes that read container `c`.
    pub fn readers_of(&self, c: ContainerId) -> Vec<usize> {
        self.nodes
            .iter()
            .filter(|n| n.reads.iter().any(|r| r.container == c))
            .map(|n| n.index)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::ProgramBuilder;
    use crate::symbolic::{int, load, Expr};

    /// Syntactic summarizer: all reads/writes of the subtree, unpropagated.
    fn syntactic(n: &Node) -> (Vec<Access>, Vec<Access>) {
        let mut reads = Vec::new();
        let mut writes = Vec::new();
        for s in n.stmts() {
            reads.extend(s.reads());
            writes.push(s.write.clone());
        }
        (reads, writes)
    }

    #[test]
    fn definite_edge_and_self_containment() {
        // s0: T[i] = A[i];  s1: B[i] = T[i] * 2   — T read is self-contained.
        let mut b = ProgramBuilder::new("df");
        let n = b.param_positive("df_N");
        let a = b.array("A", Expr::Sym(n));
        let t = b.transient("T", Expr::Sym(n));
        let bb = b.array("B", Expr::Sym(n));
        let i = b.sym("df_i");
        b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
            b.assign(t, Expr::Sym(i), load(a, Expr::Sym(i)));
            b.assign(bb, Expr::Sym(i), load(t, Expr::Sym(i)) * Expr::real(2.0));
        });
        let p = b.finish();
        let l = p.loops()[0];
        let g = BodyGraph::build(&l.body, &syntactic);
        assert_eq!(g.nodes.len(), 2);
        assert!(g
            .edges
            .iter()
            .any(|e| e.src == 0 && e.dst == 1 && e.kind == EdgeKind::Definite));
        let read = Access::read(t, Expr::Sym(i));
        assert!(g.is_self_contained(1, &read));
        // A's read in s0 is NOT self-contained (no earlier writer).
        let read_a = Access::read(a, Expr::Sym(i));
        assert!(!g.is_self_contained(0, &read_a));
    }

    #[test]
    fn offset_mismatch_is_possible_edge_not_self_contained() {
        // s0: T[i] = ...;  s1: B[i] = T[i-1]  — not self-contained.
        let mut b = ProgramBuilder::new("df2");
        let n = b.param_positive("df2_N");
        let t = b.transient("T", Expr::Sym(n));
        let bb = b.array("B", Expr::Sym(n));
        let i = b.sym("df2_i");
        b.for_(i, int(1), Expr::Sym(n), int(1), |b| {
            b.assign(t, Expr::Sym(i), Expr::real(1.0));
            b.assign(bb, Expr::Sym(i), load(t, Expr::Sym(i) - int(1)));
        });
        let p = b.finish();
        let l = p.loops()[0];
        let g = BodyGraph::build(&l.body, &syntactic);
        assert!(g
            .edges
            .iter()
            .any(|e| e.src == 0 && e.dst == 1 && e.kind == EdgeKind::Possible));
        let read = Access::read(t, Expr::Sym(i) - int(1));
        assert!(!g.is_self_contained(1, &read));
    }

    #[test]
    fn guarded_writes_do_not_dominate() {
        let mut b = ProgramBuilder::new("df3");
        let n = b.param_positive("df3_N");
        let t = b.transient("T", Expr::Sym(n));
        let bb = b.array("B", Expr::Sym(n));
        let i = b.sym("df3_i");
        b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
            b.assign_if(Expr::Sym(i), t, Expr::Sym(i), Expr::real(1.0));
            b.assign(bb, Expr::Sym(i), load(t, Expr::Sym(i)));
        });
        let p = b.finish();
        let l = p.loops()[0];
        let g = BodyGraph::build(&l.body, &syntactic);
        let read = Access::read(t, Expr::Sym(i));
        assert!(!g.is_self_contained(1, &read));
    }
}
