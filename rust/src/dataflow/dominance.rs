//! Dominance / post-dominance over loop-body sequences.
//!
//! Loop bodies in this IR are straight-line sequences of elements (guards
//! live *inside* statements), so dominance collapses to sequence order:
//! element `u` dominates `v` iff `u ≤ v`, and `u` post-dominates `v` iff
//! `u ≥ v` and `u` is unguarded. This is exactly the structure the paper's
//! release-placement rule (§3.3.2) needs.

use super::graph::BodyGraph;

/// Does element `u` dominate element `v` (every execution reaching `v`
/// passed `u` first, within one iteration)?
pub fn dominates(g: &BodyGraph, u: usize, v: usize) -> bool {
    u <= v && !g.nodes[u].guarded
}

/// Does element `u` post-dominate element `v` (every execution leaving `v`
/// later passes `u`)?
pub fn post_dominates(g: &BodyGraph, u: usize, v: usize) -> bool {
    u >= v && !g.nodes[u].guarded
}

/// Among `candidates` (dependency-resolving writes, §3.3.2), find the one
/// that post-dominates all others — the single release point. `None` means
/// "release at end of body".
pub fn post_dominating_resolver(g: &BodyGraph, candidates: &[usize]) -> Option<usize> {
    'outer: for &u in candidates {
        for &v in candidates {
            if !post_dominates(g, u, v) {
                continue 'outer;
            }
        }
        return Some(u);
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::graph::BodyGraph;
    use crate::ir::{Access, Node, ProgramBuilder};
    use crate::symbolic::{int, Expr};

    fn three_stmt_graph(guard_last: bool) -> BodyGraph {
        let mut b = ProgramBuilder::new("dom");
        let n = b.param_positive("dom_N");
        let a = b.array("A", Expr::Sym(n));
        let i = b.sym("dom_i");
        b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
            b.assign(a, Expr::Sym(i), Expr::real(1.0));
            b.assign(a, Expr::Sym(i) + int(1), Expr::real(2.0));
            if guard_last {
                b.assign_if(Expr::Sym(i), a, Expr::Sym(i) + int(2), Expr::real(3.0));
            } else {
                b.assign(a, Expr::Sym(i) + int(2), Expr::real(3.0));
            }
        });
        let p = b.finish();
        let l = p.loops()[0];
        let syntactic = |n: &Node| {
            let mut reads = Vec::new();
            let mut writes: Vec<Access> = Vec::new();
            for s in n.stmts() {
                reads.extend(s.reads());
                writes.push(s.write.clone());
            }
            (reads, writes)
        };
        BodyGraph::build(&l.body, &syntactic)
    }

    #[test]
    fn sequence_dominance() {
        let g = three_stmt_graph(false);
        assert!(dominates(&g, 0, 2));
        assert!(!dominates(&g, 2, 0));
        assert!(post_dominates(&g, 2, 0));
        assert!(!post_dominates(&g, 0, 2));
    }

    #[test]
    fn post_dominating_resolver_picks_last() {
        let g = three_stmt_graph(false);
        assert_eq!(post_dominating_resolver(&g, &[0, 2]), Some(2));
        assert_eq!(post_dominating_resolver(&g, &[1]), Some(1));
    }

    #[test]
    fn guarded_element_cannot_postdominate() {
        let g = three_stmt_graph(true);
        // Element 2 is guarded: not a valid single release point.
        assert_eq!(post_dominating_resolver(&g, &[0, 2]), None);
    }
}
