//! Dataflow graphs over loop bodies (paper §3.1).
//!
//! The consumer/producer analysis builds, per loop body, a graph whose
//! nodes are the body's top-level elements (statements or summarized
//! nested loops) and whose edges carry `(container, offset)` dataflow. The
//! graph answers the two questions the paper's analyses need: which reads
//! are *self-contained* (dominated by a symbolically-equal write in the
//! same iteration), and which resolving access *post-dominates* the others
//! (release placement, §3.3.2).

pub mod dominance;
pub mod graph;

pub use graph::{BodyGraph, EdgeKind, GraphNode, NodeRef};
