//! Expression → bytecode compilation.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::symbolic::{Expr, FuncKind, Sym};
use crate::verify::CheckSet;

use super::bytecode::Op;

/// Compilation context: global symbol registers plus a scratch allocator.
pub struct ExprCtx {
    pub sym_regs: Vec<(Sym, u16)>,
    /// First scratch int / float register (symbols live below).
    pub int_base: u16,
    pub float_base: u16,
    int_free: Vec<u16>,
    int_next: u16,
    float_free: Vec<u16>,
    float_next: u16,
    pub max_int: u16,
    pub max_float: u16,
    /// Cursor registers for ptr-inc loads: (stmt, container, const-off) →
    /// cursor int reg. Filled by the lowering before compiling rhs.
    pub cursors: Vec<CursorBinding>,
    pub current_stmt: Option<crate::ir::StmtId>,
    /// Accesses the static verifier could not prove in bounds: they
    /// compile through an explicit index register guarded by
    /// [`Op::BoundsCheck`] (bypassing cursor addressing so the checked
    /// index is exactly the dereferenced one). Proven accesses keep all
    /// fast paths.
    pub checks: Arc<CheckSet>,
    /// `BoundsCheck` ops emitted through this context.
    pub checks_emitted: u32,
    /// Address registers of naive (non-cursor) accesses in the current
    /// statement — kept live until the statement completes, modeling the
    /// out-of-order scheduling that overlaps load latencies (and thereby
    /// the register pressure §4.2 attributes to offset arithmetic).
    deferred_int: Vec<u16>,
}

/// How a cursor-served access is addressed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CursorDelta {
    /// `cursor + c` — folds into the addressing mode.
    Const(i32),
    /// `cursor + i[reg]` — hoisted loop-invariant symbolic delta.
    Reg(u16),
}

/// "Loads of `container` at symbolic `offset` in statement `stmt` read
/// through int register `reg` plus `delta`."
#[derive(Debug, Clone)]
pub struct CursorBinding {
    pub stmt: crate::ir::StmtId,
    pub container: crate::symbolic::ContainerId,
    pub offset: Expr,
    pub reg: u16,
    pub delta: CursorDelta,
}

impl ExprCtx {
    pub fn new(sym_regs: Vec<(Sym, u16)>, int_base: u16, float_base: u16) -> ExprCtx {
        ExprCtx {
            sym_regs,
            int_base,
            float_base,
            int_free: Vec::new(),
            int_next: int_base,
            float_free: Vec::new(),
            float_next: float_base,
            max_int: int_base,
            max_float: float_base,
            cursors: Vec::new(),
            current_stmt: None,
            checks: Arc::new(CheckSet::none()),
            checks_emitted: 0,
            deferred_int: Vec::new(),
        }
    }

    /// Must this (current-statement) access be bounds-checked?
    pub fn needs_check(&self, c: crate::symbolic::ContainerId, off: &Expr) -> bool {
        self.current_stmt
            .map(|s| self.checks.needs(s, c, off))
            .unwrap_or(false)
    }

    /// Keep an address register live until `flush_deferred`.
    pub fn defer_free_int(&mut self, r: u16) {
        self.deferred_int.push(r);
    }

    /// Release all deferred address registers (statement boundary).
    pub fn flush_deferred(&mut self) {
        while let Some(r) = self.deferred_int.pop() {
            self.free_int(r);
        }
    }

    pub fn alloc_int(&mut self) -> u16 {
        let r = self.int_free.pop().unwrap_or_else(|| {
            let r = self.int_next;
            self.int_next += 1;
            r
        });
        self.max_int = self.max_int.max(r + 1);
        r
    }

    pub fn free_int(&mut self, r: u16) {
        if r >= self.int_base {
            self.int_free.push(r);
        }
    }

    pub fn alloc_float(&mut self) -> u16 {
        let r = self.float_free.pop().unwrap_or_else(|| {
            let r = self.float_next;
            self.float_next += 1;
            r
        });
        self.max_float = self.max_float.max(r + 1);
        r
    }

    pub fn free_float(&mut self, r: u16) {
        if r >= self.float_base {
            self.float_free.push(r);
        }
    }

    fn sym_reg(&self, s: Sym) -> Result<u16> {
        match self.sym_regs.iter().find(|(x, _)| *x == s) {
            Some((_, r)) => Ok(*r),
            None => bail!("symbol {} has no register", s.name()),
        }
    }

    pub fn cursor_for(
        &self,
        c: crate::symbolic::ContainerId,
        off: &Expr,
    ) -> Option<(u16, CursorDelta)> {
        let stmt = self.current_stmt?;
        self.cursors
            .iter()
            .find(|b| b.stmt == stmt && b.container == c && &b.offset == off)
            .map(|b| (b.reg, b.delta))
    }
}

/// Compile an integer (index) expression; returns the register holding the
/// result. Caller frees it.
pub fn compile_int(e: &Expr, ctx: &mut ExprCtx, ops: &mut Vec<Op>) -> Result<u16> {
    Ok(match e {
        Expr::Int(v) => {
            let dst = ctx.alloc_int();
            ops.push(Op::IConst { dst, val: *v });
            dst
        }
        Expr::Real(_) => bail!("real constant in index expression"),
        Expr::Sym(s) => {
            // Symbols live in fixed registers below the scratch base:
            // return them directly — `free_int` ignores sub-base ids and
            // no op ever writes through a returned source register.
            ctx.sym_reg(*s)?
        }
        Expr::Add(xs) => fold_int(xs, ctx, ops, |dst, a, b| Op::IAdd { dst, a, b })?,
        Expr::Mul(xs) => fold_int(xs, ctx, ops, |dst, a, b| Op::IMul { dst, a, b })?,
        Expr::Pow(b, p) => {
            let a = compile_int(b, ctx, ops)?;
            let dst = ctx.alloc_int();
            ops.push(Op::IPow { dst, a, exp: *p });
            ctx.free_int(a);
            dst
        }
        Expr::FloorDiv(a, b) => binary_int(a, b, ctx, ops, |dst, a, b| Op::IFloorDiv {
            dst,
            a,
            b,
        })?,
        Expr::Mod(a, b) => binary_int(a, b, ctx, ops, |dst, a, b| Op::IMod { dst, a, b })?,
        Expr::Min(a, b) => binary_int(a, b, ctx, ops, |dst, a, b| Op::IMin { dst, a, b })?,
        Expr::Max(a, b) => binary_int(a, b, ctx, ops, |dst, a, b| Op::IMax { dst, a, b })?,
        Expr::Func(FuncKind::Log2, args) => {
            let a = compile_int(&args[0], ctx, ops)?;
            let dst = ctx.alloc_int();
            ops.push(Op::ILog2 { dst, a });
            ctx.free_int(a);
            dst
        }
        Expr::Func(FuncKind::Abs, args) => {
            let a = compile_int(&args[0], ctx, ops)?;
            let dst = ctx.alloc_int();
            ops.push(Op::IAbs { dst, a });
            ctx.free_int(a);
            dst
        }
        Expr::Func(k, _) => bail!("function {} in index expression", k.name()),
        Expr::Load(..) => bail!("load in index expression"),
    })
}

fn fold_int(
    xs: &[Expr],
    ctx: &mut ExprCtx,
    ops: &mut Vec<Op>,
    mk: impl Fn(u16, u16, u16) -> Op,
) -> Result<u16> {
    let mut acc = compile_int(&xs[0], ctx, ops)?;
    for x in &xs[1..] {
        let r = compile_int(x, ctx, ops)?;
        let dst = ctx.alloc_int();
        ops.push(mk(dst, acc, r));
        ctx.free_int(acc);
        ctx.free_int(r);
        acc = dst;
    }
    Ok(acc)
}

fn binary_int(
    a: &Expr,
    b: &Expr,
    ctx: &mut ExprCtx,
    ops: &mut Vec<Op>,
    mk: impl Fn(u16, u16, u16) -> Op,
) -> Result<u16> {
    let ra = compile_int(a, ctx, ops)?;
    let rb = compile_int(b, ctx, ops)?;
    let dst = ctx.alloc_int();
    ops.push(mk(dst, ra, rb));
    ctx.free_int(ra);
    ctx.free_int(rb);
    Ok(dst)
}

/// Compile a float (compute) expression.
pub fn compile_float(e: &Expr, ctx: &mut ExprCtx, ops: &mut Vec<Op>) -> Result<u16> {
    Ok(match e {
        Expr::Int(v) => {
            let dst = ctx.alloc_float();
            ops.push(Op::FConst {
                dst,
                bits: (*v as f64).to_bits(),
            });
            dst
        }
        Expr::Real(bits) => {
            let dst = ctx.alloc_float();
            ops.push(Op::FConst { dst, bits: *bits });
            dst
        }
        Expr::Sym(_) => {
            // Integer symbol promoted to float.
            let ri = compile_int(e, ctx, ops)?;
            let dst = ctx.alloc_float();
            ops.push(Op::FFromI { dst, src: ri });
            ctx.free_int(ri);
            dst
        }
        Expr::Add(xs) => fold_float(xs, ctx, ops, |dst, a, b| Op::FAdd { dst, a, b })?,
        Expr::Mul(xs) => fold_float(xs, ctx, ops, |dst, a, b| Op::FMul { dst, a, b })?,
        Expr::Pow(b, p) => {
            let a = compile_float(b, ctx, ops)?;
            let dst = ctx.alloc_float();
            ops.push(Op::FPow { dst, a, exp: *p });
            ctx.free_float(a);
            dst
        }
        Expr::FloorDiv(a, b) => {
            let ra = compile_float(a, ctx, ops)?;
            let rb = compile_float(b, ctx, ops)?;
            let t = ctx.alloc_float();
            ops.push(Op::FDiv { dst: t, a: ra, b: rb });
            let dst = ctx.alloc_float();
            ops.push(Op::FFloor { dst, a: t });
            ctx.free_float(ra);
            ctx.free_float(rb);
            ctx.free_float(t);
            dst
        }
        Expr::Mod(a, b) => {
            // a - b*floor(a/b)
            let ra = compile_float(a, ctx, ops)?;
            let rb = compile_float(b, ctx, ops)?;
            let q = ctx.alloc_float();
            ops.push(Op::FDiv { dst: q, a: ra, b: rb });
            let fl = ctx.alloc_float();
            ops.push(Op::FFloor { dst: fl, a: q });
            let prod = ctx.alloc_float();
            ops.push(Op::FMul { dst: prod, a: rb, b: fl });
            let dst = ctx.alloc_float();
            ops.push(Op::FSub { dst, a: ra, b: prod });
            for r in [ra, rb, q, fl, prod] {
                ctx.free_float(r);
            }
            dst
        }
        Expr::Min(a, b) => binary_float(a, b, ctx, ops, |dst, a, b| Op::FMin { dst, a, b })?,
        Expr::Max(a, b) => binary_float(a, b, ctx, ops, |dst, a, b| Op::FMax { dst, a, b })?,
        Expr::Func(k, args) => match k {
            FuncKind::Select => {
                let c = compile_float(&args[0], ctx, ops)?;
                let a = compile_float(&args[1], ctx, ops)?;
                let b = compile_float(&args[2], ctx, ops)?;
                let dst = ctx.alloc_float();
                ops.push(Op::FSelect { dst, cond: c, a, b });
                for r in [c, a, b] {
                    ctx.free_float(r);
                }
                dst
            }
            FuncKind::Recip => {
                let a = compile_float(&args[0], ctx, ops)?;
                let one = ctx.alloc_float();
                ops.push(Op::FConst {
                    dst: one,
                    bits: 1f64.to_bits(),
                });
                let dst = ctx.alloc_float();
                ops.push(Op::FDiv { dst, a: one, b: a });
                ctx.free_float(a);
                ctx.free_float(one);
                dst
            }
            _ => {
                let a = compile_float(&args[0], ctx, ops)?;
                let dst = ctx.alloc_float();
                ops.push(match k {
                    FuncKind::Exp => Op::FExp { dst, a },
                    FuncKind::Sqrt => Op::FSqrt { dst, a },
                    FuncKind::Abs => Op::FAbs { dst, a },
                    FuncKind::Log2 => Op::FLog2 { dst, a },
                    FuncKind::Select | FuncKind::Recip => unreachable!(),
                });
                ctx.free_float(a);
                dst
            }
        },
        Expr::Load(c, off) => {
            let dst = ctx.alloc_float();
            let checked = ctx.needs_check(*c, off);
            // Pointer-increment path: the lowering pre-registered a cursor
            // for this (stmt, container, offset). Checked accesses bypass
            // it so the guard covers exactly the dereferenced index.
            match (checked, ctx.cursor_for(*c, off)) {
                (false, Some((reg, delta))) => match delta {
                    CursorDelta::Const(d) => ops.push(Op::LoadOff {
                        dst,
                        cont: c.0 as u16,
                        idx: reg,
                        off: d,
                    }),
                    CursorDelta::Reg(dr) => ops.push(Op::LoadAt2 {
                        dst,
                        cont: c.0 as u16,
                        a: reg,
                        b: dr,
                    }),
                },
                _ => {
                    let idx = compile_int(off, ctx, ops)?;
                    if checked {
                        ops.push(Op::BoundsCheck {
                            cont: c.0 as u16,
                            idx,
                            off: 0,
                        });
                        ctx.checks_emitted += 1;
                    }
                    ops.push(Op::Load {
                        dst,
                        cont: c.0 as u16,
                        idx,
                    });
                    // Address stays live until the statement ends (OoO model).
                    ctx.defer_free_int(idx);
                }
            }
            dst
        }
    })
}

fn fold_float(
    xs: &[Expr],
    ctx: &mut ExprCtx,
    ops: &mut Vec<Op>,
    mk: impl Fn(u16, u16, u16) -> Op,
) -> Result<u16> {
    let mut acc = compile_float(&xs[0], ctx, ops)?;
    for x in &xs[1..] {
        let r = compile_float(x, ctx, ops)?;
        let dst = ctx.alloc_float();
        ops.push(mk(dst, acc, r));
        ctx.free_float(acc);
        ctx.free_float(r);
        acc = dst;
    }
    Ok(acc)
}

fn binary_float(
    a: &Expr,
    b: &Expr,
    ctx: &mut ExprCtx,
    ops: &mut Vec<Op>,
    mk: impl Fn(u16, u16, u16) -> Op,
) -> Result<u16> {
    let ra = compile_float(a, ctx, ops)?;
    let rb = compile_float(b, ctx, ops)?;
    let dst = ctx.alloc_float();
    ops.push(mk(dst, ra, rb));
    ctx.free_float(ra);
    ctx.free_float(rb);
    Ok(dst)
}
