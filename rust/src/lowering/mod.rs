//! IR → bytecode lowering. Memory schedules (§4) are materialized here,
//! keeping them out of the analyzable IR per the paper's architecture.

pub mod bytecode;
pub mod compile;
pub mod expr_compile;

pub use bytecode::{CodeBlock, ContainerMeta, ExecNode, ExecProgram, ExecSchedule, LoopExec, Op};
pub use compile::{lower, lower_profiled, lower_speculative, lower_with_checks};
