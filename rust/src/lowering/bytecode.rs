//! Register-machine bytecode the VM executes.
//!
//! Index arithmetic runs on an i64 register file, compute on an f64 file.
//! Sequential loop nests compile to flat blocks with explicit jumps; loops
//! with Parallel/Doacross schedules stay tree nodes (see
//! [`super::compile`]) so the runtime can distribute their iterations.

use crate::symbolic::{ContainerId, Sym};

/// One bytecode instruction. `u16` register ids; containers are referenced
/// by their dense id.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Op {
    // ---- integer (index) ops ----
    IConst { dst: u16, val: i64 },
    ICopy { dst: u16, src: u16 },
    IAdd { dst: u16, a: u16, b: u16 },
    IAddImm { dst: u16, a: u16, imm: i64 },
    ISub { dst: u16, a: u16, b: u16 },
    IMul { dst: u16, a: u16, b: u16 },
    IMulImm { dst: u16, a: u16, imm: i64 },
    IFloorDiv { dst: u16, a: u16, b: u16 },
    IMod { dst: u16, a: u16, b: u16 },
    IMin { dst: u16, a: u16, b: u16 },
    IMax { dst: u16, a: u16, b: u16 },
    IPow { dst: u16, a: u16, exp: u32 },
    ILog2 { dst: u16, a: u16 },
    IAbs { dst: u16, a: u16 },

    // ---- float (compute) ops ----
    FConst { dst: u16, bits: u64 },
    FCopy { dst: u16, src: u16 },
    FAdd { dst: u16, a: u16, b: u16 },
    FSub { dst: u16, a: u16, b: u16 },
    FMul { dst: u16, a: u16, b: u16 },
    FDiv { dst: u16, a: u16, b: u16 },
    FMin { dst: u16, a: u16, b: u16 },
    FMax { dst: u16, a: u16, b: u16 },
    FPow { dst: u16, a: u16, exp: u32 },
    FExp { dst: u16, a: u16 },
    FSqrt { dst: u16, a: u16 },
    FAbs { dst: u16, a: u16 },
    FLog2 { dst: u16, a: u16 },
    FFloor { dst: u16, a: u16 },
    /// dst = cond > 0.0 ? a : b
    FSelect { dst: u16, cond: u16, a: u16, b: u16 },
    FFromI { dst: u16, src: u16 },

    // ---- memory ----
    /// `f[dst] = heap[cont][ i[idx] ]`
    Load { dst: u16, cont: u16, idx: u16 },
    /// `f[dst] = heap[cont][ i[idx] + off ]` — pointer-increment path.
    LoadOff { dst: u16, cont: u16, idx: u16, off: i32 },
    /// `f[dst] = heap[cont][ i[a] + i[b] ]` — cursor + hoisted symbolic
    /// delta register (x86 base+index addressing; zero extra pressure).
    LoadAt2 { dst: u16, cont: u16, a: u16, b: u16 },
    /// `heap[cont][ i[idx] ] = f[src]`
    Store { cont: u16, idx: u16, src: u16 },
    StoreOff { cont: u16, idx: u16, off: i32, src: u16 },
    /// f32 containers round through f32 on store.
    StoreF32 { cont: u16, idx: u16, src: u16 },
    StoreOffF32 { cont: u16, idx: u16, off: i32, src: u16 },
    /// Software prefetch hint — a no-op for results; drives the cache model
    /// through the trace hook.
    Prefetch { cont: u16, idx: u16, write: bool },
    /// Checked-tier guard: trap with a structured
    /// [`Trap::OutOfBounds`](crate::exec::Trap) unless
    /// `0 ≤ i[idx] + off < len(cont)`. Emitted only for accesses the
    /// static verifier could not prove in bounds, immediately before the
    /// load/store they protect — fully proven programs carry none.
    BoundsCheck { cont: u16, idx: u16, off: i32 },

    // ---- control ----
    Jump { target: u32 },
    /// Loop back-edge test: continue when `(stride > 0 && var < end) ||
    /// (stride < 0 && var > end)`; otherwise fall through to `exit`.
    LoopCond { var: u16, end: u16, stride: u16, exit: u32 },
    /// Skip the next `skip` instructions when `f[cond] <= 0` (stmt guards).
    GuardSkip { cond: u16, skip: u32 },
    Halt,
}

/// A flat instruction block with its register budget.
#[derive(Debug, Clone, Default)]
pub struct CodeBlock {
    pub ops: Vec<Op>,
    pub n_int: u16,
    pub n_float: u16,
}

/// How a tree-level loop is executed by the runtime.
#[derive(Debug, Clone)]
pub enum ExecSchedule {
    Seq,
    /// DOALL: iterations partitioned across worker threads.
    Par,
    /// DOACROSS pipeline: `waits` = (body element index, δ); iteration `t`
    /// blocks before that element until iteration `t − δ` has released.
    /// `release_after` = body element index after which iteration `t`
    /// releases (None = end of body).
    Doacross {
        waits: Vec<(usize, i64)>,
        release_after: Option<usize>,
    },
}

/// Executable tree node.
#[derive(Debug, Clone)]
pub enum ExecNode {
    /// Fully sequential subtree compiled to flat bytecode.
    Code(CodeBlock),
    /// A loop that is parallel/doacross or contains one.
    Loop(Box<LoopExec>),
}

/// Tree-level loop.
#[derive(Debug, Clone)]
pub struct LoopExec {
    pub loop_id: crate::ir::LoopId,
    /// Int register holding the loop variable (global symbol register).
    pub var_reg: u16,
    /// Evaluates start/end/stride into `*_reg` (run at loop entry; stride
    /// re-evaluated per iteration to support variable strides).
    pub start: CodeBlock,
    pub start_reg: u16,
    pub end: CodeBlock,
    pub end_reg: u16,
    pub stride: CodeBlock,
    pub stride_reg: u16,
    pub schedule: ExecSchedule,
    pub body: Vec<ExecNode>,
    /// Pointer-increment maintenance: run after each iteration's body /
    /// after the loop exits.
    pub post_body: CodeBlock,
    pub post_loop: CodeBlock,
    /// Cursor initializations that §4.2.1 pins to the top of this loop's
    /// body (parallel involved loops — thread-private cursors).
    pub pre_body: CodeBlock,
    /// Prefetch hints (§4.1) executed at the top of each iteration.
    pub prefetch: CodeBlock,
}

/// Container metadata the executor needs.
#[derive(Debug, Clone)]
pub struct ContainerMeta {
    pub id: ContainerId,
    pub name: String,
    pub size: crate::symbolic::Expr,
    pub f32_storage: bool,
    /// Thread-private (privatized registers, §3.2.1).
    pub private: bool,
}

/// A fully lowered program.
#[derive(Debug, Clone)]
pub struct ExecProgram {
    pub name: String,
    pub params: Vec<Sym>,
    pub containers: Vec<ContainerMeta>,
    pub root: Vec<ExecNode>,
    /// Global symbol → int register assignment (params and loop vars).
    pub sym_regs: Vec<(Sym, u16)>,
    pub n_int: u16,
    pub n_float: u16,
    /// Number of [`Op::BoundsCheck`] guards emitted (0 = the unchecked
    /// fast tier — bitwise-identical bytecode to a trusted compile).
    pub checked_accesses: u32,
    /// Loops force-lowered as tree nodes for the speculative tier
    /// (`lowering::lower_speculative`): sequential top-level loops the
    /// runtime may run chunk-parallel against privatized buffers with
    /// conflict detection (`exec::speculate`). Empty everywhere else.
    pub spec_loops: Vec<crate::ir::LoopId>,
}

impl ExecProgram {
    pub fn sym_reg(&self, s: Sym) -> Option<u16> {
        self.sym_regs.iter().find(|(x, _)| *x == s).map(|(_, r)| *r)
    }

    /// Total op count across all blocks (diagnostics / cost model).
    pub fn op_count(&self) -> usize {
        fn node_ops(n: &ExecNode) -> usize {
            match n {
                ExecNode::Code(c) => c.ops.len(),
                ExecNode::Loop(l) => {
                    l.start.ops.len()
                        + l.end.ops.len()
                        + l.stride.ops.len()
                        + l.pre_body.ops.len()
                        + l.prefetch.ops.len()
                        + l.post_body.ops.len()
                        + l.post_loop.ops.len()
                        + l.body.iter().map(node_ops).sum::<usize>()
                }
            }
        }
        self.root.iter().map(node_ops).sum()
    }
}
