//! IR → bytecode lowering.
//!
//! Fully sequential subtrees flatten into single [`CodeBlock`]s (the VM hot
//! path); loops that are Parallel/Doacross — or contain one — stay tree
//! nodes so the runtime can distribute their iterations. Memory schedules
//! are realized here, per the paper's §4 architecture: prefetch hints
//! become [`Op::Prefetch`] at loop-body tops, pointer-increment plans
//! become cursor registers with init/increment/reset code.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::ir::{Loop, LoopId, LoopSchedule, Node, Program, Stmt};
use crate::schedules::ptr_inc::{all_plans, PtrPlan};
use crate::symbolic::{Expr, Sym};
use crate::verify::CheckSet;

use super::bytecode::{
    CodeBlock, ContainerMeta, ExecNode, ExecProgram, ExecSchedule, LoopExec, Op,
};
use super::expr_compile::{compile_float, compile_int, CursorBinding, CursorDelta, ExprCtx};

/// Cache lines each software-prefetch hint covers (8 f64 elements per
/// 64-byte line).
const PREFETCH_LINES: usize = 4;

/// Lower a program to its executable form (unchecked — the trusted
/// fast tier; see [`lower_with_checks`] for the verifier-driven tier).
pub fn lower(p: &Program) -> Result<ExecProgram> {
    lower_with_checks(p, &CheckSet::none())
}

/// Lower a program, guarding every access in `checks` with an
/// [`Op::BoundsCheck`] immediately before its load/store. With an empty
/// set this emits bytecode identical to [`lower`]; with
/// [`CheckSet::all`] every access is guarded (the differential-test
/// tier).
pub fn lower_with_checks(p: &Program, checks: &CheckSet) -> Result<ExecProgram> {
    lower_impl(p, checks, &[])
}

/// Lower the speculative-tier artifact: the loops in `spec` are kept as
/// *tree* nodes (scheduled `Seq`) instead of flattening into code
/// blocks, so `exec::speculate` can run their iterations chunk-parallel
/// against privatized buffers and fall back to in-place sequential
/// execution of the very same node on conflict. Memory schedules
/// (pointer-increment plans, prefetch hints) are stripped first: cursor
/// initialization is emitted only on the flat path, so a force-treed
/// loop under a ptr-inc plan would read garbage cursors. `checks` is
/// schedule-independent (keyed by statement/container/offset), so the
/// verifier's CheckSet applies to the stripped clone unchanged.
pub fn lower_speculative(
    p: &Program,
    checks: &CheckSet,
    spec: &[crate::ir::LoopId],
) -> Result<ExecProgram> {
    let mut stripped = p.clone();
    stripped.schedules = crate::ir::ScheduleSet::default();
    lower_impl(&stripped, checks, spec)
}

/// Lower the profiling artifact: *every* loop is kept as a tree node so
/// the runtime retains loop identity and the [`crate::exec::Tracer`]
/// loop hooks fire per nest level — flat-lowered loops have no runtime
/// identity at all. Memory schedules are stripped for the same reason as
/// [`lower_speculative`] (cursor initialization is flat-path-only), so
/// the profiled artifact reports the program's *semantic* accesses, not
/// schedule-injected prefetches. The bytecode this produces is only used
/// by `silo profile` / explicit profiling entry points; ordinary runs are
/// untouched.
pub fn lower_profiled(p: &Program, checks: &CheckSet) -> Result<ExecProgram> {
    let mut stripped = p.clone();
    stripped.schedules = crate::ir::ScheduleSet::default();
    let every_loop: Vec<LoopId> = stripped.loops().iter().map(|l| l.id).collect();
    let mut prog = lower_impl(&stripped, checks, &every_loop)?;
    // `lower_impl` records `force_tree` as speculative candidates; the
    // profiled artifact runs on the ordinary VM, never the speculative
    // runtime, so none of its loops are speculation targets.
    prog.spec_loops.clear();
    Ok(prog)
}

fn lower_impl(
    p: &Program,
    checks: &CheckSet,
    force_tree: &[crate::ir::LoopId],
) -> Result<ExecProgram> {
    crate::ir::validate::validate(p)?;

    // 1. Global symbol registers: params first, then every loop variable.
    let mut sym_regs: Vec<(Sym, u16)> = Vec::new();
    for s in &p.params {
        sym_regs.push((*s, sym_regs.len() as u16));
    }
    for l in p.loops() {
        if !sym_regs.iter().any(|(s, _)| *s == l.var) {
            sym_regs.push((l.var, sym_regs.len() as u16));
        }
    }

    // 2. Pointer-increment plans → global cursor registers.
    let plans = all_plans(p);
    let cursor_base = sym_regs.len() as u16;
    let mut cursor_regs: Vec<u16> = Vec::new();
    for (i, _) in plans.iter().enumerate() {
        cursor_regs.push(cursor_base + i as u16);
    }
    // Hoisted symbolic delta registers (shared across plans).
    let delta_base = cursor_base + plans.len() as u16;
    let mut delta_exprs: Vec<Expr> = Vec::new();
    for plan in &plans {
        for (_, d) in &plan.accesses {
            if let crate::schedules::ptr_inc::AccessDelta::Sym(e) = d {
                if !delta_exprs.contains(e) {
                    delta_exprs.push(e.clone());
                }
            }
        }
    }
    let scratch_int_base = delta_base + delta_exprs.len() as u16;

    // Plan-derived lowering tables.
    let mut lowering = Lowering {
        program: p,
        sym_regs: sym_regs.clone(),
        scratch_int_base,
        plans: &plans,
        cursor_regs: &cursor_regs,
        init_before: HashMap::new(),
        init_inside: HashMap::new(),
        incs: HashMap::new(),
        resets: HashMap::new(),
        prefetches: HashMap::new(),
        delta_base,
        delta_exprs: delta_exprs.clone(),
        max_int: scratch_int_base,
        max_float: 0,
        checks: Arc::new(checks.clone()),
        checks_emitted: 0,
        force_tree: force_tree.iter().copied().collect(),
    };
    for (idx, plan) in plans.iter().enumerate() {
        match plan.init_inside {
            Some(lid) => lowering.init_inside.entry(lid).or_default().push(idx),
            None => lowering
                .init_before
                .entry(plan.outermost)
                .or_default()
                .push(idx),
        }
        for d in &plan.deltas {
            lowering
                .incs
                .entry(d.loop_id)
                .or_default()
                .push((cursor_regs[idx], d.inc.clone()));
            if let Some(r) = &d.reset {
                lowering
                    .resets
                    .entry(d.loop_id)
                    .or_default()
                    .push((cursor_regs[idx], r.clone()));
            }
        }
    }
    for h in &p.schedules.prefetches {
        lowering
            .prefetches
            .entry(h.at_loop)
            .or_default()
            .push(h.clone());
    }

    // 3. Build the tree (prefixed by the delta-register prelude).
    let mut root = Vec::new();
    if !delta_exprs.is_empty() {
        root.push(ExecNode::Code(lowering.compile_delta_prelude()?));
    }
    root.extend(lowering.lower_sequence(&p.body)?);

    // 4. Container metadata.
    let containers: Vec<ContainerMeta> = p
        .containers
        .iter()
        .map(|c| ContainerMeta {
            id: c.id,
            name: c.name.clone(),
            size: c.size.clone(),
            f32_storage: c.dtype == crate::ir::DType::F32,
            private: c.kind == crate::ir::ContainerKind::Register,
        })
        .collect();

    Ok(ExecProgram {
        name: p.name.clone(),
        params: p.params.clone(),
        containers,
        root,
        sym_regs,
        n_int: lowering.max_int,
        n_float: lowering.max_float.max(1),
        checked_accesses: lowering.checks_emitted,
        spec_loops: force_tree.to_vec(),
    })
}

struct Lowering<'a> {
    program: &'a Program,
    sym_regs: Vec<(Sym, u16)>,
    scratch_int_base: u16,
    plans: &'a [PtrPlan],
    cursor_regs: &'a [u16],
    /// plan indices whose cursor init is emitted before loop L.
    init_before: HashMap<LoopId, Vec<usize>>,
    /// plan indices whose cursor init runs at the top of L's body.
    init_inside: HashMap<LoopId, Vec<usize>>,
    /// per-loop cursor increments (after each iteration).
    incs: HashMap<LoopId, Vec<(u16, Expr)>>,
    /// per-loop cursor resets (after the loop completes).
    resets: HashMap<LoopId, Vec<(u16, Expr)>>,
    prefetches: HashMap<LoopId, Vec<crate::ir::PrefetchHint>>,
    /// First hoisted-delta register; `delta_exprs[i]` lives in
    /// `delta_base + i`.
    delta_base: u16,
    delta_exprs: Vec<Expr>,
    max_int: u16,
    max_float: u16,
    /// Verifier-unproven accesses to guard ([`lower_with_checks`]).
    checks: Arc<CheckSet>,
    checks_emitted: u32,
    /// Loops lowered as tree nodes even though fully sequential — the
    /// speculative tier's dispatch points ([`lower_speculative`]).
    force_tree: HashSet<LoopId>,
}

impl<'a> Lowering<'a> {
    fn ctx(&self) -> ExprCtx {
        let mut ctx = ExprCtx::new(self.sym_regs.clone(), self.scratch_int_base, 0);
        ctx.checks = Arc::clone(&self.checks);
        ctx
    }

    fn bindings_for_ctx(&self) -> Vec<CursorBinding> {
        let mut out = Vec::new();
        for (idx, plan) in self.plans.iter().enumerate() {
            for (off, delta) in &plan.accesses {
                let delta = match delta {
                    crate::schedules::ptr_inc::AccessDelta::Const(c) => {
                        CursorDelta::Const(*c as i32)
                    }
                    crate::schedules::ptr_inc::AccessDelta::Sym(e) => {
                        let pos = self
                            .delta_exprs
                            .iter()
                            .position(|x| x == e)
                            .expect("delta expr registered");
                        CursorDelta::Reg(self.delta_base + pos as u16)
                    }
                };
                out.push(CursorBinding {
                    stmt: plan.stmt,
                    container: plan.container,
                    offset: off.clone(),
                    reg: self.cursor_regs[idx],
                    delta,
                });
            }
        }
        out
    }

    /// Program prelude: evaluate each hoisted symbolic delta into its
    /// dedicated register (param-only expressions — loop-invariant).
    fn compile_delta_prelude(&mut self) -> Result<CodeBlock> {
        let mut ctx = self.ctx();
        let mut ops = Vec::new();
        for (i, e) in self.delta_exprs.clone().iter().enumerate() {
            let r = compile_int(e, &mut ctx, &mut ops)?;
            ops.push(Op::ICopy {
                dst: self.delta_base + i as u16,
                src: r,
            });
            ctx.free_int(r);
        }
        ops.push(Op::Halt);
        let block = CodeBlock {
            ops,
            n_int: ctx.max_int,
            n_float: ctx.max_float,
        };
        self.absorb(&ctx);
        Ok(block)
    }

    fn absorb(&mut self, ctx: &ExprCtx) {
        self.max_int = self.max_int.max(ctx.max_int);
        self.max_float = self.max_float.max(ctx.max_float);
        self.checks_emitted += ctx.checks_emitted;
    }

    fn sym_reg(&self, s: Sym) -> u16 {
        self.sym_regs
            .iter()
            .find(|(x, _)| *x == s)
            .map(|(_, r)| *r)
            .expect("symbol register")
    }

    /// Does this subtree stay on the sequential fast path?
    fn fully_sequential(n: &Node) -> bool {
        match n {
            Node::Stmt(_) => true,
            Node::Loop(l) => {
                matches!(l.schedule, LoopSchedule::Sequential)
                    && l.body.iter().all(Self::fully_sequential)
            }
        }
    }

    /// Lower a node sequence: coalesce runs of sequential nodes into flat
    /// blocks; parallel-bearing loops become tree nodes.
    fn lower_sequence(&mut self, nodes: &[Node]) -> Result<Vec<ExecNode>> {
        let mut out: Vec<ExecNode> = Vec::new();
        let mut run: Vec<&Node> = Vec::new();
        for n in nodes {
            let forced = n
                .as_loop()
                .is_some_and(|l| self.force_tree.contains(&l.id));
            if Self::fully_sequential(n) && !forced {
                run.push(n);
            } else {
                if !run.is_empty() {
                    out.push(ExecNode::Code(self.compile_flat(&run)?));
                    run.clear();
                }
                let Node::Loop(l) = n else {
                    unreachable!("statements are always sequential");
                };
                out.push(self.lower_tree_loop(l)?);
            }
        }
        if !run.is_empty() {
            out.push(ExecNode::Code(self.compile_flat(&run)?));
        }
        Ok(out)
    }

    /// Lower a loop that is parallel/doacross or contains one.
    fn lower_tree_loop(&mut self, l: &Loop) -> Result<ExecNode> {
        let var_reg = self.sym_reg(l.var);
        let mk_block = |this: &mut Self, e: &Expr| -> Result<(CodeBlock, u16)> {
            let mut ctx = this.ctx();
            let mut ops = Vec::new();
            let r = compile_int(e, &mut ctx, &mut ops)?;
            this.absorb(&ctx);
            Ok((
                CodeBlock {
                    ops,
                    n_int: ctx.max_int,
                    n_float: ctx.max_float,
                },
                r,
            ))
        };
        let (start, start_reg) = mk_block(self, &l.start)?;
        let (end, end_reg) = mk_block(self, &l.end)?;
        let (stride, stride_reg) = mk_block(self, &l.stride)?;

        // pre_body: cursor inits pinned to the top of this loop's body.
        let mut pre_body = CodeBlock::default();
        if let Some(idxs) = self.init_inside.get(&l.id).cloned() {
            let mut ctx = self.ctx();
            for idx in idxs {
                let init = self.plans[idx].init.clone();
                let r = compile_int(&init, &mut ctx, &mut pre_body.ops)?;
                pre_body.ops.push(Op::ICopy {
                    dst: self.cursor_regs[idx],
                    src: r,
                });
                ctx.free_int(r);
            }
            pre_body.n_int = ctx.max_int;
            self.absorb(&ctx);
        }

        // prefetch hints at the top of each iteration: cover the first
        // few cache lines (8 elements apart) of the next iteration's
        // access region, like a compiler unrolling __builtin_prefetch.
        let mut prefetch = CodeBlock::default();
        if let Some(hints) = self.prefetches.get(&l.id).cloned() {
            let mut ctx = self.ctx();
            for h in hints {
                let r = compile_int(&h.offset, &mut ctx, &mut prefetch.ops)?;
                for line in 0..PREFETCH_LINES {
                    let idx = if line == 0 {
                        r
                    } else {
                        let t = ctx.alloc_int();
                        prefetch.ops.push(Op::IAddImm {
                            dst: t,
                            a: r,
                            imm: (line * 8) as i64,
                        });
                        t
                    };
                    prefetch.ops.push(Op::Prefetch {
                        cont: h.container.0 as u16,
                        idx,
                        write: h.for_write,
                    });
                    if line != 0 {
                        ctx.free_int(idx);
                    }
                }
                ctx.free_int(r);
            }
            prefetch.n_int = ctx.max_int;
            self.absorb(&ctx);
        }

        // post_body: cursor increments for this loop.
        let mut post_body = CodeBlock::default();
        if let Some(incs) = self.incs.get(&l.id).cloned() {
            let mut ctx = self.ctx();
            for (reg, inc) in incs {
                self.emit_cursor_add(&mut ctx, &mut post_body.ops, reg, &inc, false)?;
            }
            post_body.n_int = ctx.max_int;
            self.absorb(&ctx);
        }

        // post_loop: cursor resets after this loop exits.
        let mut post_loop = CodeBlock::default();
        if let Some(resets) = self.resets.get(&l.id).cloned() {
            let mut ctx = self.ctx();
            for (reg, r) in resets {
                self.emit_cursor_add(&mut ctx, &mut post_loop.ops, reg, &r, true)?;
            }
            post_loop.n_int = ctx.max_int;
            self.absorb(&ctx);
        }

        let body = self.lower_sequence(&l.body)?;

        // Schedule: map WaitSpecs (stmt ids) to body element indices.
        let schedule = match &l.schedule {
            LoopSchedule::Sequential => ExecSchedule::Seq,
            LoopSchedule::Parallel => ExecSchedule::Par,
            LoopSchedule::Doacross { waits, release } => {
                let elem_of_stmt = |sid: crate::ir::StmtId| -> Option<usize> {
                    l.body
                        .iter()
                        .position(|n| n.stmts().iter().any(|s| s.id == sid))
                };
                let mut ws = Vec::new();
                for w in waits {
                    let Some(elem) = elem_of_stmt(w.before_stmt) else {
                        bail!("DOACROSS wait target not in body");
                    };
                    ws.push((elem, w.delta));
                }
                // Deduplicate (same element, same delta).
                ws.sort();
                ws.dedup();
                let release_after = match release {
                    crate::ir::ReleaseSpec::AfterStmt(sid) => {
                        Some(elem_of_stmt(*sid).ok_or_else(|| {
                            anyhow::anyhow!("DOACROSS release target not in body")
                        })?)
                    }
                    crate::ir::ReleaseSpec::EndOfBody => None,
                };
                // Body element indices must match ExecNode indices: they do
                // only when each IR body node lowers to exactly one
                // ExecNode. Guarantee it by lowering each element alone.
                let mut tree_body = Vec::new();
                for n in &l.body {
                    let lowered = self.lower_sequence(std::slice::from_ref(n))?;
                    debug_assert_eq!(lowered.len(), 1);
                    tree_body.extend(lowered);
                }
                return Ok(ExecNode::Loop(Box::new(LoopExec {
                    loop_id: l.id,
                    var_reg,
                    start,
                    start_reg,
                    end,
                    end_reg,
                    stride,
                    stride_reg,
                    schedule: ExecSchedule::Doacross {
                        waits: ws,
                        release_after,
                    },
                    body: tree_body,
                    post_body,
                    post_loop,
                    pre_body,
                    prefetch,
                })));
            }
        };

        Ok(ExecNode::Loop(Box::new(LoopExec {
            loop_id: l.id,
            var_reg,
            start,
            start_reg,
            end,
            end_reg,
            stride,
            stride_reg,
            schedule,
            body,
            post_body,
            post_loop,
            pre_body,
            prefetch,
        })))
    }

    /// `cursor += expr` (or `-=` when `negate`), constant-folded when the
    /// expr is a literal.
    fn emit_cursor_add(
        &mut self,
        ctx: &mut ExprCtx,
        ops: &mut Vec<Op>,
        reg: u16,
        e: &Expr,
        negate: bool,
    ) -> Result<()> {
        if let Some(v) = e.as_int() {
            let imm = if negate { -v } else { v };
            ops.push(Op::IAddImm { dst: reg, a: reg, imm });
            return Ok(());
        }
        let r = compile_int(e, ctx, ops)?;
        if negate {
            ops.push(Op::ISub { dst: reg, a: reg, b: r });
        } else {
            ops.push(Op::IAdd { dst: reg, a: reg, b: r });
        }
        ctx.free_int(r);
        Ok(())
    }

    /// Flatten a run of fully sequential nodes into one code block.
    fn compile_flat(&mut self, nodes: &[&Node]) -> Result<CodeBlock> {
        let mut ctx = self.ctx();
        ctx.cursors = self.bindings_for_ctx();
        let mut ops: Vec<Op> = Vec::new();
        for n in nodes {
            self.flat_node(n, &mut ctx, &mut ops)?;
        }
        ops.push(Op::Halt);
        let block = CodeBlock {
            ops,
            n_int: ctx.max_int,
            n_float: ctx.max_float,
        };
        self.absorb(&ctx);
        Ok(block)
    }

    fn flat_node(&self, n: &Node, ctx: &mut ExprCtx, ops: &mut Vec<Op>) -> Result<()> {
        match n {
            Node::Stmt(s) => self.flat_stmt(s, ctx, ops),
            Node::Loop(l) => self.flat_loop(l, ctx, ops),
        }
    }

    fn flat_stmt(&self, s: &Stmt, ctx: &mut ExprCtx, ops: &mut Vec<Op>) -> Result<()> {
        ctx.current_stmt = Some(s.id);
        // Guard: skip the statement when guard <= 0.
        let guard_pos = if let Some(g) = &s.guard {
            let cond = compile_float(g, ctx, ops)?;
            let pos = ops.len();
            ops.push(Op::GuardSkip { cond, skip: 0 });
            ctx.free_float(cond);
            Some(pos)
        } else {
            None
        };

        let val = compile_float(&s.rhs, ctx, ops)?;
        let cont = s.write.container.0 as u16;
        let f32s = self.program.container(s.write.container).dtype == crate::ir::DType::F32;
        let checked = ctx.needs_check(s.write.container, &s.write.offset);
        if checked {
            // Checked writes recompute the index so the guard covers
            // exactly the stored-through address (no cursor addressing).
            let idx = compile_int(&s.write.offset, ctx, ops)?;
            ops.push(Op::BoundsCheck { cont, idx, off: 0 });
            ctx.checks_emitted += 1;
            ops.push(if f32s {
                Op::StoreF32 {
                    cont,
                    idx,
                    src: val,
                }
            } else {
                Op::Store {
                    cont,
                    idx,
                    src: val,
                }
            });
            ctx.free_int(idx);
        } else if let Some((reg, CursorDelta::Const(delta))) = ctx
            .cursors
            .iter()
            .find(|b| {
                b.stmt == s.id && b.container == s.write.container && b.offset == s.write.offset
            })
            .map(|b| (b.reg, b.delta))
        {
            ops.push(if f32s {
                Op::StoreOffF32 {
                    cont,
                    idx: reg,
                    off: delta,
                    src: val,
                }
            } else {
                Op::StoreOff {
                    cont,
                    idx: reg,
                    off: delta,
                    src: val,
                }
            });
        } else {
            let idx = compile_int(&s.write.offset, ctx, ops)?;
            ops.push(if f32s {
                Op::StoreF32 {
                    cont,
                    idx,
                    src: val,
                }
            } else {
                Op::Store {
                    cont,
                    idx,
                    src: val,
                }
            });
            ctx.free_int(idx);
        }
        ctx.free_float(val);

        if let Some(pos) = guard_pos {
            let skip = (ops.len() - pos - 1) as u32;
            if let Op::GuardSkip { skip: s, .. } = &mut ops[pos] {
                *s = skip;
            }
        }
        ctx.flush_deferred();
        ctx.current_stmt = None;
        Ok(())
    }

    fn flat_loop(&self, l: &Loop, ctx: &mut ExprCtx, ops: &mut Vec<Op>) -> Result<()> {
        // Cursor inits placed before this loop.
        if let Some(idxs) = self.init_before.get(&l.id) {
            for idx in idxs {
                let init = self.plans[*idx].init.clone();
                let r = compile_int(&init, ctx, ops)?;
                ops.push(Op::ICopy {
                    dst: self.cursor_regs[*idx],
                    src: r,
                });
                ctx.free_int(r);
            }
        }
        let var = self.sym_reg(l.var);
        // start → var
        let r = compile_int(&l.start, ctx, ops)?;
        ops.push(Op::ICopy { dst: var, src: r });
        ctx.free_int(r);
        // end → held register (not freed until loop done)
        let end_reg = compile_int(&l.end, ctx, ops)?;
        // loop head
        let head = ops.len();
        // stride (re-evaluated each iteration: may depend on the loop var)
        let stride_reg = compile_int(&l.stride, ctx, ops)?;
        let cond_pos = ops.len();
        ops.push(Op::LoopCond {
            var,
            end: end_reg,
            stride: stride_reg,
            exit: 0,
        });
        // prefetch hints at iteration top (multi-line, see lower_tree_loop)
        if let Some(hints) = self.prefetches.get(&l.id) {
            for h in hints {
                let ri = compile_int(&h.offset, ctx, ops)?;
                for line in 0..PREFETCH_LINES {
                    let idx = if line == 0 {
                        ri
                    } else {
                        let t = ctx.alloc_int();
                        ops.push(Op::IAddImm {
                            dst: t,
                            a: ri,
                            imm: (line * 8) as i64,
                        });
                        t
                    };
                    ops.push(Op::Prefetch {
                        cont: h.container.0 as u16,
                        idx,
                        write: h.for_write,
                    });
                    if line != 0 {
                        ctx.free_int(idx);
                    }
                }
                ctx.free_int(ri);
            }
        }
        // body
        for n in &l.body {
            self.flat_node(n, ctx, ops)?;
        }
        // post-body cursor increments
        if let Some(incs) = self.incs.get(&l.id) {
            for (reg, inc) in incs {
                if let Some(v) = inc.as_int() {
                    ops.push(Op::IAddImm {
                        dst: *reg,
                        a: *reg,
                        imm: v,
                    });
                } else {
                    let ri = compile_int(inc, ctx, ops)?;
                    ops.push(Op::IAdd {
                        dst: *reg,
                        a: *reg,
                        b: ri,
                    });
                    ctx.free_int(ri);
                }
            }
        }
        // var += stride; loop back
        ops.push(Op::IAdd {
            dst: var,
            a: var,
            b: stride_reg,
        });
        ops.push(Op::Jump {
            target: head as u32,
        });
        let exit = ops.len() as u32;
        if let Op::LoopCond { exit: e, .. } = &mut ops[cond_pos] {
            *e = exit;
        }
        // post-loop cursor resets
        if let Some(resets) = self.resets.get(&l.id) {
            for (reg, reset) in resets {
                if let Some(v) = reset.as_int() {
                    ops.push(Op::IAddImm {
                        dst: *reg,
                        a: *reg,
                        imm: -v,
                    });
                } else {
                    let ri = compile_int(reset, ctx, ops)?;
                    ops.push(Op::ISub {
                        dst: *reg,
                        a: *reg,
                        b: ri,
                    });
                    ctx.free_int(ri);
                }
            }
        }
        ctx.free_int(stride_reg);
        ctx.free_int(end_reg);
        Ok(())
    }
}
