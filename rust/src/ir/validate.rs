//! Structural validation of loop programs.
//!
//! Catches builder/transform bugs early: duplicate ids, unbound symbols,
//! accesses to undeclared containers, malformed schedules.

use std::collections::HashSet;

use anyhow::{bail, Result};

use crate::symbolic::Sym;

use super::nest::{LoopSchedule, Node};
use super::program::Program;

/// Validate program structure. Transform passes call this in debug builds
/// and tests call it on every kernel in the corpus.
pub fn validate(p: &Program) -> Result<()> {
    let mut loop_ids = HashSet::new();
    let mut stmt_ids = HashSet::new();
    let n_containers = p.containers.len() as u32;

    // Bound symbols: params + loop vars (collected on the way down).
    fn check_nodes(
        nodes: &[Node],
        p: &Program,
        bound: &mut Vec<Sym>,
        loop_ids: &mut HashSet<u32>,
        stmt_ids: &mut HashSet<u32>,
        n_containers: u32,
    ) -> Result<()> {
        for n in nodes {
            match n {
                Node::Stmt(s) => {
                    if !stmt_ids.insert(s.id.0) {
                        bail!("duplicate stmt id s{}", s.id.0);
                    }
                    if s.write.container.0 >= n_containers {
                        bail!("stmt s{} writes undeclared container", s.id.0);
                    }
                    for a in s.reads() {
                        if a.container.0 >= n_containers {
                            bail!("stmt s{} reads undeclared container", s.id.0);
                        }
                    }
                    for sym in s.write.offset.symbols() {
                        if !bound.contains(&sym) && !p.params.contains(&sym) {
                            bail!(
                                "stmt s{} offset uses unbound symbol {}",
                                s.id.0,
                                sym.name()
                            );
                        }
                    }
                    for sym in s.rhs.symbols() {
                        if !bound.contains(&sym) && !p.params.contains(&sym) {
                            bail!("stmt s{} rhs uses unbound symbol {}", s.id.0, sym.name());
                        }
                    }
                }
                Node::Loop(l) => {
                    if !loop_ids.insert(l.id.0) {
                        bail!("duplicate loop id L{}", l.id.0);
                    }
                    if bound.contains(&l.var) {
                        bail!("loop L{} shadows loop variable {}", l.id.0, l.var.name());
                    }
                    for e in [&l.start, &l.end] {
                        for sym in e.symbols() {
                            if sym != l.var && !bound.contains(&sym) && !p.params.contains(&sym) {
                                bail!(
                                    "loop L{} bound uses unbound symbol {}",
                                    l.id.0,
                                    sym.name()
                                );
                            }
                        }
                    }
                    // Stride may reference the loop's own variable (Fig. 2).
                    for sym in l.stride.symbols() {
                        if sym != l.var && !bound.contains(&sym) && !p.params.contains(&sym) {
                            bail!(
                                "loop L{} stride uses unbound symbol {}",
                                l.id.0,
                                sym.name()
                            );
                        }
                    }
                    if l.stride.is_zero() {
                        bail!("loop L{} has zero stride", l.id.0);
                    }
                    // DOACROSS wait/release targets must be in this body.
                    if let LoopSchedule::Doacross { waits, release } = &l.schedule {
                        let body_stmts: HashSet<u32> =
                            Node::Loop(l.clone()).stmts().iter().map(|s| s.id.0).collect();
                        for w in waits {
                            if !body_stmts.contains(&w.before_stmt.0) {
                                bail!(
                                    "L{} DOACROSS waits on stmt s{} outside its body",
                                    l.id.0,
                                    w.before_stmt.0
                                );
                            }
                            if w.delta <= 0 {
                                bail!("L{} DOACROSS wait with non-positive δ", l.id.0);
                            }
                        }
                        if let super::nest::ReleaseSpec::AfterStmt(sid) = release {
                            if !body_stmts.contains(&sid.0) {
                                bail!("L{} DOACROSS release outside its body", l.id.0);
                            }
                        }
                    }
                    bound.push(l.var);
                    check_nodes(&l.body, p, bound, loop_ids, stmt_ids, n_containers)?;
                    bound.pop();
                }
            }
        }
        Ok(())
    }

    let mut bound = Vec::new();
    check_nodes(
        &p.body,
        p,
        &mut bound,
        &mut loop_ids,
        &mut stmt_ids,
        n_containers,
    )?;

    // Schedule set references must resolve.
    for (sid, cid) in &p.schedules.ptr_inc {
        if p.find_stmt(*sid).is_none() {
            bail!("ptr-inc schedule names missing stmt s{}", sid.0);
        }
        if cid.0 >= n_containers {
            bail!("ptr-inc schedule names undeclared container");
        }
    }
    for pf in &p.schedules.prefetches {
        if p.find_loop(pf.at_loop).is_none() {
            bail!("prefetch hint names missing loop L{}", pf.at_loop.0);
        }
        if pf.container.0 >= n_containers {
            bail!("prefetch hint names undeclared container");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::ProgramBuilder;
    use crate::symbolic::{int, load, Expr};

    #[test]
    fn valid_program_passes() {
        let mut b = ProgramBuilder::new("v");
        let n = b.param_positive("val_N");
        let a = b.array("A", Expr::Sym(n));
        let i = b.sym("val_i");
        b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
            b.assign(a, Expr::Sym(i), load(a, Expr::Sym(i)));
        });
        validate(&b.finish()).unwrap();
    }

    #[test]
    fn unbound_symbol_rejected() {
        let mut b = ProgramBuilder::new("v2");
        let n = b.param_positive("val2_N");
        let a = b.array("A", Expr::Sym(n));
        let i = b.sym("val2_i");
        let rogue = b.sym("val2_rogue");
        b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
            b.assign(a, Expr::Sym(rogue), Expr::real(0.0));
        });
        assert!(validate(&b.finish()).is_err());
    }

    #[test]
    fn variable_stride_is_legal() {
        // Fig. 2: for (i=1; i<=n; i+=i)
        let mut b = ProgramBuilder::new("v3");
        let n = b.param_positive("val3_N");
        let a = b.array("A", Expr::Sym(n));
        let i = b.sym("val3_i");
        b.for_(i, int(1), Expr::Sym(n), Expr::Sym(i), |b| {
            use crate::symbolic::{func, FuncKind};
            b.assign(a, func(FuncKind::Log2, vec![Expr::Sym(i)]), Expr::real(1.0));
        });
        validate(&b.finish()).unwrap();
    }

    #[test]
    fn zero_stride_rejected() {
        let mut b = ProgramBuilder::new("v4");
        let n = b.param_positive("val4_N");
        let a = b.array("A", Expr::Sym(n));
        let i = b.sym("val4_i");
        b.for_(i, int(0), Expr::Sym(n), int(0), |b| {
            b.assign(a, Expr::Sym(i), Expr::real(0.0));
        });
        assert!(validate(&b.finish()).is_err());
    }
}
