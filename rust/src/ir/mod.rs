//! The loop-nest intermediate representation SILO analyzes and transforms.
//!
//! Mirrors the paper's program model (§2.1): a program is a tree of loops
//! and statements. A loop is characterized by `(var, start, end, stride)` —
//! all symbolic — plus a body; a statement is a guarded single assignment
//! `D[f] := expr(loads…)` whose reads/writes are container+offset pairs with
//! injective symbolic offset expressions. Memory schedules (§4) are
//! *properties on accesses*, kept out of the tree and materialized only at
//! lowering.

pub mod access;
pub mod builder;
pub mod container;
pub mod nest;
pub mod pretty;
pub mod program;
pub mod validate;

pub use access::{Access, AccessKind};
pub use builder::ProgramBuilder;
pub use container::{Container, ContainerKind, DType};
pub use nest::{Loop, LoopId, LoopSchedule, Node, ReleaseSpec, Stmt, StmtId, WaitSpec};
pub use program::{PrefetchHint, Program, ScheduleSet};
