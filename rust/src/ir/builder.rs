//! Fluent construction of loop programs (used by the kernel corpus, the
//! examples, and tests).

use crate::symbolic::{ContainerId, Expr, Sym};

use super::access::Access;
use super::container::{ContainerKind, DType};
use super::nest::{Loop, LoopSchedule, Node, Stmt};
use super::program::Program;

/// Builder over a [`Program`] with a cursor into the loop tree.
///
/// ```no_run
/// use silo::ir::ProgramBuilder;
/// use silo::symbolic::{int, load, psym, Expr};
///
/// let mut b = ProgramBuilder::new("axpy");
/// let n = b.param_positive("axpy_N");
/// let x = b.array("x", Expr::Sym(n));
/// let y = b.array("y", Expr::Sym(n));
/// let i = b.sym("axpy_i");
/// b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
///     let iv = Expr::Sym(i);
///     b.assign(y, iv.clone(), Expr::real(2.0) * load(x, iv.clone()) + load(y, iv));
/// });
/// let prog = b.finish();
/// assert_eq!(prog.stmts().len(), 1);
/// ```
pub struct ProgramBuilder {
    prog: Program,
    /// Stack of open loops; statements append to the innermost.
    stack: Vec<Loop>,
}

impl ProgramBuilder {
    pub fn new(name: &str) -> ProgramBuilder {
        ProgramBuilder {
            prog: Program::new(name),
            stack: Vec::new(),
        }
    }

    /// Declare a symbolic program parameter (assumed positive — array
    /// extents and strides).
    pub fn param_positive(&mut self, name: &str) -> Sym {
        let s = Sym::positive(name);
        if !self.prog.params.contains(&s) {
            self.prog.params.push(s);
        }
        s
    }

    /// Plain (unassumed) symbol, e.g. loop variables.
    pub fn sym(&mut self, name: &str) -> Sym {
        Sym::new(name)
    }

    /// Declare an array *dimension extent* parameter: positive, ≥ 2, and
    /// registered so the affinity classifier accepts `var·extent` products
    /// as multidimensional-affine (the paper's multidim array notation).
    pub fn dim_param(&mut self, name: &str) -> Sym {
        let s = Sym::positive_min(name, 2);
        if !self.prog.params.contains(&s) {
            self.prog.params.push(s);
        }
        if !self.prog.dim_syms.contains(&s) {
            self.prog.dim_syms.push(s);
        }
        s
    }

    /// Declare an f64 argument array of `size` elements.
    pub fn array(&mut self, name: &str, size: Expr) -> ContainerId {
        self.prog
            .add_container(name, size, DType::F64, ContainerKind::Argument)
    }

    pub fn array_typed(&mut self, name: &str, size: Expr, dtype: DType) -> ContainerId {
        self.prog
            .add_container(name, size, dtype, ContainerKind::Argument)
    }

    /// Declare a transient (program-allocated) array.
    pub fn transient(&mut self, name: &str, size: Expr) -> ContainerId {
        self.prog
            .add_container(name, size, DType::F64, ContainerKind::Transient)
    }

    /// Declare a scalar transient.
    pub fn scalar(&mut self, name: &str) -> ContainerId {
        self.prog
            .add_container(name, Expr::Int(1), DType::F64, ContainerKind::Transient)
    }

    /// Open a loop `for (var = start; var <?> end; var += stride)`, build
    /// the body in the closure, close it.
    pub fn for_(
        &mut self,
        var: Sym,
        start: Expr,
        end: Expr,
        stride: Expr,
        body: impl FnOnce(&mut ProgramBuilder),
    ) {
        let id = self.prog.fresh_loop_id();
        self.stack.push(Loop {
            id,
            var,
            start,
            end,
            stride,
            schedule: LoopSchedule::Sequential,
            body: Vec::new(),
        });
        body(self);
        let l = self.stack.pop().expect("builder loop stack underflow");
        self.push_node(Node::Loop(l));
    }

    /// `for_` with a returned loop id (when transforms/tests need it).
    pub fn for_id(
        &mut self,
        var: Sym,
        start: Expr,
        end: Expr,
        stride: Expr,
        body: impl FnOnce(&mut ProgramBuilder),
    ) -> super::nest::LoopId {
        let id_probe = self.prog.fresh_loop_id();
        self.stack.push(Loop {
            id: id_probe,
            var,
            start,
            end,
            stride,
            schedule: LoopSchedule::Sequential,
            body: Vec::new(),
        });
        body(self);
        let l = self.stack.pop().expect("builder loop stack underflow");
        let id = l.id;
        self.push_node(Node::Loop(l));
        id
    }

    /// Append `container[offset] := rhs`.
    pub fn assign(
        &mut self,
        container: ContainerId,
        offset: Expr,
        rhs: Expr,
    ) -> super::nest::StmtId {
        let id = self.prog.fresh_stmt_id();
        self.push_node(Node::Stmt(Stmt {
            id,
            write: Access::write(container, crate::symbolic::simplify(&offset)),
            rhs: crate::symbolic::simplify(&rhs),
            guard: None,
        }));
        id
    }

    /// Append a guarded assignment (executes iff guard != 0).
    pub fn assign_if(
        &mut self,
        guard: Expr,
        container: ContainerId,
        offset: Expr,
        rhs: Expr,
    ) -> super::nest::StmtId {
        let id = self.prog.fresh_stmt_id();
        self.push_node(Node::Stmt(Stmt {
            id,
            write: Access::write(container, crate::symbolic::simplify(&offset)),
            rhs: crate::symbolic::simplify(&rhs),
            guard: Some(crate::symbolic::simplify(&guard)),
        }));
        id
    }

    fn push_node(&mut self, n: Node) {
        if let Some(top) = self.stack.last_mut() {
            top.body.push(n);
        } else {
            self.prog.body.push(n);
        }
    }

    pub fn finish(self) -> Program {
        assert!(
            self.stack.is_empty(),
            "unclosed loops at ProgramBuilder::finish"
        );
        self.prog
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::symbolic::{int, load};

    #[test]
    fn nested_loops_build() {
        let mut b = ProgramBuilder::new("t");
        let n = b.param_positive("bld_N");
        let a = b.array("A", Expr::Sym(n) * Expr::Sym(n));
        let i = b.sym("bld_i");
        let j = b.sym("bld_j");
        b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
            b.for_(j, int(0), Expr::Sym(n), int(1), |b| {
                let off = Expr::Sym(i) * Expr::Sym(n) + Expr::Sym(j);
                b.assign(a, off.clone(), load(a, off) + Expr::real(1.0));
            });
        });
        let p = b.finish();
        assert_eq!(p.loops().len(), 2);
        assert_eq!(p.stmts().len(), 1);
        let parents = p.stmt_parents();
        let sid = p.stmts()[0].id;
        assert_eq!(parents[&sid].len(), 2);
    }

    #[test]
    #[should_panic(expected = "unclosed")]
    fn unclosed_loop_panics() {
        let mut b = ProgramBuilder::new("bad");
        let i = b.sym("bld_bad_i");
        let id = b.prog.fresh_loop_id();
        b.stack.push(Loop {
            id,
            var: i,
            start: int(0),
            end: int(1),
            stride: int(1),
            schedule: LoopSchedule::Sequential,
            body: vec![],
        });
        let _ = b.finish();
    }
}
