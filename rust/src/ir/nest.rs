//! Loop-nest tree: loops characterized by `(var, start, end, stride)` and
//! guarded single-assignment statements.

use crate::symbolic::{ContainerId, Expr, Sym};

use super::access::{Access, AccessKind};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct LoopId(pub u32);

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct StmtId(pub u32);

/// How a loop's iterations are scheduled after optimization.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LoopSchedule {
    /// Iterations run in order (default).
    Sequential,
    /// DOALL: iterations are independent and may run concurrently.
    Parallel,
    /// DOACROSS pipeline parallelism (§3.3): iterations run concurrently
    /// but synchronize on the listed wait/release points.
    Doacross {
        waits: Vec<WaitSpec>,
        release: ReleaseSpec,
    },
}

/// "Iteration `var` must block before `before_stmt` until iteration
/// `var − delta·stride` has released" (§3.3.1's iteration vector, expressed
/// per loop — cross-loop components with δᵢ = 0 need no wait).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WaitSpec {
    pub before_stmt: StmtId,
    /// Dependence distance in iterations of this loop (δ from the solver).
    pub delta: i64,
}

/// Where a loop iteration signals completion of its dependency-resolving
/// writes (§3.3.2: after the post-dominating resolving access, or at the
/// end of the body if none post-dominates).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ReleaseSpec {
    AfterStmt(StmtId),
    EndOfBody,
}

/// A loop: the paper's four characterizing parameters plus the body.
///
/// Iteration semantics follow the C pattern
/// `for (var = start; cond; var += stride)` where `cond` is `var < end`
/// for ascending iteration and `var > end` for descending (the sign of the
/// evaluated stride decides; strides may themselves be symbolic and even
/// depend on `var` — e.g. Fig. 2's `i += i`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Loop {
    pub id: LoopId,
    pub var: Sym,
    pub start: Expr,
    pub end: Expr,
    pub stride: Expr,
    pub schedule: LoopSchedule,
    pub body: Vec<Node>,
}

/// A guarded single-assignment statement: `if guard != 0: D[f] := rhs`.
/// `rhs` is a compute expression whose `Load` leaves are the reads.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Stmt {
    pub id: StmtId,
    pub write: Access,
    pub rhs: Expr,
    pub guard: Option<Expr>,
}

impl Stmt {
    /// All reads performed by this statement (loads in rhs + guard).
    pub fn reads(&self) -> Vec<Access> {
        let mut out: Vec<Access> = self
            .rhs
            .loads()
            .into_iter()
            .map(|(c, off)| Access::read(c, off))
            .collect();
        if let Some(g) = &self.guard {
            out.extend(
                g.loads()
                    .into_iter()
                    .map(|(c, off)| Access::read(c, off)),
            );
        }
        out
    }

    /// Reads and the write, in evaluation order (reads first).
    pub fn accesses(&self) -> Vec<Access> {
        let mut out = self.reads();
        out.push(self.write.clone());
        out
    }
}

/// A node in the loop tree.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Node {
    Stmt(Stmt),
    Loop(Loop),
}

impl Node {
    pub fn as_loop(&self) -> Option<&Loop> {
        match self {
            Node::Loop(l) => Some(l),
            _ => None,
        }
    }

    pub fn as_stmt(&self) -> Option<&Stmt> {
        match self {
            Node::Stmt(s) => Some(s),
            _ => None,
        }
    }

    /// Visit every node in the subtree (pre-order).
    pub fn visit(&self, f: &mut impl FnMut(&Node)) {
        f(self);
        if let Node::Loop(l) = self {
            for c in &l.body {
                c.visit(f);
            }
        }
    }

    /// Mutable pre-order visit.
    pub fn visit_mut(&mut self, f: &mut impl FnMut(&mut Node)) {
        f(self);
        if let Node::Loop(l) = self {
            for c in &mut l.body {
                c.visit_mut(f);
            }
        }
    }

    /// All statements in the subtree, in program order.
    pub fn stmts(&self) -> Vec<&Stmt> {
        let mut out = Vec::new();
        self.collect_stmts(&mut out);
        out
    }

    fn collect_stmts<'a>(&'a self, out: &mut Vec<&'a Stmt>) {
        match self {
            Node::Stmt(s) => out.push(s),
            Node::Loop(l) => {
                for c in &l.body {
                    c.collect_stmts(out);
                }
            }
        }
    }

    /// All accesses (reads then write per statement) in the subtree.
    pub fn accesses(&self) -> Vec<Access> {
        self.stmts().iter().flat_map(|s| s.accesses()).collect()
    }

    /// Does the subtree write container `c`?
    pub fn writes_container(&self, c: ContainerId) -> bool {
        self.stmts().iter().any(|s| s.write.container == c)
    }

    /// Does the subtree read container `c`?
    pub fn reads_container(&self, c: ContainerId) -> bool {
        self.stmts()
            .iter()
            .any(|s| s.reads().iter().any(|a| a.container == c))
    }
}

impl Loop {
    /// Loop variables of this loop and all nested loops, outermost first.
    pub fn nest_vars(&self) -> Vec<Sym> {
        let mut out = vec![self.var];
        for n in &self.body {
            if let Node::Loop(l) = n {
                out.extend(l.nest_vars());
            }
        }
        out
    }

    /// Is the schedule parallel (DOALL or DOACROSS)?
    pub fn is_parallel(&self) -> bool {
        !matches!(self.schedule, LoopSchedule::Sequential)
    }

    /// Find a nested loop by id (including self).
    pub fn find_loop(&self, id: LoopId) -> Option<&Loop> {
        if self.id == id {
            return Some(self);
        }
        for n in &self.body {
            if let Node::Loop(l) = n {
                if let Some(found) = l.find_loop(id) {
                    return Some(found);
                }
            }
        }
        None
    }
}

/// All accesses performed by one statement to a given container, split by
/// kind. Convenience used throughout the analyses.
pub fn accesses_to(stmt: &Stmt, c: ContainerId, kind: AccessKind) -> Vec<Expr> {
    match kind {
        AccessKind::Write => {
            if stmt.write.container == c {
                vec![stmt.write.offset.clone()]
            } else {
                vec![]
            }
        }
        AccessKind::Read => stmt
            .reads()
            .into_iter()
            .filter(|a| a.container == c)
            .map(|a| a.offset)
            .collect(),
    }
}
