//! Whole-program container: declarations, the loop tree, and the memory
//! schedule set (kept separate from the tree per the paper's §4 design —
//! "a memory schedule … does not directly modify the IR").

use std::collections::HashMap;

use crate::symbolic::{ContainerId, Expr, Sym};

use super::container::{Container, ContainerKind, DType};
use super::nest::{Loop, LoopId, Node, Stmt, StmtId};

/// A software-prefetch hint (§4.1): before each iteration of `at_loop`,
/// prefetch `container[offset]` (offset already shifted by the loop stride
/// so it targets the *next* iteration's first access).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PrefetchHint {
    pub at_loop: LoopId,
    pub container: ContainerId,
    pub offset: Expr,
    /// Prepare for write (true) or read (false) — the second argument of
    /// `__builtin_prefetch`.
    pub for_write: bool,
}

/// Memory schedules attached to accesses (realized at lowering).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ScheduleSet {
    /// `(stmt, container)` pairs whose accesses use pointer incrementation
    /// (§4.2). All accesses to that container in that statement share the
    /// cursor (constant-offset reuse, §4.2.3).
    pub ptr_inc: Vec<(StmtId, ContainerId)>,
    /// Software prefetch hints (§4.1).
    pub prefetches: Vec<PrefetchHint>,
}

impl ScheduleSet {
    pub fn has_ptr_inc(&self, s: StmtId, c: ContainerId) -> bool {
        self.ptr_inc.contains(&(s, c))
    }
}

/// A complete loop program.
///
/// Structural equality (`PartialEq`) compares declarations, the loop tree,
/// and the schedule set — the property the SILO-Text round-trip tests pin
/// (`parse(print(p)) == p`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Program {
    pub name: String,
    /// Symbolic parameters (sizes, strides) that must be bound at run time.
    pub params: Vec<Sym>,
    /// Parameters registered as array *dimension extents* (row strides of
    /// multidimensional arrays). The affinity classifier treats
    /// `var·extent` products as multidim-affine — what C's `A[k][j][i]`
    /// notation gives polyhedral tools (§6.1's "compatible
    /// multidimensional array notation").
    pub dim_syms: Vec<Sym>,
    pub containers: Vec<Container>,
    pub body: Vec<Node>,
    pub schedules: ScheduleSet,
    next_loop: u32,
    next_stmt: u32,
    next_container: u32,
}

impl Program {
    pub fn new(name: &str) -> Program {
        Program {
            name: name.to_string(),
            params: Vec::new(),
            dim_syms: Vec::new(),
            containers: Vec::new(),
            body: Vec::new(),
            schedules: ScheduleSet::default(),
            next_loop: 0,
            next_stmt: 0,
            next_container: 0,
        }
    }

    pub fn add_container(
        &mut self,
        name: &str,
        size: Expr,
        dtype: DType,
        kind: ContainerKind,
    ) -> ContainerId {
        let id = ContainerId(self.next_container);
        self.next_container += 1;
        self.containers.push(Container {
            id,
            name: name.to_string(),
            size,
            dtype,
            kind,
            base: 0,
        });
        id
    }

    pub fn container(&self, id: ContainerId) -> &Container {
        &self.containers[id.0 as usize]
    }

    pub fn container_mut(&mut self, id: ContainerId) -> &mut Container {
        &mut self.containers[id.0 as usize]
    }

    pub fn fresh_loop_id(&mut self) -> LoopId {
        let id = LoopId(self.next_loop);
        self.next_loop += 1;
        id
    }

    pub fn fresh_stmt_id(&mut self) -> StmtId {
        let id = StmtId(self.next_stmt);
        self.next_stmt += 1;
        id
    }

    /// Raise the id allocators so subsequently created loops/statements do
    /// not collide with explicitly numbered ones (the textual frontend can
    /// carry `L<n>:`/`s<n>:` labels).
    pub fn reserve_ids(&mut self, next_loop: u32, next_stmt: u32) {
        self.next_loop = self.next_loop.max(next_loop);
        self.next_stmt = self.next_stmt.max(next_stmt);
    }

    /// Visit every node (pre-order across the top-level sequence).
    pub fn visit(&self, f: &mut impl FnMut(&Node)) {
        for n in &self.body {
            n.visit(f);
        }
    }

    pub fn visit_mut(&mut self, f: &mut impl FnMut(&mut Node)) {
        for n in &mut self.body {
            n.visit_mut(f);
        }
    }

    /// All loops, outermost-first pre-order.
    pub fn loops(&self) -> Vec<&Loop> {
        let mut out = Vec::new();
        // visit takes a closure that can't easily capture lifetimes; do it
        // manually instead.
        fn collect<'a>(nodes: &'a [Node], out: &mut Vec<&'a Loop>) {
            for n in nodes {
                if let Node::Loop(l) = n {
                    out.push(l);
                    collect(&l.body, out);
                }
            }
        }
        collect(&self.body, &mut out);
        out
    }

    /// All statements in program order.
    pub fn stmts(&self) -> Vec<&Stmt> {
        let mut out = Vec::new();
        for n in &self.body {
            out.extend(n.stmts());
        }
        out
    }

    pub fn find_loop(&self, id: LoopId) -> Option<&Loop> {
        self.loops().into_iter().find(|l| l.id == id)
    }

    pub fn find_stmt(&self, id: StmtId) -> Option<&Stmt> {
        self.stmts().into_iter().find(|s| s.id == id)
    }

    /// Map loop-id → chain of enclosing loop ids (outermost first,
    /// excluding the loop itself).
    pub fn loop_parents(&self) -> HashMap<LoopId, Vec<LoopId>> {
        let mut out = HashMap::new();
        fn walk(nodes: &[Node], chain: &mut Vec<LoopId>, out: &mut HashMap<LoopId, Vec<LoopId>>) {
            for n in nodes {
                if let Node::Loop(l) = n {
                    out.insert(l.id, chain.clone());
                    chain.push(l.id);
                    walk(&l.body, chain, out);
                    chain.pop();
                }
            }
        }
        walk(&self.body, &mut Vec::new(), &mut out);
        out
    }

    /// Map stmt-id → chain of enclosing loop ids (outermost first).
    pub fn stmt_parents(&self) -> HashMap<StmtId, Vec<LoopId>> {
        let mut out = HashMap::new();
        fn walk(nodes: &[Node], chain: &mut Vec<LoopId>, out: &mut HashMap<StmtId, Vec<LoopId>>) {
            for n in nodes {
                match n {
                    Node::Stmt(s) => {
                        out.insert(s.id, chain.clone());
                    }
                    Node::Loop(l) => {
                        chain.push(l.id);
                        walk(&l.body, chain, out);
                        chain.pop();
                    }
                }
            }
        }
        walk(&self.body, &mut Vec::new(), &mut out);
        out
    }

    /// Resolve a container by name (test/debug convenience).
    pub fn container_by_name(&self, name: &str) -> Option<ContainerId> {
        self.containers
            .iter()
            .find(|c| c.name == name)
            .map(|c| c.id)
    }

    /// Assign flat-heap base addresses to all containers given concrete
    /// parameter bindings. Returns total heap size in elements.
    pub fn assign_bases(&mut self, env: &dyn crate::symbolic::eval::Env) -> anyhow::Result<u64> {
        let mut base = 0u64;
        for c in &mut self.containers {
            c.base = base;
            let n = crate::symbolic::eval::eval_int(&c.size, env)? as u64;
            // 64-byte align each container so the cache model sees
            // realistic line boundaries.
            base += n.div_ceil(8) * 8;
        }
        Ok(base)
    }
}
