//! Data containers: arrays, transients and scalars operated on by loops.

use crate::symbolic::{ContainerId, Expr};

/// Element type of a container. The VM stores everything as f64 lanes; the
/// dtype controls rounding on store (f32 simulation) and element size for
/// the cache model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F64,
    F32,
    I64,
}

impl DType {
    pub fn bytes(self) -> u64 {
        match self {
            DType::F64 | DType::I64 => 8,
            DType::F32 => 4,
        }
    }
}

/// Lifetime/visibility class of a container.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ContainerKind {
    /// Program input/output — externally visible by definition.
    Argument,
    /// Allocated inside the program; visibility is determined by dataflow
    /// analysis (paper §3.1).
    Transient,
    /// Scalar register value produced by privatization (§3.2.1). Never
    /// externally visible; one live instance per loop iteration.
    Register,
}

/// A data container declaration.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Container {
    pub id: ContainerId,
    pub name: String,
    /// Total number of elements (symbolic expressions allowed; scalars = 1).
    pub size: Expr,
    pub dtype: DType,
    pub kind: ContainerKind,
    /// Base address in the simulated flat heap (filled by the lowering; the
    /// cache model needs distinct address ranges per container).
    pub base: u64,
}

impl Container {
    pub fn is_scalar(&self) -> bool {
        matches!(self.size, Expr::Int(1))
    }
}
