//! Data accesses: `container[offset]` pairs with read/write direction.

use crate::symbolic::{ContainerId, Expr};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessKind {
    Read,
    Write,
}

/// A single data access — the unit the paper's analyses reason about
/// (§2.1: "each read and write is represented by the name of a data
/// container D and a symbolic expression f … denoted `D[f]`").
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Access {
    pub container: ContainerId,
    pub offset: Expr,
    pub kind: AccessKind,
}

impl Access {
    pub fn read(container: ContainerId, offset: Expr) -> Access {
        Access {
            container,
            offset,
            kind: AccessKind::Read,
        }
    }

    pub fn write(container: ContainerId, offset: Expr) -> Access {
        Access {
            container,
            offset,
            kind: AccessKind::Write,
        }
    }
}
