//! Canonical printer for loop programs.
//!
//! The output is valid SILO-Text: `frontend::parse_str(pretty(p))`
//! reconstructs `p` exactly (ids included — loops and statements print
//! `L<n>:`/`s<n>:` labels the parser honors). Schedule information that
//! lives outside the grammar (DOALL/DOACROSS annotations, memory
//! schedules) prints as `//` comments, which the lexer skips.
//!
//! The identity is on [`Program`]: preset bindings and `init(...)`
//! annotations belong to `frontend::ParsedKernel`, not the IR, so a
//! printed file needs presets re-added before `silo run` can bind its
//! params (the runtime error names the param and the exact syntax).

use std::fmt::Write;

use super::container::DType;
use super::nest::{LoopSchedule, Node, ReleaseSpec};
use super::program::Program;

/// Render the full program as parseable SILO-Text with schedule comments.
pub fn pretty(p: &Program) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "program {} {{", p.name);
    for s in &p.params {
        if p.dim_syms.contains(s) {
            let _ = writeln!(out, "  param {}: dim;", s.name());
        } else {
            let _ = writeln!(out, "  param {};", s.name());
        }
    }
    for c in &p.containers {
        let kind = match c.kind {
            super::container::ContainerKind::Argument => "array",
            super::container::ContainerKind::Transient => "transient",
            super::container::ContainerKind::Register => "register",
        };
        let dtype = match c.dtype {
            DType::F64 => "",
            DType::F32 => ": f32",
            DType::I64 => ": i64",
        };
        let _ = writeln!(
            out,
            "  {kind} \"{}\"[{}]{dtype};",
            c.name,
            render_expr(p, &c.size)
        );
    }
    for n in &p.body {
        write_node(&mut out, p, n, 1);
    }
    if !p.schedules.ptr_inc.is_empty() {
        let _ = writeln!(out, "  // memory schedules:");
        for (s, c) in &p.schedules.ptr_inc {
            let _ = writeln!(
                out,
                "  //   ptr-inc on stmt s{} container \"{}\"",
                s.0,
                p.container(*c).name
            );
        }
    }
    for pf in &p.schedules.prefetches {
        let _ = writeln!(
            out,
            "  //   prefetch \"{}\"[{}] ({}) at loop L{}",
            p.container(pf.container).name,
            pf.offset,
            if pf.for_write { "write" } else { "read" },
            pf.at_loop.0
        );
    }
    out.push_str("}\n");
    out
}

fn write_node(out: &mut String, p: &Program, n: &Node, depth: usize) {
    let pad = "  ".repeat(depth);
    match n {
        Node::Stmt(s) => {
            let guard = s
                .guard
                .as_ref()
                .map(|g| format!("if ({}) ", render_expr(p, g)))
                .unwrap_or_default();
            let _ = writeln!(
                out,
                "{pad}{guard}s{}: \"{}\"[{}] = {};",
                s.id.0,
                p.container(s.write.container).name,
                render_expr(p, &s.write.offset),
                render_expr(p, &s.rhs)
            );
        }
        Node::Loop(l) => {
            let sched = match &l.schedule {
                LoopSchedule::Sequential => String::new(),
                LoopSchedule::Parallel => " // parallel (DOALL)".to_string(),
                LoopSchedule::Doacross { waits, release } => {
                    let w: Vec<String> = waits
                        .iter()
                        .map(|w| format!("wait(s{}, δ={})", w.before_stmt.0, w.delta))
                        .collect();
                    let r = match release {
                        ReleaseSpec::AfterStmt(s) => format!("release after s{}", s.0),
                        ReleaseSpec::EndOfBody => "release at end".to_string(),
                    };
                    format!(" // DOACROSS [{} | {}]", w.join(", "), r)
                }
            };
            // `<>`: iteration direction decided by the stride's run-time
            // sign (`<` ascending, `>` descending) — the parser accepts
            // either comparator spelling for the same IR.
            let _ = writeln!(
                out,
                "{pad}L{}: for ({} = {}; {} <> {}; {} += {}) {{{}",
                l.id.0,
                l.var.name(),
                render_expr(p, &l.start),
                l.var.name(),
                render_expr(p, &l.end),
                l.var.name(),
                render_expr(p, &l.stride),
                sched
            );
            for c in &l.body {
                write_node(out, p, c, depth + 1);
            }
            let _ = writeln!(out, "{pad}}}");
        }
    }
}

/// Render an expression, replacing `%id[...]` loads with quoted container
/// names (the parser resolves them back to the same ids, since containers
/// print in declaration order).
fn render_expr(p: &Program, e: &crate::symbolic::Expr) -> String {
    let mut s = e.to_string();
    if !s.contains('%') {
        return s;
    }
    // Longest ids first so %12 is not clobbered by %1.
    let mut ids: Vec<_> = p.containers.iter().collect();
    ids.sort_by_key(|c| std::cmp::Reverse(c.id.0));
    for c in ids {
        s = s.replace(&format!("%{}", c.id.0), &format!("\"{}\"", c.name));
    }
    s
}

#[cfg(test)]
mod tests {
    use crate::ir::ProgramBuilder;
    use crate::symbolic::{int, load, Expr};

    #[test]
    fn pretty_renders_structure() {
        let mut b = ProgramBuilder::new("pp");
        let n = b.param_positive("pp_N");
        let a = b.array("A", Expr::Sym(n));
        let i = b.sym("pp_i");
        b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
            b.assign(a, Expr::Sym(i), load(a, Expr::Sym(i)) + Expr::real(1.0));
        });
        let p = b.finish();
        let s = super::pretty(&p);
        assert!(s.contains("for (pp_i = 0"), "{s}");
        assert!(s.contains("\"A\""), "{s}");
        assert!(s.contains("param pp_N;"), "{s}");
        assert!(s.contains("array \"A\"[pp_N];"), "{s}");
    }

    #[test]
    fn guards_and_dims_render_parseably() {
        let mut b = ProgramBuilder::new("pp2");
        let n = b.dim_param("pp2_N");
        let a = b.array("A", Expr::Sym(n));
        let i = b.sym("pp2_i");
        b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
            b.assign_if(Expr::Sym(i), a, Expr::Sym(i), load(a, Expr::Sym(i)));
        });
        let p = b.finish();
        let s = super::pretty(&p);
        assert!(s.contains("param pp2_N: dim;"), "{s}");
        assert!(s.contains("if (pp2_i) s0:"), "{s}");
        // Guard loads render with container names, not raw %ids.
        assert!(!s.contains('%'), "{s}");
    }
}
