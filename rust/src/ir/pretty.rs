//! Pretty-printer for loop programs (CLI/report output and debugging).

use std::fmt::Write;

use super::nest::{LoopSchedule, Node, ReleaseSpec};
use super::program::Program;

/// Render the full program as pseudo-C with schedule annotations.
pub fn pretty(p: &Program) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "program {} {{", p.name);
    if !p.params.is_empty() {
        let names: Vec<String> = p.params.iter().map(|s| s.name()).collect();
        let _ = writeln!(out, "  params: {}", names.join(", "));
    }
    for c in &p.containers {
        let kind = match c.kind {
            super::container::ContainerKind::Argument => "arg",
            super::container::ContainerKind::Transient => "transient",
            super::container::ContainerKind::Register => "register",
        };
        let _ = writeln!(out, "  {} %{} \"{}\"[{}]", kind, c.id.0, c.name, c.size);
    }
    for n in &p.body {
        write_node(&mut out, p, n, 1);
    }
    if !p.schedules.ptr_inc.is_empty() {
        let _ = writeln!(out, "  // memory schedules:");
        for (s, c) in &p.schedules.ptr_inc {
            let _ = writeln!(
                out,
                "  //   ptr-inc on stmt s{} container \"{}\"",
                s.0,
                p.container(*c).name
            );
        }
    }
    for pf in &p.schedules.prefetches {
        let _ = writeln!(
            out,
            "  //   prefetch \"{}\"[{}] ({}) at loop L{}",
            p.container(pf.container).name,
            pf.offset,
            if pf.for_write { "write" } else { "read" },
            pf.at_loop.0
        );
    }
    out.push_str("}\n");
    out
}

fn write_node(out: &mut String, p: &Program, n: &Node, depth: usize) {
    let pad = "  ".repeat(depth);
    match n {
        Node::Stmt(s) => {
            let guard = s
                .guard
                .as_ref()
                .map(|g| format!("if ({g}) "))
                .unwrap_or_default();
            let _ = writeln!(
                out,
                "{pad}{guard}s{}: \"{}\"[{}] = {};",
                s.id.0,
                p.container(s.write.container).name,
                s.write.offset,
                render_rhs(p, &s.rhs)
            );
        }
        Node::Loop(l) => {
            let sched = match &l.schedule {
                LoopSchedule::Sequential => String::new(),
                LoopSchedule::Parallel => " // parallel (DOALL)".to_string(),
                LoopSchedule::Doacross { waits, release } => {
                    let w: Vec<String> = waits
                        .iter()
                        .map(|w| format!("wait(s{}, δ={})", w.before_stmt.0, w.delta))
                        .collect();
                    let r = match release {
                        ReleaseSpec::AfterStmt(s) => format!("release after s{}", s.0),
                        ReleaseSpec::EndOfBody => "release at end".to_string(),
                    };
                    format!(" // DOACROSS [{} | {}]", w.join(", "), r)
                }
            };
            let _ = writeln!(
                out,
                "{pad}L{}: for ({} = {}; {} <> {}; {} += {}) {{{}",
                l.id.0,
                l.var.name(),
                l.start,
                l.var.name(),
                l.end,
                l.var.name(),
                l.stride,
                sched
            );
            for c in &l.body {
                write_node(out, p, c, depth + 1);
            }
            let _ = writeln!(out, "{pad}}}");
        }
    }
}

/// Render an rhs, replacing `%id[...]` loads with container names.
fn render_rhs(p: &Program, e: &crate::symbolic::Expr) -> String {
    use crate::symbolic::Expr;
    let renamed = e.map(&|x| x.clone());
    // Simple textual pass: render, then replace %N with names.
    let mut s = format!("{renamed}");
    // Longest ids first so %12 is not clobbered by %1.
    let mut ids: Vec<_> = p.containers.iter().collect();
    ids.sort_by_key(|c| std::cmp::Reverse(c.id.0));
    for c in ids {
        s = s.replace(&format!("%{}", c.id.0), &format!("\"{}\"", c.name));
    }
    let _ = Expr::Int(0); // keep import used
    s
}

#[cfg(test)]
mod tests {
    use crate::ir::ProgramBuilder;
    use crate::symbolic::{int, load, Expr};

    #[test]
    fn pretty_renders_structure() {
        let mut b = ProgramBuilder::new("pp");
        let n = b.param_positive("pp_N");
        let a = b.array("A", Expr::Sym(n));
        let i = b.sym("pp_i");
        b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
            b.assign(a, Expr::Sym(i), load(a, Expr::Sym(i)) + Expr::real(1.0));
        });
        let p = b.finish();
        let s = super::pretty(&p);
        assert!(s.contains("for (pp_i = 0"), "{s}");
        assert!(s.contains("\"A\""), "{s}");
    }
}
