//! SILO's inductive loop analyses (paper §3.1–§3.3.1).
//!
//! * [`visibility`] — consumer/producer analysis: externally visible reads
//!   and writes per iteration and propagated over whole loops.
//! * [`deps`] — the δ-solver-based RAW/WAR/WAW dependence tests.
//! * [`affine`] — SCoP classifier encoding the polyhedral baselines'
//!   restrictions (what Polly/Pluto refuse to touch).
//! * [`propagate`] — concrete interval propagation for conflict checks and
//!   cross-validation against enumeration.
//! * [`cache`] — per-loop memoization of the above with version-counted
//!   invalidation, shared by every pass in a [`crate::transforms::Pipeline`].

pub mod affine;
pub mod cache;
pub mod deps;
pub mod propagate;
pub mod visibility;

pub use affine::{classify_nest, classify_program, is_affine_in, AffineViolation, AffinityReport};
pub use cache::{AnalysisCache, CacheStats};
pub use deps::{loop_deps, provably_independent, sync_points, Dep, DepDistance, DepKind, DepReport};
pub use propagate::{access_interval, iteration_count, Interval};
pub use visibility::{
    body_graph, iter_visibility, loop_summary, IterVisibility, LoopRange, PropAccess, SummaryMemo,
    SummaryPair,
};
