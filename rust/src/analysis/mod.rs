//! SILO's inductive loop analyses (paper §3.1–§3.3.1).
//!
//! * [`visibility`] — consumer/producer analysis: externally visible reads
//!   and writes per iteration and propagated over whole loops.
//! * [`deps`] — the δ-solver-based RAW/WAR/WAW dependence tests.
//! * [`affine`] — SCoP classifier encoding the polyhedral baselines'
//!   restrictions (what Polly/Pluto refuse to touch).
//! * [`propagate`] — concrete interval propagation for conflict checks and
//!   cross-validation against enumeration.

pub mod affine;
pub mod deps;
pub mod propagate;
pub mod visibility;

pub use affine::{classify_nest, classify_program, is_affine_in, AffineViolation, AffinityReport};
pub use deps::{loop_deps, provably_independent, sync_points, Dep, DepDistance, DepKind, DepReport};
pub use propagate::{access_interval, iteration_count, Interval};
pub use visibility::{body_graph, iter_visibility, loop_summary, IterVisibility, LoopRange, PropAccess};
