//! Loop-carried dependence analysis via the inductive δ-test
//! (paper §3.2.2 and §3.3.1).
//!
//! For a loop `L` with externally visible per-iteration reads `D[f]` and
//! writes `D[g]`, a dependence across iterations exists when
//! `∃ δ > 0 : f(var) = g(var ± δ·stride)`:
//! * RAW (loop-carried): read at `var` sees a write from iteration
//!   `var − δ·stride` (shift [`ShiftDir::Earlier`]).
//! * WAR (input): read at `var` is overwritten by iteration
//!   `var + δ·stride` (shift [`ShiftDir::Later`]).
//! * WAW (output): two writes collide across iterations.

use crate::ir::{Container, Loop, StmtId};
use crate::symbolic::{solve_delta, ContainerId, DeltaSolution, Expr, ShiftDir, Truth};

use super::visibility::{iter_visibility_memo, SummaryMemo};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DepKind {
    Raw,
    War,
    Waw,
}

/// How certain / resolvable the dependence is.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DepDistance {
    /// Exact constant iteration distance δ.
    Constant(i64),
    /// Symbolic δ provably positive.
    Symbolic(Expr),
    /// The solver could not decide — conservatively assume dependent at
    /// unknown distance (paper's over-approximation).
    Unknown,
    /// The accesses collide at *every* iteration (loop-invariant offsets) —
    /// e.g. a scalar accumulated across iterations.
    AllIterations,
}

/// One loop-carried dependence on `container`, from the statement that
/// writes (`writer`) to the statement that reads/writes (`sink`).
#[derive(Debug, Clone)]
pub struct Dep {
    pub kind: DepKind,
    pub container: ContainerId,
    pub writer: StmtId,
    pub sink: StmtId,
    pub distance: DepDistance,
}

/// Full dependence report for one loop level.
#[derive(Debug, Clone, Default)]
pub struct DepReport {
    pub deps: Vec<Dep>,
}

impl DepReport {
    pub fn of_kind(&self, k: DepKind) -> impl Iterator<Item = &Dep> {
        self.deps.iter().filter(move |d| d.kind == k)
    }

    pub fn has(&self, k: DepKind) -> bool {
        self.of_kind(k).next().is_some()
    }

    /// DOALL-parallelizable: no loop-carried dependence of any kind.
    pub fn is_doall(&self) -> bool {
        self.deps.is_empty()
    }

    /// Containers involved in dependencies of kind `k`.
    pub fn containers(&self, k: DepKind) -> Vec<ContainerId> {
        let mut out = Vec::new();
        for d in self.of_kind(k) {
            if !out.contains(&d.container) {
                out.push(d.container);
            }
        }
        out
    }
}

/// Interpret a solver verdict as an iteration-distance classification.
/// `None` means "no dependence".
///
/// Range feasibility: a positive δ only denotes a real dependence when the
/// source iteration `var ∓ δ·stride` can lie inside the loop's range —
/// if `δ·|stride| ≥ end − start` is provable, the "colliding" iteration is
/// outside the loop and the accesses never actually conflict (e.g. the
/// i-loop of a k-recurrence reading row k−1: δ = N ≥ trip count).
fn classify(sol: DeltaSolution, l: &Loop) -> Option<DepDistance> {
    match sol {
        DeltaSolution::NoSolution => None,
        DeltaSolution::AlwaysEqual => Some(DepDistance::AllIterations),
        DeltaSolution::Unsolvable => Some(DepDistance::Unknown),
        DeltaSolution::Unique { delta, positive } => match positive {
            Truth::Yes => {
                if delta_out_of_range(&delta, l) {
                    return None;
                }
                match delta.as_int() {
                    Some(v) => Some(DepDistance::Constant(v)),
                    None => Some(DepDistance::Symbolic(delta)),
                }
            }
            // δ exists but is provably non-positive ⇒ this direction of the
            // test carries no dependence (the opposite direction finds it).
            Truth::No => None,
            // Can't prove sign ⇒ conservative.
            Truth::Unknown => Some(DepDistance::Unknown),
        },
    }
}

/// Is `δ·|stride| ≥ span` provable (iteration distance exceeds the loop's
/// extent)? Sound: `false` when unknown.
fn delta_out_of_range(delta: &Expr, l: &Loop) -> bool {
    use crate::symbolic::{is_nonneg, is_positive};
    let (dist, span) = if is_positive(&l.stride) == Truth::Yes {
        (
            delta.clone() * l.stride.clone(),
            l.end.clone() - l.start.clone(),
        )
    } else if is_positive(&(-l.stride.clone())) == Truth::Yes {
        (
            delta.clone() * (-l.stride.clone()),
            l.start.clone() - l.end.clone(),
        )
    } else {
        return false; // stride sign unknown: stay conservative
    };
    is_nonneg(&(dist - span)) == Truth::Yes
}

/// True when accesses `f` and `g` on the same container provably never
/// alias at any iteration pair of `l` (δ > 0 in both directions is
/// infeasible and the δ = 0 offsets provably differ). Used by fusion
/// legality: a read of a cross-plane value (`cp[k−1]` vs the write
/// `cp[k]`) is disjoint, not a fusion blocker.
pub fn provably_independent(f: &Expr, g: &Expr, l: &Loop) -> bool {
    use crate::symbolic::{is_zero, poly_diff};
    for dir in [ShiftDir::Earlier, ShiftDir::Later] {
        let sol = solve_delta(f, g, l.var, &l.stride, dir);
        if classify(sol, l).is_some() {
            return false;
        }
    }
    match poly_diff(f, g) {
        Some(d) => !d.is_zero() && is_zero(&d.to_expr()) == Truth::No,
        None => false,
    }
}

/// Analyze the loop-carried dependencies of `l` (w.r.t. `l.var` only; inner
/// loops are summarized by the visibility analysis).
pub fn loop_deps(l: &Loop, containers: &[Container]) -> DepReport {
    loop_deps_memo(l, containers, &mut SummaryMemo::disabled())
}

/// [`loop_deps`] with nested-loop summaries served from `memo` (see
/// [`crate::analysis::AnalysisCache`]).
pub fn loop_deps_memo(l: &Loop, containers: &[Container], memo: &mut SummaryMemo) -> DepReport {
    let vis = iter_visibility_memo(l, containers, memo);
    let mut report = DepReport::default();

    // RAW: read f vs writes g from earlier iterations.
    for (rs, read) in &vis.reads {
        for (ws, write) in &vis.writes {
            if read.container != write.container {
                continue;
            }
            let sol = solve_delta(
                &read.offset,
                &write.offset,
                l.var,
                &l.stride,
                ShiftDir::Earlier,
            );
            if let Some(distance) = classify(sol, l) {
                report.deps.push(Dep {
                    kind: DepKind::Raw,
                    container: read.container,
                    writer: *ws,
                    sink: *rs,
                    distance,
                });
            }
        }
    }

    // WAR: read f vs writes g from later iterations.
    for (rs, read) in &vis.reads {
        for (ws, write) in &vis.writes {
            if read.container != write.container {
                continue;
            }
            let sol = solve_delta(
                &read.offset,
                &write.offset,
                l.var,
                &l.stride,
                ShiftDir::Later,
            );
            if let Some(distance) = classify(sol, l) {
                // AllIterations RAW and WAR coincide for loop-invariant
                // offsets; report both (transforms handle them jointly).
                report.deps.push(Dep {
                    kind: DepKind::War,
                    container: read.container,
                    writer: *ws,
                    sink: *rs,
                    distance,
                });
            }
        }
    }

    // WAW: write pairs across iterations.
    for (ws1, w1) in &vis.writes {
        for (ws2, w2) in &vis.writes {
            if w1.container != w2.container {
                continue;
            }
            let sol = solve_delta(&w1.offset, &w2.offset, l.var, &l.stride, ShiftDir::Earlier);
            if let Some(distance) = classify(sol, l) {
                // Deduplicate the symmetric pair: keep writer ≤ sink.
                if ws1.0 <= ws2.0 {
                    report.deps.push(Dep {
                        kind: DepKind::Waw,
                        container: w1.container,
                        writer: *ws2,
                        sink: *ws1,
                        distance,
                    });
                }
            }
        }
    }

    // Deduplicate identical entries (multiple reads of the same offset).
    report.deps.dedup_by(|a, b| {
        a.kind == b.kind
            && a.container == b.container
            && a.writer == b.writer
            && a.sink == b.sink
            && a.distance == b.distance
    });
    report
}

/// Synchronization points for DOACROSS parallelization (§3.3.1): for each
/// externally visible read with a RAW dependence at constant δ, the sink
/// statement must wait for iteration `var − δ·stride` to pass the writer.
/// Returns `None` if any dependence is not expressible as a constant δ
/// (the paper then skips pipelining).
pub fn sync_points(l: &Loop, containers: &[Container]) -> Option<Vec<(StmtId, StmtId, i64)>> {
    let report = loop_deps(l, containers);
    let mut out = Vec::new();
    for d in &report.deps {
        match d.kind {
            DepKind::Raw => match &d.distance {
                DepDistance::Constant(delta) if *delta > 0 => {
                    out.push((d.sink, d.writer, *delta));
                }
                _ => return None,
            },
            // WAR/WAW must have been resolved before pipelining (§3.3:
            // "if any data access exhibits one of the other types of
            // dependencies and that dependency cannot be resolved, no
            // parallelization is possible with this strategy").
            DepKind::War | DepKind::Waw => return None,
        }
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::ProgramBuilder;
    use crate::symbolic::{int, load, Expr};

    /// `for i in 1..N: A[i] = A[i-1] + B[i]` — classic RAW δ=1.
    #[test]
    fn raw_distance_one() {
        let mut b = ProgramBuilder::new("dep1");
        let n = b.param_positive("dep1_N");
        let a = b.array("A", Expr::Sym(n));
        let bb = b.array("B", Expr::Sym(n));
        let i = b.sym("dep1_i");
        b.for_(i, int(1), Expr::Sym(n), int(1), |b| {
            b.assign(
                a,
                Expr::Sym(i),
                load(a, Expr::Sym(i) - int(1)) + load(bb, Expr::Sym(i)),
            );
        });
        let p = b.finish();
        let l = p.loops()[0];
        let r = loop_deps(l, &p.containers);
        assert!(r.has(DepKind::Raw));
        let raw: Vec<_> = r.of_kind(DepKind::Raw).collect();
        assert_eq!(raw.len(), 1);
        assert_eq!(raw[0].distance, DepDistance::Constant(1));
        // The A[i] write vs A[i-1] read is also a WAR in the Later
        // direction? f = i-1, g(i+δ) = i+δ ⇒ δ = -1 < 0 ⇒ no WAR.
        assert!(!r.has(DepKind::War));
        // WAW: A written at i vs i ± δ ⇒ δ=0 only ⇒ none.
        assert!(!r.has(DepKind::Waw));
        // Sync points exist for DOACROSS.
        let sp = sync_points(l, &p.containers).unwrap();
        assert_eq!(sp.len(), 1);
        assert_eq!(sp[0].2, 1);
    }

    /// `for i: A[i] = B[i] * 2` — no deps, DOALL.
    #[test]
    fn independent_loop_is_doall() {
        let mut b = ProgramBuilder::new("dep2");
        let n = b.param_positive("dep2_N");
        let a = b.array("A", Expr::Sym(n));
        let bb = b.array("B", Expr::Sym(n));
        let i = b.sym("dep2_i");
        b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
            b.assign(a, Expr::Sym(i), load(bb, Expr::Sym(i)) * Expr::real(2.0));
        });
        let p = b.finish();
        let r = loop_deps(p.loops()[0], &p.containers);
        assert!(r.is_doall(), "{:?}", r.deps);
    }

    /// `for i: B[i] = C[i+1]; C[i] = ...` — WAR (input) dependence δ=1.
    #[test]
    fn war_detected() {
        let mut b = ProgramBuilder::new("dep3");
        let n = b.param_positive("dep3_N");
        let bb = b.array("B", Expr::Sym(n) + int(1));
        let cc = b.array("C", Expr::Sym(n) + int(1));
        let i = b.sym("dep3_i");
        b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
            b.assign(bb, Expr::Sym(i), load(cc, Expr::Sym(i) + int(1)));
            b.assign(cc, Expr::Sym(i), Expr::real(0.0));
        });
        let p = b.finish();
        let r = loop_deps(p.loops()[0], &p.containers);
        assert!(r.has(DepKind::War));
        let war: Vec<_> = r.of_kind(DepKind::War).collect();
        assert_eq!(war[0].distance, DepDistance::Constant(1));
        assert!(!r.has(DepKind::Raw));
    }

    /// Scalar accumulator: `for i: s[0] = s[0] + A[i]` — RAW/WAR/WAW at all
    /// distances (AllIterations).
    #[test]
    fn scalar_accumulation_all_iterations() {
        let mut b = ProgramBuilder::new("dep4");
        let n = b.param_positive("dep4_N");
        let a = b.array("A", Expr::Sym(n));
        let s = b.scalar("s");
        let i = b.sym("dep4_i");
        b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
            b.assign(s, int(0), load(s, int(0)) + load(a, Expr::Sym(i)));
        });
        let p = b.finish();
        let r = loop_deps(p.loops()[0], &p.containers);
        assert!(r
            .of_kind(DepKind::Waw)
            .any(|d| d.distance == DepDistance::AllIterations));
        assert!(r
            .of_kind(DepKind::Raw)
            .any(|d| d.distance == DepDistance::AllIterations));
        assert!(sync_points(p.loops()[0], &p.containers).is_none());
    }

    /// Parametric stride: `A[i*S] = A[(i-2)*S] + 1` with positive S —
    /// δ = 2 despite the symbolic coefficient.
    #[test]
    fn parametric_stride_raw() {
        let mut b = ProgramBuilder::new("dep5");
        let n = b.param_positive("dep5_N");
        let s = b.param_positive("dep5_S");
        let a = b.array("A", Expr::Sym(n) * Expr::Sym(s));
        let i = b.sym("dep5_i");
        b.for_(i, int(2), Expr::Sym(n), int(1), |b| {
            b.assign(
                a,
                Expr::Sym(i) * Expr::Sym(s),
                load(a, (Expr::Sym(i) - int(2)) * Expr::Sym(s)) + Expr::real(1.0),
            );
        });
        let p = b.finish();
        let r = loop_deps(p.loops()[0], &p.containers);
        let raw: Vec<_> = r.of_kind(DepKind::Raw).collect();
        assert_eq!(raw.len(), 1);
        assert_eq!(raw[0].distance, DepDistance::Constant(2));
    }

    /// Triangular inner loop (Fig. 2 right): stride of inner loop depends
    /// on the outer variable — still analyzable w.r.t. the *inner* loop.
    #[test]
    fn fig2_triangular_inner_analyzable() {
        let mut b = ProgramBuilder::new("dep6");
        let n = b.param_positive("dep6_N");
        let a = b.array("A", Expr::Sym(n) + int(1));
        let i = b.sym("dep6_i");
        let j = b.sym("dep6_j");
        b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
            b.for_(j, Expr::Sym(i), Expr::Sym(n), Expr::Sym(i) + int(1), |b| {
                b.assign(a, Expr::Sym(j), Expr::real(0.0));
            });
        });
        let p = b.finish();
        let inner = p.loops()[1];
        // Writes a[j] with stride (i+1): g(j) - g(j - δ(i+1)) = δ(i+1) ≠ 0
        // for δ>0 under positivity of... i is not assumed positive, so the
        // solver yields δ·(i+1) with unknown positivity ⇒ conservative or
        // no-dep; crucially never a wrong parallel claim. With the bound
        // i ≥ 0 the transform layer can refine. Here we check the report
        // shape only.
        let r = loop_deps(inner, &p.containers);
        // Single write, no reads: only possible WAW.
        assert!(!r.has(DepKind::Raw) && !r.has(DepKind::War));
    }
}
