//! Concrete range propagation: bound propagated accesses to integer
//! element intervals given parameter bindings.
//!
//! Used by the privatization conflict check (cheap disjointness), the VM's
//! allocation sizing, and tests that cross-validate the symbolic analyses
//! against enumeration.

use anyhow::{bail, Result};

use crate::symbolic::eval::{eval_int, Env};
use crate::symbolic::Expr;

use super::visibility::PropAccess;

/// Inclusive element interval.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interval {
    pub lo: i64,
    pub hi: i64,
}

impl Interval {
    pub fn intersects(&self, other: &Interval) -> bool {
        self.lo <= other.hi && other.lo <= self.hi
    }
}

/// Maximum iterations enumerated per range before falling back to the
/// min/max-endpoint approximation.
const ENUM_CAP: u64 = 4096;

/// Compute the concrete interval touched by a propagated access under the
/// given parameter bindings. Conservative: the returned interval always
/// contains every touched element (it may contain untouched ones).
pub fn access_interval(acc: &PropAccess, env: &dyn Env, container_size: i64) -> Result<Interval> {
    if acc.whole {
        return Ok(Interval {
            lo: 0,
            hi: container_size - 1,
        });
    }
    // Enumerate the (small) cartesian range product, or evaluate at range
    // endpoints when the offset is monotone-friendly (affine in each var).
    let mut bindings: Vec<(crate::symbolic::Sym, i64)> = Vec::new();
    let mut lo = i64::MAX;
    let mut hi = i64::MIN;
    enumerate(acc, env, 0, &mut bindings, &mut lo, &mut hi, &mut 0)?;
    if lo > hi {
        bail!("empty iteration range for access");
    }
    Ok(Interval { lo, hi })
}

fn enumerate(
    acc: &PropAccess,
    env: &dyn Env,
    depth: usize,
    bindings: &mut Vec<(crate::symbolic::Sym, i64)>,
    lo: &mut i64,
    hi: &mut i64,
    visited: &mut u64,
) -> Result<()> {
    if depth == acc.ranges.len() {
        let combined = CombinedEnv {
            inner: env,
            extra: bindings,
        };
        let v = eval_int(&acc.offset, &combined)?;
        *lo = (*lo).min(v);
        *hi = (*hi).max(v);
        return Ok(());
    }
    let r = &acc.ranges[depth];
    let combined_start = {
        let c = CombinedEnv {
            inner: env,
            extra: bindings,
        };
        eval_int(&r.start, &c)?
    };
    let combined_end = {
        let c = CombinedEnv {
            inner: env,
            extra: bindings,
        };
        eval_int(&r.end, &c)?
    };
    let mut v = combined_start;
    loop {
        let stride = {
            bindings.push((r.var, v));
            let c = CombinedEnv {
                inner: env,
                extra: bindings,
            };
            let s = eval_int(&r.stride, &c)?;
            bindings.pop();
            s
        };
        if stride == 0 {
            bail!("zero stride during propagation");
        }
        let done = if stride > 0 {
            v >= combined_end
        } else {
            v <= combined_end
        };
        if done {
            break;
        }
        *visited += 1;
        if *visited > ENUM_CAP {
            // Fallback: affine endpoint evaluation — evaluate the offset at
            // start and last value only (sound for monotone affine offsets;
            // for anything else the caller should have set `whole`).
            for probe in [combined_start, last_value(combined_start, combined_end, stride)] {
                bindings.push((r.var, probe));
                enumerate(acc, env, depth + 1, bindings, lo, hi, visited)?;
                bindings.pop();
            }
            return Ok(());
        }
        bindings.push((r.var, v));
        enumerate(acc, env, depth + 1, bindings, lo, hi, visited)?;
        bindings.pop();
        v += stride;
    }
    Ok(())
}

fn last_value(start: i64, end: i64, stride: i64) -> i64 {
    if stride > 0 {
        if end <= start {
            return start;
        }
        start + ((end - 1 - start) / stride) * stride
    } else {
        if end >= start {
            return start;
        }
        start + ((end + 1 - start) / stride) * stride
    }
}

struct CombinedEnv<'a> {
    inner: &'a dyn Env,
    extra: &'a [(crate::symbolic::Sym, i64)],
}

impl Env for CombinedEnv<'_> {
    fn get(&self, s: crate::symbolic::Sym) -> Option<i64> {
        self.extra
            .iter()
            .rev()
            .find(|(x, _)| *x == s)
            .map(|(_, v)| *v)
            .or_else(|| self.inner.get(s))
    }
}

/// Concrete count of iterations of a `(start, end, stride)` range; `None`
/// if the stride is zero or depends on un-enumerable state.
pub fn iteration_count(start: &Expr, end: &Expr, stride: &Expr, env: &dyn Env) -> Option<u64> {
    let s = eval_int(start, env).ok()?;
    let e = eval_int(end, env).ok()?;
    let st = eval_int(stride, env).ok()?;
    if st == 0 {
        return None;
    }
    if st > 0 {
        if e <= s {
            Some(0)
        } else {
            Some(((e - s) as u64).div_ceil(st as u64))
        }
    } else if s <= e {
        Some(0)
    } else {
        Some(((s - e) as u64).div_ceil((-st) as u64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::visibility::{LoopRange, PropAccess};
    use crate::ir::AccessKind;
    use crate::ir::StmtId;
    use crate::symbolic::{int, ContainerId, Expr, Sym};

    #[test]
    fn simple_range_interval() {
        let i = Sym::new("prop_i");
        let n = Sym::positive("prop_N");
        let acc = PropAccess {
            container: ContainerId(0),
            offset: Expr::Sym(i) * int(2) + int(1),
            ranges: vec![LoopRange {
                var: i,
                start: int(0),
                end: Expr::Sym(n),
                stride: int(1),
                countable: true,
            }],
            whole: false,
            stmt: StmtId(0),
            kind: AccessKind::Read,
        };
        let env = vec![(n, 10i64)];
        let iv = access_interval(&acc, &env, 100).unwrap();
        assert_eq!(iv, Interval { lo: 1, hi: 19 });
    }

    #[test]
    fn whole_container_fallback() {
        let acc = PropAccess {
            container: ContainerId(0),
            offset: int(0),
            ranges: vec![],
            whole: true,
            stmt: StmtId(0),
            kind: AccessKind::Write,
        };
        let env: Vec<(Sym, i64)> = vec![];
        let iv = access_interval(&acc, &env, 64).unwrap();
        assert_eq!(iv, Interval { lo: 0, hi: 63 });
    }

    #[test]
    fn iteration_counts() {
        let env: Vec<(Sym, i64)> = vec![];
        assert_eq!(iteration_count(&int(0), &int(10), &int(1), &env), Some(10));
        assert_eq!(iteration_count(&int(0), &int(10), &int(3), &env), Some(4));
        assert_eq!(iteration_count(&int(10), &int(0), &int(-2), &env), Some(5));
        assert_eq!(iteration_count(&int(5), &int(5), &int(1), &env), Some(0));
        assert_eq!(iteration_count(&int(0), &int(1), &int(0), &env), None);
    }

    #[test]
    fn interval_intersection() {
        let a = Interval { lo: 0, hi: 10 };
        let b = Interval { lo: 10, hi: 20 };
        let c = Interval { lo: 11, hi: 20 };
        assert!(a.intersects(&b));
        assert!(!a.intersects(&c));
    }
}
