//! Memoized analysis cache shared by every optimization pass (DESIGN.md
//! §Pass manager).
//!
//! The SILO pipeline re-queries the same per-loop analyses — dependence
//! reports, body dataflow graphs, iteration visibility, and propagated
//! summaries — at every pass, and the recursive summarization re-walks a
//! depth-d nest once per enclosing level. The cache memoizes all four per
//! [`LoopId`], keyed by a program *version counter* that transforms bump
//! through the invalidation API:
//!
//! * [`AnalysisCache::dirty`] — a transform mutated loop *L*: evict *L*'s
//!   subtree (its body changed) and its ancestors (their summaries include
//!   *L*'s). Sibling nests stay cached — the cross-pass win.
//! * [`AnalysisCache::dirty_all`] — global restructurings (fusion,
//!   scalarization) evict everything.
//!
//! Transforms that only flip a loop's `schedule` need no invalidation:
//! none of the cached analyses read schedules.

use std::collections::HashMap;
use std::sync::Arc;

use crate::dataflow::BodyGraph;
use crate::ir::{Container, Loop, LoopId, Node, Program};

use super::deps::{loop_deps_memo, DepReport};
use super::visibility::{
    body_graph_memo, iter_visibility_memo, loop_summary_memo, IterVisibility, SummaryMemo,
    SummaryPair,
};

/// Hit/miss/invalidation counters (summary counters live in the memo and
/// are folded in by the accessors below).
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub invalidations: u64,
}

/// Per-loop memoization of SILO's analyses. See the module docs for the
/// invalidation contract.
#[derive(Debug)]
pub struct AnalysisCache {
    enabled: bool,
    version: u64,
    summaries: SummaryMemo,
    graphs: HashMap<LoopId, Arc<BodyGraph>>,
    deps: HashMap<LoopId, Arc<DepReport>>,
    vis: HashMap<LoopId, Arc<IterVisibility>>,
    stats: CacheStats,
}

impl AnalysisCache {
    pub fn new() -> AnalysisCache {
        AnalysisCache {
            enabled: true,
            version: 0,
            summaries: SummaryMemo::new(),
            graphs: HashMap::new(),
            deps: HashMap::new(),
            vis: HashMap::new(),
            stats: CacheStats::default(),
        }
    }

    /// Prepare the cache for a pipeline run over `_p`: evict everything
    /// unless the cache is still pristine. `LoopId`s restart at 0 in
    /// every [`Program`] instance (and instances can share a name), so a
    /// cache that has ever been populated cannot be trusted for a program
    /// handed to a new run. `Pipeline::run_with` calls this; do the same
    /// before reusing one cache with ad-hoc transform calls. (The program
    /// parameter reserves room for a real instance identity later.)
    pub fn rebind(&mut self, _p: &Program) {
        if !self.is_pristine() {
            self.dirty_all();
        }
    }

    fn is_pristine(&self) -> bool {
        self.deps.is_empty()
            && self.graphs.is_empty()
            && self.vis.is_empty()
            && self.summaries.is_empty()
    }

    /// A cache that never stores: every query recomputes. The uncached
    /// baseline for `bench_optimizer`'s ablation and the backing for the
    /// legacy free-function transform entry points.
    pub fn disabled() -> AnalysisCache {
        AnalysisCache {
            enabled: false,
            version: 0,
            summaries: SummaryMemo::disabled(),
            graphs: HashMap::new(),
            deps: HashMap::new(),
            vis: HashMap::new(),
            stats: CacheStats::default(),
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Program version this cache believes it matches; bumped on every
    /// invalidation.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Total hits across all four analysis kinds.
    pub fn hits(&self) -> u64 {
        self.stats.hits + self.summaries.hits
    }

    /// Total misses (recomputations) across all four analysis kinds.
    pub fn misses(&self) -> u64 {
        self.stats.misses + self.summaries.misses
    }

    pub fn invalidations(&self) -> u64 {
        self.stats.invalidations
    }

    /// Loop-carried dependence report for `l` (memoized).
    pub fn deps(&mut self, l: &Loop, containers: &[Container]) -> Arc<DepReport> {
        if self.enabled {
            if let Some(hit) = self.deps.get(&l.id) {
                self.stats.hits += 1;
                return hit.clone();
            }
        }
        self.stats.misses += 1;
        let d = Arc::new(loop_deps_memo(l, containers, &mut self.summaries));
        if self.enabled {
            self.deps.insert(l.id, d.clone());
        }
        d
    }

    /// Body dataflow graph for `l` (memoized).
    pub fn body_graph(&mut self, l: &Loop, containers: &[Container]) -> Arc<BodyGraph> {
        if self.enabled {
            if let Some(hit) = self.graphs.get(&l.id) {
                self.stats.hits += 1;
                return hit.clone();
            }
        }
        self.stats.misses += 1;
        let g = Arc::new(body_graph_memo(l, containers, &mut self.summaries));
        if self.enabled {
            self.graphs.insert(l.id, g.clone());
        }
        g
    }

    /// Externally visible per-iteration reads/writes of `l` (memoized).
    pub fn visibility(&mut self, l: &Loop, containers: &[Container]) -> Arc<IterVisibility> {
        if self.enabled {
            if let Some(hit) = self.vis.get(&l.id) {
                self.stats.hits += 1;
                return hit.clone();
            }
        }
        self.stats.misses += 1;
        let v = Arc::new(iter_visibility_memo(l, containers, &mut self.summaries));
        if self.enabled {
            self.vis.insert(l.id, v.clone());
        }
        v
    }

    /// Propagated whole-loop summary of `l` (memoized; also feeds the
    /// recursion inside the other three analyses).
    pub fn summary(&mut self, l: &Loop, containers: &[Container]) -> Arc<SummaryPair> {
        loop_summary_memo(l, containers, &mut self.summaries)
    }

    /// Is a dependence report currently cached for `id`? (Test hook for
    /// the invalidation contract.)
    pub fn has_deps_for(&self, id: LoopId) -> bool {
        self.deps.contains_key(&id)
    }

    /// Is a visibility/summary entry currently cached for `id`?
    pub fn has_summary_for(&self, id: LoopId) -> bool {
        self.summaries.contains(id)
    }

    /// A transform mutated loop `id` (body, bounds, or the containers its
    /// subtree touches): evict the loop's subtree and its ancestor chain.
    /// Call *after* the mutation — the ancestor chain is read from the
    /// current tree. Falls back to [`Self::dirty_all`] when the loop no
    /// longer exists (it was dissolved by a restructuring).
    pub fn dirty(&mut self, p: &Program, id: LoopId) {
        self.version += 1;
        self.stats.invalidations += 1;
        let Some(l) = p.find_loop(id) else {
            self.evict_all();
            return;
        };
        let mut ids: Vec<LoopId> = Vec::new();
        fn subtree(l: &Loop, out: &mut Vec<LoopId>) {
            out.push(l.id);
            for n in &l.body {
                if let Node::Loop(c) = n {
                    subtree(c, out);
                }
            }
        }
        subtree(l, &mut ids);
        if let Some(parents) = p.loop_parents().get(&id) {
            ids.extend(parents.iter().copied());
        }
        for i in ids {
            self.evict(i);
        }
    }

    /// Global restructuring: evict everything and bump the version.
    pub fn dirty_all(&mut self) {
        self.version += 1;
        self.stats.invalidations += 1;
        self.evict_all();
    }

    fn evict(&mut self, id: LoopId) {
        self.graphs.remove(&id);
        self.deps.remove(&id);
        self.vis.remove(&id);
        self.summaries.remove(id);
    }

    fn evict_all(&mut self) {
        self.graphs.clear();
        self.deps.clear();
        self.vis.clear();
        self.summaries.clear();
    }
}

impl Default for AnalysisCache {
    fn default() -> AnalysisCache {
        AnalysisCache::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{loop_deps, DepKind};
    use crate::ir::ProgramBuilder;
    use crate::symbolic::{int, load, Expr};

    /// Two independent top-level nests; nest 1 has a privatizable
    /// transient (WAW across k), nest 2 is a plain streaming loop.
    fn two_nests() -> (crate::ir::Program, crate::ir::LoopId, crate::ir::LoopId) {
        let mut b = ProgramBuilder::new("cache1");
        let n = b.param_positive("cache1_N");
        let m = b.param_positive("cache1_M");
        let t = b.transient("T", Expr::Sym(n));
        let bb = b.array("B", Expr::Sym(n) * Expr::Sym(m));
        let out = b.array("O", Expr::Sym(n));
        let k = b.sym("cache1_k");
        let i = b.sym("cache1_i");
        let j = b.sym("cache1_j");
        let kl = b.for_id(k, int(1), Expr::Sym(m), int(1), |b| {
            b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
                let off = Expr::Sym(i) * Expr::Sym(m) + Expr::Sym(k);
                b.assign(t, Expr::Sym(i), load(bb, off.clone() - int(1)) * Expr::real(0.2));
                b.assign(bb, off, load(t, Expr::Sym(i)));
            });
        });
        let jl = b.for_id(j, int(0), Expr::Sym(n), int(1), |b| {
            b.assign(out, Expr::Sym(j), Expr::Sym(j) * Expr::real(2.0));
        });
        (b.finish(), kl, jl)
    }

    #[test]
    fn hit_on_repeat_query_and_agrees_with_uncached() {
        let (p, kl, _) = two_nests();
        let mut cache = AnalysisCache::new();
        let l = p.find_loop(kl).unwrap();
        let first = cache.deps(l, &p.containers);
        assert_eq!(cache.hits(), 0);
        let second = cache.deps(l, &p.containers);
        assert!(cache.hits() > 0);
        assert_eq!(first.deps.len(), second.deps.len());
        let fresh = loop_deps(l, &p.containers);
        assert_eq!(first.deps.len(), fresh.deps.len());
    }

    #[test]
    fn mutating_one_loop_invalidates_it_and_spares_siblings() {
        let (mut p, kl, jl) = two_nests();
        let mut cache = AnalysisCache::new();
        // Warm both nests.
        let before = cache.deps(p.find_loop(kl).unwrap(), &p.containers);
        cache.deps(p.find_loop(jl).unwrap(), &p.containers);
        assert!(before.of_kind(DepKind::Waw).next().is_some());
        assert!(cache.has_deps_for(kl) && cache.has_deps_for(jl));
        let v0 = cache.version();

        // Privatize T at the k loop through the cache-aware transform.
        let rep = crate::transforms::privatize::privatize_with(&mut p, kl, &mut cache).unwrap();
        assert_eq!(rep.privatized.len(), 1);

        // Exactly the mutated loop's entries are gone; the sibling nest
        // stays cached.
        assert!(!cache.has_deps_for(kl), "mutated loop must be evicted");
        assert!(cache.has_deps_for(jl), "untouched sibling must stay");
        assert!(cache.version() > v0);

        // Stale-read regression: a fresh query must see the WAW gone.
        let after = cache.deps(p.find_loop(kl).unwrap(), &p.containers);
        assert!(
            after.of_kind(DepKind::Waw).next().is_none(),
            "stale WAW served from the cache: {:?}",
            after.deps
        );
    }

    #[test]
    fn dirty_evicts_ancestors_and_subtree() {
        let (p, kl, jl) = two_nests();
        let mut cache = AnalysisCache::new();
        let outer = p.find_loop(kl).unwrap();
        let inner = match &outer.body[0] {
            crate::ir::Node::Loop(l) => l.id,
            _ => unreachable!(),
        };
        cache.deps(outer, &p.containers);
        cache.deps(p.find_loop(inner).unwrap(), &p.containers);
        cache.deps(p.find_loop(jl).unwrap(), &p.containers);
        cache.dirty(&p, inner);
        assert!(!cache.has_deps_for(inner));
        assert!(!cache.has_deps_for(kl), "ancestor must be evicted");
        assert!(cache.has_deps_for(jl), "sibling nest must survive");
    }

    #[test]
    fn disabled_cache_never_stores() {
        let (p, kl, _) = two_nests();
        let mut cache = AnalysisCache::disabled();
        cache.deps(p.find_loop(kl).unwrap(), &p.containers);
        cache.deps(p.find_loop(kl).unwrap(), &p.containers);
        assert_eq!(cache.hits(), 0);
        assert!(!cache.has_deps_for(kl));
    }
}
