//! Consumer/producer analysis (paper §3.1): which reads and writes of a
//! loop iteration are *externally visible*, and the propagation of those
//! accesses over the loop's full iteration range.

use std::collections::HashMap;
use std::sync::Arc;

use crate::dataflow::BodyGraph;
use crate::ir::{Access, AccessKind, Container, ContainerKind, Loop, LoopId, Node, StmtId};
use crate::symbolic::{ContainerId, Expr, Sym};

/// Propagated `(reads, writes)` of one whole loop.
pub type SummaryPair = (Vec<PropAccess>, Vec<PropAccess>);

/// Memo table for per-loop propagated summaries, threaded through the
/// recursive analyses so a nested loop is summarized once per program
/// version instead of once per enclosing query. [`crate::analysis::cache`]
/// owns one per [`crate::analysis::AnalysisCache`]; the plain entry points
/// below use a disabled (always-miss) memo for drop-in compatibility.
#[derive(Debug)]
pub struct SummaryMemo {
    enabled: bool,
    map: HashMap<LoopId, Arc<SummaryPair>>,
    /// Memo hits/misses (misses count every recomputation, cached or not).
    pub hits: u64,
    pub misses: u64,
}

impl SummaryMemo {
    pub fn new() -> SummaryMemo {
        SummaryMemo {
            enabled: true,
            map: HashMap::new(),
            hits: 0,
            misses: 0,
        }
    }

    /// A memo that never stores: every lookup recomputes (the uncached
    /// baseline the optimizer bench compares against).
    pub fn disabled() -> SummaryMemo {
        SummaryMemo {
            enabled: false,
            map: HashMap::new(),
            hits: 0,
            misses: 0,
        }
    }

    fn lookup(&mut self, id: LoopId) -> Option<Arc<SummaryPair>> {
        if self.enabled {
            if let Some(hit) = self.map.get(&id) {
                self.hits += 1;
                return Some(hit.clone());
            }
        }
        self.misses += 1;
        None
    }

    fn store(&mut self, id: LoopId, pair: Arc<SummaryPair>) {
        if self.enabled {
            self.map.insert(id, pair);
        }
    }

    /// Drop the entry for one loop (cache invalidation).
    pub fn remove(&mut self, id: LoopId) {
        self.map.remove(&id);
    }

    /// Drop every entry.
    pub fn clear(&mut self) {
        self.map.clear();
    }

    /// Is a summary currently memoized for `id`?
    pub fn contains(&self, id: LoopId) -> bool {
        self.map.contains_key(&id)
    }

    /// Is the memo empty?
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

impl Default for SummaryMemo {
    fn default() -> SummaryMemo {
        SummaryMemo::new()
    }
}

/// The symbolic iteration range of one loop level, attached to a
/// propagated access.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LoopRange {
    pub var: Sym,
    pub start: Expr,
    pub end: Expr,
    pub stride: Expr,
    /// Whether the iteration set is statically countable from the symbolic
    /// expressions (false when e.g. the stride depends on the loop variable
    /// itself — the paper's over-approximation trigger).
    pub countable: bool,
}

impl LoopRange {
    pub fn of(l: &Loop) -> LoopRange {
        // Countable iff the stride does not depend on the loop's own
        // variable and no bound depends on it either.
        let countable = !l.stride.depends_on(l.var)
            && !l.start.depends_on(l.var)
            && !l.end.depends_on(l.var);
        LoopRange {
            var: l.var,
            start: l.start.clone(),
            end: l.end.clone(),
            stride: l.stride.clone(),
            countable,
        }
    }
}

/// An access propagated over one or more loop ranges (paper §3.1:
/// "instances of the loop's iteration variable inside the offset
/// expressions are given a specific range of values").
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PropAccess {
    pub container: ContainerId,
    pub offset: Expr,
    pub ranges: Vec<LoopRange>,
    /// Conservative fallback: the access may touch the whole container
    /// (uncountable range or unsolvable offset).
    pub whole: bool,
    /// Statement the access originates from.
    pub stmt: StmtId,
    pub kind: AccessKind,
}

/// Externally visible reads/writes of a *single iteration* of a loop.
#[derive(Debug, Clone, Default)]
pub struct IterVisibility {
    pub reads: Vec<(StmtId, Access)>,
    pub writes: Vec<(StmtId, Access)>,
}

/// Is a write to this container externally invisible by construction?
fn iteration_local(c: &Container) -> bool {
    matches!(c.kind, ContainerKind::Register)
}

/// Compute the externally visible reads and writes of one iteration of
/// loop `l` (§3.1). Writes: everything except iteration-local containers.
/// Reads: everything not *self-contained* (dominated by a write of the
/// same symbolic offset within the iteration).
pub fn iter_visibility(l: &Loop, containers: &[Container]) -> IterVisibility {
    iter_visibility_memo(l, containers, &mut SummaryMemo::disabled())
}

/// [`iter_visibility`] with nested-loop summaries served from `memo`.
pub fn iter_visibility_memo(
    l: &Loop,
    containers: &[Container],
    memo: &mut SummaryMemo,
) -> IterVisibility {
    let graph = body_graph_memo(l, containers, memo);
    let mut out = IterVisibility::default();
    for (idx, node) in graph.nodes.iter().enumerate() {
        for w in &node.writes {
            if !iteration_local(&containers[w.container.0 as usize]) {
                out.writes.push((stmt_of(node, l), w.clone()));
            }
        }
        for r in &node.reads {
            if iteration_local(&containers[r.container.0 as usize]) {
                continue;
            }
            if !graph.is_self_contained(idx, r) {
                out.reads.push((stmt_of(node, l), r.clone()));
            }
        }
    }
    out
}

fn stmt_of(node: &crate::dataflow::GraphNode, l: &Loop) -> StmtId {
    match node.node {
        crate::dataflow::NodeRef::Stmt(s) => s,
        crate::dataflow::NodeRef::Loop(lid) => {
            // Attribute a summarized nested loop's accesses to its first
            // statement (used only for reporting; dependence analysis on
            // nested statements re-resolves precisely).
            l.find_loop(lid)
                .and_then(|nl| Node::Loop(nl.clone()).stmts().first().map(|s| s.id))
                .unwrap_or(StmtId(u32::MAX))
        }
    }
}

/// Build the dataflow graph for `l`'s body, summarizing nested loops with
/// their *propagated* external accesses.
pub fn body_graph(l: &Loop, containers: &[Container]) -> BodyGraph {
    body_graph_memo(l, containers, &mut SummaryMemo::disabled())
}

/// [`body_graph`] with nested-loop summaries served from `memo`.
pub fn body_graph_memo(l: &Loop, containers: &[Container], memo: &mut SummaryMemo) -> BodyGraph {
    // Resolve child summaries first (the memo borrow), then build the
    // graph from the immutable table.
    let mut child: HashMap<LoopId, Arc<SummaryPair>> = HashMap::new();
    for n in &l.body {
        if let Node::Loop(inner) = n {
            child.insert(inner.id, loop_summary_memo(inner, containers, memo));
        }
    }
    let summarize = |n: &Node| -> (Vec<Access>, Vec<Access>) {
        match n {
            Node::Loop(inner) => {
                let pair = &child[&inner.id];
                (
                    pair.0
                        .iter()
                        .map(|p| Access::read(p.container, p.offset.clone()))
                        .collect(),
                    pair.1
                        .iter()
                        .map(|p| Access::write(p.container, p.offset.clone()))
                        .collect(),
                )
            }
            Node::Stmt(_) => unreachable!("summarize called on stmt"),
        }
    };
    BodyGraph::build(&l.body, &summarize)
}

/// Propagate the externally visible accesses of loop `l` over its full
/// iteration range (§3.1), recursively summarizing nested loops. Returns
/// `(reads, writes)` for the loop as a whole — each a [`PropAccess`] whose
/// `ranges` binds every loop variable the offset still mentions.
pub fn loop_summary(l: &Loop, containers: &[Container]) -> (Vec<PropAccess>, Vec<PropAccess>) {
    let pair = loop_summary_memo(l, containers, &mut SummaryMemo::disabled());
    (pair.0.clone(), pair.1.clone())
}

/// [`loop_summary`] memoized per [`LoopId`]: the recursion checks `memo`
/// before recomputing, so summarizing a depth-d nest touches each loop
/// once instead of once per enclosing level.
pub fn loop_summary_memo(
    l: &Loop,
    containers: &[Container],
    memo: &mut SummaryMemo,
) -> Arc<SummaryPair> {
    if let Some(hit) = memo.lookup(l.id) {
        return hit;
    }
    let graph = body_graph_memo(l, containers, memo);
    let mut reads: Vec<PropAccess> = Vec::new();
    let mut writes: Vec<PropAccess> = Vec::new();

    for (idx, node) in l.body.iter().enumerate() {
        match node {
            Node::Stmt(s) => {
                for r in s.reads() {
                    if iteration_local(&containers[r.container.0 as usize]) {
                        continue;
                    }
                    if graph.is_self_contained(idx, &r) {
                        continue;
                    }
                    reads.push(PropAccess {
                        container: r.container,
                        offset: r.offset,
                        ranges: Vec::new(),
                        whole: false,
                        stmt: s.id,
                        kind: AccessKind::Read,
                    });
                }
                if !iteration_local(&containers[s.write.container.0 as usize]) {
                    writes.push(PropAccess {
                        container: s.write.container,
                        offset: s.write.offset.clone(),
                        ranges: Vec::new(),
                        whole: false,
                        stmt: s.id,
                        kind: AccessKind::Write,
                    });
                }
            }
            Node::Loop(inner) => {
                let pair = loop_summary_memo(inner, containers, memo);
                for r in pair.0.iter() {
                    let as_access = Access::read(r.container, r.offset.clone());
                    if graph.is_self_contained(idx, &as_access) {
                        continue;
                    }
                    reads.push(r.clone());
                }
                writes.extend(pair.1.iter().cloned());
            }
        }
    }

    // Bind this loop's range on every access whose offset mentions its var,
    // *normalizing* the variable to `start + var~` (var~ a per-loop fresh
    // symbol ranging over [0, end−start)). Normalization keeps offsets of
    // tiled/triangular inner loops explicitly dependent on the outer
    // variables their start expressions mention — without it, a summarized
    // `A[.. + i]` with `i ∈ [i_t, i_t+T)` would look invariant to the tile
    // loop `i_t` and produce phantom all-iteration WAW conflicts.
    let range = LoopRange::of(l);
    let tilde = crate::symbolic::Sym::nonneg(&format!("{}~", l.var.name()));
    for p in reads.iter_mut().chain(writes.iter_mut()) {
        if p.whole || !p.offset.depends_on(l.var) {
            continue;
        }
        if range.countable {
            p.offset = crate::symbolic::subs(
                &p.offset,
                l.var,
                &(l.start.clone() + crate::symbolic::Expr::Sym(tilde)),
            );
            p.ranges.push(LoopRange {
                var: tilde,
                start: crate::symbolic::Expr::Int(0),
                end: crate::symbolic::simplify(&(l.end.clone() - l.start.clone())),
                stride: l.stride.clone(),
                countable: true,
            });
        } else {
            p.whole = true;
        }
    }
    let pair = Arc::new((reads, writes));
    memo.store(l.id, pair.clone());
    pair
}

/// Do two propagated accesses possibly overlap? Sound over-approximation:
/// `false` only when provably disjoint.
pub fn may_overlap(a: &PropAccess, b: &PropAccess) -> bool {
    use crate::symbolic::{poly_diff, is_zero, Truth};
    if a.container != b.container {
        return false;
    }
    if a.whole || b.whole {
        return true;
    }
    // Quick exact check: identical offsets on identical ranges obviously
    // overlap; provably constant nonzero difference with no free loop vars
    // means disjoint only if neither ranges over anything... keep it sound:
    if a.ranges.is_empty() && b.ranges.is_empty() {
        return match poly_diff(&a.offset, &b.offset) {
            Some(d) if d.is_zero() => true,
            Some(d) => is_zero(&d.to_expr()) != Truth::No,
            None => true,
        };
    }
    // Ranged accesses: conservatively overlap. (The dependence analysis
    // does the precise δ-based disambiguation; this helper only gates
    // privatization, where over-approximation is safe.)
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::ProgramBuilder;
    use crate::symbolic::{int, load, Expr};

    /// Fig. 4's didactic loop nest:
    /// `for k: for i: { S1: t = B[i][k-1]*0.2; S2: A[i] = t + C[i][k+1];`
    /// `S3: B[i][k] = A[i]; C[i][k] = t; }`
    /// (flattened to 1D offsets with symbolic row stride M)
    fn fig4() -> (crate::ir::Program, [crate::symbolic::ContainerId; 4]) {
        let mut b = ProgramBuilder::new("fig4");
        let n = b.param_positive("vis_N");
        let m = b.param_positive("vis_M");
        let a = b.array("A", Expr::Sym(n));
        let bb = b.array("B", Expr::Sym(n) * Expr::Sym(m));
        let cc = b.array("C", Expr::Sym(n) * Expr::Sym(m));
        let t = b.transient("t", int(1));
        let k = b.sym("vis_k");
        let i = b.sym("vis_i");
        b.for_(k, int(1), Expr::Sym(m) - int(1), int(1), |b| {
            b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
                let iv = Expr::Sym(i);
                let kv = Expr::Sym(k);
                let off = |col: Expr| iv.clone() * Expr::Sym(m) + col;
                // S1: t = B[i][k-1] * 0.2
                b.assign(t, int(0), load(bb, off(kv.clone() - int(1))) * Expr::real(0.2));
                // S2: A[i] = t + C[i][k+1]
                b.assign(a, iv.clone(), load(t, int(0)) + load(cc, off(kv.clone() + int(1))));
                // S3: B[i][k] = A[i]
                b.assign(bb, off(kv.clone()), load(a, iv.clone()));
                // S4: C[i][k] = t
                b.assign(cc, off(kv.clone()), load(t, int(0)));
            });
        });
        (b.finish(), [a, bb, cc, t])
    }

    #[test]
    fn self_contained_reads_hidden() {
        let (p, [a, _bb, _cc, t]) = fig4();
        let outer = p.loops()[0];
        let inner = p.loops()[1];
        let vis = iter_visibility(inner, &p.containers);
        // Reads of t (s2, s4) are self-contained (t written in s1);
        // the read of A in S3 is self-contained (written in S2).
        assert!(
            !vis.reads.iter().any(|(_, r)| r.container == t),
            "t reads should be self-contained"
        );
        assert!(
            !vis.reads.iter().any(|(_, r)| r.container == a),
            "A read dominated by same-iteration write"
        );
        // B[i][k-1] and C[i][k+1] remain externally visible.
        assert_eq!(vis.reads.len(), 2);
        let _ = outer;
    }

    #[test]
    fn outer_loop_sees_summarized_inner() {
        let (p, [_a, bb, cc, _t]) = fig4();
        let outer = p.loops()[0];
        let vis = iter_visibility(outer, &p.containers);
        // From the k-iteration's perspective the i-loop is one black box:
        // it reads B[.][k-1], C[.][k+1] and writes t, A, B[.][k], C[.][k]
        // (the transient scalar t stays visible until privatization
        // decides it is iteration-local — §3.2.1 is a *transform*, not part
        // of this analysis).
        assert!(vis.reads.iter().any(|(_, r)| r.container == bb));
        assert!(vis.reads.iter().any(|(_, r)| r.container == cc));
        assert_eq!(vis.writes.len(), 4);
    }

    #[test]
    fn propagation_binds_ranges() {
        let (p, [_a, bb, _cc, _t]) = fig4();
        let outer = p.loops()[0];
        let (reads, writes) = loop_summary(outer, &p.containers);
        let b_read = reads.iter().find(|r| r.container == bb).unwrap();
        // Offset depends on both i and k; the i range was bound by the
        // inner summary, the k range by the outer propagation.
        assert!(!b_read.whole);
        assert_eq!(b_read.ranges.len(), 2);
        assert!(writes.iter().all(|w| !w.whole));
    }

    #[test]
    fn uncountable_range_over_approximates() {
        // Fig. 2 left: for (i=1; i<=n; i+=i) a[log2(i)] = 1.0
        let mut b = ProgramBuilder::new("vis_fig2");
        let n = b.param_positive("vis2_N");
        let a = b.array("A", Expr::Sym(n));
        let i = b.sym("vis2_i");
        use crate::symbolic::{func, FuncKind};
        b.for_(i, int(1), Expr::Sym(n), Expr::Sym(i), |b| {
            b.assign(a, func(FuncKind::Log2, vec![Expr::Sym(i)]), Expr::real(1.0));
        });
        let p = b.finish();
        let l = p.loops()[0];
        let (_, writes) = loop_summary(l, &p.containers);
        assert_eq!(writes.len(), 1);
        assert!(writes[0].whole, "variable stride must over-approximate");
    }
}
