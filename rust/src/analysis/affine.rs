//! Affinity (SCoP) classification — the restriction polyhedral baselines
//! live under.
//!
//! Polly/Pluto require *affine* loop bounds and accesses: every offset must
//! be `Σ cₖ·varₖ + g(params)` with **integer constant** coefficients cₖ on
//! the loop variables, and strides must be integer constants. Multiplying a
//! loop variable by a *symbolic* stride (`i*isI`, the Fig. 1 pattern) makes
//! the access a multivariate polynomial and ejects the loop from the
//! polyhedral model — precisely the class SILO still analyzes.

use crate::ir::{Loop, Node, Program};
use crate::symbolic::{to_poly, Atom, Expr, Sym};

/// Why a loop nest was rejected from the polyhedral model.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AffineViolation {
    /// A loop stride is not an integer constant (e.g. `i += i`, `j += i+1`).
    NonConstantStride { var: Sym },
    /// A loop bound is not affine in outer variables and parameters.
    NonAffineBound { var: Sym },
    /// An access offset has a loop variable multiplied by a parameter or
    /// another variable (multivariate polynomial, Fig. 1).
    NonAffineAccess { offset: Expr },
    /// An access offset contains a non-polynomial construct (log2, mod, …).
    NonPolynomialAccess { offset: Expr },
}

/// Result of classifying a loop nest.
#[derive(Debug, Clone)]
pub struct AffinityReport {
    pub violations: Vec<AffineViolation>,
}

impl AffinityReport {
    pub fn is_scop(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Is `e` affine in `vars` (loop variables), with everything else treated
/// as a parameter? Affine = each var appears at degree ≤ 1 with an integer
/// constant coefficient, no var·var or var·param products, and no opaque
/// atoms mentioning a var.
pub fn is_affine_in(e: &Expr, vars: &[Sym]) -> Result<(), AffineViolation> {
    is_affine_in_with(e, vars, &[])
}

/// Like [`is_affine_in`], but `dim_strides` lists parameters that are
/// array-dimension extents: `var·extent` products are accepted (multidim
/// array notation — a polyhedral tool sees `A[k][j][i]`, not the
/// linearized polynomial).
pub fn is_affine_in_with(
    e: &Expr,
    vars: &[Sym],
    dim_strides: &[Sym],
) -> Result<(), AffineViolation> {
    let Some(p) = to_poly(e) else {
        return Err(AffineViolation::NonPolynomialAccess { offset: e.clone() });
    };
    for (m, _c) in &p.0 {
        let mut var_degree = 0u32;
        let mut has_param_factor = false;
        for (a, pw) in &m.0 {
            match a {
                Atom::Sym(s) if vars.contains(s) => var_degree += pw,
                Atom::Sym(_) => has_param_factor = true,
                Atom::Opaque(inner) => {
                    if vars.iter().any(|v| inner.depends_on(*v)) {
                        return Err(AffineViolation::NonPolynomialAccess {
                            offset: e.clone(),
                        });
                    }
                    has_param_factor = true;
                }
            }
        }
        if var_degree > 1 {
            return Err(AffineViolation::NonAffineAccess { offset: e.clone() });
        }
        if var_degree == 1 && has_param_factor {
            // var·param: reject unless every param factor is a declared
            // dimension extent (multidim linearization).
            let all_dims = m.0.iter().all(|(a, _)| match a {
                Atom::Sym(s) if vars.contains(s) => true,
                Atom::Sym(s) => dim_strides.contains(s),
                Atom::Opaque(_) => false,
            });
            if !all_dims {
                return Err(AffineViolation::NonAffineAccess { offset: e.clone() });
            }
        }
    }
    Ok(())
}

/// Classify a loop nest rooted at `l` against the polyhedral restrictions.
/// `outer_vars` are loop variables already in scope.
pub fn classify_nest(l: &Loop, outer_vars: &[Sym]) -> AffinityReport {
    classify_nest_with(l, outer_vars, &[])
}

/// [`classify_nest`] with declared dimension-extent parameters.
pub fn classify_nest_with(l: &Loop, outer_vars: &[Sym], dim_strides: &[Sym]) -> AffinityReport {
    let mut violations = Vec::new();
    let mut vars = outer_vars.to_vec();
    classify_rec(l, &mut vars, dim_strides, &mut violations);
    AffinityReport { violations }
}

/// Classify every top-level nest of a program (uses the program's declared
/// dimension extents).
pub fn classify_program(p: &Program) -> AffinityReport {
    let mut violations = Vec::new();
    for n in &p.body {
        if let Node::Loop(l) = n {
            let mut vars = Vec::new();
            classify_rec(l, &mut vars, &p.dim_syms, &mut violations);
        }
    }
    AffinityReport { violations }
}

fn classify_rec(
    l: &Loop,
    vars: &mut Vec<Sym>,
    dim_strides: &[Sym],
    violations: &mut Vec<AffineViolation>,
) {
    // Stride must be a nonzero integer constant.
    if l.stride.as_int().is_none() {
        violations.push(AffineViolation::NonConstantStride { var: l.var });
    }
    // Bounds affine in outer vars + params.
    for bound in [&l.start, &l.end] {
        if is_affine_in_with(bound, vars, dim_strides).is_err() {
            violations.push(AffineViolation::NonAffineBound { var: l.var });
        }
    }
    vars.push(l.var);
    for n in &l.body {
        match n {
            Node::Stmt(s) => {
                if let Err(v) = is_affine_in_with(&s.write.offset, vars, dim_strides) {
                    violations.push(v);
                }
                for r in s.reads() {
                    if let Err(v) = is_affine_in_with(&r.offset, vars, dim_strides) {
                        violations.push(v);
                    }
                }
            }
            Node::Loop(inner) => classify_rec(inner, vars, dim_strides, violations),
        }
    }
    vars.pop();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::ProgramBuilder;
    use crate::symbolic::{int, load, Expr};

    #[test]
    fn constant_stride_affine_access_is_scop() {
        let mut b = ProgramBuilder::new("aff1");
        let n = b.param_positive("aff1_N");
        let a = b.array("A", Expr::Sym(n) * int(64));
        let i = b.sym("aff1_i");
        b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
            // A[64*i + 3] — constant coefficient: affine.
            b.assign(a, int(64) * Expr::Sym(i) + int(3), Expr::real(0.0));
        });
        let p = b.finish();
        assert!(classify_program(&p).is_scop());
    }

    #[test]
    fn parametric_stride_rejected() {
        // The Fig. 1 Laplace pattern: in[i*isI + j*isJ].
        let mut b = ProgramBuilder::new("aff2");
        let n = b.param_positive("aff2_N");
        let is_i = b.param_positive("aff2_isI");
        let a = b.array("A", Expr::Sym(n) * Expr::Sym(is_i));
        let i = b.sym("aff2_i");
        b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
            b.assign(a, Expr::Sym(i) * Expr::Sym(is_i), Expr::real(0.0));
        });
        let p = b.finish();
        let r = classify_program(&p);
        assert!(!r.is_scop());
        assert!(matches!(
            r.violations[0],
            AffineViolation::NonAffineAccess { .. }
        ));
    }

    #[test]
    fn variable_stride_rejected() {
        // Fig. 2: i += i.
        let mut b = ProgramBuilder::new("aff3");
        let n = b.param_positive("aff3_N");
        let a = b.array("A", Expr::Sym(n));
        let i = b.sym("aff3_i");
        b.for_(i, int(1), Expr::Sym(n), Expr::Sym(i), |b| {
            b.assign(a, Expr::Sym(i), Expr::real(1.0));
        });
        let p = b.finish();
        let r = classify_program(&p);
        assert!(r
            .violations
            .iter()
            .any(|v| matches!(v, AffineViolation::NonConstantStride { .. })));
    }

    #[test]
    fn log2_access_rejected() {
        use crate::symbolic::{func, FuncKind};
        let mut b = ProgramBuilder::new("aff4");
        let n = b.param_positive("aff4_N");
        let a = b.array("A", Expr::Sym(n));
        let i = b.sym("aff4_i");
        b.for_(i, int(1), Expr::Sym(n), int(1), |b| {
            b.assign(a, func(FuncKind::Log2, vec![Expr::Sym(i)]), Expr::real(1.0));
        });
        let p = b.finish();
        assert!(classify_program(&p)
            .violations
            .iter()
            .any(|v| matches!(v, AffineViolation::NonPolynomialAccess { .. })));
    }

    #[test]
    fn affine_bound_on_outer_var_ok() {
        // Triangular bounds (j from i) are affine and SCoP-legal.
        let mut b = ProgramBuilder::new("aff5");
        let n = b.param_positive("aff5_N");
        let a = b.array("A", Expr::Sym(n) * Expr::Sym(n));
        let i = b.sym("aff5_i");
        let j = b.sym("aff5_j");
        b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
            b.for_(j, Expr::Sym(i), Expr::Sym(n), int(1), |b| {
                b.assign(a, int(8) * Expr::Sym(i) + Expr::Sym(j), Expr::real(0.0));
            });
        });
        let p = b.finish();
        assert!(classify_program(&p).is_scop());
    }
}
