//! Register-pressure / spill estimation (Fig. 1's "13 register spills" and
//! the Fig. 10 pointer-incrementation mechanism).
//!
//! The estimator runs linear-scan liveness over the lowered bytecode of
//! each innermost loop body and reports max-live virtual registers. A
//! compiler model turns that into a spill count: values the loop needs
//! live simultaneously beyond the architectural budget (minus the model's
//! allocator slack) spill to the stack every iteration.

use std::collections::HashMap;

use crate::lowering::bytecode::{CodeBlock, ExecNode, ExecProgram, Op};

use super::nodes::CompilerModel;

/// Pressure report for one innermost loop.
#[derive(Debug, Clone)]
pub struct LoopPressure {
    /// Max simultaneously-live integer registers (incl. loop-invariants:
    /// bounds, strides, parameters, cursors, base pointers).
    pub int_live: usize,
    /// Max live FP registers.
    pub fp_live: usize,
    /// Ops per iteration (cost accounting).
    pub ops_per_iter: usize,
    /// Integer (index-arithmetic) ops per iteration — §4.2: "stride
    /// calculations increase the register count".
    pub index_ops_per_iter: usize,
    /// Memory accesses per iteration.
    pub accesses_per_iter: usize,
}

impl LoopPressure {
    /// Effective integer pressure under a compiler model: measured
    /// max-live plus the in-flight address-arithmetic chains the compiler
    /// keeps alive while software-pipelining/unrolling the loop (one extra
    /// live value per `sched_window` index ops — the §4.2 mechanism that
    /// pointer incrementation removes).
    pub fn effective_int_live(&self, cm: &CompilerModel) -> usize {
        // Capped: a compiler keeps at most a handful of address chains in
        // flight regardless of loop size.
        self.int_live + (self.index_ops_per_iter / cm.sched_window).min(8)
    }

    /// Spills under a compiler model (§4.2's motivation).
    pub fn spills(&self, cm: &CompilerModel) -> usize {
        let int_avail = cm.int_regs.saturating_sub(cm.alloc_slack);
        let fp_avail = cm.fp_regs.saturating_sub(cm.alloc_slack / 2);
        self.effective_int_live(cm).saturating_sub(int_avail)
            + self.fp_live.saturating_sub(fp_avail)
    }
}

/// Whole-program pressure report: per innermost loop, plus the worst one.
#[derive(Debug, Clone, Default)]
pub struct PressureReport {
    pub loops: Vec<LoopPressure>,
}

impl PressureReport {
    pub fn worst(&self) -> Option<&LoopPressure> {
        self.loops.iter().max_by_key(|l| l.int_live + l.fp_live)
    }

    pub fn total_spills(&self, cm: &CompilerModel) -> usize {
        self.loops.iter().map(|l| l.spills(cm)).sum()
    }

    pub fn worst_spills(&self, cm: &CompilerModel) -> usize {
        self.worst().map(|l| l.spills(cm)).unwrap_or(0)
    }
}

/// Analyze every innermost loop in the lowered program.
pub fn analyze(prog: &ExecProgram) -> PressureReport {
    let mut report = PressureReport::default();
    for node in &prog.root {
        walk(node, &mut report);
    }
    report
}

fn walk(node: &ExecNode, report: &mut PressureReport) {
    match node {
        ExecNode::Code(block) => {
            for range in innermost_loop_ranges(block) {
                report.loops.push(pressure_of(block, range));
            }
        }
        ExecNode::Loop(l) => {
            // Tree loops: recurse; if the body is a single Code block whose
            // flat loops are the innermost ones they are handled there. A
            // leaf tree-loop body of straight-line code is itself an
            // innermost loop.
            let has_inner_loop = l.body.iter().any(|n| match n {
                ExecNode::Loop(_) => true,
                ExecNode::Code(b) => !innermost_loop_ranges(b).is_empty(),
            });
            if has_inner_loop {
                for n in &l.body {
                    walk(n, report);
                }
            } else {
                // Concatenate body blocks as one iteration body.
                let mut combined = CodeBlock::default();
                for n in &l.body {
                    if let ExecNode::Code(b) = n {
                        combined.ops.extend(b.ops.iter().copied());
                    }
                }
                combined.ops.extend(l.post_body.ops.iter().copied());
                let range = 0..combined.ops.len();
                report.loops.push(pressure_of(&combined, range));
            }
        }
    }
}

/// Byte ranges of innermost flat loops: a `LoopCond` whose body (up to its
/// back-jump) contains no further `LoopCond`.
fn innermost_loop_ranges(block: &CodeBlock) -> Vec<std::ops::Range<usize>> {
    let mut out = Vec::new();
    for (i, op) in block.ops.iter().enumerate() {
        if let Op::LoopCond { exit, .. } = op {
            let body = i + 1..(*exit as usize).saturating_sub(1).min(block.ops.len());
            let inner = block.ops[body.clone()]
                .iter()
                .any(|o| matches!(o, Op::LoopCond { .. }));
            if !inner {
                out.push(body);
            }
        }
    }
    out
}

/// Linear-scan max-live over one op range. Loop-invariant inputs (regs
/// read before being defined in the range) count as live throughout —
/// they occupy architectural registers across the whole loop, exactly the
/// pressure §4.2 says parametric-stride index arithmetic creates.
fn pressure_of(block: &CodeBlock, range: std::ops::Range<usize>) -> LoopPressure {
    let ops = &block.ops[range.clone()];
    // Last use position per register (int/float spaces separate).
    let mut int_last: HashMap<u16, usize> = HashMap::new();
    let mut fp_last: HashMap<u16, usize> = HashMap::new();
    let mut int_def: HashMap<u16, usize> = HashMap::new();
    let mut fp_def: HashMap<u16, usize> = HashMap::new();
    let mut accesses = 0usize;
    let mut index_ops = 0usize;
    for (pos, op) in ops.iter().enumerate() {
        let (iu, id, fu, fd) = uses_defs(op);
        for r in iu {
            int_last.insert(r, pos);
            int_def.entry(r).or_insert(0); // read-before-def ⇒ invariant
        }
        for r in fu {
            fp_last.insert(r, pos);
            fp_def.entry(r).or_insert(0);
        }
        if let Some(r) = id {
            int_def.entry(r).or_insert(pos);
            int_last.entry(r).or_insert(pos);
        }
        if let Some(r) = fd {
            fp_def.entry(r).or_insert(pos);
            fp_last.entry(r).or_insert(pos);
        }
        if matches!(
            op,
            Op::Load { .. }
                | Op::LoadOff { .. }
                | Op::LoadAt2 { .. }
                | Op::Store { .. }
                | Op::StoreOff { .. }
                | Op::StoreF32 { .. }
                | Op::StoreOffF32 { .. }
        ) {
            accesses += 1;
        }
        if matches!(
            op,
            Op::IConst { .. }
                | Op::ICopy { .. }
                | Op::IAdd { .. }
                | Op::IAddImm { .. }
                | Op::ISub { .. }
                | Op::IMul { .. }
                | Op::IMulImm { .. }
                | Op::IFloorDiv { .. }
                | Op::IMod { .. }
                | Op::IMin { .. }
                | Op::IMax { .. }
                | Op::IPow { .. }
                | Op::ILog2 { .. }
                | Op::IAbs { .. }
        ) {
            index_ops += 1;
        }
    }
    // Loop-invariants stay live to the end (used again next iteration).
    for (r, d) in &int_def {
        if *d == 0 {
            int_last.insert(*r, ops.len());
        }
    }
    for (r, d) in &fp_def {
        if *d == 0 {
            fp_last.insert(*r, ops.len());
        }
    }
    // Sweep: count live intervals.
    let max_live = |def: &HashMap<u16, usize>, last: &HashMap<u16, usize>| -> usize {
        let mut events: Vec<(usize, i32)> = Vec::new();
        for (r, d) in def {
            let l = last.get(r).copied().unwrap_or(*d);
            events.push((*d, 1));
            events.push((l + 1, -1));
        }
        events.sort();
        let mut live = 0i32;
        let mut max = 0i32;
        for (_, e) in events {
            live += e;
            max = max.max(live);
        }
        max as usize
    };
    LoopPressure {
        int_live: max_live(&int_def, &int_last),
        fp_live: max_live(&fp_def, &fp_last),
        ops_per_iter: ops.len(),
        index_ops_per_iter: index_ops,
        accesses_per_iter: accesses,
    }
}

/// (int uses, int def, float uses, float def) of an op. Shared with the
/// native JIT, which seeds its register pinning from this model.
#[allow(clippy::type_complexity)]
pub(crate) fn uses_defs(op: &Op) -> (Vec<u16>, Option<u16>, Vec<u16>, Option<u16>) {
    use Op::*;
    match *op {
        IConst { dst, .. } => (vec![], Some(dst), vec![], None),
        ICopy { dst, src } => (vec![src], Some(dst), vec![], None),
        IAdd { dst, a, b } | ISub { dst, a, b } | IMul { dst, a, b } | IFloorDiv { dst, a, b }
        | IMod { dst, a, b } | IMin { dst, a, b } | IMax { dst, a, b } => {
            (vec![a, b], Some(dst), vec![], None)
        }
        IAddImm { dst, a, .. } | IMulImm { dst, a, .. } => (vec![a], Some(dst), vec![], None),
        IPow { dst, a, .. } | ILog2 { dst, a } | IAbs { dst, a } => {
            (vec![a], Some(dst), vec![], None)
        }
        FConst { dst, .. } => (vec![], None, vec![], Some(dst)),
        FCopy { dst, src } => (vec![], None, vec![src], Some(dst)),
        FAdd { dst, a, b } | FSub { dst, a, b } | FMul { dst, a, b } | FDiv { dst, a, b }
        | FMin { dst, a, b } | FMax { dst, a, b } => (vec![], None, vec![a, b], Some(dst)),
        FPow { dst, a, .. } | FExp { dst, a } | FSqrt { dst, a } | FAbs { dst, a }
        | FLog2 { dst, a } | FFloor { dst, a } => (vec![], None, vec![a], Some(dst)),
        FSelect { dst, cond, a, b } => (vec![], None, vec![cond, a, b], Some(dst)),
        FFromI { dst, src } => (vec![src], None, vec![], Some(dst)),
        Load { dst, idx, .. } => (vec![idx], None, vec![], Some(dst)),
        LoadOff { dst, idx, .. } => (vec![idx], None, vec![], Some(dst)),
        LoadAt2 { dst, a, b, .. } => (vec![a, b], None, vec![], Some(dst)),
        Store { idx, src, .. } => (vec![idx], None, vec![src], None),
        StoreOff { idx, src, .. } => (vec![idx], None, vec![src], None),
        StoreF32 { idx, src, .. } => (vec![idx], None, vec![src], None),
        StoreOffF32 { idx, src, .. } => (vec![idx], None, vec![src], None),
        Prefetch { idx, .. } => (vec![idx], None, vec![], None),
        BoundsCheck { idx, .. } => (vec![idx], None, vec![], None),
        Jump { .. } | Halt => (vec![], None, vec![], None),
        LoopCond { var, end, stride, .. } => (vec![var, end, stride], None, vec![], None),
        GuardSkip { cond, .. } => (vec![], None, vec![cond], None),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::ProgramBuilder;
    use crate::lowering::lower;
    use crate::machine::nodes::{clang, gcc};
    use crate::symbolic::{int, load, Expr};

    /// Pointer incrementation must reduce measured int pressure on the
    /// parametric-stride Laplace pattern (the Fig. 1 mechanism).
    #[test]
    fn ptr_inc_reduces_pressure() {
        let build = |ptr_inc: bool| {
            let mut b = ProgramBuilder::new("ra1");
            let n = b.param_positive("ra1_N");
            let (isi, isj) = (b.param_positive("ra1_isI"), b.param_positive("ra1_isJ"));
            let (lsi, lsj) = (b.param_positive("ra1_lsI"), b.param_positive("ra1_lsJ"));
            let input = b.array("in", (Expr::Sym(n) + int(2)) * (Expr::Sym(isi) + Expr::Sym(isj)));
            let lap = b.array("lap", (Expr::Sym(n) + int(2)) * (Expr::Sym(lsi) + Expr::Sym(lsj)));
            let i = b.sym("ra1_i");
            let j = b.sym("ra1_j");
            b.for_(j, int(1), Expr::Sym(n), int(1), |b| {
                b.for_(i, int(1), Expr::Sym(n), int(1), |b| {
                    let at = |di: i64, dj: i64| {
                        (Expr::Sym(i) + int(di)) * Expr::Sym(isi)
                            + (Expr::Sym(j) + int(dj)) * Expr::Sym(isj)
                    };
                    b.assign(
                        lap,
                        Expr::Sym(i) * Expr::Sym(lsi) + Expr::Sym(j) * Expr::Sym(lsj),
                        Expr::real(4.0) * load(input, at(0, 0))
                            - load(input, at(1, 0))
                            - load(input, at(-1, 0))
                            - load(input, at(0, 1))
                            - load(input, at(0, -1)),
                    );
                });
            });
            let mut p = b.finish();
            if ptr_inc {
                crate::schedules::schedule_all_ptr_inc(&mut p);
            }
            analyze(&lower(&p).unwrap())
        };
        let naive = build(false);
        let opt = build(true);
        let cl = clang();
        let (n_live, o_live) = (
            naive.worst().unwrap().effective_int_live(&cl),
            opt.worst().unwrap().effective_int_live(&cl),
        );
        assert!(
            o_live < n_live,
            "ptr-inc should cut effective int pressure: {n_live} -> {o_live}"
        );
        // The Fig. 1 shape: the naive parametric-stride loop spills under
        // both compilers; the cursor version spills (much) less.
        assert!(naive.worst_spills(&clang()) > opt.worst_spills(&clang()));
        assert!(naive.worst_spills(&gcc()) > naive.worst_spills(&clang()));
        // gcc (more slack wasted) spills at least as much as clang.
        assert!(naive.worst_spills(&gcc()) >= naive.worst_spills(&clang()));
    }

    #[test]
    fn trivial_loop_fits_registers() {
        let mut b = ProgramBuilder::new("ra2");
        let n = b.param_positive("ra2_N");
        let a = b.array("A", Expr::Sym(n));
        let i = b.sym("ra2_i");
        b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
            b.assign(a, Expr::Sym(i), Expr::real(1.0));
        });
        let p = b.finish();
        let rep = analyze(&lower(&p).unwrap());
        assert_eq!(rep.loops.len(), 1);
        assert_eq!(rep.worst_spills(&clang()), 0);
    }
}
