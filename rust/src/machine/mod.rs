//! Simulated-machine models: cache hierarchy + hardware prefetcher
//! ([`cache`]), register-pressure/spill estimation over the lowered
//! bytecode ([`regalloc`]), the cycle cost model ([`cost`]), node and
//! compiler models ([`nodes`]), and the multicore makespan simulator
//! ([`simsched`]). Together these stand in for the paper's testbed
//! (DESIGN.md §Substitutions).
//!
//! Besides powering the experiment harnesses, this layer is the
//! *decision oracle* of the optimizer: the cost-gated schedule stages in
//! `transforms::pipeline` and the whole `tuner` search rank candidate
//! schedules by [`cycles_per_iteration`] (op mix + spill penalties from
//! [`analyze`]) — so every number the optimizer acts on is derived from
//! the actual lowered program, not from constants.

pub mod cache;
pub mod cost;
pub mod nodes;
pub mod regalloc;
pub mod simsched;

pub use cache::{CacheCfg, CacheSim, CacheStats, LevelCfg};
pub use cost::{cycles_per_iteration, modeled_ms, op_cost};
pub use nodes::{all_compilers, amd_node, clang, gcc, icc, intel_node, CompilerModel, NodeModel};
pub use regalloc::{analyze, LoopPressure, PressureReport};
pub use simsched::{
    barriered_phases, doacross_grid, doacross_grid_segmented, doall_phase, makespan, seq_chain,
    Task,
};
