//! Simulated-machine models: cache hierarchy + hardware prefetcher,
//! register-pressure/spill estimation, cycle cost model, node/compiler
//! models, and the multicore makespan simulator. Together these stand in
//! for the paper's testbed (DESIGN.md §Substitutions).

pub mod cache;
pub mod cost;
pub mod nodes;
pub mod regalloc;
pub mod simsched;

pub use cache::{CacheCfg, CacheSim, CacheStats, LevelCfg};
pub use cost::{cycles_per_iteration, modeled_ms, op_cost};
pub use nodes::{all_compilers, amd_node, clang, gcc, icc, intel_node, CompilerModel, NodeModel};
pub use regalloc::{analyze, LoopPressure, PressureReport};
pub use simsched::{barriered_phases, doacross_grid, doacross_grid_segmented, doall_phase, makespan, seq_chain, Task};
