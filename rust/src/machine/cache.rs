//! Trace-driven set-associative cache hierarchy with a hardware stream
//! prefetcher (substitute for the paper's Xeon 6140 / EPYC 7742 memory
//! subsystems — see DESIGN.md §Substitutions).
//!
//! The hierarchy is fed element-granular accesses from the VM trace hook
//! and charges cycles per level. The stream prefetcher models the behavior
//! Table 1 depends on: it locks onto constant strides within a page and
//! prefetches ahead, but *mispredicts at sudden stride changes* — exactly
//! what software prefetch hints (§4.1) compensate for.

/// Geometry + latency of one cache level.
#[derive(Debug, Clone, Copy)]
pub struct LevelCfg {
    pub size_bytes: u64,
    pub ways: u64,
    pub latency: u64,
}

/// Full hierarchy configuration.
#[derive(Debug, Clone, Copy)]
pub struct CacheCfg {
    pub line_bytes: u64,
    pub l1: LevelCfg,
    pub l2: LevelCfg,
    pub l3: LevelCfg,
    pub mem_latency: u64,
    /// Stream-prefetcher lookahead (lines).
    pub pf_degree: u64,
    /// Consecutive same-stride accesses needed before the HW prefetcher
    /// locks on.
    pub pf_train: u32,
}

impl CacheCfg {
    /// Scaled-down Skylake-SP-like geometry (Intel node). The working-set
    /// scaling rule (DESIGN.md): kernel sizes are scaled ~8× down from the
    /// paper's, so cache capacities scale with them to preserve which
    /// level each working set spills out of.
    pub fn intel_scaled() -> CacheCfg {
        CacheCfg {
            line_bytes: 64,
            l1: LevelCfg {
                size_bytes: 32 * 1024,
                ways: 8,
                latency: 4,
            },
            l2: LevelCfg {
                size_bytes: 256 * 1024,
                ways: 16,
                latency: 14,
            },
            l3: LevelCfg {
                size_bytes: 4 * 1024 * 1024,
                ways: 11,
                latency: 50,
            },
            mem_latency: 200,
            pf_degree: 2,
            pf_train: 2,
        }
    }

    /// Zen-2-like geometry (AMD node): bigger L3 slices, faster memory
    /// relative to core, more aggressive prefetcher — the reason Table 1
    /// shows almost no SW-prefetch benefit for gcc on AMD.
    pub fn amd_scaled() -> CacheCfg {
        CacheCfg {
            line_bytes: 64,
            l1: LevelCfg {
                size_bytes: 32 * 1024,
                ways: 8,
                latency: 4,
            },
            l2: LevelCfg {
                size_bytes: 512 * 1024,
                ways: 8,
                latency: 12,
            },
            l3: LevelCfg {
                size_bytes: 8 * 1024 * 1024,
                ways: 16,
                latency: 40,
            },
            mem_latency: 170,
            pf_degree: 4,
            pf_train: 2,
        }
    }
}

impl CacheCfg {
    /// Shrink L2/L3 in proportion to a scaled-down working set (DESIGN.md
    /// §Substitutions: the paper's 4096² matmul streams 128 MB arrays past
    /// a 25 MB L3; the scaled 256² arrays must likewise exceed the scaled
    /// L3 for the same level transitions to occur).
    pub fn scaled_for_streaming(mut self) -> CacheCfg {
        self.l2.size_bytes /= 4;
        self.l3.size_bytes /= 16;
        self
    }
}

/// One set-associative level with LRU replacement.
struct Level {
    sets: Vec<Vec<(u64, u64)>>, // (tag, last-use stamp)
    n_sets: u64,
    ways: usize,
    shift: u32,
}

impl Level {
    fn new(cfg: LevelCfg, line: u64) -> Level {
        let n_sets = (cfg.size_bytes / line / cfg.ways).max(1);
        Level {
            sets: (0..n_sets).map(|_| Vec::new()).collect(),
            n_sets,
            ways: cfg.ways as usize,
            shift: line.trailing_zeros(),
        }
    }

    /// Returns true on hit; inserts on miss.
    fn access(&mut self, addr: u64, stamp: u64) -> bool {
        let line = addr >> self.shift;
        let set = (line % self.n_sets) as usize;
        let s = &mut self.sets[set];
        if let Some(e) = s.iter_mut().find(|(tag, _)| *tag == line) {
            e.1 = stamp;
            return true;
        }
        if s.len() >= self.ways {
            // Evict LRU.
            let lru = s
                .iter()
                .enumerate()
                .min_by_key(|(_, (_, t))| *t)
                .map(|(i, _)| i)
                .unwrap();
            s.swap_remove(lru);
        }
        s.push((line, stamp));
        false
    }

    fn insert(&mut self, addr: u64, stamp: u64) {
        let _ = self.access(addr, stamp);
    }
}

/// Per-4KiB-page stream detector.
#[derive(Clone, Copy, Default)]
struct Stream {
    page: u64,
    last_addr: u64,
    stride: i64,
    confidence: u32,
    valid: bool,
}

/// Hierarchy statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheStats {
    pub accesses: u64,
    pub l1_hits: u64,
    pub l2_hits: u64,
    pub l3_hits: u64,
    pub mem_accesses: u64,
    pub hw_prefetches: u64,
    pub sw_prefetches: u64,
    pub cycles: u64,
}

impl CacheStats {
    pub fn miss_rate_l1(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            1.0 - self.l1_hits as f64 / self.accesses as f64
        }
    }

    /// Cache lines transferred from L3/DRAM toward the core (demand fills
    /// past L2 + hardware prefetch fills) — the bandwidth the access
    /// pattern consumes. Strided walks (K-outer vadv) move a full 64-byte
    /// line per 8-byte element; streaming walks amortize it 8×.
    pub fn traffic_lines(&self) -> u64 {
        self.l3_hits + self.mem_accesses + self.hw_prefetches
    }

    /// Cycles the transfer bandwidth alone needs at `bytes_per_cycle`
    /// sustained (per-core share). The effective memory cost of a run is
    /// `max(latency cycles, bandwidth cycles)`.
    pub fn bandwidth_cycles(&self, line_bytes: u64, bytes_per_cycle: f64) -> u64 {
        ((self.traffic_lines() * line_bytes) as f64 / bytes_per_cycle) as u64
    }

    /// Effective memory cycles: latency- or bandwidth-bound, whichever
    /// dominates.
    pub fn effective_cycles(&self, line_bytes: u64, bytes_per_cycle: f64) -> u64 {
        self.cycles.max(self.bandwidth_cycles(line_bytes, bytes_per_cycle))
    }
}

/// The simulated hierarchy.
pub struct CacheSim {
    cfg: CacheCfg,
    l1: Level,
    l2: Level,
    l3: Level,
    streams: Vec<Stream>,
    stamp: u64,
    pub stats: CacheStats,
}

impl CacheSim {
    pub fn new(cfg: CacheCfg) -> CacheSim {
        CacheSim {
            l1: Level::new(cfg.l1, cfg.line_bytes),
            l2: Level::new(cfg.l2, cfg.line_bytes),
            l3: Level::new(cfg.l3, cfg.line_bytes),
            streams: vec![Stream::default(); 64],
            stamp: 0,
            cfg,
            stats: CacheStats::default(),
        }
    }

    /// Demand access; returns cycles charged.
    pub fn access(&mut self, addr: u64, _write: bool) -> u64 {
        self.stamp += 1;
        self.stats.accesses += 1;
        let cycles = self.lookup_fill(addr);
        self.stats.cycles += cycles;
        self.train_prefetcher(addr);
        cycles
    }

    /// Software prefetch (§4.1): pulls the line toward L1 in the
    /// background. Charged a fixed small issue cost; the payoff is the
    /// avoided demand miss later.
    pub fn sw_prefetch(&mut self, addr: u64, _write: bool) -> u64 {
        self.stamp += 1;
        self.stats.sw_prefetches += 1;
        self.fill_all(addr);
        let issue = 1;
        self.stats.cycles += issue;
        issue
    }

    fn lookup_fill(&mut self, addr: u64) -> u64 {
        if self.l1.access(addr, self.stamp) {
            self.stats.l1_hits += 1;
            return self.cfg.l1.latency;
        }
        if self.l2.access(addr, self.stamp) {
            self.stats.l2_hits += 1;
            self.l1.insert(addr, self.stamp);
            return self.cfg.l2.latency;
        }
        if self.l3.access(addr, self.stamp) {
            self.stats.l3_hits += 1;
            self.l1.insert(addr, self.stamp);
            self.l2.insert(addr, self.stamp);
            return self.cfg.l3.latency;
        }
        self.stats.mem_accesses += 1;
        self.fill_all(addr);
        self.cfg.mem_latency
    }

    fn fill_all(&mut self, addr: u64) {
        self.l1.insert(addr, self.stamp);
        self.l2.insert(addr, self.stamp);
        self.l3.insert(addr, self.stamp);
    }

    fn train_prefetcher(&mut self, addr: u64) {
        let page = addr >> 12;
        let slot = (page % self.streams.len() as u64) as usize;
        let s = &mut self.streams[slot];
        if s.valid && s.page == page {
            let stride = addr as i64 - s.last_addr as i64;
            if stride != 0 && stride == s.stride {
                s.confidence += 1;
            } else {
                s.stride = stride;
                s.confidence = 1;
            }
            s.last_addr = addr;
            if s.confidence >= self.cfg.pf_train && s.stride != 0 {
                // Locked on: prefetch the lines the stream will touch next.
                let stride = s.stride;
                let degree = self.cfg.pf_degree;
                for d in 1..=degree {
                    let target = addr as i64 + stride * d as i64;
                    // Hardware prefetchers do not cross 4 KiB page
                    // boundaries — the cold misses at page/tile
                    // transitions are what §4.1's software hints cover.
                    if target >= 0 && (target as u64) >> 12 == page {
                        self.stats.hw_prefetches += 1;
                        let t = target as u64;
                        self.stamp += 1;
                        let stamp = self.stamp;
                        self.l1.insert(t, stamp);
                        self.l2.insert(t, stamp);
                        self.l3.insert(t, stamp);
                    }
                }
            }
        } else {
            *s = Stream {
                page,
                last_addr: addr,
                stride: 0,
                confidence: 0,
                valid: true,
            };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repeated_access_hits_l1() {
        let mut c = CacheSim::new(CacheCfg::intel_scaled());
        c.access(0x1000, false);
        let cyc = c.access(0x1000, false);
        assert_eq!(cyc, 4);
        assert_eq!(c.stats.l1_hits, 1);
    }

    #[test]
    fn streaming_trains_prefetcher() {
        let mut c = CacheSim::new(CacheCfg::intel_scaled());
        // Walk a page with stride 64: after training, later lines hit.
        let mut misses = 0;
        for i in 0..32u64 {
            let cyc = c.access(0x10000 + i * 64, false);
            if cyc > 14 {
                misses += 1;
            }
        }
        assert!(c.stats.hw_prefetches > 0);
        // Only the first few accesses miss; the stream covers the rest.
        assert!(misses <= 4, "misses={misses}");
    }

    #[test]
    fn sw_prefetch_hides_cold_miss() {
        let mut c = CacheSim::new(CacheCfg::intel_scaled());
        c.sw_prefetch(0x40000, false);
        let cyc = c.access(0x40000, false);
        assert_eq!(cyc, 4, "prefetched line must be an L1 hit");
    }

    #[test]
    fn capacity_eviction() {
        let mut c = CacheSim::new(CacheCfg::intel_scaled());
        // Touch far more than L1 capacity, then re-touch the first line:
        // it must have been evicted from L1 (but L2/L3 may keep it).
        c.access(0, false);
        for i in 1..4096u64 {
            c.access(i * 64, false);
        }
        let cyc = c.access(0, false);
        assert!(cyc > 4, "line 0 should have left L1 (got {cyc})");
    }

    #[test]
    fn stride_change_defeats_hw_prefetcher() {
        // Streaming with an abrupt jump: the access right after the jump
        // misses even though the stream before it was perfectly covered.
        let mut c = CacheSim::new(CacheCfg::intel_scaled());
        for i in 0..16u64 {
            c.access(0x100000 + i * 64, false);
        }
        // Sudden jump to a fresh region (different page).
        let cyc = c.access(0x900000, false);
        assert!(cyc >= c.cfg.mem_latency, "jump target should cold-miss");
    }
}
