//! Event-driven multicore makespan simulator (the Fig. 9 substitute for
//! the paper's 36-core node — see DESIGN.md §Substitutions).
//!
//! The simulator executes an explicit task DAG on `workers` cores with
//! greedy list scheduling: whenever a core is free, it picks the ready
//! task with the earliest ready-time. Builders below construct the DAGs
//! the evaluated schedules induce: fork-join DOALL phases, sequential
//! chains, and DOACROSS pipelines with per-chunk δ-distance edges.

/// One schedulable task.
#[derive(Debug, Clone)]
pub struct Task {
    pub cost: f64,
    /// Indices of tasks that must complete first.
    pub deps: Vec<usize>,
}

/// Greedy list-scheduled makespan of a DAG on `workers` cores. Cost unit
/// is cycles; `per_task_overhead` models dispatch/sync cost.
pub fn makespan(tasks: &[Task], workers: usize, per_task_overhead: f64) -> f64 {
    if tasks.is_empty() {
        return 0.0;
    }
    let n = tasks.len();
    let workers = workers.max(1);
    // ready_time[i] = max over deps of finish time; computed lazily.
    let mut finish = vec![f64::NAN; n];
    let mut indeg: Vec<usize> = tasks.iter().map(|t| t.deps.len()).collect();
    let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, t) in tasks.iter().enumerate() {
        for &d in &t.deps {
            dependents[d].push(i);
        }
    }
    let mut ready_time = vec![0f64; n];
    // Min-heaps via sorted vecs would be O(n²); use BinaryHeap with reverse.
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    #[derive(PartialEq)]
    struct F(f64);
    impl Eq for F {}
    impl PartialOrd for F {
        fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(other))
        }
    }
    impl Ord for F {
        fn cmp(&self, other: &Self) -> std::cmp::Ordering {
            self.0.partial_cmp(&other.0).unwrap_or(std::cmp::Ordering::Equal)
        }
    }

    // Ready queue ordered by ready_time (then index for determinism).
    let mut ready: BinaryHeap<Reverse<(F, usize)>> = BinaryHeap::new();
    for i in 0..n {
        if indeg[i] == 0 {
            ready.push(Reverse((F(0.0), i)));
        }
    }
    // Worker pool: finish times.
    let mut cores: BinaryHeap<Reverse<F>> = BinaryHeap::new();
    for _ in 0..workers {
        cores.push(Reverse(F(0.0)));
    }
    let mut done = 0usize;
    let mut total_end = 0f64;
    while let Some(Reverse((F(rt), i))) = ready.pop() {
        let Reverse(F(core_free)) = cores.pop().unwrap();
        let start = rt.max(core_free);
        let end = start + tasks[i].cost + per_task_overhead;
        finish[i] = end;
        total_end = total_end.max(end);
        cores.push(Reverse(F(end)));
        done += 1;
        for &d in &dependents[i] {
            indeg[d] -= 1;
            ready_time[d] = ready_time[d].max(end);
            if indeg[d] == 0 {
                ready.push(Reverse((F(ready_time[d]), d)));
            }
        }
    }
    debug_assert_eq!(done, n, "cyclic task graph");
    total_end
}

/// Fork-join DOALL phase: `n` independent tasks of equal `cost`.
pub fn doall_phase(n: usize, cost: f64) -> Vec<Task> {
    (0..n).map(|_| Task { cost, deps: vec![] }).collect()
}

/// Sequential chain: `n` tasks each depending on the previous.
pub fn seq_chain(n: usize, cost: f64) -> Vec<Task> {
    (0..n)
        .map(|i| Task {
            cost,
            deps: if i == 0 { vec![] } else { vec![i - 1] },
        })
        .collect()
}

/// DOACROSS pipeline grid (the cfg2 vadv schedule): `k_steps × chunks`
/// tasks; task `(k, c)` depends on `(k−δ, c)` — the paper's iteration
/// vector `(k−δ, i)` aggregated to chunk granularity. `cost` is the work
/// of one chunk at one k.
pub fn doacross_grid(k_steps: usize, chunks: usize, delta: usize, cost: f64) -> Vec<Task> {
    let mut tasks = Vec::with_capacity(k_steps * chunks);
    for k in 0..k_steps {
        for c in 0..chunks {
            let mut deps = Vec::new();
            if k >= delta {
                deps.push((k - delta) * chunks + c);
            }
            tasks.push(Task { cost, deps });
        }
    }
    tasks
}

/// Segmented DOACROSS grid — the schedule §3.3.2's code motion produces:
/// each `(k, c)` iteration is a *parallel* segment (the statements moved
/// before the wait) followed by a *dependent* segment that waits on
/// `(k−δ, c)`'s dependent segment. With enough workers the parallel
/// segments all overlap and only the dependent chain serializes:
/// `T ≈ par_cost + k·dep_cost` instead of `k·(par_cost + dep_cost)`.
pub fn doacross_grid_segmented(
    k_steps: usize,
    chunks: usize,
    delta: usize,
    par_cost: f64,
    dep_cost: f64,
) -> Vec<Task> {
    // Task ids: par(k,c) = 2·(k·chunks + c), dep(k,c) = par(k,c) + 1.
    let mut tasks = Vec::with_capacity(2 * k_steps * chunks);
    for k in 0..k_steps {
        for c in 0..chunks {
            let par_id = tasks.len();
            tasks.push(Task {
                cost: par_cost,
                deps: vec![],
            });
            let mut deps = vec![par_id];
            if k >= delta {
                deps.push(2 * ((k - delta) * chunks + c) + 1);
            }
            tasks.push(Task {
                cost: dep_cost,
                deps,
            });
        }
    }
    tasks
}

/// K sequential phases of `chunks`-wide DOALL work with a barrier between
/// phases (the baseline "parallelize I×J inside sequential K" schedule).
pub fn barriered_phases(k_steps: usize, chunks: usize, cost: f64) -> Vec<Task> {
    let mut tasks: Vec<Task> = Vec::with_capacity(k_steps * chunks);
    for k in 0..k_steps {
        for _c in 0..chunks {
            let deps = if k == 0 {
                vec![]
            } else {
                // Barrier: depend on every task of the previous phase.
                ((k - 1) * chunks..k * chunks).collect()
            };
            tasks.push(Task { cost, deps });
        }
    }
    tasks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn doall_scales_linearly() {
        let tasks = doall_phase(64, 100.0);
        let t1 = makespan(&tasks, 1, 0.0);
        let t8 = makespan(&tasks, 8, 0.0);
        assert_eq!(t1, 6400.0);
        assert_eq!(t8, 800.0);
    }

    #[test]
    fn chain_does_not_scale() {
        let tasks = seq_chain(10, 50.0);
        assert_eq!(makespan(&tasks, 1, 0.0), 500.0);
        assert_eq!(makespan(&tasks, 8, 0.0), 500.0);
    }

    #[test]
    fn doacross_pipeline_beats_barriers() {
        // 16 k-steps, 4 chunks, δ=1: pipeline fills and all 4 chunks run
        // concurrently; barriers serialize phases.
        let pipe = doacross_grid(16, 4, 1, 100.0);
        let barr = barriered_phases(16, 4, 100.0);
        let workers = 8;
        let t_pipe = makespan(&pipe, workers, 0.0);
        let t_barr = makespan(&barr, workers, 0.0);
        assert!(
            t_pipe <= t_barr,
            "pipeline {t_pipe} should not exceed barriered {t_barr}"
        );
        // The segmented pipeline (code motion moved independent statements
        // before the wait) overlaps the parallel segments across k:
        // strictly better than barriered phases when work is narrow.
        let narrow_pipe =
            makespan(&doacross_grid_segmented(64, 2, 1, 70.0, 30.0), workers, 0.0);
        let narrow_barr = makespan(&barriered_phases(64, 2, 100.0), workers, 0.0);
        assert!(
            narrow_pipe < narrow_barr,
            "segmented pipe {narrow_pipe} vs barrier {narrow_barr}"
        );
        // Asymptotics: ≈ par + k·dep, far below k·(par+dep).
        assert!(narrow_pipe < 0.55 * narrow_barr);
    }

    #[test]
    fn overheads_accumulate() {
        let tasks = doall_phase(4, 100.0);
        let t = makespan(&tasks, 1, 10.0);
        assert_eq!(t, 440.0);
    }

    #[test]
    fn diamond_dag() {
        // 0 → {1, 2} → 3
        let tasks = vec![
            Task { cost: 10.0, deps: vec![] },
            Task { cost: 20.0, deps: vec![0] },
            Task { cost: 30.0, deps: vec![0] },
            Task { cost: 5.0, deps: vec![1, 2] },
        ];
        assert_eq!(makespan(&tasks, 2, 0.0), 45.0);
        assert_eq!(makespan(&tasks, 1, 0.0), 65.0);
    }
}
