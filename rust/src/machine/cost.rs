//! Per-iteration cycle cost model: turns lowered bytecode + a compiler
//! model + register pressure into cycles/iteration, and whole-program
//! trace-driven runs into milliseconds.

use crate::lowering::bytecode::{ExecProgram, Op};

use super::nodes::{CompilerModel, NodeModel};
use super::regalloc::{analyze, PressureReport};

/// Throughput cost (cycles) of one op on a modern OoO core, assuming
/// reasonable ILP (the model divides the dependence-free op mix by a
/// superscalar factor below).
pub fn op_cost(op: &Op) -> f64 {
    use Op::*;
    match op {
        IConst { .. } | ICopy { .. } | FConst { .. } | FCopy { .. } => 0.3,
        IAdd { .. } | IAddImm { .. } | ISub { .. } | IMin { .. } | IMax { .. } | IAbs { .. } => 0.5,
        IMul { .. } | IMulImm { .. } => 1.0,
        IFloorDiv { .. } | IMod { .. } => 15.0,
        IPow { .. } | ILog2 { .. } => 2.0,
        FAdd { .. } | FSub { .. } | FMul { .. } | FMin { .. } | FMax { .. } | FAbs { .. }
        | FFromI { .. } | FSelect { .. } | FFloor { .. } => 0.5,
        FDiv { .. } => 8.0,
        FPow { .. } => 4.0,
        FExp { .. } | FLog2 { .. } => 12.0,
        FSqrt { .. } => 9.0,
        // Demand accesses: L1-hit baseline; the cache model refines this
        // for trace-driven experiments.
        Load { .. } | LoadOff { .. } | LoadAt2 { .. } => 1.0,
        Store { .. } | StoreOff { .. } | StoreF32 { .. } | StoreOffF32 { .. } => 1.0,
        Prefetch { .. } => 0.5,
        // Compare + well-predicted branch (the in-bounds path).
        BoundsCheck { .. } => 0.5,
        Jump { .. } | LoopCond { .. } | GuardSkip { .. } | Halt => 0.5,
    }
}

/// Cycles per iteration of the worst innermost loop, under a compiler
/// model: op mix / superscalar width + spill penalties, scaled by the
/// model's code quality.
pub fn cycles_per_iteration(prog: &ExecProgram, cm: &CompilerModel) -> f64 {
    let pressure: PressureReport = analyze(prog);
    let Some(worst) = pressure.worst() else {
        return 1.0;
    };
    // Sum op costs over the worst innermost loop's body, issued on a
    // 4-wide out-of-order core (independent index arithmetic overlaps),
    // floored by the load/store-port throughput (2 accesses per cycle).
    let total_ops: f64 = total_op_cost(prog);
    let n_ops: usize = op_count(prog).max(1);
    let avg = total_ops / n_ops as f64;
    let issue = worst.ops_per_iter as f64 * avg / 4.0;
    let mem_floor = worst.accesses_per_iter as f64 * 0.5;
    let base = issue.max(mem_floor);
    let spills = pressure.worst_spills(cm) as f64;
    (base + spills * cm.spill_penalty) / cm.code_quality
}

fn total_op_cost(prog: &ExecProgram) -> f64 {
    let mut sum = 0.0;
    visit_ops(prog, &mut |op| sum += op_cost(op));
    sum
}

fn op_count(prog: &ExecProgram) -> usize {
    let mut n = 0;
    visit_ops(prog, &mut |_| n += 1);
    n
}

fn visit_ops(prog: &ExecProgram, f: &mut impl FnMut(&Op)) {
    fn node(n: &crate::lowering::bytecode::ExecNode, f: &mut impl FnMut(&Op)) {
        match n {
            crate::lowering::bytecode::ExecNode::Code(b) => b.ops.iter().for_each(|o| f(o)),
            crate::lowering::bytecode::ExecNode::Loop(l) => {
                for b in [
                    &l.start,
                    &l.end,
                    &l.stride,
                    &l.pre_body,
                    &l.prefetch,
                    &l.post_body,
                    &l.post_loop,
                ] {
                    b.ops.iter().for_each(|o| f(o));
                }
                for c in &l.body {
                    node(c, f);
                }
            }
        }
    }
    for n in &prog.root {
        node(n, f);
    }
}

/// Convert a measured VM wall-time ratio into a modeled runtime: the
/// experiments report `base_ms * (cycles_b / cycles_a)` style numbers so
/// compiler models shift measured ratios, never invent them.
pub fn modeled_ms(node: &NodeModel, cycles: f64) -> f64 {
    node.cycles_to_ms(cycles)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::ProgramBuilder;
    use crate::lowering::lower;
    use crate::machine::nodes::{clang, gcc};
    use crate::symbolic::{int, load, Expr};

    #[test]
    fn heavier_loops_cost_more() {
        let light = {
            let mut b = ProgramBuilder::new("cost_l");
            let n = b.param_positive("cost_N");
            let a = b.array("A", Expr::Sym(n));
            let i = b.sym("cost_i");
            b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
                b.assign(a, Expr::Sym(i), Expr::real(1.0));
            });
            lower(&b.finish()).unwrap()
        };
        let heavy = {
            let mut b = ProgramBuilder::new("cost_h");
            let n = b.param_positive("cost_N");
            let s1 = b.param_positive("cost_S1");
            let a = b.array("A", Expr::Sym(n) * Expr::Sym(s1) + int(16));
            let i = b.sym("cost_hi");
            b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
                let off = Expr::Sym(i) * Expr::Sym(s1);
                b.assign(
                    a,
                    off.clone(),
                    load(a, off.clone() + int(1))
                        + load(a, off.clone() + int(2))
                        + load(a, off.clone() + int(3)) * load(a, off + int(4)),
                );
            });
            lower(&b.finish()).unwrap()
        };
        let cl = clang();
        assert!(cycles_per_iteration(&heavy, &cl) > cycles_per_iteration(&light, &cl));
    }

    #[test]
    fn gcc_at_least_as_slow_as_clang() {
        let mut b = ProgramBuilder::new("cost_g");
        let n = b.param_positive("cost_gN");
        let a = b.array("A", Expr::Sym(n));
        let i = b.sym("cost_gi");
        b.for_(i, int(0), Expr::Sym(n), int(1), |b| {
            b.assign(a, Expr::Sym(i), load(a, Expr::Sym(i)) * Expr::real(2.0));
        });
        let prog = lower(&b.finish()).unwrap();
        assert!(cycles_per_iteration(&prog, &gcc()) >= cycles_per_iteration(&prog, &clang()));
    }
}
