//! Testbed models: the paper's two evaluation nodes and three compilers
//! (DESIGN.md §Substitutions — parameterized models standing in for
//! hardware/toolchains this sandbox does not have).

use super::cache::CacheCfg;

/// A machine-node model.
#[derive(Debug, Clone, Copy)]
pub struct NodeModel {
    pub name: &'static str,
    pub cores: usize,
    pub ghz: f64,
    pub cache: CacheCfg,
    /// Fork/join cost of a parallel region (cycles).
    pub fork_join_cycles: f64,
    /// Per-wait synchronization cost in a DOACROSS pipeline (cycles).
    pub sync_cycles: f64,
}

/// 2× Intel Xeon Gold 6140 (18 cores/socket, 2.3 GHz) — §6's Intel node.
pub fn intel_node() -> NodeModel {
    NodeModel {
        name: "intel-xeon-6140",
        cores: 36,
        ghz: 2.3,
        cache: CacheCfg::intel_scaled(),
        fork_join_cycles: 12_000.0,
        sync_cycles: 120.0,
    }
}

/// 2× AMD EPYC 7742 (64 cores/socket, 2.25 GHz) — §6's AMD node.
pub fn amd_node() -> NodeModel {
    NodeModel {
        name: "amd-epyc-7742",
        cores: 128,
        ghz: 2.25,
        cache: CacheCfg::amd_scaled(),
        fork_join_cycles: 16_000.0,
        sync_cycles: 150.0,
    }
}

impl NodeModel {
    pub fn cycles_to_ms(&self, cycles: f64) -> f64 {
        cycles / (self.ghz * 1e6)
    }
}

/// A compiler model: register budget, allocator quality, and how the
/// toolchain treats prefetching. Calibrated to reproduce the *shape* of
/// Fig. 1 / Table 1 / Fig. 10 (who wins, by roughly what factor), not the
/// absolute numbers of the authors' testbed.
#[derive(Debug, Clone, Copy)]
pub struct CompilerModel {
    pub name: &'static str,
    /// General-purpose integer registers the allocator can use (x86-64
    /// leaves ~14 after SP/BP and calling-convention reservations).
    pub int_regs: usize,
    /// Vector/FP registers.
    pub fp_regs: usize,
    /// Allocator quality: extra registers effectively wasted vs. the ideal
    /// allocation (gcc's allocator spills earlier than clang's — Fig. 1's
    /// 13 vs 6 spills on identical code).
    pub alloc_slack: usize,
    /// Cycle penalty per spilled value per iteration (store+reload).
    pub spill_penalty: f64,
    /// Scheduling window: index-arithmetic ops the compiler keeps in
    /// flight per extra live register (larger = better scheduler).
    pub sched_window: usize,
    /// Baseline scalar-code quality factor (IPC relative to clang = 1.0).
    pub code_quality: f64,
    /// Does the compiler emit the `__builtin_prefetch` hints we generate?
    pub honors_sw_prefetch: bool,
    /// Does the compiler already insert its own aggressive prefetching
    /// (icc) — making our hints redundant?
    pub auto_prefetch: bool,
}

pub fn gcc() -> CompilerModel {
    CompilerModel {
        name: "gcc",
        int_regs: 14,
        fp_regs: 16,
        alloc_slack: 3,
        spill_penalty: 3.0,
        sched_window: 3,
        code_quality: 0.92,
        honors_sw_prefetch: true,
        auto_prefetch: false,
    }
}

pub fn clang() -> CompilerModel {
    CompilerModel {
        name: "clang",
        int_regs: 14,
        fp_regs: 16,
        alloc_slack: 0,
        spill_penalty: 3.0,
        sched_window: 4,
        code_quality: 1.0,
        honors_sw_prefetch: true,
        auto_prefetch: false,
    }
}

pub fn icc() -> CompilerModel {
    CompilerModel {
        name: "icc",
        int_regs: 14,
        fp_regs: 16,
        alloc_slack: 1,
        spill_penalty: 3.0,
        sched_window: 4,
        code_quality: 0.97,
        honors_sw_prefetch: false,
        auto_prefetch: true,
    }
}

pub fn all_compilers() -> [CompilerModel; 3] {
    [gcc(), clang(), icc()]
}
