//! Minimal JSON for the wire protocol (the vendored crate set has no
//! serde): a value enum with a recursive-descent parser and a
//! deterministic serializer. Covers exactly what the service needs —
//! objects, arrays, strings with escapes, `f64` numbers, booleans, null.
//!
//! Number fidelity matters here: run outputs round-trip **bit-exactly**
//! for finite doubles, because Rust's `{}` formatting emits the shortest
//! decimal that parses back to the same bits (and `-0.0` is kept signed).
//! Non-finite values have no JSON representation and serialize as `null`.

use std::fmt;

/// A JSON value. Object keys keep insertion order — the protocol's maps
/// are small, and ordered output keeps responses deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse one JSON document (trailing non-whitespace is an error).
    pub fn parse(src: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: src.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }

    /// Object field lookup (first match; `None` on non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Integral numbers only (exact in f64, i.e. |n| ≤ 2⁵³).
    pub fn as_i64(&self) -> Option<i64> {
        let n = self.as_f64()?;
        if n.is_finite() && n.fract() == 0.0 && n.abs() <= 9_007_199_254_740_992.0 {
            Some(n as i64)
        } else {
            None
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(kv) => Some(kv),
            _ => None,
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(true) => f.write_str("true"),
            Json::Bool(false) => f.write_str("false"),
            Json::Num(n) => write_num(f, *n),
            Json::Str(s) => write_str(f, s),
            Json::Arr(v) => {
                f.write_str("[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{x}")?;
                }
                f.write_str("]")
            }
            Json::Obj(kv) => {
                f.write_str("{")?;
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_str(f, k)?;
                    f.write_str(":")?;
                    write!(f, "{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

fn write_num(f: &mut fmt::Formatter<'_>, n: f64) -> fmt::Result {
    if !n.is_finite() {
        return f.write_str("null"); // no JSON spelling for NaN/±inf
    }
    if n == 0.0 && n.is_sign_negative() {
        return f.write_str("-0.0"); // keep the sign bit round-trippable
    }
    if n.fract() == 0.0 && n.abs() < 9_007_199_254_740_992.0 {
        return write!(f, "{}", n as i64); // integral: no trailing ".0"
    }
    // Rust's shortest-round-trip formatting never uses exponents, so the
    // output is always a valid JSON number.
    write!(f, "{n}")
}

fn write_str(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> String {
        format!("{msg} at byte {}", self.i)
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn value(&mut self, depth: u32) -> Result<Json, String> {
        if depth > 64 {
            return Err(self.err("nesting too deep"));
        }
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c.is_ascii_digit() || c == b'-' => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("expected a JSON value"))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while matches!(self.peek(), Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')) {
            self.i += 1;
        }
        // Accepted bytes are all ASCII, so the slice is valid UTF-8.
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        match text.parse::<f64>() {
            Ok(n) if n.is_finite() => Ok(Json::Num(n)),
            _ => Err(format!("malformed number `{text}` at byte {start}")),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.i += 1; // opening quote
        let mut out: Vec<u8> = Vec::new();
        let mut buf = [0u8; 4];
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    // Input is &str and escapes decode to chars, so the
                    // bytes are valid UTF-8 by construction.
                    return Ok(String::from_utf8(out).expect("utf8 preserved"));
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = match self.peek() {
                        Some(b'"') => '"',
                        Some(b'\\') => '\\',
                        Some(b'/') => '/',
                        Some(b'n') => '\n',
                        Some(b'r') => '\r',
                        Some(b't') => '\t',
                        Some(b'b') => '\u{0008}',
                        Some(b'f') => '\u{000c}',
                        Some(b'u') => {
                            self.i += 1;
                            let c = self.unicode_escape()?;
                            out.extend_from_slice(c.encode_utf8(&mut buf).as_bytes());
                            continue;
                        }
                        _ => return Err(self.err("bad escape")),
                    };
                    self.i += 1;
                    out.extend_from_slice(c.encode_utf8(&mut buf).as_bytes());
                }
                Some(b) => {
                    self.i += 1;
                    out.push(b);
                }
            }
        }
    }

    /// `\uXXXX` body (cursor on the first hex digit); handles surrogate
    /// pairs. Consumes exactly what it parses — the caller `continue`s
    /// instead of applying its usual post-escape advance.
    fn unicode_escape(&mut self) -> Result<char, String> {
        let hi = self.hex4()?;
        if (0xD800..0xDC00).contains(&hi) {
            // High surrogate: require a following \uXXXX low surrogate.
            if self.b.get(self.i) == Some(&b'\\') && self.b.get(self.i + 1) == Some(&b'u') {
                self.i += 2;
                let lo = self.hex4()?;
                if (0xDC00..0xE000).contains(&lo) {
                    let c = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                    return char::from_u32(c).ok_or_else(|| self.err("bad surrogate pair"));
                }
            }
            return Err(self.err("unpaired surrogate"));
        }
        char::from_u32(hi).ok_or_else(|| self.err("bad unicode escape"))
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let mut v = 0u32;
        for _ in 0..4 {
            let d = match self.peek() {
                Some(c @ b'0'..=b'9') => (c - b'0') as u32,
                Some(c @ b'a'..=b'f') => (c - b'a' + 10) as u32,
                Some(c @ b'A'..=b'F') => (c - b'A' + 10) as u32,
                _ => return Err(self.err("bad \\u escape")),
            };
            v = v * 16 + d;
            self.i += 1;
        }
        Ok(v)
    }

    fn object(&mut self, depth: u32) -> Result<Json, String> {
        self.i += 1; // '{'
        let mut kv = Vec::new();
        self.skip_ws();
        if self.b.get(self.i) == Some(&b'}') {
            self.i += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.skip_ws();
            if self.b.get(self.i) != Some(&b'"') {
                return Err(self.err("expected an object key"));
            }
            let k = self.string()?;
            self.skip_ws();
            if self.b.get(self.i) != Some(&b':') {
                return Err(self.err("expected `:`"));
            }
            self.i += 1;
            let v = self.value(depth + 1)?;
            kv.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(kv));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self, depth: u32) -> Result<Json, String> {
        self.i += 1; // '['
        let mut v = Vec::new();
        self.skip_ws();
        if self.b.get(self.i) == Some(&b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(v: &Json) -> Json {
        Json::parse(&v.to_string()).unwrap()
    }

    #[test]
    fn values_round_trip() {
        let v = Json::Obj(vec![
            ("a".into(), Json::Num(1.0)),
            ("b".into(), Json::Arr(vec![Json::Null, Json::Bool(true), Json::Num(-2.5)])),
            ("weird \"key\"\n".into(), Json::Str("tab\t, slash \\, unicode é".into())),
            ("empty".into(), Json::Obj(vec![])),
        ]);
        assert_eq!(roundtrip(&v), v);
    }

    #[test]
    fn floats_round_trip_bit_exactly() {
        for x in [
            0.1f64,
            -0.0,
            1.0 / 3.0,
            f64::MIN_POSITIVE,
            -9.007199254740992e15,
            123456789.123456789,
            2.0f64.powi(60),
        ] {
            let v = roundtrip(&Json::Num(x));
            let y = v.as_f64().unwrap();
            assert_eq!(x.to_bits(), y.to_bits(), "{x} mangled to {y}");
        }
    }

    #[test]
    fn integral_numbers_print_without_fraction() {
        assert_eq!(Json::Num(64.0).to_string(), "64");
        assert_eq!(Json::Num(-3.0).to_string(), "-3");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::parse("64").unwrap().as_i64(), Some(64));
        assert_eq!(Json::parse("0.5").unwrap().as_i64(), None);
    }

    #[test]
    fn escapes_parse() {
        let v = Json::parse(r#""a\u0041\u00e9\ud83d\ude00\n""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "aAé😀\n");
    }

    #[test]
    fn malformed_documents_are_rejected() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "tru", "1e999", "\"unterminated", "{\"a\":1} trailing",
            "{a: 1}", "[1 2]", "\"\\ud800\"",
        ] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 3, "s": "x", "a": [1], "b": false}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_i64(), Some(3));
        assert_eq!(v.get("s").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(v.get("b").unwrap().as_bool(), Some(false));
        assert!(v.get("missing").is_none());
        assert_eq!(v.as_obj().unwrap().len(), 4);
    }
}
