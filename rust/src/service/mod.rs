//! The SILO service daemon — a cached compile-and-run server over the
//! whole optimizer stack (`silo serve` / `silo submit`).
//!
//! The paper positions SILO as a practical optimization pipeline for
//! real HPC applications; this subsystem makes the pipeline *persistent*:
//! a dependency-free HTTP/1.1 daemon (std::net + a worker thread pool)
//! that accepts SILO-Text over `POST /compile`, resolves it through the
//! frontend → autotuner → lowering stack exactly once, and keeps the
//! resulting [`CompiledKernel`](crate::coordinator::CompiledKernel) in a
//! sharded, content-addressed LRU cache ([`cache::ScheduleCache`]). A
//! repeat submission — byte-identical or merely *canonically* identical
//! (comments, whitespace, label spelling) — skips dependence analysis,
//! schedule search, and bytecode lowering entirely, amortizing the
//! optimizer across submissions the way a Daisytuner-style tuning
//! service amortizes normalization. `POST /run/<id>` then executes the
//! cached artifact on the threaded VM with per-request parameter
//! bindings and inputs.
//!
//! Layers (each its own module, server-side top down):
//!
//! | Module      | Role                                                  |
//! |-------------|-------------------------------------------------------|
//! | [`server`]  | Listener, worker pool, router, endpoint handlers      |
//! | [`cache`]   | Sharded LRU + single-flight builds, content hashing   |
//! | [`protocol`]| Request/response shapes shared by daemon and client   |
//! | [`http`]    | Minimal HTTP/1.1 framing over std::net                |
//! | [`json`]    | Dependency-free JSON with bit-exact f64 round-trips   |
//! | [`metrics`] | Relaxed-atomic counters behind `GET /metrics`         |
//! | [`client`]  | `silo submit`, tests, and CI drive the daemon here    |
//!
//! Wire protocol and cache-key definition: DESIGN.md §Service.

pub mod cache;
pub mod client;
pub mod http;
pub mod json;
pub mod metrics;
pub mod protocol;
pub mod server;

pub use cache::{CacheStats, Outcome, ScheduleCache};
pub use client::{check_against_local, Client, SubmitOutcome};
pub use json::Json;
pub use metrics::Metrics;
pub use protocol::{
    CompileReply, CompileRequest, ExtractReply, ExtractRequest, ExtractedKernelReply, RunReply,
    RunRequest, SkipReply,
};
pub use server::{ServedKernel, Server, ServiceConfig};
