//! The sharded, content-addressed schedule cache — the heart of the
//! service daemon.
//!
//! Keys are 64-bit content hashes ([`kernel_key`]): FNV-1a over the
//! **canonical printing** of the parsed program (so whitespace, comments,
//! and label spelling never fragment the cache), its preset/`init`
//! annotations (they live outside the printed grammar), and the
//! normalized pipeline spec. Values are whatever the caller compiles —
//! the daemon stores a full `ServedKernel` (tuned program + lowered VM),
//! so a repeat submission skips parsing-to-bytecode entirely.
//!
//! Three properties the tests pin:
//!
//! * **LRU at capacity** — each shard evicts its least-recently-used
//!   completed entry once it exceeds its share of the capacity;
//! * **coalescing** — concurrent `get_or_build` calls for one key run
//!   the builder exactly once, with every other caller blocking on the
//!   in-flight slot instead of duplicating the (expensive) autotune;
//! * **error transparency** — failed builds are reported to all waiters
//!   but never occupy a cache slot.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::frontend::ParsedKernel;
use crate::ir::pretty::pretty;

/// How a [`ScheduleCache::get_or_build`] call was satisfied.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// Completed entry found — no compile work at all.
    Hit,
    /// This call ran the builder.
    Miss,
    /// Another thread was already building the same key; this call
    /// waited for its result instead of duplicating the work.
    Coalesced,
}

/// Point-in-time counter snapshot (`GET /metrics`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub coalesced: u64,
    pub evictions: u64,
    pub entries: usize,
    pub capacity: usize,
}

struct Slot<V> {
    val: Arc<V>,
    last_used: u64,
    /// Compile-path hits only (`touch` bumps recency, not this).
    hits: u64,
}

struct Inflight<V> {
    done: Mutex<Option<Result<Arc<V>, String>>>,
    cv: Condvar,
}

struct Shard<V> {
    entries: HashMap<u64, Slot<V>>,
    inflight: HashMap<u64, Arc<Inflight<V>>>,
}

impl<V> Shard<V> {
    fn new() -> Shard<V> {
        Shard {
            entries: HashMap::new(),
            inflight: HashMap::new(),
        }
    }
}

/// A sharded LRU map with single-flight builds. Lock granularity is one
/// mutex per shard; builders run with no lock held.
pub struct ScheduleCache<V> {
    shards: Vec<Mutex<Shard<V>>>,
    cap_per_shard: usize,
    tick: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    coalesced: AtomicU64,
    evictions: AtomicU64,
}

impl<V> ScheduleCache<V> {
    /// `capacity` completed entries across the default 8 shards.
    pub fn new(capacity: usize) -> ScheduleCache<V> {
        ScheduleCache::with_shards(capacity, 8)
    }

    /// Explicit shard count (tests use 1 shard for deterministic LRU).
    /// Each shard holds `max(1, capacity / shards)` entries.
    pub fn with_shards(capacity: usize, shards: usize) -> ScheduleCache<V> {
        let shards = shards.max(1);
        ScheduleCache {
            shards: (0..shards).map(|_| Mutex::new(Shard::new())).collect(),
            cap_per_shard: (capacity / shards).max(1),
            tick: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    fn shard(&self, key: u64) -> &Mutex<Shard<V>> {
        &self.shards[(key % self.shards.len() as u64) as usize]
    }

    fn next_tick(&self) -> u64 {
        self.tick.fetch_add(1, Ordering::Relaxed)
    }

    /// Return the cached value for `key`, or run `build` to create it.
    /// Concurrent calls for the same key coalesce onto one build; the
    /// builder runs outside every lock.
    pub fn get_or_build(
        &self,
        key: u64,
        build: impl FnOnce() -> Result<V, String>,
    ) -> (Result<Arc<V>, String>, Outcome) {
        let (result, outcome, _evicted) = self.get_or_build_evicting(key, build);
        (result, outcome)
    }

    /// [`ScheduleCache::get_or_build`], additionally returning the
    /// entries this call evicted to stay within capacity. The daemon
    /// uses the evicted values to drop per-entry resources the cache
    /// itself doesn't know about (interned symbols); plain callers use
    /// `get_or_build` and let the `Arc`s drop.
    pub fn get_or_build_evicting(
        &self,
        key: u64,
        build: impl FnOnce() -> Result<V, String>,
    ) -> (Result<Arc<V>, String>, Outcome, Vec<Arc<V>>) {
        let waiting = {
            let mut s = self.shard(key).lock().unwrap();
            if let Some(slot) = s.entries.get_mut(&key) {
                slot.last_used = self.next_tick();
                slot.hits += 1;
                self.hits.fetch_add(1, Ordering::Relaxed);
                return (Ok(slot.val.clone()), Outcome::Hit, Vec::new());
            }
            match s.inflight.get(&key) {
                Some(inf) => Some(inf.clone()),
                None => {
                    let inf = Arc::new(Inflight {
                        done: Mutex::new(None),
                        cv: Condvar::new(),
                    });
                    s.inflight.insert(key, inf);
                    None
                }
            }
        };
        if let Some(inf) = waiting {
            self.coalesced.fetch_add(1, Ordering::Relaxed);
            let mut done = inf.done.lock().unwrap();
            while done.is_none() {
                done = inf.cv.wait(done).unwrap();
            }
            return (done.clone().unwrap(), Outcome::Coalesced, Vec::new());
        }
        // This call owns the build (no lock held while it runs). A panic
        // is demoted to an error so waiters are never stranded.
        self.misses.fetch_add(1, Ordering::Relaxed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(build))
            .unwrap_or_else(|_| Err("builder panicked".to_string()))
            .map(Arc::new);
        let mut evicted = Vec::new();
        {
            let mut s = self.shard(key).lock().unwrap();
            if let Ok(v) = &result {
                let slot = Slot {
                    val: v.clone(),
                    last_used: self.next_tick(),
                    hits: 0,
                };
                s.entries.insert(key, slot);
                while s.entries.len() > self.cap_per_shard {
                    let Some(lru) =
                        s.entries.iter().min_by_key(|(_, sl)| sl.last_used).map(|(k, _)| *k)
                    else {
                        break;
                    };
                    if let Some(slot) = s.entries.remove(&lru) {
                        evicted.push(slot.val);
                    }
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                }
            }
            // Publish to waiters and clear the in-flight slot under the
            // same shard lock, so no reader can observe "neither entry
            // nor in-flight" for a completed build.
            if let Some(inf) = s.inflight.remove(&key) {
                let mut done = inf.done.lock().unwrap();
                *done = Some(result.clone());
                inf.cv.notify_all();
            }
        }
        (result, Outcome::Miss, evicted)
    }

    /// Recency-bumping lookup that does **not** count toward hit/miss —
    /// the run path touches entries without implying compile reuse.
    pub fn touch(&self, key: u64) -> Option<Arc<V>> {
        let mut s = self.shard(key).lock().unwrap();
        let slot = s.entries.get_mut(&key)?;
        slot.last_used = self.next_tick();
        Some(slot.val.clone())
    }

    /// Lookup without any side effect (tests).
    pub fn peek(&self, key: u64) -> Option<Arc<V>> {
        let s = self.shard(key).lock().unwrap();
        s.entries.get(&key).map(|slot| slot.val.clone())
    }

    /// Resident completed entries.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().entries.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total completed-entry capacity across shards.
    pub fn capacity(&self) -> usize {
        self.cap_per_shard * self.shards.len()
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            coalesced: self.coalesced.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries: self.len(),
            capacity: self.capacity(),
        }
    }

    /// `(key, value, compile-path hits)` for every resident entry,
    /// sorted by key for deterministic listings (`GET /kernels`).
    pub fn entries(&self) -> Vec<(u64, Arc<V>, u64)> {
        let mut out: Vec<(u64, Arc<V>, u64)> = Vec::new();
        for shard in &self.shards {
            let s = shard.lock().unwrap();
            out.extend(s.entries.iter().map(|(k, sl)| (*k, sl.val.clone(), sl.hits)));
        }
        out.sort_by_key(|(k, _, _)| *k);
        out
    }
}

// ---------------------------------------------------------------------------
// Content-addressed keys
// ---------------------------------------------------------------------------

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv(h: u64, bytes: &[u8]) -> u64 {
    let mut h = h;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Content hash of one submission: canonical program text × annotations
/// × normalized pipeline spec. Submissions that differ only in
/// formatting, comments, or declaration spelling collapse onto one key;
/// anything observable (structure, presets, `init`s, spec) separates.
pub fn kernel_key(parsed: &ParsedKernel, spec: &str) -> u64 {
    let mut h = fnv(FNV_OFFSET, pretty(&parsed.program).as_bytes());
    h = fnv(h, &[0]);
    h = fnv(h, spec.as_bytes());
    for (sym, b) in &parsed.presets {
        h = fnv(h, &[1]);
        h = fnv(h, sym.name().as_bytes());
        for v in [b.tiny, b.small, b.medium] {
            match v {
                Some(v) => h = fnv(h, &v.to_le_bytes()),
                None => h = fnv(h, &[0xff]),
            }
        }
    }
    for init in &parsed.inits {
        h = fnv(h, &[2]);
        h = fnv(h, init.container.as_bytes());
        h = fnv(h, &init.shift.to_bits().to_le_bytes());
        h = fnv(h, &init.scale.to_bits().to_le_bytes());
    }
    h
}

/// Wire form of a cache key: `k` + 16 hex digits.
pub fn kernel_id(key: u64) -> String {
    format!("k{key:016x}")
}

/// Parse a wire kernel id back to its key.
pub fn parse_kernel_id(id: &str) -> Option<u64> {
    let hex = id.strip_prefix('k')?;
    if hex.len() != 16 {
        return None;
    }
    u64::from_str_radix(hex, 16).ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn hit_after_insert_and_stats() {
        let cache: ScheduleCache<i32> = ScheduleCache::with_shards(4, 1);
        let (v, o) = cache.get_or_build(7, || Ok(42));
        assert_eq!((*v.unwrap(), o), (42, Outcome::Miss));
        let (v, o) = cache.get_or_build(7, || panic!("must not rebuild"));
        assert_eq!((*v.unwrap(), o), (42, Outcome::Hit));
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries, s.capacity), (1, 1, 1, 4));
    }

    #[test]
    fn lru_evicts_least_recently_used_at_capacity() {
        let cache: ScheduleCache<&'static str> = ScheduleCache::with_shards(2, 1);
        cache.get_or_build(1, || Ok("a"));
        cache.get_or_build(2, || Ok("b"));
        assert!(cache.touch(1).is_some()); // 1 is now more recent than 2
        cache.get_or_build(3, || Ok("c")); // evicts 2
        assert!(cache.peek(1).is_some());
        assert!(cache.peek(2).is_none());
        assert!(cache.peek(3).is_some());
        let s = cache.stats();
        assert_eq!((s.evictions, s.entries), (1, 2));
        // Rebuilding the evicted key is a miss, not a hit.
        let (_, o) = cache.get_or_build(2, || Ok("b2"));
        assert_eq!(o, Outcome::Miss);
    }

    #[test]
    fn evicting_variant_hands_back_displaced_entries() {
        let cache: ScheduleCache<&'static str> = ScheduleCache::with_shards(2, 1);
        let (_, o, ev) = cache.get_or_build_evicting(1, || Ok("a"));
        assert_eq!((o, ev.len()), (Outcome::Miss, 0));
        cache.get_or_build(2, || Ok("b"));
        let (_, _, ev) = cache.get_or_build_evicting(3, || Ok("c")); // displaces 1
        assert_eq!(ev.len(), 1);
        assert_eq!(*ev[0], "a");
        // Hits and failed builds evict nothing.
        let (_, o, ev) = cache.get_or_build_evicting(3, || unreachable!());
        assert_eq!((o, ev.len()), (Outcome::Hit, 0));
        let (r, _, ev) = cache.get_or_build_evicting(4, || Err("no".into()));
        assert!(r.is_err() && ev.is_empty());
    }

    #[test]
    fn concurrent_builds_for_one_key_coalesce() {
        let cache: ScheduleCache<u64> = ScheduleCache::new(8);
        let builds = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..8 {
                scope.spawn(|| {
                    let (v, _) = cache.get_or_build(99, || {
                        builds.fetch_add(1, Ordering::SeqCst);
                        std::thread::sleep(std::time::Duration::from_millis(25));
                        Ok(123)
                    });
                    assert_eq!(*v.unwrap(), 123);
                });
            }
        });
        assert_eq!(builds.load(Ordering::SeqCst), 1, "duplicate builds ran");
        let s = cache.stats();
        assert_eq!(s.misses, 1);
        assert_eq!(s.hits + s.coalesced, 7);
    }

    #[test]
    fn failed_builds_are_not_cached_and_wake_waiters() {
        let cache: ScheduleCache<i32> = ScheduleCache::new(8);
        let (r, o) = cache.get_or_build(5, || Err("boom".to_string()));
        assert_eq!(o, Outcome::Miss);
        assert_eq!(r.unwrap_err(), "boom");
        assert!(cache.peek(5).is_none());
        // A panicking builder is demoted to an error, not a poisoned slot.
        let (r, _) = cache.get_or_build(6, || panic!("bang"));
        assert!(r.unwrap_err().contains("panicked"));
        assert!(cache.peek(6).is_none());
        // The keys stay buildable.
        let (r, o) = cache.get_or_build(5, || Ok(1));
        assert_eq!((*r.unwrap(), o), (1, Outcome::Miss));
    }

    #[test]
    fn kernel_keys_hash_canonical_structure_not_text() {
        let a = crate::frontend::parse_str("program ck1 {\n  array A[8];\n  A[0] = 1.0;\n}\n")
            .unwrap();
        let b = crate::frontend::parse_str(
            "// formatting-only differences\nprogram ck1 {\n  array  A[ 8 ];\n  A[0]   = \
             1.0;\n}\n",
        )
        .unwrap();
        assert_eq!(kernel_key(&a, "auto"), kernel_key(&b, "auto"));
        assert_ne!(kernel_key(&a, "auto"), kernel_key(&a, "cfg1"));
    }

    #[test]
    fn kernel_ids_round_trip() {
        for key in [0u64, 1, u64::MAX, 0xdead_beef_0123_4567] {
            let id = kernel_id(key);
            assert_eq!(parse_kernel_id(&id), Some(key), "{id}");
        }
        assert_eq!(parse_kernel_id("nope"), None);
        assert_eq!(parse_kernel_id("k123"), None);
        assert_eq!(parse_kernel_id("kzzzzzzzzzzzzzzzz"), None);
    }
}
