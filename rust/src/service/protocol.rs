//! Wire protocol: the request/response shapes of every endpoint, in one
//! place so the daemon and the client cannot drift. All bodies are JSON
//! (see [`super::json`]); DESIGN.md §Service documents the schemas.
//!
//! | Endpoint          | Request            | Response           |
//! |-------------------|--------------------|--------------------|
//! | `POST /compile`   | [`CompileRequest`] | [`CompileReply`]   |
//! | `POST /run/<id>`  | [`RunRequest`]     | [`RunReply`]       |
//! | `GET /kernels`    | —                  | array of kernels   |
//! | `GET /metrics`    | —                  | counter object     |
//! | `GET /healthz`    | —                  | `{"ok":true,...}`  |
//!
//! Non-200 responses carry `{"error": "<message>"}` ([`error_body`]).

use super::json::Json;

/// `POST /compile`: a SILO-Text module plus a pipeline spec (the same
/// strings `--pipeline` accepts; defaults to `auto`).
#[derive(Debug, Clone)]
pub struct CompileRequest {
    pub source: String,
    pub pipeline: String,
}

impl CompileRequest {
    pub fn new(source: &str, pipeline: &str) -> CompileRequest {
        CompileRequest {
            source: source.to_string(),
            pipeline: pipeline.to_string(),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("source".into(), Json::Str(self.source.clone())),
            ("pipeline".into(), Json::Str(self.pipeline.clone())),
        ])
    }

    pub fn from_json(v: &Json) -> Result<CompileRequest, String> {
        let source = v
            .get("source")
            .and_then(Json::as_str)
            .ok_or("missing string field `source` (SILO-Text)")?
            .to_string();
        let pipeline = match v.get("pipeline") {
            None | Some(Json::Null) => "auto".to_string(),
            Some(p) => p.as_str().ok_or("field `pipeline` must be a string")?.to_string(),
        };
        Ok(CompileRequest { source, pipeline })
    }
}

/// `POST /compile` success reply.
#[derive(Debug, Clone)]
pub struct CompileReply {
    /// Content-addressed kernel id (`k` + 16 hex digits) for `/run/<id>`.
    pub kernel: String,
    pub name: String,
    /// Normalized pipeline spec the artifact was compiled under.
    pub pipeline: String,
    /// True when the submission was served from the schedule cache
    /// (analysis + autotuning + lowering all skipped).
    pub cached: bool,
    /// True when this submission piggybacked on a concurrent in-flight
    /// compile of the same program.
    pub coalesced: bool,
    /// `(pass, detail)` log of the pipeline that built the artifact.
    pub passes: Vec<(String, String)>,
    /// Program parameter names (bind via presets or explicit values).
    pub params: Vec<String>,
    /// Argument (externally visible) container names.
    pub arguments: Vec<String>,
    /// Safety tier the artifact earned: `"trusted"` (no verification —
    /// the default daemon), `"proven"` (every access statically proven
    /// in bounds), or `"checked"` (runtime bounds guards on unproven
    /// accesses).
    pub tier: String,
    /// How many accesses carry runtime checks (0 on proven/trusted).
    pub unproven: u64,
    /// Symbolic worst-case fuel (loop back-edges), when boundable.
    pub fuel_bound: Option<String>,
}

impl CompileReply {
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("kernel".into(), Json::Str(self.kernel.clone())),
            ("name".into(), Json::Str(self.name.clone())),
            ("pipeline".into(), Json::Str(self.pipeline.clone())),
            ("cached".into(), Json::Bool(self.cached)),
            ("coalesced".into(), Json::Bool(self.coalesced)),
            (
                "passes".into(),
                Json::Arr(
                    self.passes
                        .iter()
                        .map(|(p, d)| {
                            Json::Obj(vec![
                                ("pass".into(), Json::Str(p.clone())),
                                ("detail".into(), Json::Str(d.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "params".into(),
                Json::Arr(self.params.iter().map(|s| Json::Str(s.clone())).collect()),
            ),
            (
                "arguments".into(),
                Json::Arr(self.arguments.iter().map(|s| Json::Str(s.clone())).collect()),
            ),
            ("tier".into(), Json::Str(self.tier.clone())),
            ("unproven".into(), Json::Num(self.unproven as f64)),
            (
                "fuel_bound".into(),
                match &self.fuel_bound {
                    Some(f) => Json::Str(f.clone()),
                    None => Json::Null,
                },
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<CompileReply, String> {
        let field = |k: &str| -> Result<&str, String> {
            v.get(k)
                .and_then(Json::as_str)
                .ok_or_else(|| format!("missing string field `{k}`"))
        };
        let strings = |k: &str| -> Result<Vec<String>, String> {
            v.get(k)
                .and_then(Json::as_arr)
                .ok_or_else(|| format!("missing array field `{k}`"))?
                .iter()
                .map(|x| {
                    x.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| format!("`{k}`: non-string entry"))
                })
                .collect()
        };
        let passes = v
            .get("passes")
            .and_then(Json::as_arr)
            .ok_or("missing array field `passes`")?
            .iter()
            .map(|x| {
                let pass = x.get("pass").and_then(Json::as_str).unwrap_or("?").to_string();
                let detail = x.get("detail").and_then(Json::as_str).unwrap_or("").to_string();
                (pass, detail)
            })
            .collect();
        Ok(CompileReply {
            kernel: field("kernel")?.to_string(),
            name: field("name")?.to_string(),
            pipeline: field("pipeline")?.to_string(),
            cached: v.get("cached").and_then(Json::as_bool).unwrap_or(false),
            coalesced: v.get("coalesced").and_then(Json::as_bool).unwrap_or(false),
            passes,
            params: strings("params")?,
            arguments: strings("arguments")?,
            // Absent on replies from pre-verifier daemons: trusted.
            tier: v
                .get("tier")
                .and_then(Json::as_str)
                .unwrap_or("trusted")
                .to_string(),
            unproven: v.get("unproven").and_then(Json::as_i64).unwrap_or(0).max(0) as u64,
            fuel_bound: v
                .get("fuel_bound")
                .and_then(Json::as_str)
                .map(str::to_string),
        })
    }
}

/// `POST /run/<id>`: parameter bindings and inputs for one execution.
/// Every field is optional on the wire — an empty body runs the tiny
/// preset on one thread with the kernel's default inputs.
#[derive(Debug, Clone)]
pub struct RunRequest {
    /// Preset used for any param without an explicit binding
    /// (`tiny` | `small` | `medium`).
    pub preset: String,
    /// Explicit `name → value` param bindings (override the preset).
    pub params: Vec<(String, i64)>,
    /// Explicit argument-container contents (defaults: the kernel's
    /// `init(...)` annotations / deterministic default initializer).
    pub inputs: Vec<(String, Vec<f64>)>,
    /// VM worker threads (clamped to 1..=8 by the daemon).
    pub threads: usize,
    /// Argument containers to return (`None` = all of them).
    pub outputs: Option<Vec<String>>,
    /// Execution backend (`"vm"` | `"native"` | `"speculative"`);
    /// `None` = the daemon's configured default. A `"native"` request
    /// silently degrades to the VM when the daemon's host has no JIT,
    /// and a `"speculative"` request degrades to the VM when the
    /// program has no speculation candidates — [`RunReply::backend`]
    /// reports what actually ran.
    pub backend: Option<String>,
    /// Run the inspector before executing: evaluate the program's
    /// symbolic access functions over the concrete iteration space for
    /// this param-set and report per-loop parallelization certificates
    /// in [`RunReply::inspector`]. Certificates are memoized per
    /// (kernel, param-set) on the daemon.
    pub inspector: bool,
}

impl Default for RunRequest {
    fn default() -> RunRequest {
        RunRequest {
            preset: "tiny".to_string(),
            params: Vec::new(),
            inputs: Vec::new(),
            threads: 1,
            outputs: None,
            backend: None,
            inspector: false,
        }
    }
}

impl RunRequest {
    pub fn to_json(&self) -> Json {
        let mut kv = vec![
            ("preset".into(), Json::Str(self.preset.clone())),
            ("threads".into(), Json::Num(self.threads as f64)),
        ];
        if !self.params.is_empty() {
            kv.push((
                "params".into(),
                Json::Obj(
                    self.params.iter().map(|(k, v)| (k.clone(), Json::Num(*v as f64))).collect(),
                ),
            ));
        }
        if !self.inputs.is_empty() {
            kv.push((
                "inputs".into(),
                Json::Obj(
                    self.inputs
                        .iter()
                        .map(|(k, data)| {
                            (k.clone(), Json::Arr(data.iter().map(|x| Json::Num(*x)).collect()))
                        })
                        .collect(),
                ),
            ));
        }
        if let Some(outs) = &self.outputs {
            kv.push((
                "outputs".into(),
                Json::Arr(outs.iter().map(|s| Json::Str(s.clone())).collect()),
            ));
        }
        if let Some(b) = &self.backend {
            kv.push(("backend".into(), Json::Str(b.clone())));
        }
        if self.inspector {
            kv.push(("inspector".into(), Json::Bool(true)));
        }
        Json::Obj(kv)
    }

    pub fn from_json(v: &Json) -> Result<RunRequest, String> {
        let mut req = RunRequest::default();
        if let Some(p) = v.get("preset") {
            req.preset = p.as_str().ok_or("field `preset` must be a string")?.to_string();
        }
        if let Some(t) = v.get("threads") {
            req.threads =
                t.as_i64().ok_or("field `threads` must be an integer")?.clamp(0, 1 << 16) as usize;
        }
        if let Some(p) = v.get("params") {
            for (k, x) in p.as_obj().ok_or("field `params` must be an object")? {
                let val = x.as_i64().ok_or_else(|| format!("param `{k}` must be an integer"))?;
                req.params.push((k.clone(), val));
            }
        }
        if let Some(inp) = v.get("inputs") {
            for (k, x) in inp.as_obj().ok_or("field `inputs` must be an object")? {
                let arr = x.as_arr().ok_or_else(|| format!("input `{k}` must be a number array"))?;
                let data = arr
                    .iter()
                    .map(|e| e.as_f64().ok_or_else(|| format!("input `{k}`: non-numeric entry")))
                    .collect::<Result<Vec<f64>, String>>()?;
                req.inputs.push((k.clone(), data));
            }
        }
        if let Some(outs) = v.get("outputs") {
            let arr = outs.as_arr().ok_or("field `outputs` must be a string array")?;
            let names = arr
                .iter()
                .map(|e| e.as_str().map(str::to_string).ok_or("`outputs`: non-string entry"))
                .collect::<Result<Vec<String>, _>>()?;
            req.outputs = Some(names);
        }
        if let Some(b) = v.get("backend") {
            req.backend = Some(b.as_str().ok_or("field `backend` must be a string")?.to_string());
        }
        if let Some(i) = v.get("inspector") {
            req.inspector = i.as_bool().ok_or("field `inspector` must be a boolean")?;
        }
        Ok(req)
    }
}

/// `POST /run/<id>` success reply.
#[derive(Debug, Clone)]
pub struct RunReply {
    pub kernel: String,
    pub name: String,
    /// Wall-clock VM execution time on the daemon, milliseconds.
    pub wall_ms: f64,
    /// Fuel spent (loop back-edges), reported on metered (untrusted)
    /// runs; `None` on unmetered daemons.
    pub fuel_used: Option<u64>,
    /// The backend that actually executed (`"vm"` | `"native"` |
    /// `"speculative"`) — a native *request* may still run on the VM
    /// when the daemon's host has no JIT. Absent on replies from
    /// pre-native daemons: `"vm"`.
    pub backend: String,
    /// Speculation counters `(attempted, commits, aborts)` when the run
    /// executed on the speculative tier; `None` otherwise (and absent
    /// on the wire).
    pub speculation: Option<(u64, u64, u64)>,
    /// Per-loop inspector certificates (`"L<id> <var>: <certificate>"`)
    /// when the request asked for inspection; `None` otherwise.
    pub inspector: Option<Vec<String>>,
    /// `name → contents` for each requested argument container.
    pub outputs: Vec<(String, Vec<f64>)>,
}

impl RunReply {
    pub fn to_json(&self) -> Json {
        let mut kv = vec![
            ("kernel".into(), Json::Str(self.kernel.clone())),
            ("name".into(), Json::Str(self.name.clone())),
            ("wall_ms".into(), Json::Num(self.wall_ms)),
        ];
        if let Some(f) = self.fuel_used {
            kv.push(("fuel_used".into(), Json::Num(f as f64)));
        }
        kv.push(("backend".into(), Json::Str(self.backend.clone())));
        if let Some((attempted, commits, aborts)) = self.speculation {
            kv.push((
                "speculation".into(),
                Json::Obj(vec![
                    ("attempted".into(), Json::Num(attempted as f64)),
                    ("commits".into(), Json::Num(commits as f64)),
                    ("aborts".into(), Json::Num(aborts as f64)),
                ]),
            ));
        }
        if let Some(lines) = &self.inspector {
            kv.push((
                "inspector".into(),
                Json::Arr(lines.iter().map(|s| Json::Str(s.clone())).collect()),
            ));
        }
        kv.push((
            "outputs".into(),
            Json::Obj(
                self.outputs
                    .iter()
                    .map(|(k, data)| {
                        (k.clone(), Json::Arr(data.iter().map(|x| Json::Num(*x)).collect()))
                    })
                    .collect(),
            ),
        ));
        Json::Obj(kv)
    }

    pub fn from_json(v: &Json) -> Result<RunReply, String> {
        let mut outputs = Vec::new();
        for (k, x) in v
            .get("outputs")
            .and_then(Json::as_obj)
            .ok_or("missing object field `outputs`")?
        {
            let data = x
                .as_arr()
                .ok_or_else(|| format!("output `{k}` must be a number array"))?
                .iter()
                .map(|e| e.as_f64().ok_or_else(|| format!("output `{k}`: non-numeric entry")))
                .collect::<Result<Vec<f64>, String>>()?;
            outputs.push((k.clone(), data));
        }
        Ok(RunReply {
            kernel: v
                .get("kernel")
                .and_then(Json::as_str)
                .ok_or("missing string field `kernel`")?
                .to_string(),
            name: v
                .get("name")
                .and_then(Json::as_str)
                .ok_or("missing string field `name`")?
                .to_string(),
            wall_ms: v.get("wall_ms").and_then(Json::as_f64).unwrap_or(0.0),
            fuel_used: v
                .get("fuel_used")
                .and_then(Json::as_i64)
                .map(|f| f.max(0) as u64),
            backend: v
                .get("backend")
                .and_then(Json::as_str)
                .unwrap_or("vm")
                .to_string(),
            // Absent on replies from pre-speculation daemons.
            speculation: v.get("speculation").map(|s| {
                let n = |k: &str| s.get(k).and_then(Json::as_i64).unwrap_or(0).max(0) as u64;
                (n("attempted"), n("commits"), n("aborts"))
            }),
            inspector: v.get("inspector").and_then(Json::as_arr).map(|arr| {
                arr.iter()
                    .filter_map(|e| e.as_str().map(str::to_string))
                    .collect()
            }),
            outputs,
        })
    }
}

/// The uniform non-200 body.
pub fn error_body(msg: &str) -> String {
    Json::Obj(vec![("error".to_string(), Json::Str(msg.to_string()))]).to_string()
}

/// Non-200 body with a machine-readable `code` (structured traps:
/// `out_of_bounds`, `fuel_exhausted`, `time_limit`; verifier refusals:
/// `rejected`).
pub fn error_body_code(msg: &str, code: &str) -> String {
    Json::Obj(vec![
        ("error".to_string(), Json::Str(msg.to_string())),
        ("code".to_string(), Json::Str(code.to_string())),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compile_request_round_trips_and_defaults_pipeline() {
        let req = CompileRequest::new("program t { }", "cfg2");
        let back = CompileRequest::from_json(&req.to_json()).unwrap();
        assert_eq!(back.source, "program t { }");
        assert_eq!(back.pipeline, "cfg2");
        let v = Json::parse(r#"{"source": "program t { }"}"#).unwrap();
        assert_eq!(CompileRequest::from_json(&v).unwrap().pipeline, "auto");
        assert!(CompileRequest::from_json(&Json::Obj(vec![])).is_err());
    }

    #[test]
    fn run_request_round_trips() {
        let req = RunRequest {
            preset: "small".into(),
            params: vec![("st_N".into(), 64)],
            inputs: vec![("u".into(), vec![1.0, -0.5])],
            threads: 4,
            outputs: Some(vec!["u".into()]),
            backend: Some("native".into()),
            inspector: true,
        };
        let back = RunRequest::from_json(&req.to_json()).unwrap();
        assert_eq!(back.preset, "small");
        assert_eq!(back.params, vec![("st_N".to_string(), 64)]);
        assert_eq!(back.inputs.len(), 1);
        assert_eq!(back.inputs[0].1, vec![1.0, -0.5]);
        assert_eq!(back.threads, 4);
        assert_eq!(back.outputs.as_deref(), Some(&["u".to_string()][..]));
        assert_eq!(back.backend.as_deref(), Some("native"));
        assert!(back.inspector);
        // Empty object = all defaults.
        let d = RunRequest::from_json(&Json::Obj(vec![])).unwrap();
        assert_eq!((d.preset.as_str(), d.threads), ("tiny", 1));
        assert_eq!(d.backend, None);
        assert!(!d.inspector);
        // Type errors are reported by field.
        let bad = Json::parse(r#"{"params": {"N": 1.5}}"#).unwrap();
        assert!(RunRequest::from_json(&bad).unwrap_err().contains("`N`"));
    }

    #[test]
    fn replies_round_trip() {
        let reply = CompileReply {
            kernel: "k0123456789abcdef".into(),
            name: "stencil_time".into(),
            pipeline: "auto".into(),
            cached: true,
            coalesced: false,
            passes: vec![("doall".into(), "L1".into())],
            params: vec!["st_N".into()],
            arguments: vec!["u".into()],
            tier: "proven".into(),
            unproven: 0,
            fuel_bound: Some("st_T*st_N".into()),
        };
        let back = CompileReply::from_json(&reply.to_json()).unwrap();
        assert_eq!(back.kernel, reply.kernel);
        assert!(back.cached);
        assert_eq!(back.passes, reply.passes);
        assert_eq!(back.arguments, reply.arguments);
        assert_eq!(back.tier, "proven");
        assert_eq!(back.fuel_bound.as_deref(), Some("st_T*st_N"));
        // A pre-verifier reply (no tier fields) parses as trusted.
        let legacy = Json::parse(
            r#"{"kernel":"k0","name":"t","pipeline":"auto","passes":[],
                "params":[],"arguments":[]}"#,
        )
        .unwrap();
        let back = CompileReply::from_json(&legacy).unwrap();
        assert_eq!(back.tier, "trusted");
        assert_eq!(back.fuel_bound, None);

        let run = RunReply {
            kernel: reply.kernel.clone(),
            name: reply.name.clone(),
            wall_ms: 0.25,
            fuel_used: Some(12),
            backend: "native".into(),
            speculation: Some((2, 1, 1)),
            inspector: Some(vec!["L0 i: doall".into()]),
            outputs: vec![("u".into(), vec![0.0, -0.0, 2.5])],
        };
        let back = RunReply::from_json(&run.to_json()).unwrap();
        assert_eq!(back.outputs[0].0, "u");
        assert_eq!(back.backend, "native");
        assert_eq!(back.speculation, Some((2, 1, 1)));
        assert_eq!(back.inspector.as_deref(), Some(&["L0 i: doall".to_string()][..]));
        // A pre-native reply (no backend field) parses as vm.
        let legacy = Json::parse(r#"{"kernel":"k0","name":"t","outputs":{}}"#).unwrap();
        let legacy = RunReply::from_json(&legacy).unwrap();
        assert_eq!(legacy.backend, "vm");
        assert_eq!(legacy.speculation, None);
        assert_eq!(legacy.inspector, None);
        let bits: Vec<u64> = back.outputs[0].1.iter().map(|x| x.to_bits()).collect();
        assert_eq!(bits, vec![0.0f64.to_bits(), (-0.0f64).to_bits(), 2.5f64.to_bits()]);
    }

    #[test]
    fn error_bodies_are_json() {
        let v = Json::parse(&error_body("parse error at line 3")).unwrap();
        assert_eq!(v.get("error").unwrap().as_str(), Some("parse error at line 3"));
    }
}
