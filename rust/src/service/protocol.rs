//! Wire protocol: the request/response shapes of every endpoint, in one
//! place so the daemon and the client cannot drift. All bodies are JSON
//! (see [`super::json`]); DESIGN.md §Service documents the schemas.
//!
//! | Endpoint          | Request            | Response           |
//! |-------------------|--------------------|--------------------|
//! | `POST /compile`   | [`CompileRequest`] | [`CompileReply`]   |
//! | `POST /extract`   | [`ExtractRequest`] | [`ExtractReply`]   |
//! | `POST /run/<id>`  | [`RunRequest`]     | [`RunReply`]       |
//! | `GET /kernels`    | —                  | array of kernels   |
//! | `GET /metrics`    | —                  | counter object     |
//! | `GET /healthz`    | —                  | `{"ok":true,...}`  |
//!
//! Non-200 responses carry `{"error": "<message>"}` ([`error_body`]).

use super::json::Json;

/// `POST /compile`: a SILO-Text module plus a pipeline spec (the same
/// strings `--pipeline` accepts; defaults to `auto`).
#[derive(Debug, Clone)]
pub struct CompileRequest {
    pub source: String,
    pub pipeline: String,
}

impl CompileRequest {
    pub fn new(source: &str, pipeline: &str) -> CompileRequest {
        CompileRequest {
            source: source.to_string(),
            pipeline: pipeline.to_string(),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("source".into(), Json::Str(self.source.clone())),
            ("pipeline".into(), Json::Str(self.pipeline.clone())),
        ])
    }

    pub fn from_json(v: &Json) -> Result<CompileRequest, String> {
        let source = v
            .get("source")
            .and_then(Json::as_str)
            .ok_or("missing string field `source` (SILO-Text)")?
            .to_string();
        let pipeline = match v.get("pipeline") {
            None | Some(Json::Null) => "auto".to_string(),
            Some(p) => p.as_str().ok_or("field `pipeline` must be a string")?.to_string(),
        };
        Ok(CompileRequest { source, pipeline })
    }
}

/// `POST /compile` success reply.
#[derive(Debug, Clone)]
pub struct CompileReply {
    /// Content-addressed kernel id (`k` + 16 hex digits) for `/run/<id>`.
    pub kernel: String,
    pub name: String,
    /// Normalized pipeline spec the artifact was compiled under.
    pub pipeline: String,
    /// True when the submission was served from the schedule cache
    /// (analysis + autotuning + lowering all skipped).
    pub cached: bool,
    /// True when this submission piggybacked on a concurrent in-flight
    /// compile of the same program.
    pub coalesced: bool,
    /// `(pass, detail)` log of the pipeline that built the artifact.
    pub passes: Vec<(String, String)>,
    /// Program parameter names (bind via presets or explicit values).
    pub params: Vec<String>,
    /// Argument (externally visible) container names.
    pub arguments: Vec<String>,
    /// Safety tier the artifact earned: `"trusted"` (no verification —
    /// the default daemon), `"proven"` (every access statically proven
    /// in bounds), or `"checked"` (runtime bounds guards on unproven
    /// accesses).
    pub tier: String,
    /// How many accesses carry runtime checks (0 on proven/trusted).
    pub unproven: u64,
    /// Symbolic worst-case fuel (loop back-edges), when boundable.
    pub fuel_bound: Option<String>,
}

impl CompileReply {
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("kernel".into(), Json::Str(self.kernel.clone())),
            ("name".into(), Json::Str(self.name.clone())),
            ("pipeline".into(), Json::Str(self.pipeline.clone())),
            ("cached".into(), Json::Bool(self.cached)),
            ("coalesced".into(), Json::Bool(self.coalesced)),
            (
                "passes".into(),
                Json::Arr(
                    self.passes
                        .iter()
                        .map(|(p, d)| {
                            Json::Obj(vec![
                                ("pass".into(), Json::Str(p.clone())),
                                ("detail".into(), Json::Str(d.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "params".into(),
                Json::Arr(self.params.iter().map(|s| Json::Str(s.clone())).collect()),
            ),
            (
                "arguments".into(),
                Json::Arr(self.arguments.iter().map(|s| Json::Str(s.clone())).collect()),
            ),
            ("tier".into(), Json::Str(self.tier.clone())),
            ("unproven".into(), Json::Num(self.unproven as f64)),
            (
                "fuel_bound".into(),
                match &self.fuel_bound {
                    Some(f) => Json::Str(f.clone()),
                    None => Json::Null,
                },
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<CompileReply, String> {
        let field = |k: &str| -> Result<&str, String> {
            v.get(k)
                .and_then(Json::as_str)
                .ok_or_else(|| format!("missing string field `{k}`"))
        };
        let strings = |k: &str| -> Result<Vec<String>, String> {
            v.get(k)
                .and_then(Json::as_arr)
                .ok_or_else(|| format!("missing array field `{k}`"))?
                .iter()
                .map(|x| {
                    x.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| format!("`{k}`: non-string entry"))
                })
                .collect()
        };
        let passes = v
            .get("passes")
            .and_then(Json::as_arr)
            .ok_or("missing array field `passes`")?
            .iter()
            .map(|x| {
                let pass = x.get("pass").and_then(Json::as_str).unwrap_or("?").to_string();
                let detail = x.get("detail").and_then(Json::as_str).unwrap_or("").to_string();
                (pass, detail)
            })
            .collect();
        Ok(CompileReply {
            kernel: field("kernel")?.to_string(),
            name: field("name")?.to_string(),
            pipeline: field("pipeline")?.to_string(),
            cached: v.get("cached").and_then(Json::as_bool).unwrap_or(false),
            coalesced: v.get("coalesced").and_then(Json::as_bool).unwrap_or(false),
            passes,
            params: strings("params")?,
            arguments: strings("arguments")?,
            // Absent on replies from pre-verifier daemons: trusted.
            tier: v
                .get("tier")
                .and_then(Json::as_str)
                .unwrap_or("trusted")
                .to_string(),
            unproven: v.get("unproven").and_then(Json::as_i64).unwrap_or(0).max(0) as u64,
            fuel_bound: v
                .get("fuel_bound")
                .and_then(Json::as_str)
                .map(str::to_string),
        })
    }
}

/// `POST /extract`: raw C/Fortran application source. The daemon lifts
/// every affine loop nest it recognizes ([`crate::extract`]), compiles
/// each through the normal `/compile` path (same cache, same safety
/// policy), and reports everything it refused in the skip list.
#[derive(Debug, Clone)]
pub struct ExtractRequest {
    /// The application source text (not SILO-Text).
    pub source: String,
    /// Language tag: `c`, `f`/`fixed` (fixed-form Fortran), or
    /// `f90`/`free` (free-form).
    pub lang: String,
    /// Pipeline for the per-kernel compiles (defaults to `auto`).
    pub pipeline: String,
    /// Name stem prefixed onto extracted kernel names (defaults to
    /// `app`) — plays the role the file stem plays on the CLI.
    pub stem: String,
}

impl ExtractRequest {
    pub fn new(source: &str, lang: &str, pipeline: &str, stem: &str) -> ExtractRequest {
        ExtractRequest {
            source: source.to_string(),
            lang: lang.to_string(),
            pipeline: pipeline.to_string(),
            stem: stem.to_string(),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("source".into(), Json::Str(self.source.clone())),
            ("lang".into(), Json::Str(self.lang.clone())),
            ("pipeline".into(), Json::Str(self.pipeline.clone())),
            ("stem".into(), Json::Str(self.stem.clone())),
        ])
    }

    pub fn from_json(v: &Json) -> Result<ExtractRequest, String> {
        let source = v
            .get("source")
            .and_then(Json::as_str)
            .ok_or("missing string field `source` (C or Fortran text)")?
            .to_string();
        let lang = v
            .get("lang")
            .and_then(Json::as_str)
            .ok_or("missing string field `lang` (c | f | f90)")?
            .to_string();
        let pipeline = match v.get("pipeline") {
            None | Some(Json::Null) => "auto".to_string(),
            Some(p) => p.as_str().ok_or("field `pipeline` must be a string")?.to_string(),
        };
        let stem = match v.get("stem") {
            None | Some(Json::Null) => "app".to_string(),
            Some(s) => s.as_str().ok_or("field `stem` must be a string")?.to_string(),
        };
        Ok(ExtractRequest {
            source,
            lang,
            pipeline,
            stem,
        })
    }
}

/// One kernel in an [`ExtractReply`]: the compile outcome (identical in
/// shape to `POST /compile`'s reply, content-addressed id included) plus
/// the canonical SILO-Text the extractor emitted for it.
#[derive(Debug, Clone)]
pub struct ExtractedKernelReply {
    pub compile: CompileReply,
    pub silo: String,
}

/// One refused construct in an [`ExtractReply`] (`line` is 1-based in
/// the submitted source).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SkipReply {
    pub line: u64,
    pub construct: String,
    pub reason: String,
}

/// `POST /extract` success reply. An extraction with zero kernels is
/// still a 200 — the skip list says why nothing lifted.
#[derive(Debug, Clone)]
pub struct ExtractReply {
    pub kernels: Vec<ExtractedKernelReply>,
    pub skipped: Vec<SkipReply>,
}

impl ExtractReply {
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            (
                "kernels".into(),
                Json::Arr(
                    self.kernels
                        .iter()
                        .map(|k| {
                            // The compile object plus a `silo` key.
                            let mut kv = match k.compile.to_json() {
                                Json::Obj(kv) => kv,
                                _ => unreachable!("CompileReply::to_json is an object"),
                            };
                            kv.push(("silo".into(), Json::Str(k.silo.clone())));
                            Json::Obj(kv)
                        })
                        .collect(),
                ),
            ),
            (
                "skipped".into(),
                Json::Arr(
                    self.skipped
                        .iter()
                        .map(|s| {
                            Json::Obj(vec![
                                ("line".into(), Json::Num(s.line as f64)),
                                ("construct".into(), Json::Str(s.construct.clone())),
                                ("reason".into(), Json::Str(s.reason.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<ExtractReply, String> {
        let kernels = v
            .get("kernels")
            .and_then(Json::as_arr)
            .ok_or("missing array field `kernels`")?
            .iter()
            .map(|x| {
                let compile = CompileReply::from_json(x)?;
                let silo = x
                    .get("silo")
                    .and_then(Json::as_str)
                    .ok_or("kernel entry missing string field `silo`")?
                    .to_string();
                Ok(ExtractedKernelReply { compile, silo })
            })
            .collect::<Result<Vec<_>, String>>()?;
        let skipped = v
            .get("skipped")
            .and_then(Json::as_arr)
            .ok_or("missing array field `skipped`")?
            .iter()
            .map(|x| {
                Ok(SkipReply {
                    line: x.get("line").and_then(Json::as_i64).unwrap_or(0).max(0) as u64,
                    construct: x
                        .get("construct")
                        .and_then(Json::as_str)
                        .ok_or("skip entry missing string field `construct`")?
                        .to_string(),
                    reason: x
                        .get("reason")
                        .and_then(Json::as_str)
                        .ok_or("skip entry missing string field `reason`")?
                        .to_string(),
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(ExtractReply { kernels, skipped })
    }
}

/// `POST /run/<id>`: parameter bindings and inputs for one execution.
/// Every field is optional on the wire — an empty body runs the tiny
/// preset on one thread with the kernel's default inputs.
#[derive(Debug, Clone)]
pub struct RunRequest {
    /// Preset used for any param without an explicit binding
    /// (`tiny` | `small` | `medium`).
    pub preset: String,
    /// Explicit `name → value` param bindings (override the preset).
    pub params: Vec<(String, i64)>,
    /// Explicit argument-container contents (defaults: the kernel's
    /// `init(...)` annotations / deterministic default initializer).
    pub inputs: Vec<(String, Vec<f64>)>,
    /// VM worker threads (clamped to 1..=8 by the daemon).
    pub threads: usize,
    /// Argument containers to return (`None` = all of them).
    pub outputs: Option<Vec<String>>,
    /// Execution backend (`"vm"` | `"native"` | `"speculative"`);
    /// `None` = the daemon's configured default. A `"native"` request
    /// silently degrades to the VM when the daemon's host has no JIT,
    /// and a `"speculative"` request degrades to the VM when the
    /// program has no speculation candidates — [`RunReply::backend`]
    /// reports what actually ran.
    pub backend: Option<String>,
    /// Run the inspector before executing: evaluate the program's
    /// symbolic access functions over the concrete iteration space for
    /// this param-set and report per-loop parallelization certificates
    /// in [`RunReply::inspector`]. Certificates are memoized per
    /// (kernel, param-set) on the daemon.
    pub inspector: bool,
}

impl Default for RunRequest {
    fn default() -> RunRequest {
        RunRequest {
            preset: "tiny".to_string(),
            params: Vec::new(),
            inputs: Vec::new(),
            threads: 1,
            outputs: None,
            backend: None,
            inspector: false,
        }
    }
}

impl RunRequest {
    pub fn to_json(&self) -> Json {
        let mut kv = vec![
            ("preset".into(), Json::Str(self.preset.clone())),
            ("threads".into(), Json::Num(self.threads as f64)),
        ];
        if !self.params.is_empty() {
            kv.push((
                "params".into(),
                Json::Obj(
                    self.params.iter().map(|(k, v)| (k.clone(), Json::Num(*v as f64))).collect(),
                ),
            ));
        }
        if !self.inputs.is_empty() {
            kv.push((
                "inputs".into(),
                Json::Obj(
                    self.inputs
                        .iter()
                        .map(|(k, data)| {
                            (k.clone(), Json::Arr(data.iter().map(|x| Json::Num(*x)).collect()))
                        })
                        .collect(),
                ),
            ));
        }
        if let Some(outs) = &self.outputs {
            kv.push((
                "outputs".into(),
                Json::Arr(outs.iter().map(|s| Json::Str(s.clone())).collect()),
            ));
        }
        if let Some(b) = &self.backend {
            kv.push(("backend".into(), Json::Str(b.clone())));
        }
        if self.inspector {
            kv.push(("inspector".into(), Json::Bool(true)));
        }
        Json::Obj(kv)
    }

    pub fn from_json(v: &Json) -> Result<RunRequest, String> {
        let mut req = RunRequest::default();
        if let Some(p) = v.get("preset") {
            req.preset = p.as_str().ok_or("field `preset` must be a string")?.to_string();
        }
        if let Some(t) = v.get("threads") {
            req.threads =
                t.as_i64().ok_or("field `threads` must be an integer")?.clamp(0, 1 << 16) as usize;
        }
        if let Some(p) = v.get("params") {
            for (k, x) in p.as_obj().ok_or("field `params` must be an object")? {
                let val = x.as_i64().ok_or_else(|| format!("param `{k}` must be an integer"))?;
                req.params.push((k.clone(), val));
            }
        }
        if let Some(inp) = v.get("inputs") {
            for (k, x) in inp.as_obj().ok_or("field `inputs` must be an object")? {
                let arr = x.as_arr().ok_or_else(|| format!("input `{k}` must be a number array"))?;
                let data = arr
                    .iter()
                    .map(|e| e.as_f64().ok_or_else(|| format!("input `{k}`: non-numeric entry")))
                    .collect::<Result<Vec<f64>, String>>()?;
                req.inputs.push((k.clone(), data));
            }
        }
        if let Some(outs) = v.get("outputs") {
            let arr = outs.as_arr().ok_or("field `outputs` must be a string array")?;
            let names = arr
                .iter()
                .map(|e| e.as_str().map(str::to_string).ok_or("`outputs`: non-string entry"))
                .collect::<Result<Vec<String>, _>>()?;
            req.outputs = Some(names);
        }
        if let Some(b) = v.get("backend") {
            req.backend = Some(b.as_str().ok_or("field `backend` must be a string")?.to_string());
        }
        if let Some(i) = v.get("inspector") {
            req.inspector = i.as_bool().ok_or("field `inspector` must be a boolean")?;
        }
        Ok(req)
    }
}

/// `POST /run/<id>` success reply.
#[derive(Debug, Clone)]
pub struct RunReply {
    pub kernel: String,
    pub name: String,
    /// Wall-clock VM execution time on the daemon, milliseconds.
    pub wall_ms: f64,
    /// Fuel spent (loop back-edges), reported on metered (untrusted)
    /// runs; `None` on unmetered daemons.
    pub fuel_used: Option<u64>,
    /// The backend that actually executed (`"vm"` | `"native"` |
    /// `"speculative"`) — a native *request* may still run on the VM
    /// when the daemon's host has no JIT. Absent on replies from
    /// pre-native daemons: `"vm"`.
    pub backend: String,
    /// Speculation counters `(attempted, commits, aborts)` when the run
    /// executed on the speculative tier; `None` otherwise (and absent
    /// on the wire).
    pub speculation: Option<(u64, u64, u64)>,
    /// Per-loop inspector certificates (`"L<id> <var>: <certificate>"`)
    /// when the request asked for inspection; `None` otherwise.
    pub inspector: Option<Vec<String>>,
    /// `name → contents` for each requested argument container.
    pub outputs: Vec<(String, Vec<f64>)>,
}

impl RunReply {
    pub fn to_json(&self) -> Json {
        let mut kv = vec![
            ("kernel".into(), Json::Str(self.kernel.clone())),
            ("name".into(), Json::Str(self.name.clone())),
            ("wall_ms".into(), Json::Num(self.wall_ms)),
        ];
        if let Some(f) = self.fuel_used {
            kv.push(("fuel_used".into(), Json::Num(f as f64)));
        }
        kv.push(("backend".into(), Json::Str(self.backend.clone())));
        if let Some((attempted, commits, aborts)) = self.speculation {
            kv.push((
                "speculation".into(),
                Json::Obj(vec![
                    ("attempted".into(), Json::Num(attempted as f64)),
                    ("commits".into(), Json::Num(commits as f64)),
                    ("aborts".into(), Json::Num(aborts as f64)),
                ]),
            ));
        }
        if let Some(lines) = &self.inspector {
            kv.push((
                "inspector".into(),
                Json::Arr(lines.iter().map(|s| Json::Str(s.clone())).collect()),
            ));
        }
        kv.push((
            "outputs".into(),
            Json::Obj(
                self.outputs
                    .iter()
                    .map(|(k, data)| {
                        (k.clone(), Json::Arr(data.iter().map(|x| Json::Num(*x)).collect()))
                    })
                    .collect(),
            ),
        ));
        Json::Obj(kv)
    }

    pub fn from_json(v: &Json) -> Result<RunReply, String> {
        let mut outputs = Vec::new();
        for (k, x) in v
            .get("outputs")
            .and_then(Json::as_obj)
            .ok_or("missing object field `outputs`")?
        {
            let data = x
                .as_arr()
                .ok_or_else(|| format!("output `{k}` must be a number array"))?
                .iter()
                .map(|e| e.as_f64().ok_or_else(|| format!("output `{k}`: non-numeric entry")))
                .collect::<Result<Vec<f64>, String>>()?;
            outputs.push((k.clone(), data));
        }
        Ok(RunReply {
            kernel: v
                .get("kernel")
                .and_then(Json::as_str)
                .ok_or("missing string field `kernel`")?
                .to_string(),
            name: v
                .get("name")
                .and_then(Json::as_str)
                .ok_or("missing string field `name`")?
                .to_string(),
            wall_ms: v.get("wall_ms").and_then(Json::as_f64).unwrap_or(0.0),
            fuel_used: v
                .get("fuel_used")
                .and_then(Json::as_i64)
                .map(|f| f.max(0) as u64),
            backend: v
                .get("backend")
                .and_then(Json::as_str)
                .unwrap_or("vm")
                .to_string(),
            // Absent on replies from pre-speculation daemons.
            speculation: v.get("speculation").map(|s| {
                let n = |k: &str| s.get(k).and_then(Json::as_i64).unwrap_or(0).max(0) as u64;
                (n("attempted"), n("commits"), n("aborts"))
            }),
            inspector: v.get("inspector").and_then(Json::as_arr).map(|arr| {
                arr.iter()
                    .filter_map(|e| e.as_str().map(str::to_string))
                    .collect()
            }),
            outputs,
        })
    }
}

/// The uniform non-200 body.
pub fn error_body(msg: &str) -> String {
    Json::Obj(vec![("error".to_string(), Json::Str(msg.to_string()))]).to_string()
}

/// Non-200 body with a machine-readable `code` (structured traps:
/// `out_of_bounds`, `fuel_exhausted`, `time_limit`; verifier refusals:
/// `rejected`).
pub fn error_body_code(msg: &str, code: &str) -> String {
    Json::Obj(vec![
        ("error".to_string(), Json::Str(msg.to_string())),
        ("code".to_string(), Json::Str(code.to_string())),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compile_request_round_trips_and_defaults_pipeline() {
        let req = CompileRequest::new("program t { }", "cfg2");
        let back = CompileRequest::from_json(&req.to_json()).unwrap();
        assert_eq!(back.source, "program t { }");
        assert_eq!(back.pipeline, "cfg2");
        let v = Json::parse(r#"{"source": "program t { }"}"#).unwrap();
        assert_eq!(CompileRequest::from_json(&v).unwrap().pipeline, "auto");
        assert!(CompileRequest::from_json(&Json::Obj(vec![])).is_err());
    }

    #[test]
    fn run_request_round_trips() {
        let req = RunRequest {
            preset: "small".into(),
            params: vec![("st_N".into(), 64)],
            inputs: vec![("u".into(), vec![1.0, -0.5])],
            threads: 4,
            outputs: Some(vec!["u".into()]),
            backend: Some("native".into()),
            inspector: true,
        };
        let back = RunRequest::from_json(&req.to_json()).unwrap();
        assert_eq!(back.preset, "small");
        assert_eq!(back.params, vec![("st_N".to_string(), 64)]);
        assert_eq!(back.inputs.len(), 1);
        assert_eq!(back.inputs[0].1, vec![1.0, -0.5]);
        assert_eq!(back.threads, 4);
        assert_eq!(back.outputs.as_deref(), Some(&["u".to_string()][..]));
        assert_eq!(back.backend.as_deref(), Some("native"));
        assert!(back.inspector);
        // Empty object = all defaults.
        let d = RunRequest::from_json(&Json::Obj(vec![])).unwrap();
        assert_eq!((d.preset.as_str(), d.threads), ("tiny", 1));
        assert_eq!(d.backend, None);
        assert!(!d.inspector);
        // Type errors are reported by field.
        let bad = Json::parse(r#"{"params": {"N": 1.5}}"#).unwrap();
        assert!(RunRequest::from_json(&bad).unwrap_err().contains("`N`"));
    }

    #[test]
    fn replies_round_trip() {
        let reply = CompileReply {
            kernel: "k0123456789abcdef".into(),
            name: "stencil_time".into(),
            pipeline: "auto".into(),
            cached: true,
            coalesced: false,
            passes: vec![("doall".into(), "L1".into())],
            params: vec!["st_N".into()],
            arguments: vec!["u".into()],
            tier: "proven".into(),
            unproven: 0,
            fuel_bound: Some("st_T*st_N".into()),
        };
        let back = CompileReply::from_json(&reply.to_json()).unwrap();
        assert_eq!(back.kernel, reply.kernel);
        assert!(back.cached);
        assert_eq!(back.passes, reply.passes);
        assert_eq!(back.arguments, reply.arguments);
        assert_eq!(back.tier, "proven");
        assert_eq!(back.fuel_bound.as_deref(), Some("st_T*st_N"));
        // A pre-verifier reply (no tier fields) parses as trusted.
        let legacy = Json::parse(
            r#"{"kernel":"k0","name":"t","pipeline":"auto","passes":[],
                "params":[],"arguments":[]}"#,
        )
        .unwrap();
        let back = CompileReply::from_json(&legacy).unwrap();
        assert_eq!(back.tier, "trusted");
        assert_eq!(back.fuel_bound, None);

        let run = RunReply {
            kernel: reply.kernel.clone(),
            name: reply.name.clone(),
            wall_ms: 0.25,
            fuel_used: Some(12),
            backend: "native".into(),
            speculation: Some((2, 1, 1)),
            inspector: Some(vec!["L0 i: doall".into()]),
            outputs: vec![("u".into(), vec![0.0, -0.0, 2.5])],
        };
        let back = RunReply::from_json(&run.to_json()).unwrap();
        assert_eq!(back.outputs[0].0, "u");
        assert_eq!(back.backend, "native");
        assert_eq!(back.speculation, Some((2, 1, 1)));
        assert_eq!(back.inspector.as_deref(), Some(&["L0 i: doall".to_string()][..]));
        // A pre-native reply (no backend field) parses as vm.
        let legacy = Json::parse(r#"{"kernel":"k0","name":"t","outputs":{}}"#).unwrap();
        let legacy = RunReply::from_json(&legacy).unwrap();
        assert_eq!(legacy.backend, "vm");
        assert_eq!(legacy.speculation, None);
        assert_eq!(legacy.inspector, None);
        let bits: Vec<u64> = back.outputs[0].1.iter().map(|x| x.to_bits()).collect();
        assert_eq!(bits, vec![0.0f64.to_bits(), (-0.0f64).to_bits(), 2.5f64.to_bits()]);
    }

    #[test]
    fn extract_request_and_reply_round_trip() {
        let req = ExtractRequest::new("void f(int n) {}", "c", "cfg2", "demo");
        let back = ExtractRequest::from_json(&req.to_json()).unwrap();
        assert_eq!(back.source, "void f(int n) {}");
        assert_eq!((back.lang.as_str(), back.pipeline.as_str()), ("c", "cfg2"));
        assert_eq!(back.stem, "demo");
        // `pipeline` and `stem` default; `source` and `lang` are required.
        let v = Json::parse(r#"{"source": "x", "lang": "f90"}"#).unwrap();
        let d = ExtractRequest::from_json(&v).unwrap();
        assert_eq!((d.pipeline.as_str(), d.stem.as_str()), ("auto", "app"));
        assert!(ExtractRequest::from_json(&Json::Obj(vec![])).is_err());

        let reply = ExtractReply {
            kernels: vec![ExtractedKernelReply {
                compile: CompileReply {
                    kernel: "kfeedfacefeedface".into(),
                    name: "demo_stencil".into(),
                    pipeline: "auto".into(),
                    cached: false,
                    coalesced: false,
                    passes: vec![],
                    params: vec!["demo_stencil_n".into()],
                    arguments: vec!["a".into(), "b".into()],
                    tier: "proven".into(),
                    unproven: 0,
                    fuel_bound: Some("demo_stencil_n".into()),
                },
                silo: "program demo_stencil { }".into(),
            }],
            skipped: vec![SkipReply {
                line: 7,
                construct: "goto statement".into(),
                reason: "unstructured control flow is not liftable".into(),
            }],
        };
        let back = ExtractReply::from_json(&reply.to_json()).unwrap();
        assert_eq!(back.kernels.len(), 1);
        assert_eq!(back.kernels[0].compile.kernel, "kfeedfacefeedface");
        assert_eq!(back.kernels[0].silo, "program demo_stencil { }");
        assert_eq!(back.skipped.len(), 1);
        assert_eq!(back.skipped[0].line, 7);
        assert_eq!(back.skipped[0].construct, "goto statement");
    }

    #[test]
    fn error_bodies_are_json() {
        let v = Json::parse(&error_body("parse error at line 3")).unwrap();
        assert_eq!(v.get("error").unwrap().as_str(), Some("parse error at line 3"));
    }
}
