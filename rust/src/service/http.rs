//! Tiny HTTP/1.1 framing over `std::net` — exactly enough for the
//! service's fixed-length JSON bodies. Shared by the daemon and the
//! client so the two ends cannot drift: bodies framed by
//! `Content-Length`, connections reused (`Connection: keep-alive`) up
//! to the daemon's per-connection request cap, closed when either side
//! says `Connection: close`.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

/// Largest accepted message body. Compile sources and run inputs sit far
/// below this; the cap keeps a misbehaving peer from ballooning a
/// worker's memory.
pub const MAX_BODY: usize = 16 * 1024 * 1024;

/// Largest accepted request/status/header line — same rationale as
/// [`MAX_BODY`], enforced by the capped line reader so a newline-free
/// byte stream cannot grow a worker's memory either.
const MAX_LINE: usize = 64 * 1024;

/// Per-connection socket timeout (both directions). Generous because a
/// cold `/compile` of a large program autotunes before replying.
pub const IO_TIMEOUT: Duration = Duration::from_secs(120);

/// A parsed request: method, path, headers, raw body.
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    /// The body as UTF-8 text (the protocol is all JSON).
    pub fn body_str(&self) -> Result<&str> {
        std::str::from_utf8(&self.body).context("request body is not UTF-8")
    }
}

/// `read_line` with a hard cap: returns the line including its
/// terminator, or everything up to EOF (empty string = clean EOF).
fn read_line_capped<R: BufRead>(stream: &mut R, cap: usize) -> Result<String> {
    let mut line: Vec<u8> = Vec::new();
    loop {
        let buf = stream.fill_buf()?;
        if buf.is_empty() {
            break; // EOF
        }
        let (chunk_len, done) = match buf.iter().position(|&b| b == b'\n') {
            Some(p) => (p + 1, true),
            None => (buf.len(), false),
        };
        line.extend_from_slice(&buf[..chunk_len]);
        stream.consume(chunk_len);
        if done {
            break;
        }
        if line.len() > cap {
            bail!("line too long ({} bytes, cap {cap})", line.len());
        }
    }
    Ok(String::from_utf8_lossy(&line).into_owned())
}

/// Read one request (blocking; body framed by `Content-Length`).
pub fn read_request<R: BufRead>(stream: &mut R) -> Result<Request> {
    match read_request_opt(stream)? {
        Some(req) => Ok(req),
        None => bail!("peer closed before sending a request"),
    }
}

/// [`read_request`] distinguishing a clean EOF (`Ok(None)` — the peer
/// finished a keep-alive conversation) from a malformed request.
pub fn read_request_opt<R: BufRead>(stream: &mut R) -> Result<Option<Request>> {
    let line = read_line_capped(stream, MAX_LINE)?;
    if line.is_empty() {
        return Ok(None);
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_ascii_uppercase();
    let path = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("");
    if method.is_empty() || path.is_empty() || !version.starts_with("HTTP/1") {
        bail!("malformed request line: {}", line.trim_end());
    }
    let headers = read_headers(stream)?;
    let len = content_length(&headers)?;
    let mut body = vec![0u8; len];
    stream.read_exact(&mut body).context("truncated request body")?;
    Ok(Some(Request {
        method,
        path,
        headers,
        body,
    }))
}

/// Write a JSON response with a fixed status set and `Connection: close`.
pub fn write_response<W: Write>(stream: &mut W, status: u16, body: &str) -> Result<()> {
    write_response_conn(stream, status, body, true)
}

/// [`write_response`] with an explicit connection disposition: `close =
/// false` advertises `Connection: keep-alive` so the peer may send the
/// next request on the same socket.
pub fn write_response_conn<W: Write>(
    stream: &mut W,
    status: u16,
    body: &str,
    close: bool,
) -> Result<()> {
    write_response_full(stream, status, "application/json", body, close)
}

/// [`write_response_conn`] with an explicit content type (the protocol
/// is JSON everywhere except the Prometheus text exposition).
pub fn write_response_full<W: Write>(
    stream: &mut W,
    status: u16,
    content_type: &str,
    body: &str,
    close: bool,
) -> Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        422 => "Unprocessable Entity",
        500 => "Internal Server Error",
        _ => "Unknown",
    };
    let conn = if close { "close" } else { "keep-alive" };
    let msg = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: {conn}\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(msg.as_bytes())?;
    stream.flush()?;
    Ok(())
}

/// One client-side exchange: connect, send, read the full response.
/// Returns `(status, body)`.
pub fn roundtrip(addr: &str, method: &str, path: &str, body: &str) -> Result<(u16, String)> {
    let stream =
        TcpStream::connect(addr).with_context(|| format!("cannot connect to {addr}"))?;
    stream.set_read_timeout(Some(IO_TIMEOUT))?;
    stream.set_write_timeout(Some(IO_TIMEOUT))?;
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    (&stream).write_all(req.as_bytes())?;
    (&stream).flush()?;
    let mut reader = BufReader::new(&stream);
    read_response(&mut reader)
}

/// Read a response (status + headers + `Content-Length` body).
pub fn read_response<R: BufRead>(stream: &mut R) -> Result<(u16, String)> {
    let line = read_line_capped(stream, MAX_LINE)?;
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("malformed status line: {}", line.trim_end()))?;
    let headers = read_headers(stream)?;
    let len = content_length(&headers)?;
    let mut body = vec![0u8; len];
    stream.read_exact(&mut body).context("truncated response body")?;
    Ok((status, String::from_utf8(body).context("response body is not UTF-8")?))
}

fn read_headers<R: BufRead>(stream: &mut R) -> Result<Vec<(String, String)>> {
    let mut headers = Vec::new();
    for _ in 0..64 {
        let h = read_line_capped(stream, MAX_LINE)?;
        if h.is_empty() {
            bail!("peer closed mid-headers");
        }
        let t = h.trim_end();
        if t.is_empty() {
            return Ok(headers);
        }
        if let Some((k, v)) = t.split_once(':') {
            headers.push((k.trim().to_string(), v.trim().to_string()));
        }
    }
    bail!("too many headers")
}

fn content_length(headers: &[(String, String)]) -> Result<usize> {
    let len = headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case("content-length"))
        .map(|(_, v)| v.parse::<usize>().context("malformed Content-Length"))
        .transpose()?
        .unwrap_or(0);
    if len > MAX_BODY {
        bail!("body too large: {len} bytes (max {MAX_BODY})");
    }
    Ok(len)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn request_parses_with_body() {
        let raw = "POST /compile HTTP/1.1\r\nHost: x\r\nContent-Length: 5\r\n\r\nhello";
        let req = read_request(&mut Cursor::new(raw)).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/compile");
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.body_str().unwrap(), "hello");
    }

    #[test]
    fn request_without_body_parses() {
        let raw = "GET /healthz HTTP/1.1\r\n\r\n";
        let req = read_request(&mut Cursor::new(raw)).unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());
    }

    #[test]
    fn malformed_requests_are_rejected() {
        for raw in [
            "",
            "GARBAGE\r\n\r\n",
            "GET /x SPDY/3\r\n\r\n",
            "POST /x HTTP/1.1\r\nContent-Length: 99\r\n\r\nshort",
            "POST /x HTTP/1.1\r\nContent-Length: 99999999999\r\n\r\n",
        ] {
            assert!(read_request(&mut Cursor::new(raw)).is_err(), "accepted {raw:?}");
        }
    }

    #[test]
    fn newline_free_streams_are_rejected_not_buffered() {
        // A request line with no terminator must hit the line cap, not
        // grow the worker's memory until OOM.
        let huge = vec![b'a'; MAX_LINE + 8192];
        let e = read_request(&mut Cursor::new(huge)).unwrap_err();
        assert!(e.to_string().contains("line too long"), "{e}");
    }

    #[test]
    fn clean_eof_is_none_not_error() {
        // A keep-alive peer that simply hangs up between requests is a
        // clean end of conversation, not a protocol error.
        assert!(read_request_opt(&mut Cursor::new("")).unwrap().is_none());
    }

    #[test]
    fn keep_alive_response_advertises_connection() {
        let mut buf = Vec::new();
        write_response_conn(&mut buf, 200, "{}", false).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.contains("Connection: keep-alive"), "{text}");
        let mut buf = Vec::new();
        write_response_conn(&mut buf, 422, "{}", true).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.contains("Connection: close"), "{text}");
        assert!(text.contains("422 Unprocessable Entity"), "{text}");
    }

    #[test]
    fn response_round_trips() {
        let mut buf = Vec::new();
        write_response(&mut buf, 404, "{\"error\":\"nope\"}").unwrap();
        let (status, body) = read_response(&mut Cursor::new(buf)).unwrap();
        assert_eq!(status, 404);
        assert_eq!(body, "{\"error\":\"nope\"}");
    }
}
