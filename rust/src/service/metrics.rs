//! Service request counters (`GET /metrics`).
//!
//! All updates are relaxed atomics — the endpoint is an observability
//! surface, not a synchronization point. Cache-level counters
//! (hits/misses/coalesced/evictions) live on the
//! [`ScheduleCache`](super::cache::ScheduleCache) itself; the metrics
//! endpoint merges both sets into one JSON document.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Monotonic daemon counters. Latency totals are in microseconds so tiny
/// kernels still register; `/metrics` reports derived milliseconds.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Every request that reached the router (any endpoint, any status).
    pub requests: AtomicU64,
    /// Responses with a non-200 status.
    pub errors: AtomicU64,
    /// Builder runs: compile-path cache misses that actually optimized,
    /// tuned, and lowered a program.
    pub compiles: AtomicU64,
    pub compile_us_total: AtomicU64,
    /// Completed `/run/<id>` executions.
    pub runs: AtomicU64,
    pub run_us_total: AtomicU64,
    /// Completed runs of artifacts the verifier fully proved (executed
    /// on the unchecked fast tier).
    pub runs_proven: AtomicU64,
    /// Completed runs of artifacts carrying runtime bounds checks.
    pub runs_checked: AtomicU64,
    /// Untrusted-mode compiles refused by the verifier (provably
    /// out-of-bounds accesses).
    pub rejected: AtomicU64,
    /// Runs aborted by a structured trap (bounds / fuel / wall clock).
    pub trapped: AtomicU64,
    /// Runs that went through the inspector (fresh or memoized
    /// certificate).
    pub runs_inspected: AtomicU64,
    /// Speculative-tier chunk-parallel attempts whose conflict check
    /// passed and whose privatized writes were committed.
    pub speculation_commits: AtomicU64,
    /// Speculative-tier attempts discarded (conflict or worker trap)
    /// and re-run sequentially.
    pub speculation_aborts: AtomicU64,
}

impl Metrics {
    pub fn bump(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_time(counter: &AtomicU64, wall: Duration) {
        counter.fetch_add(wall.as_micros() as u64, Ordering::Relaxed);
    }

    pub fn get(counter: &AtomicU64) -> u64 {
        counter.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::default();
        Metrics::bump(&m.requests);
        Metrics::bump(&m.requests);
        Metrics::add_time(&m.run_us_total, Duration::from_millis(3));
        assert_eq!(Metrics::get(&m.requests), 2);
        assert_eq!(Metrics::get(&m.run_us_total), 3000);
    }
}
