//! Service request counters and latency histograms (`GET /metrics`,
//! JSON or Prometheus text exposition).
//!
//! All updates are relaxed atomics — the endpoint is an observability
//! surface, not a synchronization point. Cache-level counters
//! (hits/misses/coalesced/evictions) live on the
//! [`ScheduleCache`](super::cache::ScheduleCache) itself; the metrics
//! endpoint merges both sets into one document. Per-endpoint request
//! latencies go into log₂-bucketed [`AtomicHistogram`]s
//! ([`crate::obs::hist`]), which the Prometheus exposition renders as
//! cumulative `_bucket{le=...}` series.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::obs::AtomicHistogram;

/// The daemon's endpoints, as latency-histogram labels. `Other` absorbs
/// unroutable paths so 404 scans cannot mint unbounded label values.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Endpoint {
    Healthz,
    Metrics,
    Kernels,
    Compile,
    Run,
    Extract,
    Other,
}

impl Endpoint {
    pub const ALL: [Endpoint; 7] = [
        Endpoint::Healthz,
        Endpoint::Metrics,
        Endpoint::Kernels,
        Endpoint::Compile,
        Endpoint::Run,
        Endpoint::Extract,
        Endpoint::Other,
    ];

    /// Stable label used in the Prometheus exposition.
    pub fn label(self) -> &'static str {
        match self {
            Endpoint::Healthz => "healthz",
            Endpoint::Metrics => "metrics",
            Endpoint::Kernels => "kernels",
            Endpoint::Compile => "compile",
            Endpoint::Run => "run",
            Endpoint::Extract => "extract",
            Endpoint::Other => "other",
        }
    }

    /// Classify a request path (query string already stripped).
    pub fn of_path(path: &str) -> Endpoint {
        match path {
            "/healthz" => Endpoint::Healthz,
            "/metrics" => Endpoint::Metrics,
            "/kernels" => Endpoint::Kernels,
            "/compile" => Endpoint::Compile,
            "/extract" => Endpoint::Extract,
            p if p.starts_with("/run/") => Endpoint::Run,
            _ => Endpoint::Other,
        }
    }
}

/// Monotonic daemon counters. Latency totals are in microseconds so tiny
/// kernels still register; `/metrics` reports derived milliseconds.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Every request that reached the router (any endpoint, any status).
    pub requests: AtomicU64,
    /// Responses with a non-200 status (= `errors_client` +
    /// `errors_server`; kept whole for wire compatibility).
    pub errors: AtomicU64,
    /// 4xx responses: the caller's fault (malformed body, unknown
    /// kernel, refused program, trapped run).
    pub errors_client: AtomicU64,
    /// 5xx responses: the daemon's fault.
    pub errors_server: AtomicU64,
    /// Builder runs: compile-path cache misses that actually optimized,
    /// tuned, and lowered a program.
    pub compiles: AtomicU64,
    pub compile_us_total: AtomicU64,
    /// Completed `/run/<id>` executions.
    pub runs: AtomicU64,
    pub run_us_total: AtomicU64,
    /// Completed runs of artifacts the verifier fully proved (executed
    /// on the unchecked fast tier).
    pub runs_proven: AtomicU64,
    /// Completed runs of artifacts carrying runtime bounds checks.
    pub runs_checked: AtomicU64,
    /// Untrusted-mode compiles refused by the verifier (provably
    /// out-of-bounds accesses).
    pub rejected: AtomicU64,
    /// Runs aborted by a structured trap (bounds / fuel / wall clock).
    pub trapped: AtomicU64,
    /// Runs that went through the inspector (fresh or memoized
    /// certificate).
    pub runs_inspected: AtomicU64,
    /// Speculative-tier chunk-parallel attempts whose conflict check
    /// passed and whose privatized writes were committed.
    pub speculation_commits: AtomicU64,
    /// Speculative-tier attempts discarded (conflict or worker trap)
    /// and re-run sequentially.
    pub speculation_aborts: AtomicU64,
    /// Measured-latency calibration samples folded into the cost model
    /// (successful `/run`s with a positive fuel count).
    pub cal_samples: AtomicU64,
    /// Adaptive recompilations triggered: a cached artifact's measured
    /// drift crossed `--retune-drift` and a background re-tune ran
    /// (whether or not it ended up swapping the artifact).
    pub retunes: AtomicU64,
    /// Retunes whose re-tuned schedule scored strictly better under the
    /// kernel's calibrated cost model and was hot-swapped in.
    pub retunes_improved: AtomicU64,
    /// Per-endpoint request latency, microseconds, log₂ buckets —
    /// indexed by [`Endpoint`]'s position in [`Endpoint::ALL`].
    pub latency: [AtomicHistogram; 7],
}

impl Metrics {
    pub fn bump(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_time(counter: &AtomicU64, wall: Duration) {
        counter.fetch_add(wall.as_micros() as u64, Ordering::Relaxed);
    }

    pub fn get(counter: &AtomicU64) -> u64 {
        counter.load(Ordering::Relaxed)
    }

    /// Record one routed response: latency into the endpoint's
    /// histogram, status into the error counters.
    pub fn observe(&self, endpoint: Endpoint, status: u16, wall: Duration) {
        Metrics::bump(&self.requests);
        if status != 200 {
            Metrics::bump(&self.errors);
            if status >= 500 {
                Metrics::bump(&self.errors_server);
            } else {
                Metrics::bump(&self.errors_client);
            }
        }
        let idx = Endpoint::ALL.iter().position(|e| *e == endpoint).unwrap_or(6);
        self.latency[idx].record(wall.as_micros() as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::default();
        Metrics::bump(&m.requests);
        Metrics::bump(&m.requests);
        Metrics::add_time(&m.run_us_total, Duration::from_millis(3));
        assert_eq!(Metrics::get(&m.requests), 2);
        assert_eq!(Metrics::get(&m.run_us_total), 3000);
    }

    #[test]
    fn observe_splits_errors_and_records_latency() {
        let m = Metrics::default();
        m.observe(Endpoint::Run, 200, Duration::from_micros(100));
        m.observe(Endpoint::Run, 404, Duration::from_micros(10));
        m.observe(Endpoint::Compile, 500, Duration::from_micros(10));
        assert_eq!(Metrics::get(&m.requests), 3);
        assert_eq!(Metrics::get(&m.errors), 2);
        assert_eq!(Metrics::get(&m.errors_client), 1);
        assert_eq!(Metrics::get(&m.errors_server), 1);
        let run = m.latency[4].snapshot();
        assert_eq!(run.count, 2);
        assert_eq!(run.sum_us, 110);
    }

    #[test]
    fn endpoint_classification() {
        assert_eq!(Endpoint::of_path("/healthz"), Endpoint::Healthz);
        assert_eq!(Endpoint::of_path("/run/abc123"), Endpoint::Run);
        assert_eq!(Endpoint::of_path("/nope"), Endpoint::Other);
        assert_eq!(Endpoint::of_path("/metrics"), Endpoint::Metrics);
        assert_eq!(Endpoint::of_path("/compile"), Endpoint::Compile);
        assert_eq!(Endpoint::of_path("/extract"), Endpoint::Extract);
    }
}
