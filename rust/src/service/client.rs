//! Client for the service wire protocol — `silo submit`, the tests, and
//! CI all drive the daemon through this, so the loop from SILO-Text
//! source to validated outputs closes end to end in-crate.

use anyhow::{anyhow, bail, Result};

use crate::coordinator::{compile_program, MemSchedules, OptConfig, PipelineSpec};
use crate::ir::ContainerKind;
use crate::kernels::Preset;
use crate::symbolic::Sym;

use super::http;
use super::json::Json;
use super::protocol::{
    CompileReply, CompileRequest, ExtractReply, ExtractRequest, RunReply, RunRequest,
};

/// A thin, connection-per-request client (mirrors the daemon's
/// `Connection: close` policy).
#[derive(Debug, Clone)]
pub struct Client {
    addr: String,
}

/// What one `submit` (compile + run) produced.
pub struct SubmitOutcome {
    pub compile: CompileReply,
    pub run: RunReply,
}

impl Client {
    /// `addr` is `host:port` (the daemon default is `127.0.0.1:7420`).
    pub fn new(addr: &str) -> Client {
        Client {
            addr: addr.to_string(),
        }
    }

    fn request(&self, method: &str, path: &str, body: &str) -> Result<Json> {
        let (status, text) = http::roundtrip(&self.addr, method, path, body)?;
        let v = Json::parse(&text)
            .map_err(|e| anyhow!("{method} {path}: malformed response body: {e}"))?;
        if status != 200 {
            let msg = v.get("error").and_then(Json::as_str).unwrap_or(&text);
            bail!("{method} {path}: HTTP {status}: {msg}");
        }
        Ok(v)
    }

    pub fn healthz(&self) -> Result<Json> {
        self.request("GET", "/healthz", "")
    }

    pub fn metrics(&self) -> Result<Json> {
        self.request("GET", "/metrics", "")
    }

    /// The Prometheus text exposition (`GET /metrics?format=prometheus`)
    /// — raw text, not JSON.
    pub fn metrics_prometheus(&self) -> Result<String> {
        let (status, text) = http::roundtrip(&self.addr, "GET", "/metrics?format=prometheus", "")?;
        if status != 200 {
            bail!("GET /metrics?format=prometheus: HTTP {status}: {text}");
        }
        Ok(text)
    }

    pub fn kernels(&self) -> Result<Json> {
        self.request("GET", "/kernels", "")
    }

    /// Submit SILO-Text for compilation under `pipeline` (e.g. `auto`).
    pub fn compile(&self, source: &str, pipeline: &str) -> Result<CompileReply> {
        let body = CompileRequest::new(source, pipeline).to_json().to_string();
        let v = self.request("POST", "/compile", &body)?;
        CompileReply::from_json(&v).map_err(|e| anyhow!("POST /compile: {e}"))
    }

    /// Submit raw C/Fortran source for extraction: the daemon lifts
    /// every affine nest it recognizes, compiles each through the
    /// normal cache, and reports refused constructs in `skipped`.
    pub fn extract(&self, req: &ExtractRequest) -> Result<ExtractReply> {
        let v = self.request("POST", "/extract", &req.to_json().to_string())?;
        ExtractReply::from_json(&v).map_err(|e| anyhow!("POST /extract: {e}"))
    }

    /// Execute a compiled kernel by id.
    pub fn run(&self, id: &str, req: &RunRequest) -> Result<RunReply> {
        let path = format!("/run/{id}");
        let v = self.request("POST", &path, &req.to_json().to_string())?;
        RunReply::from_json(&v).map_err(|e| anyhow!("POST {path}: {e}"))
    }

    /// Compile + run in one call — the `silo submit` path.
    pub fn submit_source(
        &self,
        source: &str,
        pipeline: &str,
        run: &RunRequest,
    ) -> Result<SubmitOutcome> {
        let compile = self.compile(source, pipeline)?;
        let run = self.run(&compile.kernel, run)?;
        Ok(SubmitOutcome { compile, run })
    }
}

/// The end-to-end check behind `silo submit --check` and the CI smoke
/// job: the daemon's outputs must be **bit-identical** to a local,
/// unoptimized run of the same source — the same invariant `silo
/// validate` pins for local pipelines, stretched across the wire.
pub fn check_against_local(source: &str, run_req: &RunRequest, reply: &RunReply) -> Result<()> {
    let parsed = crate::frontend::parse_str(source)?;
    let compiled = compile_program(
        parsed.program.clone(),
        &PipelineSpec::Config(OptConfig::None),
        MemSchedules::default(),
    )?;
    let preset = Preset::parse(&run_req.preset)?;
    // Rebuild the daemon's parameter bindings: explicit wins, preset
    // annotation otherwise.
    let mut params: Vec<(Sym, i64)> = Vec::new();
    for sym in &compiled.program.params {
        let explicit = run_req
            .params
            .iter()
            .find(|(n, _)| n.as_str() == sym.name())
            .map(|(_, v)| *v);
        let value = explicit.or_else(|| {
            parsed
                .presets
                .iter()
                .find(|(s, _)| s == sym)
                .and_then(|(_, b)| b.get(preset))
        });
        match value {
            Some(v) => params.push((*sym, v)),
            None => bail!("param `{}` unbound locally", sym.name()),
        }
    }
    let inputs = crate::kernels::gen_inputs_with(&compiled.program, &params, |name, i| {
        match run_req.inputs.iter().find(|(n, _)| n == name) {
            Some((_, data)) => data[i],
            None => parsed.init_value(name, i),
        }
    })?;
    let refs: Vec<_> = inputs.iter().map(|(c, v)| (*c, v.as_slice())).collect();
    let (storage, _) = compiled.execute(&params, &refs, 1)?;

    for (name, remote) in &reply.outputs {
        let container = compiled
            .program
            .containers
            .iter()
            .find(|c| c.kind == ContainerKind::Argument && c.name == *name)
            .ok_or_else(|| anyhow!("daemon returned unknown container `{name}`"))?;
        let local = &storage.arrays[container.id.0 as usize];
        if local.len() != remote.len() {
            bail!(
                "output `{name}`: daemon returned {} elements, local run has {}",
                remote.len(),
                local.len()
            );
        }
        for (i, (l, r)) in local.iter().zip(remote.iter()).enumerate() {
            if l.to_bits() != r.to_bits() {
                bail!(
                    "output `{name}`[{i}] diverged: daemon {r:?} vs local baseline {l:?} \
                     (bitwise)"
                );
            }
        }
    }
    Ok(())
}
