//! The daemon: a `std::net` HTTP/1.1 listener, a worker thread pool, and
//! the router over the schedule cache (`silo serve`).
//!
//! Request flow for `POST /compile`: parse the SILO-Text body → hash its
//! canonical printing × pipeline spec ([`super::cache::kernel_key`]) →
//! either return the resident [`ServedKernel`] (a cache hit skips
//! analysis, autotuning, and lowering entirely) or compile under the
//! shard's single-flight slot, so concurrent submissions of one program
//! tune exactly once. `POST /run/<id>` executes the cached artifact on
//! the VM with per-request parameter bindings, inputs, and thread count
//! — no optimizer work at all.
//!
//! **Trust model.** The daemon runs in one of two modes:
//!
//! * **Default (trusted)**: submissions execute on the same unchecked
//!   VM the CLI uses — no subscript bounds checks, no iteration
//!   budget. Bind to localhost (the default `127.0.0.1:7420`) or an
//!   otherwise-authenticated network; treat submissions like local CLI
//!   input.
//! * **`--untrusted`**: every submission is run through the static
//!   bounds verifier (`crate::verify`) *after* optimization. Programs
//!   whose accesses are all proven in bounds execute on the unchecked
//!   fast tier (`tier: "proven"` on the wire); unproven accesses are
//!   check-compiled so the VM traps with a structured `out_of_bounds`
//!   error instead of dereferencing wild (`tier: "checked"`); programs
//!   containing an access that can *never* be in bounds are refused
//!   with HTTP 422. Every `/run` is additionally metered: a fuel
//!   budget (`--fuel`, loop back-edges, checked at every back-edge)
//!   and a wall-clock cap (`--wall-ms`) turn runaway submissions into
//!   structured `fuel_exhausted` / `time_limit` errors instead of a
//!   wedged worker.
//!
//! In both modes the pre-execution surface is hardened: capped HTTP
//! framing with a per-connection keep-alive request cap, depth-limited
//! parsing, spec validation, per-run total allocation caps with
//! checked arithmetic, and panic-isolated workers.
//!
//! The daemon inherits the frontend's process-global symbol table, so
//! two submitted programs that reuse a `param` name share one symbol and
//! its assumptions — follow the corpus convention of kernel-prefixed
//! names (`st_N`, `hd_N`) when submitting many programs to one daemon.
//! Two daemon-relevant consequences, both snapshotted/bounded where the
//! service can and documented where it cannot:
//!
//! * assumption floors are captured per artifact at compile time
//!   ([`ServedKernel::param_floors`]), so a later submission raising a
//!   shared symbol's floor never changes which runs a cached kernel
//!   accepts;
//! * the intern table is bounded by the cache, not the submission
//!   history: each compile records the symbols it touches
//!   ([`crate::symbolic::SymScope`]), the daemon refcounts them per
//!   cache entry ([`SymRegistry`]), and evicting an entry's last
//!   reference releases its service-created symbols back to the
//!   interner's free list. `/metrics` exposes the live count as
//!   `symbols_interned`.

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::{
    compile_program_calibrated, CompiledKernel, MemSchedules, PipelineSpec, SafetyPolicy,
};
use crate::exec::{ExecLimits, Trap};
use crate::frontend::{init_value_with, InitSpec, PresetBindings};
use crate::ir::ContainerKind;
use crate::kernels::Preset;
use crate::native::Tier;
use crate::symbolic::eval::eval_int;
use crate::symbolic::{ContainerId, Sym};
use crate::tuner::CostCalibration;
use crate::verify::SafetyTier;

use super::cache::{self, Outcome, ScheduleCache};
use super::http::{self, Request};
use super::json::Json;
use super::metrics::{Endpoint, Metrics};
use super::protocol::{
    error_body, error_body_code, CompileReply, CompileRequest, ExtractReply, ExtractRequest,
    ExtractedKernelReply, RunReply, RunRequest, SkipReply,
};

/// Requests served on one keep-alive connection before the daemon
/// closes it (bounds per-connection resource pinning).
const MAX_REQUESTS_PER_CONN: usize = 32;

/// Idle window between keep-alive requests. Much shorter than the
/// in-request [`http::IO_TIMEOUT`]: a connection waiting for its *next*
/// request pins a blocking worker thread, so idle peers are hung up on
/// quickly (and silently) instead of holding a worker for the full
/// compile timeout 32 times over.
const KEEPALIVE_IDLE: std::time::Duration = std::time::Duration::from_secs(10);

/// Daemon configuration (`silo serve --addr --threads --cache-cap
/// [--untrusted --fuel --wall-ms]`).
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Bind address; port 0 picks an ephemeral port (tests).
    pub addr: String,
    /// Worker threads handling requests.
    pub workers: usize,
    /// Schedule-cache capacity in compiled kernels.
    pub cache_cap: usize,
    /// Cache shard count (tests pin 1 for deterministic LRU order).
    pub cache_shards: usize,
    /// Untrusted mode: verify every submission (refusing provable
    /// out-of-bounds programs, check-compiling unproven accesses) and
    /// meter every run with fuel + wall-clock caps.
    pub untrusted: bool,
    /// Per-run fuel budget (loop back-edges) in untrusted mode.
    pub fuel_limit: u64,
    /// Per-run wall-clock cap (milliseconds) in untrusted mode.
    pub wall_ms: u64,
    /// Default execution backend for runs that don't request one
    /// (`silo serve --backend=native`). Per-request `backend` overrides;
    /// either way a native run silently degrades to the VM when the
    /// host has no JIT, and the reply reports what actually ran.
    pub backend: Tier,
    /// Emit a structured (JSON-lines) access log on stderr: one line
    /// per routed request with its daemon-assigned request id, method,
    /// path, status, and latency (`silo serve --access-log`).
    pub access_log: bool,
    /// Adaptive recompilation threshold (`silo serve --retune-drift=R`,
    /// R > 1.0). When a cached autotuned artifact's per-kernel drift
    /// EWMA leaves the band [1/R, R], a single-flight background worker
    /// re-tunes it under the kernel's own calibration and hot-swaps the
    /// artifact (outputs verified bitwise identical first). `None`
    /// disables retuning entirely.
    pub retune_drift: Option<f64>,
    /// Minimum measured samples before a kernel's drift can trigger a
    /// retune — one cold-cache run must not tear down a warm artifact.
    pub retune_min: u64,
}

impl Default for ServiceConfig {
    fn default() -> ServiceConfig {
        ServiceConfig {
            addr: "127.0.0.1:7420".to_string(),
            workers: 4,
            cache_cap: 64,
            cache_shards: 8,
            untrusted: false,
            fuel_limit: 1 << 32,
            wall_ms: 30_000,
            backend: Tier::Vm,
            access_log: false,
            retune_drift: None,
            retune_min: 3,
        }
    }
}

/// Fuel-weighted aggregate of every per-kernel drift sample, for the
/// daemon-wide `model_drift` gauge (and as the calibration prior for
/// kernels that haven't run yet). Weighting by fuel makes the gauge
/// follow where the cycles actually went instead of letting a tiny
/// kernel's noisy ratio swamp the heavy hitters.
#[derive(Default)]
struct CalAgg {
    /// Σ fuel·ratio over accepted samples.
    weighted: f64,
    /// Σ fuel over accepted samples.
    weight: f64,
    samples: u64,
}

impl CalAgg {
    fn fold(&mut self, ratio: f64, fuel: u64) {
        self.weighted += ratio * fuel as f64;
        self.weight += fuel as f64;
        self.samples += 1;
    }

    /// The aggregate ratio (1.0 until a sample lands — the gauge's
    /// documented "model is exact" resting value).
    fn ratio(&self) -> f64 {
        if self.weight > 0.0 {
            self.weighted / self.weight
        } else {
            1.0
        }
    }
}

/// Per-kernel EWMA of whole-run hardware-counter rates, sampled around
/// `/run` executions when `perf_event_open` is available on this host.
#[derive(Default)]
struct HwStats {
    ipc: Option<f64>,
    miss_rate: Option<f64>,
    samples: u64,
}

impl HwStats {
    /// Fold one run's counts in. Each rate updates only when the sample
    /// defines it (a run with zero cache references must not drag the
    /// miss-rate EWMA toward a fake 0.0).
    fn fold(&mut self, counts: &crate::obs::HwCounts) {
        fn ewma(slot: &mut Option<f64>, sample: Option<f64>) {
            if let Some(s) = sample {
                *slot = Some(match *slot {
                    Some(prev) => 0.7 * prev + 0.3 * s,
                    None => s,
                });
            }
        }
        ewma(&mut self.ipc, counts.ipc());
        ewma(&mut self.miss_rate, counts.miss_rate());
        self.samples += 1;
    }
}

/// Refcounts of service-created interned symbols across resident cache
/// entries, so the process-global symbol table stays bounded by the
/// cache instead of growing with the submission history.
///
/// Every compile endpoint wraps its parse+build in a
/// [`crate::symbolic::SymScope`] and brackets itself with
/// `begin_compile`/`end_compile`. Entries `register` their captured
/// symbols on insertion and `unregister` them on eviction; symbols are
/// *owned* (eligible for release) once any scope records creating them,
/// which keeps pre-service symbols — built-in kernel params, test
/// fixtures — permanently off-limits. Actual release happens in exactly
/// one place: the last `end_compile` drains the pending set while no
/// compile is in flight, so an in-flight parse can never be left holding
/// a symbol whose slot was just recycled.
#[derive(Default)]
struct SymRegistry {
    inner: Mutex<SymRegistryInner>,
}

#[derive(Default)]
struct SymRegistryInner {
    /// Symbols some service scope created (`new == true`) — the only
    /// ones this registry may ever release.
    owned: std::collections::HashSet<Sym>,
    /// Live cache-entry references per owned symbol.
    counts: std::collections::HashMap<Sym, usize>,
    /// Release candidates awaiting an idle moment (no compile in
    /// flight). Re-checked against `counts` at drain time.
    pending: std::collections::HashSet<Sym>,
    in_flight: usize,
}

impl SymRegistry {
    fn begin_compile(&self) {
        self.inner.lock().unwrap().in_flight += 1;
    }

    /// Close a compile bracket; the last one out drains the pending
    /// release candidates. `release_syms` runs under the registry lock,
    /// so a concurrent `begin_compile` cannot start parsing (and
    /// re-interning a doomed name) mid-drain.
    fn end_compile(&self) {
        let mut g = self.inner.lock().unwrap();
        g.in_flight -= 1;
        if g.in_flight > 0 {
            return;
        }
        let candidates: Vec<Sym> = g.pending.drain().collect();
        let free: Vec<Sym> = candidates
            .into_iter()
            .filter(|s| g.owned.contains(s) && !g.counts.contains_key(s))
            .collect();
        for s in &free {
            g.owned.remove(s);
        }
        crate::symbolic::release_syms(&free);
    }

    /// Record a newly inserted cache entry's captured symbols.
    fn register(&self, syms: &[(Sym, bool)]) {
        let mut g = self.inner.lock().unwrap();
        for (s, new) in syms {
            if *new {
                g.owned.insert(*s);
            }
            if g.owned.contains(s) {
                *g.counts.entry(*s).or_insert(0) += 1;
            }
        }
    }

    /// A compile that produced no cache entry (parse/build error, or a
    /// cache hit whose scope re-looked-up existing names): owned symbols
    /// with no entry holding them become release candidates.
    fn discard(&self, syms: &[(Sym, bool)]) {
        let mut g = self.inner.lock().unwrap();
        for (s, new) in syms {
            if *new {
                g.owned.insert(*s);
            }
            if g.owned.contains(s) && !g.counts.contains_key(s) {
                g.pending.insert(*s);
            }
        }
    }

    /// Drop evicted entries' references; symbols with no remaining
    /// holder become release candidates.
    fn unregister(&self, evicted: &[std::sync::Arc<ServedKernel>]) {
        let mut g = self.inner.lock().unwrap();
        for e in evicted {
            for (s, _) in &e.syms {
                if let Some(c) = g.counts.get_mut(s) {
                    *c -= 1;
                    if *c == 0 {
                        g.counts.remove(s);
                        g.pending.insert(*s);
                    }
                }
            }
        }
    }
}

/// One cached compile: the optimized, lowered artifact plus the run-time
/// annotations (presets, input initialization) that live outside the IR.
/// Deliberately *not* the whole `ParsedKernel` — the pristine program is
/// only needed for key computation, and duplicating it per entry would
/// double the cache's program footprint.
pub struct ServedKernel {
    pub id: String,
    pub name: String,
    /// Normalized pipeline spec this artifact was compiled under.
    pub spec: String,
    /// Per-preset param bindings from the submission's annotations.
    pub presets: Vec<(Sym, PresetBindings)>,
    /// `init(shift, scale)` input annotations from the submission.
    pub inits: Vec<InitSpec>,
    /// Assumed lower bound of each param, snapshotted at compile time —
    /// the symbol table's assumptions are process-global and may be
    /// raised by a *later* submission reusing a name, which must not
    /// retroactively change which runs this cached artifact accepts.
    pub param_floors: Vec<(Sym, i64)>,
    /// The live artifact, behind an `Arc` swap point: `/run` snapshots
    /// the `Arc` once and executes that artifact end to end, so an
    /// adaptive retune can replace the artifact mid-traffic without
    /// tearing schedules out from under an in-flight run (the old
    /// artifact serves until its last holder drops it).
    artifact: Mutex<Arc<CompiledKernel>>,
    /// The submission's unoptimized program, kept only when adaptive
    /// retuning is armed and this artifact was autotuned — a retune
    /// must re-run the search from the pristine nest, not re-optimize
    /// an already-scheduled one.
    pristine: Option<crate::ir::Program>,
    /// Wall-clock cost of the build (optimize + tune + lower), ms.
    pub compile_ms: f64,
    /// Symbols this entry's compile touched, captured by the build's
    /// [`crate::symbolic::SymScope`] (`true` = the scope interned it).
    /// The daemon's [`SymRegistry`] refcounts these and releases the
    /// last holder's symbols on eviction.
    pub syms: Vec<(Sym, bool)>,
    /// Inspector certificate lines memoized per canonical parameter
    /// binding — the content-addressed cache entry *is* the
    /// (kernel, param-set) memo table, and eviction drops the
    /// certificates with the artifact they describe.
    pub inspect_memo: Mutex<std::collections::HashMap<String, Arc<Vec<String>>>>,
    /// Per-kernel measured ÷ modeled drift EWMA (keyed by this cache
    /// entry's content id, i.e. per artifact). Feeds this kernel's own
    /// recompile calibration and the `drift` field in `GET /kernels`;
    /// reset after every retune so post-swap drift re-accumulates
    /// against the *new* artifact's model.
    cal: Mutex<crate::tuner::CalEwma>,
    /// Whole-run hardware-counter EWMAs (empty where `perf_event_open`
    /// is unavailable — exported as explicit `hw: unavailable`, never
    /// as zeros).
    hw: Mutex<HwStats>,
    /// Single-flight latch: at most one background retune per kernel.
    retuning: AtomicBool,
}

impl ServedKernel {
    /// Snapshot the current artifact. Callers hold the `Arc` across
    /// their whole run; the mutex guards only the pointer swap.
    pub fn compiled(&self) -> Arc<CompiledKernel> {
        Arc::clone(&self.artifact.lock().unwrap())
    }
}

struct ServiceState {
    cache: ScheduleCache<ServedKernel>,
    syms: SymRegistry,
    metrics: Metrics,
    stop: AtomicBool,
    untrusted: bool,
    fuel_limit: u64,
    wall_ms: u64,
    backend: Tier,
    access_log: bool,
    started: Instant,
    /// Daemon-assigned request ids (access log + request spans).
    next_req: std::sync::atomic::AtomicU64,
    /// Fuel-weighted aggregate of per-kernel drift samples, fed by
    /// `/run`, exported as `model_drift`, and used as the calibration
    /// prior for kernels that haven't run yet.
    cal: Mutex<CalAgg>,
    /// Adaptive-recompilation threshold (ratio band edge, > 1.0);
    /// `None` = retuning disabled.
    retune_drift: Option<f64>,
    /// Samples a kernel needs before its drift can trigger a retune.
    retune_min: u64,
}

impl ServiceState {
    /// The calibration a *fresh* compile should use: the fuel-weighted
    /// aggregate across all kernels (identity until any run has been
    /// measured), clamped so one absurd sample cannot poison the search
    /// space's scores. Retunes of an already-measured kernel use that
    /// kernel's own EWMA instead.
    fn calibration(&self) -> CostCalibration {
        let g = self.cal.lock().unwrap();
        if g.samples == 0 {
            CostCalibration::identity()
        } else {
            CostCalibration {
                scale: g.ratio().clamp(1e-3, 1e3),
            }
        }
    }
}

/// A running daemon. Dropping the handle leaves the threads running
/// until process exit; call [`Server::shutdown`] for an orderly stop or
/// [`Server::join`] to serve until killed.
pub struct Server {
    addr: SocketAddr,
    state: Arc<ServiceState>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Server {
    /// Bind `config.addr` and start the accept loop + worker pool.
    pub fn serve(config: &ServiceConfig) -> Result<Server> {
        let listener = TcpListener::bind(&config.addr)
            .with_context(|| format!("cannot bind {}", config.addr))?;
        let addr = listener.local_addr()?;
        let state = Arc::new(ServiceState {
            cache: ScheduleCache::with_shards(config.cache_cap, config.cache_shards),
            syms: SymRegistry::default(),
            metrics: Metrics::default(),
            stop: AtomicBool::new(false),
            untrusted: config.untrusted,
            fuel_limit: config.fuel_limit.max(1),
            wall_ms: config.wall_ms.max(1),
            backend: config.backend,
            access_log: config.access_log,
            started: Instant::now(),
            next_req: std::sync::atomic::AtomicU64::new(1),
            cal: Mutex::new(CalAgg::default()),
            retune_drift: config.retune_drift.filter(|r| r.is_finite() && *r > 1.0),
            retune_min: config.retune_min.max(1),
        });
        let (tx, rx) = mpsc::channel::<TcpStream>();
        let rx = Arc::new(Mutex::new(rx));
        let workers: Vec<JoinHandle<()>> = (0..config.workers.max(1))
            .map(|_| {
                let rx = Arc::clone(&rx);
                let state = Arc::clone(&state);
                std::thread::spawn(move || loop {
                    // Standard shared-receiver pool: hold the lock only
                    // while dequeuing, never while handling.
                    let next = rx.lock().unwrap().recv();
                    match next {
                        Ok(stream) => {
                            // A panicking request must not shrink the
                            // pool: catch it, drop the connection, keep
                            // serving.
                            let _ = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(|| {
                                    handle_connection(stream, &state)
                                }),
                            );
                        }
                        Err(_) => break, // sender dropped: shutting down
                    }
                })
            })
            .collect();
        let accept = {
            let state = Arc::clone(&state);
            std::thread::spawn(move || {
                for stream in listener.incoming() {
                    if state.stop.load(Ordering::SeqCst) {
                        break;
                    }
                    if let Ok(s) = stream {
                        let _ = tx.send(s);
                    }
                }
                // tx drops here; workers drain the queue and exit.
            })
        };
        Ok(Server {
            addr,
            state,
            accept: Some(accept),
            workers,
        })
    }

    /// The bound address (resolves port 0 to the actual port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block until the accept loop exits (i.e. serve until killed).
    pub fn join(mut self) {
        self.join_threads();
    }

    /// Stop accepting, let in-flight requests finish, and return.
    pub fn shutdown(mut self) {
        self.state.stop.store(true, Ordering::SeqCst);
        // Wake the blocking accept with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        self.join_threads();
    }

    fn join_threads(&mut self) {
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Serve one connection: up to [`MAX_REQUESTS_PER_CONN`] requests over
/// HTTP keep-alive. The connection closes when the client asks
/// (`Connection: close`), on a framing error, at the request cap, or
/// on a clean client hang-up between requests.
fn handle_connection(stream: TcpStream, state: &Arc<ServiceState>) {
    let _ = stream.set_read_timeout(Some(http::IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(http::IO_TIMEOUT));
    let mut reader = BufReader::new(&stream);
    for served in 0..MAX_REQUESTS_PER_CONN {
        if served > 0 {
            // Between keep-alive requests only a short idle window is
            // tolerated (see [`KEEPALIVE_IDLE`]).
            let _ = stream.set_read_timeout(Some(KEEPALIVE_IDLE));
        }
        let req = match http::read_request_opt(&mut reader) {
            Ok(Some(req)) => req,
            // Clean EOF between requests: the peer is done.
            Ok(None) => return,
            Err(e) => {
                // An idle keep-alive peer timing out is a normal hangup,
                // not a protocol error — close without a 400 or an
                // `errors` bump.
                let idle = e
                    .downcast_ref::<std::io::Error>()
                    .map(|io| {
                        matches!(
                            io.kind(),
                            std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock
                        )
                    })
                    .unwrap_or(false);
                if idle && served > 0 {
                    return;
                }
                let msg = format!("{e:#}");
                // Framing-layer size rejections are 413 per the wire
                // protocol; everything else malformed is a 400.
                let status = if msg.contains("body too large") { 413 } else { 400 };
                state
                    .metrics
                    .observe(Endpoint::Other, status, std::time::Duration::ZERO);
                let _ = http::write_response(&mut (&stream), status, &error_body(&msg));
                return;
            }
        };
        // Reading the body may have started under the idle timeout; the
        // in-request budget applies while handling and responding.
        let _ = stream.set_read_timeout(Some(http::IO_TIMEOUT));
        let client_close = req
            .header("connection")
            .map(|v| v.eq_ignore_ascii_case("close"))
            .unwrap_or(false);
        let close = client_close || served + 1 == MAX_REQUESTS_PER_CONN;
        // Request bracket: a daemon-assigned id, a request-scoped trace
        // id (so spans recorded while handling group under it), latency
        // into the endpoint's histogram, and the optional access log.
        let path_only = req.path.split('?').next().unwrap_or("").to_string();
        let endpoint = Endpoint::of_path(&path_only);
        let req_id = state
            .next_req
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let prev_trace = crate::obs::span::set_current_trace(crate::obs::next_trace_id());
        let mut sp = crate::obs::span("http", || format!("{} {path_only}", req.method));
        let t0 = Instant::now();
        let (status, body, content_type) = route(&req, state);
        let wall = t0.elapsed();
        sp.arg("status", || status.to_string());
        sp.arg("req_id", || req_id.to_string());
        drop(sp);
        crate::obs::span::set_current_trace(prev_trace);
        state.metrics.observe(endpoint, status, wall);
        if state.access_log {
            access_log_line(req_id, &req.method, &path_only, endpoint.label(), status, wall);
        }
        let ok =
            http::write_response_full(&mut (&stream), status, content_type, &body, close).is_ok();
        if !ok || close {
            return;
        }
    }
}

/// One structured access-log line on stderr (JSON lines; `Json::Str`
/// escapes the attacker-controlled path).
fn access_log_line(
    id: u64,
    method: &str,
    path: &str,
    endpoint: &str,
    status: u16,
    wall: std::time::Duration,
) {
    let line = Json::Obj(vec![
        ("id".into(), Json::Num(id as f64)),
        ("method".into(), Json::Str(method.into())),
        ("path".into(), Json::Str(path.into())),
        ("endpoint".into(), Json::Str(endpoint.into())),
        ("status".into(), Json::Num(status as f64)),
        ("ms".into(), Json::Num(wall.as_secs_f64() * 1e3)),
    ]);
    eprintln!("{line}");
}

/// Prometheus text exposition content type (scrapers accept plain text,
/// but the versioned type is the documented contract).
const PROMETHEUS_CT: &str = "text/plain; version=0.0.4";
const JSON_CT: &str = "application/json";

fn route(req: &Request, state: &Arc<ServiceState>) -> (u16, String, &'static str) {
    // Split the query string off: `/metrics?format=prometheus` must
    // route like `/metrics`.
    let (path, query) = match req.path.split_once('?') {
        Some((p, q)) => (p, q),
        None => (req.path.as_str(), ""),
    };
    let json = |(status, body): (u16, String)| (status, body, JSON_CT);
    match (req.method.as_str(), path) {
        ("GET", "/healthz") => json((200, healthz_body(state))),
        ("GET", "/metrics") if query.split('&').any(|kv| kv == "format=prometheus") => {
            (200, prometheus_body(state), PROMETHEUS_CT)
        }
        ("GET", "/metrics") => json((200, metrics_body(state))),
        ("GET", "/kernels") => json((200, kernels_body(state))),
        ("POST", "/compile") => json(compile_endpoint(req, state)),
        ("POST", "/extract") => json(extract_endpoint(req, state)),
        ("POST", p) if p.starts_with("/run/") => {
            json(run_endpoint(req, state, &p["/run/".len()..]))
        }
        ("GET" | "POST", _) => json((
            404,
            error_body(&format!(
                "no such route {} {} (endpoints: GET /healthz /metrics /kernels, \
                 POST /compile /extract /run/<id>)",
                req.method, req.path
            )),
        )),
        _ => json((405, error_body(&format!("method {} not allowed", req.method)))),
    }
}

fn healthz_body(state: &ServiceState) -> String {
    Json::Obj(vec![
        ("ok".into(), Json::Bool(true)),
        ("service".into(), Json::Str("silo".into())),
        ("version".into(), Json::Str(env!("CARGO_PKG_VERSION").into())),
        (
            "uptime_s".into(),
            Json::Num(state.started.elapsed().as_secs_f64()),
        ),
        ("pid".into(), Json::Num(std::process::id() as f64)),
        (
            "backend_default".into(),
            Json::Str(state.backend.as_str().into()),
        ),
        ("untrusted".into(), Json::Bool(state.untrusted)),
    ])
    .to_string()
}

fn metrics_body(state: &ServiceState) -> String {
    let s = state.cache.stats();
    let m = &state.metrics;
    let cal = {
        let c = state.cal.lock().unwrap();
        (c.ratio(), c.samples)
    };
    let num = |v: u64| Json::Num(v as f64);
    let mut fields = vec![
        ("hits".into(), num(s.hits)),
        ("misses".into(), num(s.misses)),
        ("coalesced".into(), num(s.coalesced)),
        ("evictions".into(), num(s.evictions)),
        ("entries".into(), num(s.entries as u64)),
        ("capacity".into(), num(s.capacity as u64)),
        ("requests".into(), num(Metrics::get(&m.requests))),
        ("errors".into(), num(Metrics::get(&m.errors))),
        ("errors_client".into(), num(Metrics::get(&m.errors_client))),
        ("errors_server".into(), num(Metrics::get(&m.errors_server))),
        ("compiles".into(), num(Metrics::get(&m.compiles))),
        (
            "compile_ms_total".into(),
            Json::Num(Metrics::get(&m.compile_us_total) as f64 / 1e3),
        ),
        ("runs".into(), num(Metrics::get(&m.runs))),
        (
            "run_ms_total".into(),
            Json::Num(Metrics::get(&m.run_us_total) as f64 / 1e3),
        ),
        ("runs_proven".into(), num(Metrics::get(&m.runs_proven))),
        ("runs_checked".into(), num(Metrics::get(&m.runs_checked))),
        ("rejected".into(), num(Metrics::get(&m.rejected))),
        ("trapped".into(), num(Metrics::get(&m.trapped))),
        ("runs_inspected".into(), num(Metrics::get(&m.runs_inspected))),
        (
            "speculation_commits".into(),
            num(Metrics::get(&m.speculation_commits)),
        ),
        (
            "speculation_aborts".into(),
            num(Metrics::get(&m.speculation_aborts)),
        ),
        ("untrusted".into(), Json::Bool(state.untrusted)),
        // Live interned symbols. Bounded under cache churn now that
        // eviction releases an entry's symbols (the ROADMAP-flagged
        // monotonic growth, fixed and kept observable).
        (
            "symbols_interned".into(),
            num(crate::symbolic::intern_table_size() as u64),
        ),
        // Measured-latency cost-model feedback: the fuel-weighted
        // aggregate of per-kernel measured ÷ modeled ratios (1.0 = the
        // model is exact) and how many runs have fed it.
        ("model_drift".into(), Json::Num(cal.0)),
        ("cal_samples".into(), num(cal.1)),
        // Adaptive recompilation: drift-triggered background re-tunes
        // of cached artifacts, and how many ended up hot-swapped in.
        ("retunes".into(), num(Metrics::get(&m.retunes))),
        (
            "retunes_improved".into(),
            num(Metrics::get(&m.retunes_improved)),
        ),
        (
            "uptime_s".into(),
            Json::Num(state.started.elapsed().as_secs_f64()),
        ),
    ];
    // Hardware-counter availability: an explicit marker, so a scraper
    // can tell "no cache misses" apart from "cannot measure".
    match crate::obs::perf::status() {
        Ok(()) => fields.push(("hw_available".into(), Json::Bool(true))),
        Err(_) => {
            fields.push(("hw_available".into(), Json::Bool(false)));
            fields.push(("hw".into(), Json::Str("unavailable".into())));
        }
    }
    Json::Obj(fields).to_string()
}

/// The same counters in Prometheus text exposition format
/// (`GET /metrics?format=prometheus`), plus per-endpoint latency
/// histograms that the JSON document does not carry.
fn prometheus_body(state: &ServiceState) -> String {
    fn metric(out: &mut String, name: &str, kind: &str, help: &str, v: f64) {
        out.push_str(&format!(
            "# HELP {name} {help}\n# TYPE {name} {kind}\n{name} {v}\n"
        ));
    }
    let s = state.cache.stats();
    let m = &state.metrics;
    let g = |c: &std::sync::atomic::AtomicU64| Metrics::get(c) as f64;
    let mut out = String::new();
    let counters = [
        ("silo_cache_hits_total", s.hits as f64, "Compile cache hits."),
        ("silo_cache_misses_total", s.misses as f64, "Compile cache misses."),
        ("silo_cache_coalesced_total", s.coalesced as f64, "Coalesced builds."),
        ("silo_cache_evictions_total", s.evictions as f64, "Evicted entries."),
        ("silo_requests_total", g(&m.requests), "Requests routed."),
        ("silo_errors_total", g(&m.errors), "Non-200 responses."),
        ("silo_errors_client_total", g(&m.errors_client), "4xx responses."),
        ("silo_errors_server_total", g(&m.errors_server), "5xx responses."),
        ("silo_compiles_total", g(&m.compiles), "Builder runs."),
        ("silo_runs_total", g(&m.runs), "Completed /run executions."),
        ("silo_runs_proven_total", g(&m.runs_proven), "Proven-tier runs."),
        ("silo_runs_checked_total", g(&m.runs_checked), "Checked-tier runs."),
        ("silo_runs_inspected_total", g(&m.runs_inspected), "Inspector runs."),
        ("silo_rejected_total", g(&m.rejected), "Verifier refusals."),
        ("silo_trapped_total", g(&m.trapped), "Trapped runs."),
        ("silo_speculation_commits_total", g(&m.speculation_commits), "Chunks committed."),
        ("silo_speculation_aborts_total", g(&m.speculation_aborts), "Chunks aborted."),
        ("silo_retunes_total", g(&m.retunes), "Drift-triggered background re-tunes."),
        ("silo_retunes_improved_total", g(&m.retunes_improved), "Re-tunes hot-swapped in."),
    ];
    for (name, v, help) in counters {
        metric(&mut out, name, "counter", help, v);
    }
    metric(
        &mut out,
        "silo_cache_entries",
        "gauge",
        "Resident compiled kernels.",
        s.entries as f64,
    );
    metric(
        &mut out,
        "silo_symbols_interned",
        "gauge",
        "Live interned symbols.",
        crate::symbolic::intern_table_size() as f64,
    );
    let cal = {
        let c = state.cal.lock().unwrap();
        (c.ratio(), c.samples)
    };
    metric(
        &mut out,
        "silo_model_drift",
        "gauge",
        "Fuel-weighted measured/modeled cycles-per-iteration ratio (1 = exact).",
        cal.0,
    );
    metric(
        &mut out,
        "silo_cal_samples_total",
        "counter",
        "Runs folded into the cost-model calibration.",
        cal.1 as f64,
    );
    metric(
        &mut out,
        "silo_hw_available",
        "gauge",
        "1 when perf_event_open hardware counters work on this host, else 0.",
        if crate::obs::perf::available() { 1.0 } else { 0.0 },
    );
    metric(
        &mut out,
        "silo_uptime_seconds",
        "gauge",
        "Seconds since the daemon started.",
        state.started.elapsed().as_secs_f64(),
    );
    // Per-kernel observability: one labeled series per resident cache
    // entry that has actually been measured. Series appear only once a
    // sample exists — an unmeasured kernel must be *absent*, not 0.0.
    // Kernel names come from submissions, so label values are escaped
    // per the exposition format (backslash, quote, newline).
    fn prom_label(s: &str) -> String {
        s.chars()
            .flat_map(|c| match c {
                '\\' => vec!['\\', '\\'],
                '"' => vec!['\\', '"'],
                '\n' => vec!['\\', 'n'],
                c => vec![c],
            })
            .collect()
    }
    let entries = state.cache.entries();
    out.push_str(
        "# HELP silo_kernel_drift Per-kernel measured/modeled drift EWMA.\n\
         # TYPE silo_kernel_drift gauge\n",
    );
    for (_, k, _) in &entries {
        let c = *k.cal.lock().unwrap();
        if c.samples > 0 {
            out.push_str(&format!(
                "silo_kernel_drift{{kernel=\"{}\",id=\"{}\"}} {}\n",
                prom_label(&k.name),
                k.id,
                c.ratio
            ));
        }
    }
    out.push_str(
        "# HELP silo_kernel_hw_ipc Per-kernel instructions-per-cycle EWMA (hardware counters).\n\
         # TYPE silo_kernel_hw_ipc gauge\n",
    );
    for (_, k, _) in &entries {
        if let Some(ipc) = k.hw.lock().unwrap().ipc {
            out.push_str(&format!(
                "silo_kernel_hw_ipc{{kernel=\"{}\",id=\"{}\"}} {ipc}\n",
                prom_label(&k.name),
                k.id
            ));
        }
    }
    out.push_str(
        "# HELP silo_kernel_hw_miss_rate Per-kernel cache-miss-rate EWMA (hardware counters).\n\
         # TYPE silo_kernel_hw_miss_rate gauge\n",
    );
    for (_, k, _) in &entries {
        if let Some(mr) = k.hw.lock().unwrap().miss_rate {
            out.push_str(&format!(
                "silo_kernel_hw_miss_rate{{kernel=\"{}\",id=\"{}\"}} {mr}\n",
                prom_label(&k.name),
                k.id
            ));
        }
    }
    // Per-endpoint latency histograms: one metric family, one series
    // set per endpoint, cumulative le buckets per the exposition spec.
    out.push_str(
        "# HELP silo_request_duration_us Request latency by endpoint, microseconds.\n\
         # TYPE silo_request_duration_us histogram\n",
    );
    for (i, e) in Endpoint::ALL.iter().enumerate() {
        let h = m.latency[i].snapshot();
        let label = e.label();
        let mut cum = 0u64;
        for b in 0..crate::obs::BUCKETS {
            cum += h.counts[b];
            let le = crate::obs::hist::upper_edge(b);
            if le.is_finite() {
                out.push_str(&format!(
                    "silo_request_duration_us_bucket{{endpoint=\"{label}\",le=\"{le}\"}} {cum}\n"
                ));
            } else {
                out.push_str(&format!(
                    "silo_request_duration_us_bucket{{endpoint=\"{label}\",le=\"+Inf\"}} {cum}\n"
                ));
            }
        }
        out.push_str(&format!(
            "silo_request_duration_us_sum{{endpoint=\"{label}\"}} {}\n",
            h.sum_us
        ));
        out.push_str(&format!(
            "silo_request_duration_us_count{{endpoint=\"{label}\"}} {}\n",
            h.count
        ));
    }
    out
}

fn kernels_body(state: &ServiceState) -> String {
    let hw_ok = crate::obs::perf::available();
    let list: Vec<Json> = state
        .cache
        .entries()
        .into_iter()
        .map(|(_, k, hits)| {
            let mut fields = vec![
                ("id".into(), Json::Str(k.id.clone())),
                ("name".into(), Json::Str(k.name.clone())),
                ("pipeline".into(), Json::Str(k.spec.clone())),
                ("hits".into(), Json::Num(hits as f64)),
                ("compile_ms".into(), Json::Num(k.compile_ms)),
            ];
            let cal = *k.cal.lock().unwrap();
            if cal.samples > 0 {
                fields.push(("drift".into(), Json::Num(cal.ratio)));
                fields.push(("drift_samples".into(), Json::Num(cal.samples as f64)));
            }
            if hw_ok {
                let hw = k.hw.lock().unwrap();
                if let Some(ipc) = hw.ipc {
                    fields.push(("hw_ipc".into(), Json::Num(ipc)));
                }
                if let Some(mr) = hw.miss_rate {
                    fields.push(("hw_miss_rate".into(), Json::Num(mr)));
                }
            } else {
                // Explicit marker so a 0.0 miss rate can never mean
                // "could not measure" on locked-down hosts.
                fields.push(("hw".into(), Json::Str("unavailable".into())));
            }
            Json::Obj(fields)
        })
        .collect();
    Json::Arr(list).to_string()
}

/// Normalized spec string (the cache-key component): named configs print
/// their canonical name, pass lists their trimmed spelling.
fn normalize_spec(spec: &PipelineSpec) -> String {
    match spec {
        PipelineSpec::Config(c) => c.name().to_string(),
        PipelineSpec::Auto => "auto".to_string(),
        PipelineSpec::Custom(s) => s.trim().to_string(),
    }
}

fn compile_endpoint(req: &Request, state: &ServiceState) -> (u16, String) {
    // Bracket the whole parse+build against the symbol registry: the
    // final close drains deferred symbol releases, and no release can
    // happen while this (or any) compile is mid-parse.
    state.syms.begin_compile();
    let out = compile_endpoint_inner(req, state);
    state.syms.end_compile();
    out
}

fn compile_endpoint_inner(req: &Request, state: &ServiceState) -> (u16, String) {
    let body = match req.body_str() {
        Ok(b) => b,
        Err(e) => return (400, error_body(&format!("{e:#}"))),
    };
    let v = match Json::parse(body) {
        Ok(v) => v,
        Err(e) => return (400, error_body(&format!("malformed JSON body: {e}"))),
    };
    let creq = match CompileRequest::from_json(&v) {
        Ok(r) => r,
        Err(e) => return (400, error_body(&e)),
    };
    let spec = PipelineSpec::parse(&creq.pipeline);
    // Validate custom pass lists up front: a bad spec is the caller's
    // fault and must not occupy a cache slot or a build attempt.
    if let PipelineSpec::Custom(_) = &spec {
        if let Err(e) = spec.build(MemSchedules::default()) {
            return (400, error_body(&format!("{e:#}")));
        }
    }
    match compile_source_to_reply(state, &creq.source, &spec) {
        Ok(reply) => (200, reply.to_json().to_string()),
        Err(err) => err,
    }
}

/// Parse + cache-compile one SILO-Text module and shape the
/// [`CompileReply`] — the shared core of `POST /compile` and the
/// per-kernel compiles of `POST /extract`. The caller holds the
/// `begin_compile` symbol-registry bracket and has validated the spec.
fn compile_source_to_reply(
    state: &ServiceState,
    source: &str,
    spec: &PipelineSpec,
) -> Result<CompileReply, (u16, String)> {
    // Capture every symbol the parse interns; the entry (if one is
    // built) holds them, any other outcome hands them back to the
    // registry as release candidates.
    let scope = crate::symbolic::SymScope::begin();
    let parsed = match crate::frontend::parse_str(source) {
        Ok(p) => p,
        Err(e) => {
            state.syms.discard(&scope.finish());
            return Err((400, error_body(&e.to_string())));
        }
    };
    let parse_syms = scope.finish();
    // The safety policy is daemon-wide (one process is either trusted
    // or untrusted for its lifetime), so it needs no cache-key
    // component: every cached artifact was built under this policy.
    let policy = if state.untrusted {
        SafetyPolicy::Verified
    } else {
        SafetyPolicy::Trusted
    };
    let spec_name = normalize_spec(spec);
    let key = cache::kernel_key(&parsed, &spec_name);
    let id = cache::kernel_id(key);
    let (result, outcome, evicted) = state.cache.get_or_build_evicting(key, || {
        // The optimizer can intern fresh symbols of its own (tile/
        // privatization temporaries) — a nested scope captures those,
        // and the entry records both sets.
        let bscope = crate::symbolic::SymScope::begin();
        let t0 = Instant::now();
        // New builds compile under the daemon's live measured-latency
        // calibration. One shared scale never reorders one search's
        // candidates, so the cache key needs no calibration component —
        // a cached artifact is byte-identical either way.
        let compiled = match compile_program_calibrated(
            parsed.program.clone(),
            spec,
            MemSchedules::default(),
            policy,
            state.calibration(),
        ) {
            Ok(c) => c,
            Err(e) => {
                state.syms.discard(&bscope.finish());
                return Err(format!("{e:#}"));
            }
        };
        let wall = t0.elapsed();
        Metrics::bump(&state.metrics.compiles);
        Metrics::add_time(&state.metrics.compile_us_total, wall);
        let mut syms = parse_syms.clone();
        for (s, new) in bscope.finish() {
            match syms.iter_mut().find(|(x, _)| *x == s) {
                Some((_, n)) => *n |= new,
                None => syms.push((s, new)),
            }
        }
        // Retunes re-run the schedule search, so they only make sense
        // for autotuned artifacts — and they need the unoptimized nest
        // to search from (the cached program is already scheduled).
        let pristine = (state.retune_drift.is_some() && spec_name == "auto")
            .then(|| parsed.program.clone());
        Ok(ServedKernel {
            id: id.clone(),
            name: parsed.program.name.clone(),
            spec: spec_name.clone(),
            presets: parsed.presets.clone(),
            inits: parsed.inits.clone(),
            param_floors: parsed
                .program
                .params
                .iter()
                .map(|s| (*s, s.assumptions().min))
                .collect(),
            artifact: Mutex::new(Arc::new(compiled)),
            pristine,
            compile_ms: wall.as_secs_f64() * 1e3,
            syms,
            inspect_memo: Mutex::new(std::collections::HashMap::new()),
            cal: Mutex::new(crate::tuner::CalEwma::default()),
            hw: Mutex::new(HwStats::default()),
            retuning: AtomicBool::new(false),
        })
    });
    match outcome {
        // This call built and inserted the entry: it now holds its syms.
        Outcome::Miss if result.is_ok() => {
            if let Ok(k) = &result {
                state.syms.register(&k.syms);
            }
        }
        // Hit, coalesced, or failed build: this request's parse-time
        // interns are not held by any new entry.
        _ => state.syms.discard(&parse_syms),
    }
    state.syms.unregister(&evicted);
    let kernel = match result {
        Ok(k) => k,
        Err(e) => {
            // Verifier refusals are 422 with a machine-readable code so
            // clients can distinguish "your program is unsafe" from
            // "your request is malformed". The prefix is the shared
            // constant, so driver rewording cannot silently break this.
            if e.starts_with(crate::coordinator::REJECTED_PREFIX) {
                Metrics::bump(&state.metrics.rejected);
                return Err((422, error_body_code(&e, "rejected")));
            }
            return Err((400, error_body(&e)));
        }
    };
    let compiled = kernel.compiled();
    let reply = CompileReply {
        kernel: kernel.id.clone(),
        name: kernel.name.clone(),
        pipeline: kernel.spec.clone(),
        cached: outcome == Outcome::Hit,
        coalesced: outcome == Outcome::Coalesced,
        passes: compiled
            .pipeline
            .as_ref()
            .map(|r| r.log.iter().map(|l| (l.pass.clone(), l.detail.clone())).collect())
            .unwrap_or_default(),
        params: compiled.program.params.iter().map(|s| s.name().to_string()).collect(),
        arguments: compiled
            .program
            .containers
            .iter()
            .filter(|c| c.kind == ContainerKind::Argument)
            .map(|c| c.name.clone())
            .collect(),
        tier: compiled.tier.as_str().to_string(),
        unproven: compiled
            .verify
            .as_ref()
            .map(|r| r.unproven().len() as u64)
            .unwrap_or(0),
        fuel_bound: compiled
            .verify
            .as_ref()
            .and_then(|r| r.fuel_bound.as_ref())
            .map(|f| f.to_string()),
    };
    Ok(reply)
}

fn extract_endpoint(req: &Request, state: &ServiceState) -> (u16, String) {
    // Same symbol-registry bracket as /compile: the extractor's lifter
    // and round-trip parse intern symbols, and so does each per-kernel
    // compile below.
    state.syms.begin_compile();
    let out = extract_endpoint_inner(req, state);
    state.syms.end_compile();
    out
}

fn extract_endpoint_inner(req: &Request, state: &ServiceState) -> (u16, String) {
    let body = match req.body_str() {
        Ok(b) => b,
        Err(e) => return (400, error_body(&format!("{e:#}"))),
    };
    let v = match Json::parse(body) {
        Ok(v) => v,
        Err(e) => return (400, error_body(&format!("malformed JSON body: {e}"))),
    };
    let ereq = match ExtractRequest::from_json(&v) {
        Ok(r) => r,
        Err(e) => return (400, error_body(&e)),
    };
    let Some(lang) = crate::extract::lang_for_tag(&ereq.lang) else {
        return (
            400,
            error_body(&format!(
                "unknown `lang` `{}` (expected c, f/fixed, or f90/free)",
                ereq.lang
            )),
        );
    };
    let spec = PipelineSpec::parse(&ereq.pipeline);
    if let PipelineSpec::Custom(_) = &spec {
        if let Err(e) = spec.build(MemSchedules::default()) {
            return (400, error_body(&format!("{e:#}")));
        }
    }
    // The extraction itself (lifting + the round-trip re-parse) interns
    // symbols no cache entry will hold — discard them as release
    // candidates; each kernel's compile below re-interns what it needs
    // under its own scope, exactly like a direct /compile.
    let scope = crate::symbolic::SymScope::begin();
    let report = crate::extract::extract_source(&ereq.stem, &ereq.source, lang);
    state.syms.discard(&scope.finish());
    let mut kernels = Vec::new();
    for k in &report.kernels {
        // Extracted kernels re-parse by construction, so a failure here
        // is a genuine compile/verify outcome (e.g. an untrusted daemon
        // refusing a provably-oob nest) — surface it as-is.
        match compile_source_to_reply(state, &k.silo, &spec) {
            Ok(reply) => kernels.push(ExtractedKernelReply {
                compile: reply,
                silo: k.silo.clone(),
            }),
            Err(err) => return err,
        }
    }
    let reply = ExtractReply {
        kernels,
        skipped: report
            .skips
            .iter()
            .map(|s| SkipReply {
                line: s.line as u64,
                construct: s.construct.clone(),
                reason: s.reason.clone(),
            })
            .collect(),
    };
    (200, reply.to_json().to_string())
}

fn run_endpoint(req: &Request, state: &Arc<ServiceState>, id_str: &str) -> (u16, String) {
    let Some(key) = cache::parse_kernel_id(id_str) else {
        return (404, error_body(&format!("malformed kernel id `{id_str}`")));
    };
    let Some(kernel) = state.cache.touch(key) else {
        return (
            404,
            error_body(&format!(
                "unknown kernel id `{id_str}` (evicted or never compiled — resubmit \
                 via POST /compile)"
            )),
        );
    };
    let rreq = if req.body.is_empty() {
        RunRequest::default()
    } else {
        let parsed = match req.body_str().map_err(|e| format!("{e:#}")).and_then(|b| {
            Json::parse(b).map_err(|e| format!("malformed JSON body: {e}"))
        }) {
            Ok(v) => v,
            Err(e) => return (400, error_body(&e)),
        };
        match RunRequest::from_json(&parsed) {
            Ok(r) => r,
            Err(e) => return (400, error_body(&e)),
        }
    };
    match execute_run(&kernel, &rreq, state) {
        Ok(reply) => (200, reply.to_json().to_string()),
        Err((status, body)) => (status, body),
    }
}

/// Kick off at most one background re-tune of `kernel` (the observe→act
/// close of the calibration loop). The `retuning` latch makes the worker
/// single-flight per kernel; the latch clears — and the kernel's drift
/// EWMA resets — only when the worker finishes, so a crossing triggers
/// exactly one retune and post-swap drift re-accumulates against the
/// live artifact from scratch (the min-sample gate then stops an
/// immediate re-fire).
fn spawn_retune(state: &Arc<ServiceState>, kernel: &Arc<ServedKernel>) {
    if kernel
        .retuning
        .compare_exchange(false, true, Ordering::SeqCst, Ordering::SeqCst)
        .is_err()
    {
        return;
    }
    Metrics::bump(&state.metrics.retunes);
    let state = Arc::clone(state);
    let kernel = Arc::clone(kernel);
    std::thread::spawn(move || {
        // A panicking retune must neither take the daemon down nor wedge
        // the latch shut forever.
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            retune_kernel(&state, &kernel);
        }));
        *kernel.cal.lock().unwrap() = crate::tuner::CalEwma::default();
        kernel.retuning.store(false, Ordering::SeqCst);
    });
}

/// The retune body: re-run the schedule search from the pristine nest
/// under this kernel's *own* measured calibration, prove the re-tuned
/// artifact produces bitwise-identical outputs, and hot-swap it in. Any
/// failure on this path simply keeps the old artifact serving — a
/// background optimization must never degrade a working kernel.
fn retune_kernel(state: &ServiceState, kernel: &ServedKernel) {
    let Some(pristine) = &kernel.pristine else {
        return;
    };
    let policy = if state.untrusted {
        SafetyPolicy::Verified
    } else {
        SafetyPolicy::Trusted
    };
    let cal = kernel.cal.lock().unwrap().calibration();
    let old = kernel.compiled();
    // Bracket against the symbol registry so a concurrent eviction's
    // deferred symbol release cannot run mid-search. Symbols the search
    // interns (tile temporaries) are deliberately *not* scoped for
    // release: the swapped-in artifact holds them, and their names are
    // deterministic, so re-interning dedups and the table stays bounded.
    state.syms.begin_compile();
    let rebuilt = compile_program_calibrated(
        pristine.clone(),
        &PipelineSpec::Auto,
        MemSchedules::default(),
        policy,
        cal,
    );
    state.syms.end_compile();
    let Ok(new) = rebuilt else {
        return;
    };

    // Differential gate under the kernel's Tiny preset binding: no
    // binding, no proof, no swap.
    let mut params: Vec<(Sym, i64)> = Vec::new();
    for sym in &new.program.params {
        let bound = kernel
            .presets
            .iter()
            .find(|(s, _)| s == sym)
            .and_then(|(_, b)| b.get(Preset::Tiny));
        match bound {
            Some(v) => params.push((*sym, v)),
            None => return,
        }
    }
    let mut arg_data: Vec<(String, Vec<f64>)> = Vec::new();
    let mut total: i64 = 0;
    for c in &new.program.containers {
        let Ok(n) = eval_int(&c.size, &params) else {
            return;
        };
        total = total.checked_add(n).unwrap_or(i64::MAX);
        if !(0..=(1 << 28)).contains(&n) || total > (1 << 28) {
            return;
        }
        if c.kind != ContainerKind::Argument {
            continue;
        }
        let data = (0..n as usize)
            .map(|i| init_value_with(&kernel.inits, &c.name, i))
            .collect();
        arg_data.push((c.name.clone(), data));
    }
    // Old and new programs were optimized independently, so arguments
    // are matched by name, not container id.
    let bind = |prog: &crate::ir::Program| -> Option<Vec<(ContainerId, &[f64])>> {
        arg_data
            .iter()
            .map(|(name, v)| prog.container_by_name(name).map(|id| (id, v.as_slice())))
            .collect()
    };
    let (Some(old_refs), Some(new_refs)) = (bind(&old.program), bind(&new.program)) else {
        return;
    };
    let limits = ExecLimits {
        fuel: Some(state.fuel_limit),
        wall: Some(std::time::Duration::from_millis(state.wall_ms)),
    };
    let Ok((old_out, _, _, _)) = old.execute_limited_tier(Tier::Vm, &params, &old_refs, 1, &limits)
    else {
        return;
    };
    let Ok((new_out, _, _, _)) = new.execute_limited_tier(Tier::Vm, &params, &new_refs, 1, &limits)
    else {
        return;
    };
    for (name, _) in &arg_data {
        let (Some(a), Some(b)) = (old_out.by_name(name), new_out.by_name(name)) else {
            return;
        };
        let identical =
            a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits());
        if !identical {
            return;
        }
    }

    // Did the calibrated search actually find a better schedule, or just
    // re-confirm the old one? Counted either way the swap is safe — the
    // new artifact is the search's pick under the *measured* scale.
    let cm = crate::machine::clang();
    let node = crate::machine::intel_node();
    let improved = match (
        crate::tuner::schedule_cost_with(&old.program, &cm, &node, cal),
        crate::tuner::schedule_cost_with(&new.program, &cm, &node, cal),
    ) {
        (Ok(o), Ok(n)) => n.score < o.score,
        _ => false,
    };
    if improved {
        Metrics::bump(&state.metrics.retunes_improved);
    }
    *kernel.artifact.lock().unwrap() = Arc::new(new);
}

/// Bind params, materialize inputs, execute the cached VM, and shape the
/// reply. Pre-execution failures are caller errors (HTTP 400); checked
/// runs can additionally trap (HTTP 422 with a structured code).
fn execute_run(
    kernel: &Arc<ServedKernel>,
    rreq: &RunRequest,
    state: &Arc<ServiceState>,
) -> Result<RunReply, (u16, String)> {
    let caller = |m: String| (400u16, error_body(&m));
    let preset = Preset::parse(&rreq.preset).map_err(|e| caller(format!("{e:#}")))?;
    // One artifact snapshot for the whole request: a concurrent retune
    // swap must not change which artifact this run executes or reports.
    let compiled = kernel.compiled();
    let prog = &compiled.program;

    // Parameter bindings: explicit values win, preset annotations fill
    // the rest; anything unbound is an actionable error.
    let mut params: Vec<(Sym, i64)> = Vec::new();
    for sym in &prog.params {
        let name = sym.name();
        let explicit = rreq.params.iter().find(|(n, _)| n == name).map(|(_, v)| *v);
        let value = explicit.or_else(|| {
            kernel
                .presets
                .iter()
                .find(|(s, _)| s == sym)
                .and_then(|(_, b)| b.get(preset))
        });
        let Some(value) = value else {
            return Err(caller(format!(
                "param `{name}` has no {preset:?} preset binding and no explicit value; \
                 pass {{\"params\": {{\"{name}\": <int>}}}}"
            )));
        };
        // The optimizer's positivity assumptions were baked in at compile
        // time; a binding below the assumed floor would execute a program
        // whose analyses no longer hold. Compare against the floor
        // *snapshotted at compile time*, not the live global table.
        let floor = kernel
            .param_floors
            .iter()
            .find(|(s, _)| s == sym)
            .map(|(_, f)| *f)
            .unwrap_or(i64::MIN);
        if value < floor {
            return Err(caller(format!(
                "param `{name}` = {value} is below its assumed minimum {floor}"
            )));
        }
        params.push((*sym, value));
    }
    for (n, _) in &rreq.params {
        if !prog.params.iter().any(|s| s.name() == n.as_str()) {
            return Err(caller(format!("program `{}` has no param `{n}`", kernel.name)));
        }
    }

    // Inputs: explicit contents (size-checked) or the deterministic
    // default initializer with the kernel's `init(...)` annotations.
    // The *total* extent across all containers — transients included —
    // is capped, since the VM allocates everything up front and an
    // oversized request must come back as a 400, not abort the daemon
    // in the allocator.
    let mut inputs: Vec<(ContainerId, Vec<f64>)> = Vec::new();
    let mut total_elems: i64 = 0;
    for c in &prog.containers {
        let n = eval_int(&c.size, &params).map_err(|e| caller(format!("{e:#}")))?;
        // Checked arithmetic: size polynomials over caller-chosen params
        // can wrap i64, which must read as "too big", not sneak under
        // the cap.
        let total = total_elems.checked_add(n).unwrap_or(i64::MAX);
        if !(0..=(1 << 28)).contains(&n) || total > (1 << 28) {
            return Err(caller(format!(
                "container `{}` holds {n} elements under these params ({total} total); \
                 the service caps one run's allocation at 2^28 elements",
                c.name
            )));
        }
        total_elems = total;
        if c.kind != ContainerKind::Argument {
            continue;
        }
        let n = n as usize;
        let data = match rreq.inputs.iter().find(|(name, _)| *name == c.name) {
            Some((_, provided)) => {
                if provided.len() != n {
                    return Err(caller(format!(
                        "input `{}` has {} elements, expected {n}",
                        c.name,
                        provided.len()
                    )));
                }
                provided.clone()
            }
            None => (0..n).map(|i| init_value_with(&kernel.inits, &c.name, i)).collect(),
        };
        inputs.push((c.id, data));
    }
    for (n, _) in &rreq.inputs {
        if !prog
            .containers
            .iter()
            .any(|c| c.kind == ContainerKind::Argument && c.name == *n)
        {
            return Err(caller(format!(
                "program `{}` has no argument container `{n}`",
                kernel.name
            )));
        }
    }

    // Requested outputs must name argument containers.
    let arg_names: Vec<&str> = prog
        .containers
        .iter()
        .filter(|c| c.kind == ContainerKind::Argument)
        .map(|c| c.name.as_str())
        .collect();
    if let Some(outs) = &rreq.outputs {
        for n in outs {
            if !arg_names.contains(&n.as_str()) {
                return Err(caller(format!(
                    "no argument container `{n}` (available: {})",
                    arg_names.join(", ")
                )));
            }
        }
    }

    let refs: Vec<(ContainerId, &[f64])> =
        inputs.iter().map(|(c, v)| (*c, v.as_slice())).collect();
    let threads = rreq.threads.clamp(1, 8);
    // Backend: per-request choice wins, else the daemon default. Unknown
    // strings are caller errors; an unavailable JIT is not (the tier
    // call degrades and the reply says what ran).
    let backend = match &rreq.backend {
        Some(s) => Tier::parse(s).map_err(caller)?,
        None => state.backend,
    };
    // Untrusted daemons meter every run; trusted daemons run unlimited.
    let limits = if state.untrusted {
        ExecLimits {
            fuel: Some(state.fuel_limit),
            wall: Some(std::time::Duration::from_millis(state.wall_ms)),
        }
    } else {
        ExecLimits::none()
    };
    // Structured traps (bounds/fuel/wall) are 422 with a code; anything
    // else on the execution path is a caller error.
    let trap_err = |e: anyhow::Error| match e.downcast_ref::<Trap>() {
        Some(trap) => {
            Metrics::bump(&state.metrics.trapped);
            (422u16, error_body_code(&format!("{e:#}"), trap.code()))
        }
        None => caller(format!("{e:#}")),
    };
    // The speculative tier returns its commit/abort accounting alongside
    // the storage; the other tiers go through the common dispatch. A
    // kernel with no speculation candidates degrades to the VM and the
    // reply says so, mirroring the native-tier convention.
    // Hardware counters around the execution, where the host allows
    // them. A failed open/start degrades to "no sample" — the /kernels
    // and /metrics expositions mark the whole host `hw: unavailable`
    // via the probe, so absence is explicit rather than zero.
    let hw_group = crate::obs::perf::available()
        .then(|| {
            crate::obs::HwGroup::open()
                .and_then(|g| g.start().map(|()| g))
                .ok()
        })
        .flatten();
    let (storage, wall, fuel_used, ran_on, spec_stats) = if backend == Tier::Speculative {
        let (storage, wall, fuel, stats) = compiled
            .execute_speculative(&params, &refs, threads, &limits)
            .map_err(|e| trap_err(e))?;
        let ran = if compiled.spec.is_some() { Tier::Speculative } else { Tier::Vm };
        (storage, wall, fuel, ran, Some(stats))
    } else {
        let (storage, wall, fuel, ran) = compiled
            .execute_limited_tier(backend, &params, &refs, threads, &limits)
            .map_err(|e| trap_err(e))?;
        (storage, wall, fuel, ran, None)
    };
    if let Some(counts) = hw_group.and_then(|g| g.stop().ok()) {
        kernel.hw.lock().unwrap().fold(&counts);
    }
    Metrics::bump(&state.metrics.runs);
    Metrics::add_time(&state.metrics.run_us_total, wall);
    match compiled.tier {
        SafetyTier::Proven => Metrics::bump(&state.metrics.runs_proven),
        SafetyTier::Checked => Metrics::bump(&state.metrics.runs_checked),
        SafetyTier::Trusted => {}
    }
    if let Some(st) = &spec_stats {
        state.metrics.speculation_commits.fetch_add(st.commits, Ordering::Relaxed);
        state.metrics.speculation_aborts.fetch_add(st.aborts, Ordering::Relaxed);
    }
    // Measured-latency feedback: this run's observed cycles per
    // iteration (wall × node GHz ÷ back-edges) over the artifact's
    // modeled cycles per iteration. The ratio folds into this kernel's
    // *own* drift EWMA (keyed by content id — it calibrates retunes of
    // this artifact and surfaces as `drift` in /kernels) and into the
    // fuel-weighted daemon aggregate behind the `model_drift` gauge.
    // When retuning is armed, a settled EWMA outside [1/R, R] kicks off
    // the single-flight background retune of this artifact.
    if fuel_used > 0 && compiled.modeled_cycles_per_iter > 0.0 {
        let node = crate::machine::intel_node();
        let measured = wall.as_secs_f64() * node.ghz * 1e9 / fuel_used as f64;
        let ratio = measured / compiled.modeled_cycles_per_iter;
        if ratio.is_finite() && ratio > 0.0 {
            Metrics::bump(&state.metrics.cal_samples);
            state.cal.lock().unwrap().fold(ratio, fuel_used);
            let settled = {
                let mut cal = kernel.cal.lock().unwrap();
                cal.fold(ratio);
                *cal
            };
            if let Some(threshold) = state.retune_drift {
                let drifted =
                    settled.ratio >= threshold || settled.ratio <= 1.0 / threshold;
                if settled.samples >= state.retune_min && drifted && kernel.pristine.is_some()
                {
                    spawn_retune(state, kernel);
                }
            }
        }
    }
    // Inspector: certify this binding's sequential loops, memoized per
    // canonical parameter string on the cache entry.
    let inspector = if rreq.inspector {
        Metrics::bump(&state.metrics.runs_inspected);
        let key: String = params
            .iter()
            .map(|(s, v)| format!("{}={v}", s.name()))
            .collect::<Vec<_>>()
            .join(",");
        let memoized = kernel.inspect_memo.lock().unwrap().get(&key).cloned();
        let lines = match memoized {
            Some(l) => l,
            None => {
                let rep = crate::inspect::inspect_program(
                    &compiled.program,
                    &params,
                    crate::inspect::DEFAULT_BUDGET,
                );
                let fresh: Arc<Vec<String>> = Arc::new(
                    rep.loops
                        .iter()
                        .map(|l| {
                            format!("L{} {}: {}", l.loop_id.0, l.var.name(), l.certificate.label())
                        })
                        .collect(),
                );
                Arc::clone(
                    kernel
                        .inspect_memo
                        .lock()
                        .unwrap()
                        .entry(key)
                        .or_insert(fresh),
                )
            }
        };
        Some(lines.as_ref().clone())
    } else {
        None
    };

    let wanted = |name: &str| match &rreq.outputs {
        Some(outs) => outs.iter().any(|n| n == name),
        None => true,
    };
    let outputs: Vec<(String, Vec<f64>)> = prog
        .containers
        .iter()
        .filter(|c| c.kind == ContainerKind::Argument && wanted(&c.name))
        .map(|c| (c.name.clone(), storage.arrays[c.id.0 as usize].clone()))
        .collect();
    Ok(RunReply {
        kernel: kernel.id.clone(),
        name: kernel.name.clone(),
        wall_ms: wall.as_secs_f64() * 1e3,
        fuel_used: state.untrusted.then_some(fuel_used),
        backend: ran_on.as_str().to_string(),
        speculation: spec_stats.map(|s| (s.attempted, s.commits, s.aborts)),
        inspector,
        outputs,
    })
}
